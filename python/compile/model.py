"""Layer 2: GPT-2-style decoder transformer + fused train step (build-time
JAX, calling the Layer-1 Pallas kernels).

The whole training state lives in ONE flat f32 vector so the rust runtime
can chain steps on-device without knowing the parameter pytree:

    state = [ params (P) | adam_m (P) | adam_v (P) | step | loss ]   (S = 3P+2)

`train_step(state, tokens) -> state'` is the single computation the AOT
path lowers; `init_state() -> state` seeds it deterministically.

Architecture (pre-LN GPT-2):
  wte [V,h] · wpe [T,h] · L × { ln1, qkv [h,3h]+[3h], proj [h,h]+[h],
  ln2, mlp w1 [h,4h]+[4h], w2 [4h,h]+[h] } · ln_f · tied LM head.
Attention uses `kernels.flash_attention`, the MLP uses `kernels.fused_mlp`,
and the optimizer is the fused `kernels.adamw` Pallas kernel.
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.adamw import adamw_update
from .kernels.flash_attention import flash_attention
from .kernels.fused_mlp import fused_mlp

INIT_SEED = 42
INIT_STD = 0.02


@dataclasses.dataclass(frozen=True)
class GptConfig:
    name: str
    vocab: int
    hidden: int
    layers: int
    heads: int
    seq_len: int
    batch: int

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads


# The AOT model variants (tiny stand-ins for the CPU runtime; matching
# entries exist in the rust model zoo).
CONFIGS: Dict[str, GptConfig] = {
    "gpt2-tiny": GptConfig("gpt2-tiny", vocab=1024, hidden=128, layers=4, heads=4, seq_len=128, batch=8),
    "gpt2-mini": GptConfig("gpt2-mini", vocab=4096, hidden=256, layers=6, heads=8, seq_len=256, batch=4),
}


def param_shapes(cfg: GptConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat layout."""
    h, hf = cfg.hidden, 4 * cfg.hidden
    shapes: List[Tuple[str, Tuple[int, ...]]] = [
        ("wte", (cfg.vocab, h)),
        ("wpe", (cfg.seq_len, h)),
    ]
    for i in range(cfg.layers):
        shapes += [
            (f"l{i}.ln1.g", (h,)),
            (f"l{i}.ln1.b", (h,)),
            (f"l{i}.qkv.w", (h, 3 * h)),
            (f"l{i}.qkv.b", (3 * h,)),
            (f"l{i}.proj.w", (h, h)),
            (f"l{i}.proj.b", (h,)),
            (f"l{i}.ln2.g", (h,)),
            (f"l{i}.ln2.b", (h,)),
            (f"l{i}.mlp.w1", (h, hf)),
            (f"l{i}.mlp.b1", (hf,)),
            (f"l{i}.mlp.w2", (hf, h)),
            (f"l{i}.mlp.b2", (h,)),
        ]
    shapes += [("ln_f.g", (h,)), ("ln_f.b", (h,))]
    return shapes


def param_count(cfg: GptConfig) -> int:
    import math

    return sum(math.prod(s) for _, s in param_shapes(cfg))


def state_len(cfg: GptConfig) -> int:
    return 3 * param_count(cfg) + 2


def _unflatten(cfg: GptConfig, flat: jax.Array) -> Dict[str, jax.Array]:
    params = {}
    off = 0
    for name, shape in param_shapes(cfg):
        n = 1
        for s in shape:
            n *= s
        params[name] = flat[off : off + n].reshape(shape)
        off += n
    return params


def init_params_flat(cfg: GptConfig) -> jax.Array:
    """Deterministic init: N(0, 0.02) for matrices/embeddings, zeros for
    biases, ones for layernorm gains."""
    key = jax.random.PRNGKey(INIT_SEED)
    chunks = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".b", ".b1", ".b2")):
            chunks.append(jnp.zeros(shape, jnp.float32).ravel())
        elif name.endswith(".g"):
            chunks.append(jnp.ones(shape, jnp.float32).ravel())
        else:
            chunks.append((INIT_STD * jax.random.normal(sub, shape, jnp.float32)).ravel())
    return jnp.concatenate(chunks)


def init_state(cfg: GptConfig) -> jax.Array:
    p = init_params_flat(cfg)
    zeros = jnp.zeros_like(p)
    tail = jnp.zeros((2,), jnp.float32)  # [step, loss]
    return jnp.concatenate([p, zeros, zeros, tail])


def forward(cfg: GptConfig, params: Dict[str, jax.Array], tokens: jax.Array) -> jax.Array:
    """Logits [B, T, V] for int32 tokens [B, T]."""
    b, t = tokens.shape
    x = params["wte"][tokens] + params["wpe"][:t][None, :, :]
    for i in range(cfg.layers):
        # --- attention block (pre-LN) ---
        ln1 = ref.layernorm_ref(x, params[f"l{i}.ln1.g"], params[f"l{i}.ln1.b"])
        qkv = ln1 @ params[f"l{i}.qkv.w"] + params[f"l{i}.qkv.b"]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(z):
            return (
                z.reshape(b, t, cfg.heads, cfg.head_dim)
                .transpose(0, 2, 1, 3)
                .reshape(b * cfg.heads, t, cfg.head_dim)
            )

        attn = flash_attention(heads(q), heads(k), heads(v), True)
        attn = (
            attn.reshape(b, cfg.heads, t, cfg.head_dim)
            .transpose(0, 2, 1, 3)
            .reshape(b, t, cfg.hidden)
        )
        x = x + attn @ params[f"l{i}.proj.w"] + params[f"l{i}.proj.b"]
        # --- MLP block ---
        ln2 = ref.layernorm_ref(x, params[f"l{i}.ln2.g"], params[f"l{i}.ln2.b"])
        y = fused_mlp(
            ln2.reshape(b * t, cfg.hidden),
            params[f"l{i}.mlp.w1"],
            params[f"l{i}.mlp.b1"],
            params[f"l{i}.mlp.w2"],
            params[f"l{i}.mlp.b2"],
        ).reshape(b, t, cfg.hidden)
        x = x + y
    x = ref.layernorm_ref(x, params["ln_f.g"], params["ln_f.b"])
    return x @ params["wte"].T  # tied LM head


def loss_fn(cfg: GptConfig, flat_params: jax.Array, tokens: jax.Array) -> jax.Array:
    """Next-token cross-entropy (positions 0..T-2 predict 1..T-1)."""
    params = _unflatten(cfg, flat_params)
    logits = forward(cfg, params, tokens)[:, :-1, :]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def train_step(cfg: GptConfig, state: jax.Array, tokens: jax.Array) -> jax.Array:
    """One fused step: fwd + bwd + Pallas-AdamW; returns the new state."""
    p_count = param_count(cfg)
    p = state[:p_count]
    m = state[p_count : 2 * p_count]
    v = state[2 * p_count : 3 * p_count]
    step = state[3 * p_count]

    loss, grads = jax.value_and_grad(lambda fp: loss_fn(cfg, fp, tokens))(p)
    new_p, new_m, new_v = adamw_update(p, m, v, grads, step + 1.0)
    tail = jnp.stack([step + 1.0, loss])
    return jnp.concatenate([new_p, new_m, new_v, tail])
