"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these to tight tolerances. They are also used as the
custom-VJP backward bodies where noted in the kernel files.
"""

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True) -> jax.Array:
    """Scaled dot-product attention over [..., T, D] with optional causal mask."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    scores = jnp.einsum("...qd,...kd->...qk", q, k) * scale
    if causal:
        t = q.shape[-2]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", probs, v)


def gelu_ref(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU (GPT-2's flavor)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def mlp_ref(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array, b2: jax.Array) -> jax.Array:
    """GPT-2 MLP: gelu(x @ w1 + b1) @ w2 + b2."""
    return gelu_ref(x @ w1 + b1) @ w2 + b2


def adamw_ref(p, m, v, g, step, lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8, wd=0.0):
    """AdamW update on flat vectors; `step` is the 1-based step index."""
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / (1.0 - beta1**step)
    vhat = v2 / (1.0 - beta2**step)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p2, m2, v2


def layernorm_ref(x, gamma, beta, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
