"""Fused AdamW optimizer update as a Pallas kernel.

The optimizer step is the elementwise hot-spot of the training loop (it
touches 3 state vectors + the gradient for every parameter — exactly the
20-bytes/param traffic MARP's static term models). Fusing
moment-update + bias-correction + parameter-update into one kernel makes it
a single HBM pass instead of ~8 (one per jnp op).

The flat vectors are tiled into VMEM blocks of `BLOCK` elements (8·128-lane
aligned); the step counter arrives as a scalar operand broadcast to every
grid step. No custom VJP is needed: the optimizer runs outside `jax.grad`.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 16 * 1024

LR = 1e-3
BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8
WD = 0.0


def _adamw_kernel(step_ref, p_ref, m_ref, v_ref, g_ref, po_ref, mo_ref, vo_ref):
    t = step_ref[0]
    p = p_ref[...]
    g = g_ref[...]
    m = BETA1 * m_ref[...] + (1.0 - BETA1) * g
    v = BETA2 * v_ref[...] + (1.0 - BETA2) * g * g
    mhat = m / (1.0 - BETA1**t)
    vhat = v / (1.0 - BETA2**t)
    po_ref[...] = p - LR * (mhat / (jnp.sqrt(vhat) + EPS) + WD * p)
    mo_ref[...] = m
    vo_ref[...] = v


def adamw_update(p, m, v, g, step):
    """One fused AdamW step over flat f32 vectors.

    `step` is the 1-based step count as a float scalar (bias correction).
    Returns (p', m', v').
    """
    (n,) = p.shape
    blk = min(BLOCK, n)
    n_pad = (n + blk - 1) // blk * blk
    pad = lambda x: jnp.pad(x, (0, n_pad - n))
    step_arr = jnp.reshape(step.astype(p.dtype), (1,))
    vec = pl.BlockSpec((blk,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    outs = pl.pallas_call(
        _adamw_kernel,
        grid=(n_pad // blk,),
        in_specs=[scalar, vec, vec, vec, vec],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((n_pad,), p.dtype)] * 3,
        interpret=True,
    )(step_arr, pad(p), pad(m), pad(v), pad(g))
    return tuple(o[:n] for o in outs)
