"""Fused transformer MLP (matmul → GELU → matmul) as a Pallas kernel.

TPU framing: the kernel streams row-blocks of the [N, h] activation matrix
through VMEM while both weight matrices stay VMEM-resident, so the
intermediate [rows, 4h] GELU activation never hits HBM — on GPU this is the
"fuse the epilogue" trick; on TPU it is a BlockSpec over rows with the MXU
doing back-to-back [rows, h]×[h, 4h] and [rows, 4h]×[4h, h] matmuls.

Backward uses a recompute VJP in plain jnp (`ref.mlp_ref`): the fused
forward discards the intermediate, so backward recomputes it — the same
memory/compute trade Korthikanti et al. analyze (and the basis of the
activation term MARP predicts).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Rows per grid step; (8,128)-aligned for the TPU VPU lanes.
ROW_BLOCK = 128


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]
    h1 = jnp.dot(x, w1_ref[...]) + b1_ref[...]
    c = jnp.sqrt(2.0 / jnp.pi).astype(h1.dtype)
    g = 0.5 * h1 * (1.0 + jnp.tanh(c * (h1 + 0.044715 * h1**3)))
    o_ref[...] = (jnp.dot(g, w2_ref[...]) + b2_ref[...]).astype(o_ref.dtype)


def _fwd_call(x, w1, b1, w2, b2):
    n, h = x.shape
    hf = w1.shape[1]
    rb = min(ROW_BLOCK, n)
    # Pad rows to a multiple of the block.
    n_pad = (n + rb - 1) // rb * rb
    x_p = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    grid = (n_pad // rb,)
    out = pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, h), lambda i: (i, 0)),
            pl.BlockSpec((h, hf), lambda i: (0, 0)),
            pl.BlockSpec((hf,), lambda i: (0,)),
            pl.BlockSpec((hf, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, h), x.dtype),
        interpret=True,
    )(x_p, w1, b1, w2, b2)
    return out[:n]


@jax.custom_vjp
def fused_mlp(x, w1, b1, w2, b2):
    """gelu(x @ w1 + b1) @ w2 + b2 over [N, h] rows."""
    return _fwd_call(x, w1, b1, w2, b2)


def _vjp_fwd(x, w1, b1, w2, b2):
    return _fwd_call(x, w1, b1, w2, b2), (x, w1, b1, w2, b2)


def _vjp_bwd(res, dy):
    x, w1, b1, w2, b2 = res
    # Recompute-in-backward against the reference formula.
    _, vjp = jax.vjp(ref.mlp_ref, x, w1, b1, w2, b2)
    return vjp(dy)


fused_mlp.defvjp(_vjp_fwd, _vjp_bwd)
