"""Fused causal attention as a Pallas kernel (Layer 1).

TPU-style design (DESIGN.md §Hardware-Adaptation): one grid step per
(batch·head), with the head's Q/K/V tiles resident in VMEM and the
score/softmax/weighted-sum pipeline fused so the [T, T] score matrix never
round-trips to HBM — the same insight FlashAttention expresses with CUDA
shared memory/threadblocks, re-expressed with BlockSpec + VMEM. The MXU
sees two [T, D]×[D, T]-shaped matmuls per head.

For the sequence lengths the AOT models use (T ≤ 256) a head's working set
is ≤ (3·T·D + T·T) · 4 B ≈ 0.5 MiB, comfortably inside a TPU core's
~16 MiB VMEM; longer sequences would add an online-softmax loop over KV
blocks (see DESIGN.md §Perf for the VMEM budget table).

`interpret=True` is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.

Differentiation: wrapped in `jax.custom_vjp`; the backward pass is also a
Pallas kernel (dQ/dK/dV via score recomputation — the FlashAttention-style
recompute-in-backward trade).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _causal_mask(scores):
    t = scores.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    return jnp.where(row >= col, scores, NEG_INF)


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool):
    # One (batch·head) per grid step; block refs are [1, T, D] VMEM tiles.
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.dot(q, k.T) * scale  # [T, T] stays in VMEM
    if causal:
        scores = _causal_mask(scores)
    # Numerically stable softmax, fused in-register.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v).astype(o_ref.dtype)


def _attn_bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref, *, causal: bool):
    # Recompute probabilities (FlashAttention-style), then the standard VJP.
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.dot(q, k.T) * scale
    if causal:
        scores = _causal_mask(scores)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)  # [T, T]
    dv = jnp.dot(p.T, do)
    dp = jnp.dot(do, v.T)
    # softmax VJP: dS = P ⊙ (dP − rowsum(dP ⊙ P))
    ds = (p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))) * scale
    dq_ref[0] = jnp.dot(ds, k).astype(dq_ref.dtype)
    dk_ref[0] = jnp.dot(ds.T, q).astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _fwd_call(q, k, v, causal: bool):
    bh, t, d = q.shape
    spec = pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))
    kernel = functools.partial(_attn_fwd_kernel, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        interpret=True,
    )(q, k, v)


def _bwd_call(q, k, v, do, causal: bool):
    bh, t, d = q.shape
    spec = pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))
    kernel = functools.partial(_attn_bwd_kernel, causal=causal)
    shapes = [jax.ShapeDtypeStruct((bh, t, d), q.dtype)] * 3
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=shapes,
        interpret=True,
    )(q, k, v, do)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q, k, v, causal: bool = True):
    """Fused attention over [BH, T, D] (batch·heads flattened)."""
    return _fwd_call(q, k, v, causal)


def _vjp_fwd(q, k, v, causal):
    return _fwd_call(q, k, v, causal), (q, k, v)


def _vjp_bwd(causal, res, do):
    q, k, v = res
    return _bwd_call(*res, do, causal)


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
