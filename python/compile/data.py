"""Deterministic synthetic token stream.

MUST match `rust/src/runtime/mod.rs::synth_tokens` exactly — the rust
integration tests replay training and compare losses against the python
oracle recorded in the manifest, so both sides must feed identical data.

The stream is next-token predictable (token[t+1] = token[t] + 13 mod V), so
a language model trained on it shows a cleanly decreasing loss curve.
"""

import numpy as np


def synth_tokens(batch: int, seq: int, vocab: int, step: int) -> np.ndarray:
    """tokens[i, j] = (7*i + 13*j + 17*step) % vocab, int32 [batch, seq]."""
    i = np.arange(batch, dtype=np.int64)[:, None]
    j = np.arange(seq, dtype=np.int64)[None, :]
    toks = (7 * i + 13 * j + 17 * int(step)) % int(vocab)
    return toks.astype(np.int32)
