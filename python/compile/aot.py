"""AOT compile path: lower init/train-step to HLO **text** + manifest.

Run once via `make artifacts` (python never touches the request path):

    cd python && python -m compile.aot --out ../artifacts

Per model variant this emits
  <name>_init.hlo.txt   () -> f32[S]
  <name>_step.hlo.txt   (f32[S], i32[B,T]) -> f32[S]
plus `manifest.json` with shapes and the **oracle losses** — the first k
losses of the python reference execution on the deterministic token stream,
which the rust integration tests must reproduce through PJRT.

HLO *text* (not `.serialize()`): jax ≥ 0.5 emits protos with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .data import synth_tokens

ORACLE_STEPS = 3
ORACLE_TOL = 2e-3


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_model(cfg: model.GptConfig, out_dir: str) -> dict:
    """Lower one variant; returns its manifest entry."""
    s_len = model.state_len(cfg)
    state_spec = jax.ShapeDtypeStruct((s_len,), jnp.float32)
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)

    init_fn = functools.partial(model.init_state, cfg)
    step_fn = functools.partial(model.train_step, cfg)
    # Tiny probe: read back [step, loss] without copying the whole state
    # (CPU PJRT 0.5.1 has no CopyRawToHost, so the rust side executes this
    # 2-element slice instead of an offset host read).
    probe_fn = lambda state: state[-2:]

    init_hlo = to_hlo_text(jax.jit(init_fn).lower())
    step_hlo = to_hlo_text(jax.jit(step_fn).lower(state_spec, tok_spec))
    probe_hlo = to_hlo_text(jax.jit(probe_fn).lower(state_spec))

    base = cfg.name.replace("-", "_")
    init_path = f"{base}_init.hlo.txt"
    step_path = f"{base}_step.hlo.txt"
    probe_path = f"{base}_probe.hlo.txt"
    for path, text in [(init_path, init_hlo), (step_path, step_hlo), (probe_path, probe_hlo)]:
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)

    # Oracle: run the jitted step on the python side for k steps.
    jit_step = jax.jit(step_fn)
    state = jax.jit(init_fn)()
    losses = []
    for s in range(ORACLE_STEPS):
        tokens = jnp.asarray(synth_tokens(cfg.batch, cfg.seq_len, cfg.vocab, s))
        state = jit_step(state, tokens)
        losses.append(float(state[-1]))

    return {
        "init_hlo": init_path,
        "step_hlo": step_path,
        "probe_hlo": probe_path,
        "state_len": s_len,
        "param_count": model.param_count(cfg),
        "batch": cfg.batch,
        "seq_len": cfg.seq_len,
        "vocab": cfg.vocab,
        "hidden": cfg.hidden,
        "layers": cfg.layers,
        "heads": cfg.heads,
        "oracle_losses": losses,
        "oracle_tol": ORACLE_TOL,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument(
        "--models",
        default="gpt2-tiny,gpt2-mini",
        help="comma-separated variant names (see compile.model.CONFIGS)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"models": {}}
    for name in args.models.split(","):
        name = name.strip()
        if name not in model.CONFIGS:
            print(f"unknown model '{name}' (have {list(model.CONFIGS)})", file=sys.stderr)
            sys.exit(2)
        cfg = model.CONFIGS[name]
        print(f"lowering {name} (P={model.param_count(cfg)}, S={model.state_len(cfg)}) ...")
        entry = lower_model(cfg, args.out)
        manifest["models"][name] = entry
        print(f"  oracle losses: {['%.4f' % l for l in entry['oracle_losses']]}")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
