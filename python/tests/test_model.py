"""Layer-2 model checks: shapes, flat-state layout, loss behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.data import synth_tokens

CFG = model.CONFIGS["gpt2-tiny"]


def small_cfg():
    return model.GptConfig("unit", vocab=97, hidden=32, layers=2, heads=4, seq_len=16, batch=2)


def test_param_count_matches_layout():
    cfg = small_cfg()
    flat = model.init_params_flat(cfg)
    assert flat.shape == (model.param_count(cfg),)
    params = model._unflatten(cfg, flat)
    assert params["wte"].shape == (97, 32)
    assert params["l1.mlp.w1"].shape == (32, 128)
    total = sum(int(np.prod(p.shape)) for p in params.values())
    assert total == model.param_count(cfg)


def test_state_layout():
    cfg = small_cfg()
    state = model.init_state(cfg)
    p = model.param_count(cfg)
    assert state.shape == (3 * p + 2,)
    assert float(state[-1]) == 0.0  # loss slot
    assert float(state[-2]) == 0.0  # step slot
    # optimizer moments start at zero
    assert float(jnp.abs(state[p : 3 * p]).max()) == 0.0


def test_forward_shapes_and_finiteness():
    cfg = small_cfg()
    params = model._unflatten(cfg, model.init_params_flat(cfg))
    toks = jnp.asarray(synth_tokens(cfg.batch, cfg.seq_len, cfg.vocab, 0))
    logits = model.forward(cfg, params, toks)
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_uniform():
    cfg = small_cfg()
    flat = model.init_params_flat(cfg)
    toks = jnp.asarray(synth_tokens(cfg.batch, cfg.seq_len, cfg.vocab, 0))
    loss = model.loss_fn(cfg, flat, toks)
    expect = np.log(cfg.vocab)
    assert abs(float(loss) - expect) < 0.3, (float(loss), expect)


def test_train_step_decreases_loss():
    cfg = small_cfg()
    state = jax.jit(lambda: model.init_state(cfg))()
    step = jax.jit(lambda s, t: model.train_step(cfg, s, t))
    losses = []
    for s in range(12):
        toks = jnp.asarray(synth_tokens(cfg.batch, cfg.seq_len, cfg.vocab, s))
        state = step(state, toks)
        losses.append(float(state[-1]))
    assert losses[-1] < losses[0] - 0.2, losses
    assert float(state[-2]) == 12.0  # step counter advanced


def test_train_step_deterministic():
    cfg = small_cfg()
    run = lambda: _run_steps(cfg, 3)
    assert run() == run()


def _run_steps(cfg, n):
    state = jax.jit(lambda: model.init_state(cfg))()
    step = jax.jit(lambda s, t: model.train_step(cfg, s, t))
    out = []
    for s in range(n):
        toks = jnp.asarray(synth_tokens(cfg.batch, cfg.seq_len, cfg.vocab, s))
        state = step(state, toks)
        out.append(float(state[-1]))
    return out


def test_gradients_flow_to_all_params():
    cfg = small_cfg()
    flat = model.init_params_flat(cfg)
    toks = jnp.asarray(synth_tokens(cfg.batch, cfg.seq_len, cfg.vocab, 0))
    g = jax.grad(lambda fp: model.loss_fn(cfg, fp, toks))(flat)
    assert bool(jnp.isfinite(g).all())
    # Every parameter tensor must receive some gradient signal.
    off = 0
    for name, shape in model.param_shapes(cfg):
        n = int(np.prod(shape))
        seg = g[off : off + n]
        if name != "wpe":  # positions beyond seq_len-1... wpe fully used here
            assert float(jnp.abs(seg).max()) > 0.0, f"no gradient into {name}"
        off += n


def test_aot_configs_match_rust_zoo_names():
    # The rust model zoo must contain matching tiny configs (used by the
    # serverless runtime mapping).
    for name, cfg in model.CONFIGS.items():
        assert name in ("gpt2-tiny", "gpt2-mini")
        assert cfg.hidden % cfg.heads == 0
