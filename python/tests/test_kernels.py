"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles, swept with
hypothesis over shapes and (where meaningful) dtypes. THE core correctness
signal for the kernels the AOT path bakes into the artifacts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.adamw import adamw_update
from compile.kernels.flash_attention import flash_attention
from compile.kernels.fused_mlp import fused_mlp

settings.register_profile("kernels", deadline=None, max_examples=12)
settings.load_profile("kernels")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- attention
@given(
    bh=st.sampled_from([1, 2, 6, 8]),
    t=st.sampled_from([4, 16, 32, 128]),
    d=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
)
def test_attention_forward_matches_ref(bh, t, d, causal):
    q, k, v = (rand(i, (bh, t, d)) for i in range(3))
    got = flash_attention(q, k, v, causal)
    want = ref.attention_ref(q, k, v, causal)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    bh=st.sampled_from([1, 4]),
    t=st.sampled_from([8, 32]),
    d=st.sampled_from([8, 16]),
    causal=st.booleans(),
)
def test_attention_gradients_match_ref(bh, t, d, causal):
    q, k, v = (rand(i + 7, (bh, t, d)) for i in range(3))

    def loss_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_attention_causality():
    # Changing a future token must not change past outputs.
    q, k, v = (rand(i, (2, 16, 8)) for i in range(3))
    base = flash_attention(q, k, v, True)
    k2 = k.at[:, -1, :].add(100.0)
    v2 = v.at[:, -1, :].add(100.0)
    pert = flash_attention(q, k2, v2, True)
    np.testing.assert_allclose(base[:, :-1], pert[:, :-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(base[:, -1], pert[:, -1])


def test_attention_rows_are_convex_combinations():
    # With softmax weights, outputs lie within [min(v), max(v)] per dim.
    q, k, v = (rand(i + 3, (3, 12, 8)) for i in range(3))
    out = np.asarray(flash_attention(q, k, v, False))
    v_np = np.asarray(v)
    assert (out <= v_np.max(axis=1, keepdims=True) + 1e-5).all()
    assert (out >= v_np.min(axis=1, keepdims=True) - 1e-5).all()


# ---------------------------------------------------------------- fused MLP
@given(
    n=st.sampled_from([1, 7, 50, 128, 200]),
    h=st.sampled_from([8, 24, 64]),
)
def test_mlp_forward_matches_ref(n, h):
    x = rand(0, (n, h))
    w1 = rand(1, (h, 4 * h), scale=0.1)
    b1 = rand(2, (4 * h,), scale=0.1)
    w2 = rand(3, (4 * h, h), scale=0.1)
    b2 = rand(4, (h,), scale=0.1)
    got = fused_mlp(x, w1, b1, w2, b2)
    want = ref.mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@given(n=st.sampled_from([3, 40]), h=st.sampled_from([8, 32]))
def test_mlp_gradients_match_ref(n, h):
    args = (
        rand(0, (n, h)),
        rand(1, (h, 4 * h), scale=0.1),
        rand(2, (4 * h,), scale=0.1),
        rand(3, (4 * h, h), scale=0.1),
        rand(4, (h,), scale=0.1),
    )
    gk = jax.grad(lambda *a: jnp.sum(fused_mlp(*a) ** 2), argnums=tuple(range(5)))(*args)
    gr = jax.grad(lambda *a: jnp.sum(ref.mlp_ref(*a) ** 2), argnums=tuple(range(5)))(*args)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_mlp_row_block_padding_edge():
    # Row counts straddling the 128-row block boundary.
    for n in [127, 128, 129, 255, 256, 257]:
        h = 16
        x = rand(9, (n, h))
        w1 = rand(1, (h, 4 * h), scale=0.1)
        b1 = jnp.zeros((4 * h,))
        w2 = rand(3, (4 * h, h), scale=0.1)
        b2 = jnp.zeros((h,))
        got = fused_mlp(x, w1, b1, w2, b2)
        assert got.shape == (n, h)
        np.testing.assert_allclose(got, ref.mlp_ref(x, w1, b1, w2, b2), rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------------- adamw
@given(
    n=st.sampled_from([1, 100, 16 * 1024, 16 * 1024 + 1, 50_000]),
    step=st.sampled_from([1, 2, 10, 1000]),
)
def test_adamw_matches_ref(n, step):
    p = rand(0, (n,))
    m = rand(1, (n,), scale=0.1)
    v = jnp.abs(rand(2, (n,), scale=0.1))
    g = rand(3, (n,))
    got = adamw_update(p, m, v, g, jnp.asarray(float(step)))
    want = ref.adamw_ref(p, m, v, g, step)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_adamw_zero_grad_still_decays_moments():
    n = 256
    p = rand(0, (n,))
    m = rand(1, (n,), scale=0.5)
    v = jnp.abs(rand(2, (n,), scale=0.5))
    g = jnp.zeros((n,))
    p2, m2, v2 = adamw_update(p, m, v, g, jnp.asarray(5.0))
    np.testing.assert_allclose(m2, 0.9 * m, rtol=1e-6)
    np.testing.assert_allclose(v2, 0.999 * v, rtol=1e-6)
    # Parameters still move (bias-corrected momentum is nonzero).
    assert not np.allclose(p2, p)


def test_adamw_descends_quadratic():
    # Minimize ||x||^2: AdamW must reduce it monotonically-ish.
    x = rand(4, (128,))
    m = jnp.zeros_like(x)
    v = jnp.zeros_like(x)
    norms = [float(jnp.sum(x**2))]
    update = jax.jit(adamw_update)
    for t in range(1, 150):
        g = 2.0 * x
        x, m, v = update(x, m, v, g, jnp.asarray(float(t)))
        norms.append(float(jnp.sum(x**2)))
    # lr = 1e-3 and |x_i| ~ 1: Adam moves each coordinate ~lr per step, so
    # 150 steps shave ~15-25 % off the norm and never increase it.
    assert norms[-1] < 0.85 * norms[0], norms[::30]
    assert all(b <= a + 1e-6 for a, b in zip(norms, norms[1:]))
