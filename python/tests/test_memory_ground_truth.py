"""Cross-validation of the memory models against real JAX quantities.

1. The paper's parameter-count formula `W = V·h + l·(12h² + 13h)` vs the
   actual parameter count of our transformer implementation.
2. The rust exact-accounting ground truth (Fig 6 "measured") vs JAX's own
   compiled buffer statistics for the tiny model — the closest thing to an
   `nvidia-smi` measurement this substrate has (DESIGN.md §6).
"""

import functools

import jax
import jax.numpy as jnp
import pytest

from compile import model


def paper_w(vocab, hidden, layers):
    return vocab * hidden + layers * (12 * hidden * hidden + 13 * hidden)


@pytest.mark.parametrize("name", list(model.CONFIGS))
def test_paper_formula_close_to_actual_params(name):
    cfg = model.CONFIGS[name]
    actual = model.param_count(cfg)
    formula = paper_w(cfg.vocab, cfg.hidden, cfg.layers)
    # The formula profiles GPT-2-with-untied-head; ours ties the LM head and
    # includes position embeddings — agreement must be within ~15 %.
    ratio = actual / formula
    assert 0.8 < ratio < 1.2, (name, actual, formula)


def test_static_bytes_20x_params():
    # fp32 single-device here: params + m + v = 12 bytes/param live in the
    # state vector; mixed-precision adds fp16 copies + fp32 grads -> 20.
    cfg = model.CONFIGS["gpt2-tiny"]
    state = model.init_state(cfg)
    assert state.nbytes == 4 * (3 * model.param_count(cfg) + 2)


def test_compiled_peak_memory_in_expected_band():
    """JAX compiled-memory analysis vs an analytic floor/ceiling.

    The train step must at minimum hold the state (3P floats) plus
    activations; it must not exceed a generous multiple of that (XLA
    fusion keeps temporaries bounded). This anchors the exact-accounting
    model in something actually measured by the compiler.
    """
    cfg = model.GptConfig("mem", vocab=512, hidden=64, layers=2, heads=4, seq_len=64, batch=4)
    s_len = model.state_len(cfg)
    state_spec = jax.ShapeDtypeStruct((s_len,), jnp.float32)
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    compiled = jax.jit(functools.partial(model.train_step, cfg)).lower(state_spec, tok_spec).compile()
    try:
        analysis = compiled.memory_analysis()
    except Exception:
        pytest.skip("memory_analysis not available on this backend")
    if analysis is None:
        pytest.skip("no memory analysis returned")
    total = (
        analysis.temp_size_in_bytes
        + analysis.argument_size_in_bytes
        + analysis.output_size_in_bytes
    )
    p_bytes = 4 * model.param_count(cfg)
    # floor: state in + state out (params+m+v each way)
    assert total >= 2 * 3 * p_bytes, (total, p_bytes)
    # ceiling: an order of magnitude over the state (activations for this
    # tiny config are < 2x state)
    assert total < 30 * 3 * p_bytes, (total, p_bytes)
