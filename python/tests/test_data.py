"""Token-stream golden values — mirrored in
`rust/tests/integration_runtime.rs::synth_tokens_matches_python_formula_snapshot`.
Keep both in sync or the oracle comparison silently diverges."""

import numpy as np

from compile.data import synth_tokens


def test_golden_snapshot_matches_rust():
    toks = synth_tokens(2, 4, 97, 5)
    assert toks.tolist() == [[85, 1, 14, 27], [92, 8, 21, 34]]


def test_shape_dtype_range():
    toks = synth_tokens(8, 128, 1024, 0)
    assert toks.shape == (8, 128)
    assert toks.dtype == np.int32
    assert toks.min() >= 0 and toks.max() < 1024


def test_next_token_is_learnable_shift():
    # token[t+1] - token[t] == 13 (mod V): the pattern the model learns.
    v = 211
    toks = synth_tokens(4, 32, v, 9)
    diff = (toks[:, 1:].astype(np.int64) - toks[:, :-1]) % v
    assert (diff == 13).all()


def test_step_changes_stream():
    a = synth_tokens(4, 16, 101, 1)
    b = synth_tokens(4, 16, 101, 2)
    assert (a != b).any()
