"""AOT path checks: HLO text artifacts exist/parse, manifest is consistent
with the model definitions, and the lowered computation matches the eager
reference. (Artifact regeneration itself is exercised by `make artifacts`;
these tests run against a temp dir so they are hermetic.)"""

import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.data import synth_tokens


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    entry = aot.lower_model(model.CONFIGS["gpt2-tiny"], out)
    return out, entry


def test_hlo_text_is_parseable_hlo(tiny_artifacts):
    out, entry = tiny_artifacts
    for key in ("init_hlo", "step_hlo", "probe_hlo"):
        path = os.path.join(out, entry[key])
        text = open(path).read()
        assert "HloModule" in text.splitlines()[0], f"{key} missing HloModule header"
        assert "ENTRY" in text
    # the train step must be a substantial module (the probe is tiny)
    assert len(open(os.path.join(out, entry["step_hlo"])).read()) > 10_000


def test_manifest_entry_consistent_with_model(tiny_artifacts):
    _, entry = tiny_artifacts
    cfg = model.CONFIGS["gpt2-tiny"]
    assert entry["param_count"] == model.param_count(cfg)
    assert entry["state_len"] == model.state_len(cfg)
    assert entry["state_len"] == 3 * entry["param_count"] + 2
    assert entry["batch"] == cfg.batch
    assert entry["seq_len"] == cfg.seq_len
    assert entry["vocab"] == cfg.vocab
    assert len(entry["oracle_losses"]) == aot.ORACLE_STEPS


def test_oracle_losses_decrease_and_start_at_uniform(tiny_artifacts):
    _, entry = tiny_artifacts
    losses = entry["oracle_losses"]
    cfg = model.CONFIGS["gpt2-tiny"]
    assert abs(losses[0] - np.log(cfg.vocab)) < 0.3
    assert losses[-1] < losses[0]


def test_lowered_step_matches_eager_reference(tiny_artifacts):
    # Execute the jitted (lowered) computation and the eager python path on
    # the same inputs: they must agree — this is what the rust side runs.
    cfg = model.CONFIGS["gpt2-tiny"]
    state0 = jax.jit(functools.partial(model.init_state, cfg))()
    toks = jnp.asarray(synth_tokens(cfg.batch, cfg.seq_len, cfg.vocab, 0))
    jit_out = jax.jit(functools.partial(model.train_step, cfg))(state0, toks)
    eager_out = model.train_step(cfg, state0, toks)
    np.testing.assert_allclose(
        np.asarray(jit_out[-2:]), np.asarray(eager_out[-2:]), rtol=1e-5, atol=1e-5
    )
    p = model.param_count(cfg)
    np.testing.assert_allclose(
        np.asarray(jit_out[:1000]), np.asarray(eager_out[:1000]), rtol=1e-4, atol=1e-6
    )
    assert jit_out.shape == (3 * p + 2,)


def test_probe_returns_step_and_loss(tiny_artifacts):
    cfg = model.CONFIGS["gpt2-tiny"]
    state = jnp.arange(10, dtype=jnp.float32)
    probe = jax.jit(lambda s: s[-2:])
    out = probe(state)
    assert out.tolist() == [8.0, 9.0]
