//! Bench: Fig 5(a) — per-round scheduling latency, HAS vs Sia, vs queue
//! depth. The paper's "scheduling overhead reduced 10 times" claim.

use frenzy::bench_harness::Bench;
use frenzy::cluster::{ClusterState, ClusterView};
use frenzy::config::sia_sim;
use frenzy::marp::Marp;
use frenzy::sched::{has::Has, sia::Sia, PendingJob, PendingQueue, Scheduler};
use frenzy::workload::newworkload;

fn pending(n: usize) -> PendingQueue {
    newworkload::generate(n, 11).into_iter().map(|spec| PendingJob { spec, attempts: 0 }).collect()
}

fn main() {
    let spec = sia_sim();
    let snap = ClusterState::from_spec(&spec);
    let view = ClusterView::build(&snap);
    let mut b = Bench::new("fig5a_overhead");
    for &n in &[10usize, 40, 160] {
        let queue = pending(n);
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        b.bench(&format!("has_{n}tasks"), || has.schedule(&queue, &view, 0.0).work_units);
        let mut sia = Sia::new(&spec);
        sia.node_limit = 2_000_000;
        b.bench(&format!("sia_{n}tasks"), || sia.schedule(&queue, &view, 0.0).work_units);
    }
    b.report();
    // Print the paper-facing ratio.
    let r = b.results();
    for i in 0..3 {
        let has = &r[2 * i];
        let sia = &r[2 * i + 1];
        println!("{} vs {}: Sia/HAS overhead ratio = {:.0}x", sia.name, has.name, sia.mean_s / has.mean_s);
    }
}
