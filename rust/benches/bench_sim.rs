//! Bench: discrete-event simulator throughput (events/sec) — the substrate
//! every figure rests on must itself be fast.

use frenzy::bench_harness::Bench;
use frenzy::config::{real_testbed, sia_sim};
use frenzy::marp::Marp;
use frenzy::sched::has::Has;
use frenzy::sim::{simulate, SimConfig};
use frenzy::workload::{newworkload, philly};

fn main() {
    std::env::set_var("FRENZY_BENCH_FAST", "1");
    let mut b = Bench::new("sim");
    let real = real_testbed();
    let siasim = sia_sim();
    let nw = newworkload::generate(60, 11);
    let ph = philly::generate(200, 11);
    // Each job produces >= 2 events (arrival, finish) + scheduling rounds.
    b.bench_throughput("newworkload_60_jobs", 60.0, || {
        let mut has = Has::new(Marp::with_defaults(real.clone()));
        simulate(&real, &mut has, &nw, SimConfig::default(), "nw").n_completed
    });
    b.bench_throughput("philly_200_jobs", 200.0, || {
        let mut has = Has::new(Marp::with_defaults(siasim.clone()));
        simulate(&siasim, &mut has, &ph, SimConfig::default(), "ph").n_completed
    });
    b.report();
}
