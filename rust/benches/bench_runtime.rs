//! Bench: PJRT hot path — HLO load/compile and per-step training latency of
//! the AOT artifacts. Skips gracefully when `artifacts/` is absent.

use frenzy::bench_harness::Bench;
use frenzy::runtime::{synth_tokens, Manifest, Runtime};

fn main() {
    let dir = frenzy::util::repo_path("artifacts");
    let Ok(manifest) = Manifest::load(&dir) else {
        println!("bench_runtime: artifacts/ missing — run `make artifacts` first (skipping)");
        return;
    };
    let mut b = Bench::new("runtime");
    b.bench("synth_tokens_8x128", || synth_tokens(8, 128, 1024, 3));

    let meta = manifest.model("gpt2-tiny").expect("gpt2-tiny artifact").clone();
    let mut rt = Runtime::new().expect("pjrt cpu client");
    // Compile cost (cache defeated by fresh Runtime) — measured once each.
    let t0 = std::time::Instant::now();
    let mut rt2 = Runtime::new().expect("client");
    let _ = rt2.load(&meta).expect("load");
    println!("cold load+compile (init+step): {:.3}s", t0.elapsed().as_secs_f64());

    let mut session = rt.start_session(&meta).expect("session");
    b.bench("train_step_gpt2_tiny", || session.step().expect("step"));
    b.report();
}
