//! Bench: MARP prediction + plan enumeration latency (the serverless
//! admission path — must be microseconds), plus the Fig 6 accuracy table.

use frenzy::bench_harness::Bench;
use frenzy::config::models::model_by_name;
use frenzy::config::real_testbed;
use frenzy::marp::Marp;
use frenzy::memory::{exact::exact_peak_bytes, marp_peak_bytes, Parallelism, TrainConfig};

fn main() {
    let mut b = Bench::new("marp");
    let m7 = model_by_name("gpt2-7b").unwrap();
    let m350 = model_by_name("gpt2-350m").unwrap();
    let cfg = TrainConfig { global_batch: 8 };
    let par = Parallelism::new(2, 4);

    b.bench("closed_form_peak", || marp_peak_bytes(&m7, &cfg, par));
    b.bench("exact_accounting_peak", || exact_peak_bytes(&m7, &cfg, par));

    let marp = Marp::with_defaults(real_testbed());
    b.bench("plan_enumeration_gpt2_7b", || marp.plans(&m7, &cfg).len());
    b.bench("plan_enumeration_gpt2_350m", || marp.plans(&m350, &cfg).len());
    b.report();

    frenzy::exp::fig6::report();
}
