//! Bench: ingest throughput — submits/sec and per-request p99 latency
//! through the full HTTP path (SDK framing, acceptor, worker pool,
//! coordinator mailbox, WAL), single submits vs `jobs:batch`, at two
//! simulated client counts; plus a watermark storm proving pending depth
//! stays bounded under overload.
//!
//! Every simulated client in single mode is a fresh connection that
//! submits once and disconnects — the serverless cold-path. Batch mode
//! pushes the same job count as `jobs:batch` bodies over a few persistent
//! connections. The submitted model is deliberately infeasible for the
//! bench cluster, so every job takes the cheap admission-reject path:
//! the full ingest pipeline (parse, admission, id mint, MARP planning,
//! WAL append + fsync, audit event) runs, but no placement state
//! accumulates to confound transport measurements across client counts.
//!
//! The acceptance gate (full mode only — smoke timings are unstable)
//! requires batched ingest to beat single-submit throughput at least 5x
//! at the larger client count: one fsync and one coordinator message per
//! 256 jobs has to show up. The watermark storm asserts in both modes:
//! bounded queue depth is a correctness property, not a timing.
//! Results land in `BENCH_api.json` at the repository root.

use frenzy::config::{gpu_by_name, ClusterSpec, LinkKind, NodeSpec};
use frenzy::durability::FsyncPolicy;
use frenzy::job::JobState;
use frenzy::serverless::api::{ListRequestV1, SubmitRequestV1, SubmitResultV1, MAX_BATCH_SUBMIT};
use frenzy::serverless::client::{FrenzyClient, SubmitOutcome};
use frenzy::serverless::{server, spawn, CoordinatorConfig, Handle};
use frenzy::util::json::Json;
use frenzy::util::stats::Sample;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// One small node: enough to run admission + planning, too small to host
/// the bench model (see module docs).
fn bench_cluster() -> ClusterSpec {
    let gpu = gpu_by_name("RTX2080Ti").expect("zoo gpu");
    ClusterSpec {
        name: "bench-ingest".into(),
        nodes: vec![NodeSpec { gpu, count: 1, link: LinkKind::Pcie }],
        inter_node_gbps: 12.5,
    }
}

fn start(cfg: CoordinatorConfig) -> (Handle, SocketAddr, Arc<AtomicBool>) {
    let (h, _j) = spawn(bench_cluster(), cfg);
    let stop = Arc::new(AtomicBool::new(false));
    let addr = server::serve(h.clone(), "127.0.0.1:0", stop.clone()).expect("bind bench server");
    (h, addr, stop)
}

struct StormResult {
    elapsed_s: f64,
    /// Per-request latency (one submit in single mode, one batch body in
    /// batch mode).
    latency: Sample,
    accepted: u64,
    throttled: u64,
}

impl StormResult {
    fn submits_per_s(&self) -> f64 {
        self.accepted as f64 / self.elapsed_s.max(1e-9)
    }
}

/// `n_clients` one-shot clients: fresh connection, one `POST /v1/jobs`,
/// disconnect — spread over `threads` workers.
fn storm_single(addr: &str, model: &str, n_clients: usize, threads: usize) -> StormResult {
    let t0 = Instant::now();
    let workers: Vec<_> = (0..threads)
        .map(|w| {
            let addr = addr.to_string();
            let share = n_clients / threads + usize::from(w < n_clients % threads);
            let req = SubmitRequestV1::new(model, 8, 1_000);
            std::thread::spawn(move || {
                let mut lat = Vec::with_capacity(share);
                let (mut acc, mut thr) = (0u64, 0u64);
                for _ in 0..share {
                    let mut c = FrenzyClient::new(addr.clone());
                    let s0 = Instant::now();
                    match c.submit_once(&req).expect("single submit") {
                        SubmitOutcome::Accepted { .. } => acc += 1,
                        SubmitOutcome::Throttled { .. } => thr += 1,
                    }
                    lat.push(s0.elapsed().as_secs_f64());
                }
                (lat, acc, thr)
            })
        })
        .collect();
    let mut latency = Sample::new();
    let (mut accepted, mut throttled) = (0u64, 0u64);
    for w in workers {
        let (lat, acc, thr) = w.join().expect("storm worker");
        lat.into_iter().for_each(|l| latency.push(l));
        accepted += acc;
        throttled += thr;
    }
    StormResult { elapsed_s: t0.elapsed().as_secs_f64(), latency, accepted, throttled }
}

/// The same `n_clients` submits as `jobs:batch` bodies (up to
/// [`MAX_BATCH_SUBMIT`] each) over `threads` persistent connections.
fn storm_batch(addr: &str, model: &str, n_clients: usize, threads: usize) -> StormResult {
    let t0 = Instant::now();
    let workers: Vec<_> = (0..threads)
        .map(|w| {
            let addr = addr.to_string();
            let share = n_clients / threads + usize::from(w < n_clients % threads);
            let req = SubmitRequestV1::new(model, 8, 1_000);
            std::thread::spawn(move || {
                let mut c = FrenzyClient::new(addr);
                let mut lat = Vec::new();
                let (mut acc, mut thr) = (0u64, 0u64);
                let mut left = share;
                while left > 0 {
                    let n = left.min(MAX_BATCH_SUBMIT);
                    let body = vec![req.clone(); n];
                    let s0 = Instant::now();
                    let resp = c.submit_batch(&body).expect("batch submit");
                    lat.push(s0.elapsed().as_secs_f64());
                    for r in &resp.results {
                        match r {
                            SubmitResultV1::Accepted { .. } => acc += 1,
                            SubmitResultV1::Rejected(e) if e.code == 429 => thr += 1,
                            SubmitResultV1::Rejected(e) => {
                                panic!("unexpected rejection: {}: {}", e.code, e.message)
                            }
                        }
                    }
                    left -= n;
                }
                (lat, acc, thr)
            })
        })
        .collect();
    let mut latency = Sample::new();
    let (mut accepted, mut throttled) = (0u64, 0u64);
    for w in workers {
        let (lat, acc, thr) = w.join().expect("storm worker");
        lat.into_iter().for_each(|l| latency.push(l));
        accepted += acc;
        throttled += thr;
    }
    StormResult { elapsed_s: t0.elapsed().as_secs_f64(), latency, accepted, throttled }
}

fn entry(clients: usize, mode: &str, r: &mut StormResult) -> Json {
    let mut j = Json::obj();
    j.set("clients", clients as u64)
        .set("mode", mode)
        .set("submits_per_s", r.submits_per_s())
        .set("p99_request_s", r.latency.p99())
        .set("mean_request_s", r.latency.mean())
        .set("accepted", r.accepted)
        .set("throttled", r.throttled)
        .set("elapsed_s", r.elapsed_s);
    j
}

/// Overload a watermarked server (tiny `max_pending`, jobs that occupy
/// the only GPU for minutes) and verify the queue never exceeds the
/// watermark while a sampler watches — the backpressure path sheds load
/// instead of buffering it.
fn watermark_storm(fast: bool) -> (usize, usize, u64) {
    let max_pending = 32usize;
    let cfg = CoordinatorConfig {
        execute_training: false,
        stub_delay_ms: 60_000,
        max_pending,
        ..CoordinatorConfig::default()
    };
    let (h, addr, stop) = start(cfg);
    let done = Arc::new(AtomicBool::new(false));
    let peak = Arc::new(AtomicUsize::new(0));
    let sampler = {
        let (addr, done, peak) = (addr.to_string(), done.clone(), peak.clone());
        std::thread::spawn(move || {
            let mut c = FrenzyClient::new(addr);
            while !done.load(Ordering::Relaxed) {
                let queued = c
                    .list(&ListRequestV1 { state: Some(JobState::Queued), offset: 0, limit: 1 })
                    .expect("sampler list")
                    .total;
                peak.fetch_max(queued, Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        })
    };
    // gpt2-tiny fits the bench GPU: one job runs for minutes, the rest
    // queue up to the watermark, everything past it must bounce with 429.
    let n = if fast { 120 } else { 400 };
    let r = storm_single(&addr.to_string(), "gpt2-tiny", n, 8);
    done.store(true, Ordering::Relaxed);
    sampler.join().expect("sampler");
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
    let observed = peak.load(Ordering::Relaxed);
    assert!(
        observed <= max_pending,
        "pending depth must stay bounded by the watermark: saw {observed} > {max_pending}"
    );
    assert!(
        r.accepted as usize <= max_pending + 1 && r.throttled > 0,
        "overload must shed: accepted {} (cap {}), throttled {}",
        r.accepted,
        max_pending + 1,
        r.throttled
    );
    (max_pending, observed, r.throttled)
}

fn main() {
    let fast = std::env::var("FRENZY_BENCH_FAST").ok().is_some_and(|v| v == "1");
    let client_counts: &[usize] = if fast { &[64, 256] } else { &[1_000, 10_000] };
    let threads = if fast { 8 } else { 16 };
    // Infeasible on the 1-GPU bench cluster: ingest-only work (see module
    // docs). Verified below before any timing is trusted.
    let model = "gpt2-7b";

    let dir = std::env::temp_dir().join(format!("frenzy_bench_api_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = CoordinatorConfig {
        execute_training: false,
        data_dir: Some(dir.clone()),
        fsync: FsyncPolicy::Always,
        ..CoordinatorConfig::default()
    };
    let (h, addr, stop) = start(cfg);
    let addr = addr.to_string();
    {
        let mut probe = FrenzyClient::new(addr.clone());
        let p = probe.predict(model, 8).expect("probe predict");
        assert!(!p.feasible, "{model} must be infeasible on the bench cluster");
    }

    let mut entries: Vec<Json> = Vec::new();
    let mut per_count: Vec<(usize, f64, f64)> = Vec::new();
    for &n in client_counts {
        let mut single = storm_single(&addr, model, n, threads);
        let mut batch = storm_batch(&addr, model, n, threads);
        println!(
            "{n} clients: single {:.0} submits/s (p99 {:.2} ms), batch {:.0} submits/s \
             (p99/request {:.2} ms, {} jobs/body max)",
            single.submits_per_s(),
            single.latency.p99() * 1e3,
            batch.submits_per_s(),
            batch.latency.p99() * 1e3,
            MAX_BATCH_SUBMIT
        );
        per_count.push((n, single.submits_per_s(), batch.submits_per_s()));
        entries.push(entry(n, "single", &mut single));
        entries.push(entry(n, "batch", &mut batch));
    }
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    let (watermark, observed_peak, shed) = watermark_storm(fast);
    println!(
        "watermark storm: pending peaked at {observed_peak} (cap {watermark}), \
         {shed} submits shed with 429"
    );

    let mut payload = Json::obj();
    let mut wm = Json::obj();
    wm.set("max_pending", watermark as u64)
        .set("max_observed_queued", observed_peak as u64)
        .set("throttled", shed);
    payload
        .set("bench", "api")
        .set("smoke", fast)
        .set("model", model)
        .set("wal_fsync", "always")
        .set("entries", Json::Arr(entries))
        .set("watermark_storm", wm);
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_api.json");
    frenzy::util::write_file(&path, &payload.to_string_pretty()).expect("write BENCH_api.json");
    println!("wrote {}", path.display());

    if !fast {
        let &(n, single_tput, batch_tput) = per_count.last().expect("at least one client count");
        assert!(
            batch_tput >= 5.0 * single_tput,
            "batched ingest must beat single submits >=5x at {n} clients: \
             {batch_tput:.0}/s vs {single_tput:.0}/s"
        );
        println!(
            "acceptance: batch {:.1}x single at {n} clients — OK",
            batch_tput / single_tput.max(1e-9)
        );
    }
}
