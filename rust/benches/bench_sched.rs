//! Bench: scheduling-round latency vs. cluster size, indexed vs. naive.
//!
//! Sweeps synthetic heterogeneous clusters (3 GPU size classes) from 100 to
//! 10,000 nodes with two pending queues — a Philly-trace-derived one and a
//! generated open-world stream (`synth:` grammar, seeded) — and compares
//! the capacity-index hot path (`indexed: true`) against the reference
//! full-scan implementation for every scheduler that carries the flag
//! (HAS, Sia, Opportunistic). Before timing, it asserts each pair produces
//! **identical decisions and work units** — a divergence panics, which is
//! the CI gate. Results are written to `BENCH_sched.json` at the
//! repository root so the perf trajectory is tracked PR over PR.
//!
//! Smoke mode (`FRENZY_BENCH_FAST=1`, used by CI on every push) shrinks
//! the sweep and measurement budget; the ≥10× speedup assertion at 5,000
//! nodes only runs in full mode, where timings are stable.

use frenzy::bench_harness::Bench;
use frenzy::cluster::{ClusterState, ClusterView};
use frenzy::config::synthetic_cluster;
use frenzy::marp::Marp;
use frenzy::sched::{has::Has, opportunistic::Opportunistic, sia::Sia, PendingJob, PendingQueue};
use frenzy::sched::Scheduler;
use frenzy::util::json::Json;
use frenzy::workload::{generator, philly};

/// The generated-workload sweep spec: seeded, tenant-attributed, zoo mix.
const SYNTH_SPEC: &str = "seed=11,arrivals=poisson:0.5,tenants=8,mix=zoo";

fn to_queue(jobs: Vec<frenzy::job::JobSpec>) -> PendingQueue {
    jobs.into_iter().map(|spec| PendingJob { spec, attempts: 0 }).collect()
}

/// `(job, parts, d, t)` per decision — the differential gate's identity.
type Fingerprint = Vec<(u64, Vec<(usize, u32)>, u32, u32)>;

fn fingerprint(round: &frenzy::sched::SchedRound) -> Fingerprint {
    round
        .decisions
        .iter()
        .map(|d| (d.job, d.alloc.parts.clone(), d.par.d, d.par.t))
        .collect()
}

/// Run one scheduler pair (indexed vs. naive reference) over the queue and
/// panic on any decision or work-unit divergence.
fn gate(
    name: &str,
    n: usize,
    indexed: &mut dyn Scheduler,
    naive: &mut dyn Scheduler,
    pending: &PendingQueue,
    view: &ClusterView,
) {
    let ri = indexed.schedule(pending, view, 0.0);
    let rn = naive.schedule(pending, view, 0.0);
    assert_eq!(
        fingerprint(&ri),
        fingerprint(&rn),
        "indexed and naive {name} decisions diverged at {n} nodes"
    );
    assert_eq!(
        ri.work_units, rn.work_units,
        "{name} work-unit accounting diverged at {n} nodes"
    );
}

fn main() {
    let fast = std::env::var("FRENZY_BENCH_FAST").ok().is_some_and(|v| v == "1");
    let node_counts: &[usize] = if fast { &[100, 1000] } else { &[100, 1000, 5000, 10_000] };
    let queue_len = if fast { 32 } else { 64 };

    let mut b = Bench::new("sched_round");
    let mut entries: Vec<Json> = Vec::new();
    let mut speedup_at_5k: Option<f64> = None;

    // Philly keeps its untagged bench ids so the trajectory stays
    // comparable across PRs; the generated stream rides alongside.
    let workloads: [(&str, &str, PendingQueue); 2] = [
        ("philly(seed 11)", "", to_queue(philly::generate(queue_len, 11))),
        (
            "synth:seed=11,tenants=8,mix=zoo",
            "synth_",
            to_queue(generator::from_spec(SYNTH_SPEC, queue_len, 11).expect("synth spec")),
        ),
    ];

    for &n in node_counts {
        let spec = synthetic_cluster(n);
        let state = ClusterState::from_spec(&spec);
        let view = ClusterView::build(&state);

        for (workload, tag, pending) in &workloads {
            // Differential gates: every indexed scheduler against its
            // full-scan reference, identical decisions AND work units,
            // before any timing.
            let mut has_idx = Has::new(Marp::with_defaults(spec.clone()));
            let mut has_nv = Has::new(Marp::with_defaults(spec.clone()));
            has_nv.indexed = false;
            gate("HAS", n, &mut has_idx, &mut has_nv, pending, &view);

            let mut sia_idx = Sia::new(&spec);
            let mut sia_nv = Sia::new(&spec);
            sia_nv.indexed = false;
            gate("Sia", n, &mut sia_idx, &mut sia_nv, pending, &view);

            let mut opp_idx = Opportunistic::new(&spec);
            let mut opp_nv = Opportunistic::new(&spec);
            opp_nv.indexed = false;
            gate("Opportunistic", n, &mut opp_idx, &mut opp_nv, pending, &view);

            let decisions = has_idx.schedule(pending, &view, 0.0);
            let r_idx = b
                .bench(&format!("{tag}indexed_{n}nodes"), || {
                    has_idx.schedule(pending, &view, 0.0).decisions.len()
                })
                .clone();
            let r_nv = b
                .bench(&format!("{tag}naive_{n}nodes"), || {
                    has_nv.schedule(pending, &view, 0.0).decisions.len()
                })
                .clone();
            let speedup = r_nv.mean_s / r_idx.mean_s.max(1e-12);
            if n == 5000 && tag.is_empty() {
                speedup_at_5k = Some(speedup);
            }
            let mut e = Json::obj();
            e.set("nodes", n)
                .set("workload", *workload)
                .set("queue_depth", queue_len)
                .set("indexed_mean_s", r_idx.mean_s)
                .set("naive_mean_s", r_nv.mean_s)
                .set("speedup", speedup)
                .set("decisions", decisions.decisions.len())
                .set("work_units", decisions.work_units);
            entries.push(e);
            println!(
                "{n:>6} nodes [{workload}]: naive {:.3e}s  indexed {:.3e}s  \
                 speedup {speedup:.1}x  ({} decisions, identical)",
                r_nv.mean_s,
                r_idx.mean_s,
                decisions.decisions.len()
            );
        }
    }
    b.report();

    let mut payload = Json::obj();
    payload
        .set("bench", "sched_round")
        .set("smoke", fast)
        .set(
            "workloads",
            Json::Arr(
                workloads.iter().map(|(w, _, _)| Json::from(*w)).collect::<Vec<Json>>(),
            ),
        )
        .set("entries", Json::Arr(entries));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_sched.json");
    frenzy::util::write_file(&path, &payload.to_string_pretty()).expect("write BENCH_sched.json");
    println!("wrote {}", path.display());

    if let Some(s) = speedup_at_5k {
        assert!(
            s >= 10.0,
            "indexed path must be ≥10x the naive path at 5000 nodes, got {s:.1}x"
        );
        println!("acceptance: ≥10x at 5000 nodes — OK ({s:.1}x)");
    }
}
