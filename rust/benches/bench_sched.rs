//! Bench: scheduling-round latency vs. cluster size, indexed vs. naive.
//!
//! Sweeps synthetic heterogeneous clusters (3 GPU size classes) from 100 to
//! 10,000 nodes with a Philly-trace-derived pending queue, and compares the
//! capacity-index hot path (`Has { indexed: true }`) against the reference
//! full-scan implementation. Before timing, it asserts the two paths
//! produce **identical decisions and work units** — a divergence panics,
//! which is the CI gate. Results are written to `BENCH_sched.json` at the
//! repository root so the perf trajectory is tracked PR over PR.
//!
//! Smoke mode (`FRENZY_BENCH_FAST=1`, used by CI on every push) shrinks
//! the sweep and measurement budget; the ≥10× speedup assertion at 5,000
//! nodes only runs in full mode, where timings are stable.

use frenzy::bench_harness::Bench;
use frenzy::cluster::{ClusterState, ClusterView};
use frenzy::config::synthetic_cluster;
use frenzy::marp::Marp;
use frenzy::sched::{has::Has, PendingJob, PendingQueue, Scheduler};
use frenzy::util::json::Json;
use frenzy::workload::philly;

fn queue(n: usize) -> PendingQueue {
    philly::generate(n, 11)
        .into_iter()
        .map(|spec| PendingJob { spec, attempts: 0 })
        .collect()
}

/// `(job, parts, d, t)` per decision — the differential gate's identity.
type Fingerprint = Vec<(u64, Vec<(usize, u32)>, u32, u32)>;

fn fingerprint(round: &frenzy::sched::SchedRound) -> Fingerprint {
    round
        .decisions
        .iter()
        .map(|d| (d.job, d.alloc.parts.clone(), d.par.d, d.par.t))
        .collect()
}

fn main() {
    let fast = std::env::var("FRENZY_BENCH_FAST").ok().is_some_and(|v| v == "1");
    let node_counts: &[usize] = if fast { &[100, 1000] } else { &[100, 1000, 5000, 10_000] };
    let queue_len = if fast { 32 } else { 64 };

    let mut b = Bench::new("sched_round");
    let mut entries: Vec<Json> = Vec::new();
    let mut speedup_at_5k: Option<f64> = None;

    for &n in node_counts {
        let spec = synthetic_cluster(n);
        let state = ClusterState::from_spec(&spec);
        let view = ClusterView::build(&state);
        let pending = queue(queue_len);

        let mut indexed = Has::new(Marp::with_defaults(spec.clone()));
        let mut naive = Has::new(Marp::with_defaults(spec.clone()));
        naive.indexed = false;

        // Differential gate: identical decisions AND identical work units,
        // every sweep point, before any timing.
        let ri = indexed.schedule(&pending, &view, 0.0);
        let rn = naive.schedule(&pending, &view, 0.0);
        assert_eq!(
            fingerprint(&ri),
            fingerprint(&rn),
            "indexed and naive HAS decisions diverged at {n} nodes"
        );
        assert_eq!(
            ri.work_units, rn.work_units,
            "work-unit accounting diverged at {n} nodes"
        );

        let r_idx = b
            .bench(&format!("indexed_{n}nodes"), || {
                indexed.schedule(&pending, &view, 0.0).decisions.len()
            })
            .clone();
        let r_nv = b
            .bench(&format!("naive_{n}nodes"), || {
                naive.schedule(&pending, &view, 0.0).decisions.len()
            })
            .clone();
        let speedup = r_nv.mean_s / r_idx.mean_s.max(1e-12);
        if n == 5000 {
            speedup_at_5k = Some(speedup);
        }
        let mut e = Json::obj();
        e.set("nodes", n)
            .set("queue_depth", queue_len)
            .set("indexed_mean_s", r_idx.mean_s)
            .set("naive_mean_s", r_nv.mean_s)
            .set("speedup", speedup)
            .set("decisions", ri.decisions.len())
            .set("work_units", ri.work_units);
        entries.push(e);
        println!(
            "{n:>6} nodes: naive {:.3e}s  indexed {:.3e}s  speedup {speedup:.1}x  \
             ({} decisions, identical)",
            r_nv.mean_s,
            r_idx.mean_s,
            ri.decisions.len()
        );
    }
    b.report();

    let mut payload = Json::obj();
    payload
        .set("bench", "sched_round")
        .set("smoke", fast)
        .set("workload", "philly(seed 11)")
        .set("entries", Json::Arr(entries));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_sched.json");
    frenzy::util::write_file(&path, &payload.to_string_pretty()).expect("write BENCH_sched.json");
    println!("wrote {}", path.display());

    if let Some(s) = speedup_at_5k {
        assert!(
            s >= 10.0,
            "indexed path must be ≥10x the naive path at 5000 nodes, got {s:.1}x"
        );
        println!("acceptance: ≥10x at 5000 nodes — OK ({s:.1}x)");
    }
}
