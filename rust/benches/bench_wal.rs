//! Bench: durability cost — raw WAL append latency per fsync policy, and
//! the end-to-end overhead a journal adds to a Philly replay through the
//! scheduling engine.
//!
//! The acceptance gate is the engine-level one: a journaled replay must
//! stay within 10% of the in-memory replay (the WAL is a length-prefixed
//! append + occasional fsync; it must never dominate scheduling). The
//! gate only runs in full mode — under `FRENZY_BENCH_FAST=1` (CI smoke)
//! timings are too short to be stable. Results land in `BENCH_wal.json`
//! at the repository root.

use frenzy::bench_harness::Bench;
use frenzy::config::real_testbed;
use frenzy::durability::{FsyncPolicy, SharedJournal, Wal, WalRecord};
use frenzy::engine::clock::{Clock, VirtualClock};
use frenzy::engine::{ClusterEvent, EngineConfig, SchedulingEngine};
use frenzy::job::JobSpec;
use frenzy::marp::Marp;
use frenzy::sched::has::Has;
use frenzy::util::json::Json;
use frenzy::workload::philly;
use std::cell::RefCell;
use std::rc::Rc;

/// One full virtual-clock replay of `jobs`; journaled when `wal` is set.
/// Returns completions so the work can't be optimized away.
fn replay(jobs: &[JobSpec], wal: Option<Rc<RefCell<Wal>>>) -> usize {
    let spec = real_testbed();
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
    if let Some(w) = wal {
        engine.set_journal(Box::new(SharedJournal(w)));
    }
    let mut clock = VirtualClock::new();
    for j in jobs {
        clock.schedule(j.submit_time, ClusterEvent::Arrival(j.clone()));
    }
    while let Some((_, ev)) = clock.pop() {
        engine.handle(ev, &mut clock);
        engine.run_round(&mut clock);
    }
    engine.aggregates().n_completed
}

fn main() {
    let fast = std::env::var("FRENZY_BENCH_FAST").ok().is_some_and(|v| v == "1");
    let n_jobs = if fast { 10 } else { 24 };
    let jobs = philly::generate(n_jobs, 11);

    let dir = std::env::temp_dir().join("frenzy_bench_wal");
    let _ = std::fs::remove_dir_all(&dir);

    let mut b = Bench::new("wal");

    // Raw append latency per fsync policy. One representative Event
    // record; the WAL grows across iterations, which is exactly the
    // steady state (append is O(1) in log length).
    let rec = WalRecord::Event {
        time: 12.5,
        ev: ClusterEvent::Arrival(jobs[0].clone()),
    };
    let mut raw_results: Vec<(String, f64)> = Vec::new();
    for (name, policy) in [
        ("append_every64", FsyncPolicy::EveryN(64)),
        ("append_interval1s", FsyncPolicy::IntervalS(1.0)),
        ("append_always", FsyncPolicy::Always),
    ] {
        let (mut wal, _) = Wal::open(&dir.join(name), policy).expect("open bench WAL");
        let r = b.bench_throughput(name, 1.0, || wal.append(&rec).unwrap()).clone();
        raw_results.push((name.to_string(), r.mean_s));
    }

    // End-to-end: the same Philly replay with and without a journal. The
    // journaled run shares one WAL across iterations — appends stay O(1),
    // and no per-iteration setup pollutes the measurement.
    let (wal, _) = Wal::open(&dir.join("replay"), FsyncPolicy::EveryN(64)).expect("open WAL");
    let wal = Rc::new(RefCell::new(wal));
    let mem = b.bench(&format!("replay_{n_jobs}jobs_in_memory"), || replay(&jobs, None)).clone();
    let jnl = b
        .bench(&format!("replay_{n_jobs}jobs_journaled"), || replay(&jobs, Some(wal.clone())))
        .clone();
    b.report();

    let overhead = (jnl.mean_s - mem.mean_s) / mem.mean_s.max(1e-12);
    println!(
        "journal overhead on a {n_jobs}-job philly replay: {:.2}% \
         (in-memory {:.3e}s, journaled {:.3e}s)",
        overhead * 100.0,
        mem.mean_s,
        jnl.mean_s
    );

    let mut payload = Json::obj();
    let mut raw = Json::obj();
    for (name, mean_s) in &raw_results {
        raw.set(name.as_str(), *mean_s);
    }
    payload
        .set("bench", "wal")
        .set("smoke", fast)
        .set("workload", format!("philly(seed 11, {n_jobs} jobs)"))
        .set("append_mean_s", raw)
        .set("replay_in_memory_mean_s", mem.mean_s)
        .set("replay_journaled_mean_s", jnl.mean_s)
        .set("journal_overhead_frac", overhead);
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_wal.json");
    frenzy::util::write_file(&path, &payload.to_string_pretty()).expect("write BENCH_wal.json");
    println!("wrote {}", path.display());

    let _ = std::fs::remove_dir_all(&dir);

    if !fast {
        assert!(
            overhead < 0.10,
            "journaled replay must stay within 10% of in-memory, got {:.2}%",
            overhead * 100.0
        );
        println!("acceptance: journal overhead <10% — OK ({:.2}%)", overhead * 100.0);
    }
}
