//! Bench: Fig 5(b) — trace-driven JCT simulation (Philly / Helios), Frenzy
//! vs Sia, plus the figure output.

use frenzy::bench_harness::Bench;
use frenzy::config::sia_sim;
use frenzy::marp::Marp;
use frenzy::sched::{has::Has, sia::Sia};
use frenzy::sim::{simulate, SimConfig};
use frenzy::workload::{helios, philly};

fn main() {
    std::env::set_var("FRENZY_BENCH_FAST", "1");
    let spec = sia_sim();
    let mut b = Bench::new("fig5b_traces");
    let philly_trace = philly::generate(80, 11);
    let helios_trace = helios::generate(80, 11);
    for (name, trace) in [("philly", &philly_trace), ("helios", &helios_trace)] {
        b.bench(&format!("frenzy_{name}_80"), || {
            let mut has = Has::new(Marp::with_defaults(spec.clone()));
            simulate(&spec, &mut has, trace, SimConfig::default(), name).avg_jct_s
        });
        b.bench(&format!("sia_{name}_80"), || {
            let mut sia = Sia::new(&spec);
            sia.node_limit = 200_000;
            simulate(&spec, &mut sia, trace, SimConfig::default(), name).avg_jct_s
        });
    }
    b.report();
    frenzy::exp::fig5b::report();
}
