//! Bench: Fig 4 — end-to-end NewWorkload simulation under Frenzy and
//! Opportunistic scheduling (also reports the figure's metrics).

use frenzy::bench_harness::Bench;
use frenzy::config::real_testbed;
use frenzy::marp::Marp;
use frenzy::sched::{has::Has, opportunistic::Opportunistic};
use frenzy::sim::{simulate, SimConfig};
use frenzy::workload::newworkload;

fn main() {
    std::env::set_var("FRENZY_BENCH_FAST", "1"); // sims are ~ms; keep iters sane
    let spec = real_testbed();
    let mut b = Bench::new("fig4_e2e_sim");
    for &tasks in &[30usize, 60] {
        let trace = newworkload::generate(tasks, 11);
        b.bench(&format!("frenzy_{tasks}"), || {
            let mut has = Has::new(Marp::with_defaults(spec.clone()));
            simulate(&spec, &mut has, &trace, SimConfig::default(), "nw").avg_jct_s
        });
        b.bench(&format!("opportunistic_{tasks}"), || {
            let mut opp = Opportunistic::new(&spec);
            simulate(&spec, &mut opp, &trace, SimConfig::default(), "nw").avg_jct_s
        });
    }
    b.report();
    // And the figure itself, once.
    frenzy::exp::fig4::report();
}
