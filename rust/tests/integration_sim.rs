//! Integration: simulator × schedulers × workloads, cross-module invariants.

use frenzy::config::{real_testbed, sia_sim};
use frenzy::marp::Marp;
use frenzy::sched::{has::Has, opportunistic::Opportunistic, sia::Sia, Scheduler};
use frenzy::sim::{simulate, SimConfig, Simulator};
use frenzy::workload::{helios, newworkload, philly};

#[test]
fn every_scheduler_terminates_on_newworkload() {
    let spec = real_testbed();
    let trace = newworkload::generate(30, 11);
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let mut opp = Opportunistic::new(&spec);
    let mut sia = Sia::new(&spec);
    sia.node_limit = 100_000;
    let scheds: Vec<&mut dyn Scheduler> = vec![&mut has, &mut opp, &mut sia];
    for sched in scheds {
        let name = sched.name();
        let report = simulate(&spec, sched, &trace, SimConfig::default(), "nw30");
        assert_eq!(
            report.n_completed + report.n_rejected,
            30,
            "{name}: every job must reach a terminal state"
        );
        assert!(report.n_completed >= 25, "{name}: most jobs should complete");
        assert!(report.makespan_s > 0.0);
    }
}

#[test]
fn sim_conserves_resources_across_all_traces() {
    for (name, trace) in [
        ("nw", newworkload::generate(40, 3)),
        ("philly", philly::generate(60, 3)),
        ("helios", helios::generate(40, 3)),
    ] {
        let spec = sia_sim();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut sim = Simulator::new(&spec, &mut has, SimConfig::default());
        sim.submit_all(&trace);
        let report = sim.run(name);
        assert!(sim.conservation_ok(), "{name}: ledger conservation");
        assert_eq!(
            sim.cluster_state().idle_gpus(),
            sim.cluster_state().total_gpus(),
            "{name}: all GPUs returned"
        );
        assert_eq!(report.n_completed + report.n_rejected, trace.len());
    }
}

#[test]
fn aggregates_have_sane_timings() {
    // The engine streams per-job results into bounded aggregates; the
    // invariants the old per-outcome check asserted are still visible
    // there: queue times are non-negative (start >= submit), JCTs are
    // positive (finish > start), throughput is positive, and no JCT can
    // exceed the makespan.
    let spec = real_testbed();
    let trace = newworkload::generate(25, 5);
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let mut sim = Simulator::new(&spec, &mut has, SimConfig::default());
    sim.submit_all(&trace);
    let report = sim.run("nw");
    let agg = sim.aggregates();
    assert!(agg.n_completed > 0);
    assert!(agg.min_queue_s() >= 0.0, "every job starts after its submit");
    assert!(agg.jct_min_s() > 0.0, "every job finishes after it starts");
    assert!(agg.jct_max_s() <= report.makespan_s + 1e-9, "JCT bounded by makespan");
    assert!(agg.avg_samples_per_sec() > 0.0);
    // The histogram accounts for every completed job.
    let hist_total: u64 =
        report.jct_hist.iter().map(|&(_, c)| c).sum::<u64>() + report.jct_hist_overflow;
    assert_eq!(hist_total, agg.n_completed as u64);
    // Per-job timings remain auditable through the event log: every
    // Finished record has a matching earlier Placed record.
    use frenzy::engine::EventKind;
    let log = sim.event_log();
    for rec in log.iter() {
        if let EventKind::Finished { job, epoch } = rec.kind {
            let placed = log.iter().any(|p| {
                matches!(p.kind, EventKind::Placed { job: pj, epoch: pe, .. }
                    if pj == job && pe == epoch)
                    && p.seq < rec.seq
                    && p.time <= rec.time
            });
            assert!(placed, "job {job} finished without a placement record");
        }
    }
}

#[test]
fn heavier_load_means_longer_queues() {
    let spec = real_testbed();
    let run = |n: usize| {
        let trace = newworkload::generate(n, 13);
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        simulate(&spec, &mut has, &trace, SimConfig::default(), "nw")
    };
    let light = run(10);
    let heavy = run(60);
    assert!(
        heavy.avg_queue_s >= light.avg_queue_s,
        "60-task queue time {:.1}s must be >= 10-task {:.1}s",
        heavy.avg_queue_s,
        light.avg_queue_s
    );
}

#[test]
fn frenzy_has_zero_oom_retries() {
    // Memory-awareness is the whole point: HAS placements never OOM.
    for seed in [1u64, 7, 23] {
        let spec = real_testbed();
        let trace = newworkload::generate(40, seed);
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let report = simulate(&spec, &mut has, &trace, SimConfig::default(), "nw");
        assert_eq!(report.total_oom_retries, 0, "seed {seed}");
    }
}

#[test]
fn sched_overhead_charged_into_queue_time() {
    // The same trace under a scheduler with huge per-unit cost must show
    // longer queues — validates the overhead-injection path Fig 5 relies on.
    let spec = sia_sim();
    let trace = philly::generate(60, 29);
    let run = |unit: f64| {
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let cfg = SimConfig { sched_work_unit_s: unit, ..SimConfig::default() };
        simulate(&spec, &mut has, &trace, cfg, "ph")
    };
    let cheap = run(0.0);
    let pricey = run(1.0); // 1 s per work unit — absurd on purpose
    assert!(pricey.avg_queue_s > cheap.avg_queue_s);
    assert!(pricey.avg_jct_s > cheap.avg_jct_s);
}
