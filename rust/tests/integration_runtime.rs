//! Integration: the PJRT runtime executing the real AOT artifacts.
//!
//! These tests require `make artifacts` to have produced `artifacts/`; when
//! absent they skip (printing why) so `cargo test` stays usable before the
//! python build step. CI order: `make artifacts` → `cargo test`.

use frenzy::runtime::{synth_tokens, Manifest, Runtime};

fn manifest_or_skip() -> Option<Manifest> {
    let dir = frenzy::util::repo_path("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn loads_compiles_and_trains_tiny_model() {
    let Some(manifest) = manifest_or_skip() else { return };
    let meta = manifest.model("gpt2-tiny").expect("tiny model in manifest");
    let mut rt = Runtime::new().expect("pjrt cpu client");
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    let mut session = rt.start_session(meta).expect("session");
    let losses = session.run(12).expect("12 steps");
    assert_eq!(losses.len(), 12);
    for l in &losses {
        assert!(l.is_finite(), "loss must be finite: {losses:?}");
    }
    // Training on the deterministic stream must make progress.
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss should decrease: {losses:?}"
    );
}

#[test]
fn rust_losses_match_python_oracle() {
    // THE cross-language correctness check: the python reference (same
    // tokens, same init seed) recorded its first losses in the manifest;
    // the rust PJRT execution must reproduce them within tolerance.
    let Some(manifest) = manifest_or_skip() else { return };
    for meta in manifest.models.values() {
        if meta.oracle_losses.is_empty() {
            continue;
        }
        let mut rt = Runtime::new().expect("client");
        let mut session = rt.start_session(meta).expect("session");
        session.run(meta.oracle_losses.len() as u64).expect("steps");
        session.check_oracle().unwrap_or_else(|e| panic!("{}: {e:#}", meta.name));
    }
}

#[test]
fn state_vector_has_declared_length_and_changes() {
    let Some(manifest) = manifest_or_skip() else { return };
    let meta = manifest.model("gpt2-tiny").expect("tiny");
    let mut rt = Runtime::new().expect("client");
    let mut session = rt.start_session(meta).expect("session");
    let s0 = session.state_vec().expect("state");
    assert_eq!(s0.len(), meta.state_len);
    session.step().expect("step");
    let s1 = session.state_vec().expect("state");
    let changed = s0.iter().zip(&s1).filter(|(a, b)| a != b).count();
    assert!(
        changed > meta.param_count / 2,
        "most parameters should move in one Adam step (changed {changed})"
    );
}

#[test]
fn deterministic_across_sessions() {
    let Some(manifest) = manifest_or_skip() else { return };
    let meta = manifest.model("gpt2-tiny").expect("tiny");
    let mut rt = Runtime::new().expect("client");
    let mut a = rt.start_session(meta).expect("session a");
    let mut b = rt.start_session(meta).expect("session b");
    let la = a.run(5).expect("a");
    let lb = b.run(5).expect("b");
    assert_eq!(la, lb, "init + data are deterministic, so losses must match");
}

#[test]
fn synth_tokens_matches_python_formula_snapshot() {
    // Golden values mirrored in python/tests/test_data.py — keep in sync.
    let toks = synth_tokens(2, 4, 97, 5);
    assert_eq!(toks, vec![85, 1, 14, 27, 92, 8, 21, 34]);
}
