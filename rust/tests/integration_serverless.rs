//! Integration: the live serverless coordinator + HTTP API (control plane
//! with the training stub; the PJRT-backed path is exercised by the
//! e2e_train example and the runtime integration tests).

use frenzy::config::{real_testbed, sia_sim};
use frenzy::job::JobState;
use frenzy::serverless::http::{parse_request, route, Request};
use frenzy::serverless::{spawn, CoordinatorConfig, SubmitRequest};
use std::io::Write;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn cfg_stub() -> CoordinatorConfig {
    CoordinatorConfig { execute_training: false, ..CoordinatorConfig::default() }
}

#[test]
fn fifty_jobs_drain_on_sia_sim() {
    let (h, _j) = spawn(sia_sim(), cfg_stub());
    let mut ids = Vec::new();
    for i in 0..50u32 {
        let model = ["gpt2-125m", "gpt2-350m", "gpt2-760m", "bert-base"][i as usize % 4];
        ids.push(
            h.submit(SubmitRequest {
                model: model.into(),
                global_batch: 4 << (i % 3),
                total_samples: 100 + i as u64,
            })
            .unwrap(),
        );
    }
    h.drain().unwrap();
    for id in ids {
        let st = h.status(id).unwrap().unwrap();
        assert_eq!(st.state, JobState::Completed, "job {id}");
    }
    let (total, idle, _) = h.cluster_info().unwrap();
    assert_eq!(total, idle);
    let report = h.report().unwrap();
    assert_eq!(report.n_completed, 50);
    h.shutdown();
}

#[test]
fn http_full_cycle_over_tcp() {
    let (h, _j) = spawn(real_testbed(), cfg_stub());
    let stop = Arc::new(AtomicBool::new(false));
    let addr = frenzy::serverless::http::serve(h.clone(), "127.0.0.1:0", stop.clone()).unwrap();

    // `Connection: close` so read_to_string sees EOF (the v1 server keeps
    // HTTP/1.1 connections alive by default).
    let post = |body: &str| -> (u16, String) {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        write!(
            s,
            "POST /jobs HTTP/1.1\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        read_response(s)
    };
    let get = |path: &str| -> (u16, String) {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        read_response(s)
    };

    let (code, body) = get("/healthz");
    assert_eq!(code, 200, "{body}");

    let (code, body) = post(r#"{"model":"gpt2-760m","batch":8,"samples":200}"#);
    assert_eq!(code, 200, "{body}");
    let id = frenzy::util::json::parse(&body).unwrap().get("job_id").unwrap().as_u64().unwrap();

    h.drain().unwrap();
    let (code, body) = get(&format!("/jobs/{id}"));
    assert_eq!(code, 200);
    assert!(body.contains("completed"), "{body}");

    let (code, body) = get("/cluster");
    assert_eq!(code, 200);
    assert!(body.contains("idle_gpus"), "{body}");

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.shutdown();
}

fn read_response(mut s: std::net::TcpStream) -> (u16, String) {
    use std::io::Read;
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let code: u16 = buf.split_whitespace().nth(1).unwrap_or("0").parse().unwrap_or(0);
    let body = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (code, body)
}

#[test]
fn http_parser_handles_pipelined_headers() {
    let raw = "GET /cluster HTTP/1.1\r\nHost: x\r\nX-Weird: a:b:c\r\nContent-Length: 0\r\n\r\n";
    let mut r = std::io::BufReader::new(raw.as_bytes());
    let req = parse_request(&mut r).unwrap();
    assert_eq!(req.method, "GET");
    assert_eq!(req.path, "/cluster");
    assert!(req.body.is_empty());
}

#[test]
fn concurrent_submitters() {
    let (h, _j) = spawn(sia_sim(), cfg_stub());
    let mut threads = Vec::new();
    for t in 0..4 {
        let h2 = h.clone();
        threads.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            for i in 0..10u64 {
                ids.push(
                    h2.submit(SubmitRequest {
                        model: "gpt2-350m".into(),
                        global_batch: 8,
                        total_samples: 64 + t * 10 + i,
                    })
                    .unwrap(),
                );
            }
            ids
        }));
    }
    let all: Vec<u64> = threads.into_iter().flat_map(|t| t.join().unwrap()).collect();
    assert_eq!(all.len(), 40);
    let mut dedup = all.clone();
    dedup.sort_unstable();
    dedup.dedup();
    assert_eq!(dedup.len(), 40, "job ids must be unique");
    h.drain().unwrap();
    let report = h.report().unwrap();
    assert_eq!(report.n_completed, 40);
    h.shutdown();
}

#[test]
fn legacy_unversioned_routes_still_alias_v1_after_engine_refactor() {
    // The coordinator was rebuilt on the shared scheduling engine; the
    // pre-v1 compat shim must be unaffected: every unversioned path still
    // aliases its /v1 twin, and the *new* v1-only surface gained no alias.
    let (h, _j) = spawn(real_testbed(), cfg_stub());
    let req = |method: &str, path: &str, body: &str| {
        route(&h, &Request { method: method.into(), path: path.into(), body: body.into() })
    };
    for (legacy, versioned) in [("/healthz", "/v1/healthz"), ("/cluster", "/v1/cluster")] {
        let (ls, lb) = req("GET", legacy, "");
        let (vs, vb) = req("GET", versioned, "");
        assert_eq!(ls, 200, "{legacy}");
        assert_eq!((ls, &lb), (vs, &vb), "{legacy} must answer exactly like {versioned}");
    }
    let body = r#"{"model":"gpt2-350m","batch":8,"samples":60}"#;
    let (s, b) = req("POST", "/jobs", body);
    assert_eq!(s, 200, "{b}");
    let id = frenzy::util::json::parse(&b).unwrap().get("job_id").unwrap().as_u64().unwrap();
    h.drain().unwrap();
    let (s, b) = req("GET", &format!("/jobs/{id}"), "");
    assert_eq!(s, 200);
    assert!(b.contains("completed"), "{b}");
    let (s, legacy_list) = req("GET", "/jobs", "");
    assert_eq!(s, 200);
    let (_, v1_list) = req("GET", "/v1/jobs", "");
    assert_eq!(legacy_list, v1_list, "listing identical through the alias");
    // Cancel alias still answers (409: the job already completed).
    let (s, _) = req("POST", &format!("/jobs/{id}/cancel"), "");
    assert_eq!(s, 409);
    // The elastic scale route is v1-only — no legacy alias was grown.
    let (s, _) = req("POST", "/cluster/scale", r#"{"op":"leave","node":0}"#);
    assert_eq!(s, 404);
    let (s, _) = req("POST", "/v1/cluster/scale", r#"{"op":"join","gpu":"A100-40G","count":1}"#);
    assert_eq!(s, 200);
    h.shutdown();
}

#[test]
fn route_rejects_garbage_without_crashing_coordinator() {
    let (h, _j) = spawn(real_testbed(), cfg_stub());
    for body in ["", "{}", "[1,2]", r#"{"model":123}"#, r#"{"model":"gpt2-350m","batch":0,"samples":0}"#]
    {
        let (code, _) = route(
            &h,
            &Request { method: "POST".into(), path: "/jobs".into(), body: body.into() },
        );
        assert_eq!(code, 400, "body: {body}");
    }
    // Coordinator still alive.
    assert!(h.cluster_info().is_ok());
    h.shutdown();
}
