//! Integration: the checkpoint-aware job runtime — device-memory
//! accounting (real, ledger-observed OOMs) and graceful drain
//! (checkpoint → release → requeue → resume) — across the simulator, the
//! engine, and the live coordinator + HTTP API.

use frenzy::config::models::model_by_name;
use frenzy::config::{gpu_by_name, gpu_catalog, real_testbed, ClusterSpec, LinkKind, NodeSpec};
use frenzy::engine::clock::VirtualClock;
use frenzy::engine::{ClusterEvent, EngineConfig, EventKind, SchedulingEngine};
use frenzy::job::{JobSpec, JobState};
use frenzy::marp::Marp;
use frenzy::runtime::checkpoint::state_digest;
use frenzy::sched::has::Has;
use frenzy::sched::opportunistic::Opportunistic;
use frenzy::serverless::api::EventsRequestV1;
use frenzy::serverless::client::FrenzyClient;
use frenzy::serverless::{spawn, CoordinatorConfig, ScaleOp, SubmitRequest};
use frenzy::sim::{SimConfig, Simulator};
use frenzy::util::prop::Runner;

fn job(id: u64, model: &str, batch: u32, samples: u64, t: f64) -> JobSpec {
    JobSpec::new(id, model_by_name(model).unwrap(), batch, samples, t)
}

/// The acceptance scenario: a `NodeLeave` mid-job drains the hosted job —
/// checkpoint, release, requeue — and the job resumes from its checkpoint
/// instead of step 0, so the total executed steps stay strictly under
/// twice the job's nominal steps.
#[test]
fn sim_node_leave_resumes_from_checkpoint() {
    let spec = real_testbed();
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let cfg = SimConfig {
        drain_grace_s: 60.0,
        ckpt_every_steps: 10,
        ckpt_write_s: 2.0,
        max_sim_time_s: 1e18,
        ..SimConfig::default()
    };
    let mut sim = Simulator::new(&spec, &mut has, cfg);
    let total_samples: u64 = 100_000_000;
    let batch = 8u32;
    sim.submit_all(&[job(0, "gpt2-350m", batch, total_samples, 0.0)]);
    // Retire whichever node hosts the job: with one job on an empty
    // cluster, the first placement's first part names it. HAS places at
    // t=0, so by t=2000 the job has run long enough to have checkpoints.
    // (We cannot know the node before running, so retire all candidates'
    // worth: node ids are stable, and the job is on exactly one of 0..5 —
    // retiring every node except one forces the drain + migration.)
    for node in 0..4usize {
        sim.schedule_event(2_000.0 + node as f64, ClusterEvent::NodeLeave(node));
    }
    let report = sim.run("drain-accept");
    assert_eq!(report.n_completed, 1, "the drained job still completes");
    assert!(report.n_drains >= 1, "the leave must have drained, not killed, the job");
    assert!(sim.conservation_ok());

    // The drain story is in the audit log, with checkpoint handoff intact.
    let mut drained_steps = None;
    let mut resumed_steps = None;
    for r in sim.event_log().iter() {
        match r.kind {
            EventKind::Drained { job: 0, steps_ckpt, state_digest: d, .. } => {
                assert_eq!(d, state_digest(0, steps_ckpt), "digest fingerprints the snapshot");
                drained_steps = Some(steps_ckpt);
            }
            EventKind::ResumedFromCkpt { job: 0, steps_ckpt, .. } => {
                resumed_steps = Some(steps_ckpt);
            }
            _ => {}
        }
    }
    let drained = drained_steps.expect("a Drained record for job 0");
    assert!(drained >= 10, "progress survived in checkpoint units");
    assert_eq!(resumed_steps, Some(drained), "the resume picked up exactly the checkpoint");

    // Total executed steps < 2× nominal: the whole point of resuming.
    let nominal = total_samples / batch as u64;
    let executed = report.total_steps_executed;
    assert!(
        executed >= nominal && executed < 2 * nominal,
        "executed {executed} vs nominal {nominal}: must resume, not restart"
    );
    // Prediction accuracy folded into the report on every dispatch.
    assert!(report.mem_pred_samples >= 2, "initial placement + resume sampled");
    assert!(report.mem_pred_accuracy_avg > 0.9, "paper band: {}", report.mem_pred_accuracy_avg);
}

/// A memory-oblivious placement must produce an `oom_observed` event from
/// the byte ledger — on a virtual clock there is no OOM-detection timer
/// anywhere; the charge itself raises the crash.
#[test]
fn sim_memory_oblivious_placement_yields_observed_oom() {
    let spec = real_testbed();
    let mut opp = Opportunistic::new(&spec);
    let mut sim = Simulator::new(&spec, &mut opp, SimConfig::default());
    let jobs: Vec<JobSpec> =
        (0..4).map(|i| job(i, "gpt2-2.7b", 8, 50_000, i as f64 * 10.0)).collect();
    sim.submit_all(&jobs);
    let report = sim.run("oom-accept");
    assert_eq!(report.n_completed + report.n_rejected, 4);
    assert!(report.n_oom_events > 0, "the mis-sized placements must OOM");
    // Every OOM is explained by a ledger observation with real bytes.
    let observed: Vec<(u64, u64, u64)> = sim
        .event_log()
        .iter()
        .filter_map(|r| match r.kind {
            EventKind::OomObserved { predicted_bytes, observed_bytes, capacity_bytes, .. } => {
                Some((predicted_bytes, observed_bytes, capacity_bytes))
            }
            _ => None,
        })
        .collect();
    assert!(!observed.is_empty(), "OOMs must be ledger-observed");
    for (pred, obs, cap) in observed {
        assert!(obs > cap, "observed {obs} must exceed capacity {cap}");
        assert!(pred > 0);
    }
    assert!(sim.conservation_ok());
}

/// Property: under random elastic churn with graceful drain enabled (and
/// activation jitter on the byte ledger), GPU counts AND device-memory
/// bytes are conserved after every event — no leak, no double-free — and
/// every job still reaches a terminal state.
#[test]
fn prop_drain_conserves_gpus_and_bytes_under_churn() {
    Runner::new("drain conservation", 0xD4A15, 10).run(|g| {
        // Random heterogeneous cluster, guaranteed to host every model.
        let catalog = gpu_catalog();
        let mut nodes = vec![NodeSpec {
            gpu: gpu_by_name("A800-80G").unwrap(),
            count: 4,
            link: LinkKind::NvLink,
        }];
        for _ in 0..g.usize_in(1, 4) {
            nodes.push(NodeSpec {
                gpu: g.pick(&catalog).clone(),
                count: g.usize_in(1, 4) as u32,
                link: if g.bool() { LinkKind::NvLink } else { LinkKind::Pcie },
            });
        }
        let n_nodes = nodes.len();
        let cluster = ClusterSpec { name: "churn".into(), nodes, inter_node_gbps: 25.0 };
        let mut has = Has::new(Marp::with_defaults(cluster.clone()));
        let cfg = EngineConfig {
            drain_grace_s: 30.0,
            ckpt_every_steps: g.usize_in(1, 50) as u64,
            ckpt_write_s: 2.0,
            // Jitter makes the observed peak vary per (job, epoch): some
            // tight placements may genuinely OOM — the ledger must stay
            // conserved through those crashes too.
            mem_jitter_frac: 0.02,
            ..EngineConfig::default()
        };
        let mut engine = SchedulingEngine::new(&cluster, &mut has, cfg);
        let mut clock = VirtualClock::new();
        let models = ["gpt2-125m", "gpt2-350m", "gpt2-760m", "bert-large"];
        let n_jobs = g.usize_in(3, 10);
        for i in 0..n_jobs {
            let t = g.f64_in(0.0, 500.0);
            clock.schedule(
                t,
                ClusterEvent::Arrival(job(
                    i as u64,
                    models[g.usize_in(0, models.len() - 1)],
                    1 << g.usize_in(0, 4),
                    g.usize_in(10_000, 2_000_000) as u64,
                    t,
                )),
            );
        }
        for _ in 0..g.usize_in(1, 3) {
            clock.schedule(
                g.f64_in(50.0, 5_000.0),
                ClusterEvent::NodeLeave(g.usize_in(0, n_nodes - 1)),
            );
        }
        // An elastic join mid-churn (sometimes of a never-seen GPU size —
        // the incremental class insert must hold up under drain traffic).
        clock.schedule(
            g.f64_in(100.0, 2_000.0),
            ClusterEvent::NodeJoin(NodeSpec {
                gpu: g.pick(&catalog).clone(),
                count: g.usize_in(1, 4) as u32,
                link: LinkKind::Pcie,
            }),
        );
        let mut guard = 0;
        while let Some((_, ev)) = clock.pop() {
            engine.handle(ev, &mut clock);
            if !engine.conservation_ok() {
                return Err("GPU/byte conservation violated after event".into());
            }
            engine.run_round(&mut clock);
            if !engine.conservation_ok() {
                return Err("GPU/byte conservation violated after round".into());
            }
            guard += 1;
            if guard > 200_000 {
                return Err("event loop did not terminate".into());
            }
        }
        let agg = engine.aggregates();
        if agg.n_completed + engine.rejected_count() != n_jobs {
            return Err(format!(
                "{} completed + {} rejected != {n_jobs}",
                agg.n_completed,
                engine.rejected_count()
            ));
        }
        if engine.device_memory().total_used_bytes() != 0 {
            return Err("device-memory bytes leaked past the last release".into());
        }
        if engine.checkpoint_count() != 0 {
            return Err("checkpoint store leaked entries for terminal jobs".into());
        }
        Ok(())
    });
}

/// Sim-vs-live differential: the same drain-and-resume scenario through
/// the virtual clock and through the wall-clock coordinator must produce
/// identical terminal states and conserve the job's step total — the
/// checkpoint handed to the resume equals the one written by the drain
/// (same digest function on both clocks), nothing is lost or re-counted.
#[test]
fn differential_checkpoint_resume_sim_vs_live() {
    let total_samples: u64 = 1_000_000_000;
    let batch = 1u32;
    let nominal = total_samples / batch as u64;

    // Asserts the drain→resume bookkeeping within one event log and
    // returns (drained steps_ckpt, executed steps from the report).
    let check_log = |events: Vec<EventKind>, executed: u64, label: &str| -> u64 {
        let mut drained_steps = None;
        let mut resumed_steps = None;
        for k in &events {
            match *k {
                EventKind::Drained { steps_ckpt, state_digest: d, job, .. } => {
                    assert_eq!(d, state_digest(job, steps_ckpt), "{label}: digest");
                    drained_steps = Some(steps_ckpt);
                }
                EventKind::ResumedFromCkpt { steps_ckpt, .. } => {
                    resumed_steps = Some(steps_ckpt);
                }
                _ => {}
            }
        }
        let drained = drained_steps.unwrap_or_else(|| panic!("{label}: no Drained record"));
        assert!(drained >= 1, "{label}: checkpointed progress");
        assert_eq!(resumed_steps, Some(drained), "{label}: resume == checkpoint");
        assert!(
            executed >= nominal && executed < 2 * nominal,
            "{label}: executed {executed} vs nominal {nominal}"
        );
        drained
    };

    // --- virtual-clock path --------------------------------------------
    let spec = real_testbed();
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let cfg = SimConfig {
        drain_grace_s: 60.0,
        ckpt_every_steps: 1,
        ckpt_write_s: 1.0,
        max_sim_time_s: 1e18,
        ..SimConfig::default()
    };
    let mut sim = Simulator::new(&spec, &mut has, cfg);
    sim.submit_all(&[job(0, "gpt2-350m", batch, total_samples, 0.0)]);
    for node in 0..4usize {
        sim.schedule_event(2_000.0 + node as f64, ClusterEvent::NodeLeave(node));
    }
    let sim_report = sim.run("ckpt-diff");
    assert_eq!(sim_report.n_completed, 1, "sim: job completes");
    let sim_events: Vec<EventKind> = sim.event_log().iter().map(|r| r.kind.clone()).collect();
    check_log(sim_events, sim_report.total_steps_executed, "sim");

    // --- wall-clock path -----------------------------------------------
    let cfg = CoordinatorConfig {
        execute_training: false,
        stub_delay_ms: 1_000,
        drain_grace_ms: 60,
        ckpt_write_ms: 10,
        ckpt_every_steps: 1,
        ..CoordinatorConfig::default()
    };
    let (h, _j) = spawn(real_testbed(), cfg);
    let id = h
        .submit(SubmitRequest {
            model: "gpt2-350m".into(),
            global_batch: batch,
            total_samples,
        })
        .unwrap();
    assert_eq!(h.status(id).unwrap().unwrap().state, JobState::Running);
    // Let wall-clock progress accrue so the drain has steps to checkpoint
    // (modeled throughput is tens of samples/s; batch 1 ⇒ well over one
    // whole step by now), while staying far inside the 1 s stub run.
    std::thread::sleep(std::time::Duration::from_millis(400));
    let node = h.decisions().unwrap()[0].1[0].0;
    let rep = h.scale(ScaleOp::Leave { node }).unwrap();
    assert_eq!(rep.preempted, vec![id]);
    h.drain().unwrap();
    // Identical terminal state.
    assert_eq!(h.status(id).unwrap().unwrap().state, JobState::Completed);
    let live_report = h.report().unwrap();
    assert_eq!(live_report.n_completed, 1);
    assert_eq!(live_report.n_drains, 1);
    let live_events: Vec<EventKind> =
        h.events(0, 1000).unwrap().events.into_iter().map(|r| r.kind).collect();
    check_log(live_events, live_report.total_steps_executed, "live");
    let (total, idle, _) = h.cluster_info().unwrap();
    assert_eq!(total, idle, "live: all resources released");
    h.shutdown();
}

/// The full network path: `GET /v1/report` carries the
/// prediction-accuracy fields, and `GET /v1/cluster/events?wait_ms=`
/// long-polls (empty page only after the hold, immediate page once events
/// exist).
#[test]
fn report_accuracy_and_events_long_poll_over_http() {
    let cfg = CoordinatorConfig { execute_training: false, ..CoordinatorConfig::default() };
    let (h, _j) = spawn(real_testbed(), cfg);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let addr =
        frenzy::serverless::server::serve(h.clone(), "127.0.0.1:0", stop.clone()).unwrap();
    let mut c = FrenzyClient::new(addr.to_string());

    // Long-poll with nothing to report: held, then an empty page.
    let t0 = std::time::Instant::now();
    let page = c
        .events(&EventsRequestV1 { since: 0, limit: 100, wait_ms: 150, stream: false })
        .unwrap();
    assert!(page.events.is_empty());
    assert!(t0.elapsed() >= std::time::Duration::from_millis(140), "server held the poll");

    let id = c.submit("gpt2-350m", 8, 400).unwrap();
    h.drain().unwrap();

    // Now the same long-poll answers immediately with the history.
    let t1 = std::time::Instant::now();
    let page = c
        .events(&EventsRequestV1 { since: 0, limit: 100, wait_ms: 10_000, stream: false })
        .unwrap();
    assert!(t1.elapsed() < std::time::Duration::from_secs(5), "events exist: no hold");
    assert!(page
        .events
        .iter()
        .any(|e| matches!(e.kind, EventKind::Finished { job, .. } if job == id)));

    // The streaming report carries the paper's prediction-accuracy metric.
    let r = c.report().unwrap();
    assert_eq!(r.n_completed, 1);
    assert!(r.mem_pred_samples >= 1, "the dispatch was sampled");
    assert!(
        r.mem_pred_accuracy_avg > 0.9 && r.mem_pred_accuracy_avg <= 1.0,
        "accuracy {} out of the paper's >92% band",
        r.mem_pred_accuracy_avg
    );
    assert!(r.mem_pred_accuracy_min > 0.0);

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.shutdown();
}
