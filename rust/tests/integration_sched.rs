//! Integration: scheduler behaviour on the paper's topologies.

use frenzy::cluster::{ClusterState, ClusterView, Orchestrator};
use frenzy::config::models::model_by_name;
use frenzy::config::{real_testbed, sia_sim, GIB};
use frenzy::job::JobSpec;
use frenzy::marp::Marp;
use frenzy::sched::{
    has::Has, opportunistic::Opportunistic, sia::Sia, PendingJob, PendingQueue, Scheduler,
};

fn pending(id: u64, model: &str, batch: u32) -> PendingJob {
    PendingJob {
        spec: JobSpec::new(id, model_by_name(model).unwrap(), batch, 10_000, 0.0),
        attempts: 0,
    }
}

fn q(jobs: Vec<PendingJob>) -> PendingQueue {
    PendingQueue::from(jobs)
}

#[test]
fn has_best_fit_preserves_big_gpus_for_big_jobs() {
    // Two 1-GPU-class plans placed by Algorithm 1 must take the 40G cards
    // (best fit), so that a following 7B job still finds its 80G (or
    // 8×40G-equivalent) resources free.
    use frenzy::marp::ResourcePlan;
    use frenzy::memory::Parallelism;
    let spec = real_testbed();
    let mut orch = Orchestrator::new(&spec);
    let small_plan = ResourcePlan {
        par: Parallelism::new(1, 1),
        n_gpus: 1,
        min_gpu_mem: 20 * GIB,
        predicted_bytes: 18 * GIB,
        est_samples_per_sec: 1.0,
        est_efficiency: 1.0,
        score: 1.0,
    };
    for job in [1u64, 2] {
        let mut work = 0;
        let (_, mut alloc) =
            Has::allocate_one(std::slice::from_ref(&small_plan), &orch.snapshot(), &mut work)
                .expect("place small");
        alloc.job = job;
        let node = alloc.parts[0].0;
        assert_eq!(
            orch.snapshot().nodes[node].gpu.mem_bytes,
            40 * GIB,
            "small job must take a 40G card, got {alloc:?}"
        );
        orch.allocate(alloc).unwrap();
    }

    // The 7B job now arrives; the 80G pool is untouched, so it schedules.
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let round2 = has.schedule(&q(vec![pending(3, "gpt2-7b", 2)]), &orch.view(), 1.0);
    assert_eq!(round2.decisions.len(), 1, "7B must still fit");
    let d2 = &round2.decisions[0];
    assert!(!d2.will_oom);
    assert!(d2.gpu.mem_bytes >= 40 * GIB);
    orch.allocate(d2.alloc.clone()).unwrap();
    assert!(orch.check_conservation());
}

#[test]
fn opportunistic_grabs_fast_nodes_first_and_fragments() {
    let spec = sia_sim();
    let mut opp = Opportunistic::new(&spec);
    let snap = ClusterState::from_spec(&spec);
    let view = ClusterView::build(&snap);
    // Four small jobs: all land on the A100 nodes, leaving 2080Tis idle.
    let jobs: Vec<PendingJob> = (0..4).map(|i| pending(i, "gpt2-125m", 4)).collect();
    let round = opp.schedule(&q(jobs), &view, 0.0);
    assert_eq!(round.decisions.len(), 4);
    for d in &round.decisions {
        assert_eq!(d.gpu.name, "A100-40G", "fastest-first policy");
    }
}

#[test]
fn sia_allocations_feasible_under_pressure() {
    let spec = sia_sim();
    let mut sia = Sia::new(&spec);
    sia.node_limit = 500_000;
    let snap = ClusterState::from_spec(&spec);
    let view = ClusterView::build(&snap);
    let jobs: Vec<PendingJob> = (0..20)
        .map(|i| {
            let m = ["gpt2-125m", "gpt2-350m", "gpt2-760m", "gpt2-1.3b"][i as usize % 4];
            pending(i, m, 8)
        })
        .collect();
    let round = sia.schedule(&q(jobs), &view, 0.0);
    assert!(!round.decisions.is_empty());
    let mut orch = Orchestrator::new(&spec);
    for d in &round.decisions {
        orch.allocate(d.alloc.clone()).expect("sia must respect capacity");
    }
    assert!(orch.check_conservation());
}

#[test]
fn all_schedulers_handle_empty_and_full_cluster() {
    let spec = real_testbed();
    let empty_snap = {
        let mut s = ClusterState::from_spec(&spec);
        for n in &mut s.nodes {
            n.idle = 0;
        }
        s
    };
    let fresh_snap = ClusterState::from_spec(&spec);
    let fresh_view = ClusterView::build(&fresh_snap);
    let empty_view = ClusterView::build(&empty_snap);
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let mut opp = Opportunistic::new(&spec);
    let mut sia = Sia::new(&spec);
    for sched in [&mut has as &mut dyn Scheduler, &mut opp, &mut sia] {
        assert!(sched.schedule(&q(vec![]), &fresh_view, 0.0).decisions.is_empty());
        assert!(
            sched
                .schedule(&q(vec![pending(1, "gpt2-350m", 8)]), &empty_view, 0.0)
                .decisions
                .is_empty(),
            "{}: nothing to give",
            sched.name()
        );
    }
}

#[test]
fn paper_example_job_2_32_prefers_node_3_40_over_6_80() {
    // §IV.B: "for Job(2,32), allocating it to Node(3,40) is more efficient
    // than Node(6,80)". Build exactly that cluster and check.
    use frenzy::config::cluster_file::parse_cluster;
    let spec = parse_cluster(
        "cluster paper-example\nnode A100-40G x3 pcie\nnode A100-80G x6 pcie\n",
    )
    .unwrap();
    let snap = ClusterState::from_spec(&spec);
    // A job whose plan needs 2 GPUs of ≥32G: gpt2-1.3b batch 8 gives d=2,t=1
    // ~27G requirement... use marp and grab a 2-GPU plan requiring ≤40G.
    let marp = Marp::with_defaults(spec.clone());
    let m = model_by_name("gpt2-1.3b").unwrap();
    let plans = marp.plans(&m, &frenzy::memory::TrainConfig { global_batch: 2 });
    let plan = plans
        .iter()
        .find(|p| p.n_gpus <= 3 && p.min_gpu_mem <= 40 * GIB)
        .expect("a ≤3-GPU 40G-class plan exists");
    let mut work = 0;
    let (_, alloc) =
        Has::allocate_one(std::slice::from_ref(plan), &snap, &mut work).expect("place");
    // All parts must sit on node 0 (the 3×40G node), not the 80G node.
    for (node, _) in &alloc.parts {
        assert_eq!(*node, 0, "best-fit must choose the 40G node: {alloc:?}");
    }
}

#[test]
fn paper_example_job_4_35_prefers_single_node() {
    // §IV.B: "For Job(4,35), it is more appropriate to schedule it on
    // Node(4,40) rather than four Node(1,40) units."
    use frenzy::config::cluster_file::parse_cluster;
    let spec = parse_cluster(
        "cluster paper-example2\nnode A100-40G x1 pcie\nnode A100-40G x1 pcie\nnode A100-40G x1 pcie\nnode A100-40G x1 pcie\nnode A100-40G x4 nvlink\n",
    )
    .unwrap();
    let snap = ClusterState::from_spec(&spec);
    let marp = Marp::with_defaults(spec.clone());
    let m = model_by_name("gpt2-2.7b").unwrap();
    let plans = marp.plans(&m, &frenzy::memory::TrainConfig { global_batch: 4 });
    let plan = plans.iter().find(|p| p.n_gpus == 4).expect("4-GPU plan");
    let mut work = 0;
    let (_, alloc) =
        Has::allocate_one(std::slice::from_ref(plan), &snap, &mut work).expect("place");
    assert_eq!(alloc.parts.len(), 1, "must use the single 4-GPU node: {alloc:?}");
    assert_eq!(alloc.parts[0].0, 4);
}
