//! Integration: the event-sourced durability subsystem — WAL + snapshots
//! + recovery — across the engine, the live coordinator, and the HTTP API.
//!
//! The load-bearing test is `kill_at_every_record_...`: a journaled
//! reference run is "crashed" after **every** WAL record, recovered by
//! pure replay, driven to completion, and required to reach the byte-for-
//! byte identical final engine state (modulo re-measured scheduler wall
//! time, which no replay can reproduce).

use frenzy::config::real_testbed;
use frenzy::durability::{recover, FsyncPolicy, SharedJournal, SnapshotStore, Wal, WalRecord};
use frenzy::engine::clock::{Clock, VirtualClock};
use frenzy::engine::{ClusterEvent, EngineConfig, SchedulingEngine};
use frenzy::job::{JobSpec, JobState};
use frenzy::marp::Marp;
use frenzy::sched::has::Has;
use frenzy::serverless::client::FrenzyClient;
use frenzy::serverless::{spawn, CoordinatorConfig, SubmitRequest};
use frenzy::util::json::Json;
use frenzy::workload::philly;
use std::cell::RefCell;
use std::rc::Rc;

fn temp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("frenzy_intdur_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Canonical final engine state: the deterministic snapshot minus
/// `sched_wall_s` — live rounds *measure* scheduler wall time, so two
/// otherwise-identical runs differ there by nature.
fn canonical(engine: &SchedulingEngine<'_>) -> String {
    let mut j = engine.snapshot_json();
    if let Json::Obj(m) = &mut j {
        m.remove("sched_wall_s");
    }
    j.to_string_compact()
}

/// Drive `jobs` through a journaled virtual-clock engine run to
/// completion; returns everything the WAL retained plus the canonical
/// final state the recovery runs must reproduce.
fn journaled_reference_run(
    wal_dir: &std::path::Path,
    jobs: &[JobSpec],
) -> (Vec<(u64, WalRecord)>, String) {
    let spec = real_testbed();
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
    let (wal, existing) = Wal::open(wal_dir, FsyncPolicy::EveryN(64)).unwrap();
    assert!(existing.is_empty(), "reference run must start on an empty WAL");
    let wal = Rc::new(RefCell::new(wal));
    engine.set_journal(Box::new(SharedJournal(wal.clone())));
    let mut clock = VirtualClock::new();
    for j in jobs {
        clock.schedule(j.submit_time, ClusterEvent::Arrival(j.clone()));
    }
    let mut guard = 0;
    while let Some((_, ev)) = clock.pop() {
        engine.handle(ev, &mut clock);
        engine.run_round(&mut clock);
        guard += 1;
        assert!(guard < 100_000, "reference run did not terminate");
    }
    assert!(engine.aggregates().n_completed >= 1, "scenario must complete work");
    let canon = canonical(&engine);
    wal.borrow_mut().sync().unwrap();
    drop(engine);
    drop(wal);
    // Reopen: the recovery input is what actually reached the files.
    let (_reopened, records) = Wal::open(wal_dir, FsyncPolicy::EveryN(64)).unwrap();
    (records, canon)
}

/// The acceptance scenario: crash after every single WAL record, recover
/// by pure replay of the prefix, re-arm, re-feed only the *external*
/// events the outside world would re-deliver (arrivals), and run to
/// completion. Every crash point must converge to the identical final
/// state — no transition is lost, none is applied twice.
#[test]
fn kill_at_every_record_recovers_to_the_identical_final_state() {
    let dir = temp_dir("killpoints");
    let jobs = philly::generate(6, 11);
    let (records, want) = journaled_reference_run(&dir, &jobs);
    assert!(records.len() >= 12, "scenario too small to exercise crash points: {}", records.len());

    for k in 0..=records.len() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let recovered = recover(&mut engine, None, records[..k].to_vec()).unwrap();

        let mut clock = VirtualClock::new();
        // A crash can land between an event append and the scheduling
        // round that followed it (the round record was never written).
        // Re-run that round at the recovered engine time — a queued
        // RoundTick pops first and carries the right timestamp. When the
        // prefix *does* end on a round record nothing is due, and the
        // extra tick would re-run the scheduler (diverging work-unit
        // accounting), so it is only armed after an event record.
        if matches!(records[..k].last(), Some((_, WalRecord::Event { .. }))) {
            clock.schedule(recovered.engine_time, ClusterEvent::RoundTick);
        }
        // Predicted outcomes of recovered running jobs.
        for (t, ev) in engine.rearm_events() {
            clock.schedule(t, ev);
        }
        // External events past the crash point are re-delivered by the
        // outside world (clients, the trace); engine-generated outcomes
        // (Finish/Oom/Drained) are re-derived by the engine, never re-fed.
        for (_, rec) in &records[k..] {
            if let WalRecord::Event { time, ev } = rec {
                match ev {
                    ClusterEvent::Arrival(_)
                    | ClusterEvent::NodeJoin(_)
                    | ClusterEvent::NodeLeave(_)
                    | ClusterEvent::Cancel { .. } => {
                        clock.schedule(*time, ev.clone());
                    }
                    _ => {}
                }
            }
        }
        let mut guard = 0;
        while let Some((_, ev)) = clock.pop() {
            engine.handle(ev, &mut clock);
            engine.run_round(&mut clock);
            guard += 1;
            assert!(guard < 100_000, "crash point {k}: continuation did not terminate");
        }
        assert_eq!(canonical(&engine), want, "crash point {k} diverged");
    }
}

/// Snapshot-plus-tail recovery equals the uninterrupted run, and the
/// snapshot makes the covered WAL segments prunable: after pruning, the
/// on-disk WAL starts past seq 1, yet recovery still lands on the exact
/// final state.
#[test]
fn snapshot_plus_pruned_tail_recovers_the_exact_final_state() {
    let root = temp_dir("snaptail");
    let wal_dir = root.join("wal");
    let snap_dir = root.join("snapshots");
    let jobs = philly::generate(8, 23);

    let spec = real_testbed();
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
    let (mut wal, _) = Wal::open(&wal_dir, FsyncPolicy::EveryN(8)).unwrap();
    // Tiny segments force rotation so the snapshot actually frees history.
    wal.segment_bytes = 512;
    let wal = Rc::new(RefCell::new(wal));
    engine.set_journal(Box::new(SharedJournal(wal.clone())));
    let store = SnapshotStore::new(&snap_dir).unwrap();

    let mut clock = VirtualClock::new();
    for j in &jobs {
        clock.schedule(j.submit_time, ClusterEvent::Arrival(j.clone()));
    }
    let mut snap_seq = None;
    let mut n_events = 0;
    let mut guard = 0;
    while let Some((t, ev)) = clock.pop() {
        engine.handle(ev, &mut clock);
        engine.run_round(&mut clock);
        n_events += 1;
        if n_events == 10 && snap_seq.is_none() {
            // Mid-run snapshot at the WAL position reached so far — the
            // coordinator's cadence in miniature: sync, snapshot, prune.
            let seq = wal.borrow().last_seq();
            wal.borrow_mut().sync().unwrap();
            let mut state = Json::obj();
            state.set("time", t).set("engine", engine.snapshot_json());
            store.save(seq, &state).unwrap();
            wal.borrow_mut().prune_through(seq).unwrap();
            snap_seq = Some(seq);
        }
        guard += 1;
        assert!(guard < 100_000);
    }
    let want = canonical(&engine);
    let snap_seq = snap_seq.expect("run long enough to snapshot mid-flight");
    wal.borrow_mut().sync().unwrap();
    drop(engine);
    drop(wal);

    let (_reopened, records) = Wal::open(&wal_dir, FsyncPolicy::EveryN(8)).unwrap();
    assert!(records.first().unwrap().0 > 1, "pruning must have dropped covered segments");
    let loaded = store.load_newest().unwrap().expect("snapshot on disk");
    assert_eq!(loaded.0, snap_seq);

    let mut has2 = Has::new(Marp::with_defaults(spec.clone()));
    let mut engine2 = SchedulingEngine::new(&spec, &mut has2, EngineConfig::default());
    let recovered = recover(&mut engine2, Some(loaded), records).unwrap();
    assert!(recovered.last_seq > snap_seq, "the tail extended past the snapshot");
    assert_eq!(canonical(&engine2), want, "snapshot + pruned tail diverged");
}

fn durable_cfg(dir: &std::path::Path) -> CoordinatorConfig {
    CoordinatorConfig {
        execute_training: false,
        data_dir: Some(dir.to_path_buf()),
        fsync: FsyncPolicy::Always,
        snapshot_every: 4,
        ..CoordinatorConfig::default()
    }
}

fn submit_one(h: &frenzy::serverless::Handle) -> u64 {
    h.submit(SubmitRequest { model: "gpt2-350m".into(), global_batch: 8, total_samples: 100 })
        .unwrap()
}

/// A crash mid-append leaves a torn record at the WAL tail. The restarted
/// coordinator must truncate it and recover every acknowledged job — a
/// torn tail is the *expected* crash artifact, never a fatal one.
#[test]
fn coordinator_survives_a_torn_wal_tail_across_restart() {
    let dir = temp_dir("torntail");
    let (h, j) = spawn(real_testbed(), durable_cfg(&dir));
    let a = submit_one(&h);
    let b = submit_one(&h);
    h.drain().unwrap();
    let d1 = h.durability().unwrap();
    assert!(d1.enabled && d1.last_seq > 0);
    h.shutdown();
    j.join().unwrap();

    // Simulate the kill -9 mid-write: garbage where the next record's
    // header would have gone, in the newest segment.
    let mut segs: Vec<_> = std::fs::read_dir(dir.join("wal"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "log"))
        .collect();
    segs.sort();
    let tail = segs.last().expect("a WAL segment exists");
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new().append(true).open(tail).unwrap();
    f.write_all(&[0xAB, 0xCD, 0xEF]).unwrap();
    drop(f);

    let (h, j) = spawn(real_testbed(), durable_cfg(&dir));
    for id in [a, b] {
        let st = h.status(id).unwrap().expect("job recovered despite torn tail");
        assert_eq!(st.state, JobState::Completed, "job {id}");
    }
    let d2 = h.durability().unwrap();
    assert_eq!(d2.last_seq, d1.last_seq, "the torn bytes were truncated, not replayed");
    h.shutdown();
    j.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Deleting every snapshot forces recovery to fall back to a full WAL
/// replay — snapshots are an optimization, never the source of truth.
#[test]
fn coordinator_recovers_from_wal_alone_when_snapshots_vanish() {
    let dir = temp_dir("nosnaps");
    let (h, j) = spawn(real_testbed(), durable_cfg(&dir));
    let a = submit_one(&h);
    h.drain().unwrap();
    let report1 = h.report().unwrap();
    let d1 = h.durability().unwrap();
    assert!(d1.snapshot_seq.is_some(), "snapshot_every=4 must have produced a snapshot");
    h.shutdown();
    j.join().unwrap();

    for e in std::fs::read_dir(dir.join("snapshots")).unwrap() {
        std::fs::remove_file(e.unwrap().path()).unwrap();
    }

    let (h, j) = spawn(real_testbed(), durable_cfg(&dir));
    let st = h.status(a).unwrap().expect("job recovered from WAL alone");
    assert_eq!(st.state, JobState::Completed);
    assert!(!st.losses.is_empty(), "losses rode the WAL, not the snapshot");
    let report2 = h.report().unwrap();
    assert_eq!(report2.n_completed, report1.n_completed);
    h.shutdown();
    j.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The full network path: `GET /v1/durability` on a durable server
/// reports the live WAL position and snapshot freshness.
#[test]
fn durability_status_over_http() {
    let dir = temp_dir("http");
    let (h, j) = spawn(real_testbed(), durable_cfg(&dir));
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let addr = frenzy::serverless::server::serve(h.clone(), "127.0.0.1:0", stop.clone()).unwrap();
    let mut c = FrenzyClient::new(addr.to_string());
    let id = c.submit("gpt2-350m", 8, 100).unwrap();
    h.drain().unwrap();
    let d = c.durability().unwrap();
    assert!(d.enabled);
    assert!(d.last_seq > 0, "the submit and its completion were journaled");
    assert!(d.wal_bytes > 0);
    assert!(d.wal_segments >= 1);
    if let Some(age) = d.snapshot_age_s {
        assert!(age >= 0.0);
    }
    assert_eq!(c.status(id).unwrap().unwrap().state, JobState::Completed);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.shutdown();
    j.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
