//! Integration: the v1 serverless API end-to-end over TCP — typed SDK,
//! predict dry-run, cancel/list lifecycle, keep-alive connections, the
//! fixed-size worker pool, and the HTTP edge cases (405/413).

use frenzy::config::{model_zoo, real_testbed, sia_sim};
use frenzy::engine::EventKind;
use frenzy::job::JobState;
use frenzy::serverless::api::{EventsRequestV1, ListRequestV1, ScaleRequestV1};
use frenzy::serverless::client::FrenzyClient;
use frenzy::serverless::{server, spawn, CoordinatorConfig, Handle};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn start(
    spec: frenzy::config::ClusterSpec,
    stub_delay_ms: u64,
) -> (Handle, SocketAddr, Arc<AtomicBool>) {
    let cfg = CoordinatorConfig {
        execute_training: false,
        stub_delay_ms,
        ..CoordinatorConfig::default()
    };
    let (h, _j) = spawn(spec, cfg);
    let stop = Arc::new(AtomicBool::new(false));
    let addr = server::serve(h.clone(), "127.0.0.1:0", stop.clone()).unwrap();
    (h, addr, stop)
}

/// Read exactly one framed HTTP response off a kept-alive connection.
fn read_framed(reader: &mut BufReader<TcpStream>) -> (u16, Vec<String>, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        let h = h.trim().to_string();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap();
        }
        headers.push(h);
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).unwrap();
    (status, headers, String::from_utf8_lossy(&body).to_string())
}

#[test]
fn predict_dry_run_covers_every_zoo_model() {
    let (h, addr, stop) = start(real_testbed(), 0);
    let mut client = FrenzyClient::new(addr.to_string());
    let gpu_types_in_cluster = 3; // real testbed: A100-40G, A800-80G, A100-80G
    for model in model_zoo() {
        let resp = client.predict(model.name, 4).unwrap();
        assert_eq!(resp.model, model.name);
        assert_eq!(resp.batch, 4);
        assert!(resp.feasible, "{} should fit the real testbed", model.name);
        let chosen = resp.chosen.as_ref().unwrap_or_else(|| panic!("{} has no chosen plan", model.name));
        assert_eq!(chosen.d * chosen.t, chosen.gpus, "{}", model.name);
        assert_eq!(resp.plans.first(), Some(chosen), "chosen = head of ranked list");
        assert_eq!(resp.per_gpu_type.len(), gpu_types_in_cluster, "{}", model.name);
        // Peak-memory prediction per GPU type: present iff some plan fits it,
        // and never above the type's capacity.
        assert!(
            resp.per_gpu_type.iter().any(|g| g.predicted_peak_bytes.is_some()),
            "{}: no GPU type can host a feasible plan?",
            model.name
        );
        for g in &resp.per_gpu_type {
            if let Some(peak) = g.predicted_peak_bytes {
                assert!(peak <= g.mem_bytes, "{}: {} peak {peak} > mem", model.name, g.gpu);
                assert_eq!(g.best_plan.as_ref().map(|p| p.predicted_bytes), Some(peak));
                assert!(g.feasible_plans > 0);
            } else {
                assert_eq!(g.feasible_plans, 0);
            }
        }
    }
    // Dry runs created no jobs.
    let page = client.list(&ListRequestV1::default()).unwrap();
    assert_eq!(page.total, 0, "predict must not enqueue jobs");
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

#[test]
fn cancel_queued_and_running_over_tcp() {
    // Slow stub so jobs are observably Running; 12 jobs on 11 GPUs
    // guarantees at least one stays Queued.
    let (h, addr, stop) = start(real_testbed(), 1500);
    let mut client = FrenzyClient::new(addr.to_string());
    let mut ids = Vec::new();
    for _ in 0..12 {
        ids.push(client.submit("gpt2-1.3b", 16, 300).unwrap());
    }
    let queued = client
        .list(&ListRequestV1 { state: Some(JobState::Queued), offset: 0, limit: 100 })
        .unwrap();
    assert!(queued.total >= 1, "12 jobs on 11 GPUs must leave one queued");
    let running = client
        .list(&ListRequestV1 { state: Some(JobState::Running), offset: 0, limit: 100 })
        .unwrap();
    assert!(running.total >= 1);

    let queued_id = queued.jobs[0].job_id;
    let resp = client.cancel(queued_id).unwrap();
    assert!(resp.cancelled);
    assert_eq!(resp.state, JobState::Cancelled);

    let running_id = running.jobs[0].job_id;
    let resp = client.cancel(running_id).unwrap();
    assert!(resp.cancelled, "cancel-while-running");
    assert_eq!(resp.state, JobState::Cancelled);

    h.drain().unwrap();
    // The stub's late TrainDone for the cancelled running job must not
    // resurrect it to Completed.
    assert_eq!(client.status(queued_id).unwrap().unwrap().state, JobState::Cancelled);
    assert_eq!(client.status(running_id).unwrap().unwrap().state, JobState::Cancelled);
    let completed = client
        .list(&ListRequestV1 { state: Some(JobState::Completed), offset: 0, limit: 100 })
        .unwrap();
    assert_eq!(completed.total, 10);
    // All resources released despite the mid-flight cancellation.
    let info = client.cluster().unwrap();
    assert_eq!(info.total_gpus, info.idle_gpus);
    // Cancelling a terminal job now conflicts (409) …
    let err = client.cancel(queued_id).unwrap_err().to_string();
    assert!(err.contains("409"), "{err}");
    // … and unknown jobs are 404.
    let err = client.cancel(9999).unwrap_err().to_string();
    assert!(err.contains("404"), "{err}");
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

#[test]
fn list_pagination_edges() {
    let (h, addr, stop) = start(sia_sim(), 0);
    let mut client = FrenzyClient::new(addr.to_string());
    for _ in 0..25 {
        client.submit("gpt2-125m", 4, 50).unwrap();
    }
    h.drain().unwrap();
    let p1 = client.list(&ListRequestV1 { state: None, offset: 0, limit: 10 }).unwrap();
    assert_eq!((p1.total, p1.jobs.len()), (25, 10));
    let p2 = client.list(&ListRequestV1 { state: None, offset: 10, limit: 10 }).unwrap();
    assert_eq!(p2.jobs.len(), 10);
    let p3 = client.list(&ListRequestV1 { state: None, offset: 20, limit: 10 }).unwrap();
    assert_eq!(p3.jobs.len(), 5);
    // Pages are disjoint and ascending overall.
    let all: Vec<u64> = p1
        .jobs
        .iter()
        .chain(p2.jobs.iter())
        .chain(p3.jobs.iter())
        .map(|j| j.job_id)
        .collect();
    let mut sorted = all.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(all.len(), 25);
    assert_eq!(all, sorted);
    // Offset past the end: empty page, correct total.
    let p4 = client.list(&ListRequestV1 { state: None, offset: 100, limit: 10 }).unwrap();
    assert_eq!((p4.total, p4.jobs.len()), (25, 0));
    // State filter with no matches.
    let p5 = client
        .list(&ListRequestV1 { state: Some(JobState::Running), offset: 0, limit: 10 })
        .unwrap();
    assert_eq!((p5.total, p5.jobs.len()), (0, 0));
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

#[test]
fn keep_alive_serves_many_requests_per_connection() {
    let (h, addr, stop) = start(real_testbed(), 0);
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    for i in 0..5 {
        write!(stream, "GET /v1/healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let (status, headers, body) = read_framed(&mut reader);
        assert_eq!(status, 200, "request {i}");
        assert!(body.contains("ok"));
        assert!(
            headers.iter().any(|h| h.to_ascii_lowercase() == "connection: keep-alive"),
            "{headers:?}"
        );
    }
    // The SDK reuses its connection too: several calls, one client.
    let mut client = FrenzyClient::new(addr.to_string());
    assert!(client.health().unwrap());
    let id = client.submit("gpt2-350m", 8, 100).unwrap();
    h.drain().unwrap();
    assert_eq!(client.status(id).unwrap().unwrap().state, JobState::Completed);
    assert!(client.cluster().unwrap().total_gpus > 0);
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

#[test]
fn thread_pool_handles_concurrent_clients() {
    let (h, addr, stop) = start(sia_sim(), 0);
    let mut threads = Vec::new();
    for _ in 0..8 {
        let addr = addr.to_string();
        threads.push(std::thread::spawn(move || {
            let mut client = FrenzyClient::new(addr);
            let mut ids = Vec::new();
            for _ in 0..5 {
                let id = client.submit("gpt2-350m", 8, 64).unwrap();
                assert!(client.status(id).unwrap().is_some());
                ids.push(id);
            }
            ids
        }));
    }
    let mut all: Vec<u64> = threads.into_iter().flat_map(|t| t.join().unwrap()).collect();
    assert_eq!(all.len(), 40);
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len(), 40, "job ids must be unique across concurrent clients");
    h.drain().unwrap();
    let report = h.report().unwrap();
    assert_eq!(report.n_completed, 40);
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

#[test]
fn events_and_report_over_tcp() {
    // The full observability path over the wire: SDK tails the event log
    // incrementally and reads the streaming report.
    let (h, addr, stop) = start(real_testbed(), 0);
    let mut client = FrenzyClient::new(addr.to_string());
    let id = client.submit("gpt2-350m", 8, 200).unwrap();
    h.drain().unwrap();
    // Elastic churn shows up in the log with the preempted job ids.
    client
        .scale(&ScaleRequestV1::Join {
            gpu: "A100-80G".into(),
            count: 2,
            link: frenzy::config::LinkKind::NvLink,
        })
        .unwrap();
    client.scale(&ScaleRequestV1::Leave { node: 5 }).unwrap();

    let page = client.events(&EventsRequestV1::default()).unwrap();
    assert!(!page.dropped);
    let has = |pred: &dyn Fn(&EventKind) -> bool| page.events.iter().any(|e| pred(&e.kind));
    assert!(has(&|k| matches!(k, EventKind::Arrival { job } if *job == id)));
    assert!(has(&|k| matches!(k, EventKind::Placed { job, .. } if *job == id)));
    assert!(has(&|k| matches!(k, EventKind::Finished { job, .. } if *job == id)));
    assert!(has(&|k| matches!(k, EventKind::NodeJoined { node: 5, .. })));
    assert!(has(&|k| matches!(k, EventKind::NodeLeft { node: 5, .. })));
    // Tail from next_since: quiet cluster, no new events.
    let tail = client
        .events(&EventsRequestV1 { since: page.next_since, limit: 100, wait_ms: 0, stream: false })
        .unwrap();
    assert!(tail.events.is_empty());
    assert_eq!(tail.next_since, page.next_since);

    let report = client.report().unwrap();
    assert_eq!(report.n_completed, 1);
    assert_eq!(report.n_jobs, 1);
    let hist_total: u64 =
        report.jct_hist.iter().map(|&(_, c)| c).sum::<u64>() + report.jct_hist_overflow;
    assert_eq!(hist_total, 1, "one completed job lands in exactly one bucket");
    assert!(report.avg_utilization >= 0.0 && report.avg_utilization <= 1.0);
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

#[test]
fn oversized_body_gets_413_not_truncation() {
    let (h, addr, stop) = start(real_testbed(), 0);
    let mut stream = TcpStream::connect(addr).unwrap();
    // Declare a body bigger than the 1 MiB cap; send only a prefix.
    write!(
        stream,
        "POST /v1/jobs HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
        2 * 1024 * 1024
    )
    .unwrap();
    stream.write_all(&[b'x'; 1024]).unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).unwrap();
    assert!(response.starts_with("HTTP/1.1 413"), "{response}");
    assert!(response.contains("Connection: close"), "oversized request must close");
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

#[test]
fn wrong_method_gets_405_with_allow_header() {
    let (h, addr, stop) = start(real_testbed(), 0);
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream, "DELETE /v1/cluster HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).unwrap();
    assert!(response.starts_with("HTTP/1.1 405"), "{response}");
    assert!(response.contains("Allow: GET"), "{response}");
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

#[test]
fn error_bodies_parse_as_json_over_tcp() {
    let (h, addr, stop) = start(real_testbed(), 0);
    let mut client = FrenzyClient::new(addr.to_string());
    // Hostile model name: the old format!-built error body would emit
    // broken JSON here; the SDK's parse would fail loudly.
    let err = client.submit(r#"mo"del\with"quotes"#, 8, 100).unwrap_err().to_string();
    assert!(err.contains("400"), "{err}");
    assert!(err.contains("unknown model"), "{err}");
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}
