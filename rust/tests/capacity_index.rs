//! The capacity index's correctness contract: every answer the index (or
//! an overlay on top of it) gives must equal the naive full scan over the
//! authoritative `ClusterState`, under arbitrary allocate / release /
//! grow / shrink churn — and HAS decisions must be byte-identical whether
//! Algorithm 1 runs against the index or the reference scans.

use frenzy::cluster::{Allocation, ClusterState, ClusterView, Orchestrator};
use frenzy::config::models::model_zoo;
use frenzy::config::{gpu_catalog, synthetic_cluster, ClusterSpec, LinkKind, NodeSpec};
use frenzy::job::JobSpec;
use frenzy::marp::Marp;
use frenzy::sched::{has::Has, PendingJob, PendingQueue, Scheduler};
use frenzy::sim::{SimConfig, Simulator};
use frenzy::util::prop::{Gen, Runner};
use frenzy::workload::philly;

fn arb_cluster(g: &mut Gen) -> ClusterSpec {
    let catalog = gpu_catalog();
    let n_nodes = g.usize_in(1, 12);
    let nodes: Vec<NodeSpec> = (0..n_nodes)
        .map(|_| NodeSpec {
            gpu: g.pick(&catalog).clone(),
            count: g.usize_in(1, 8) as u32,
            link: if g.bool() { LinkKind::NvLink } else { LinkKind::Pcie },
        })
        .collect();
    ClusterSpec { name: "arb".into(), nodes, inter_node_gbps: 12.5 }
}

/// Memory thresholds worth probing: every size present, plus off-by-one
/// values around them and the extremes.
fn probe_mems(state: &ClusterState) -> Vec<u64> {
    let mut mems = vec![1u64];
    for n in &state.nodes {
        mems.push(n.gpu.mem_bytes.saturating_sub(1));
        mems.push(n.gpu.mem_bytes);
        mems.push(n.gpu.mem_bytes + 1);
    }
    mems
}

#[test]
fn prop_index_matches_naive_scans_under_churn() {
    Runner::new("index == naive scans", 0x1DEC5, 60).run(|g| {
        let spec = arb_cluster(g);
        let mut orch = Orchestrator::new(&spec);
        let mut next_job: u64 = 1;
        let mut active: Vec<u64> = Vec::new();
        let catalog = gpu_catalog();
        for _step in 0..g.usize_in(5, 40) {
            match g.usize_in(0, 3) {
                // Allocate a random feasible job.
                0 => {
                    let candidates: Vec<(usize, u32)> = orch
                        .state()
                        .nodes
                        .iter()
                        .filter(|n| n.idle > 0)
                        .map(|n| (n.id, n.idle))
                        .collect();
                    if candidates.is_empty() {
                        continue;
                    }
                    let n_parts = g.usize_in(1, candidates.len().min(3));
                    let start = g.usize_in(0, candidates.len() - 1);
                    let mut parts = Vec::new();
                    for k in 0..n_parts {
                        let (node, idle) = candidates[(start + k) % candidates.len()];
                        parts.push((node, g.usize_in(1, idle as usize) as u32));
                    }
                    parts.sort_unstable();
                    parts.dedup_by_key(|p| p.0);
                    let job = next_job;
                    next_job += 1;
                    orch.allocate(Allocation { job, parts })
                        .map_err(|e| format!("feasible allocate failed: {e}"))?;
                    active.push(job);
                }
                // Release a random active job.
                1 => {
                    if active.is_empty() {
                        continue;
                    }
                    let i = g.usize_in(0, active.len() - 1);
                    let job = active.swap_remove(i);
                    orch.release(job).map_err(|e| format!("release failed: {e}"))?;
                }
                // Elastic grow (sometimes with a never-seen GPU type).
                2 => {
                    let node = NodeSpec {
                        gpu: g.pick(&catalog).clone(),
                        count: g.usize_in(1, 8) as u32,
                        link: LinkKind::Pcie,
                    };
                    orch.grow(&node);
                }
                // Elastic shrink of a random live node.
                _ => {
                    let live: Vec<usize> =
                        orch.state().active_nodes().map(|n| n.id).collect();
                    if live.len() <= 1 {
                        continue; // keep at least one node around
                    }
                    let node = *g.pick(&live);
                    let released =
                        orch.shrink(node).map_err(|e| format!("shrink failed: {e}"))?;
                    for alloc in released {
                        active.retain(|&j| j != alloc.job);
                    }
                }
            }
            if !orch.check_index() {
                return Err("incremental index diverged from rebuilt index".into());
            }
            for mem in probe_mems(orch.state()) {
                let naive = orch.state().idle_gpus_with_mem(mem);
                let indexed = orch.index().idle_with_mem(mem);
                if naive != indexed {
                    return Err(format!(
                        "idle_with_mem({mem}) mismatch: naive {naive} vs index {indexed}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_overlay_queries_match_reference_scans() {
    Runner::new("overlay == reference scans", 0x0EA1, 80).run(|g| {
        let spec = arb_cluster(g);
        let mut state = ClusterState::from_spec(&spec);
        // Random committed occupancy first.
        for i in 0..state.nodes.len() {
            let idle = state.nodes[i].idle;
            if idle > 0 && g.bool() {
                state.nodes[i].idle = g.usize_in(0, idle as usize) as u32;
            }
        }
        let view = ClusterView::build(&state);
        let mut ov = view.overlay();
        // Reference: effective idle under tentative takes.
        let mut eff: Vec<u32> = state.nodes.iter().map(|n| n.idle).collect();
        for _ in 0..g.usize_in(0, 10) {
            let takeable: Vec<usize> =
                (0..eff.len()).filter(|&i| eff[i] > 0).collect();
            if takeable.is_empty() {
                break;
            }
            let node = *g.pick(&takeable);
            let amount = g.usize_in(1, eff[node] as usize) as u32;
            ov.take(node, amount);
            eff[node] -= amount;
        }

        for mem in probe_mems(&state) {
            let want: u32 = state
                .nodes
                .iter()
                .filter(|n| n.gpu.mem_bytes >= mem)
                .map(|n| eff[n.id])
                .sum();
            if ov.idle_with_mem(mem) != want {
                return Err(format!(
                    "overlay idle_with_mem({mem}) = {} want {want}",
                    ov.idle_with_mem(mem)
                ));
            }
            // Reference fit size + candidate list, mirroring Has::allocate_one.
            let fit_sz = state
                .nodes
                .iter()
                .filter(|n| eff[n.id] > 0 && n.gpu.mem_bytes >= mem)
                .map(|n| n.gpu.mem_bytes)
                .min();
            let got_fit = ov.fit_class(mem).map(|c| view.index().class_size(c));
            if got_fit != fit_sz {
                return Err(format!("fit size for {mem}: {got_fit:?} want {fit_sz:?}"));
            }
            let Some(fit_sz) = fit_sz else { continue };
            let fit_c = ov.fit_class(mem).expect("checked");
            let mut nlst: Vec<usize> = state
                .nodes
                .iter()
                .filter(|n| eff[n.id] > 0 && n.gpu.mem_bytes >= fit_sz)
                .map(|n| n.id)
                .collect();
            nlst.sort_by_key(|&id| eff[id]);
            if ov.avail_nodes(fit_c) != nlst.len() as u64 {
                return Err(format!(
                    "avail_nodes = {} want {}",
                    ov.avail_nodes(fit_c),
                    nlst.len()
                ));
            }
            for req in [1u32, 2, 3, 5, 8, 16] {
                let want_bf = nlst
                    .iter()
                    .find(|&&id| eff[id] >= req)
                    .map(|&id| (id, eff[id]));
                if ov.best_fit(fit_c, req) != want_bf {
                    return Err(format!(
                        "best_fit(req={req}) = {:?} want {want_bf:?}",
                        ov.best_fit(fit_c, req)
                    ));
                }
            }
            let want_mi = nlst.last().map(|&id| (id, eff[id]));
            if ov.most_idle(fit_c) != want_mi {
                return Err(format!(
                    "most_idle = {:?} want {want_mi:?}",
                    ov.most_idle(fit_c)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_has_indexed_equals_naive_rounds() {
    Runner::new("HAS indexed == naive", 0x11A5, 60).run(|g| {
        let cluster = arb_cluster(g);
        let zoo = model_zoo();
        let n_jobs = g.usize_in(1, 12);
        let jobs: Vec<PendingJob> = (0..n_jobs)
            .map(|i| PendingJob {
                spec: JobSpec::new(
                    i as u64,
                    g.pick(&zoo).clone(),
                    (1 << g.usize_in(0, 5)) as u32,
                    1000,
                    0.0,
                ),
                attempts: 0,
            })
            .collect();
        let snap = ClusterState::from_spec(&cluster);
        let view = ClusterView::build(&snap);
        let mut hi = Has::new(Marp::with_defaults(cluster.clone()));
        let mut hn = Has::new(Marp::with_defaults(cluster.clone()));
        hn.indexed = false;
        let ri = hi.schedule(&PendingQueue::from(jobs.clone()), &view, 0.0);
        let rn = hn.schedule(&PendingQueue::from(jobs), &view, 0.0);
        if ri.work_units != rn.work_units {
            return Err(format!(
                "work units diverged: indexed {} naive {}",
                ri.work_units, rn.work_units
            ));
        }
        if ri.decisions.len() != rn.decisions.len() {
            return Err(format!(
                "decision counts diverged: indexed {} naive {}",
                ri.decisions.len(),
                rn.decisions.len()
            ));
        }
        for (a, b) in ri.decisions.iter().zip(&rn.decisions) {
            if a.job != b.job
                || a.alloc.parts != b.alloc.parts
                || a.par != b.par
                || a.will_oom != b.will_oom
            {
                return Err(format!(
                    "decision diverged for job {}: {:?} vs {:?}",
                    a.job, a.alloc.parts, b.alloc.parts
                ));
            }
        }
        Ok(())
    });
}

/// The regression the tentpole must not break: running the Philly trace
/// prefix through the full simulator, the indexed engine produces a
/// byte-identical placement log (and identical modeled overhead) to the
/// pre-index reference implementation.
#[test]
fn philly_trace_decisions_identical_pre_post_index() {
    let spec = synthetic_cluster(9);
    let trace = philly::generate(120, 42);
    let run = |indexed: bool| {
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        has.indexed = indexed;
        let mut sim = Simulator::new(&spec, &mut has, SimConfig::default());
        sim.submit_all(&trace);
        let report = sim.run("philly-prefix");
        let log = sim.engine().decision_log().to_vec();
        (log, report)
    };
    let (log_idx, rep_idx) = run(true);
    let (log_naive, rep_naive) = run(false);
    assert!(!log_idx.is_empty(), "trace must produce placements");
    assert_eq!(log_idx, log_naive, "placement logs must be byte-identical");
    assert_eq!(rep_idx.sched_work_units, rep_naive.sched_work_units);
    assert_eq!(rep_idx.n_completed, rep_naive.n_completed);
    assert_eq!(rep_idx.n_rejected, rep_naive.n_rejected);
    assert_eq!(rep_idx.avg_jct_s, rep_naive.avg_jct_s);
    assert_eq!(rep_idx.makespan_s, rep_naive.makespan_s);
}

/// Same regression on the paper's sim topology with the engine's
/// elasticity events in the mix: index answers must stay correct through
/// mid-trace NodeJoin/NodeLeave.
#[test]
fn elastic_trace_decisions_identical_pre_post_index() {
    use frenzy::engine::ClusterEvent;
    let spec = synthetic_cluster(6);
    let trace = philly::generate(60, 7);
    let join = NodeSpec {
        gpu: frenzy::config::gpu_by_name("A100-80G").unwrap(),
        count: 4,
        link: LinkKind::NvLink,
    };
    let run = |indexed: bool| {
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        has.indexed = indexed;
        let mut sim = Simulator::new(&spec, &mut has, SimConfig::default());
        sim.submit_all(&trace);
        sim.schedule_event(500.0, ClusterEvent::NodeLeave(1));
        sim.schedule_event(2000.0, ClusterEvent::NodeJoin(join.clone()));
        let report = sim.run("philly-elastic");
        let log = sim.engine().decision_log().to_vec();
        assert!(sim.conservation_ok());
        (log, report)
    };
    let (log_idx, rep_idx) = run(true);
    let (log_naive, rep_naive) = run(false);
    assert_eq!(log_idx, log_naive);
    assert_eq!(rep_idx.sched_work_units, rep_naive.sched_work_units);
    assert_eq!(rep_idx.n_completed, rep_naive.n_completed);
}
