//! Integration coverage for the observability subsystem: the bounded
//! cluster event log (ring eviction, `?since=` paging semantics) and the
//! streaming `RunReport` aggregates, including the property that a trace
//! replayed through the simulator and through the live coordinator folds
//! to identical aggregate counters.

use frenzy::config::real_testbed;
use frenzy::engine::clock::VirtualClock;
use frenzy::engine::{ClusterEvent, EngineConfig, EventKind, SchedulingEngine};
use frenzy::job::{JobSpec, JobState};
use frenzy::marp::Marp;
use frenzy::sched::has::Has;
use frenzy::serverless::{spawn, CoordinatorConfig, SubmitRequest};
use frenzy::sim::{SimConfig, Simulator};
use frenzy::util::prop::Runner;

fn job(id: u64, model: &str, batch: u32, samples: u64, t: f64) -> JobSpec {
    JobSpec::new(
        id,
        frenzy::config::models::model_by_name(model).unwrap(),
        batch,
        samples,
        t,
    )
}

/// Drive an engine + virtual clock to completion.
fn drive(engine: &mut SchedulingEngine, clock: &mut VirtualClock) {
    let mut guard = 0;
    while let Some((_, ev)) = clock.pop() {
        engine.handle(ev, clock);
        engine.run_round(clock);
        guard += 1;
        assert!(guard < 100_000, "event loop did not terminate");
    }
}

#[test]
fn ring_eviction_keeps_monotonic_seqs_and_since_semantics() {
    // A tiny ring under a real engine run: many more events than capacity.
    let spec = real_testbed();
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let cfg = EngineConfig { event_log_cap: 8, ..EngineConfig::default() };
    let mut engine = SchedulingEngine::new(&spec, &mut has, cfg);
    let mut clock = VirtualClock::new();
    let n_jobs = 12u64;
    for i in 0..n_jobs {
        clock.schedule(
            i as f64 * 10_000.0,
            ClusterEvent::Arrival(job(i, "gpt2-350m", 8, 1_000, i as f64 * 10_000.0)),
        );
    }
    drive(&mut engine, &mut clock);
    assert_eq!(engine.aggregates().n_completed, n_jobs as usize);

    let log = engine.event_log();
    // 3 events per job (arrival, placed, finished) >> cap of 8.
    assert_eq!(log.len(), 8, "ring bounded at capacity");
    assert_eq!(log.last_seq(), 3 * n_jobs, "every event got a seq, evicted or not");
    let seqs: Vec<u64> = log.iter().map(|r| r.seq).collect();
    assert!(
        seqs.windows(2).all(|w| w[1] == w[0] + 1),
        "retained seqs stay dense and monotonic after eviction: {seqs:?}"
    );
    assert_eq!(*seqs.first().unwrap(), log.first_seq());

    // since=0 (from the beginning) must flag the gap and return the tail.
    let page = log.since(0, 100);
    assert!(page.dropped, "records before the ring were evicted unseen");
    assert_eq!(page.events.len(), 8);
    assert_eq!(page.events.first().unwrap().seq, log.first_seq());

    // A client that kept up sees no gap.
    let page = log.since(log.first_seq() - 1, 100);
    assert!(!page.dropped);
    assert_eq!(page.events.len(), 8);
    let page = log.since(log.last_seq(), 100);
    assert!(!page.dropped);
    assert!(page.events.is_empty());

    // Paging with a limit walks the ring without skipping or repeating.
    let mut since = 0;
    let mut walked = Vec::new();
    loop {
        let page = log.since(since, 3);
        if page.events.is_empty() {
            break;
        }
        walked.extend(page.events.iter().map(|r| r.seq));
        since = page.events.last().unwrap().seq;
    }
    assert_eq!(walked, seqs, "limit-paged walk reconstructs the retained window");

    // Times never decrease along the log.
    let times: Vec<f64> = log.iter().map(|r| r.time).collect();
    assert!(times.windows(2).all(|w| w[1] >= w[0]), "event times are monotone: {times:?}");
}

#[test]
fn prop_sim_and_live_replay_fold_to_identical_aggregates() {
    // The acceptance property for the streaming report: a serialized trace
    // (each job runs on an otherwise-empty cluster) replayed through the
    // simulator and through the live coordinator must produce the same
    // placements and the same aggregate counters — only clock-dependent
    // values (JCT seconds) may differ.
    let models = ["gpt2-125m", "gpt2-350m", "gpt2-760m", "gpt2-1.3b"];
    let batches = [4u32, 8, 16];
    Runner::new("sim/live aggregate parity", 0x0B5E6E, 12).run(|g| {
        let n = g.usize_in(1, 5);
        let trace: Vec<JobSpec> = (0..n)
            .map(|i| {
                job(
                    i as u64,
                    g.pick(&models),
                    *g.pick(&batches),
                    g.u64_in(50, 20_000),
                    i as f64 * 1e9,
                )
            })
            .collect();

        // Simulator path.
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let cfg = SimConfig { max_sim_time_s: 1e18, ..SimConfig::default() };
        let mut sim = Simulator::new(&spec, &mut has, cfg);
        sim.submit_all(&trace);
        let sim_report = sim.run("prop");
        let sim_decisions = sim.engine().decision_log().to_vec();

        // Live path (instant stub serializes: each job completes before
        // the next submit is processed).
        let (h, _j) = spawn(
            spec,
            CoordinatorConfig { execute_training: false, ..CoordinatorConfig::default() },
        );
        for j in &trace {
            h.submit(SubmitRequest {
                model: j.model.name.to_string(),
                global_batch: j.train.global_batch,
                total_samples: j.total_samples,
            })
            .map_err(|e| format!("submit: {e}"))?;
        }
        h.drain().map_err(|e| format!("drain: {e}"))?;
        let live_report = h.report().map_err(|e| format!("report: {e}"))?;
        let live_decisions = h.decisions().map_err(|e| format!("decisions: {e}"))?;
        h.shutdown();

        // Identical placements (live ids are 1-based).
        if sim_decisions.len() != live_decisions.len() {
            return Err(format!(
                "decision count: sim {} vs live {}",
                sim_decisions.len(),
                live_decisions.len()
            ));
        }
        for (s, l) in sim_decisions.iter().zip(live_decisions.iter()) {
            if s.0 + 1 != l.0 || s.1 != l.1 {
                return Err(format!("decision mismatch: sim {s:?} vs live {l:?}"));
            }
        }
        // Identical aggregate counters.
        let pairs = [
            ("n_jobs", sim_report.n_jobs, live_report.n_jobs),
            ("n_completed", sim_report.n_completed, live_report.n_completed),
            ("n_rejected", sim_report.n_rejected, live_report.n_rejected),
            ("n_cancelled", sim_report.n_cancelled, live_report.n_cancelled),
            (
                "oom_retries",
                sim_report.total_oom_retries as usize,
                live_report.total_oom_retries as usize,
            ),
            (
                "oom_events",
                sim_report.n_oom_events as usize,
                live_report.n_oom_events as usize,
            ),
        ];
        for (name, s, l) in pairs {
            if s != l {
                return Err(format!("{name}: sim {s} vs live {l}"));
            }
        }
        // The histograms account for every completed job on both sides.
        let total = |hist: &[(f64, u64)], overflow: u64| {
            hist.iter().map(|&(_, c)| c).sum::<u64>() + overflow
        };
        if total(&sim_report.jct_hist, sim_report.jct_hist_overflow)
            != sim_report.n_completed as u64
        {
            return Err("sim histogram does not cover all completions".into());
        }
        if total(&live_report.jct_hist, live_report.jct_hist_overflow)
            != live_report.n_completed as u64
        {
            return Err("live histogram does not cover all completions".into());
        }
        Ok(())
    });
}

#[test]
fn live_event_log_matches_terminal_states() {
    // Every terminal state the status table reports must have a matching
    // record in the event log (completed -> Finished, etc.).
    let (h, _j) = spawn(
        real_testbed(),
        CoordinatorConfig { execute_training: false, ..CoordinatorConfig::default() },
    );
    let ids: Vec<u64> = (0..6)
        .map(|_| {
            h.submit(SubmitRequest {
                model: "gpt2-350m".into(),
                global_batch: 8,
                total_samples: 200,
            })
            .unwrap()
        })
        .collect();
    h.drain().unwrap();
    let page = h.events(0, 1000).unwrap();
    for id in ids {
        let st = h.status(id).unwrap().unwrap().state;
        assert_eq!(st, JobState::Completed);
        assert!(
            page.events
                .iter()
                .any(|r| matches!(r.kind, EventKind::Finished { job, .. } if job == id)),
            "job {id} completed but has no Finished event"
        );
    }
    h.shutdown();
}
