//! Integration: the high-throughput ingest path end-to-end — a concurrent
//! submit storm against a watermarked server yields only 202/429 with
//! bounded queue depth, watermark 429s carry `Retry-After` on the wire,
//! batch and single submits journal byte-for-byte the same WAL transitions
//! (replay identity), and the SSE feed pushes events as they happen.

use frenzy::config::real_testbed;
use frenzy::durability::{FsyncPolicy, Wal, WalRecord};
use frenzy::engine::{ClusterEvent, EventKind};
use frenzy::job::JobState;
use frenzy::serverless::api::{EventsRequestV1, SubmitRequestV1, SubmitResultV1};
use frenzy::serverless::client::{FrenzyClient, SubmitOutcome};
use frenzy::serverless::{server, spawn, CoordinatorConfig, Handle};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

fn start(cfg: CoordinatorConfig) -> (Handle, SocketAddr, Arc<AtomicBool>) {
    let (h, _j) = spawn(real_testbed(), cfg);
    let stop = Arc::new(AtomicBool::new(false));
    let addr = server::serve(h.clone(), "127.0.0.1:0", stop.clone()).unwrap();
    (h, addr, stop)
}

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("frenzy_ingest_{tag}_{}", std::process::id()))
}

/// Storm a watermarked server from many threads. Every submit must answer
/// 202 or 429 — [`FrenzyClient::submit_once`] turns anything else into an
/// error, which the test unwraps loudly. A sampler thread watches queue
/// depth the whole time: admission runs on the coordinator thread, so the
/// watermark is a hard bound even under concurrency. Afterwards every
/// accepted job must reach a terminal state.
#[test]
fn storm_yields_only_202_or_429_with_bounded_depth() {
    let max_pending = 4usize;
    let cfg = CoordinatorConfig {
        execute_training: false,
        stub_delay_ms: 20,
        max_pending,
        ..CoordinatorConfig::default()
    };
    let (h, addr, stop) = start(cfg);
    let done = Arc::new(AtomicBool::new(false));
    let peak = Arc::new(AtomicUsize::new(0));
    let sampler = {
        let (addr, done, peak) = (addr.to_string(), done.clone(), peak.clone());
        std::thread::spawn(move || {
            let mut c = FrenzyClient::new(addr);
            while !done.load(Ordering::Relaxed) {
                let queued = c
                    .list(&frenzy::serverless::api::ListRequestV1 {
                        state: Some(JobState::Queued),
                        offset: 0,
                        limit: 1,
                    })
                    .unwrap()
                    .total;
                peak.fetch_max(queued, Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        })
    };
    let workers: Vec<_> = (0..6)
        .map(|_| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut c = FrenzyClient::new(addr);
                let req = SubmitRequestV1::new("gpt2-350m", 8, 50);
                let mut ids = Vec::new();
                let mut throttled = 0u64;
                for _ in 0..30 {
                    match c.submit_once(&req).unwrap() {
                        SubmitOutcome::Accepted { job_id } => ids.push(job_id),
                        SubmitOutcome::Throttled { retry_after_ms } => {
                            assert!(retry_after_ms > 0, "throttle must carry a retry hint");
                            throttled += 1;
                        }
                    }
                }
                (ids, throttled)
            })
        })
        .collect();
    let mut accepted = Vec::new();
    let mut throttled = 0u64;
    for w in workers {
        let (ids, thr) = w.join().unwrap();
        accepted.extend(ids);
        throttled += thr;
    }
    done.store(true, Ordering::Relaxed);
    sampler.join().unwrap();
    assert!(
        peak.load(Ordering::Relaxed) <= max_pending,
        "queue depth exceeded the watermark: {} > {max_pending}",
        peak.load(Ordering::Relaxed)
    );
    assert!(!accepted.is_empty(), "storm must land some submits");
    h.drain().unwrap();
    let mut c = FrenzyClient::new(addr.to_string());
    for id in &accepted {
        let st = c.status(*id).unwrap().unwrap_or_else(|| panic!("job {id} vanished"));
        assert!(
            matches!(st.state, JobState::Completed | JobState::Rejected),
            "accepted job {id} must end terminal, is {:?}",
            st.state
        );
    }
    // 180 submits against an 11-GPU cluster with a 4-deep watermark: the
    // storm must actually have exercised the backpressure path.
    assert!(throttled > 0, "storm never hit the watermark — not a storm");
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

/// The watermark 429 carries `Retry-After` on the wire (header, seconds)
/// and `retry_after_ms` in the body.
#[test]
fn watermark_429_carries_retry_after_on_the_wire() {
    let cfg = CoordinatorConfig {
        execute_training: false,
        stub_delay_ms: 60_000, // nothing completes: the queue only grows
        max_pending: 1,
        ..CoordinatorConfig::default()
    };
    let (h, addr, stop) = start(cfg);
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let body = r#"{"model":"gpt2-350m","batch":8,"samples":400}"#;
    let mut saw_429 = false;
    for _ in 0..100 {
        write!(
            stream,
            "POST /v1/jobs HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let (status, headers, resp_body) = read_framed(&mut reader);
        if status == 202 {
            continue;
        }
        assert_eq!(status, 429, "submit path answers only 202 or 429");
        let lower: Vec<String> = headers.iter().map(|h| h.to_ascii_lowercase()).collect();
        let retry = lower
            .iter()
            .find_map(|h| h.strip_prefix("retry-after:"))
            .expect("429 must carry Retry-After")
            .trim();
        assert!(retry.parse::<u64>().unwrap() >= 1, "whole seconds, rounded up: {retry}");
        assert!(resp_body.contains("retry_after_ms"), "{resp_body}");
        saw_429 = true;
        break;
    }
    assert!(saw_429, "the queue never hit a watermark of 1 — backpressure is broken");
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

/// Read exactly one framed HTTP response off a kept-alive connection.
fn read_framed(reader: &mut BufReader<TcpStream>) -> (u16, Vec<String>, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        let h = h.trim().to_string();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap();
        }
        headers.push(h);
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).unwrap();
    (status, headers, String::from_utf8_lossy(&body).to_string())
}

/// Canonical form of a WAL record with wall-clock times erased — the
/// transitions a replay applies, independent of when they were journaled.
fn canon(rec: &WalRecord) -> String {
    match rec {
        WalRecord::Event { ev: ClusterEvent::Arrival(j), .. } => {
            format!("arrival({},{},{})", j.model.name, j.train.global_batch, j.total_samples)
        }
        WalRecord::Event { ev, .. } => format!("event({ev:?})"),
        WalRecord::Round { .. } => "round".to_string(),
        WalRecord::AdmissionReject { job, model, batch, samples, .. } => {
            format!("reject({job},{model},{batch},{samples})")
        }
        WalRecord::Losses { job, .. } => format!("losses({job})"),
    }
}

/// Differential: the same jobs submitted one-by-one and as one
/// `jobs:batch` body mint the same ids and journal the same WAL
/// transitions in the same order — batching changes fsync grouping, never
/// durable state (replay identity).
#[test]
fn batch_and_single_submits_journal_identical_transitions() {
    let jobs: Vec<SubmitRequestV1> = ["gpt2-125m", "gpt2-350m", "bert-base", "gpt2-760m"]
        .iter()
        .cycle()
        .take(12)
        .enumerate()
        .map(|(i, m)| SubmitRequestV1::new(*m, 8, 100 + i as u64))
        .collect();
    let run = |tag: &str, submit: &dyn Fn(&mut FrenzyClient) -> Vec<u64>| {
        let dir = tmp(tag);
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = CoordinatorConfig {
            execute_training: false,
            stub_delay_ms: 60_000, // no completions: WAL holds ingest only
            data_dir: Some(dir.clone()),
            fsync: FsyncPolicy::Always,
            ..CoordinatorConfig::default()
        };
        let (h, addr, stop) = start(cfg);
        let mut c = FrenzyClient::new(addr.to_string());
        let ids = submit(&mut c);
        stop.store(true, Ordering::Relaxed);
        h.shutdown();
        let (_, records) = Wal::open(&dir.join("wal"), FsyncPolicy::Always).unwrap();
        let transitions: Vec<String> = records.iter().map(|(_, r)| canon(r)).collect();
        let _ = std::fs::remove_dir_all(&dir);
        (ids, transitions)
    };
    let singles = jobs.clone();
    let (ids_single, wal_single) = run("single", &move |c| {
        singles
            .iter()
            .map(|j| match c.submit_once(j).unwrap() {
                SubmitOutcome::Accepted { job_id } => job_id,
                SubmitOutcome::Throttled { .. } => panic!("unthrottled server throttled"),
            })
            .collect()
    });
    let batched = jobs.clone();
    let (ids_batch, wal_batch) = run("batch", &move |c| {
        c.submit_batch(&batched)
            .unwrap()
            .results
            .iter()
            .map(|r| match r {
                SubmitResultV1::Accepted { job_id } => *job_id,
                SubmitResultV1::Rejected(e) => panic!("rejected: {}: {}", e.code, e.message),
            })
            .collect()
    });
    assert_eq!(ids_single, ids_batch, "same ids, same order");
    assert_eq!(ids_single.len(), jobs.len());
    assert!(
        wal_single.iter().filter(|t| t.starts_with("arrival(")).count() == jobs.len(),
        "every submit journaled an arrival: {wal_single:?}"
    );
    assert_eq!(wal_single, wal_batch, "batch must journal exactly the single-path transitions");
}

/// The SSE feed delivers events pushed by the server as they happen: a
/// subscriber sees arrival → placed → finished for a job submitted after
/// it connected, with ascending sequence numbers.
#[test]
fn sse_stream_pushes_events_as_they_happen() {
    let cfg = CoordinatorConfig {
        execute_training: false,
        stub_delay_ms: 10,
        ..CoordinatorConfig::default()
    };
    let (h, addr, stop) = start(cfg);
    let subscriber = {
        let addr = addr.to_string();
        std::thread::spawn(move || {
            let mut c = FrenzyClient::new(addr);
            let mut seqs = Vec::new();
            let mut kinds = Vec::new();
            let last = c
                .events_stream(&EventsRequestV1::default(), |e| {
                    seqs.push(e.seq);
                    kinds.push(e.kind.clone());
                    kinds.len() < 3
                })
                .unwrap();
            (seqs, kinds, last)
        })
    };
    // Give the subscriber time to attach before the events exist.
    std::thread::sleep(std::time::Duration::from_millis(150));
    let mut c = FrenzyClient::new(addr.to_string());
    let id = c.submit("gpt2-350m", 8, 50).unwrap();
    h.drain().unwrap();
    let (seqs, kinds, last) = subscriber.join().unwrap();
    assert_eq!(kinds.len(), 3);
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "ascending seqs: {seqs:?}");
    assert_eq!(last, *seqs.last().unwrap());
    assert!(
        matches!(&kinds[0], EventKind::Arrival { job } if *job == id),
        "first pushed event is the arrival: {kinds:?}"
    );
    assert!(
        kinds.iter().any(|k| matches!(k, EventKind::Finished { job, .. } if *job == id)),
        "completion must be pushed live: {kinds:?}"
    );
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}
