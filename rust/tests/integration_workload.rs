//! Integration: the open-world workload generator and the multi-tenant
//! fairness layer, end to end. Same synth spec ⇒ byte-identical trace AND
//! byte-identical simulator report; stream statistics (arrival rate,
//! duration tail) hold through the CLI grammar entry point; weighted
//! max-min ordering provably beats FCFS under a 10:1 tenant skew; a
//! generated stream driven through the simulator and the live coordinator
//! yields identical placements and per-tenant completions; a seeded synth
//! stream under a seeded fault plan terminates every job with conservation
//! intact; and one tenant blowing its submit quota (429s) leaves every
//! other tenant's submissions untouched.

use frenzy::config::models::model_by_name;
use frenzy::config::{gpu_by_name, real_testbed, sia_sim, ClusterSpec, LinkKind, NodeSpec};
use frenzy::faults::FaultPlan;
use frenzy::job::{JobSpec, JobState};
use frenzy::marp::Marp;
use frenzy::metrics::RunReport;
use frenzy::sched::has::Has;
use frenzy::serverless::admission::QuotaCfg;
use frenzy::serverless::{spawn, CoordinatorConfig, SubmitError, SubmitRequest};
use frenzy::sim::{SimConfig, Simulator};
use frenzy::workload::generator::{self, SynthSpec};
use frenzy::workload::trace;

/// Run a trace through the simulator with Has and optional tenant weights.
/// Returns the placement order (job ids, in decision order) and the report.
fn simulate_trace(
    spec: &ClusterSpec,
    jobs: &[JobSpec],
    weights: Vec<(String, f64)>,
    name: &str,
) -> (Vec<u64>, RunReport) {
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let cfg = SimConfig { max_sim_time_s: 1e18, tenant_weights: weights, ..SimConfig::default() };
    let mut sim = Simulator::new(spec, &mut has, cfg);
    sim.submit_all(jobs);
    let report = sim.run(name);
    let order: Vec<u64> = sim.engine().decision_log().iter().map(|d| d.0).collect();
    assert!(sim.conservation_ok(), "{name}: conservation");
    (order, report)
}

/// The replay-determinism acceptance test: one spec string, two full runs
/// (fresh PRNG, fresh scheduler, fresh simulator each time), byte-identical
/// trace CSV and byte-identical report JSON.
#[test]
fn same_synth_spec_yields_byte_identical_trace_and_report() {
    let spec_str = "seed=42,jobs=40,arrivals=poisson:0.5,tenants=8,mix=zoo";
    let a = generator::from_spec(spec_str, 40, 11).unwrap();
    let b = generator::from_spec(spec_str, 40, 11).unwrap();
    assert_eq!(a, b, "same spec must regenerate the same stream");
    assert_eq!(trace::to_csv(&a), trace::to_csv(&b), "byte-identical CSV");

    let report_json = |jobs: &[JobSpec]| {
        let spec = sia_sim();
        let (_, r) = simulate_trace(&spec, jobs, Vec::new(), "synth-determinism");
        // Wall-clock fields (scheduler overhead, measured with Instant)
        // live in the report's "nondeterministic" section; the
        // deterministic projection drops it rather than hand-zeroing.
        r.to_json_deterministic().to_string_compact()
    };
    let ra = report_json(&a);
    assert_eq!(ra, report_json(&b), "byte-identical reports from the same spec");
    assert!(ra.contains("\"tenants\""), "an 8-tenant stream reports a fairness breakdown");

    // A different seed in the same grammar diverges immediately.
    let c = generator::from_spec("seed=43,jobs=40,arrivals=poisson:0.5,tenants=8,mix=zoo", 40, 11)
        .unwrap();
    assert_ne!(a, c);
}

/// Stream statistics hold through the grammar entry point: a Poisson rate
/// lands within ±10 % of nominal over 4000 arrivals, and a Pareto duration
/// spec produces the heavy tail it promises (tolerances documented in
/// EXPERIMENTS.md).
#[test]
fn generated_stream_statistics_within_tolerance() {
    let jobs = generator::from_spec("seed=11,jobs=4000,arrivals=poisson:0.5,mix=small", 0, 0)
        .unwrap();
    let mean = jobs.last().unwrap().submit_time / jobs.len() as f64;
    assert!((1.8..2.2).contains(&mean), "Poisson(0.5) mean inter-arrival {mean} ∉ 2 s ± 10 %");

    let jobs =
        generator::from_spec("seed=17,jobs=1000,dur=pareto:600x1.2,mix=gpt2-350m", 0, 0).unwrap();
    let mut samples: Vec<u64> = jobs.iter().map(|j| j.total_samples).collect();
    samples.sort_unstable();
    let p50 = samples[samples.len() / 2] as f64;
    let p99 = samples[(samples.len() as f64 * 0.99) as usize] as f64;
    assert!(p99 > 5.0 * p50, "Pareto(α=1.2) tail too light: p50={p50} p99={p99}");
}

/// Four single-GPU nodes: a small job occupies exactly one node (small
/// jobs never span nodes), so the cluster runs exactly four jobs at a
/// time and the decision log exposes the queue order directly.
fn four_single_gpu_nodes() -> ClusterSpec {
    let a100_40 = gpu_by_name("A100-40G").unwrap();
    ClusterSpec {
        name: "fair-4x1".into(),
        nodes: (0..4)
            .map(|_| NodeSpec { gpu: a100_40.clone(), count: 1, link: LinkKind::Pcie })
            .collect(),
        inter_node_gbps: 12.5,
    }
}

/// The fairness acceptance test. Tenant "heavy" floods 8 jobs, tenant
/// "light" queues 4, all in the same instant — a 2:1 backlog skew on a
/// 4-slot cluster (and 8:0 at the head of the FCFS queue, since every
/// heavy job arrived first). FCFS provably starves light: not one of its
/// jobs makes the first two waves. The weighted max-min layer alternates
/// tenants instead, and an explicit weight tilts the first wave toward
/// the weighted tenant.
#[test]
fn weighted_fair_ordering_beats_fcfs_under_skew() {
    let model = model_by_name("gpt2-350m").unwrap();
    let mk = |id: u64, tenant: &str| {
        JobSpec::new(id, model.clone(), 8, 3_000, 0.0).with_tenant(tenant)
    };
    let heavy: Vec<JobSpec> = (0..8).map(|i| mk(i, "heavy")).collect();
    let light: Vec<JobSpec> = (8..12).map(|i| mk(i, "light")).collect();
    let jobs: Vec<JobSpec> = heavy.iter().chain(light.iter()).cloned().collect();
    let spec = four_single_gpu_nodes();
    let is_light = |id: &u64| (8..12).contains(id);

    // FCFS baseline: the identical queue, stripped of tenancy, keeps
    // strict submission order — light's first placement is dead last in
    // wave 3 (positions 8..11).
    let anon: Vec<JobSpec> =
        jobs.iter().map(|j| JobSpec { tenant: String::new(), ..j.clone() }).collect();
    let (fcfs_order, fcfs_report) = simulate_trace(&spec, &anon, Vec::new(), "fcfs");
    let fcfs_first_light = fcfs_order.iter().position(is_light).unwrap();
    assert!(fcfs_first_light >= 8, "FCFS starves light until wave 3: {fcfs_order:?}");
    assert!(fcfs_report.tenants.is_empty(), "a tenantless run reports no breakdown");

    // Equal weights: the deficit ordering alternates heavy/light, so the
    // first 4-slot wave carries two light jobs despite the 8-job head
    // start — the weighted max-min invariant (no tenant exceeds its
    // share while another is backlogged) visible in the decision log.
    let (fair_order, fair_report) = simulate_trace(&spec, &jobs, Vec::new(), "fair");
    let first_wave_light = fair_order[..4].iter().filter(|id| is_light(id)).count();
    assert_eq!(first_wave_light, 2, "equal weights alternate tenants: {fair_order:?}");
    assert_eq!(fair_order.iter().position(is_light), Some(1), "light's head job runs second");

    // The per-tenant report quantifies the same thing: light clears its
    // backlog in the early waves, so its mean queue delay is strictly
    // below heavy's, and the share accounting is a proper partition.
    let row = |r: &RunReport, t: &str| {
        r.tenants.iter().find(|x| x.tenant == t).unwrap_or_else(|| panic!("no row for {t}")).clone()
    };
    let (h, l) = (row(&fair_report, "heavy"), row(&fair_report, "light"));
    assert_eq!(h.n_completed + l.n_completed, fair_report.n_completed as u64);
    assert!(l.avg_queue_s < h.avg_queue_s, "light queues less: {l:?} vs {h:?}");
    assert!((h.gpu_share + l.gpu_share - 1.0).abs() < 1e-6, "shares partition GPU-seconds");
    assert!(h.gpu_share > l.gpu_share, "heavy's 8 jobs still consume the larger share");

    // A 5× weight on light entitles it to the majority of the first
    // wave: three of four slots, with heavy's FCFS head taking the
    // tie-broken first pick.
    let (tilt_order, _) =
        simulate_trace(&spec, &jobs, vec![("light".to_string(), 5.0)], "fair-weighted");
    let tilt_first_wave = tilt_order[..4].iter().filter(|id| is_light(id)).count();
    assert_eq!(tilt_first_wave, 3, "5× weight claims 3 of 4 first-wave slots: {tilt_order:?}");
}

/// Differential: a generated (tenant-attributed) stream, serialized so
/// both clocks present identical snapshots, must produce identical
/// placements, identical terminal counts, and identical per-tenant
/// completion rows in the simulator and the live coordinator.
#[test]
fn generated_stream_sim_vs_live_differential() {
    let raw = generator::from_spec("seed=42,jobs=10,arrivals=poisson:0.5,tenants=3,mix=small", 0, 0)
        .unwrap();
    // Re-time: each job runs on an otherwise-empty cluster (arrivals far
    // apart in virtual time; sequential drained submits in wall time),
    // keeping the generated model/batch/samples/tenant attribution.
    let spec = sia_sim();
    let jobs: Vec<JobSpec> = raw
        .iter()
        .enumerate()
        .map(|(i, j)| {
            JobSpec::new(
                i as u64,
                j.model.clone(),
                j.train.global_batch,
                j.total_samples.min(20_000),
                i as f64 * 1e9,
            )
            .with_tenant(&j.tenant)
        })
        .collect();

    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let cfg = SimConfig { max_sim_time_s: 1e18, ..SimConfig::default() };
    let mut sim = Simulator::new(&spec, &mut has, cfg);
    sim.submit_all(&jobs);
    let sim_report = sim.run("synth-diff");
    let sim_decisions = sim.engine().decision_log().to_vec();

    let (h, _j) = spawn(
        spec,
        CoordinatorConfig { execute_training: false, ..CoordinatorConfig::default() },
    );
    for j in &jobs {
        h.try_submit_as(
            SubmitRequest {
                model: j.model.name.to_string(),
                global_batch: j.train.global_batch,
                total_samples: j.total_samples,
            },
            &j.tenant,
        )
        .unwrap()
        .unwrap();
    }
    h.drain().unwrap();
    let live_report = h.report().unwrap();
    let live_decisions = h.decisions().unwrap();

    // Identical placements (live ids are 1-based, sim ids 0-based).
    assert_eq!(sim_decisions.len(), live_decisions.len());
    for (k, (s, l)) in sim_decisions.iter().zip(live_decisions.iter()).enumerate() {
        assert_eq!(s.0 + 1, l.0, "placement #{k} is for a different job");
        assert_eq!(s.1, l.1, "placement #{k} (job {}) differs: {:?} vs {:?}", s.0, s.1, l.1);
    }
    assert_eq!(sim_report.n_completed, live_report.n_completed);
    assert_eq!(sim_report.n_rejected, live_report.n_rejected);

    // Per-tenant completions agree row for row (timing columns are
    // clock-dependent; the counts are not). Rows arrive sorted by tenant
    // on both paths (BTreeMap iteration order).
    let counts = |r: &RunReport| -> Vec<(String, u64)> {
        r.tenants.iter().map(|t| (t.tenant.clone(), t.n_completed)).collect()
    };
    assert_eq!(counts(&sim_report), counts(&live_report), "per-tenant completions");
    assert!(!sim_report.tenants.is_empty(), "a 3-tenant stream reports a breakdown");

    let (total, idle, _) = h.cluster_info().unwrap();
    assert_eq!(total, idle, "live resources all released");
    h.shutdown();
}

/// The seeded soak: a bursty, zipf-skewed synth stream under a seeded
/// chaos plan (crashes, stragglers, checkpoint-failure windows). Every
/// job must reach a terminal state, GPUs and device-memory bytes must
/// conserve, goodput must be a ratio, and the tenant breakdown must stay
/// a coherent partition of consumption.
#[test]
fn seeded_soak_synth_stream_under_fault_plan() {
    let spec = real_testbed();
    let jobs = generator::from_spec(
        "seed=9,jobs=30,arrivals=bursty:0.05x10+600,tenants=4:zipf,mix=small",
        0,
        0,
    )
    .unwrap();
    // Cap samples so re-execution after chaos stays inside the sim-time
    // budget; arrival times keep the generated bursty shape.
    let jobs: Vec<JobSpec> = jobs
        .iter()
        .map(|j| JobSpec { total_samples: j.total_samples.min(30_000), ..j.clone() })
        .collect();
    let span = jobs.last().unwrap().submit_time;
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let cfg = SimConfig { max_sim_time_s: 1e18, ..SimConfig::default() };
    let mut sim = Simulator::new(&spec, &mut has, cfg);
    sim.submit_all(&jobs);
    let plan = FaultPlan::parse("seed:42", spec.nodes.len(), span + 2_000.0).unwrap();
    assert!(!plan.is_empty());
    sim.inject_faults(&plan);
    let report = sim.run("synth-chaos");

    assert_eq!(report.n_jobs, jobs.len());
    assert_eq!(
        report.n_completed + report.n_rejected + report.n_cancelled,
        jobs.len(),
        "all jobs terminal: {report:?}"
    );
    assert!(sim.conservation_ok(), "GPU + device-memory conservation under chaos");
    assert_eq!(sim.cluster_state().idle_gpus(), sim.cluster_state().total_gpus());
    assert!((0.0..=1.0).contains(&report.goodput), "goodput {}", report.goodput);

    // Tenant accounting survives the chaos: completions are attributed,
    // and the GPU-share column partitions what was actually consumed —
    // including work later discarded by a crash.
    assert!(!report.tenants.is_empty(), "every job carried a tenant");
    let completed: u64 = report.tenants.iter().map(|t| t.n_completed).sum();
    assert_eq!(completed, report.n_completed as u64);
    let share_sum: f64 = report.tenants.iter().map(|t| t.gpu_share).sum();
    assert!(share_sum <= 1.0 + 1e-6, "share sum {share_sum}");
    for t in &report.tenants {
        assert!((0.0..=1.0).contains(&t.gpu_share), "share out of range: {t:?}");
        assert!(t.gpu_seconds >= 0.0 && t.avg_queue_s >= 0.0, "negative accounting: {t:?}");
    }
}

/// Admission isolation: one tenant exhausting its per-user token bucket
/// collects 429s without consuming anyone else's budget — other tenants
/// (and the anonymous principal) submit unimpeded, and the report
/// attributes completions to the right principals.
#[test]
fn tenant_quota_blowout_leaves_other_tenants_unaffected() {
    let cfg = CoordinatorConfig {
        execute_training: false,
        // Two submits of burst, effectively no refill within the test.
        user_quota: Some(QuotaCfg { rate_per_s: 1e-6, burst: 2.0 }),
        ..CoordinatorConfig::default()
    };
    let (h, _j) = spawn(real_testbed(), cfg);
    let req =
        || SubmitRequest { model: "gpt2-125m".into(), global_batch: 4, total_samples: 200 };

    let a = h.try_submit_as(req(), "noisy").unwrap().unwrap();
    let b = h.try_submit_as(req(), "noisy").unwrap().unwrap();
    for k in 0..5 {
        match h.try_submit_as(req(), "noisy").unwrap() {
            Err(SubmitError::QuotaExceeded { retry_after_ms }) => {
                assert!(retry_after_ms > 0, "a throttle always hints a pause");
            }
            other => panic!("noisy submit #{k} should be throttled, got {other:?}"),
        }
    }
    // Every other principal still has its full budget.
    let c = h.try_submit_as(req(), "quiet").unwrap().unwrap();
    let d = h.try_submit(req()).unwrap().unwrap();

    h.drain().unwrap();
    for id in [a, b, c, d] {
        assert_eq!(h.status(id).unwrap().unwrap().state, JobState::Completed);
    }
    // The quota principal doubles as the job's tenant end to end.
    assert_eq!(h.status(a).unwrap().unwrap().tenant, "noisy");
    assert_eq!(h.status(c).unwrap().unwrap().tenant, "quiet");
    assert_eq!(h.status(d).unwrap().unwrap().tenant, "");

    let r = h.report().unwrap();
    assert_eq!(r.n_throttled_quota, 5, "all five blowout submits counted");
    let completed = |t: &str| {
        r.tenants.iter().find(|row| row.tenant == t).map(|row| row.n_completed)
    };
    assert_eq!(completed("noisy"), Some(2));
    assert_eq!(completed("quiet"), Some(1));
    h.shutdown();
}

/// The grammar rejects bad specs with contextual errors at the CLI
/// boundary (the same strings `--workload synth:<spec>` would pass in).
#[test]
fn synth_grammar_errors_surface_through_from_spec() {
    for (s, needle) in [
        ("arrivals=warp:1", "unknown arrival process"),
        ("volume=11", "unknown synth clause"),
        ("mix=not-a-model", "bad mix"),
    ] {
        let err = generator::from_spec(s, 10, 1).expect_err(s);
        assert!(err.contains(needle), "'{s}': error '{err}' lacks '{needle}'");
    }
    // And a full kitchen-sink spec parses to exactly what it says.
    let spec = SynthSpec::parse("seed=7,jobs=5,arrivals=diurnal:0.1+3600,dur=lognormal:6x1.2,tenants=2:zipf,mix=small")
        .unwrap();
    assert_eq!(spec.seed, Some(7));
    assert_eq!(spec.jobs, Some(5));
    assert_eq!(spec.tenants, 2);
}
