//! Integration: the failure-domain runtime end-to-end. A seeded chaos
//! plan over a Philly prefix terminates every job with GPU and
//! device-memory conservation intact; K crashes inside the flap window
//! quarantine a node and placements provably avoid it; the same scripted
//! fault plan driven through the simulator (VirtualClock) and the live
//! coordinator (WallClock) yields identical placements and terminal
//! states; crash events ride the events API (cursor resume + SSE) with
//! no gaps; and `/v1/healthz` + `/v1/cluster/heartbeat` work over HTTP.

use frenzy::config::real_testbed;
use frenzy::engine::{ClusterEvent, EventKind};
use frenzy::faults::FaultPlan;
use frenzy::job::{JobSpec, JobState};
use frenzy::marp::Marp;
use frenzy::sched::has::Has;
use frenzy::serverless::api::EventsRequestV1;
use frenzy::serverless::client::FrenzyClient;
use frenzy::serverless::{server, spawn, CoordinatorConfig, Handle, SubmitRequest};
use frenzy::sim::{SimConfig, Simulator};
use frenzy::workload::philly;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn start(cfg: CoordinatorConfig) -> (Handle, SocketAddr, Arc<AtomicBool>) {
    let (h, _j) = spawn(real_testbed(), cfg);
    let stop = Arc::new(AtomicBool::new(false));
    let addr = server::serve(h.clone(), "127.0.0.1:0", stop.clone()).unwrap();
    (h, addr, stop)
}

fn wait_terminal(h: &Handle, id: u64) -> JobState {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        let st = h.status(id).unwrap().unwrap().state;
        if st.is_terminal() {
            return st;
        }
        assert!(std::time::Instant::now() < deadline, "job {id} not terminal after 30s");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
}

/// The chaos property test: a seeded [`FaultPlan`] over a Philly prefix —
/// crashes, a blackout-detected crash, stragglers, a checkpoint-failure
/// window — must leave every job terminal, conserve GPUs and
/// device-memory bytes, and fold honest crash counters and goodput into
/// the report.
#[test]
fn seeded_chaos_on_philly_prefix_terminates_and_conserves() {
    let spec = real_testbed();
    // Re-time the prefix to a dense arrival schedule so the seeded plan's
    // events (scattered over the horizon) overlap running jobs.
    let jobs: Vec<JobSpec> = philly::generate(24, 7)
        .iter()
        .take(14)
        .enumerate()
        .map(|(i, j)| {
            JobSpec::new(
                i as u64,
                j.model.clone(),
                j.train.global_batch,
                j.total_samples.min(30_000),
                i as f64 * 50.0,
            )
        })
        .collect();
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let cfg = SimConfig { max_sim_time_s: 1e18, ..SimConfig::default() };
    let mut sim = Simulator::new(&spec, &mut has, cfg);
    sim.submit_all(&jobs);
    let plan = FaultPlan::parse("seed:42", spec.nodes.len(), 14.0 * 50.0 + 2_000.0).unwrap();
    assert!(!plan.is_empty());
    sim.inject_faults(&plan);
    let report = sim.run("philly-chaos");

    // Every job goes terminal despite the chaos.
    assert_eq!(report.n_jobs, jobs.len());
    assert_eq!(
        report.n_completed + report.n_rejected + report.n_cancelled,
        jobs.len(),
        "all jobs terminal: {report:?}"
    );
    // Conservation: the allocation ledger and the device-memory byte
    // ledger both balance, and everything is released at the end.
    assert!(sim.conservation_ok(), "GPU + device-memory conservation");
    assert_eq!(sim.cluster_state().idle_gpus(), sim.cluster_state().total_gpus());
    // Crash counters agree with the audit log, and goodput is a ratio.
    let crashes_logged = sim
        .event_log()
        .iter()
        .filter(|r| matches!(r.kind, EventKind::NodeCrashed { .. }))
        .count() as u64;
    assert!(crashes_logged >= 1, "the seeded plan always crashes at least once");
    assert_eq!(report.n_node_crashes, crashes_logged);
    assert!((0.0..=1.0).contains(&report.goodput), "goodput {}", report.goodput);
}

/// K crashes inside the flap window quarantine the node; while the
/// quarantine holds, no placement touches it.
#[test]
fn k_crashes_quarantine_a_node_and_placements_avoid_it() {
    let spec = real_testbed();
    let model = frenzy::config::models::model_by_name("gpt2-350m").unwrap();
    // Jobs keep arriving well past the third crash so post-quarantine
    // placements exist to check.
    let jobs: Vec<JobSpec> =
        (0..12).map(|i| JobSpec::new(i, model.clone(), 8, 20_000, i as f64 * 15.0)).collect();
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let cfg = SimConfig {
        max_sim_time_s: 1e18,
        quarantine_crashes: 3,
        quarantine_window_s: 100.0,
        // Longer than the run: once quarantined, node 2 never returns.
        probation_s: 1e9,
        ..SimConfig::default()
    };
    let mut sim = Simulator::new(&spec, &mut has, cfg);
    sim.submit_all(&jobs);
    // Crash the 4-GPU A800 node three times inside the window.
    for t in [10.0, 20.0, 30.0] {
        sim.schedule_event(t, ClusterEvent::NodeCrash(2));
    }
    let report = sim.run("flap");
    assert_eq!(report.n_completed + report.n_rejected + report.n_cancelled, jobs.len());
    assert_eq!(report.n_quarantines, 1, "the third crash quarantines node 2");
    let quarantined_at = sim
        .event_log()
        .iter()
        .find(|r| matches!(r.kind, EventKind::NodeQuarantined { node: 2, .. }))
        .expect("node_quarantined event in the audit log")
        .time;
    let placed_after: Vec<&Vec<(usize, u32)>> = sim
        .event_log()
        .iter()
        .filter(|r| r.time > quarantined_at)
        .filter_map(|r| match &r.kind {
            EventKind::Placed { parts, .. } => Some(parts),
            _ => None,
        })
        .collect();
    assert!(!placed_after.is_empty(), "jobs are still placed after the quarantine");
    for parts in placed_after {
        assert!(
            parts.iter().all(|&(node, _)| node != 2),
            "placement touched the quarantined node: {parts:?}"
        );
    }
    assert!(sim.conservation_ok());
}

/// Differential chaos replay: the same scripted crash plan driven through
/// the simulator and the live coordinator must produce identical
/// placements, identical crash counters, and identical terminal states —
/// the two clocks share one failure-domain engine.
#[test]
fn same_fault_plan_sim_vs_live_identical_terminal_states() {
    let spec = real_testbed();
    let model = frenzy::config::models::model_by_name("gpt2-7b").unwrap();
    // Serialized arrivals: each job runs on an empty cluster, so sim and
    // live present identical snapshots to the scheduler.
    let trace: Vec<JobSpec> =
        (0..3).map(|i| JobSpec::new(i, model.clone(), 2, 20_000, i as f64 * 1e9)).collect();

    // Dry sim run to learn each job's placed node — the crash targets.
    let mut dry_has = Has::new(Marp::with_defaults(spec.clone()));
    let dry_cfg = SimConfig { max_sim_time_s: 1e18, ..SimConfig::default() };
    let mut dry = Simulator::new(&spec, &mut dry_has, dry_cfg);
    dry.submit_all(&trace);
    dry.run("faults-dry");
    let targets: Vec<usize> = trace
        .iter()
        .map(|j| {
            dry.engine().decision_log().iter().find(|d| d.0 == j.id).expect("placed").1[0].0
        })
        .collect();

    // Faulted sim: crash each job's node 1 virtual second into its run.
    // Quarantine is disabled on both paths because the two clocks put the
    // crashes at wildly different distances inside the flap window.
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let cfg = SimConfig { max_sim_time_s: 1e18, quarantine_crashes: 0, ..SimConfig::default() };
    let mut sim = Simulator::new(&spec, &mut has, cfg);
    sim.submit_all(&trace);
    for (j, &n) in trace.iter().zip(&targets) {
        sim.schedule_event(j.submit_time + 1.0, ClusterEvent::NodeCrash(n));
    }
    let sim_report = sim.run("faults-diff");
    let sim_decisions = sim.engine().decision_log().to_vec();

    // Live coordinator: same crashes, injected through the same event
    // path while each job runs.
    let cfg = CoordinatorConfig {
        execute_training: false,
        stub_delay_ms: 300,
        crash_backoff_base_ms: 50,
        crash_backoff_cap_ms: 100,
        quarantine_crashes: 0,
        ..CoordinatorConfig::default()
    };
    let (h, _j) = spawn(spec.clone(), cfg);
    let mut live_states = Vec::new();
    for j in &trace {
        let id = h
            .submit(SubmitRequest {
                model: j.model.name.to_string(),
                global_batch: j.train.global_batch,
                total_samples: j.total_samples,
            })
            .unwrap();
        assert_eq!(h.status(id).unwrap().unwrap().state, JobState::Running);
        let node = h.decisions().unwrap().iter().rev().find(|d| d.0 == id).unwrap().1[0].0;
        h.inject(ClusterEvent::NodeCrash(node)).unwrap();
        live_states.push(wait_terminal(&h, id));
    }
    let live_report = h.report().unwrap();
    let live_decisions = h.decisions().unwrap();

    // Identical placements: two per job (initial + post-crash re-place),
    // same order, same (node, gpu-count) parts. Live ids are 1-based.
    assert_eq!(sim_decisions.len(), 2 * trace.len(), "initial + re-placement per job");
    assert_eq!(sim_decisions.len(), live_decisions.len());
    for (k, (s, l)) in sim_decisions.iter().zip(live_decisions.iter()).enumerate() {
        assert_eq!(s.0 + 1, l.0, "placement #{k} is for a different job");
        assert_eq!(s.1, l.1, "placement #{k} (job {}) differs: {:?} vs {:?}", s.0, s.1, l.1);
    }
    // Identical terminal states: a crash never kills a job on either path.
    for (i, st) in live_states.iter().enumerate() {
        assert_eq!(*st, JobState::Completed, "live job {i}");
        assert!(
            sim.event_log().iter().any(
                |r| matches!(r.kind, EventKind::Finished { job, .. } if job == i as u64)
            ),
            "sim job {i} completed"
        );
    }
    // Identical failure accounting on both clocks.
    assert_eq!(sim_report.n_node_crashes, trace.len() as u64);
    assert_eq!(live_report.n_node_crashes, trace.len() as u64);
    assert_eq!(sim_report.n_crash_requeues, trace.len() as u64);
    assert_eq!(live_report.n_crash_requeues, trace.len() as u64);
    assert!((0.0..=1.0).contains(&sim_report.goodput));
    assert!((0.0..=1.0).contains(&live_report.goodput));
    assert!(sim.conservation_ok());
    let (total, idle, _) = h.cluster_info().unwrap();
    assert_eq!(total, idle, "live resources all released");
    h.shutdown();
}

/// Crash events ride the events API like any other kind: an SSE
/// subscriber sees them pushed live, and a cursor consumer that pages,
/// disconnects across a crash burst, and resumes from `next_since` sees
/// every event exactly once with dense sequence numbers.
#[test]
fn cursor_resume_and_sse_across_a_crash_burst() {
    let cfg = CoordinatorConfig {
        execute_training: false,
        stub_delay_ms: 400,
        crash_backoff_base_ms: 50,
        crash_backoff_cap_ms: 100,
        quarantine_crashes: 0,
        ..CoordinatorConfig::default()
    };
    let (h, addr, stop) = start(cfg);
    // SSE subscriber attached before the burst: it must see both crashes
    // and the eventual completion pushed, not polled.
    let subscriber = {
        let addr = addr.to_string();
        std::thread::spawn(move || {
            let mut c = FrenzyClient::new(addr);
            let mut kinds = Vec::new();
            c.events_stream(&EventsRequestV1::default(), |e| {
                kinds.push(e.kind.clone());
                let crashes =
                    kinds.iter().filter(|k| matches!(k, EventKind::NodeCrashed { .. })).count();
                let finished =
                    kinds.iter().filter(|k| matches!(k, EventKind::Finished { .. })).count();
                crashes < 2 || finished < 1
            })
            .unwrap();
            kinds
        })
    };
    std::thread::sleep(std::time::Duration::from_millis(150));

    let mut c = FrenzyClient::new(addr.to_string());
    let id = c.submit("gpt2-350m", 8, 400).unwrap();
    // Page 1, then "disconnect" (drop the position into a cursor).
    let p1 = c.events(&EventsRequestV1::default()).unwrap();
    assert!(!p1.dropped);
    let node = h.decisions().unwrap().iter().rev().find(|d| d.0 == id).unwrap().1[0].0;
    h.inject(ClusterEvent::NodeCrash(node)).unwrap();
    h.inject(ClusterEvent::NodeCrash((node + 1) % 5)).unwrap();
    h.drain().unwrap();
    // Resume from the stored cursor: the burst arrives exactly once.
    let p2 = c.events(&EventsRequestV1 { since: p1.next_since, ..Default::default() }).unwrap();
    assert!(!p2.dropped);
    assert_eq!(p2.next_since, p2.last_seq, "one resume page catches up");
    let seqs: Vec<u64> =
        p1.events.iter().chain(p2.events.iter()).map(|e| e.seq).collect();
    assert!(
        seqs.windows(2).all(|w| w[1] == w[0] + 1),
        "dense, gapless, duplicate-free across the resume: {seqs:?}"
    );
    let crash_events: Vec<&frenzy::serverless::api::EventV1> = p2
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::NodeCrashed { .. }))
        .collect();
    assert_eq!(crash_events.len(), 2, "both crashes are in the resumed page");
    assert!(
        crash_events.iter().any(|e| matches!(&e.kind,
            EventKind::NodeCrashed { preempted, .. } if preempted.contains(&id))),
        "the first crash displaced the running job"
    );
    assert!(
        p2.events.iter().any(|e| matches!(e.kind, EventKind::Finished { job, .. } if job == id)),
        "the displaced job still completed"
    );
    assert_eq!(h.report().unwrap().n_node_crashes, 2);

    let kinds = subscriber.join().unwrap();
    assert_eq!(
        kinds.iter().filter(|k| matches!(k, EventKind::NodeCrashed { .. })).count(),
        2,
        "SSE pushed both crash events: {kinds:?}"
    );
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

/// `/v1/healthz` answers liveness + readiness and
/// `/v1/cluster/heartbeat` renews a lease over the wire — the SDK methods
/// round-trip both.
#[test]
fn healthz_and_heartbeat_over_http() {
    let cfg = CoordinatorConfig {
        execute_training: false,
        // Long lease: nothing expires during the test; the response just
        // advertises the window.
        lease_timeout_ms: 5_000,
        ..CoordinatorConfig::default()
    };
    let (h, addr, stop) = start(cfg);
    let mut c = FrenzyClient::new(addr.to_string());
    let (ok, ready) = c.healthz().unwrap();
    assert!(ok && ready, "in-memory server is ready as soon as it serves");
    assert!(c.health().unwrap());
    let resp = c.heartbeat(0).unwrap();
    assert_eq!(resp.node, 0);
    assert_eq!(resp.lease_ms, 5_000, "the response advertises the lease window");
    let err = c.heartbeat(99).unwrap_err().to_string();
    assert!(err.contains("no such node"), "got: {err}");
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}
