//! Integration: the telemetry subsystem end to end over TCP — Prometheus
//! exposition conformance with families from every layer (HTTP server,
//! coordinator, engine, durability, runtime), per-job timelines through the
//! SDK, version skew check, and the determinism differential: the exact
//! same workload scheduled with telemetry recording disabled produces
//! byte-identical decisions and a byte-identical deterministic report.

use frenzy::config::{real_testbed, sia_sim};
use frenzy::job::JobSpec;
use frenzy::marp::Marp;
use frenzy::obs::{self, expo};
use frenzy::sched::has::Has;
use frenzy::serverless::client::FrenzyClient;
use frenzy::serverless::{server, spawn, CoordinatorConfig, Handle};
use frenzy::sim::{SimConfig, Simulator};
use frenzy::workload::generator;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Tests that either toggle the process-global recording switch or assert
/// on recorded values serialize through this gate, so a disabled window in
/// one test cannot eat another test's counter increments.
static OBS_GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    OBS_GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restores recording on drop so a panicking test cannot leave the
/// process-global switch off for the rest of the binary.
struct EnabledGuard;
impl Drop for EnabledGuard {
    fn drop(&mut self) {
        obs::set_enabled(true);
    }
}

fn start(spec: frenzy::config::ClusterSpec) -> (Handle, SocketAddr, Arc<AtomicBool>) {
    let cfg = CoordinatorConfig { execute_training: false, ..CoordinatorConfig::default() };
    let (h, _j) = spawn(spec, cfg);
    let stop = Arc::new(AtomicBool::new(false));
    let addr = server::serve(h.clone(), "127.0.0.1:0", stop.clone()).unwrap();
    (h, addr, stop)
}

#[test]
fn metrics_exposition_is_conformant_and_covers_every_layer() {
    let _g = gate();
    let (h, addr, stop) = start(real_testbed());
    let mut client = FrenzyClient::new(addr.to_string());
    let n = 4u64;
    for _ in 0..n {
        client.submit("gpt2-350m", 8, 100).unwrap();
    }
    h.drain().unwrap();
    // One extra poll so the coordinator republishes its gauges after the
    // jobs completed.
    client.report().unwrap();

    let text = client.metrics_text().unwrap();
    let samples = expo::parse(&text).expect("exposition must parse");
    expo::validate(&text).expect("exposition must be conformant");

    // Every layer is represented: TYPE metadata renders for all registered
    // families whether or not traffic has touched them yet.
    for family in [
        "frenzy_build_info",
        "frenzy_process_uptime_seconds",
        "frenzy_http_requests_total",
        "frenzy_http_request_duration_seconds",
        "frenzy_http_inflight_requests",
        "frenzy_http_shed_total",
        "frenzy_coordinator_mailbox_depth",
        "frenzy_admission_decisions_total",
        "frenzy_jobs",
        "frenzy_sched_rounds_total",
        "frenzy_sched_round_phase_seconds",
        "frenzy_engine_events_total",
        "frenzy_wal_appends_total",
        "frenzy_wal_fsync_seconds",
        "frenzy_snapshot_age_seconds",
        "frenzy_node_device_mem_used_bytes",
        "frenzy_oom_events_total",
    ] {
        assert!(text.contains(&format!("# TYPE {family} ")), "missing family {family}");
    }

    // Recorded values from the traffic this test generated. The registry is
    // process-global and other tests in this binary add to it, so every
    // bound is a ≥.
    let build = samples.iter().find(|s| s.name == "frenzy_build_info").expect("build_info");
    let version = build.labels.iter().find(|(k, _)| k == "version").map(|(_, v)| v.as_str());
    assert_eq!(version, Some(env!("CARGO_PKG_VERSION")));
    assert_eq!(build.value, 1.0);

    let submits = expo::sample_value(
        &samples,
        "frenzy_http_requests_total",
        &[("route", "/v1/jobs"), ("code", "2xx")],
    )
    .unwrap_or(0.0);
    assert!(submits >= n as f64, "submits recorded: {submits} < {n}");

    let lat = expo::bucket_series(
        &samples,
        "frenzy_http_request_duration_seconds",
        &[("route", "/v1/jobs")],
    );
    assert!(lat.last().map_or(0.0, |&(_, c)| c) >= n as f64, "latency observations");
    assert!(expo::quantile(&lat, 0.5).is_some());

    let admitted = expo::sample_value(
        &samples,
        "frenzy_admission_decisions_total",
        &[("decision", "admitted")],
    )
    .unwrap_or(0.0);
    assert!(admitted >= n as f64, "admissions recorded: {admitted} < {n}");

    assert!(
        expo::sample_value(&samples, "frenzy_sched_rounds_total", &[]).unwrap_or(0.0) >= 1.0,
        "the engine ran at least one round"
    );
    for phase in ["candidate_scan", "plan_rank", "placement"] {
        let series =
            expo::bucket_series(&samples, "frenzy_sched_round_phase_seconds", &[("phase", phase)]);
        assert!(series.last().map_or(0.0, |&(_, c)| c) >= 1.0, "phase {phase} observed");
    }

    // Runtime gauges: the coordinator publishes per-node device memory.
    let cap: f64 = samples
        .iter()
        .filter(|s| s.name == "frenzy_node_device_mem_capacity_bytes")
        .map(|s| s.value)
        .sum();
    assert!(cap > 0.0, "device memory capacity published");

    assert!(
        expo::sample_value(&samples, "frenzy_process_uptime_seconds", &[]).unwrap_or(-1.0) >= 0.0
    );
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

#[test]
fn timeline_over_tcp_through_the_sdk() {
    let (h, addr, stop) = start(real_testbed());
    let mut client = FrenzyClient::new(addr.to_string());
    let id = client.submit("gpt2-350m", 8, 150).unwrap();
    h.drain().unwrap();

    let tl = client.timeline(id).unwrap().expect("completed job has a timeline");
    assert_eq!(tl.job, id);
    assert!(tl.terminal, "drained job is terminal");
    assert!(!tl.partial, "short run cannot have evicted records");
    assert_eq!(tl.placements, 1);
    assert!(tl.phases.iter().any(|p| p.phase == "queued"));
    assert!(tl.phases.iter().any(|p| p.phase == "running"));
    // Every span is closed once the job is terminal, and the books balance:
    // per-phase sums never exceed the overall span.
    assert!(tl.phases.iter().all(|p| p.end_s.is_some()));
    let sum = tl.queue_s + tl.run_s + tl.drain_s + tl.crash_backoff_s;
    assert!(sum <= tl.total_s + 1e-6, "phase sums {sum} > total {}", tl.total_s);
    // The referenced event records cover the lifecycle in order.
    let kinds: Vec<&str> = tl.events.iter().map(|e| e.kind.as_str()).collect();
    assert!(kinds.contains(&"arrival"), "{kinds:?}");
    assert!(kinds.contains(&"placed"), "{kinds:?}");
    assert!(kinds.contains(&"finished"), "{kinds:?}");
    assert!(tl.events.windows(2).all(|w| w[0].seq < w[1].seq), "events ordered by seq");

    // Unknown job: a clean None, not an error.
    assert!(client.timeline(999_999).unwrap().is_none());
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

#[test]
fn version_over_tcp_matches_the_build() {
    let (h, addr, stop) = start(sia_sim());
    let mut client = FrenzyClient::new(addr.to_string());
    let v = client.version().unwrap();
    assert_eq!(v.version, env!("CARGO_PKG_VERSION"));
    assert!(!v.git_sha.is_empty());
    assert!(v.features.iter().any(|f| f == "obs"));
    stop.store(true, Ordering::Relaxed);
    h.shutdown();
}

/// The hard constraint of this subsystem: telemetry must be a pure
/// observer. Running the exact same seeded workload with recording
/// disabled yields the same placement decisions in the same order and a
/// byte-identical deterministic report.
#[test]
fn disabling_telemetry_changes_no_scheduling_decision() {
    let _g = gate();

    fn run(jobs: &[JobSpec]) -> (Vec<u64>, String) {
        let spec = sia_sim();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let cfg = SimConfig { max_sim_time_s: 1e18, ..SimConfig::default() };
        let mut sim = Simulator::new(&spec, &mut has, cfg);
        sim.submit_all(jobs);
        let report = sim.run("obs-differential");
        let order: Vec<u64> = sim.engine().decision_log().iter().map(|d| d.0).collect();
        assert!(sim.conservation_ok());
        (order, report.to_json_deterministic().to_string_compact())
    }

    let jobs =
        generator::from_spec("seed=77,jobs=30,arrivals=poisson:0.4,tenants=4,mix=zoo", 30, 7)
            .unwrap();

    let _restore = EnabledGuard;
    obs::set_enabled(false);
    let (order_off, report_off) = run(&jobs);
    obs::set_enabled(true);
    let (order_on, report_on) = run(&jobs);

    assert_eq!(order_off, order_on, "placement decision order must not depend on telemetry");
    assert_eq!(report_off, report_on, "deterministic report must be byte-identical");
}
