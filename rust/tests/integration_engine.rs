//! Integration: the unified scheduling engine drives BOTH the discrete
//! event simulator (VirtualClock) and the live serverless coordinator
//! (WallClock). The differential test here is the refactor's acceptance
//! proof: the same trace, driven through both clocks, must yield identical
//! placement decisions and terminal job states.

use frenzy::config::{gpu_by_name, real_testbed, sia_sim, ClusterSpec, LinkKind, NodeSpec};
use frenzy::engine::ClusterEvent;
use frenzy::job::{JobSpec, JobState};
use frenzy::marp::Marp;
use frenzy::sched::has::Has;
use frenzy::sched::sia::Sia;
use frenzy::serverless::{spawn, CoordinatorConfig, Handle, ScaleOp, SchedulerKind, SubmitRequest};
use frenzy::sim::{SimConfig, Simulator};
use frenzy::workload::{helios, philly};

/// Re-time a generated trace so each job runs on an otherwise-empty
/// cluster: arrivals far enough apart that every job finishes (in sim
/// time) before the next arrives. This serialization is the regime where a
/// virtual clock and a wall clock are *guaranteed* to present identical
/// snapshots to the scheduler — so every placement must match exactly.
fn serialized_prefix(jobs: &[JobSpec], n: usize) -> Vec<JobSpec> {
    jobs.iter()
        .take(n)
        .enumerate()
        .map(|(i, j)| {
            JobSpec::new(
                i as u64,
                j.model.clone(),
                j.train.global_batch,
                j.total_samples.min(20_000),
                i as f64 * 1e9,
            )
        })
        .collect()
}

fn differential(trace_name: &str, trace: Vec<JobSpec>) {
    let spec = sia_sim();

    // --- virtual-clock path: the simulator ---------------------------
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let cfg = SimConfig { max_sim_time_s: 1e18, ..SimConfig::default() };
    let mut sim = Simulator::new(&spec, &mut has, cfg);
    sim.submit_all(&trace);
    let sim_report = sim.run(trace_name);
    let sim_decisions: Vec<(u64, Vec<(usize, u32)>)> = sim.engine().decision_log().to_vec();
    let sim_completed: Vec<u64> = {
        let mut ids: Vec<u64> = sim
            .event_log()
            .iter()
            .filter_map(|r| match r.kind {
                frenzy::engine::EventKind::Finished { job, .. } => Some(job),
                _ => None,
            })
            .collect();
        ids.sort_unstable();
        ids
    };

    // --- wall-clock path: the live coordinator -----------------------
    // stub_delay_ms = 0 completes each job before the next sequential
    // submit is processed — the live counterpart of the serialized trace.
    let (h, _j) = spawn(
        spec.clone(),
        CoordinatorConfig { execute_training: false, ..CoordinatorConfig::default() },
    );
    let mut live_ids = Vec::new();
    for j in &trace {
        live_ids.push(
            h.submit(SubmitRequest {
                model: j.model.name.to_string(),
                global_batch: j.train.global_batch,
                total_samples: j.total_samples,
            })
            .unwrap(),
        );
    }
    h.drain().unwrap();
    let live_decisions = h.decisions().unwrap();

    // Identical placement decisions: same number, same order, same
    // (node, gpu-count) parts. Live job ids are 1-based where the sim
    // trace is 0-based; the order is the arrival order in both.
    assert_eq!(
        sim_decisions.len(),
        live_decisions.len(),
        "{trace_name}: sim and live must place the same jobs"
    );
    for (k, (s, l)) in sim_decisions.iter().zip(live_decisions.iter()).enumerate() {
        assert_eq!(
            s.0 + 1,
            l.0,
            "{trace_name}: placement #{k} is for a different job (sim {}, live {})",
            s.0,
            l.0
        );
        assert_eq!(
            s.1, l.1,
            "{trace_name}: placement #{k} (job {}) differs: sim {:?} vs live {:?}",
            s.0, s.1, l.1
        );
    }

    // Identical terminal states, job by job.
    for (i, j) in trace.iter().enumerate() {
        let live_state = h.status(live_ids[i]).unwrap().unwrap().state;
        let sim_done = sim_completed.binary_search(&(i as u64)).is_ok();
        match live_state {
            JobState::Completed => {
                assert!(sim_done, "{trace_name}: job {i} ({}) live-only completion", j.name)
            }
            JobState::Rejected => {
                assert!(!sim_done, "{trace_name}: job {i} ({}) live-only rejection", j.name)
            }
            other => panic!("{trace_name}: job {i} not terminal after drain: {other:?}"),
        }
    }
    let live_report = h.report().unwrap();
    assert_eq!(sim_report.n_completed, live_report.n_completed, "{trace_name}");
    assert_eq!(sim_report.n_rejected, live_report.n_rejected, "{trace_name}");

    let (total, idle, _) = h.cluster_info().unwrap();
    assert_eq!(total, idle, "{trace_name}: live resources all released");
    assert!(sim.conservation_ok(), "{trace_name}: sim conservation");
    h.shutdown();
}

#[test]
fn differential_philly_prefix_sim_vs_live() {
    let trace = serialized_prefix(&philly::generate(40, 7), 12);
    differential("philly", trace);
}

#[test]
fn differential_helios_prefix_sim_vs_live() {
    let trace = serialized_prefix(&helios::generate(40, 13), 12);
    differential("helios", trace);
}

/// Poll a job until it reaches a terminal state (live runs with real OOM
/// detection delays and round-timer ticks need more than an instant).
fn wait_terminal(h: &Handle, id: u64) -> JobState {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        let st = h.status(id).unwrap().unwrap().state;
        if st.is_terminal() {
            return st;
        }
        assert!(std::time::Instant::now() < deadline, "job {id} not terminal after 30s");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
}

#[test]
fn differential_sia_live_timer_vs_sim() {
    // The timer acceptance test: Sia — an *interval* scheduler — driven by
    // the live coordinator's round-timer thread on a WallClock must make
    // exactly the placements the simulator makes on the same serialized
    // trace, and fold to the same RunReport aggregates. Before the timer
    // existed the live engine rounded immediately, so Sia's cadence
    // semantics only existed in simulation.
    let spec = sia_sim();
    let models = ["gpt2-125m", "gpt2-350m", "gpt2-760m"];
    let trace: Vec<JobSpec> = (0..6)
        .map(|i| {
            JobSpec::new(
                i as u64,
                frenzy::config::models::model_by_name(models[i % models.len()]).unwrap(),
                8,
                5_000,
                i as f64 * 1e9, // serialized: each job runs on an empty cluster
            )
        })
        .collect();

    // --- virtual-clock path: the simulator with Sia -------------------
    let mut sia = Sia::new(&spec);
    let cfg = SimConfig { max_sim_time_s: 1e18, ..SimConfig::default() };
    let mut sim = Simulator::new(&spec, &mut sia, cfg);
    sim.submit_all(&trace);
    let sim_report = sim.run("sia-diff");
    let sim_decisions = sim.engine().decision_log().to_vec();

    // --- wall-clock path: live coordinator + round timer --------------
    // Submissions are serialized by *waiting for each job to go terminal*
    // (not by the instant stub alone: an OOM retry keeps a job alive
    // across several rounds), so every round sees the same single-job
    // queue and empty cluster as the simulator.
    let cfg = CoordinatorConfig {
        execute_training: false,
        scheduler: SchedulerKind::Sia { round_interval_s: 0.05 },
        round_tick_period_s: 0.01,
        oom_detect_ms: 20,
        ..CoordinatorConfig::default()
    };
    let (h, _j) = spawn(spec, cfg);
    let mut live_states = Vec::new();
    for j in &trace {
        let id = h
            .submit(SubmitRequest {
                model: j.model.name.to_string(),
                global_batch: j.train.global_batch,
                total_samples: j.total_samples,
            })
            .unwrap();
        live_states.push(wait_terminal(&h, id));
    }
    let live_report = h.report().unwrap();
    let live_decisions = h.decisions().unwrap();

    // Same placements, in order (live ids are 1-based).
    assert_eq!(
        sim_decisions.len(),
        live_decisions.len(),
        "sim and live Sia must place the same number of times"
    );
    for (k, (s, l)) in sim_decisions.iter().zip(live_decisions.iter()).enumerate() {
        assert_eq!(s.0 + 1, l.0, "placement #{k} is for a different job");
        assert_eq!(s.1, l.1, "placement #{k} (job {}) differs: {:?} vs {:?}", s.0, s.1, l.1);
    }
    // Same aggregates (clock-independent counters).
    assert_eq!(sim_report.n_jobs, live_report.n_jobs);
    assert_eq!(sim_report.n_completed, live_report.n_completed);
    assert_eq!(sim_report.n_rejected, live_report.n_rejected);
    assert_eq!(sim_report.total_oom_retries, live_report.total_oom_retries);
    assert_eq!(sim_report.n_oom_events, live_report.n_oom_events);
    assert_eq!(live_report.scheduler, "sia");
    // Terminal states agree job by job.
    for (i, st) in live_states.iter().enumerate() {
        let sim_done = sim.event_log().iter().any(|r| {
            matches!(r.kind, frenzy::engine::EventKind::Finished { job, .. } if job == i as u64)
        });
        match st {
            JobState::Completed => assert!(sim_done, "job {i}: live-only completion"),
            JobState::Rejected => assert!(!sim_done, "job {i}: live-only rejection"),
            other => panic!("job {i} not terminal: {other:?}"),
        }
    }
    let (total, idle, _) = h.cluster_info().unwrap();
    assert_eq!(total, idle, "live resources all released");
    h.shutdown();
}

#[test]
fn node_leave_mid_sim_preempts_and_recovers() {
    // Elasticity through the *simulator* wrapper: jobs running when node 2
    // (the 4×A800) dies are preempted, requeued with attempts + 1, and the
    // run still terminates with conservation intact.
    let spec = real_testbed();
    let mut has = Has::new(Marp::with_defaults(spec.clone()));
    let mut sim = Simulator::new(&spec, &mut has, SimConfig::default());
    // A 7b job parks on the 80G cards for a long time (but small enough to
    // finish within the sim-time cap even on a slow cross-node re-placement).
    let model = |name: &str| frenzy::config::models::model_by_name(name).unwrap();
    let jobs = vec![
        JobSpec::new(0, model("gpt2-7b"), 2, 20_000, 0.0),
        JobSpec::new(1, model("gpt2-125m"), 4, 200_000, 0.0),
    ];
    sim.submit_all(&jobs);
    sim.schedule_event(50.0, ClusterEvent::NodeLeave(2));
    let report = sim.run("elastic");
    assert_eq!(report.n_completed + report.n_rejected, 2);
    assert!(sim.conservation_ok());
    assert_eq!(sim.cluster_state().idle_gpus(), sim.cluster_state().total_gpus());
    assert_eq!(sim.cluster_state().total_gpus(), 7, "the A800 node is gone");
    // If the 7b job completed, it must record the preemption as a retry:
    // the event log shows a second placement with attempts >= 2.
    use frenzy::engine::EventKind;
    let completed_0 = sim
        .event_log()
        .iter()
        .any(|r| matches!(r.kind, EventKind::Finished { job: 0, .. }));
    if completed_0 {
        let max_attempts = sim
            .event_log()
            .iter()
            .filter_map(|r| match r.kind {
                EventKind::Placed { job: 0, attempts, .. } => Some(attempts),
                _ => None,
            })
            .max()
            .unwrap_or(0);
        assert!(max_attempts >= 2, "preempted job re-placed with attempts+1, got {max_attempts}");
    }
}

#[test]
fn node_join_in_live_coordinator_unblocks_queued_job() {
    // Live counterpart of the engine-level NodeJoin test: a cluster of
    // 2×40G cannot host gpt2-7b; while a small job keeps the cluster busy,
    // the 7b waits in the queue. Joining an 80G node must get it running.
    let a100_40 = gpu_by_name("A100-40G").unwrap();
    let tiny = ClusterSpec {
        name: "tiny".into(),
        nodes: vec![NodeSpec { gpu: a100_40, count: 2, link: LinkKind::Pcie }],
        inter_node_gbps: 12.5,
    };
    let cfg = CoordinatorConfig {
        execute_training: false,
        stub_delay_ms: 400,
        ..CoordinatorConfig::default()
    };
    let (h, _j) = spawn(tiny, cfg);
    let blocker = h
        .submit(SubmitRequest {
            model: "gpt2-125m".into(),
            global_batch: 4,
            total_samples: 400,
        })
        .unwrap();
    assert_eq!(h.status(blocker).unwrap().unwrap().state, JobState::Running);
    // 7b is admitted only once the cluster can host it: before the join,
    // admission-time MARP finds no plan and marks it rejected.
    let doomed = h
        .submit(SubmitRequest { model: "gpt2-7b".into(), global_batch: 2, total_samples: 100 })
        .unwrap();
    assert_eq!(h.status(doomed).unwrap().unwrap().state, JobState::Rejected);
    // Join 4×80G; admission MARP is rebuilt, so the same submit now queues
    // (or runs) instead of being rejected.
    let rep = h
        .scale(ScaleOp::Join { gpu: "A800-80G".into(), count: 4, link: LinkKind::NvLink })
        .unwrap();
    assert_eq!(rep.total_gpus, 6);
    let big = h
        .submit(SubmitRequest { model: "gpt2-7b".into(), global_batch: 2, total_samples: 100 })
        .unwrap();
    let st = h.status(big).unwrap().unwrap().state;
    assert!(
        st == JobState::Running || st == JobState::Completed,
        "7b must be schedulable after the join, got {st:?}"
    );
    h.drain().unwrap();
    assert_eq!(h.status(big).unwrap().unwrap().state, JobState::Completed);
    assert_eq!(h.status(blocker).unwrap().unwrap().state, JobState::Completed);
    let (total, idle, _) = h.cluster_info().unwrap();
    assert_eq!(total, idle);
    h.shutdown();
}
