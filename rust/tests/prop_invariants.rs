//! Property-based invariants over MARP, HAS, the orchestrator, the ILP
//! solver, and the simulator (using the in-house prop runner).

use frenzy::cluster::{ClusterState, ClusterView, Orchestrator};
use frenzy::config::models::model_zoo;
use frenzy::config::{gpu_catalog, ClusterSpec, LinkKind, NodeSpec};
use frenzy::ilp;
use frenzy::job::JobSpec;
use frenzy::marp::Marp;
use frenzy::memory::{
    activation_bytes_per_gpu, exact::exact_peak_bytes, marp_peak_bytes, static_bytes_per_gpu,
    Parallelism, TrainConfig,
};
use frenzy::sched::{has::Has, PendingJob, PendingQueue, Scheduler};
use frenzy::sim::{simulate, SimConfig};
use frenzy::util::prop::{Gen, Runner};

fn arb_cluster(g: &mut Gen) -> ClusterSpec {
    let catalog = gpu_catalog();
    let n_nodes = g.usize_in(1, 6);
    let nodes: Vec<NodeSpec> = (0..n_nodes)
        .map(|_| NodeSpec {
            gpu: g.pick(&catalog).clone(),
            count: g.usize_in(1, 8) as u32,
            link: if g.bool() { LinkKind::NvLink } else { LinkKind::Pcie },
        })
        .collect();
    ClusterSpec { name: "arb".into(), nodes, inter_node_gbps: g.f64_in(5.0, 50.0) }
}

fn arb_par(g: &mut Gen) -> Parallelism {
    Parallelism::new(1 << g.usize_in(0, 4), 1 << g.usize_in(0, 3))
}

#[test]
fn prop_memory_monotone_in_d_and_t() {
    Runner::new("memory monotone", 0xA11CE, 300).run(|g| {
        let zoo = model_zoo();
        let model = g.pick(&zoo).clone();
        let cfg = TrainConfig { global_batch: (1 << g.usize_in(0, 6)) as u32 };
        let par = arb_par(g);
        let par_d2 = Parallelism::new(par.d * 2, par.t);
        let par_t2 = Parallelism::new(par.d, par.t * 2);
        let a = activation_bytes_per_gpu(&model, &cfg, par);
        if activation_bytes_per_gpu(&model, &cfg, par_d2) > a + 1.0 {
            return Err(format!("activations grew with d: {model:?} {par:?}"));
        }
        if static_bytes_per_gpu(&model, par_t2) > static_bytes_per_gpu(&model, par) {
            return Err("static grew with t".into());
        }
        if marp_peak_bytes(&model, &cfg, par_t2) > marp_peak_bytes(&model, &cfg, par) {
            return Err(format!("peak grew with t: {} {par:?}", model.name));
        }
        Ok(())
    });
}

#[test]
fn prop_exact_always_exceeds_closed_form() {
    Runner::new("exact > closed form", 0xBEEF, 300).run(|g| {
        let zoo = model_zoo();
        let model = g.pick(&zoo).clone();
        let cfg = TrainConfig { global_batch: (1 << g.usize_in(0, 5)) as u32 };
        let par = arb_par(g);
        let pred = marp_peak_bytes(&model, &cfg, par);
        let exact = exact_peak_bytes(&model, &cfg, par);
        if exact <= pred {
            return Err(format!(
                "exact {exact} <= predicted {pred} for {} b={} {par:?}",
                model.name, cfg.global_batch
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_marp_plans_fit_some_cluster_gpu() {
    Runner::new("plans fit cluster", 0xC0FFEE, 120).run(|g| {
        let cluster = arb_cluster(g);
        let max_mem = cluster.max_gpu_mem();
        let marp = Marp::with_defaults(cluster.clone());
        let zoo = model_zoo();
        let model = g.pick(&zoo).clone();
        let cfg = TrainConfig { global_batch: (1 << g.usize_in(0, 5)) as u32 };
        for p in marp.plans(&model, &cfg) {
            if p.min_gpu_mem > max_mem {
                return Err(format!("plan needs {} > cluster max {max_mem}", p.min_gpu_mem));
            }
            if p.n_gpus == 0 || p.n_gpus > cluster.total_gpus() {
                return Err(format!("plan gpus {} out of range", p.n_gpus));
            }
            if p.n_gpus != p.par.gpus() {
                return Err("n_gpus != d*t".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_has_never_overallocates_and_covers_request() {
    Runner::new("HAS allocation sound", 0xD00D, 120).run(|g| {
        let cluster = arb_cluster(g);
        let marp = Marp::with_defaults(cluster.clone());
        let mut has = Has::new(marp);
        let zoo = model_zoo();
        let n_jobs = g.usize_in(1, 10);
        let pending: Vec<PendingJob> = (0..n_jobs)
            .map(|i| PendingJob {
                spec: JobSpec::new(
                    i as u64,
                    g.pick(&zoo).clone(),
                    (1 << g.usize_in(0, 5)) as u32,
                    1000,
                    0.0,
                ),
                attempts: 0,
            })
            .collect();
        let snap = ClusterState::from_spec(&cluster);
        let view = ClusterView::build(&snap);
        let round = has.schedule(&PendingQueue::from(pending), &view, 0.0);
        let mut orch = Orchestrator::new(&cluster);
        for d in &round.decisions {
            if d.will_oom {
                return Err(format!("HAS produced an OOM placement: {:?}", d.job));
            }
            orch.allocate(d.alloc.clone())
                .map_err(|e| format!("overallocation: {e}"))?;
        }
        if !orch.check_conservation() {
            return Err("conservation violated".into());
        }
        Ok(())
    });
}

#[test]
fn prop_has_placements_never_exceed_measured_memory() {
    // Even against the EXACT accounting (not just the prediction), a HAS
    // placement must fit — MARP's margins absorb the closed-form error.
    Runner::new("HAS no-OOM vs exact", 0xF001, 150).run(|g| {
        let cluster = arb_cluster(g);
        let marp = Marp::with_defaults(cluster.clone());
        let zoo = model_zoo();
        let model = g.pick(&zoo).clone();
        let cfg = TrainConfig { global_batch: (1 << g.usize_in(0, 5)) as u32 };
        let plans = marp.plans(&model, &cfg);
        let snap = ClusterState::from_spec(&cluster);
        let mut work = 0;
        if let Some((plan, alloc)) = Has::allocate_one(&plans, &snap, &mut work) {
            let min_mem = alloc
                .parts
                .iter()
                .map(|(n, _)| snap.nodes[*n].gpu.mem_bytes)
                .min()
                .unwrap();
            let measured = exact_peak_bytes(&model, &cfg, plan.par);
            if measured > min_mem {
                return Err(format!(
                    "{} b={} d={} t={}: measured {measured} > gpu {min_mem}",
                    model.name, cfg.global_batch, plan.par.d, plan.par.t
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ilp_solutions_feasible_and_not_worse_than_greedy() {
    Runner::new("ilp sound", 0x111, 80).run(|g| {
        let n_groups = g.usize_in(1, 8);
        let dims = g.usize_in(1, 3);
        let capacity: Vec<u32> = (0..dims).map(|_| g.usize_in(1, 20) as u32).collect();
        let mut items = Vec::new();
        for group in 0..n_groups {
            for _ in 0..g.usize_in(1, 4) {
                items.push(ilp::Item {
                    group,
                    value: g.f64_in(0.1, 10.0),
                    usage: (0..dims).map(|_| g.usize_in(0, 8) as u32).collect(),
                });
            }
        }
        let p = ilp::Problem { n_groups, capacity, items };
        p.validate().map_err(|e| e)?;
        let sol = ilp::solve(&p, 2_000_000);
        if !p.feasible(&sol.chosen) {
            return Err("infeasible solution".into());
        }
        // Greedy lower bound: take each group's best-fitting item in order.
        let mut used = vec![0u32; p.capacity.len()];
        let mut greedy = 0.0;
        for gi in 0..p.n_groups {
            let mut best: Option<(usize, f64)> = None;
            for (i, it) in p.items.iter().enumerate().filter(|(_, it)| it.group == gi) {
                let fits = it
                    .usage
                    .iter()
                    .zip(&p.capacity)
                    .enumerate()
                    .all(|(d2, (u, c))| used[d2] + u <= *c);
                if fits && best.map(|(_, v)| it.value > v).unwrap_or(true) {
                    best = Some((i, it.value));
                }
            }
            if let Some((i, v)) = best {
                for (d2, u) in p.items[i].usage.iter().enumerate() {
                    used[d2] += u;
                }
                greedy += v;
            }
        }
        if sol.value + 1e-9 < greedy {
            return Err(format!("B&B {:.4} worse than greedy {:.4}", sol.value, greedy));
        }
        Ok(())
    });
}

#[test]
fn prop_sim_terminates_with_conservation() {
    Runner::new("sim conservation", 0x51AB, 25).run(|g| {
        let cluster = arb_cluster(g);
        // Ensure at least one node can host the smallest model, else
        // everything is rejected (also fine, but less interesting).
        let zoo = model_zoo();
        let n = g.usize_in(2, 15);
        let jobs: Vec<JobSpec> = (0..n)
            .map(|i| {
                JobSpec::new(
                    i as u64,
                    g.pick(&zoo).clone(),
                    (1 << g.usize_in(0, 4)) as u32,
                    g.usize_in(100, 50_000) as u64,
                    g.f64_in(0.0, 600.0),
                )
            })
            .collect();
        let mut has = Has::new(Marp::with_defaults(cluster.clone()));
        let report = simulate(&cluster, &mut has, &jobs, SimConfig::default(), "prop");
        if report.n_completed + report.n_rejected != n {
            return Err(format!(
                "{} completed + {} rejected != {n}",
                report.n_completed, report.n_rejected
            ));
        }
        Ok(())
    });
}
