//! Bakes the git commit into the binary: `FRENZY_GIT_SHA` is read via
//! `option_env!` in `obs::git_sha` and surfaces in `frenzy --version`,
//! `GET /v1/version`, and the `frenzy_build_info` metric. Builds outside a
//! checkout (vendored tarball, CI artifact) simply omit the variable and
//! report `"unknown"` — never a build failure.

use std::process::Command;

fn main() {
    // Re-run when HEAD moves (or the branch it points at advances) so the
    // baked sha tracks commits, not just source edits.
    println!("cargo:rerun-if-changed=../.git/HEAD");
    println!("cargo:rerun-if-changed=../.git/refs");
    let sha = Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_default();
    if !sha.is_empty() {
        println!("cargo:rustc-env=FRENZY_GIT_SHA={sha}");
    }
}
