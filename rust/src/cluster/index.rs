//! Incremental capacity index: the scheduling hot path's answer store.
//!
//! The naive hot path answered every Stage-1 plan probe
//! (`idle_gpus_with_mem`) with a full node scan and every Stage-2 best-fit
//! step with a full scan *plus sort* — O(jobs × plans × nodes) per round,
//! which collapses at production scale (thousands of nodes). The
//! [`CapacityIndex`] is maintained incrementally by the
//! [`super::Orchestrator`] on every allocate/release/grow/shrink so the same
//! questions become logarithmic:
//!
//! * **Size classes**: the distinct GPU memory sizes present, ascending.
//!   Per-class idle-GPU totals live in a Fenwick tree, so
//!   `idle_with_mem(min_mem)` is a suffix sum in O(log S) where S is the
//!   number of classes (single digits in practice).
//! * **Idle buckets**: per class, a `BTreeMap<idle_count, BTreeSet<NodeId>>`
//!   of nodes with idle GPUs. Best-fit ("tightest node that covers the
//!   request") and greedy packing ("most-idle node") become O(log n) range
//!   lookups instead of scan-and-sort.
//!
//! Schedulers never mutate the index. A round plans against a
//! [`ClusterView`] (state + index) and layers *tentative* placements into a
//! [`CapacityOverlay`] — a sparse delta structure holding only the nodes
//! touched this round — so the round needs neither a cloned `ClusterState`
//! nor a cloned index. Overlay queries combine the immutable base index
//! with the deltas; cost is O(log n + touched) per query.
//!
//! Tie-breaking is bit-compatible with the reference implementation
//! (`Has::allocate_one`): the naive path sorts candidate nodes by idle
//! count with a stable sort over ascending node ids, so best-fit resolves
//! ties toward the *smallest* node id and most-idle toward the *largest* —
//! the overlay queries reproduce exactly that order, which is what lets the
//! differential tests demand byte-identical decisions.

use super::{ClusterState, Node, NodeId};
use std::collections::{BTreeMap, BTreeSet, HashMap};

/// Nodes holding idle GPUs, bucketed by idle count (0 is never stored).
pub type IdleBuckets = BTreeMap<u32, BTreeSet<NodeId>>;

/// Fenwick tree over size classes (indices are class numbers).
#[derive(Debug, Clone, PartialEq)]
struct Fenwick {
    tree: Vec<i64>,
}

impl Fenwick {
    fn new(n: usize) -> Self {
        Self { tree: vec![0; n + 1] }
    }

    fn add(&mut self, mut i: usize, delta: i64) {
        i += 1;
        while i < self.tree.len() {
            self.tree[i] += delta;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum of classes `[0, i)`.
    fn prefix(&self, mut i: usize) -> i64 {
        let mut s = 0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }

    fn total(&self) -> i64 {
        self.prefix(self.tree.len() - 1)
    }

    /// Sum of classes `[c0, S)`.
    fn suffix(&self, c0: usize) -> i64 {
        self.total() - self.prefix(c0.min(self.tree.len() - 1))
    }

    /// Value of a single class.
    fn at(&self, c: usize) -> i64 {
        self.prefix(c + 1) - self.prefix(c)
    }
}

/// The incrementally maintained capacity index. See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityIndex {
    /// Distinct GPU memory sizes present (bytes), ascending — the classes.
    sizes: Vec<u64>,
    /// Size class of every node id (retired nodes keep their class; they
    /// hold no idle GPUs, so they never surface in queries).
    node_class: Vec<usize>,
    /// Idle GPUs per class.
    idle: Fenwick,
    /// Count of nodes with idle > 0 per class.
    nonzero: Fenwick,
    /// Per class: idle count → nodes at that count.
    buckets: Vec<IdleBuckets>,
}

impl CapacityIndex {
    /// Build from scratch in O(n log n).
    pub fn build(state: &ClusterState) -> Self {
        let mut sizes: Vec<u64> = state.nodes.iter().map(|n| n.gpu.mem_bytes).collect();
        sizes.sort_unstable();
        sizes.dedup();
        let mut idx = Self {
            node_class: Vec::with_capacity(state.nodes.len()),
            idle: Fenwick::new(sizes.len()),
            nonzero: Fenwick::new(sizes.len()),
            buckets: vec![IdleBuckets::new(); sizes.len()],
            sizes,
        };
        for n in &state.nodes {
            let c = idx.sizes.binary_search(&n.gpu.mem_bytes).expect("size class exists");
            idx.node_class.push(c);
            if n.idle > 0 {
                idx.idle.add(c, n.idle as i64);
                idx.nonzero.add(c, 1);
                idx.buckets[c].entry(n.idle).or_default().insert(n.id);
            }
        }
        idx
    }

    pub fn n_classes(&self) -> usize {
        self.sizes.len()
    }

    /// First class whose GPU size is ≥ `min_mem` (== `n_classes()` when no
    /// class qualifies).
    pub fn class_for(&self, min_mem: u64) -> usize {
        self.sizes.partition_point(|&s| s < min_mem)
    }

    pub fn class_size(&self, c: usize) -> u64 {
        self.sizes[c]
    }

    pub fn class_of_node(&self, node: NodeId) -> usize {
        self.node_class[node]
    }

    /// Total idle GPUs on nodes whose memory is ≥ `min_mem` — the Stage-1
    /// plan probe, in O(log S).
    pub fn idle_with_mem(&self, min_mem: u64) -> u32 {
        self.idle.suffix(self.class_for(min_mem)) as u32
    }

    /// Total idle GPUs over classes `[c0, S)`.
    pub fn idle_suffix(&self, c0: usize) -> u32 {
        self.idle.suffix(c0) as u32
    }

    /// Number of nodes with idle > 0 over classes `[c0, S)`.
    pub fn nonzero_suffix(&self, c0: usize) -> u64 {
        self.nonzero.suffix(c0) as u64
    }

    /// Number of nodes with idle > 0 in class `c`.
    pub fn nonzero_in_class(&self, c: usize) -> u64 {
        self.nonzero.at(c) as u64
    }

    /// Idle buckets of class `c` (read access for overlay queries).
    pub fn bucket(&self, c: usize) -> &IdleBuckets {
        &self.buckets[c]
    }

    /// Move `node` from idle count `old` to `new`, updating buckets and
    /// per-class aggregates in O(log n).
    pub(crate) fn set_idle(&mut self, node: NodeId, old: u32, new: u32) {
        if old == new {
            return;
        }
        let c = self.node_class[node];
        self.idle.add(c, new as i64 - old as i64);
        if old > 0 {
            let bucket = self.buckets[c].get_mut(&old).expect("node indexed at old idle");
            bucket.remove(&node);
            if bucket.is_empty() {
                self.buckets[c].remove(&old);
            }
        }
        if new > 0 {
            self.buckets[c].entry(new).or_default().insert(node);
        }
        match (old > 0, new > 0) {
            (false, true) => self.nonzero.add(c, 1),
            (true, false) => self.nonzero.add(c, -1),
            _ => {}
        }
    }

    /// Register a freshly appended node. A node of a previously seen GPU
    /// size is O(log n); a *never-seen* size class is inserted incrementally
    /// via [`CapacityIndex::insert_class`] — O(n + S log S), no sort and no
    /// per-node re-bucketing — instead of the old full O(n log n) rebuild.
    pub(crate) fn on_grow(&mut self, node: &Node) {
        let c = match self.sizes.binary_search(&node.gpu.mem_bytes) {
            Ok(c) => c,
            Err(_) => self.insert_class(node.gpu.mem_bytes),
        };
        debug_assert_eq!(node.id, self.node_class.len(), "grow appends node ids");
        self.node_class.push(c);
        if node.idle > 0 {
            self.idle.add(c, node.idle as i64);
            self.nonzero.add(c, 1);
            self.buckets[c].entry(node.idle).or_default().insert(node.id);
        }
    }

    /// Splice a new (empty) size class into the index at its sorted
    /// position: existing classes at or above it shift up by one, every
    /// node's class id is bumped accordingly, and the two Fenwick trees are
    /// re-summed from the (already correct) per-class buckets. Costs
    /// O(nodes) for the class-id bump plus O(S log S) for the trees —
    /// cheaper than the old rebuild, which also re-sorted and re-bucketed
    /// every node. Returns the new class id.
    fn insert_class(&mut self, size: u64) -> usize {
        let p = self.sizes.partition_point(|&s| s < size);
        self.sizes.insert(p, size);
        self.buckets.insert(p, IdleBuckets::new());
        for c in &mut self.node_class {
            if *c >= p {
                *c += 1;
            }
        }
        let s = self.sizes.len();
        let mut idle = Fenwick::new(s);
        let mut nonzero = Fenwick::new(s);
        for (c, bucket) in self.buckets.iter().enumerate() {
            for (&count, nodes) in bucket {
                idle.add(c, count as i64 * nodes.len() as i64);
                nonzero.add(c, nodes.len() as i64);
            }
        }
        self.idle = idle;
        self.nonzero = nonzero;
        p
    }

    /// Invariant check used by tests and debug assertions: the incremental
    /// index must always agree with a fresh build from the state.
    pub fn check_against(&self, state: &ClusterState) -> bool {
        *self == Self::build(state)
    }
}

/// A scheduler's read-only window for one round: the authoritative cluster
/// state plus the capacity index and the set of nodes in graceful drain.
/// The engine hands out a borrowed view (no clones on the hot path); tests
/// and benches build an owned index from any standalone `ClusterState` via
/// [`ClusterView::build`].
///
/// Drain awareness: a `DrainRequested` node must not receive *new*
/// placements — its resident jobs are checkpointing off it. The engine
/// already strips a draining node's idle capacity, so on the live path the
/// draining set is belt-and-braces; but schedulers planning against
/// synthetic or stale views rely on it (see [`ClusterView::is_draining`]),
/// and [`ClusterView::overlay`] pre-excludes draining idle so every overlay
/// query is drain-aware with no per-scheduler code.
#[derive(Debug)]
pub struct ClusterView<'a> {
    state: &'a ClusterState,
    index: std::borrow::Cow<'a, CapacityIndex>,
    draining: std::borrow::Cow<'a, BTreeSet<NodeId>>,
}

impl<'a> ClusterView<'a> {
    /// Build an owned index for a standalone state (tests/benches).
    pub fn build(state: &'a ClusterState) -> Self {
        Self {
            state,
            index: std::borrow::Cow::Owned(CapacityIndex::build(state)),
            draining: std::borrow::Cow::Owned(BTreeSet::new()),
        }
    }

    /// Borrow an index maintained elsewhere (the orchestrator's). The
    /// index-matches-state invariant is asserted by `Orchestrator::
    /// check_index` in tests and the churn property test — not here, which
    /// sits on the per-round hot path even in debug builds.
    pub fn with_index(state: &'a ClusterState, index: &'a CapacityIndex) -> Self {
        Self {
            state,
            index: std::borrow::Cow::Borrowed(index),
            draining: std::borrow::Cow::Owned(BTreeSet::new()),
        }
    }

    /// Borrow index *and* draining set (what [`super::Orchestrator::view`]
    /// hands the engine).
    pub fn with_index_draining(
        state: &'a ClusterState,
        index: &'a CapacityIndex,
        draining: &'a BTreeSet<NodeId>,
    ) -> Self {
        Self {
            state,
            index: std::borrow::Cow::Borrowed(index),
            draining: std::borrow::Cow::Borrowed(draining),
        }
    }

    /// Builder for tests: mark nodes as draining on an owned view.
    pub fn with_draining(mut self, draining: BTreeSet<NodeId>) -> Self {
        self.draining = std::borrow::Cow::Owned(draining);
        self
    }

    pub fn state(&self) -> &'a ClusterState {
        self.state
    }

    pub fn index(&self) -> &CapacityIndex {
        &self.index
    }

    /// True when `node` is in graceful drain — schedulers must not place
    /// new jobs on it.
    pub fn is_draining(&self, node: NodeId) -> bool {
        self.draining.contains(&node)
    }

    /// Nodes currently in graceful drain, ascending.
    pub fn draining(&self) -> &BTreeSet<NodeId> {
        &self.draining
    }

    /// Stage-1 plan probe, O(log S + draining): idle GPUs with memory ≥
    /// `min_mem`, excluding capacity stranded on draining nodes.
    pub fn idle_gpus_with_mem(&self, min_mem: u64) -> u32 {
        let mut idle = self.index.idle_with_mem(min_mem);
        for &n in self.draining.iter() {
            let node = &self.state.nodes[n];
            if node.gpu.mem_bytes >= min_mem {
                idle = idle.saturating_sub(node.idle);
            }
        }
        idle
    }

    /// Start a tentative-placement overlay for one scheduling round, with
    /// draining nodes' idle capacity pre-taken so best-fit/most-idle/probe
    /// queries never surface them.
    pub fn overlay(&self) -> CapacityOverlay<'_> {
        let mut ov = CapacityOverlay::new(self.state, self.index());
        for &n in self.draining.iter() {
            let idle = self.state.nodes[n].idle;
            if idle > 0 {
                ov.take(n, idle);
            }
        }
        ov
    }
}

/// Tentative per-round deltas over a [`CapacityIndex`]. Holds only the
/// nodes touched this round; queries combine the base index with the
/// deltas, so a round never clones cluster-sized structures.
#[derive(Debug)]
pub struct CapacityOverlay<'a> {
    state: &'a ClusterState,
    index: &'a CapacityIndex,
    /// GPUs tentatively taken per node this round.
    taken: HashMap<NodeId, u32>,
    /// Touched nodes re-bucketed at their *overlay* idle count, per class.
    touched: Vec<IdleBuckets>,
    /// Idle GPUs taken per class.
    idle_delta: Vec<u64>,
    /// Touched nodes driven to overlay idle 0, per class (they still count
    /// in the base `nonzero` aggregate and must be subtracted).
    zeroed: Vec<u64>,
}

impl<'a> CapacityOverlay<'a> {
    fn new(state: &'a ClusterState, index: &'a CapacityIndex) -> Self {
        let s = index.n_classes();
        Self {
            state,
            index,
            taken: HashMap::new(),
            touched: vec![IdleBuckets::new(); s],
            idle_delta: vec![0; s],
            zeroed: vec![0; s],
        }
    }

    /// Effective idle GPUs of a node under the overlay.
    pub fn idle_of(&self, node: NodeId) -> u32 {
        self.state.nodes[node].idle - self.taken.get(&node).copied().unwrap_or(0)
    }

    /// Stage-1 probe: idle GPUs with memory ≥ `min_mem`, overlay-adjusted.
    pub fn idle_with_mem(&self, min_mem: u64) -> u32 {
        let c0 = self.index.class_for(min_mem);
        let delta: u64 = self.idle_delta[c0..].iter().sum();
        self.index.idle_suffix(c0) - delta as u32
    }

    /// Nodes with overlay idle > 0 over classes `[c0, S)` — the size the
    /// naive path's candidate list (`NLst`) would have. Used for
    /// work-unit parity with the reference implementation.
    pub fn avail_nodes(&self, c0: usize) -> u64 {
        let z: u64 = self.zeroed[c0..].iter().sum();
        self.index.nonzero_suffix(c0) - z
    }

    /// Algorithm 1's fit size: the smallest class ≥ `req_sz` that still has
    /// a node with idle GPUs.
    pub fn fit_class(&self, req_sz: u64) -> Option<usize> {
        let c0 = self.index.class_for(req_sz);
        (c0..self.index.n_classes())
            .find(|&c| self.index.nonzero_in_class(c) > self.zeroed[c])
    }

    /// Best-fit: among nodes of classes `[c0, S)` with overlay idle ≥ `req`,
    /// the one with the fewest idle GPUs (ties → smallest node id).
    /// Returns `(node, overlay idle)`.
    pub fn best_fit(&self, c0: usize, req: u32) -> Option<(NodeId, u32)> {
        let mut best: Option<(u32, NodeId)> = None;
        for c in c0..self.index.n_classes() {
            if let Some((&idle, set)) = self.touched[c].range(req..).next() {
                let id = *set.iter().next().expect("non-empty overlay bucket");
                if best.is_none_or(|b| (idle, id) < b) {
                    best = Some((idle, id));
                }
            }
            'base: for (&idle, set) in self.index.bucket(c).range(req..) {
                if let Some(b) = best {
                    if idle > b.0 {
                        break 'base;
                    }
                }
                for &id in set {
                    if self.taken.contains_key(&id) {
                        continue; // its overlay position is in `touched`
                    }
                    if best.is_none_or(|b| (idle, id) < b) {
                        best = Some((idle, id));
                    }
                    break 'base;
                }
            }
        }
        best.map(|(idle, id)| (id, idle))
    }

    /// Greedy packing step: the node with the most overlay-idle GPUs among
    /// classes `[c0, S)` (ties → largest node id).
    pub fn most_idle(&self, c0: usize) -> Option<(NodeId, u32)> {
        let mut best: Option<(u32, NodeId)> = None;
        for c in c0..self.index.n_classes() {
            if let Some((&idle, set)) = self.touched[c].iter().next_back() {
                let id = *set.iter().next_back().expect("non-empty overlay bucket");
                if best.is_none_or(|b| (idle, id) > b) {
                    best = Some((idle, id));
                }
            }
            'base: for (&idle, set) in self.index.bucket(c).iter().rev() {
                if let Some(b) = best {
                    if idle < b.0 {
                        break 'base;
                    }
                }
                for &id in set.iter().rev() {
                    if self.taken.contains_key(&id) {
                        continue;
                    }
                    if best.is_none_or(|b| (idle, id) > b) {
                        best = Some((idle, id));
                    }
                    break 'base;
                }
            }
        }
        best.map(|(idle, id)| (id, idle))
    }

    /// Tentatively take `count` GPUs from `node`.
    pub fn take(&mut self, node: NodeId, count: u32) {
        if count == 0 {
            return;
        }
        let c = self.index.class_of_node(node);
        let base = self.state.nodes[node].idle;
        let prev = self.taken.get(&node).copied().unwrap_or(0);
        let old_ov = base - prev;
        debug_assert!(count <= old_ov, "overlay overdraw on node {node}");
        let new_ov = old_ov - count;
        if prev > 0 {
            if let Some(b) = self.touched[c].get_mut(&old_ov) {
                b.remove(&node);
                if b.is_empty() {
                    self.touched[c].remove(&old_ov);
                }
            }
        }
        if new_ov > 0 {
            self.touched[c].entry(new_ov).or_default().insert(node);
        } else {
            self.zeroed[c] += 1;
        }
        self.idle_delta[c] += count as u64;
        self.taken.insert(node, prev + count);
    }

    /// Roll back a tentative take (packing that failed mid-way).
    pub fn untake(&mut self, node: NodeId, count: u32) {
        if count == 0 {
            return;
        }
        let c = self.index.class_of_node(node);
        let base = self.state.nodes[node].idle;
        let prev = self.taken.get(&node).copied().unwrap_or(0);
        debug_assert!(count <= prev, "untake exceeds taken on node {node}");
        let old_ov = base - prev;
        let new_taken = prev - count;
        let new_ov = base - new_taken;
        if old_ov > 0 {
            if let Some(b) = self.touched[c].get_mut(&old_ov) {
                b.remove(&node);
                if b.is_empty() {
                    self.touched[c].remove(&old_ov);
                }
            }
        } else {
            self.zeroed[c] -= 1;
        }
        if new_taken > 0 {
            self.touched[c].entry(new_ov).or_default().insert(node);
            self.taken.insert(node, new_taken);
        } else {
            self.taken.remove(&node);
        }
        self.idle_delta[c] -= count as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{real_testbed, sia_sim, GIB};

    fn state() -> ClusterState {
        ClusterState::from_spec(&real_testbed())
    }

    #[test]
    fn build_matches_naive_suffix_sums() {
        let s = state();
        let idx = CapacityIndex::build(&s);
        for mem in [1, 11 * GIB, 24 * GIB, 40 * GIB, 41 * GIB, 80 * GIB, 81 * GIB] {
            assert_eq!(idx.idle_with_mem(mem), s.idle_gpus_with_mem(mem), "mem={mem}");
        }
        assert!(idx.check_against(&s));
    }

    #[test]
    fn set_idle_keeps_index_consistent() {
        let mut s = state();
        let mut idx = CapacityIndex::build(&s);
        // Take 3 of the 4 A800 GPUs on node 2.
        s.nodes[2].idle = 1;
        idx.set_idle(2, 4, 1);
        assert!(idx.check_against(&s));
        assert_eq!(idx.idle_with_mem(80 * GIB), 5);
        // And back.
        s.nodes[2].idle = 4;
        idx.set_idle(2, 1, 4);
        assert!(idx.check_against(&s));
    }

    #[test]
    fn set_idle_to_zero_updates_nonzero_counts() {
        let mut s = state();
        let mut idx = CapacityIndex::build(&s);
        let c = idx.class_of_node(0);
        let before = idx.nonzero_in_class(c);
        s.nodes[0].idle = 0;
        idx.set_idle(0, 2, 0);
        assert_eq!(idx.nonzero_in_class(c), before - 1);
        assert!(idx.check_against(&s));
    }

    #[test]
    fn grow_existing_class_is_incremental() {
        let mut s = state();
        let mut idx = CapacityIndex::build(&s);
        let spec = crate::config::NodeSpec {
            gpu: crate::config::gpu_by_name("A100-80G").unwrap(),
            count: 4,
            link: crate::config::LinkKind::NvLink,
        };
        let id = s.add_node(&spec);
        idx.on_grow(&s.nodes[id]);
        assert!(idx.check_against(&s));
    }

    #[test]
    fn grow_new_class_inserts_incrementally() {
        // A never-seen GPU size (11G, below every existing class) must be
        // spliced in without a rebuild: the incremental index equals a
        // fresh build and answers suffix queries across the new boundary.
        let mut s = state();
        let mut idx = CapacityIndex::build(&s);
        let spec = crate::config::NodeSpec {
            gpu: crate::config::gpu_by_name("RTX2080Ti").unwrap(), // 11G: new class
            count: 8,
            link: crate::config::LinkKind::Pcie,
        };
        let id = s.add_node(&spec);
        idx.on_grow(&s.nodes[id]);
        assert!(idx.check_against(&s));
        assert_eq!(idx.idle_with_mem(11 * GIB), 19);
        assert_eq!(idx.idle_with_mem(40 * GIB), 11);
        // A middle class (24G) shifts ids of the classes above it.
        let spec = crate::config::NodeSpec {
            gpu: crate::config::gpu_by_name("RTX6000").unwrap(), // 24G
            count: 4,
            link: crate::config::LinkKind::Pcie,
        };
        let id = s.add_node(&spec);
        idx.on_grow(&s.nodes[id]);
        assert!(idx.check_against(&s));
        assert_eq!(idx.idle_with_mem(24 * GIB), 15);
        assert_eq!(idx.idle_with_mem(80 * GIB), 8);
    }

    #[test]
    fn overlay_take_untake_roundtrip() {
        let s = state();
        let idx = CapacityIndex::build(&s);
        let view = ClusterView::with_index(&s, &idx);
        let mut ov = view.overlay();
        let before = ov.idle_with_mem(40 * GIB);
        ov.take(2, 4); // empty the A800 node
        assert_eq!(ov.idle_with_mem(40 * GIB), before - 4);
        assert_eq!(ov.idle_of(2), 0);
        ov.untake(2, 4);
        assert_eq!(ov.idle_with_mem(40 * GIB), before);
        assert_eq!(ov.idle_of(2), 4);
        // Partial take lands the node in an overlay bucket.
        ov.take(2, 1);
        assert_eq!(ov.idle_of(2), 3);
        assert_eq!(ov.best_fit(0, 3), Some((2, 3)));
    }

    #[test]
    fn overlay_best_fit_matches_reference_order() {
        // real testbed idle: node0=2 (40G), node1=1 (40G), node2=4 (80G),
        // node3=2 (80G), node4=2 (80G).
        let s = state();
        let view = ClusterView::build(&s);
        let ov = view.overlay();
        // Request 1 GPU of ≥40G: tightest is node 1 (idle 1).
        assert_eq!(ov.best_fit(0, 1), Some((1, 1)));
        // Request 2: nodes 0, 3, 4 tie at idle 2 → smallest id (0).
        assert_eq!(ov.best_fit(0, 2), Some((0, 2)));
        // Request 3+: only node 2 covers it.
        assert_eq!(ov.best_fit(0, 3), Some((2, 4)));
        assert_eq!(ov.best_fit(0, 5), None);
        // Most idle is node 2; after taking it, ties at 2 resolve to the
        // LARGEST id (4), matching the naive stable sort's `.last()`.
        assert_eq!(ov.most_idle(0), Some((2, 4)));
        let mut ov = view.overlay();
        ov.take(2, 4);
        assert_eq!(ov.most_idle(0), Some((4, 2)));
    }

    #[test]
    fn overlay_fit_class_skips_drained_classes() {
        let s = ClusterState::from_spec(&sia_sim());
        let view = ClusterView::build(&s);
        let mut ov = view.overlay();
        // Drain the 24G class (node 5: 4×RTX6000).
        ov.take(5, 4);
        let c = ov.fit_class(12 * GIB).expect("40G class remains");
        assert_eq!(view.index().class_size(c), 40 * GIB);
        // 11G requests still fit the 2080Ti class.
        let c = ov.fit_class(1).expect("11G class");
        assert_eq!(view.index().class_size(c), 11 * GIB);
    }

    #[test]
    fn draining_nodes_hidden_from_view_queries() {
        // Mark node 2 (4×A800, the most-idle node) as draining while it
        // still shows idle capacity — the stale-view case schedulers must
        // survive.
        let s = state();
        let view = ClusterView::build(&s).with_draining([2].into_iter().collect());
        assert!(view.is_draining(2));
        assert!(!view.is_draining(0));
        assert_eq!(view.idle_gpus_with_mem(80 * GIB), 4, "node 2's 4 GPUs are hidden");
        let ov = view.overlay();
        assert_eq!(ov.idle_of(2), 0, "overlay pre-takes draining idle");
        assert_eq!(ov.most_idle(0), Some((4, 2)), "not the draining node");
        assert_eq!(ov.best_fit(0, 3), None, "only the draining node could cover 3");
        // An undrained view still sees it.
        let plain = ClusterView::build(&s);
        assert_eq!(plain.overlay().most_idle(0), Some((2, 4)));
    }

    #[test]
    fn fenwick_sums() {
        let mut f = Fenwick::new(4);
        f.add(0, 3);
        f.add(2, 5);
        f.add(3, 1);
        assert_eq!(f.total(), 9);
        assert_eq!(f.prefix(2), 3);
        assert_eq!(f.suffix(2), 6);
        assert_eq!(f.at(2), 5);
        f.add(2, -5);
        assert_eq!(f.suffix(2), 1);
    }
}
