//! Runtime cluster state and the Resource Orchestrator (§IV, third
//! component): tracks idle GPUs per node, executes allocations and releases,
//! and maintains the job→resources ledger.

use crate::config::{ClusterSpec, GpuSpec, LinkKind};
use crate::job::JobId;
use std::collections::BTreeMap;

/// Node identifier (index into the cluster's node list).
pub type NodeId = usize;

/// Mutable per-node state.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub gpu: GpuSpec,
    pub total: u32,
    pub idle: u32,
    pub link: LinkKind,
}

impl Node {
    pub fn used(&self) -> u32 {
        self.total - self.idle
    }
}

/// One job's placement: GPUs taken per node.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub job: JobId,
    pub parts: Vec<(NodeId, u32)>,
}

impl Allocation {
    pub fn total_gpus(&self) -> u32 {
        self.parts.iter().map(|(_, n)| n).sum()
    }

    pub fn node_count(&self) -> usize {
        self.parts.len()
    }

    pub fn is_single_node(&self) -> bool {
        self.parts.len() == 1
    }
}

/// Errors the orchestrator can raise.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterError {
    /// Requested more GPUs than a node has idle.
    InsufficientIdle { node: NodeId, requested: u32, idle: u32 },
    /// Unknown node id.
    NoSuchNode(NodeId),
    /// Job already holds an allocation.
    AlreadyAllocated(JobId),
    /// Job holds no allocation.
    NotAllocated(JobId),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::InsufficientIdle { node, requested, idle } => {
                write!(f, "node {node}: requested {requested} GPUs but only {idle} idle")
            }
            ClusterError::NoSuchNode(n) => write!(f, "no such node {n}"),
            ClusterError::AlreadyAllocated(j) => write!(f, "job {j} already allocated"),
            ClusterError::NotAllocated(j) => write!(f, "job {j} not allocated"),
        }
    }
}

impl std::error::Error for ClusterError {}

/// Live cluster state: nodes with idle counts.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterState {
    pub nodes: Vec<Node>,
    /// Cross-node bandwidth, forwarded from the spec.
    pub inter_node_gbps: f64,
}

impl ClusterState {
    pub fn from_spec(spec: &ClusterSpec) -> Self {
        let nodes = spec
            .nodes
            .iter()
            .enumerate()
            .map(|(id, n)| Node {
                id,
                gpu: n.gpu.clone(),
                total: n.count,
                idle: n.count,
                link: n.link,
            })
            .collect();
        Self { nodes, inter_node_gbps: spec.inter_node_gbps }
    }

    pub fn total_gpus(&self) -> u32 {
        self.nodes.iter().map(|n| n.total).sum()
    }

    pub fn idle_gpus(&self) -> u32 {
        self.nodes.iter().map(|n| n.idle).sum()
    }

    /// Idle GPUs whose memory is at least `min_mem`.
    pub fn idle_gpus_with_mem(&self, min_mem: u64) -> u32 {
        self.nodes.iter().filter(|n| n.gpu.mem_bytes >= min_mem).map(|n| n.idle).sum()
    }

    /// Overall utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        let total = self.total_gpus();
        if total == 0 {
            0.0
        } else {
            1.0 - self.idle_gpus() as f64 / total as f64
        }
    }

    /// Fragmentation metric: 1 − (largest idle block / total idle). High
    /// values mean idle GPUs are scattered across nodes.
    pub fn fragmentation(&self) -> f64 {
        let idle = self.idle_gpus();
        if idle == 0 {
            return 0.0;
        }
        let largest = self.nodes.iter().map(|n| n.idle).max().unwrap_or(0);
        1.0 - largest as f64 / idle as f64
    }

    fn take(&mut self, node: NodeId, count: u32) -> Result<(), ClusterError> {
        let n = self.nodes.get_mut(node).ok_or(ClusterError::NoSuchNode(node))?;
        if n.idle < count {
            return Err(ClusterError::InsufficientIdle { node, requested: count, idle: n.idle });
        }
        n.idle -= count;
        Ok(())
    }

    fn give(&mut self, node: NodeId, count: u32) -> Result<(), ClusterError> {
        let n = self.nodes.get_mut(node).ok_or(ClusterError::NoSuchNode(node))?;
        n.idle = (n.idle + count).min(n.total);
        Ok(())
    }
}

/// The Resource Orchestrator: authoritative allocate/release with a ledger.
#[derive(Debug, Clone)]
pub struct Orchestrator {
    state: ClusterState,
    ledger: BTreeMap<JobId, Allocation>,
}

impl Orchestrator {
    pub fn new(spec: &ClusterSpec) -> Self {
        Self { state: ClusterState::from_spec(spec), ledger: BTreeMap::new() }
    }

    pub fn state(&self) -> &ClusterState {
        &self.state
    }

    /// Snapshot for a scheduler to plan against (schedulers never mutate the
    /// authoritative state directly).
    pub fn snapshot(&self) -> ClusterState {
        self.state.clone()
    }

    pub fn allocation_of(&self, job: JobId) -> Option<&Allocation> {
        self.ledger.get(&job)
    }

    pub fn running_jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.ledger.keys().copied()
    }

    /// Atomically apply an allocation: either every part is taken or none.
    pub fn allocate(&mut self, alloc: Allocation) -> Result<(), ClusterError> {
        if self.ledger.contains_key(&alloc.job) {
            return Err(ClusterError::AlreadyAllocated(alloc.job));
        }
        // Validate first against a scratch copy (atomicity).
        let mut scratch = self.state.clone();
        for &(node, count) in &alloc.parts {
            scratch.take(node, count)?;
        }
        self.state = scratch;
        self.ledger.insert(alloc.job, alloc);
        Ok(())
    }

    /// Release a job's resources.
    pub fn release(&mut self, job: JobId) -> Result<Allocation, ClusterError> {
        let alloc = self.ledger.remove(&job).ok_or(ClusterError::NotAllocated(job))?;
        for &(node, count) in &alloc.parts {
            self.state.give(node, count).expect("ledger references valid nodes");
        }
        Ok(alloc)
    }

    /// Invariant check used by tests: ledger totals + idle == totals.
    pub fn check_conservation(&self) -> bool {
        let mut used = vec![0u32; self.state.nodes.len()];
        for alloc in self.ledger.values() {
            for &(node, count) in &alloc.parts {
                if node >= used.len() {
                    return false;
                }
                used[node] += count;
            }
        }
        self.state
            .nodes
            .iter()
            .all(|n| n.idle + used[n.id] == n.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{real_testbed, GIB};

    #[test]
    fn from_spec_counts() {
        let s = ClusterState::from_spec(&real_testbed());
        assert_eq!(s.total_gpus(), 11);
        assert_eq!(s.idle_gpus(), 11);
        assert_eq!(s.utilization(), 0.0);
    }

    #[test]
    fn allocate_release_roundtrip() {
        let mut o = Orchestrator::new(&real_testbed());
        let alloc = Allocation { job: 1, parts: vec![(2, 4)] }; // the A800 node
        o.allocate(alloc.clone()).unwrap();
        assert_eq!(o.state().idle_gpus(), 7);
        assert_eq!(o.allocation_of(1), Some(&alloc));
        assert!(o.check_conservation());
        let released = o.release(1).unwrap();
        assert_eq!(released, alloc);
        assert_eq!(o.state().idle_gpus(), 11);
        assert!(o.check_conservation());
    }

    #[test]
    fn allocation_is_atomic() {
        let mut o = Orchestrator::new(&real_testbed());
        // Part 1 is fine (node 0 has 2), part 2 overdraws node 1 (has 1).
        let bad = Allocation { job: 9, parts: vec![(0, 2), (1, 3)] };
        let err = o.allocate(bad).unwrap_err();
        assert!(matches!(err, ClusterError::InsufficientIdle { node: 1, .. }));
        // Nothing must have been taken.
        assert_eq!(o.state().idle_gpus(), 11);
        assert!(o.check_conservation());
    }

    #[test]
    fn double_allocate_rejected() {
        let mut o = Orchestrator::new(&real_testbed());
        o.allocate(Allocation { job: 1, parts: vec![(0, 1)] }).unwrap();
        let err = o.allocate(Allocation { job: 1, parts: vec![(1, 1)] }).unwrap_err();
        assert_eq!(err, ClusterError::AlreadyAllocated(1));
    }

    #[test]
    fn release_unknown_job() {
        let mut o = Orchestrator::new(&real_testbed());
        assert_eq!(o.release(42).unwrap_err(), ClusterError::NotAllocated(42));
    }

    #[test]
    fn idle_with_mem_filter() {
        let s = ClusterState::from_spec(&real_testbed());
        // 80G GPUs: 4 (A800) + 2 + 2 = 8
        assert_eq!(s.idle_gpus_with_mem(80 * GIB), 8);
        assert_eq!(s.idle_gpus_with_mem(40 * GIB), 11);
        assert_eq!(s.idle_gpus_with_mem(81 * GIB), 0);
    }

    #[test]
    fn fragmentation_metric() {
        let mut s = ClusterState::from_spec(&real_testbed());
        assert!(s.fragmentation() > 0.0); // idle spread across 5 nodes
        // Empty the cluster -> fragmentation defined as 0.
        for n in &mut s.nodes {
            n.idle = 0;
        }
        assert_eq!(s.fragmentation(), 0.0);
    }
}
