//! Runtime cluster state and the Resource Orchestrator (§IV, third
//! component): tracks idle GPUs per node, executes allocations and releases,
//! and maintains the job→resources ledger.
//!
//! The orchestrator also maintains the [`CapacityIndex`] incrementally on
//! every take/give/grow/shrink, so scheduling rounds answer capacity
//! questions in logarithmic time instead of scanning the node list — see
//! [`index`] for the design. A [`DeviceMemory`] byte ledger sits beside the
//! index: the engine charges every dispatch's observed per-GPU peak bytes
//! through [`Orchestrator::charge_memory`], and [`Orchestrator::release`]
//! frees GPUs *and* bytes atomically so the two ledgers cannot diverge.
//!
//! Node retirement comes in two flavors: [`Orchestrator::shrink`] is the
//! instant preemption path (every hosted job released immediately), while
//! [`Orchestrator::retire_begin`] / [`Orchestrator::reap_retiring`]
//! implement graceful drain — the node stops accepting placements (idle
//! capacity stripped), hosted jobs keep their GPUs until they checkpoint
//! and release, and each release is reaped from the retiring node until its
//! capacity reaches zero.

pub mod index;

pub use index::{CapacityIndex, CapacityOverlay, ClusterView, IdleBuckets};

use crate::config::{ClusterSpec, GpuSpec, LinkKind, NodeSpec};
use crate::job::JobId;
use crate::runtime::device::{DeviceMemory, DeviceOom};
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};

/// Node identifier (index into the cluster's node list).
pub type NodeId = usize;

/// Mutable per-node state.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub gpu: GpuSpec,
    pub total: u32,
    pub idle: u32,
    pub link: LinkKind,
}

impl Node {
    pub fn used(&self) -> u32 {
        self.total - self.idle
    }
}

/// One job's placement: GPUs taken per node.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub job: JobId,
    pub parts: Vec<(NodeId, u32)>,
}

impl Allocation {
    pub fn total_gpus(&self) -> u32 {
        self.parts.iter().map(|(_, n)| n).sum()
    }

    pub fn node_count(&self) -> usize {
        self.parts.len()
    }

    pub fn is_single_node(&self) -> bool {
        self.parts.len() == 1
    }
}

/// Errors the orchestrator can raise.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterError {
    /// Requested more GPUs than a node has idle.
    InsufficientIdle { node: NodeId, requested: u32, idle: u32 },
    /// Unknown node id.
    NoSuchNode(NodeId),
    /// Job already holds an allocation.
    AlreadyAllocated(JobId),
    /// Job holds no allocation.
    NotAllocated(JobId),
    /// A device-memory charge exceeded a node's per-GPU capacity — a real
    /// out-of-memory, carrying the observed bytes.
    MemoryExceeded { node: NodeId, observed_bytes: u64, capacity_bytes: u64 },
    /// The node exists but is already in graceful drain — a second
    /// retirement must not reset its jobs' deadlines.
    AlreadyDraining(NodeId),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::InsufficientIdle { node, requested, idle } => {
                write!(f, "node {node}: requested {requested} GPUs but only {idle} idle")
            }
            ClusterError::NoSuchNode(n) => write!(f, "no such node {n}"),
            ClusterError::AlreadyAllocated(j) => write!(f, "job {j} already allocated"),
            ClusterError::NotAllocated(j) => write!(f, "job {j} not allocated"),
            ClusterError::MemoryExceeded { node, observed_bytes, capacity_bytes } => write!(
                f,
                "node {node}: observed {observed_bytes} bytes/GPU exceeds capacity \
                 {capacity_bytes}"
            ),
            ClusterError::AlreadyDraining(n) => write!(f, "node {n} is already draining"),
        }
    }
}

impl std::error::Error for ClusterError {}

/// Live cluster state: nodes with idle counts.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterState {
    pub nodes: Vec<Node>,
    /// Cross-node bandwidth, forwarded from the spec.
    pub inter_node_gbps: f64,
}

impl ClusterState {
    pub fn from_spec(spec: &ClusterSpec) -> Self {
        let nodes = spec
            .nodes
            .iter()
            .enumerate()
            .map(|(id, n)| Node {
                id,
                gpu: n.gpu.clone(),
                total: n.count,
                idle: n.count,
                link: n.link,
            })
            .collect();
        Self { nodes, inter_node_gbps: spec.inter_node_gbps }
    }

    pub fn total_gpus(&self) -> u32 {
        self.nodes.iter().map(|n| n.total).sum()
    }

    pub fn idle_gpus(&self) -> u32 {
        self.nodes.iter().map(|n| n.idle).sum()
    }

    /// Idle GPUs whose memory is at least `min_mem`.
    pub fn idle_gpus_with_mem(&self, min_mem: u64) -> u32 {
        self.nodes.iter().filter(|n| n.gpu.mem_bytes >= min_mem).map(|n| n.idle).sum()
    }

    /// Overall utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        let total = self.total_gpus();
        if total == 0 {
            0.0
        } else {
            1.0 - self.idle_gpus() as f64 / total as f64
        }
    }

    /// Fragmentation metric: 1 − (largest idle block / total idle). High
    /// values mean idle GPUs are scattered across nodes.
    pub fn fragmentation(&self) -> f64 {
        let idle = self.idle_gpus();
        if idle == 0 {
            return 0.0;
        }
        let largest = self.nodes.iter().map(|n| n.idle).max().unwrap_or(0);
        1.0 - largest as f64 / idle as f64
    }

    /// Append a node (elastic NodeJoin); returns its id. Node ids are
    /// stable for the lifetime of the cluster: a removed node is *retired*
    /// in place (`total = 0`) rather than spliced out, so ids held by
    /// allocations and decision logs never shift.
    pub fn add_node(&mut self, spec: &NodeSpec) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            gpu: spec.gpu.clone(),
            total: spec.count,
            idle: spec.count,
            link: spec.link,
        });
        id
    }

    /// Nodes still part of the cluster (not retired by a NodeLeave).
    pub fn active_nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| n.total > 0)
    }

    /// Derive a [`ClusterSpec`] from the current (possibly scaled)
    /// topology, skipping retired nodes — used to rebuild MARP and other
    /// derived scheduler state after elasticity events.
    pub fn to_spec(&self, name: &str) -> ClusterSpec {
        ClusterSpec {
            name: name.to_string(),
            nodes: self
                .active_nodes()
                .map(|n| NodeSpec { gpu: n.gpu.clone(), count: n.total, link: n.link })
                .collect(),
            inter_node_gbps: self.inter_node_gbps,
        }
    }
}

/// The Resource Orchestrator: authoritative allocate/release with a ledger
/// and an incrementally maintained [`CapacityIndex`].
#[derive(Debug, Clone)]
pub struct Orchestrator {
    state: ClusterState,
    ledger: BTreeMap<JobId, Allocation>,
    index: CapacityIndex,
    /// Device-memory byte ledger, maintained beside the GPU-count ledger.
    device: DeviceMemory,
    /// Nodes in graceful drain: no idle capacity, hosted jobs still
    /// resident; fully retired (total = 0) once the last job releases.
    retiring: BTreeSet<NodeId>,
    /// Nodes fenced off by the crash-flap quarantine: their capacity stays
    /// in the cluster (idle) but placement must not touch them until
    /// probation lifts the quarantine.
    quarantined: BTreeSet<NodeId>,
    /// Derived: `retiring ∪ quarantined` — the set [`Orchestrator::view`]
    /// hides from placement. Maintained on every transition of either
    /// source set so the hot path borrows one set instead of building a
    /// union per round.
    excluded: BTreeSet<NodeId>,
}

impl Orchestrator {
    pub fn new(spec: &ClusterSpec) -> Self {
        let state = ClusterState::from_spec(spec);
        let index = CapacityIndex::build(&state);
        let device = DeviceMemory::new(state.nodes.iter().map(|n| n.gpu.mem_bytes).collect());
        Self {
            state,
            ledger: BTreeMap::new(),
            index,
            device,
            retiring: BTreeSet::new(),
            quarantined: BTreeSet::new(),
            excluded: BTreeSet::new(),
        }
    }

    pub fn state(&self) -> &ClusterState {
        &self.state
    }

    /// The incrementally maintained capacity index.
    pub fn index(&self) -> &CapacityIndex {
        &self.index
    }

    /// Zero-copy planning window for a scheduling round: the live state plus
    /// the maintained index and the excluded-node set (nodes in graceful
    /// drain *or* crash quarantine). This is what the engine hands to
    /// schedulers — rounds no longer clone the cluster, and schedulers skip
    /// nodes that must not receive placements.
    pub fn view(&self) -> ClusterView<'_> {
        ClusterView::with_index_draining(&self.state, &self.index, &self.excluded)
    }

    /// Owned snapshot (kept for tests and offline analysis; the scheduling
    /// hot path uses [`Orchestrator::view`] instead).
    pub fn snapshot(&self) -> ClusterState {
        self.state.clone()
    }

    /// Test hook: the incremental index must always agree with a fresh
    /// build from the state.
    pub fn check_index(&self) -> bool {
        self.index.check_against(&self.state)
    }

    pub fn allocation_of(&self, job: JobId) -> Option<&Allocation> {
        self.ledger.get(&job)
    }

    pub fn running_jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.ledger.keys().copied()
    }

    /// Atomically apply an allocation: either every part is taken or none.
    /// Validation aggregates per node first (so duplicate node entries in
    /// `parts` cannot overdraw) and applies only after every part checks
    /// out — no cluster-sized scratch clone on the dispatch hot path.
    pub fn allocate(&mut self, alloc: Allocation) -> Result<(), ClusterError> {
        if self.ledger.contains_key(&alloc.job) {
            return Err(ClusterError::AlreadyAllocated(alloc.job));
        }
        let mut agg: Vec<(NodeId, u32)> = Vec::with_capacity(alloc.parts.len());
        for &(node, count) in &alloc.parts {
            match agg.iter_mut().find(|(n, _)| *n == node) {
                Some((_, c)) => *c += count,
                None => agg.push((node, count)),
            }
        }
        for &(node, want) in &agg {
            let n = self.state.nodes.get(node).ok_or(ClusterError::NoSuchNode(node))?;
            if n.idle < want {
                return Err(ClusterError::InsufficientIdle {
                    node,
                    requested: want,
                    idle: n.idle,
                });
            }
        }
        for &(node, want) in &agg {
            let old = self.state.nodes[node].idle;
            self.state.nodes[node].idle = old - want;
            self.index.set_idle(node, old, old - want);
        }
        self.ledger.insert(alloc.job, alloc);
        Ok(())
    }

    /// Release a job's resources — GPUs and any device-memory charge,
    /// atomically (the byte ledger cannot outlive the GPU allocation).
    pub fn release(&mut self, job: JobId) -> Result<Allocation, ClusterError> {
        let alloc = self.ledger.remove(&job).ok_or(ClusterError::NotAllocated(job))?;
        for &(node, count) in &alloc.parts {
            let (old, new) = {
                let n =
                    self.state.nodes.get_mut(node).expect("ledger references valid nodes");
                let old = n.idle;
                n.idle = (old + count).min(n.total);
                (old, n.idle)
            };
            self.index.set_idle(node, old, new);
        }
        let _ = self.device.release(job);
        Ok(alloc)
    }

    /// Charge a job's observed per-GPU peak bytes against the device-memory
    /// ledger of every node in its allocation. The job must already hold a
    /// GPU allocation; a charge that does not fit a node's per-GPU capacity
    /// fails with [`ClusterError::MemoryExceeded`] — a *real* OOM — and
    /// pins nothing.
    pub fn charge_memory(&mut self, job: JobId, per_gpu_bytes: u64) -> Result<(), ClusterError> {
        let alloc = self.ledger.get(&job).ok_or(ClusterError::NotAllocated(job))?;
        let parts = alloc.parts.clone();
        match self.device.try_charge(job, &parts, per_gpu_bytes) {
            Ok(()) => Ok(()),
            Err(DeviceOom { node, observed_bytes, capacity_bytes }) => {
                Err(ClusterError::MemoryExceeded { node, observed_bytes, capacity_bytes })
            }
        }
    }

    /// The device-memory byte ledger (read access for tests and reports).
    pub fn device_memory(&self) -> &DeviceMemory {
        &self.device
    }

    /// Elastic grow: add a node whose GPUs are immediately idle. Both a
    /// previously seen GPU size and a brand-new size class are inserted
    /// into the capacity index incrementally (no O(n log n) rebuild).
    pub fn grow(&mut self, spec: &NodeSpec) -> NodeId {
        let id = self.state.add_node(spec);
        self.index.on_grow(&self.state.nodes[id]);
        self.device.on_grow(spec.gpu.mem_bytes);
        id
    }

    /// True when `node` exists, still has capacity, and is not draining.
    pub fn node_active(&self, node: NodeId) -> bool {
        self.state.nodes.get(node).is_some_and(|n| n.total > 0)
            && !self.retiring.contains(&node)
    }

    /// Jobs whose allocation touches `node` (the set a retirement
    /// displaces — shared by [`Orchestrator::shrink`] and
    /// [`Orchestrator::retire_begin`]).
    fn jobs_on(&self, node: NodeId) -> Vec<JobId> {
        self.ledger
            .values()
            .filter(|a| a.parts.iter().any(|&(nid, _)| nid == node))
            .map(|a| a.job)
            .collect()
    }

    /// Strip a node's idle GPUs out of its capacity (index kept in sync);
    /// returns the remaining (still-allocated) capacity. The single place
    /// where retirement removes capacity, used at drain start and on every
    /// reap.
    fn strip_idle(&mut self, node: NodeId) -> u32 {
        let (old_idle, remaining) = {
            let n = &mut self.state.nodes[node];
            let old = n.idle;
            n.total -= old;
            n.idle = 0;
            (old, n.total)
        };
        self.index.set_idle(node, old_idle, 0);
        remaining
    }

    /// Begin a graceful drain of `node`: strip its idle capacity (no new
    /// placements land on it) and return the jobs still resident there —
    /// their GPUs stay allocated until each checkpoints and releases. A
    /// node with no resident jobs is fully retired immediately. Errors on
    /// unknown/retired ([`ClusterError::NoSuchNode`]) and on
    /// already-draining nodes ([`ClusterError::AlreadyDraining`] — a
    /// second leave must not reset the jobs' deadlines).
    pub fn retire_begin(&mut self, node: NodeId) -> Result<Vec<JobId>, ClusterError> {
        let n = self.state.nodes.get(node).ok_or(ClusterError::NoSuchNode(node))?;
        if self.retiring.contains(&node) {
            return Err(ClusterError::AlreadyDraining(node));
        }
        if n.total == 0 {
            return Err(ClusterError::NoSuchNode(node));
        }
        let affected = self.jobs_on(node);
        if self.strip_idle(node) > 0 {
            self.retiring.insert(node);
            self.sync_excluded();
        }
        Ok(affected)
    }

    /// Reap freed capacity off every retiring node: GPUs released back to a
    /// draining node are stripped instead of becoming placeable, and a node
    /// whose capacity reaches zero is fully retired. Call after any release
    /// that may have touched a retiring node; returns the node ids that
    /// completed retirement.
    pub fn reap_retiring(&mut self) -> Vec<NodeId> {
        let mut done = Vec::new();
        let nodes: Vec<NodeId> = self.retiring.iter().copied().collect();
        for node in nodes {
            if self.strip_idle(node) == 0 {
                self.retiring.remove(&node);
                done.push(node);
            }
        }
        if !done.is_empty() {
            self.sync_excluded();
        }
        done
    }

    /// Nodes currently in graceful drain.
    pub fn retiring_count(&self) -> usize {
        self.retiring.len()
    }

    /// Abrupt node failure: every allocation touching `node` is released
    /// at once — collective training cannot survive losing a participant —
    /// but unlike [`Orchestrator::shrink`] the node's capacity *stays* in
    /// the cluster (freed GPUs return to idle everywhere, including the
    /// crashed node). A crashed node reboots; it does not leave — whether
    /// placement may use it again is the quarantine's decision, not the
    /// capacity ledger's. Returns the released allocations so the caller
    /// can requeue the displaced jobs; a crash on a node hosting nothing
    /// is `Ok(vec![])`. Errors on unknown or retired nodes.
    pub fn crash_node(&mut self, node: NodeId) -> Result<Vec<Allocation>, ClusterError> {
        let n = self.state.nodes.get(node).ok_or(ClusterError::NoSuchNode(node))?;
        if n.total == 0 {
            return Err(ClusterError::NoSuchNode(node));
        }
        let affected = self.jobs_on(node);
        let mut released = Vec::with_capacity(affected.len());
        for job in affected {
            released.push(self.release(job).expect("ledger entry exists"));
        }
        Ok(released)
    }

    /// Fence `node` off from placement (crash-flap quarantine). Its
    /// capacity stays in the cluster — the fence is a placement veto, not
    /// a capacity change — so it is idempotent and ignores unknown nodes.
    pub fn quarantine(&mut self, node: NodeId) {
        if self.state.nodes.get(node).is_some() {
            self.quarantined.insert(node);
            self.sync_excluded();
        }
    }

    /// Lift the quarantine on `node` (probation expired). Idempotent.
    pub fn unquarantine(&mut self, node: NodeId) {
        if self.quarantined.remove(&node) {
            self.sync_excluded();
        }
    }

    /// Whether `node` is currently fenced off by the crash-flap quarantine.
    pub fn is_quarantined(&self, node: NodeId) -> bool {
        self.quarantined.contains(&node)
    }

    /// Nodes currently fenced off by the crash-flap quarantine.
    pub fn quarantined_count(&self) -> usize {
        self.quarantined.len()
    }

    fn sync_excluded(&mut self) {
        self.excluded = self.retiring.union(&self.quarantined).copied().collect();
    }

    /// Elastic shrink: retire `node`, releasing every allocation touching
    /// it. A job losing *any* part loses all parts — collective training
    /// cannot continue on a partial world — and each affected allocation is
    /// released exactly once (removed from the ledger before the node is
    /// zeroed). Returns the released allocations so the caller can requeue
    /// the affected jobs. Errors on unknown or already-retired nodes.
    pub fn shrink(&mut self, node: NodeId) -> Result<Vec<Allocation>, ClusterError> {
        let n = self.state.nodes.get(node).ok_or(ClusterError::NoSuchNode(node))?;
        if n.total == 0 {
            return Err(ClusterError::NoSuchNode(node));
        }
        let affected = self.jobs_on(node);
        let mut released = Vec::with_capacity(affected.len());
        for job in affected {
            released.push(self.release(job).expect("ledger entry exists"));
        }
        let old_idle = {
            let n = &mut self.state.nodes[node];
            let old = n.idle;
            n.total = 0;
            n.idle = 0;
            old
        };
        self.index.set_idle(node, old_idle, 0);
        if self.quarantined.remove(&node) {
            self.sync_excluded();
        }
        Ok(released)
    }

    /// Invariant check used by tests: ledger totals + idle == totals, and
    /// the device-memory byte ledger agrees with the GPU-count ledger
    /// (every charge belongs to a resident job, per-node bytes add up, no
    /// per-GPU charge exceeds its node's capacity).
    pub fn check_conservation(&self) -> bool {
        let mut used = vec![0u32; self.state.nodes.len()];
        for alloc in self.ledger.values() {
            for &(node, count) in &alloc.parts {
                if node >= used.len() {
                    return false;
                }
                used[node] += count;
            }
        }
        self.state
            .nodes
            .iter()
            .all(|n| n.idle + used[n.id] == n.total)
            && self.device.check_conservation(|job| self.ledger.contains_key(&job))
    }

    /// Serialize the full orchestrator — topology (GPUs by catalog name),
    /// idle counts, allocation ledger, device-memory charges, and the
    /// retiring and quarantined sets — for a durable snapshot. The capacity
    /// index and the derived excluded set are rebuilt on restore.
    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .state
            .nodes
            .iter()
            .map(|n| {
                let mut j = Json::obj();
                j.set("gpu", n.gpu.name)
                    .set("total", n.total)
                    .set("idle", n.idle)
                    .set("link", link_to_str(n.link));
                j
            })
            .collect();
        let ledger: Vec<Json> = self
            .ledger
            .values()
            .map(|a| {
                let parts: Vec<Json> = a
                    .parts
                    .iter()
                    .map(|&(n, c)| Json::from(vec![Json::from(n), Json::from(c)]))
                    .collect();
                let mut j = Json::obj();
                j.set("job", a.job).set("parts", Json::Arr(parts));
                j
            })
            .collect();
        let retiring: Vec<Json> = self.retiring.iter().map(|&n| Json::from(n)).collect();
        let quarantined: Vec<Json> = self.quarantined.iter().map(|&n| Json::from(n)).collect();
        let mut j = Json::obj();
        j.set("inter_node_gbps", self.state.inter_node_gbps)
            .set("nodes", Json::Arr(nodes))
            .set("ledger", Json::Arr(ledger))
            .set("device", self.device.to_json())
            .set("retiring", Json::Arr(retiring))
            .set("quarantined", Json::Arr(quarantined));
        j
    }

    /// Rebuild from [`Orchestrator::to_json`] output. Node ids are
    /// positional (stable across retirement, so positions round-trip);
    /// conservation is re-checked before the orchestrator is handed back.
    pub fn from_json(j: &Json) -> Result<Orchestrator, String> {
        let gbps = j
            .get("inter_node_gbps")
            .and_then(Json::as_f64)
            .ok_or("missing field 'inter_node_gbps'")?;
        let nodes_j = j.get("nodes").and_then(Json::as_arr).ok_or("missing field 'nodes'")?;
        let mut nodes = Vec::with_capacity(nodes_j.len());
        for (id, n) in nodes_j.iter().enumerate() {
            let gpu_name = n.get("gpu").and_then(Json::as_str).ok_or("node: no gpu")?;
            let gpu = crate::config::gpu_by_name(gpu_name)
                .ok_or_else(|| format!("unknown gpu '{gpu_name}'"))?;
            let link = n
                .get("link")
                .and_then(Json::as_str)
                .and_then(link_from_str)
                .ok_or("node: bad link")?;
            let total = n.get("total").and_then(Json::as_u64).ok_or("node: no total")? as u32;
            let idle = n.get("idle").and_then(Json::as_u64).ok_or("node: no idle")? as u32;
            if idle > total {
                return Err(format!("node {id}: idle {idle} > total {total}"));
            }
            nodes.push(Node { id, gpu, total, idle, link });
        }
        let state = ClusterState { nodes, inter_node_gbps: gbps };
        let index = CapacityIndex::build(&state);
        let device = DeviceMemory::from_json(j.get("device").ok_or("missing field 'device'")?)?;
        if device.n_nodes() != state.nodes.len() {
            return Err("device ledger / topology size mismatch".into());
        }
        let mut ledger = BTreeMap::new();
        for a in j.get("ledger").and_then(Json::as_arr).ok_or("missing field 'ledger'")? {
            let job = a.get("job").and_then(Json::as_u64).ok_or("ledger: no job")?;
            let parts_j = a.get("parts").and_then(Json::as_arr).ok_or("ledger: no parts")?;
            let mut parts = Vec::with_capacity(parts_j.len());
            for p in parts_j {
                let pair = p.as_arr().filter(|x| x.len() == 2).ok_or("ledger: bad part")?;
                parts.push((
                    pair[0].as_usize().ok_or("ledger: bad node")?,
                    pair[1].as_u64().ok_or("ledger: bad count")? as u32,
                ));
            }
            ledger.insert(job, Allocation { job, parts });
        }
        let mut retiring = BTreeSet::new();
        for r in j.get("retiring").and_then(Json::as_arr).ok_or("missing field 'retiring'")? {
            retiring.insert(r.as_usize().ok_or("retiring: bad node id")?);
        }
        // Optional for forward compatibility: snapshots written before the
        // quarantine existed simply have no fenced nodes.
        let mut quarantined = BTreeSet::new();
        if let Some(q) = j.get("quarantined").and_then(Json::as_arr) {
            for r in q {
                quarantined.insert(r.as_usize().ok_or("quarantined: bad node id")?);
            }
        }
        let excluded = retiring.union(&quarantined).copied().collect();
        let orch = Orchestrator { state, ledger, index, device, retiring, quarantined, excluded };
        if !orch.check_conservation() {
            return Err("snapshot violates resource conservation".into());
        }
        Ok(orch)
    }
}

fn link_to_str(l: LinkKind) -> &'static str {
    match l {
        LinkKind::NvLink => "nvlink",
        LinkKind::Pcie => "pcie",
    }
}

fn link_from_str(s: &str) -> Option<LinkKind> {
    match s {
        "nvlink" => Some(LinkKind::NvLink),
        "pcie" => Some(LinkKind::Pcie),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{real_testbed, GIB};

    #[test]
    fn from_spec_counts() {
        let s = ClusterState::from_spec(&real_testbed());
        assert_eq!(s.total_gpus(), 11);
        assert_eq!(s.idle_gpus(), 11);
        assert_eq!(s.utilization(), 0.0);
    }

    #[test]
    fn allocate_release_roundtrip() {
        let mut o = Orchestrator::new(&real_testbed());
        let alloc = Allocation { job: 1, parts: vec![(2, 4)] }; // the A800 node
        o.allocate(alloc.clone()).unwrap();
        assert_eq!(o.state().idle_gpus(), 7);
        assert_eq!(o.allocation_of(1), Some(&alloc));
        assert!(o.check_conservation());
        let released = o.release(1).unwrap();
        assert_eq!(released, alloc);
        assert_eq!(o.state().idle_gpus(), 11);
        assert!(o.check_conservation());
    }

    #[test]
    fn allocation_is_atomic() {
        let mut o = Orchestrator::new(&real_testbed());
        // Part 1 is fine (node 0 has 2), part 2 overdraws node 1 (has 1).
        let bad = Allocation { job: 9, parts: vec![(0, 2), (1, 3)] };
        let err = o.allocate(bad).unwrap_err();
        assert!(matches!(err, ClusterError::InsufficientIdle { node: 1, .. }));
        // Nothing must have been taken.
        assert_eq!(o.state().idle_gpus(), 11);
        assert!(o.check_conservation());
    }

    #[test]
    fn double_allocate_rejected() {
        let mut o = Orchestrator::new(&real_testbed());
        o.allocate(Allocation { job: 1, parts: vec![(0, 1)] }).unwrap();
        let err = o.allocate(Allocation { job: 1, parts: vec![(1, 1)] }).unwrap_err();
        assert_eq!(err, ClusterError::AlreadyAllocated(1));
    }

    #[test]
    fn release_unknown_job() {
        let mut o = Orchestrator::new(&real_testbed());
        assert_eq!(o.release(42).unwrap_err(), ClusterError::NotAllocated(42));
    }

    #[test]
    fn idle_with_mem_filter() {
        let s = ClusterState::from_spec(&real_testbed());
        // 80G GPUs: 4 (A800) + 2 + 2 = 8
        assert_eq!(s.idle_gpus_with_mem(80 * GIB), 8);
        assert_eq!(s.idle_gpus_with_mem(40 * GIB), 11);
        assert_eq!(s.idle_gpus_with_mem(81 * GIB), 0);
    }

    #[test]
    fn grow_adds_idle_capacity_with_stable_ids() {
        let mut o = Orchestrator::new(&real_testbed());
        let spec = NodeSpec {
            gpu: crate::config::gpu_by_name("A100-80G").unwrap(),
            count: 4,
            link: LinkKind::NvLink,
        };
        let id = o.grow(&spec);
        assert_eq!(id, 5, "appended after the 5 seed nodes");
        assert_eq!(o.state().total_gpus(), 15);
        assert_eq!(o.state().idle_gpus(), 15);
        assert!(o.check_conservation());
        // New capacity is allocatable.
        o.allocate(Allocation { job: 1, parts: vec![(id, 4)] }).unwrap();
        assert_eq!(o.state().idle_gpus(), 11);
        assert!(o.check_conservation());
    }

    #[test]
    fn shrink_releases_affected_jobs_exactly_once() {
        let mut o = Orchestrator::new(&real_testbed());
        // Job 1 spans nodes 3+4; job 2 sits on node 0 alone.
        o.allocate(Allocation { job: 1, parts: vec![(3, 2), (4, 2)] }).unwrap();
        o.allocate(Allocation { job: 2, parts: vec![(0, 2)] }).unwrap();
        let released = o.shrink(3).unwrap();
        assert_eq!(released.len(), 1);
        assert_eq!(released[0].job, 1);
        // Node 3 retired; node 4's GPUs (the job's other part) came back.
        assert_eq!(o.state().nodes[3].total, 0);
        assert_eq!(o.state().nodes[3].idle, 0);
        assert_eq!(o.state().nodes[4].idle, 2);
        assert_eq!(o.state().total_gpus(), 9);
        assert_eq!(o.state().idle_gpus(), 7, "job 2 still holds node 0");
        assert!(o.allocation_of(1).is_none(), "released exactly once");
        assert!(o.allocation_of(2).is_some(), "unaffected job keeps its GPUs");
        assert!(o.check_conservation());
        // Releasing again via the normal path must fail (not double-free).
        assert_eq!(o.release(1).unwrap_err(), ClusterError::NotAllocated(1));
        // A retired node cannot be shrunk twice or allocated on.
        assert_eq!(o.shrink(3).unwrap_err(), ClusterError::NoSuchNode(3));
        assert!(o.allocate(Allocation { job: 3, parts: vec![(3, 1)] }).is_err());
    }

    #[test]
    fn shrink_unknown_node_errors() {
        let mut o = Orchestrator::new(&real_testbed());
        assert_eq!(o.shrink(99).unwrap_err(), ClusterError::NoSuchNode(99));
    }

    #[test]
    fn to_spec_skips_retired_nodes() {
        let mut o = Orchestrator::new(&real_testbed());
        o.shrink(2).unwrap(); // retire the 4×A800 node
        let spec = o.state().to_spec("scaled");
        assert_eq!(spec.nodes.len(), 4);
        assert_eq!(spec.total_gpus(), 7);
        assert!(spec.nodes.iter().all(|n| n.gpu.name != "A800-80G"));
        assert_eq!(o.state().active_nodes().count(), 4);
    }

    #[test]
    fn index_stays_consistent_through_lifecycle() {
        let mut o = Orchestrator::new(&real_testbed());
        assert!(o.check_index());
        o.allocate(Allocation { job: 1, parts: vec![(2, 3), (0, 1)] }).unwrap();
        assert!(o.check_index());
        // A never-seen GPU size takes the incremental class-insert path.
        let spec = NodeSpec {
            gpu: crate::config::gpu_by_name("RTX3090").unwrap(),
            count: 2,
            link: LinkKind::Pcie,
        };
        o.grow(&spec);
        assert!(o.check_index());
        o.shrink(3).unwrap();
        assert!(o.check_index());
        o.release(1).unwrap();
        assert!(o.check_index());
        assert_eq!(
            o.index().idle_with_mem(24 * GIB),
            o.state().idle_gpus_with_mem(24 * GIB)
        );
    }

    #[test]
    fn allocate_rejects_duplicate_part_overdraw() {
        // Two parts naming the same node must be validated as their sum.
        let mut o = Orchestrator::new(&real_testbed());
        let bad = Allocation { job: 1, parts: vec![(2, 3), (2, 3)] }; // 6 > 4 idle
        assert!(matches!(
            o.allocate(bad).unwrap_err(),
            ClusterError::InsufficientIdle { node: 2, .. }
        ));
        assert_eq!(o.state().idle_gpus(), 11, "nothing taken");
        assert!(o.check_index());
        // The aggregated form within capacity succeeds.
        o.allocate(Allocation { job: 1, parts: vec![(2, 2), (2, 2)] }).unwrap();
        assert!(o.check_conservation());
        assert!(o.check_index());
    }

    #[test]
    fn charge_memory_tracks_bytes_and_raises_real_oom() {
        let mut o = Orchestrator::new(&real_testbed());
        // Job 1 spans a 40G node (node 0) and an 80G node (node 3).
        o.allocate(Allocation { job: 1, parts: vec![(0, 2), (3, 1)] }).unwrap();
        o.charge_memory(1, 30 * GIB).unwrap();
        assert_eq!(o.device_memory().used_bytes(0), 60 * GIB);
        assert_eq!(o.device_memory().used_bytes(3), 30 * GIB);
        assert!(o.check_conservation());
        // Job 2's observed peak exceeds the 40G card: a real OOM naming the
        // node, with nothing pinned.
        o.allocate(Allocation { job: 2, parts: vec![(1, 1)] }).unwrap();
        let err = o.charge_memory(2, 50 * GIB).unwrap_err();
        assert_eq!(
            err,
            ClusterError::MemoryExceeded {
                node: 1,
                observed_bytes: 50 * GIB,
                capacity_bytes: 40 * GIB
            }
        );
        assert_eq!(o.device_memory().used_bytes(1), 0);
        assert!(o.check_conservation());
        // Charging an unallocated job is a ledger error, not an OOM.
        assert_eq!(o.charge_memory(9, 1).unwrap_err(), ClusterError::NotAllocated(9));
        // Release frees GPUs and bytes together.
        o.release(1).unwrap();
        assert_eq!(o.device_memory().total_used_bytes(), 0);
        assert!(o.check_conservation());
    }

    #[test]
    fn retire_begin_drains_then_reap_completes() {
        let mut o = Orchestrator::new(&real_testbed());
        // Job 1 holds 2 of node 2's 4 GPUs; the other 2 are idle.
        o.allocate(Allocation { job: 1, parts: vec![(2, 2)] }).unwrap();
        o.charge_memory(1, 10 * GIB).unwrap();
        let affected = o.retire_begin(2).unwrap();
        assert_eq!(affected, vec![1]);
        // Idle capacity stripped immediately; the job keeps its GPUs.
        assert_eq!(o.state().nodes[2].total, 2);
        assert_eq!(o.state().nodes[2].idle, 0);
        assert!(!o.node_active(2), "draining node accepts no placements");
        assert_eq!(o.retiring_count(), 1);
        assert!(o.check_conservation());
        assert!(o.check_index());
        // A second drain of the same node is rejected.
        assert!(o.retire_begin(2).is_err());
        // Nothing released yet: reap finds nothing to strip.
        assert!(o.reap_retiring().is_empty());
        // The job releases (post-checkpoint): its GPUs are reaped, the node
        // completes retirement, and the bytes are freed.
        o.release(1).unwrap();
        let done = o.reap_retiring();
        assert_eq!(done, vec![2]);
        assert_eq!(o.state().nodes[2].total, 0);
        assert_eq!(o.retiring_count(), 0);
        assert_eq!(o.device_memory().total_used_bytes(), 0);
        assert!(o.check_conservation());
        assert!(o.check_index());
        // Fully retired nodes cannot drain again.
        assert!(o.retire_begin(2).is_err());
    }

    #[test]
    fn retire_begin_idle_node_completes_immediately() {
        let mut o = Orchestrator::new(&real_testbed());
        let affected = o.retire_begin(0).unwrap();
        assert!(affected.is_empty());
        assert_eq!(o.state().nodes[0].total, 0);
        assert_eq!(o.retiring_count(), 0, "no resident jobs: retired in one step");
        assert!(o.check_conservation());
        assert!(o.check_index());
    }

    #[test]
    fn orchestrator_json_roundtrip_mid_drain() {
        let mut o = Orchestrator::new(&real_testbed());
        o.allocate(Allocation { job: 1, parts: vec![(2, 2)] }).unwrap();
        o.charge_memory(1, 10 * GIB).unwrap();
        o.allocate(Allocation { job: 2, parts: vec![(0, 1), (3, 1)] }).unwrap();
        o.retire_begin(2).unwrap(); // node 2 drains with job 1 resident
        o.shrink(4).unwrap(); // node 4 fully retired
        let text = o.to_json().to_string_compact();
        let back =
            Orchestrator::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.state(), o.state());
        assert_eq!(back.allocation_of(1), o.allocation_of(1));
        assert_eq!(back.allocation_of(2), o.allocation_of(2));
        assert_eq!(back.retiring_count(), 1);
        assert!(!back.node_active(2));
        assert_eq!(back.device_memory().total_used_bytes(), 20 * GIB);
        assert!(back.check_conservation());
        assert!(back.check_index(), "index rebuilt from state");
        // Serialization itself is deterministic.
        assert_eq!(text, back.to_json().to_string_compact());
    }

    #[test]
    fn crash_node_releases_jobs_but_keeps_capacity() {
        let mut o = Orchestrator::new(&real_testbed());
        o.allocate(Allocation { job: 1, parts: vec![(2, 2), (3, 1)] }).unwrap();
        o.charge_memory(1, 10 * GIB).unwrap();
        o.allocate(Allocation { job: 2, parts: vec![(0, 2)] }).unwrap();
        let released = o.crash_node(2).unwrap();
        assert_eq!(released.len(), 1);
        assert_eq!(released[0].job, 1);
        // Unlike shrink, the crashed node's capacity survives the crash.
        assert_eq!(o.state().nodes[2].total, 4);
        assert_eq!(o.state().nodes[2].idle, 4);
        assert_eq!(o.state().nodes[3].idle, 2, "the job's other part came back too");
        assert!(o.allocation_of(1).is_none());
        assert!(o.allocation_of(2).is_some(), "jobs elsewhere are untouched");
        assert_eq!(o.device_memory().total_used_bytes(), 0);
        assert!(o.check_conservation());
        assert!(o.check_index());
        // A crash on a node hosting nothing displaces nothing.
        assert!(o.crash_node(2).unwrap().is_empty());
        assert_eq!(o.crash_node(99).unwrap_err(), ClusterError::NoSuchNode(99));
    }

    #[test]
    fn quarantined_node_hidden_from_view_until_unquarantined() {
        let mut o = Orchestrator::new(&real_testbed());
        let all = o.view().idle_gpus_with_mem(40 * GIB);
        o.quarantine(2); // the 4×A800 node: 4 idle GPUs, all fenced
        assert!(o.is_quarantined(2));
        assert_eq!(o.quarantined_count(), 1);
        assert_eq!(o.view().idle_gpus_with_mem(40 * GIB), all - 4);
        assert!(o.view().is_draining(2), "schedulers see the fence");
        assert!(o.node_active(2), "a fenced node still heartbeats");
        assert!(o.check_conservation(), "capacity is unchanged");
        o.quarantine(2); // idempotent
        assert_eq!(o.quarantined_count(), 1);
        o.unquarantine(2);
        assert_eq!(o.quarantined_count(), 0);
        assert_eq!(o.view().idle_gpus_with_mem(40 * GIB), all);
        o.quarantine(99); // unknown node: ignored
        assert_eq!(o.quarantined_count(), 0);
    }

    #[test]
    fn orchestrator_json_roundtrip_mid_quarantine() {
        let mut o = Orchestrator::new(&real_testbed());
        o.allocate(Allocation { job: 1, parts: vec![(0, 2)] }).unwrap();
        o.quarantine(2);
        let text = o.to_json().to_string_compact();
        let back =
            Orchestrator::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert!(back.is_quarantined(2));
        assert_eq!(
            back.view().idle_gpus_with_mem(40 * GIB),
            o.view().idle_gpus_with_mem(40 * GIB),
            "the derived excluded set is rebuilt on restore"
        );
        assert!(back.check_conservation());
        assert_eq!(text, back.to_json().to_string_compact());
        // Snapshots written before the quarantine existed restore cleanly
        // with no fenced nodes.
        let legacy = text.replace(",\"quarantined\":[2]", "");
        assert_ne!(legacy, text);
        let old =
            Orchestrator::from_json(&crate::util::json::parse(&legacy).unwrap()).unwrap();
        assert_eq!(old.quarantined_count(), 0);
    }

    #[test]
    fn fragmentation_metric() {
        let mut s = ClusterState::from_spec(&real_testbed());
        assert!(s.fragmentation() > 0.0); // idle spread across 5 nodes
        // Empty the cluster -> fragmentation defined as 0.
        for n in &mut s.nodes {
            n.idle = 0;
        }
        assert_eq!(s.fragmentation(), 0.0);
    }
}
