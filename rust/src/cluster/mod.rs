//! Runtime cluster state and the Resource Orchestrator (§IV, third
//! component): tracks idle GPUs per node, executes allocations and releases,
//! and maintains the job→resources ledger.
//!
//! The orchestrator also maintains the [`CapacityIndex`] incrementally on
//! every take/give/grow/shrink, so scheduling rounds answer capacity
//! questions in logarithmic time instead of scanning the node list — see
//! [`index`] for the design.

pub mod index;

pub use index::{CapacityIndex, CapacityOverlay, ClusterView, IdleBuckets};

use crate::config::{ClusterSpec, GpuSpec, LinkKind, NodeSpec};
use crate::job::JobId;
use std::collections::BTreeMap;

/// Node identifier (index into the cluster's node list).
pub type NodeId = usize;

/// Mutable per-node state.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub gpu: GpuSpec,
    pub total: u32,
    pub idle: u32,
    pub link: LinkKind,
}

impl Node {
    pub fn used(&self) -> u32 {
        self.total - self.idle
    }
}

/// One job's placement: GPUs taken per node.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub job: JobId,
    pub parts: Vec<(NodeId, u32)>,
}

impl Allocation {
    pub fn total_gpus(&self) -> u32 {
        self.parts.iter().map(|(_, n)| n).sum()
    }

    pub fn node_count(&self) -> usize {
        self.parts.len()
    }

    pub fn is_single_node(&self) -> bool {
        self.parts.len() == 1
    }
}

/// Errors the orchestrator can raise.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterError {
    /// Requested more GPUs than a node has idle.
    InsufficientIdle { node: NodeId, requested: u32, idle: u32 },
    /// Unknown node id.
    NoSuchNode(NodeId),
    /// Job already holds an allocation.
    AlreadyAllocated(JobId),
    /// Job holds no allocation.
    NotAllocated(JobId),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::InsufficientIdle { node, requested, idle } => {
                write!(f, "node {node}: requested {requested} GPUs but only {idle} idle")
            }
            ClusterError::NoSuchNode(n) => write!(f, "no such node {n}"),
            ClusterError::AlreadyAllocated(j) => write!(f, "job {j} already allocated"),
            ClusterError::NotAllocated(j) => write!(f, "job {j} not allocated"),
        }
    }
}

impl std::error::Error for ClusterError {}

/// Live cluster state: nodes with idle counts.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterState {
    pub nodes: Vec<Node>,
    /// Cross-node bandwidth, forwarded from the spec.
    pub inter_node_gbps: f64,
}

impl ClusterState {
    pub fn from_spec(spec: &ClusterSpec) -> Self {
        let nodes = spec
            .nodes
            .iter()
            .enumerate()
            .map(|(id, n)| Node {
                id,
                gpu: n.gpu.clone(),
                total: n.count,
                idle: n.count,
                link: n.link,
            })
            .collect();
        Self { nodes, inter_node_gbps: spec.inter_node_gbps }
    }

    pub fn total_gpus(&self) -> u32 {
        self.nodes.iter().map(|n| n.total).sum()
    }

    pub fn idle_gpus(&self) -> u32 {
        self.nodes.iter().map(|n| n.idle).sum()
    }

    /// Idle GPUs whose memory is at least `min_mem`.
    pub fn idle_gpus_with_mem(&self, min_mem: u64) -> u32 {
        self.nodes.iter().filter(|n| n.gpu.mem_bytes >= min_mem).map(|n| n.idle).sum()
    }

    /// Overall utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        let total = self.total_gpus();
        if total == 0 {
            0.0
        } else {
            1.0 - self.idle_gpus() as f64 / total as f64
        }
    }

    /// Fragmentation metric: 1 − (largest idle block / total idle). High
    /// values mean idle GPUs are scattered across nodes.
    pub fn fragmentation(&self) -> f64 {
        let idle = self.idle_gpus();
        if idle == 0 {
            return 0.0;
        }
        let largest = self.nodes.iter().map(|n| n.idle).max().unwrap_or(0);
        1.0 - largest as f64 / idle as f64
    }

    /// Append a node (elastic NodeJoin); returns its id. Node ids are
    /// stable for the lifetime of the cluster: a removed node is *retired*
    /// in place (`total = 0`) rather than spliced out, so ids held by
    /// allocations and decision logs never shift.
    pub fn add_node(&mut self, spec: &NodeSpec) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            gpu: spec.gpu.clone(),
            total: spec.count,
            idle: spec.count,
            link: spec.link,
        });
        id
    }

    /// Nodes still part of the cluster (not retired by a NodeLeave).
    pub fn active_nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| n.total > 0)
    }

    /// Derive a [`ClusterSpec`] from the current (possibly scaled)
    /// topology, skipping retired nodes — used to rebuild MARP and other
    /// derived scheduler state after elasticity events.
    pub fn to_spec(&self, name: &str) -> ClusterSpec {
        ClusterSpec {
            name: name.to_string(),
            nodes: self
                .active_nodes()
                .map(|n| NodeSpec { gpu: n.gpu.clone(), count: n.total, link: n.link })
                .collect(),
            inter_node_gbps: self.inter_node_gbps,
        }
    }
}

/// The Resource Orchestrator: authoritative allocate/release with a ledger
/// and an incrementally maintained [`CapacityIndex`].
#[derive(Debug, Clone)]
pub struct Orchestrator {
    state: ClusterState,
    ledger: BTreeMap<JobId, Allocation>,
    index: CapacityIndex,
}

impl Orchestrator {
    pub fn new(spec: &ClusterSpec) -> Self {
        let state = ClusterState::from_spec(spec);
        let index = CapacityIndex::build(&state);
        Self { state, ledger: BTreeMap::new(), index }
    }

    pub fn state(&self) -> &ClusterState {
        &self.state
    }

    /// The incrementally maintained capacity index.
    pub fn index(&self) -> &CapacityIndex {
        &self.index
    }

    /// Zero-copy planning window for a scheduling round: the live state plus
    /// the maintained index. This is what the engine hands to schedulers —
    /// rounds no longer clone the cluster.
    pub fn view(&self) -> ClusterView<'_> {
        ClusterView::with_index(&self.state, &self.index)
    }

    /// Owned snapshot (kept for tests and offline analysis; the scheduling
    /// hot path uses [`Orchestrator::view`] instead).
    pub fn snapshot(&self) -> ClusterState {
        self.state.clone()
    }

    /// Test hook: the incremental index must always agree with a fresh
    /// build from the state.
    pub fn check_index(&self) -> bool {
        self.index.check_against(&self.state)
    }

    pub fn allocation_of(&self, job: JobId) -> Option<&Allocation> {
        self.ledger.get(&job)
    }

    pub fn running_jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.ledger.keys().copied()
    }

    /// Atomically apply an allocation: either every part is taken or none.
    /// Validation aggregates per node first (so duplicate node entries in
    /// `parts` cannot overdraw) and applies only after every part checks
    /// out — no cluster-sized scratch clone on the dispatch hot path.
    pub fn allocate(&mut self, alloc: Allocation) -> Result<(), ClusterError> {
        if self.ledger.contains_key(&alloc.job) {
            return Err(ClusterError::AlreadyAllocated(alloc.job));
        }
        let mut agg: Vec<(NodeId, u32)> = Vec::with_capacity(alloc.parts.len());
        for &(node, count) in &alloc.parts {
            match agg.iter_mut().find(|(n, _)| *n == node) {
                Some((_, c)) => *c += count,
                None => agg.push((node, count)),
            }
        }
        for &(node, want) in &agg {
            let n = self.state.nodes.get(node).ok_or(ClusterError::NoSuchNode(node))?;
            if n.idle < want {
                return Err(ClusterError::InsufficientIdle {
                    node,
                    requested: want,
                    idle: n.idle,
                });
            }
        }
        for &(node, want) in &agg {
            let old = self.state.nodes[node].idle;
            self.state.nodes[node].idle = old - want;
            self.index.set_idle(node, old, old - want);
        }
        self.ledger.insert(alloc.job, alloc);
        Ok(())
    }

    /// Release a job's resources.
    pub fn release(&mut self, job: JobId) -> Result<Allocation, ClusterError> {
        let alloc = self.ledger.remove(&job).ok_or(ClusterError::NotAllocated(job))?;
        for &(node, count) in &alloc.parts {
            let (old, new) = {
                let n =
                    self.state.nodes.get_mut(node).expect("ledger references valid nodes");
                let old = n.idle;
                n.idle = (old + count).min(n.total);
                (old, n.idle)
            };
            self.index.set_idle(node, old, new);
        }
        Ok(alloc)
    }

    /// Elastic grow: add a node whose GPUs are immediately idle.
    pub fn grow(&mut self, spec: &NodeSpec) -> NodeId {
        let id = self.state.add_node(spec);
        if !self.index.on_grow(&self.state.nodes[id]) {
            // The join introduced a brand-new GPU size class; rebuild the
            // index (rare — a never-seen GPU type — and O(n log n)).
            self.index = CapacityIndex::build(&self.state);
        }
        id
    }

    /// Elastic shrink: retire `node`, releasing every allocation touching
    /// it. A job losing *any* part loses all parts — collective training
    /// cannot continue on a partial world — and each affected allocation is
    /// released exactly once (removed from the ledger before the node is
    /// zeroed). Returns the released allocations so the caller can requeue
    /// the affected jobs. Errors on unknown or already-retired nodes.
    pub fn shrink(&mut self, node: NodeId) -> Result<Vec<Allocation>, ClusterError> {
        let n = self.state.nodes.get(node).ok_or(ClusterError::NoSuchNode(node))?;
        if n.total == 0 {
            return Err(ClusterError::NoSuchNode(node));
        }
        let affected: Vec<JobId> = self
            .ledger
            .values()
            .filter(|a| a.parts.iter().any(|&(nid, _)| nid == node))
            .map(|a| a.job)
            .collect();
        let mut released = Vec::with_capacity(affected.len());
        for job in affected {
            released.push(self.release(job).expect("ledger entry exists"));
        }
        let old_idle = {
            let n = &mut self.state.nodes[node];
            let old = n.idle;
            n.total = 0;
            n.idle = 0;
            old
        };
        self.index.set_idle(node, old_idle, 0);
        Ok(released)
    }

    /// Invariant check used by tests: ledger totals + idle == totals.
    pub fn check_conservation(&self) -> bool {
        let mut used = vec![0u32; self.state.nodes.len()];
        for alloc in self.ledger.values() {
            for &(node, count) in &alloc.parts {
                if node >= used.len() {
                    return false;
                }
                used[node] += count;
            }
        }
        self.state
            .nodes
            .iter()
            .all(|n| n.idle + used[n.id] == n.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{real_testbed, GIB};

    #[test]
    fn from_spec_counts() {
        let s = ClusterState::from_spec(&real_testbed());
        assert_eq!(s.total_gpus(), 11);
        assert_eq!(s.idle_gpus(), 11);
        assert_eq!(s.utilization(), 0.0);
    }

    #[test]
    fn allocate_release_roundtrip() {
        let mut o = Orchestrator::new(&real_testbed());
        let alloc = Allocation { job: 1, parts: vec![(2, 4)] }; // the A800 node
        o.allocate(alloc.clone()).unwrap();
        assert_eq!(o.state().idle_gpus(), 7);
        assert_eq!(o.allocation_of(1), Some(&alloc));
        assert!(o.check_conservation());
        let released = o.release(1).unwrap();
        assert_eq!(released, alloc);
        assert_eq!(o.state().idle_gpus(), 11);
        assert!(o.check_conservation());
    }

    #[test]
    fn allocation_is_atomic() {
        let mut o = Orchestrator::new(&real_testbed());
        // Part 1 is fine (node 0 has 2), part 2 overdraws node 1 (has 1).
        let bad = Allocation { job: 9, parts: vec![(0, 2), (1, 3)] };
        let err = o.allocate(bad).unwrap_err();
        assert!(matches!(err, ClusterError::InsufficientIdle { node: 1, .. }));
        // Nothing must have been taken.
        assert_eq!(o.state().idle_gpus(), 11);
        assert!(o.check_conservation());
    }

    #[test]
    fn double_allocate_rejected() {
        let mut o = Orchestrator::new(&real_testbed());
        o.allocate(Allocation { job: 1, parts: vec![(0, 1)] }).unwrap();
        let err = o.allocate(Allocation { job: 1, parts: vec![(1, 1)] }).unwrap_err();
        assert_eq!(err, ClusterError::AlreadyAllocated(1));
    }

    #[test]
    fn release_unknown_job() {
        let mut o = Orchestrator::new(&real_testbed());
        assert_eq!(o.release(42).unwrap_err(), ClusterError::NotAllocated(42));
    }

    #[test]
    fn idle_with_mem_filter() {
        let s = ClusterState::from_spec(&real_testbed());
        // 80G GPUs: 4 (A800) + 2 + 2 = 8
        assert_eq!(s.idle_gpus_with_mem(80 * GIB), 8);
        assert_eq!(s.idle_gpus_with_mem(40 * GIB), 11);
        assert_eq!(s.idle_gpus_with_mem(81 * GIB), 0);
    }

    #[test]
    fn grow_adds_idle_capacity_with_stable_ids() {
        let mut o = Orchestrator::new(&real_testbed());
        let spec = NodeSpec {
            gpu: crate::config::gpu_by_name("A100-80G").unwrap(),
            count: 4,
            link: LinkKind::NvLink,
        };
        let id = o.grow(&spec);
        assert_eq!(id, 5, "appended after the 5 seed nodes");
        assert_eq!(o.state().total_gpus(), 15);
        assert_eq!(o.state().idle_gpus(), 15);
        assert!(o.check_conservation());
        // New capacity is allocatable.
        o.allocate(Allocation { job: 1, parts: vec![(id, 4)] }).unwrap();
        assert_eq!(o.state().idle_gpus(), 11);
        assert!(o.check_conservation());
    }

    #[test]
    fn shrink_releases_affected_jobs_exactly_once() {
        let mut o = Orchestrator::new(&real_testbed());
        // Job 1 spans nodes 3+4; job 2 sits on node 0 alone.
        o.allocate(Allocation { job: 1, parts: vec![(3, 2), (4, 2)] }).unwrap();
        o.allocate(Allocation { job: 2, parts: vec![(0, 2)] }).unwrap();
        let released = o.shrink(3).unwrap();
        assert_eq!(released.len(), 1);
        assert_eq!(released[0].job, 1);
        // Node 3 retired; node 4's GPUs (the job's other part) came back.
        assert_eq!(o.state().nodes[3].total, 0);
        assert_eq!(o.state().nodes[3].idle, 0);
        assert_eq!(o.state().nodes[4].idle, 2);
        assert_eq!(o.state().total_gpus(), 9);
        assert_eq!(o.state().idle_gpus(), 7, "job 2 still holds node 0");
        assert!(o.allocation_of(1).is_none(), "released exactly once");
        assert!(o.allocation_of(2).is_some(), "unaffected job keeps its GPUs");
        assert!(o.check_conservation());
        // Releasing again via the normal path must fail (not double-free).
        assert_eq!(o.release(1).unwrap_err(), ClusterError::NotAllocated(1));
        // A retired node cannot be shrunk twice or allocated on.
        assert_eq!(o.shrink(3).unwrap_err(), ClusterError::NoSuchNode(3));
        assert!(o.allocate(Allocation { job: 3, parts: vec![(3, 1)] }).is_err());
    }

    #[test]
    fn shrink_unknown_node_errors() {
        let mut o = Orchestrator::new(&real_testbed());
        assert_eq!(o.shrink(99).unwrap_err(), ClusterError::NoSuchNode(99));
    }

    #[test]
    fn to_spec_skips_retired_nodes() {
        let mut o = Orchestrator::new(&real_testbed());
        o.shrink(2).unwrap(); // retire the 4×A800 node
        let spec = o.state().to_spec("scaled");
        assert_eq!(spec.nodes.len(), 4);
        assert_eq!(spec.total_gpus(), 7);
        assert!(spec.nodes.iter().all(|n| n.gpu.name != "A800-80G"));
        assert_eq!(o.state().active_nodes().count(), 4);
    }

    #[test]
    fn index_stays_consistent_through_lifecycle() {
        let mut o = Orchestrator::new(&real_testbed());
        assert!(o.check_index());
        o.allocate(Allocation { job: 1, parts: vec![(2, 3), (0, 1)] }).unwrap();
        assert!(o.check_index());
        // A never-seen GPU size forces the rebuild path.
        let spec = NodeSpec {
            gpu: crate::config::gpu_by_name("RTX3090").unwrap(),
            count: 2,
            link: LinkKind::Pcie,
        };
        o.grow(&spec);
        assert!(o.check_index());
        o.shrink(3).unwrap();
        assert!(o.check_index());
        o.release(1).unwrap();
        assert!(o.check_index());
        assert_eq!(
            o.index().idle_with_mem(24 * GIB),
            o.state().idle_gpus_with_mem(24 * GIB)
        );
    }

    #[test]
    fn allocate_rejects_duplicate_part_overdraw() {
        // Two parts naming the same node must be validated as their sum.
        let mut o = Orchestrator::new(&real_testbed());
        let bad = Allocation { job: 1, parts: vec![(2, 3), (2, 3)] }; // 6 > 4 idle
        assert!(matches!(
            o.allocate(bad).unwrap_err(),
            ClusterError::InsufficientIdle { node: 2, .. }
        ));
        assert_eq!(o.state().idle_gpus(), 11, "nothing taken");
        assert!(o.check_index());
        // The aggregated form within capacity succeeds.
        o.allocate(Allocation { job: 1, parts: vec![(2, 2), (2, 2)] }).unwrap();
        assert!(o.check_conservation());
        assert!(o.check_index());
    }

    #[test]
    fn fragmentation_metric() {
        let mut s = ClusterState::from_spec(&real_testbed());
        assert!(s.fragmentation() > 0.0); // idle spread across 5 nodes
        // Empty the cluster -> fragmentation defined as 0.
        for n in &mut s.nodes {
            n.idle = 0;
        }
        assert_eq!(s.fragmentation(), 0.0);
    }
}
