//! `frenzy` — the serverless LLM-training leader binary.
//!
//! ```text
//! frenzy serve    [--addr 127.0.0.1:8315] [--cluster real] [--sched has]
//!                 [--data-dir ./frenzy-data] [--fsync every:32]
//! frenzy submit   --model gpt2-350m --batch 8 --samples 400 [--addr ...]
//! frenzy status   <job-id> [--addr ...]
//! frenzy cancel   <job-id> [--addr ...]
//! frenzy list     [--state running] [--offset 0] [--limit 100] [--addr ...]
//! frenzy events   [--since 0] [--limit 500] [--follow] [--cursor PATH] [--addr ...]
//! frenzy report   [--addr ...]
//! frenzy top      [--interval 2] [--iterations 0] [--addr ...]
//! frenzy metrics  [--check] [--addr ...]
//! frenzy version  [--addr ...]
//! frenzy predict  --model gpt2-7b --batch 2 [--addr ... | --cluster real]
//! frenzy scale    --join --gpu A100-80G --count 4 --link nvlink [--addr ...]
//! frenzy scale    --leave 2 [--addr ...]
//! frenzy simulate --workload newworkload --tasks 30 --sched has [--seed 11]
//! frenzy replay   --workload philly --tasks 20 [--speedup 1000] [--sched has]
//! frenzy train    --model gpt2-tiny --steps 50        (direct PJRT run)
//! frenzy fig4 | fig5a | fig5b | fig6 | figures
//! frenzy trace    --workload philly --n 100 --out trace.csv
//! ```
//!
//! The serverless subcommands speak the v1 HTTP API (see `API.md`) through
//! `frenzy::serverless::client::FrenzyClient`.

use anyhow::{bail, Result};
use frenzy::cli::commands;
use frenzy::cli::Args;
use frenzy::config::cluster_by_name;
use frenzy::marp::Marp;
use frenzy::sched::{has::Has, opportunistic::Opportunistic, sia::Sia, Scheduler};
use frenzy::sim::{simulate, SimConfig};
use frenzy::util::table::{fmt_duration, Table};
use frenzy::workload::trace;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "frenzy — memory-aware serverless LLM training for heterogeneous GPU clusters

USAGE:
  frenzy serve    [--addr 127.0.0.1:8315] [--cluster real|sim] [--steps N]
                  [--sched has|sia|opportunistic] [--round-interval S]
                  [--drain-ms M] [--ckpt-steps K]   (graceful-drain tuning)
                  [--data-dir D] [--fsync always|every:N|interval:S]
                  [--snapshot-every E]   (WAL + snapshots; crash-recoverable)
                  [--tenant-weights a=2,b=1]   (weighted max-min fair ordering)
  frenzy submit   --model <name> --batch <B> --samples <N> [--addr A]
  frenzy status   <job-id> [--addr A]
  frenzy cancel   <job-id> [--addr A]
  frenzy list     [--state queued|running|completed|rejected|cancelled]
                  [--offset O] [--limit L] [--addr A]
  frenzy events   [--since SEQ] [--limit L] [--follow] [--wait-ms W]
                  [--cursor PATH] [--addr A]
                  (cluster audit log: placements, observed OOMs, drains,
                   joins/leaves, ...; --follow long-polls, no busy-polling;
                   --cursor persists the last seen seq so a restarted
                   follower resumes instead of re-printing history)
  frenzy report   [--addr A]    (streaming run report: JCT histogram, drains,
                   memory-prediction accuracy)
  frenzy top      [--addr A] [--interval S] [--iterations N]
                  (live dashboard over /metrics + /v1/report: jobs, scheduler
                   round-phase latency quantiles, HTTP routes, WAL health,
                   device memory; --iterations 1 prints one frame and exits)
  frenzy metrics  [--addr A] [--check]   (dump the raw Prometheus exposition;
                   --check validates conformance instead of printing)
  frenzy version  [--addr A]    (build identity: crate version, git sha,
                   features; with --addr also the server's — also
                   `frenzy --version`)
  frenzy predict  --model <name> --batch <B> [--addr A | --cluster real|sim]
  frenzy scale    --join --gpu <type> [--count N] [--link nvlink|pcie] [--addr A]
  frenzy scale    --leave <node> [--addr A]   (graceful drain + checkpoint)
  frenzy simulate --workload newworkload|philly|helios|synth:<spec> --tasks <n>
                  --sched has|sia|opportunistic [--cluster real|sim] [--seed S]
  frenzy replay   --workload <w> --tasks <n> [--speedup X] [--stub-ms M]
                  [--sched has|sia|opportunistic] [--round-interval S]
                  [--cluster real|sim] [--seed S]
                  [--tenant-weights a=2,b=1]   (trace through the LIVE engine)
  frenzy replay   --workload <w> --tasks <n> --addr <host:port>
                  (same trace against a REMOTE frenzy serve over HTTP)
  frenzy train    --model gpt2-tiny [--steps N]
  frenzy fig4 | fig5a | fig5b | fig6 | figures
  frenzy trace    --workload <w> --n <n> --out <file> [--seed S]
  frenzy models | clusters

Workloads: newworkload | philly | helios | a trace file path | synth[:<spec>].
The synth generator is a seeded open-world workload: e.g.
  synth:seed=42,jobs=200,arrivals=poisson:0.5,dur=lognormal:6.0:1.4,tenants=8
(see EXPERIMENTS.md \"Generating a workload\" for the full grammar).

The serverless commands talk to a running `frenzy serve` over the v1 HTTP
API (documented in API.md)."
}

fn dispatch(args: &Args) -> Result<()> {
    // `frenzy --version` with no subcommand — conventional spelling of
    // `frenzy version`.
    if args.command.is_none() && args.flag("version") {
        return commands::cmd_version(args);
    }
    match args.command.as_deref() {
        None | Some("help") => {
            println!("{}", usage());
            Ok(())
        }
        Some("models") => {
            let mut t = Table::new(&["name", "params (W)", "hidden", "layers", "heads", "seq"]);
            for m in frenzy::config::model_zoo() {
                t.row(&[
                    m.name.to_string(),
                    format!("{:.1}M", m.param_count() as f64 / 1e6),
                    m.hidden.to_string(),
                    m.layers.to_string(),
                    m.heads.to_string(),
                    m.seq_len.to_string(),
                ]);
            }
            println!("{}", t.render());
            Ok(())
        }
        Some("clusters") => {
            for name in ["real-testbed", "sia-sim"] {
                let c = cluster_by_name(name).unwrap();
                println!("{}:", c.name);
                for n in &c.nodes {
                    println!("  {} x{} ({:?})", n.gpu.name, n.count, n.link);
                }
            }
            Ok(())
        }
        Some("predict") => commands::cmd_predict(args),
        Some("submit") => commands::cmd_submit(args),
        Some("status") => commands::cmd_status(args),
        Some("cancel") => commands::cmd_cancel(args),
        Some("list") => commands::cmd_list(args),
        Some("events") => commands::cmd_events(args),
        Some("report") => commands::cmd_report(args),
        Some("top") => commands::cmd_top(args),
        Some("metrics") => commands::cmd_metrics(args),
        Some("version") => commands::cmd_version(args),
        Some("scale") => commands::cmd_scale(args),
        Some("serve") => commands::cmd_serve(args),
        Some("replay") => commands::cmd_replay(args),
        Some("simulate") => {
            let cluster = commands::cluster_arg(args)?;
            let n: usize = args.opt_parse_or("tasks", 30)?;
            let seed: u64 = args.opt_parse_or("seed", 11)?;
            let workload = args.opt_or("workload", "newworkload");
            let jobs = commands::load_workload(workload, n, seed)?;
            let sched_name = args.opt_or("sched", "has");
            let mut sched: Box<dyn Scheduler> = match sched_name {
                "has" | "frenzy" => Box::new(Has::new(Marp::with_defaults(cluster.clone()))),
                "sia" => Box::new(Sia::new(&cluster)),
                "opportunistic" | "opp" => Box::new(Opportunistic::new(&cluster)),
                other => bail!("unknown scheduler '{other}'"),
            };
            let report = simulate(&cluster, sched.as_mut(), &jobs, SimConfig::default(), workload);
            let mut t = Table::new(&["metric", "value"]).with_title(&format!(
                "simulation: {} on {} ({} jobs)",
                sched_name,
                cluster.name,
                jobs.len()
            ));
            t.row_str(&["completed", &report.n_completed.to_string()]);
            t.row_str(&["rejected", &report.n_rejected.to_string()]);
            t.row_str(&["avg JCT", &fmt_duration(report.avg_jct_s)]);
            t.row_str(&["p50 JCT", &fmt_duration(report.p50_jct_s)]);
            t.row_str(&["p99 JCT", &fmt_duration(report.p99_jct_s)]);
            t.row_str(&["avg queue", &fmt_duration(report.avg_queue_s)]);
            t.row_str(&["avg samples/s/job", &format!("{:.3}", report.avg_samples_per_sec)]);
            t.row_str(&["makespan", &fmt_duration(report.makespan_s)]);
            t.row_str(&["OOM retries", &report.total_oom_retries.to_string()]);
            t.row_str(&["sched overhead (wall)", &fmt_duration(report.sched_overhead_s)]);
            t.row_str(&["utilization", &format!("{:.1}%", report.avg_utilization * 100.0)]);
            println!("{}", t.render());
            Ok(())
        }
        Some("train") => {
            let model = args.opt_or("model", "gpt2-tiny");
            let steps: u64 = args.opt_parse_or("steps", 30)?;
            let manifest = frenzy::runtime::Manifest::load(frenzy::util::repo_path("artifacts"))?;
            let meta = manifest.model(model)?;
            let mut rt = frenzy::runtime::Runtime::new()?;
            println!("platform: {}", rt.platform());
            let mut session = rt.start_session(meta)?;
            let t0 = std::time::Instant::now();
            for s in 0..steps {
                let loss = session.step()?;
                if s % 5 == 0 || s + 1 == steps {
                    println!("step {s:4}  loss {loss:.4}");
                }
            }
            let dt = t0.elapsed().as_secs_f64();
            session.check_oracle()?;
            println!(
                "{steps} steps in {} ({:.1} steps/s); python-oracle check: ok",
                fmt_duration(dt),
                steps as f64 / dt,
            );
            Ok(())
        }
        Some("trace") => {
            let workload = args.opt_or("workload", "newworkload");
            let n: usize = args.opt_parse_or("n", 100)?;
            let seed: u64 = args.opt_parse_or("seed", 11)?;
            let out = args.require("out")?;
            let jobs = commands::load_workload(workload, n, seed)?;
            trace::save(out, &jobs)?;
            let stats = frenzy::workload::trace_stats(&jobs);
            println!("wrote {} jobs to {out} (span {})", stats.n_jobs, fmt_duration(stats.span_s));
            Ok(())
        }
        Some("fig4") => {
            frenzy::exp::fig4::report();
            Ok(())
        }
        Some("fig5a") => {
            frenzy::exp::fig5a::report();
            Ok(())
        }
        Some("fig5b") => {
            frenzy::exp::fig5b::report();
            Ok(())
        }
        Some("fig6") => {
            frenzy::exp::fig6::report();
            Ok(())
        }
        Some("figures") => {
            frenzy::exp::fig6::report();
            frenzy::exp::fig5a::report();
            frenzy::exp::fig4::report();
            frenzy::exp::fig5b::report();
            Ok(())
        }
        Some(other) => {
            eprintln!("{}", usage());
            bail!("unknown command '{other}'")
        }
    }
}
