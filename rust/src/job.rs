//! Job model shared by the serverless front-end, schedulers, and simulator.

use crate::config::ModelConfig;
use crate::memory::TrainConfig;
use crate::util::json::Json;

/// Unique job identifier.
pub type JobId = u64;

/// A user-submitted training job — exactly what the serverless API takes:
/// the model hyper-parameters and training configuration. **No GPU counts or
/// types** — that is Frenzy's whole point.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub id: JobId,
    pub name: String,
    pub model: ModelConfig,
    pub train: TrainConfig,
    /// Total number of samples the job must process (steps × global batch).
    pub total_samples: u64,
    /// Submission time (seconds since simulation / server start).
    pub submit_time: f64,
    /// Tenant (quota principal) the job belongs to; empty = anonymous.
    /// Drives the weighted-fair pending ordering and the per-tenant report
    /// breakdowns.
    pub tenant: String,
}

impl JobSpec {
    pub fn new(
        id: JobId,
        model: ModelConfig,
        global_batch: u32,
        total_samples: u64,
        submit_time: f64,
    ) -> Self {
        Self {
            id,
            name: format!("{}-b{}-#{}", model.name, global_batch, id),
            model,
            train: TrainConfig { global_batch },
            total_samples,
            submit_time,
            tenant: String::new(),
        }
    }

    /// Attribute the job to a tenant (builder style; empty = anonymous).
    pub fn with_tenant(mut self, tenant: &str) -> Self {
        self.tenant = tenant.to_string();
        self
    }

    /// Serialize for the durability WAL. The model is stored by name —
    /// every `ModelConfig` comes from the static model table (`name` is
    /// `&'static str`), so the name is a complete reference.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("id", self.id)
            .set("name", self.name.as_str())
            .set("model", self.model.name)
            .set("global_batch", self.train.global_batch)
            .set("total_samples", self.total_samples)
            .set("submit_time", self.submit_time);
        // Emitted only when set: tenantless specs serialize byte-identically
        // to the pre-tenancy format (snapshot/WAL determinism tests rely on
        // stable bytes).
        if !self.tenant.is_empty() {
            j.set("tenant", self.tenant.as_str());
        }
        j
    }

    /// Rebuild from [`JobSpec::to_json`] output.
    pub fn from_json(j: &Json) -> Result<JobSpec, String> {
        let model_name =
            j.get("model").and_then(Json::as_str).ok_or("job spec: missing 'model'")?;
        let model = crate::config::models::model_by_name(model_name)
            .ok_or_else(|| format!("job spec: unknown model '{model_name}'"))?;
        Ok(JobSpec {
            id: j.get("id").and_then(Json::as_u64).ok_or("job spec: missing 'id'")?,
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or("job spec: missing 'name'")?
                .to_string(),
            model,
            train: TrainConfig {
                global_batch: j
                    .get("global_batch")
                    .and_then(Json::as_u64)
                    .and_then(|b| u32::try_from(b).ok())
                    .ok_or("job spec: missing 'global_batch'")?,
            },
            total_samples: j
                .get("total_samples")
                .and_then(Json::as_u64)
                .ok_or("job spec: missing 'total_samples'")?,
            submit_time: j
                .get("submit_time")
                .and_then(Json::as_f64)
                .ok_or("job spec: missing 'submit_time'")?,
            // Back-compat: journals written before tenancy carry no tenant.
            tenant: j
                .get("tenant")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
        })
    }
}

/// Lifecycle states of a job inside the serverless system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, waiting for resources.
    Queued,
    /// Resources allocated, training in progress.
    Running,
    /// All samples processed; resources released.
    Completed,
    /// MARP found no feasible configuration on this cluster.
    Rejected,
    /// Cancelled by the user (via `POST /v1/jobs/<id>/cancel`); resources
    /// released, any in-flight training result is discarded.
    Cancelled,
}

impl JobState {
    /// Terminal states never transition again; drain waits for all jobs to
    /// become terminal.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Completed | JobState::Rejected | JobState::Cancelled)
    }
}

/// Completion record used for JCT/QT metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct JobOutcome {
    pub id: JobId,
    pub name: String,
    pub submit_time: f64,
    pub start_time: f64,
    pub finish_time: f64,
    pub gpus_used: u32,
    /// Average samples/s while running.
    pub samples_per_sec: f64,
    /// Number of scheduling attempts (OOM retries under baselines > 1).
    pub attempts: u32,
}

impl JobOutcome {
    /// Queue time: submission → start.
    pub fn queue_time(&self) -> f64 {
        self.start_time - self.submit_time
    }

    /// Job completion time: submission → finish (the paper's JCT).
    pub fn jct(&self) -> f64 {
        self.finish_time - self.submit_time
    }

    /// Pure runtime.
    pub fn run_time(&self) -> f64 {
        self.finish_time - self.start_time
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::model_by_name;

    #[test]
    fn outcome_times() {
        let o = JobOutcome {
            id: 1,
            name: "j".into(),
            submit_time: 10.0,
            start_time: 25.0,
            finish_time: 100.0,
            gpus_used: 4,
            samples_per_sec: 3.0,
            attempts: 1,
        };
        assert_eq!(o.queue_time(), 15.0);
        assert_eq!(o.jct(), 90.0);
        assert_eq!(o.run_time(), 75.0);
    }

    #[test]
    fn terminal_states() {
        assert!(!JobState::Queued.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Completed.is_terminal());
        assert!(JobState::Rejected.is_terminal());
        assert!(JobState::Cancelled.is_terminal());
    }

    #[test]
    fn job_name_encodes_model_and_batch() {
        let j = JobSpec::new(7, model_by_name("gpt2-350m").unwrap(), 8, 1000, 0.0);
        assert!(j.name.contains("gpt2-350m"));
        assert!(j.name.contains("b8"));
    }

    #[test]
    fn spec_json_roundtrip_is_exact() {
        let j = JobSpec::new(7, model_by_name("gpt2-350m").unwrap(), 8, 1000, 12.625);
        let back = JobSpec::from_json(&j.to_json()).expect("roundtrip");
        assert_eq!(back, j);
        // Fractional submit times survive the JSON f64 path exactly.
        assert_eq!(back.submit_time, 12.625);
        // Unknown models are rejected, not silently substituted.
        let mut bad = j.to_json();
        bad.set("model", "not-a-model");
        assert!(JobSpec::from_json(&bad).is_err());
    }

    #[test]
    fn tenant_roundtrips_and_defaults_empty() {
        let j = JobSpec::new(7, model_by_name("gpt2-350m").unwrap(), 8, 1000, 0.0)
            .with_tenant("team-a");
        let back = JobSpec::from_json(&j.to_json()).expect("roundtrip");
        assert_eq!(back.tenant, "team-a");
        assert_eq!(back, j);
        // Tenantless specs serialize without the field (byte-stable with
        // pre-tenancy journals) and old records restore to anonymous.
        let anon = JobSpec::new(1, model_by_name("gpt2-125m").unwrap(), 4, 100, 0.0);
        assert!(anon.to_json().get("tenant").is_none());
        assert_eq!(JobSpec::from_json(&anon.to_json()).unwrap().tenant, "");
    }
}
