//! Crash durability for the live coordinator: WAL + snapshots + recovery.
//!
//! The coordinator's entire state is a deterministic fold over its
//! [`crate::engine::ClusterEvent`] stream (plus a handful of
//! coordinator-local facts: admission rejects and training losses). This
//! module makes that stream durable:
//!
//! * [`wal`] — an append-only, checksummed, segmented log of every
//!   transition, written **before** the transition's effects are visible
//!   anywhere else (persist-before-effect: an acked submit is on disk);
//! * [`snapshot`] — periodic atomic full-state snapshots keyed by the
//!   last WAL sequence they cover, bounding replay time and letting old
//!   segments be pruned;
//! * [`recovery`] — on restart, restore the newest snapshot and replay
//!   the WAL tail through the *same* event-application path live
//!   operation uses, then re-arm timers and resume.
//!
//! Everything here is std-only: records are the crate's own compact JSON
//! framed with a length and a CRC-32.

pub mod recovery;
pub mod snapshot;
pub mod wal;

pub use recovery::{recover, Recovered, TailStep};
pub use snapshot::SnapshotStore;
pub use wal::{Wal, WalRecord};

use crate::engine::{ClusterEvent, Journal};
use crate::util::json::Json;
use std::cell::RefCell;
use std::fmt;
use std::rc::Rc;

/// When appended WAL records are fsynced to disk. Any policy survives a
/// process kill (appends reach the kernel page cache synchronously); the
/// policy only governs exposure to whole-machine crashes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FsyncPolicy {
    /// fsync after every record. Safest, slowest.
    Always,
    /// fsync once per `n` records (default: 32).
    EveryN(u32),
    /// fsync when at least this many seconds passed since the last one.
    IntervalS(f64),
}

impl FsyncPolicy {
    /// Parse the `--fsync` CLI form: `always`, `every:<n>`, or
    /// `interval:<secs>`.
    pub fn parse(s: &str) -> Result<FsyncPolicy, String> {
        if s == "always" {
            return Ok(FsyncPolicy::Always);
        }
        if let Some(n) = s.strip_prefix("every:") {
            let n: u32 = n.parse().map_err(|_| format!("bad fsync record count '{n}'"))?;
            if n == 0 {
                return Err("fsync every:0 is invalid (use 'always')".into());
            }
            return Ok(FsyncPolicy::EveryN(n));
        }
        if let Some(secs) = s.strip_prefix("interval:") {
            let v: f64 = secs.parse().map_err(|_| format!("bad fsync interval '{secs}'"))?;
            if !v.is_finite() || v <= 0.0 {
                return Err(format!("fsync interval must be positive, got '{secs}'"));
            }
            return Ok(FsyncPolicy::IntervalS(v));
        }
        Err(format!("unknown fsync policy '{s}' (expected always | every:<n> | interval:<secs>)"))
    }
}

impl fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FsyncPolicy::Always => write!(f, "always"),
            FsyncPolicy::EveryN(n) => write!(f, "every:{n}"),
            FsyncPolicy::IntervalS(s) => write!(f, "interval:{s}"),
        }
    }
}

/// The engine's [`Journal`] sink backed by a [`Wal`] shared with the
/// coordinator (which appends its own coordinator-only records to the
/// same log).
///
/// A failed append panics: the engine has not yet applied the event, and
/// a durable coordinator that cannot write its log must stop rather than
/// silently diverge from its own recovery story.
pub struct SharedJournal(pub Rc<RefCell<Wal>>);

impl Journal for SharedJournal {
    fn event(&mut self, time: f64, ev: &ClusterEvent) {
        self.0
            .borrow_mut()
            .append(&WalRecord::Event { time, ev: ev.clone() })
            .expect("durability: WAL append failed");
    }

    fn round(&mut self, time: f64, sched_wall_s: f64) {
        self.0
            .borrow_mut()
            .append(&WalRecord::Round { time, wall_s: sched_wall_s })
            .expect("durability: WAL append failed");
    }
}

/// Durability state reported by `GET /v1/durability`.
#[derive(Debug, Clone, PartialEq)]
pub struct DurabilityStatus {
    /// False when the server runs without `--data-dir` (pure in-memory).
    pub enabled: bool,
    /// Last WAL sequence number written (0 when empty or disabled).
    pub last_seq: u64,
    /// Total bytes across live WAL segments.
    pub wal_bytes: u64,
    /// Number of live WAL segments.
    pub wal_segments: u64,
    /// WAL sequence covered by the newest snapshot, if one exists.
    pub snapshot_seq: Option<u64>,
    /// Engine-time seconds since the newest snapshot was taken.
    pub snapshot_age_s: Option<f64>,
}

impl DurabilityStatus {
    pub fn disabled() -> Self {
        Self {
            enabled: false,
            last_seq: 0,
            wal_bytes: 0,
            wal_segments: 0,
            snapshot_seq: None,
            snapshot_age_s: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("enabled", self.enabled)
            .set("last_seq", self.last_seq)
            .set("wal_bytes", self.wal_bytes)
            .set("wal_segments", self.wal_segments);
        if let Some(seq) = self.snapshot_seq {
            j.set("snapshot_seq", seq);
        }
        if let Some(age) = self.snapshot_age_s {
            j.set("snapshot_age_s", age);
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fsync_policy_parse_and_display_roundtrip() {
        for (s, want) in [
            ("always", FsyncPolicy::Always),
            ("every:1", FsyncPolicy::EveryN(1)),
            ("every:64", FsyncPolicy::EveryN(64)),
            ("interval:0.5", FsyncPolicy::IntervalS(0.5)),
        ] {
            let got = FsyncPolicy::parse(s).unwrap();
            assert_eq!(got, want);
            assert_eq!(FsyncPolicy::parse(&got.to_string()).unwrap(), got);
        }
        for bad in ["", "never", "every:0", "every:x", "interval:-1", "interval:nan"] {
            assert!(FsyncPolicy::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn status_json_omits_absent_snapshot() {
        let d = DurabilityStatus::disabled();
        let j = d.to_json();
        assert_eq!(j.get("enabled").and_then(Json::as_bool), Some(false));
        assert!(j.get("snapshot_seq").is_none());
        let full = DurabilityStatus {
            enabled: true,
            last_seq: 41,
            wal_bytes: 1024,
            wal_segments: 2,
            snapshot_seq: Some(30),
            snapshot_age_s: Some(12.5),
        };
        let j = full.to_json();
        assert_eq!(j.get("last_seq").and_then(Json::as_u64), Some(41));
        assert_eq!(j.get("snapshot_seq").and_then(Json::as_u64), Some(30));
        assert_eq!(j.get("snapshot_age_s").and_then(Json::as_f64), Some(12.5));
    }
}
