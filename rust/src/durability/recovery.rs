//! Snapshot + WAL-tail replay.
//!
//! Recovery is **pure replay**: the only code path that mutates engine
//! state here is the same [`ClusterEvent`] application path
//! (`SchedulingEngine::handle` / `replay_round`) that live operation
//! uses. There is no special-case recovery mutation — a bug class this
//! module refuses to admit by construction.
//!
//! The sequence on `frenzy serve --data-dir`:
//!
//! 1. load the newest valid snapshot (if any) and restore the engine
//!    from its `engine` section — a pure deserialization of state the
//!    engine itself wrote;
//! 2. replay every WAL record with `seq >` the snapshot's covered
//!    sequence, under a [`ReplayClock`] pinned to each record's
//!    timestamp, collecting the [`Effects`] each step produced;
//! 3. hand the coordinator the snapshot's `coord` section plus the
//!    per-step effects so it can fold its own job table forward.
//!
//! After `recover` returns, the caller re-arms live timers from
//! `SchedulingEngine::rearm_effects` and attaches the journal — in that
//! order, so replay itself is never re-journaled.

use super::wal::WalRecord;
use crate::engine::clock::ReplayClock;
use crate::engine::events::{EventKind, RejectReason};
use crate::engine::{Effects, SchedulingEngine};
use crate::util::json::Json;

/// One replayed WAL record plus what applying it produced. `effects` is
/// `None` for records that are coordinator-only bookkeeping (losses,
/// admission rejects) and never reach the engine's event path.
pub struct TailStep {
    pub seq: u64,
    pub rec: WalRecord,
    pub effects: Option<Effects>,
}

/// Everything recovery reconstructs.
pub struct Recovered {
    /// Highest sequence number applied (snapshot or tail); 0 for a cold
    /// start on an empty data dir.
    pub last_seq: u64,
    /// Engine time reached — the floor for the resumed wall clock.
    pub engine_time: f64,
    /// The snapshot's coordinator section, if a snapshot was loaded.
    pub coord: Option<Json>,
    /// WAL records replayed past the snapshot, in order, with effects.
    pub tail: Vec<TailStep>,
}

/// Restore `engine` from `snapshot` (if present) and replay `records`
/// through it. `records` must be the full WAL contents in sequence
/// order; entries at or below the snapshot's covered sequence are
/// skipped.
pub fn recover(
    engine: &mut SchedulingEngine<'_>,
    snapshot: Option<(u64, Json)>,
    records: Vec<(u64, WalRecord)>,
) -> Result<Recovered, String> {
    let mut last_seq = 0u64;
    let mut engine_time = 0.0f64;
    let mut coord = None;
    if let Some((seq, state)) = snapshot {
        let ej = state.get("engine").ok_or("snapshot: missing 'engine' section")?;
        engine.restore_from_json(ej)?;
        engine_time = state
            .get("time")
            .and_then(Json::as_f64)
            .ok_or("snapshot: missing 'time'")?;
        coord = state.get("coord").cloned();
        last_seq = seq;
    }
    let mut clock = ReplayClock::new();
    let mut tail = Vec::new();
    for (seq, rec) in records {
        if seq <= last_seq {
            continue; // covered by the snapshot
        }
        if seq != last_seq + 1 && last_seq != 0 {
            return Err(format!(
                "recovery: WAL continues at seq {seq} but snapshot/tail ends at {last_seq}"
            ));
        }
        let effects = match &rec {
            WalRecord::Event { time, ev } => {
                clock.set(*time);
                engine_time = engine_time.max(*time);
                Some(engine.handle(ev.clone(), &mut clock))
            }
            WalRecord::Round { time, wall_s } => {
                engine_time = engine_time.max(*time);
                Some(engine.replay_round(*time, *wall_s))
            }
            WalRecord::AdmissionReject { time, job, .. } => {
                // The reject never became an Arrival; its only engine
                // trace is the audit-log record the live path wrote.
                engine_time = engine_time.max(*time);
                engine.record_event(
                    *time,
                    EventKind::Rejected { job: *job, reason: RejectReason::AdmissionInfeasible },
                );
                None
            }
            WalRecord::Losses { .. } => None, // coordinator-only
        };
        last_seq = seq;
        tail.push(TailStep { seq, rec, effects });
    }
    Ok(Recovered { last_seq, engine_time, coord, tail })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::model_by_name;
    use crate::config::real_testbed;
    use crate::engine::clock::{Clock, VirtualClock};
    use crate::engine::{ClusterEvent, EngineConfig, SchedulingEngine};
    use crate::job::JobSpec;
    use crate::marp::Marp;
    use crate::sched::has::Has;

    fn spec_job(id: u64, t: f64) -> JobSpec {
        JobSpec::new(id, model_by_name("gpt2-350m").unwrap(), 8, 2_000, t)
    }

    /// Drive an engine through a short run while logging the would-be WAL,
    /// then recover a fresh engine from (a) nothing and (b) a midpoint
    /// snapshot, and check both converge to the same state.
    #[test]
    fn full_replay_and_snapshot_tail_replay_agree() {
        let spec = real_testbed();
        let cfg = EngineConfig::default();

        // Reference run, journaling by hand into `records`.
        let mut records: Vec<(u64, WalRecord)> = Vec::new();
        let mut seq = 0u64;
        let mut snapshot: Option<(u64, Json)> = None;
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, cfg.clone());
        let mut clock = VirtualClock::new();
        for id in 1..=3 {
            let ev = ClusterEvent::Arrival(spec_job(id, 0.0));
            seq += 1;
            records.push((seq, WalRecord::Event { time: 0.0, ev: ev.clone() }));
            engine.handle(ev, &mut clock);
        }
        seq += 1;
        records.push((seq, WalRecord::Round { time: 0.0, wall_s: 0.0 }));
        engine.replay_round(0.0, 0.0);
        while let Some((t, ev)) = clock.pop() {
            seq += 1;
            records.push((seq, WalRecord::Event { time: t, ev: ev.clone() }));
            engine.handle(ev, &mut clock);
            if snapshot.is_none() && engine.aggregates().n_completed >= 1 {
                let mut j = Json::obj();
                j.set("time", t).set("engine", engine.snapshot_json());
                snapshot = Some((seq, j));
            }
            seq += 1;
            records.push((seq, WalRecord::Round { time: t, wall_s: 0.0 }));
            engine.replay_round(t, 0.0);
        }
        assert_eq!(engine.aggregates().n_completed, 3);
        let want = engine.snapshot_json().to_string_compact();
        let end_time = clock.now();
        drop(engine);

        // (a) Full replay from an empty data dir.
        let mut has_a = Has::new(Marp::with_defaults(spec.clone()));
        let mut a = SchedulingEngine::new(&spec, &mut has_a, cfg.clone());
        let got = recover(&mut a, None, records.clone()).unwrap();
        assert_eq!(got.last_seq, seq);
        assert_eq!(got.engine_time, end_time);
        assert!(got.coord.is_none());
        assert_eq!(a.snapshot_json().to_string_compact(), want);

        // (b) Snapshot + tail replay.
        let (snap_seq, _) = snapshot.clone().unwrap();
        let mut has_b = Has::new(Marp::with_defaults(spec.clone()));
        let mut b = SchedulingEngine::new(&spec, &mut has_b, cfg);
        let got = recover(&mut b, snapshot, records).unwrap();
        assert_eq!(got.last_seq, seq);
        assert!(got.tail.iter().all(|s| s.seq > snap_seq), "covered records skipped");
        assert_eq!(b.snapshot_json().to_string_compact(), want);
    }

    #[test]
    fn admission_reject_replays_into_the_audit_log_only() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let records = vec![(
            1u64,
            WalRecord::AdmissionReject {
                time: 2.5,
                job: 9,
                model: "gpt2-7b".into(),
                batch: 1,
                samples: 10,
                tenant: String::new(),
            },
        )];
        let got = recover(&mut engine, None, records).unwrap();
        assert_eq!(got.last_seq, 1);
        assert_eq!(got.engine_time, 2.5);
        assert!(got.tail[0].effects.is_none());
        assert_eq!(engine.pending_count() + engine.running_count(), 0);
        let page = engine.event_log().since(0, 100);
        assert!(page
            .events
            .iter()
            .any(|e| matches!(e.kind, EventKind::Rejected { job: 9, .. })));
    }

    #[test]
    fn sequence_gap_in_tail_is_rejected() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let records = vec![
            (1u64, WalRecord::Round { time: 0.0, wall_s: 0.0 }),
            (3u64, WalRecord::Round { time: 1.0, wall_s: 0.0 }),
        ];
        let err = recover(&mut engine, None, records).unwrap_err();
        assert!(err.contains("seq 3"), "got: {err}");
    }
}
