//! Append-only write-ahead log of coordinator transitions.
//!
//! Record framing (all integers little-endian):
//!
//! ```text
//! ┌─────────────┬─────────────┬──────────────────────────┐
//! │ len: u32 LE │ crc: u32 LE │ payload: len bytes (JSON) │
//! └─────────────┴─────────────┴──────────────────────────┘
//! ```
//!
//! `crc` is CRC-32 (IEEE) of the payload. The payload is one compact JSON
//! object carrying the record's monotonic sequence number plus its body —
//! self-describing, so a segment can be audited with nothing but `xxd` and
//! a JSON parser.
//!
//! Segments are named `wal-<first_seq>.log` (zero-padded so lexicographic
//! order is numeric order) and rotate at [`Wal::segment_bytes`]. On open,
//! a torn tail — a partial or checksum-failing record at the end of the
//! *last* segment, the signature of a crash mid-write — is truncated away;
//! the same damage in any earlier segment is a hard error, because bytes
//! before a successfully written successor segment cannot be a crash
//! artifact.

use super::FsyncPolicy;
use crate::engine::ClusterEvent;
use crate::job::JobId;
use crate::util::json::{self, Json};
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Default segment rotation threshold.
pub const DEFAULT_SEGMENT_BYTES: u64 = 1 << 20;

/// CRC-32 (IEEE 802.3), bitwise — no lookup table, no dependency. WAL
/// records are small and appends are dominated by the write syscall, so
/// the byte-at-a-time loop is not the bottleneck (measured in
/// `benches/bench_wal.rs`).
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// One durable coordinator transition.
#[derive(Debug, Clone)]
pub enum WalRecord {
    /// A [`ClusterEvent`] applied by the engine at `time` — journaled at
    /// the single point every event funnels through
    /// (`SchedulingEngine::handle`), *before* the event mutates state.
    Event { time: f64, ev: ClusterEvent },
    /// A scheduling round that ran with work queued at `time`; `wall_s` is
    /// the measured scheduler wall time it charged. Rounds are replayed by
    /// re-running the (deterministic) scheduler, not by storing decisions.
    Round { time: f64, wall_s: f64 },
    /// A submission MARP rejected at admission: it consumed a job id and
    /// an audit-log record but never produced an `Arrival`. `tenant` is the
    /// submit's quota principal (empty = anonymous; the field is omitted on
    /// the wire so pre-tenancy journals replay unchanged).
    AdmissionReject { time: f64, job: JobId, model: String, batch: u32, samples: u64, tenant: String },
    /// Training losses attached to a completed job (coordinator-local
    /// state the engine never sees).
    Losses { job: JobId, losses: Vec<(u64, f32)> },
}

impl WalRecord {
    fn to_json(&self, seq: u64) -> Json {
        let mut j = Json::obj();
        j.set("seq", seq);
        match self {
            WalRecord::Event { time, ev } => {
                j.set("kind", "event").set("time", *time).set("ev", ev.to_json());
            }
            WalRecord::Round { time, wall_s } => {
                j.set("kind", "round").set("time", *time).set("wall_s", *wall_s);
            }
            WalRecord::AdmissionReject { time, job, model, batch, samples, tenant } => {
                j.set("kind", "admission_reject")
                    .set("time", *time)
                    .set("job", *job)
                    .set("model", model.as_str())
                    .set("batch", *batch)
                    .set("samples", *samples);
                // Anonymous rejects keep the pre-tenancy record bytes.
                if !tenant.is_empty() {
                    j.set("tenant", tenant.as_str());
                }
            }
            WalRecord::Losses { job, losses } => {
                let ls: Vec<Json> = losses
                    .iter()
                    .map(|&(step, loss)| {
                        // A diverged run's NaN/inf loss has no JSON number
                        // form; null round-trips it.
                        let l = if loss.is_finite() { Json::from(loss as f64) } else { Json::Null };
                        Json::Arr(vec![Json::from(step), l])
                    })
                    .collect();
                j.set("kind", "losses").set("job", *job).set("losses", Json::Arr(ls));
            }
        }
        j
    }

    fn from_json(j: &Json) -> Result<(u64, WalRecord), String> {
        let seq = j.get("seq").and_then(Json::as_u64).ok_or("wal record: missing 'seq'")?;
        let kind = j.get("kind").and_then(Json::as_str).ok_or("wal record: missing 'kind'")?;
        let time = || j.get("time").and_then(Json::as_f64).ok_or("wal record: missing 'time'");
        let job = || j.get("job").and_then(Json::as_u64).ok_or("wal record: missing 'job'");
        let rec = match kind {
            "event" => WalRecord::Event {
                time: time()?,
                ev: ClusterEvent::from_json(j.get("ev").ok_or("wal event: missing 'ev'")?)?,
            },
            "round" => WalRecord::Round {
                time: time()?,
                wall_s: j
                    .get("wall_s")
                    .and_then(Json::as_f64)
                    .ok_or("wal round: missing 'wall_s'")?,
            },
            "admission_reject" => WalRecord::AdmissionReject {
                time: time()?,
                job: job()?,
                model: j
                    .get("model")
                    .and_then(Json::as_str)
                    .ok_or("wal reject: missing 'model'")?
                    .to_string(),
                batch: j
                    .get("batch")
                    .and_then(Json::as_u64)
                    .and_then(|b| u32::try_from(b).ok())
                    .ok_or("wal reject: missing 'batch'")?,
                samples: j
                    .get("samples")
                    .and_then(Json::as_u64)
                    .ok_or("wal reject: missing 'samples'")?,
                // Absent on pre-tenancy journals → anonymous.
                tenant: j.get("tenant").and_then(Json::as_str).unwrap_or("").to_string(),
            },
            "losses" => {
                let arr = j
                    .get("losses")
                    .and_then(Json::as_arr)
                    .ok_or("wal losses: missing 'losses'")?;
                let mut losses = Vec::with_capacity(arr.len());
                for e in arr {
                    let Some([step, loss]) = e.as_arr() else {
                        return Err("wal losses: bad entry".into());
                    };
                    let step = step.as_u64().ok_or("wal losses: bad step")?;
                    let loss = match loss {
                        Json::Null => f32::NAN,
                        other => other.as_f64().ok_or("wal losses: bad loss")? as f32,
                    };
                    losses.push((step, loss));
                }
                WalRecord::Losses { job: job()?, losses }
            }
            other => return Err(format!("wal record: unknown kind '{other}'")),
        };
        Ok((seq, rec))
    }
}

fn seg_name(first_seq: u64) -> String {
    format!("wal-{first_seq:020}.log")
}

/// `wal-*.log` segments under `dir`, ascending by first sequence number.
fn list_segments(dir: &Path) -> Result<Vec<(u64, PathBuf)>, String> {
    let mut segs = Vec::new();
    let entries =
        fs::read_dir(dir).map_err(|e| format!("wal: read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("wal: read dir entry: {e}"))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(seq) = name.strip_prefix("wal-").and_then(|s| s.strip_suffix(".log")) else {
            continue;
        };
        let Ok(first) = seq.parse::<u64>() else { continue };
        segs.push((first, entry.path()));
    }
    segs.sort();
    Ok(segs)
}

/// Parse one segment. Returns the decoded records, the byte offset of the
/// last valid record's end, and the file's total length — a gap between
/// the two is a torn tail.
fn read_segment(path: &Path) -> Result<(Vec<(u64, WalRecord)>, u64, u64), String> {
    let data = fs::read(path).map_err(|e| format!("wal: read {}: {e}", path.display()))?;
    let mut recs = Vec::new();
    let mut off = 0usize;
    while off + 8 <= data.len() {
        let len = u32::from_le_bytes(data[off..off + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(data[off + 4..off + 8].try_into().unwrap());
        let end = match (off + 8).checked_add(len) {
            Some(e) if e <= data.len() => e,
            _ => break, // partial record: torn tail
        };
        let payload = &data[off + 8..end];
        if crc32(payload) != crc {
            break; // checksum mismatch: everything from here is suspect
        }
        // The payload passed its checksum: a parse failure here is not
        // crash damage but a format bug or version skew — surface it.
        let text = std::str::from_utf8(payload)
            .map_err(|e| format!("wal {}: non-UTF8 payload: {e}", path.display()))?;
        let j = json::parse(text).map_err(|e| format!("wal {}: bad payload: {e}", path.display()))?;
        recs.push(WalRecord::from_json(&j)?);
        off = end;
    }
    Ok((recs, off as u64, data.len() as u64))
}

/// The append-only log. One instance owns the directory; all appends go
/// through it so sequence numbers stay dense and monotonic.
pub struct Wal {
    dir: PathBuf,
    policy: FsyncPolicy,
    /// Active (last) segment, opened for append.
    file: File,
    seg_path: PathBuf,
    bytes_in_seg: u64,
    /// Rotation threshold; see [`DEFAULT_SEGMENT_BYTES`]. Exposed for
    /// tests that exercise rotation without writing a mebibyte.
    pub segment_bytes: u64,
    /// Fail the next N appends with an injected I/O error *before* any
    /// bytes reach the file — the error path of a full disk or pulled
    /// volume. Exposed (like [`Wal::segment_bytes`]) so tests can prove a
    /// failed append corrupts nothing.
    pub fail_appends: u32,
    /// Fail the next N fsyncs with an injected I/O error. The written
    /// bytes stay in the kernel; only the durability acknowledgment fails.
    pub fail_syncs: u32,
    next_seq: u64,
    total_bytes: u64,
    segments: usize,
    unsynced: u32,
    last_sync: Instant,
    /// Reused frame buffer: each append serializes header + payload here
    /// instead of allocating a fresh `String` and `Vec` per record.
    scratch: Vec<u8>,
    /// Inside a [`Wal::begin_group`] window, policy-driven fsyncs are
    /// deferred to [`Wal::end_group`].
    in_group: bool,
}

impl Wal {
    /// Open (or create) the WAL under `dir`, recovering its tail: returns
    /// the handle positioned for appending plus every valid record on
    /// disk, in sequence order. A torn tail on the last segment is
    /// truncated; torn bytes anywhere else are a hard error.
    pub fn open(dir: &Path, policy: FsyncPolicy) -> Result<(Wal, Vec<(u64, WalRecord)>), String> {
        fs::create_dir_all(dir).map_err(|e| format!("wal: create {}: {e}", dir.display()))?;
        let segs = list_segments(dir)?;
        let mut records: Vec<(u64, WalRecord)> = Vec::new();
        let mut next_seq = segs.first().map_or(1, |&(first, _)| first);
        let mut total_bytes = 0u64;
        for (i, (first, path)) in segs.iter().enumerate() {
            let last = i + 1 == segs.len();
            let (recs, valid, total) = read_segment(path)?;
            if valid != total {
                if !last {
                    return Err(format!(
                        "wal: segment {} is damaged mid-log ({} of {} bytes valid) — only the \
                         final segment may have a torn tail",
                        path.display(),
                        valid,
                        total
                    ));
                }
                let f = OpenOptions::new()
                    .write(true)
                    .open(path)
                    .map_err(|e| format!("wal: open {}: {e}", path.display()))?;
                f.set_len(valid).map_err(|e| format!("wal: truncate {}: {e}", path.display()))?;
                f.sync_all().map_err(|e| format!("wal: sync {}: {e}", path.display()))?;
            }
            if recs.first().is_some_and(|&(seq, _)| seq != *first) {
                return Err(format!(
                    "wal: segment {} starts at seq {} but is named for {}",
                    path.display(),
                    recs[0].0,
                    first
                ));
            }
            for (seq, rec) in recs {
                if seq != next_seq {
                    return Err(format!("wal: sequence gap: expected {next_seq}, found {seq}"));
                }
                next_seq += 1;
                records.push((seq, rec));
            }
            total_bytes += valid;
        }
        let (seg_path, bytes_in_seg) = match segs.last() {
            Some((_, path)) => {
                let len = fs::metadata(path)
                    .map_err(|e| format!("wal: stat {}: {e}", path.display()))?
                    .len();
                (path.clone(), len)
            }
            None => (dir.join(seg_name(next_seq)), 0),
        };
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&seg_path)
            .map_err(|e| format!("wal: open {}: {e}", seg_path.display()))?;
        let segments = segs.len().max(1);
        Ok((
            Wal {
                dir: dir.to_path_buf(),
                policy,
                file,
                seg_path,
                bytes_in_seg,
                segment_bytes: DEFAULT_SEGMENT_BYTES,
                fail_appends: 0,
                fail_syncs: 0,
                next_seq,
                total_bytes,
                segments,
                unsynced: 0,
                last_sync: Instant::now(),
                scratch: Vec::new(),
                in_group: false,
            },
            records,
        ))
    }

    /// Append one record; returns the sequence number it was assigned.
    /// The write reaches the kernel before this returns (surviving a
    /// process kill); reaching the *disk* is governed by the
    /// [`FsyncPolicy`].
    pub fn append(&mut self, rec: &WalRecord) -> Result<u64, String> {
        let seq = self.next_seq;
        // Serialize the payload straight after an 8-byte header slot in
        // the reusable scratch buffer, then patch len + crc in — no
        // per-record String or Vec allocation on the hot path.
        let mut buf = std::mem::take(&mut self.scratch);
        buf.clear();
        buf.extend_from_slice(&[0u8; 8]);
        rec.to_json(seq).write_compact(&mut buf);
        let payload_len = buf.len() - 8;
        let crc = crc32(&buf[8..]);
        buf[0..4].copy_from_slice(&(payload_len as u32).to_le_bytes());
        buf[4..8].copy_from_slice(&crc.to_le_bytes());
        if self.bytes_in_seg > 0 && self.bytes_in_seg + buf.len() as u64 > self.segment_bytes {
            if let Err(e) = self.rotate() {
                self.scratch = buf;
                return Err(e);
            }
        }
        if self.fail_appends > 0 {
            self.fail_appends -= 1;
            self.scratch = buf;
            return Err(format!(
                "wal: append to {}: injected I/O failure",
                self.seg_path.display()
            ));
        }
        let res = self
            .file
            .write_all(&buf)
            .map_err(|e| format!("wal: append to {}: {e}", self.seg_path.display()));
        let written = buf.len() as u64;
        self.scratch = buf;
        res?;
        self.bytes_in_seg += written;
        self.total_bytes += written;
        self.next_seq += 1;
        self.unsynced += 1;
        {
            let d = &crate::obs::reg().durability;
            d.wal_appends_total.inc();
            d.wal_append_bytes_total.add(written);
            d.wal_segments.set(self.segments as i64);
            d.wal_bytes.set(self.total_bytes as i64);
        }
        if !self.in_group {
            self.maybe_sync()?;
        }
        Ok(seq)
    }

    /// Begin a write group: appends inside the group defer policy-driven
    /// fsyncs until [`Wal::end_group`], so a batch costs at most one fsync
    /// (under [`FsyncPolicy::Always`]) instead of one per record.
    /// Persist-before-effect ordering is unchanged — every record still
    /// reaches the kernel before its `append` returns, and callers run
    /// `end_group` before acknowledging the batch. Groups do not nest.
    pub fn begin_group(&mut self) {
        debug_assert!(!self.in_group, "wal groups do not nest");
        self.in_group = true;
    }

    /// End a write group, applying the fsync policy once across everything
    /// appended since [`Wal::begin_group`]. Safe to call with nothing
    /// pending (a batch whose records were all rejected appends nothing).
    pub fn end_group(&mut self) -> Result<(), String> {
        self.in_group = false;
        self.maybe_sync()
    }

    fn maybe_sync(&mut self) -> Result<(), String> {
        if self.unsynced == 0 {
            return Ok(());
        }
        let due = match self.policy {
            FsyncPolicy::Always => true,
            FsyncPolicy::EveryN(n) => self.unsynced >= n.max(1),
            FsyncPolicy::IntervalS(s) => self.last_sync.elapsed().as_secs_f64() >= s,
        };
        if due {
            self.sync()?;
        }
        Ok(())
    }

    /// Force an fsync of the active segment.
    pub fn sync(&mut self) -> Result<(), String> {
        if self.fail_syncs > 0 {
            self.fail_syncs -= 1;
            return Err(format!(
                "wal: fsync {}: injected I/O failure",
                self.seg_path.display()
            ));
        }
        let t0 = Instant::now();
        self.file
            .sync_data()
            .map_err(|e| format!("wal: fsync {}: {e}", self.seg_path.display()))?;
        crate::obs::reg().durability.fsync_seconds.observe(t0.elapsed().as_secs_f64());
        self.unsynced = 0;
        self.last_sync = Instant::now();
        Ok(())
    }

    fn rotate(&mut self) -> Result<(), String> {
        self.sync()?;
        let path = self.dir.join(seg_name(self.next_seq));
        self.file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| format!("wal: open {}: {e}", path.display()))?;
        self.seg_path = path;
        self.bytes_in_seg = 0;
        self.segments += 1;
        Ok(())
    }

    /// Delete every segment whose records are *all* ≤ `seq` (covered by a
    /// snapshot). The active segment is never deleted. Returns how many
    /// segments were removed.
    pub fn prune_through(&mut self, seq: u64) -> Result<usize, String> {
        let segs = list_segments(&self.dir)?;
        let mut removed = 0;
        for i in 0..segs.len().saturating_sub(1) {
            // A segment's records all precede its successor's first seq.
            let next_first = segs[i + 1].0;
            if next_first <= seq + 1 && segs[i].1 != self.seg_path {
                let len = fs::metadata(&segs[i].1).map(|m| m.len()).unwrap_or(0);
                fs::remove_file(&segs[i].1)
                    .map_err(|e| format!("wal: remove {}: {e}", segs[i].1.display()))?;
                self.total_bytes = self.total_bytes.saturating_sub(len);
                self.segments -= 1;
                removed += 1;
            }
        }
        let d = &crate::obs::reg().durability;
        d.wal_segments.set(self.segments as i64);
        d.wal_bytes.set(self.total_bytes as i64);
        Ok(removed)
    }

    /// Sequence number the next append will get.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Sequence number of the most recent record (0 when empty).
    pub fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    /// Bytes across all live segments.
    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    /// Number of live segments.
    pub fn segment_count(&self) -> usize {
        self.segments
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::JobSpec;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("frenzy_wal_{name}"));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn ev(job: u64) -> WalRecord {
        WalRecord::Event { time: job as f64, ev: ClusterEvent::Finish { job, epoch: 1 } }
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn append_reopen_roundtrip_all_kinds() {
        let dir = tmp("roundtrip");
        let (mut wal, recs) = Wal::open(&dir, FsyncPolicy::EveryN(2)).unwrap();
        assert!(recs.is_empty());
        assert_eq!(wal.last_seq(), 0);
        let spec = JobSpec::new(
            3,
            crate::config::models::model_by_name("gpt2-350m").unwrap(),
            8,
            1000,
            0.5,
        );
        let records = vec![
            WalRecord::Event { time: 0.5, ev: ClusterEvent::Arrival(spec) },
            WalRecord::Round { time: 0.5, wall_s: 0.001 },
            WalRecord::AdmissionReject {
                time: 1.0,
                job: 4,
                model: "gpt2-7b".into(),
                batch: 2,
                samples: 100,
                tenant: "team-a".into(),
            },
            WalRecord::Losses { job: 3, losses: vec![(0, 4.5), (10, f32::NAN)] },
        ];
        for (i, r) in records.iter().enumerate() {
            assert_eq!(wal.append(r).unwrap(), i as u64 + 1, "dense seqs from 1");
        }
        drop(wal);
        let (wal, recs) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(wal.last_seq(), 4);
        assert_eq!(recs.len(), 4);
        assert!(matches!(&recs[0].1, WalRecord::Event { ev: ClusterEvent::Arrival(s), .. }
            if s.id == 3 && s.submit_time == 0.5));
        assert!(matches!(&recs[1].1, WalRecord::Round { wall_s, .. } if *wall_s == 0.001));
        assert!(matches!(&recs[2].1, WalRecord::AdmissionReject { model, tenant, .. }
            if model == "gpt2-7b" && tenant == "team-a"));
        match &recs[3].1 {
            WalRecord::Losses { job: 3, losses } => {
                assert_eq!(losses[0], (0, 4.5));
                assert!(losses[1].1.is_nan(), "NaN loss survives via null");
            }
            other => panic!("expected losses, got {other:?}"),
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_is_truncated_on_open() {
        let dir = tmp("torn");
        let (mut wal, _) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        for j in 1..=3 {
            wal.append(&ev(j)).unwrap();
        }
        let seg = wal.seg_path.clone();
        drop(wal);
        // Simulate a crash mid-write: append half a record.
        let mut f = OpenOptions::new().append(true).open(&seg).unwrap();
        f.write_all(&[7, 0, 0, 0, 0xAA, 0xBB]).unwrap();
        drop(f);
        let before = fs::metadata(&seg).unwrap().len();
        let (wal, recs) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(recs.len(), 3, "the three whole records survive");
        assert_eq!(wal.last_seq(), 3);
        assert!(fs::metadata(&seg).unwrap().len() < before, "torn bytes removed");
        // The truncated log accepts new appends at the right seq.
        drop(wal);
        let (mut wal, _) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(wal.append(&ev(4)).unwrap(), 4);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_checksum_drops_the_record_and_its_successors() {
        let dir = tmp("crc");
        let (mut wal, _) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        for j in 1..=3 {
            wal.append(&ev(j)).unwrap();
        }
        let seg = wal.seg_path.clone();
        drop(wal);
        // Flip one payload byte in the middle record.
        let mut data = fs::read(&seg).unwrap();
        let first_len = u32::from_le_bytes(data[0..4].try_into().unwrap()) as usize;
        let second_payload = first_len + 8 + 8 + 2; // into record 2's payload
        data[second_payload] ^= 0xFF;
        fs::write(&seg, &data).unwrap();
        let (wal, recs) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(recs.len(), 1, "records at and after the corruption are rejected");
        assert_eq!(wal.last_seq(), 1);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn segments_rotate_and_prune() {
        let dir = tmp("rotate");
        let (mut wal, _) = Wal::open(&dir, FsyncPolicy::EveryN(1000)).unwrap();
        wal.segment_bytes = 256; // tiny segments to force rotation
        for j in 1..=40 {
            wal.append(&ev(j)).unwrap();
        }
        assert!(wal.segment_count() > 2, "rotation happened");
        let segs_before = wal.segment_count();
        // Prune through seq 20: every segment fully ≤ 20 goes; later ones
        // and the active segment stay.
        let removed = wal.prune_through(20).unwrap();
        assert!(removed > 0);
        assert_eq!(wal.segment_count(), segs_before - removed);
        drop(wal);
        let (wal, recs) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(wal.last_seq(), 40);
        assert!(recs.first().unwrap().0 > 1, "pruned records are gone");
        assert_eq!(recs.last().unwrap().0, 40);
        // Remaining seqs are dense.
        let seqs: Vec<u64> = recs.iter().map(|&(s, _)| s).collect();
        assert!(seqs.windows(2).all(|w| w[1] == w[0] + 1));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn damage_in_a_non_final_segment_is_a_hard_error() {
        let dir = tmp("midlog");
        let (mut wal, _) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        wal.segment_bytes = 256;
        for j in 1..=40 {
            wal.append(&ev(j)).unwrap();
        }
        drop(wal);
        let segs = list_segments(&dir).unwrap();
        assert!(segs.len() > 2);
        // Truncate the *first* segment: not a crash artifact, refuse.
        let victim = &segs[0].1;
        let len = fs::metadata(victim).unwrap().len();
        let f = OpenOptions::new().write(true).open(victim).unwrap();
        f.set_len(len - 3).unwrap();
        drop(f);
        let err = Wal::open(&dir, FsyncPolicy::Always).unwrap_err();
        assert!(err.contains("damaged mid-log"), "got: {err}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn write_group_defers_fsync_until_end_group() {
        let dir = tmp("group");
        let (mut wal, _) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        wal.begin_group();
        for j in 1..=5 {
            wal.append(&ev(j)).unwrap();
        }
        assert_eq!(wal.unsynced, 5, "Always policy deferred inside the group");
        wal.end_group().unwrap();
        assert_eq!(wal.unsynced, 0, "end_group applied the policy once");
        // Appends after the group go back to per-record policy.
        wal.append(&ev(6)).unwrap();
        assert_eq!(wal.unsynced, 0);
        drop(wal);
        let (wal, recs) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(wal.last_seq(), 6);
        assert_eq!(recs.len(), 6);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_group_is_a_no_op() {
        let dir = tmp("group_empty");
        let (mut wal, _) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        wal.begin_group();
        wal.end_group().unwrap();
        assert_eq!(wal.last_seq(), 0);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn group_rotation_still_syncs_the_old_segment() {
        let dir = tmp("group_rotate");
        let (mut wal, _) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        wal.segment_bytes = 256;
        wal.begin_group();
        for j in 1..=40 {
            wal.append(&ev(j)).unwrap();
        }
        wal.end_group().unwrap();
        assert!(wal.segment_count() > 2, "rotation happened inside the group");
        drop(wal);
        let (wal, recs) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(wal.last_seq(), 40);
        assert_eq!(recs.len(), 40);
        fs::remove_dir_all(&dir).unwrap();
    }

    /// Grouped appends must be byte-identical on disk to the same records
    /// appended singly — groups change fsync timing, never framing.
    #[test]
    fn grouped_and_single_appends_are_byte_identical() {
        let dir_a = tmp("ident_single");
        let dir_b = tmp("ident_group");
        let records: Vec<WalRecord> = (1..=10).map(ev).collect();
        let (mut a, _) = Wal::open(&dir_a, FsyncPolicy::Always).unwrap();
        for r in &records {
            a.append(r).unwrap();
        }
        let seg_a = a.seg_path.clone();
        drop(a);
        let (mut b, _) = Wal::open(&dir_b, FsyncPolicy::Always).unwrap();
        b.begin_group();
        for r in &records {
            b.append(r).unwrap();
        }
        b.end_group().unwrap();
        let seg_b = b.seg_path.clone();
        drop(b);
        assert_eq!(fs::read(&seg_a).unwrap(), fs::read(&seg_b).unwrap());
        fs::remove_dir_all(&dir_a).unwrap();
        fs::remove_dir_all(&dir_b).unwrap();
    }

    /// A failed append is a hard error that corrupts nothing: the segment
    /// bytes are untouched, recovery from the pre-failure prefix is
    /// byte-identical, and the sequence stays dense for the next append.
    #[test]
    fn failed_append_surfaces_error_without_corrupting_the_segment() {
        let dir = tmp("fail_append");
        let (mut wal, _) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        for j in 1..=3 {
            wal.append(&ev(j)).unwrap();
        }
        let seg = wal.seg_path.clone();
        let prefix = fs::read(&seg).unwrap();
        wal.fail_appends = 1;
        let err = wal.append(&ev(4)).unwrap_err();
        assert!(err.contains("injected I/O failure"), "got: {err}");
        assert_eq!(fs::read(&seg).unwrap(), prefix, "failed append wrote nothing");
        // The handle itself still works: the failed record was never
        // assigned a seq, so the retry gets seq 4 and the log stays dense.
        assert_eq!(wal.append(&ev(4)).unwrap(), 4);
        drop(wal);
        let (wal, recs) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(wal.last_seq(), 4);
        let seqs: Vec<u64> = recs.iter().map(|&(s, _)| s).collect();
        assert_eq!(seqs, vec![1, 2, 3, 4]);
        fs::remove_dir_all(&dir).unwrap();
    }

    /// A failed fsync surfaces as a hard error from the append that
    /// triggered it, but the frame already reached the kernel — recovery
    /// finds a cleanly parseable log with no torn bytes, and re-opening
    /// does not rewrite the pre-failure prefix.
    #[test]
    fn failed_fsync_surfaces_error_and_recovery_is_byte_identical() {
        let dir = tmp("fail_fsync");
        let (mut wal, _) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        for j in 1..=2 {
            wal.append(&ev(j)).unwrap();
        }
        let seg = wal.seg_path.clone();
        wal.fail_syncs = 1;
        let err = wal.append(&ev(3)).unwrap_err();
        assert!(err.contains("fsync") && err.contains("injected"), "got: {err}");
        let after_failure = fs::read(&seg).unwrap();
        drop(wal);
        // Recovery: every whole record parses, seqs are dense, and the
        // open itself leaves the bytes exactly as the failure left them
        // (no truncation — nothing was torn).
        let (mut wal, recs) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(recs.len(), 3, "the unacknowledged frame still reached the kernel");
        assert_eq!(wal.last_seq(), 3);
        assert_eq!(fs::read(&seg).unwrap(), after_failure, "open rewrote valid bytes");
        assert_eq!(wal.append(&ev(4)).unwrap(), 4);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seq_gap_after_open_continues_densely() {
        // Reopen twice with appends in between: seqs stay dense across
        // process lifetimes (this is what a restarted follower relies on).
        let dir = tmp("dense");
        for round in 0..3u64 {
            let (mut wal, recs) = Wal::open(&dir, FsyncPolicy::EveryN(8)).unwrap();
            assert_eq!(recs.len() as u64, round * 5);
            for _ in 0..5 {
                wal.append(&ev(1)).unwrap();
            }
        }
        let (wal, recs) = Wal::open(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(wal.last_seq(), 15);
        let seqs: Vec<u64> = recs.iter().map(|&(s, _)| s).collect();
        assert_eq!(seqs, (1..=15).collect::<Vec<u64>>());
        fs::remove_dir_all(&dir).unwrap();
    }
}
