//! Periodic full-state snapshots bounding WAL replay time.
//!
//! A snapshot is one JSON file `snap-<seq>.json` whose name carries the
//! last WAL sequence number it covers: recovery loads the newest valid
//! snapshot and replays only records with a higher sequence number, and
//! the WAL can prune every segment the snapshot covers.
//!
//! Writes are atomic — the file is written to `snap-<seq>.json.tmp`,
//! fsynced, then renamed into place — so a crash mid-snapshot leaves at
//! worst a stale `.tmp` (ignored on load) and the previous snapshot
//! intact. An unreadable or truncated snapshot is skipped in favor of the
//! next-newest; the WAL tail behind it makes that strictly safe, just
//! slower.

use crate::util::json::{self, Json};
use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};

fn snap_name(seq: u64) -> String {
    format!("snap-{seq:020}.json")
}

/// Snapshot files under one directory.
pub struct SnapshotStore {
    dir: PathBuf,
}

impl SnapshotStore {
    pub fn new(dir: &Path) -> Result<SnapshotStore, String> {
        fs::create_dir_all(dir).map_err(|e| format!("snapshot: create {}: {e}", dir.display()))?;
        Ok(SnapshotStore { dir: dir.to_path_buf() })
    }

    /// Atomically persist `state` as the snapshot covering WAL seq `seq`.
    pub fn save(&self, seq: u64, state: &Json) -> Result<(), String> {
        let path = self.dir.join(snap_name(seq));
        let tmp = path.with_extension("json.tmp");
        let mut f =
            File::create(&tmp).map_err(|e| format!("snapshot: create {}: {e}", tmp.display()))?;
        f.write_all(state.to_string_compact().as_bytes())
            .map_err(|e| format!("snapshot: write {}: {e}", tmp.display()))?;
        f.sync_all().map_err(|e| format!("snapshot: sync {}: {e}", tmp.display()))?;
        drop(f);
        fs::rename(&tmp, &path)
            .map_err(|e| format!("snapshot: rename {} -> {}: {e}", tmp.display(), path.display()))
    }

    /// Snapshot files present, ascending by covered sequence number.
    fn list(&self) -> Result<Vec<(u64, PathBuf)>, String> {
        let mut snaps = Vec::new();
        let entries = fs::read_dir(&self.dir)
            .map_err(|e| format!("snapshot: read dir {}: {e}", self.dir.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("snapshot: read dir entry: {e}"))?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(seq) = name.strip_prefix("snap-").and_then(|s| s.strip_suffix(".json"))
            else {
                continue;
            };
            let Ok(seq) = seq.parse::<u64>() else { continue };
            snaps.push((seq, entry.path()));
        }
        snaps.sort();
        Ok(snaps)
    }

    /// Load the newest snapshot that parses, returning its covered
    /// sequence number and state. Damaged snapshots are skipped (never
    /// fatal): the WAL holds everything they held.
    pub fn load_newest(&self) -> Result<Option<(u64, Json)>, String> {
        for (seq, path) in self.list()?.into_iter().rev() {
            let Ok(text) = fs::read_to_string(&path) else { continue };
            let Ok(state) = json::parse(&text) else { continue };
            return Ok(Some((seq, state)));
        }
        Ok(None)
    }

    /// Remove every snapshot older than `keep_seq` (after a newer one has
    /// been durably written).
    pub fn prune_older_than(&self, keep_seq: u64) -> Result<usize, String> {
        let mut removed = 0;
        for (seq, path) in self.list()? {
            if seq < keep_seq {
                fs::remove_file(&path)
                    .map_err(|e| format!("snapshot: remove {}: {e}", path.display()))?;
                removed += 1;
            }
        }
        Ok(removed)
    }

    /// Covered sequence number of the newest snapshot file, if any
    /// (without reading it).
    pub fn newest_seq(&self) -> Result<Option<u64>, String> {
        Ok(self.list()?.last().map(|&(seq, _)| seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("frenzy_snap_{name}"));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn state(tag: u64) -> Json {
        let mut j = Json::obj();
        j.set("tag", tag);
        j
    }

    #[test]
    fn save_then_load_newest() {
        let dir = tmp("roundtrip");
        let store = SnapshotStore::new(&dir).unwrap();
        assert!(store.load_newest().unwrap().is_none());
        store.save(10, &state(1)).unwrap();
        store.save(25, &state(2)).unwrap();
        let (seq, j) = store.load_newest().unwrap().unwrap();
        assert_eq!(seq, 25);
        assert_eq!(j.get("tag").and_then(Json::as_u64), Some(2));
        assert_eq!(store.newest_seq().unwrap(), Some(25));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_newest_falls_back_to_older() {
        let dir = tmp("fallback");
        let store = SnapshotStore::new(&dir).unwrap();
        store.save(10, &state(1)).unwrap();
        store.save(25, &state(2)).unwrap();
        // Corrupt the newer one (e.g. disk damage): recovery must fall
        // back to seq 10 rather than fail.
        fs::write(dir.join("snap-00000000000000000025.json"), b"{truncat").unwrap();
        let (seq, j) = store.load_newest().unwrap().unwrap();
        assert_eq!(seq, 10);
        assert_eq!(j.get("tag").and_then(Json::as_u64), Some(1));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stale_tmp_from_crashed_save_is_ignored() {
        let dir = tmp("tmpfile");
        let store = SnapshotStore::new(&dir).unwrap();
        store.save(10, &state(1)).unwrap();
        // A crash between write and rename leaves a .tmp behind.
        fs::write(dir.join("snap-00000000000000000099.json.tmp"), b"{garbage").unwrap();
        let (seq, _) = store.load_newest().unwrap().unwrap();
        assert_eq!(seq, 10);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_keeps_newest() {
        let dir = tmp("prune");
        let store = SnapshotStore::new(&dir).unwrap();
        store.save(10, &state(1)).unwrap();
        store.save(25, &state(2)).unwrap();
        store.save(40, &state(3)).unwrap();
        assert_eq!(store.prune_older_than(40).unwrap(), 2);
        let (seq, _) = store.load_newest().unwrap().unwrap();
        assert_eq!(seq, 40);
        assert_eq!(store.list().unwrap().len(), 1);
        fs::remove_dir_all(&dir).unwrap();
    }
}
