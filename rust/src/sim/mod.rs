//! Discrete-event cluster simulator — the stand-in for the Alibaba PAI
//! platform simulator used in §V (see DESIGN.md §6 for the substitution
//! argument).
//!
//! Event semantics:
//! * `Arrival(job)` — job enters the pending queue;
//! * `Finish(job)` — job completes, resources released;
//! * `Oom(job)` — a memory-oblivious placement crashed; resources released,
//!   job requeued with `attempts + 1` (the baselines' trial-and-error);
//!
//! After each event the active [`Scheduler`] plans over the pending queue.
//! Scheduling *overhead* is modelled by charging `work_units ×
//! sched_work_unit_s` of delay before placed jobs start — so an expensive
//! scheduler (Sia) directly inflates queue times, exactly the effect the
//! paper measures. The simulator itself also measures the wall-clock the
//! scheduler burns, which feeds Fig 5a.

use crate::cluster::{ClusterState, Orchestrator};
use crate::config::ClusterSpec;
use crate::job::{JobId, JobOutcome, JobSpec};
use crate::metrics::RunReport;
use crate::perfmodel::PerfModel;
use crate::sched::{PendingJob, Scheduler};
use std::collections::{BinaryHeap, HashMap};

/// Simulator tuning knobs.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Sim-seconds before an OOM is detected and the job is requeued.
    pub oom_detect_s: f64,
    /// Sim-seconds charged per scheduler work unit (models the paper's
    /// scheduling-overhead effect; calibrated so HAS rounds are ~ms and
    /// Sia rounds grow to seconds at large queue depths).
    pub sched_work_unit_s: f64,
    /// Safety cap on simulated time.
    pub max_sim_time_s: f64,
    /// Hard cap on OOM retries before a job is rejected.
    pub max_attempts: u32,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            oom_detect_s: 45.0,
            sched_work_unit_s: 2.0e-5,
            max_sim_time_s: 60.0 * 86_400.0,
            max_attempts: 6,
        }
    }
}

#[derive(Debug, Clone)]
enum EventKind {
    Arrival(JobSpec),
    Finish(JobId),
    Oom(JobId),
    /// Round boundary for interval schedulers (Sia-style).
    RoundTick,
}

struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap: earlier time first, then lower seq.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then(other.seq.cmp(&self.seq))
    }
}

#[allow(dead_code)] // start_time/samples_per_sec kept for debugging dumps
struct RunningJob {
    spec: JobSpec,
    start_time: f64,
    first_start: f64,
    samples_per_sec: f64,
    gpus: u32,
    attempts: u32,
}

/// GPU-time utilization integrator.
struct UtilIntegrator {
    last_t: f64,
    busy_gpu_seconds: f64,
    total_gpus: f64,
}

impl UtilIntegrator {
    fn advance(&mut self, now: f64, busy: u32) {
        let dt = (now - self.last_t).max(0.0);
        self.busy_gpu_seconds += dt * busy as f64;
        self.last_t = now;
    }

    fn value(&self, end: f64, start: f64) -> f64 {
        let span = (end - start).max(1e-9);
        (self.busy_gpu_seconds / (span * self.total_gpus)).clamp(0.0, 1.0)
    }
}

/// The simulator. Owns the orchestrator and drives a [`Scheduler`].
pub struct Simulator<'a> {
    spec: ClusterSpec,
    orch: Orchestrator,
    sched: &'a mut dyn Scheduler,
    pm: PerfModel,
    cfg: SimConfig,
    events: BinaryHeap<Event>,
    seq: u64,
    pending: Vec<PendingJob>,
    running: HashMap<JobId, RunningJob>,
    outcomes: Vec<JobOutcome>,
    rejected: usize,
    clock: f64,
    work_units: u64,
    sched_wall_s: f64,
    util: UtilIntegrator,
    /// Per-job first submission times (for JCT across OOM retries).
    submit_times: HashMap<JobId, f64>,
    first_starts: HashMap<JobId, f64>,
    attempt_counts: HashMap<JobId, u32>,
    /// Interval schedulers: time of the last executed round and whether a
    /// RoundTick is already queued.
    last_round: f64,
    tick_queued: bool,
}

impl<'a> Simulator<'a> {
    pub fn new(spec: &ClusterSpec, sched: &'a mut dyn Scheduler, cfg: SimConfig) -> Self {
        let total_gpus = spec.total_gpus() as f64;
        Self {
            spec: spec.clone(),
            orch: Orchestrator::new(spec),
            sched,
            pm: PerfModel::new(spec.inter_node_gbps),
            cfg,
            events: BinaryHeap::new(),
            seq: 0,
            pending: Vec::new(),
            running: HashMap::new(),
            outcomes: Vec::new(),
            rejected: 0,
            clock: 0.0,
            work_units: 0,
            sched_wall_s: 0.0,
            util: UtilIntegrator { last_t: 0.0, busy_gpu_seconds: 0.0, total_gpus },
            submit_times: HashMap::new(),
            first_starts: HashMap::new(),
            attempt_counts: HashMap::new(),
            last_round: f64::NEG_INFINITY,
            tick_queued: false,
        }
    }

    fn push_event(&mut self, time: f64, kind: EventKind) {
        self.seq += 1;
        self.events.push(Event { time, seq: self.seq, kind });
    }

    /// Load a trace (jobs with submit times).
    pub fn submit_all(&mut self, jobs: &[JobSpec]) {
        for j in jobs {
            self.push_event(j.submit_time, EventKind::Arrival(j.clone()));
        }
    }

    fn busy_gpus(&self) -> u32 {
        self.orch.state().total_gpus() - self.orch.state().idle_gpus()
    }

    /// Run one scheduling round over the pending queue, then reject
    /// structurally unplaceable jobs. Interval schedulers (Sia-style) only
    /// run at round boundaries; between them a RoundTick is queued.
    fn schedule_round(&mut self) {
        if let Some(interval) = self.sched.round_interval_s() {
            if self.pending.is_empty() {
                return;
            }
            let due = self.last_round + interval;
            if self.clock < due {
                if !self.tick_queued {
                    self.push_event(due, EventKind::RoundTick);
                    self.tick_queued = true;
                }
                return;
            }
            self.last_round = self.clock;
        }
        self.schedule_round_inner();
        self.reject_unplaceable();
    }

    /// The placement pass.
    fn schedule_round_inner(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let snapshot = self.orch.snapshot();
        let t0 = std::time::Instant::now();
        let round = self.sched.schedule(&self.pending, &snapshot, self.clock);
        self.sched_wall_s += t0.elapsed().as_secs_f64();
        self.work_units += round.work_units;
        let overhead = round.work_units as f64 * self.cfg.sched_work_unit_s;
        let start_time = self.clock + overhead;

        for d in round.decisions {
            // Remove from pending.
            let Some(pos) = self.pending.iter().position(|p| p.spec.id == d.job) else {
                continue; // scheduler returned a stale decision — ignore
            };
            let pj = self.pending.remove(pos);
            if self.orch.allocate(d.alloc.clone()).is_err() {
                // Scheduler overdrew (bug or stale snapshot): requeue.
                self.pending.push(pj);
                continue;
            }
            self.util.advance(self.clock, self.busy_gpus().saturating_sub(d.alloc.total_gpus()));
            let attempts = pj.attempts + 1;
            self.attempt_counts.insert(d.job, attempts);
            self.first_starts.entry(d.job).or_insert(start_time);
            if d.will_oom {
                self.running.insert(
                    d.job,
                    RunningJob {
                        spec: pj.spec.clone(),
                        start_time,
                        first_start: self.first_starts[&d.job],
                        samples_per_sec: 0.0,
                        gpus: d.alloc.total_gpus(),
                        attempts,
                    },
                );
                self.push_event(start_time + self.cfg.oom_detect_s, EventKind::Oom(d.job));
            } else {
                let thr = self.pm.samples_per_sec(
                    &pj.spec.model,
                    &pj.spec.train,
                    d.par,
                    &d.gpu,
                    d.placement,
                );
                let runtime = pj.spec.total_samples as f64 / thr.max(1e-9);
                self.running.insert(
                    d.job,
                    RunningJob {
                        spec: pj.spec.clone(),
                        start_time,
                        first_start: self.first_starts[&d.job],
                        samples_per_sec: thr,
                        gpus: d.alloc.total_gpus(),
                        attempts,
                    },
                );
                self.push_event(start_time + runtime, EventKind::Finish(d.job));
            }
        }

    }

    /// If the cluster is completely idle and the scheduler still can't place
    /// a job, it never will — reject it instead of busy-looping. (A job that
    /// exceeded its OOM-retry budget is also dropped here.)
    fn reject_unplaceable(&mut self) {
        if !(self.running.is_empty()
            && self.orch.state().idle_gpus() == self.orch.state().total_gpus()
            && !self.pending.is_empty())
        {
            return;
        }
        let mut keep = Vec::new();
        let drained: Vec<PendingJob> = self.pending.drain(..).collect();
        for p in drained {
            if p.attempts >= self.cfg.max_attempts {
                self.rejected += 1;
                continue;
            }
            let snapshot = self.orch.snapshot();
            let round = self.sched.schedule(std::slice::from_ref(&p), &snapshot, self.clock);
            if round.decisions.is_empty() {
                self.rejected += 1;
            } else {
                keep.push(p);
            }
        }
        self.pending = keep;
        if !self.pending.is_empty() {
            // They are placeable on an empty cluster; place them now.
            self.schedule_round_inner();
        }
    }

    fn handle(&mut self, kind: EventKind) {
        match kind {
            EventKind::Arrival(spec) => {
                self.submit_times.insert(spec.id, spec.submit_time);
                self.pending.push(PendingJob { spec, attempts: 0 });
            }
            EventKind::Finish(id) => {
                let Some(run) = self.running.remove(&id) else { return };
                self.util.advance(self.clock, self.busy_gpus());
                let _ = self.orch.release(id);
                let submit = *self.submit_times.get(&id).unwrap_or(&0.0);
                self.outcomes.push(JobOutcome {
                    id,
                    name: run.spec.name.clone(),
                    submit_time: submit,
                    start_time: run.first_start,
                    finish_time: self.clock,
                    gpus_used: run.gpus,
                    samples_per_sec: run.spec.total_samples as f64
                        / (self.clock - run.first_start).max(1e-9),
                    attempts: run.attempts,
                });
            }
            EventKind::RoundTick => {
                self.tick_queued = false;
            }
            EventKind::Oom(id) => {
                let Some(run) = self.running.remove(&id) else { return };
                self.util.advance(self.clock, self.busy_gpus());
                let _ = self.orch.release(id);
                if run.attempts >= self.cfg.max_attempts {
                    self.rejected += 1;
                } else {
                    self.pending.push(PendingJob { spec: run.spec, attempts: run.attempts });
                }
            }
        }
    }

    /// Run to completion; returns the report.
    pub fn run(&mut self, workload_name: &str) -> RunReport {
        while let Some(ev) = self.events.pop() {
            if ev.time > self.cfg.max_sim_time_s {
                break;
            }
            self.util.advance(ev.time, self.busy_gpus());
            self.clock = ev.time;
            let mut batch = vec![ev.kind];
            // Drain events at (approximately) the same timestamp.
            while let Some(next) = self.events.peek() {
                if (next.time - self.clock).abs() < 1e-9 {
                    batch.push(self.events.pop().unwrap().kind);
                } else {
                    break;
                }
            }
            for kind in batch {
                self.handle(kind);
            }
            self.schedule_round();
        }
        // Whatever is still pending never got resources.
        self.rejected += self.pending.len();
        self.pending.clear();
        let end = self.clock.max(1e-9);
        let report = RunReport::from_outcomes(
            self.sched.name(),
            workload_name,
            &self.outcomes,
            self.rejected,
            self.work_units,
            self.sched_wall_s,
            self.util.value(end, 0.0),
        );
        report
    }

    pub fn outcomes(&self) -> &[JobOutcome] {
        &self.outcomes
    }

    pub fn cluster_state(&self) -> &ClusterState {
        self.orch.state()
    }

    pub fn conservation_ok(&self) -> bool {
        self.orch.check_conservation()
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }
}

/// Convenience: simulate a trace under a scheduler built by `make_sched`.
pub fn simulate(
    spec: &ClusterSpec,
    sched: &mut dyn Scheduler,
    jobs: &[JobSpec],
    cfg: SimConfig,
    workload_name: &str,
) -> RunReport {
    let mut sim = Simulator::new(spec, sched, cfg);
    sim.submit_all(jobs);
    sim.run(workload_name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::model_by_name;
    use crate::config::real_testbed;
    use crate::marp::Marp;
    use crate::sched::has::Has;
    use crate::sched::opportunistic::Opportunistic;

    fn jobs(n: u64, model: &str, batch: u32, samples: u64, spread_s: f64) -> Vec<JobSpec> {
        (0..n)
            .map(|i| {
                JobSpec::new(i, model_by_name(model).unwrap(), batch, samples, i as f64 * spread_s)
            })
            .collect()
    }

    #[test]
    fn single_job_completes() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let trace = jobs(1, "gpt2-350m", 8, 10_000, 0.0);
        let report = simulate(&spec, &mut has, &trace, SimConfig::default(), "t");
        assert_eq!(report.n_completed, 1);
        assert_eq!(report.n_rejected, 0);
        assert!(report.avg_jct_s > 0.0);
        assert!(report.avg_samples_per_sec > 0.0);
    }

    #[test]
    fn all_jobs_terminate_and_resources_conserved() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let trace = jobs(12, "gpt2-350m", 8, 50_000, 30.0);
        let mut sim = Simulator::new(&spec, &mut has, SimConfig::default());
        sim.submit_all(&trace);
        let report = sim.run("t");
        assert_eq!(report.n_completed + report.n_rejected, 12);
        assert_eq!(report.n_rejected, 0);
        assert!(sim.conservation_ok());
        assert_eq!(sim.cluster_state().idle_gpus(), sim.cluster_state().total_gpus());
    }

    #[test]
    fn queueing_happens_under_contention() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        // 20 jobs all at t=0, long enough to contend.
        let trace = jobs(20, "gpt2-760m", 8, 200_000, 0.0);
        let report = simulate(&spec, &mut has, &trace, SimConfig::default(), "t");
        assert_eq!(report.n_completed, 20);
        assert!(report.avg_queue_s > 0.0, "contention must produce queueing");
    }

    #[test]
    fn oom_retries_counted_for_opportunistic() {
        let spec = real_testbed();
        let mut opp = Opportunistic::new(&spec);
        // 2.7B: user sizes against 80G; fastest-first can land it on 40G.
        let trace = jobs(4, "gpt2-2.7b", 8, 50_000, 10.0);
        let report = simulate(&spec, &mut opp, &trace, SimConfig::default(), "t");
        assert_eq!(report.n_completed + report.n_rejected, 4);
        // At least some trial-and-error is expected on this workload.
        assert!(report.total_oom_retries > 0, "expected OOM retries, got none");
    }

    #[test]
    fn infeasible_job_rejected_not_looped() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut big = model_by_name("gpt2-7b").unwrap();
        big.hidden = 16384;
        big.layers = 96;
        let trace = vec![JobSpec::new(0, big, 4, 1000, 0.0)];
        let report = simulate(&spec, &mut has, &trace, SimConfig::default(), "t");
        assert_eq!(report.n_completed, 0);
        assert_eq!(report.n_rejected, 1);
    }

    #[test]
    fn deterministic_given_same_inputs() {
        let spec = real_testbed();
        let run = || {
            let mut has = Has::new(Marp::with_defaults(spec.clone()));
            let trace = jobs(8, "gpt2-350m", 8, 30_000, 15.0);
            simulate(&spec, &mut has, &trace, SimConfig::default(), "t")
        };
        let a = run();
        let b = run();
        assert_eq!(a.avg_jct_s, b.avg_jct_s);
        assert_eq!(a.makespan_s, b.makespan_s);
    }

    #[test]
    fn utilization_bounded() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let trace = jobs(6, "gpt2-350m", 8, 50_000, 0.0);
        let report = simulate(&spec, &mut has, &trace, SimConfig::default(), "t");
        assert!((0.0..=1.0).contains(&report.avg_utilization));
        assert!(report.avg_utilization > 0.0);
    }
}
