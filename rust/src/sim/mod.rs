//! Discrete-event cluster simulator — the stand-in for the Alibaba PAI
//! platform simulator used in §V (see DESIGN.md §6 for the substitution
//! argument).
//!
//! Since the engine refactor this module is a **thin wrapper**: it feeds
//! trace arrivals (and optional elasticity events) into a
//! [`crate::engine::clock::VirtualClock`] and drains the event heap through
//! the shared [`SchedulingEngine`] — the same code the live serverless
//! coordinator runs on a wall clock. Event semantics (`Arrival` / `Finish` /
//! `Oom`-requeue / `RoundTick` / `NodeJoin` / `NodeLeave`), overhead
//! charging, and rejection logic all live in [`crate::engine`].
//!
//! Scheduling *overhead* is modelled by charging `work_units ×
//! sched_work_unit_s` of delay before placed jobs start — so an expensive
//! scheduler (Sia) directly inflates queue times, exactly the effect the
//! paper measures. The wall-clock the scheduler burns is also measured and
//! feeds Fig 5a.

use crate::config::ClusterSpec;
use crate::engine::clock::{Clock, VirtualClock};
use crate::engine::{ClusterEvent, EngineConfig, EventLog, SchedulingEngine};
use crate::job::JobSpec;
use crate::metrics::{RunAggregates, RunReport};
use crate::sched::Scheduler;

/// Simulator tuning knobs.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Sim-seconds before an OOM is detected and the job is requeued (the
    /// fallback timer, used only with `device_memory` off).
    pub oom_detect_s: f64,
    /// Account device memory in bytes (see `EngineConfig::device_memory`):
    /// OOMs come from the byte ledger observing an over-capacity charge,
    /// and the run report carries prediction-accuracy aggregates.
    pub device_memory: bool,
    /// Per-dispatch activation jitter on the observed peak (deterministic
    /// per `(job, epoch)`; 0 keeps runs bit-reproducible).
    pub mem_jitter_frac: f64,
    /// Sim-seconds from start until a ledger-observed OOM crashes the run.
    pub oom_observe_s: f64,
    /// Checkpoint cadence in training steps (0 disables checkpointing).
    pub ckpt_every_steps: u64,
    /// Sim-seconds a drain spends writing the checkpoint.
    pub ckpt_write_s: f64,
    /// Graceful-drain budget on `NodeLeave` (0 = instant preemption).
    pub drain_grace_s: f64,
    /// Sim-seconds charged per scheduler work unit (models the paper's
    /// scheduling-overhead effect; calibrated so HAS rounds are ~ms and
    /// Sia rounds grow to seconds at large queue depths).
    pub sched_work_unit_s: f64,
    /// Safety cap on simulated time.
    pub max_sim_time_s: f64,
    /// Hard cap on OOM retries before a job is rejected.
    pub max_attempts: u32,
    /// First crash-backoff hold for a crash-displaced job, seconds.
    pub crash_backoff_base_s: f64,
    /// Cap on the exponential crash-backoff hold, seconds.
    pub crash_backoff_cap_s: f64,
    /// Crashes inside the window that quarantine a node (0 disables).
    pub quarantine_crashes: u32,
    /// Flap-detection window, seconds.
    pub quarantine_window_s: f64,
    /// Quarantine probation, seconds.
    pub probation_s: f64,
    /// Per-tenant fairness weights (`(tenant, weight)`; unlisted tenants
    /// weigh 1.0). Only engages when a trace carries ≥ 2 distinct tenants.
    pub tenant_weights: Vec<(String, f64)>,
}

impl Default for SimConfig {
    fn default() -> Self {
        let e = EngineConfig::default();
        Self {
            oom_detect_s: 45.0,
            device_memory: e.device_memory,
            mem_jitter_frac: e.mem_jitter_frac,
            oom_observe_s: e.oom_observe_s,
            ckpt_every_steps: e.ckpt_every_steps,
            ckpt_write_s: e.ckpt_write_s,
            drain_grace_s: e.drain_grace_s,
            sched_work_unit_s: 2.0e-5,
            max_sim_time_s: 60.0 * 86_400.0,
            max_attempts: 6,
            crash_backoff_base_s: e.crash_backoff_base_s,
            crash_backoff_cap_s: e.crash_backoff_cap_s,
            quarantine_crashes: e.quarantine_crashes,
            quarantine_window_s: e.quarantine_window_s,
            probation_s: e.probation_s,
            tenant_weights: Vec::new(),
        }
    }
}

impl SimConfig {
    fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            oom_detect_s: self.oom_detect_s,
            device_memory: self.device_memory,
            mem_jitter_frac: self.mem_jitter_frac,
            oom_observe_s: self.oom_observe_s,
            ckpt_every_steps: self.ckpt_every_steps,
            ckpt_write_s: self.ckpt_write_s,
            drain_grace_s: self.drain_grace_s,
            sched_work_unit_s: self.sched_work_unit_s,
            max_attempts: self.max_attempts,
            crash_backoff_base_s: self.crash_backoff_base_s,
            crash_backoff_cap_s: self.crash_backoff_cap_s,
            quarantine_crashes: self.quarantine_crashes,
            quarantine_window_s: self.quarantine_window_s,
            probation_s: self.probation_s,
            tenant_weights: self.tenant_weights.clone(),
            ..EngineConfig::default()
        }
    }
}

/// The simulator: a trace feeder over the shared [`SchedulingEngine`].
pub struct Simulator<'a> {
    spec: ClusterSpec,
    engine: SchedulingEngine<'a>,
    clock: VirtualClock,
    cfg: SimConfig,
}

impl<'a> Simulator<'a> {
    pub fn new(spec: &ClusterSpec, sched: &'a mut dyn Scheduler, cfg: SimConfig) -> Self {
        let engine = SchedulingEngine::new(spec, sched, cfg.engine_config());
        Self { spec: spec.clone(), engine, clock: VirtualClock::new(), cfg }
    }

    /// Load a trace (jobs with submit times).
    pub fn submit_all(&mut self, jobs: &[JobSpec]) {
        for j in jobs {
            self.clock.schedule(j.submit_time, ClusterEvent::Arrival(j.clone()));
        }
    }

    /// Inject an arbitrary event at `time` — e.g. elasticity
    /// (`ClusterEvent::NodeJoin` / `NodeLeave`) mid-trace.
    pub fn schedule_event(&mut self, time: f64, ev: ClusterEvent) {
        self.clock.schedule(time, ev);
    }

    /// Schedule every event of a compiled [`FaultPlan`] on the virtual
    /// clock. Injection rides the normal event path, so the chaos run is
    /// handled — and audited — exactly like organic failures.
    pub fn inject_faults(&mut self, plan: &crate::faults::FaultPlan) {
        for (t, ev) in plan.events() {
            self.clock.schedule(*t, ev.clone());
        }
    }

    /// Run to completion; returns the report.
    pub fn run(&mut self, workload_name: &str) -> RunReport {
        // Check the cap on the *peeked* timestamp: popping would advance the
        // clock to the discarded event's time and inflate the report's end
        // time / utilization span with a phantom tail.
        while self.clock.peek_time().is_some_and(|t| t <= self.cfg.max_sim_time_s) {
            let (t, ev) = self.clock.pop().expect("peeked");
            let mut batch = vec![ev];
            // Drain events at (approximately) the same timestamp so one
            // scheduling round covers them all.
            while let Some(next_t) = self.clock.peek_time() {
                if (next_t - t).abs() < 1e-9 {
                    batch.push(self.clock.pop().expect("peeked").1);
                } else {
                    break;
                }
            }
            for ev in batch {
                let _ = self.engine.handle(ev, &mut self.clock);
            }
            let _ = self.engine.run_round(&mut self.clock);
        }
        // Whatever is still pending never got resources.
        let now = self.clock.now();
        let _ = self.engine.reject_remaining(now);
        let end = now.max(1e-9);
        let util = self.engine.utilization_to(end);
        RunReport::from_aggregates(
            self.engine.scheduler_name(),
            workload_name,
            self.engine.aggregates(),
            0,
            self.engine.work_units(),
            self.engine.sched_wall_s(),
            util,
        )
    }

    /// The run's streaming metrics (see [`RunAggregates`]).
    pub fn aggregates(&self) -> &RunAggregates {
        self.engine.aggregates()
    }

    /// The engine's bounded audit log — arrivals, placements, finishes,
    /// OOMs, elasticity — in event order.
    pub fn event_log(&self) -> &EventLog {
        self.engine.event_log()
    }

    pub fn cluster_state(&self) -> &crate::cluster::ClusterState {
        self.engine.cluster_state()
    }

    pub fn conservation_ok(&self) -> bool {
        self.engine.conservation_ok()
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// The underlying engine (placement decision log, attempt counters…).
    pub fn engine(&self) -> &SchedulingEngine<'a> {
        &self.engine
    }
}

/// Convenience: simulate a trace under a scheduler built by `make_sched`.
pub fn simulate(
    spec: &ClusterSpec,
    sched: &mut dyn Scheduler,
    jobs: &[JobSpec],
    cfg: SimConfig,
    workload_name: &str,
) -> RunReport {
    let mut sim = Simulator::new(spec, sched, cfg);
    sim.submit_all(jobs);
    sim.run(workload_name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::model_by_name;
    use crate::config::real_testbed;
    use crate::marp::Marp;
    use crate::sched::has::Has;
    use crate::sched::opportunistic::Opportunistic;

    fn jobs(n: u64, model: &str, batch: u32, samples: u64, spread_s: f64) -> Vec<JobSpec> {
        (0..n)
            .map(|i| {
                JobSpec::new(i, model_by_name(model).unwrap(), batch, samples, i as f64 * spread_s)
            })
            .collect()
    }

    #[test]
    fn single_job_completes() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let trace = jobs(1, "gpt2-350m", 8, 10_000, 0.0);
        let report = simulate(&spec, &mut has, &trace, SimConfig::default(), "t");
        assert_eq!(report.n_completed, 1);
        assert_eq!(report.n_rejected, 0);
        assert!(report.avg_jct_s > 0.0);
        assert!(report.avg_samples_per_sec > 0.0);
    }

    #[test]
    fn all_jobs_terminate_and_resources_conserved() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let trace = jobs(12, "gpt2-350m", 8, 50_000, 30.0);
        let mut sim = Simulator::new(&spec, &mut has, SimConfig::default());
        sim.submit_all(&trace);
        let report = sim.run("t");
        assert_eq!(report.n_completed + report.n_rejected, 12);
        assert_eq!(report.n_rejected, 0);
        assert!(sim.conservation_ok());
        assert_eq!(sim.cluster_state().idle_gpus(), sim.cluster_state().total_gpus());
    }

    #[test]
    fn queueing_happens_under_contention() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        // 20 jobs all at t=0, long enough to contend.
        let trace = jobs(20, "gpt2-760m", 8, 200_000, 0.0);
        let report = simulate(&spec, &mut has, &trace, SimConfig::default(), "t");
        assert_eq!(report.n_completed, 20);
        assert!(report.avg_queue_s > 0.0, "contention must produce queueing");
    }

    #[test]
    fn oom_retries_counted_for_opportunistic() {
        let spec = real_testbed();
        let mut opp = Opportunistic::new(&spec);
        // 2.7B: user sizes against 80G; fastest-first can land it on 40G.
        let trace = jobs(4, "gpt2-2.7b", 8, 50_000, 10.0);
        let report = simulate(&spec, &mut opp, &trace, SimConfig::default(), "t");
        assert_eq!(report.n_completed + report.n_rejected, 4);
        // At least some trial-and-error is expected on this workload.
        assert!(report.total_oom_retries > 0, "expected OOM retries, got none");
    }

    #[test]
    fn infeasible_job_rejected_not_looped() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut big = model_by_name("gpt2-7b").unwrap();
        big.hidden = 16384;
        big.layers = 96;
        let trace = vec![JobSpec::new(0, big, 4, 1000, 0.0)];
        let report = simulate(&spec, &mut has, &trace, SimConfig::default(), "t");
        assert_eq!(report.n_completed, 0);
        assert_eq!(report.n_rejected, 1);
    }

    #[test]
    fn deterministic_given_same_inputs() {
        let spec = real_testbed();
        let run = || {
            let mut has = Has::new(Marp::with_defaults(spec.clone()));
            let trace = jobs(8, "gpt2-350m", 8, 30_000, 15.0);
            simulate(&spec, &mut has, &trace, SimConfig::default(), "t")
        };
        let a = run();
        let b = run();
        assert_eq!(a.avg_jct_s, b.avg_jct_s);
        assert_eq!(a.makespan_s, b.makespan_s);
    }

    #[test]
    fn utilization_bounded() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let trace = jobs(6, "gpt2-350m", 8, 50_000, 0.0);
        let report = simulate(&spec, &mut has, &trace, SimConfig::default(), "t");
        assert!((0.0..=1.0).contains(&report.avg_utilization));
        assert!(report.avg_utilization > 0.0);
    }

    #[test]
    fn elastic_node_leave_mid_trace_still_terminates_all_jobs() {
        // The new scenario axis the engine refactor opens up: the same
        // trace, but a node dies mid-run. Every job must still reach a
        // terminal state and conservation must hold at the end.
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let trace = jobs(10, "gpt2-350m", 8, 80_000, 25.0);
        let mut sim = Simulator::new(&spec, &mut has, SimConfig::default());
        sim.submit_all(&trace);
        sim.schedule_event(60.0, ClusterEvent::NodeLeave(0));
        let report = sim.run("elastic");
        assert_eq!(report.n_completed + report.n_rejected, 10);
        assert!(sim.conservation_ok());
        assert_eq!(sim.cluster_state().idle_gpus(), sim.cluster_state().total_gpus());
        assert_eq!(sim.cluster_state().total_gpus(), 9, "2 GPUs left with node 0");
    }

    #[test]
    fn chaos_fault_plan_still_terminates_all_jobs() {
        // Crashes, a straggler window, and a checkpoint-failure window
        // injected mid-trace: every job still reaches a terminal state,
        // resources are conserved, and the report carries the failure
        // counters and a goodput below 1 (crashed work was re-executed).
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let trace = jobs(10, "gpt2-350m", 8, 80_000, 25.0);
        let mut sim = Simulator::new(&spec, &mut has, SimConfig::default());
        sim.submit_all(&trace);
        let plan = crate::faults::FaultPlan::parse(
            "crash:0@120,crash:2@400,straggler:3@50x0.5+500,ckptfail:2@300+600",
            spec.nodes.len(),
            10_000.0,
        )
        .unwrap();
        sim.inject_faults(&plan);
        let report = sim.run("chaos");
        assert_eq!(report.n_completed + report.n_rejected, 10);
        assert!(sim.conservation_ok());
        assert_eq!(sim.cluster_state().idle_gpus(), sim.cluster_state().total_gpus());
        assert_eq!(
            sim.cluster_state().total_gpus(),
            11,
            "crashed nodes keep their capacity"
        );
        assert!(report.n_node_crashes >= 1, "crashes on busy nodes are counted");
        assert!((0.0..=1.0).contains(&report.goodput));
        // Seeded chaos over the same trace is reproducible end to end.
        let run_seeded = || {
            let mut has = Has::new(Marp::with_defaults(spec.clone()));
            let mut sim = Simulator::new(&spec, &mut has, SimConfig::default());
            sim.submit_all(&trace);
            let plan =
                crate::faults::FaultPlan::parse("seed:42", spec.nodes.len(), 5_000.0).unwrap();
            sim.inject_faults(&plan);
            sim.run("chaos-seeded")
        };
        let a = run_seeded();
        let b = run_seeded();
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.n_node_crashes, b.n_node_crashes);
        assert_eq!(a.goodput, b.goodput);
    }
}
