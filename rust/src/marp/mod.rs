//! MARP — Memory-Aware Resource Predictor (§IV.A).
//!
//! For a submitted job, MARP sweeps (data-parallel `d`, tensor-parallel `t`)
//! configurations, predicts the peak per-GPU memory for each with the
//! closed-form model in [`crate::memory`], discards configurations that fit
//! no GPU type in the cluster, estimates training throughput for the rest
//! with [`crate::perfmodel`], and returns a **priority-ordered list of
//! resource plans** `(d, t, N = d·t, min GPU memory)`. HAS then walks this
//! list (Fig 3).
//!
//! Ranking: plans are scored by *goodput density* — estimated samples/s
//! times parallel efficiency **squared** — so the front of the list is
//! "train fast without wasting GPUs", which is what the paper means by
//! "higher training efficiency" (§V.C: utilization highest at t=4, d=2 for
//! the 8-card GPT2-7B case). The quadratic efficiency weight keeps widths
//! moderate under multi-tenant contention (ablated in EXPERIMENTS.md).
//! Ties break toward fewer GPUs, then smaller GPUs.

use crate::config::{ClusterSpec, LinkKind, ModelConfig};
use crate::memory::{marp_peak_bytes, required_gpu_bytes, Parallelism, TrainConfig};
use crate::perfmodel::{PerfModel, Placement};

/// One resource requirement plan: the paper's `Job(n, s)` augmented with the
/// parallelism that produced it and the throughput estimate used for
/// ranking.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourcePlan {
    /// Parallelism that generated this plan.
    pub par: Parallelism,
    /// Required GPU count (`reqNum = d·t`).
    pub n_gpus: u32,
    /// Minimum per-GPU memory (`reqSz`), bytes. A GPU qualifies iff
    /// `gpu.mem >= min_gpu_mem`.
    pub min_gpu_mem: u64,
    /// MARP's predicted peak per-GPU usage, bytes.
    pub predicted_bytes: u64,
    /// Estimated samples/s under the conservative placement assumption.
    pub est_samples_per_sec: f64,
    /// Estimated parallel efficiency in (0, 1].
    pub est_efficiency: f64,
    /// Ranking score (higher = earlier in the list).
    pub score: f64,
}

/// MARP configuration knobs.
#[derive(Debug, Clone)]
pub struct MarpConfig {
    /// Largest tensor-parallel degree to consider (bounded by node size).
    pub max_tp: u32,
    /// Largest data-parallel degree to consider.
    pub max_dp: u32,
    /// Keep at most this many plans.
    pub max_plans: usize,
    /// Drop plans whose parallel efficiency falls below this floor.
    pub min_efficiency: f64,
}

impl Default for MarpConfig {
    fn default() -> Self {
        Self { max_tp: 8, max_dp: 64, max_plans: 12, min_efficiency: 0.35 }
    }
}

/// The predictor. Holds the cluster descriptor (GPU sizes present and node
/// shapes) and a performance model for ranking.
#[derive(Debug, Clone)]
pub struct Marp {
    cluster: ClusterSpec,
    pm: PerfModel,
    cfg: MarpConfig,
    /// Distinct GPU memory sizes, ascending, for min-fit lookups.
    sizes_asc: Vec<u64>,
}

impl Marp {
    pub fn new(cluster: ClusterSpec, cfg: MarpConfig) -> Self {
        let mut sizes_asc: Vec<u64> = cluster.nodes.iter().map(|n| n.gpu.mem_bytes).collect();
        sizes_asc.sort_unstable();
        sizes_asc.dedup();
        let pm = PerfModel::new(cluster.inter_node_gbps);
        Self { cluster, pm, cfg, sizes_asc }
    }

    pub fn with_defaults(cluster: ClusterSpec) -> Self {
        Self::new(cluster, MarpConfig::default())
    }

    pub fn cluster(&self) -> &ClusterSpec {
        &self.cluster
    }

    pub fn perf_model(&self) -> &PerfModel {
        &self.pm
    }

    pub fn config(&self) -> &MarpConfig {
        &self.cfg
    }

    /// Smallest GPU size in the cluster that can hold `required` bytes.
    fn min_fitting_size(&self, required: u64) -> Option<u64> {
        self.sizes_asc.iter().copied().find(|&sz| required <= sz)
    }

    /// The best (fastest/most capable) node link among nodes whose GPUs have
    /// at least `min_mem` and at least `t` GPUs — the placement HAS would
    /// aim for.
    fn best_link_for(&self, min_mem: u64, t: u32) -> Option<LinkKind> {
        let mut best: Option<LinkKind> = None;
        for n in &self.cluster.nodes {
            if n.gpu.mem_bytes >= min_mem && n.count >= t {
                match (best, n.link) {
                    (None, l) => best = Some(l),
                    (Some(LinkKind::Pcie), LinkKind::NvLink) => best = Some(LinkKind::NvLink),
                    _ => {}
                }
            }
        }
        best
    }

    /// GPU spec used for throughput scoring: the *smallest-memory* type that
    /// satisfies the plan (best-fit pessimism — HAS prefers exactly-fitting
    /// GPUs, so scoring assumes them).
    fn scoring_gpu(&self, min_mem: u64) -> Option<crate::config::GpuSpec> {
        self.cluster
            .nodes
            .iter()
            .filter(|n| n.gpu.mem_bytes >= min_mem)
            .min_by_key(|n| n.gpu.mem_bytes)
            .map(|n| n.gpu.clone())
    }

    /// Enumerate, filter, score, and rank resource plans for a job.
    /// Returns an empty vector when no configuration fits the cluster
    /// (the job must be rejected — the serverless admission decision).
    pub fn plans(&self, model: &ModelConfig, train: &TrainConfig) -> Vec<ResourcePlan> {
        let total_gpus = self.cluster.total_gpus();
        let max_tp = self.cfg.max_tp.min(self.cluster.max_gpus_per_node()).max(1);
        let max_dp = self.cfg.max_dp.min(train.global_batch.max(1)).min(total_gpus);

        let mut plans: Vec<ResourcePlan> = Vec::new();
        let mut t = 1u32;
        while t <= max_tp {
            let mut d = 1u32;
            while d <= max_dp {
                let par = Parallelism::new(d, t);
                if par.gpus() <= total_gpus {
                    if let Some(plan) = self.evaluate(model, train, par) {
                        plans.push(plan);
                    }
                }
                d *= 2;
            }
            t *= 2;
        }

        // Efficiency floor, then drop dominated plans (another plan that is
        // at least as fast with no more GPUs).
        plans.retain(|p| p.est_efficiency >= self.cfg.min_efficiency);
        plans.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap()
                .then(a.n_gpus.cmp(&b.n_gpus))
                .then(a.min_gpu_mem.cmp(&b.min_gpu_mem))
        });
        let mut kept: Vec<ResourcePlan> = Vec::new();
        for p in plans {
            let dominated = kept.iter().any(|q| {
                q.n_gpus <= p.n_gpus
                    && q.min_gpu_mem <= p.min_gpu_mem
                    && q.est_samples_per_sec >= p.est_samples_per_sec
            });
            if !dominated {
                kept.push(p);
            }
            if kept.len() >= self.cfg.max_plans {
                break;
            }
        }
        kept
    }

    /// Evaluate a single (d, t) configuration into a plan, if feasible.
    fn evaluate(
        &self,
        model: &ModelConfig,
        train: &TrainConfig,
        par: Parallelism,
    ) -> Option<ResourcePlan> {
        let predicted = marp_peak_bytes(model, train, par);
        // reqSz mirrors Job(n, s) in the paper: the minimum per-GPU memory.
        // It carries the hardened requirement (margin + head + reserve) so
        // that HAS's `gpu.size >= reqSz` comparison guarantees no OOM.
        let req_sz = required_gpu_bytes(model, train, par);
        let min_mem = self.min_fitting_size(req_sz)?;

        // Conservative placement assumption for scoring: TP on the best
        // link available among qualifying nodes (if the TP group fits a
        // node), DP crossing nodes whenever d·t exceeds one node.
        let gpu = self.scoring_gpu(min_mem)?;
        let tp_link = self.best_link_for(min_mem, par.t);
        let tp_link = match tp_link {
            Some(l) => l,
            // TP group fits no single node: cross-node TP — allowed but slow.
            None => {
                let pl = Placement::all_cross();
                let thr = self.pm.samples_per_sec(model, train, par, &gpu, pl);
                let eff = self.pm.parallel_efficiency(model, train, par, &gpu, pl);
                return Some(ResourcePlan {
                    par,
                    n_gpus: par.gpus(),
                    min_gpu_mem: req_sz,
                    predicted_bytes: predicted,
                    est_samples_per_sec: thr,
                    est_efficiency: eff,
                    score: thr * eff * eff,
                });
            }
        };
        let fits_one_node =
            self.cluster.nodes.iter().any(|n| n.gpu.mem_bytes >= min_mem && n.count >= par.gpus());
        let placement = if fits_one_node {
            Placement::single_node(tp_link)
        } else {
            Placement::tp_local_dp_cross(tp_link)
        };
        let thr = self.pm.samples_per_sec(model, train, par, &gpu, placement);
        let eff = self.pm.parallel_efficiency(model, train, par, &gpu, placement);
        Some(ResourcePlan {
            par,
            n_gpus: par.gpus(),
            min_gpu_mem: req_sz,
            predicted_bytes: predicted,
            est_samples_per_sec: thr,
            est_efficiency: eff,
            score: thr * eff * eff,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::model_by_name;
    use crate::config::{real_testbed, sia_sim, GIB};

    fn marp_real() -> Marp {
        Marp::with_defaults(real_testbed())
    }

    #[test]
    fn small_model_gets_plans_starting_cheap() {
        let marp = marp_real();
        let m = model_by_name("gpt2-350m").unwrap();
        let plans = marp.plans(&m, &TrainConfig { global_batch: 8 });
        assert!(!plans.is_empty());
        // every plan fits some GPU size in the cluster
        for p in &plans {
            assert!(p.predicted_bytes <= 80 * GIB);
            assert_eq!(p.n_gpus, p.par.gpus());
            assert!(p.est_efficiency > 0.0 && p.est_efficiency <= 1.0);
        }
        // the list must contain a single-GPU plan (350M fits one A100-40)
        assert!(plans.iter().any(|p| p.n_gpus == 1));
    }

    #[test]
    fn gpt7b_batch2_top_plan_is_t4_d2() {
        // §V.C: "8 cards ... utilization is relatively highest when tensor
        // parallelism is 4 and data parallelism is 2".
        let marp = marp_real();
        let m = model_by_name("gpt2-7b").unwrap();
        let plans = marp.plans(&m, &TrainConfig { global_batch: 2 });
        assert!(!plans.is_empty());
        let p40: Vec<&ResourcePlan> =
            plans.iter().filter(|p| p.min_gpu_mem <= 40 * GIB).collect();
        assert!(
            p40.iter().any(|p| p.par == Parallelism::new(2, 4)),
            "t=4,d=2 plan missing from 40G-feasible set: {plans:?}"
        );
        // No 40G-feasible plan with fewer than 8 GPUs exists.
        for p in &p40 {
            assert!(p.n_gpus >= 8, "underprovisioned 40G plan: {p:?}");
        }
    }

    #[test]
    fn infeasible_model_rejected() {
        // A model whose minimum memory exceeds every GPU even at max t.
        let mut m = model_by_name("gpt2-7b").unwrap();
        m.hidden = 16384;
        m.layers = 96; // ~300B params, 80G×t=4 can't hold 20W/t
        let marp = Marp::new(real_testbed(), MarpConfig { max_tp: 4, ..MarpConfig::default() });
        let plans = marp.plans(&m, &TrainConfig { global_batch: 2 });
        assert!(plans.is_empty());
    }

    #[test]
    fn plans_sorted_by_score_desc() {
        let marp = marp_real();
        let m = model_by_name("gpt2-760m").unwrap();
        let plans = marp.plans(&m, &TrainConfig { global_batch: 16 });
        assert!(plans.len() >= 2);
        for w in plans.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn plan_count_capped() {
        let marp = Marp::new(
            sia_sim(),
            MarpConfig { max_plans: 5, ..MarpConfig::default() },
        );
        let m = model_by_name("gpt2-125m").unwrap();
        let plans = marp.plans(&m, &TrainConfig { global_batch: 64 });
        assert!(plans.len() <= 5);
    }

    #[test]
    fn req_sz_accounts_for_headroom() {
        let marp = marp_real();
        let m = model_by_name("gpt2-350m").unwrap();
        let plans = marp.plans(&m, &TrainConfig { global_batch: 8 });
        for p in plans {
            assert!(p.min_gpu_mem >= p.predicted_bytes);
        }
    }

    #[test]
    fn no_plan_exceeds_cluster_gpu_count() {
        let marp = marp_real(); // 11 GPUs total
        let m = model_by_name("gpt2-125m").unwrap();
        let plans = marp.plans(&m, &TrainConfig { global_batch: 64 });
        for p in plans {
            assert!(p.n_gpus <= 11);
        }
    }
}
