//! Serverless CLI subcommands — every one goes through the v1 API.
//!
//! `submit`, `status`, `cancel`, and `list` talk to a running `frenzy serve`
//! instance over TCP via [`FrenzyClient`]. `predict` does the same when
//! `--addr` is given, and falls back to running MARP in-process otherwise
//! (so the dry-run works without a server). `serve` starts the coordinator
//! plus the thread-pool HTTP front-end.

use super::Args;
use crate::config::cluster_by_name;
use crate::serverless::api::{JobStatusV1, ListRequestV1, PlanV1, state_from_str};
use crate::serverless::client::FrenzyClient;
use crate::serverless::{CoordinatorConfig, PredictReport};
use crate::util::table::{fmt_bytes, Table};
use anyhow::{anyhow, bail, Result};

/// Default server address (matches `frenzy serve`).
pub const DEFAULT_ADDR: &str = "127.0.0.1:8315";

fn client(args: &Args) -> FrenzyClient {
    FrenzyClient::new(args.opt_or("addr", DEFAULT_ADDR))
}

/// Load a cluster: a named topology or a cluster file path.
pub fn cluster_arg(args: &Args) -> Result<crate::config::ClusterSpec> {
    let name = args.opt_or("cluster", "real");
    if let Some(c) = cluster_by_name(name) {
        return Ok(c);
    }
    crate::config::cluster_file::load_cluster(name)
}

/// First positional argument parsed as a job id (or `--id`).
fn job_id_arg(args: &Args) -> Result<u64> {
    if let Some(id) = args.opt_parse::<u64>("id")? {
        return Ok(id);
    }
    let raw = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("expected a job id (positional or --id)"))?;
    raw.parse().map_err(|_| anyhow!("bad job id '{raw}'"))
}

fn status_row(t: &mut Table, st: &JobStatusV1) {
    t.row(&[
        st.job_id.to_string(),
        st.name.clone(),
        crate::serverless::api::state_to_str(st.state).to_string(),
        st.gpus.to_string(),
        st.losses.last().map(|(_, l)| format!("{l:.4}")).unwrap_or_else(|| "-".into()),
    ]);
}

/// `frenzy submit --model M --batch B --samples N [--addr A]`
pub fn cmd_submit(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let batch: u32 = args.opt_parse_or("batch", 8)?;
    let samples: u64 = args.opt_parse_or("samples", 400)?;
    let mut c = client(args);
    let id = c.submit(model, batch, samples)?;
    println!("job {id} submitted ({model}, batch {batch}, {samples} samples)");
    println!("  frenzy status {id} --addr {}", c.addr());
    Ok(())
}

/// `frenzy status <id> [--addr A]`
pub fn cmd_status(args: &Args) -> Result<()> {
    let id = job_id_arg(args)?;
    let mut c = client(args);
    match c.status(id)? {
        None => bail!("no such job {id}"),
        Some(st) => {
            let mut t = Table::new(&["job", "name", "state", "gpus", "last loss"]);
            status_row(&mut t, &st);
            println!("{}", t.render());
            Ok(())
        }
    }
}

/// `frenzy cancel <id> [--addr A]`
pub fn cmd_cancel(args: &Args) -> Result<()> {
    let id = job_id_arg(args)?;
    let mut c = client(args);
    let resp = c.cancel(id)?;
    println!(
        "job {} {}",
        resp.job_id,
        if resp.cancelled { "cancelled" } else { "not cancelled" }
    );
    Ok(())
}

/// `frenzy list [--state S] [--offset O] [--limit L] [--addr A]`
pub fn cmd_list(args: &Args) -> Result<()> {
    let state = match args.opt("state") {
        None => None,
        Some(s) => Some(state_from_str(s).ok_or_else(|| {
            anyhow!("unknown state '{s}' (queued|running|completed|rejected|cancelled)")
        })?),
    };
    let req = ListRequestV1 {
        state,
        offset: args.opt_parse_or("offset", 0usize)?,
        limit: args.opt_parse_or("limit", crate::serverless::api::DEFAULT_LIST_LIMIT)?,
    };
    let mut c = client(args);
    let page = c.list(&req)?;
    let mut t = Table::new(&["job", "name", "state", "gpus", "last loss"]).with_title(&format!(
        "jobs {}..{} of {}",
        req.offset,
        req.offset + page.jobs.len(),
        page.total
    ));
    for st in &page.jobs {
        status_row(&mut t, st);
    }
    println!("{}", t.render());
    Ok(())
}

fn plan_table(title: &str, plans: &[PlanV1]) -> Table {
    let mut t = Table::new(&[
        "rank", "d", "t", "GPUs", "min GPU mem", "predicted", "est samples/s", "efficiency",
    ])
    .with_title(title);
    for (i, p) in plans.iter().enumerate() {
        t.row(&[
            (i + 1).to_string(),
            p.d.to_string(),
            p.t.to_string(),
            p.gpus.to_string(),
            fmt_bytes(p.min_gpu_mem),
            fmt_bytes(p.predicted_bytes),
            format!("{:.2}", p.est_samples_per_sec),
            format!("{:.0}%", p.est_efficiency * 100.0),
        ]);
    }
    t
}

/// `frenzy predict --model M --batch B [--addr A | --cluster C]`
///
/// With `--addr`, queries a running server's `/v1/predict` (the cluster is
/// whatever that server schedules for); otherwise runs MARP locally against
/// `--cluster` (default "real").
pub fn cmd_predict(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let batch: u32 = args.opt_parse_or("batch", 8)?;
    let resp = if args.opt("addr").is_some() {
        client(args).predict(model, batch)?
    } else {
        let cluster = cluster_arg(args)?;
        let marp = crate::marp::Marp::with_defaults(cluster.clone());
        let m = crate::config::models::model_by_name(model)
            .ok_or_else(|| anyhow!("unknown model '{model}' (see `frenzy models`)"))?;
        let plans = marp.plans(&m, &crate::memory::TrainConfig { global_batch: batch });
        let gpu_types = crate::serverless::GpuTypeInfo::aggregate(&cluster);
        let report = PredictReport { model: model.to_string(), batch, plans, gpu_types };
        crate::serverless::api::PredictResponseV1::from_report(&report)
    };
    if !resp.feasible {
        bail!("no feasible configuration — a submit would be rejected");
    }
    println!(
        "{}",
        plan_table(&format!("MARP resource plans for {model} (B={batch})"), &resp.plans).render()
    );
    let mut t = Table::new(&["GPU type", "mem", "count", "feasible plans", "predicted peak"])
        .with_title("per-GPU-type feasibility");
    for g in &resp.per_gpu_type {
        t.row(&[
            g.gpu.clone(),
            fmt_bytes(g.mem_bytes),
            g.count.to_string(),
            g.feasible_plans.to_string(),
            g.predicted_peak_bytes.map(fmt_bytes).unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{}", t.render());
    if let Some(chosen) = &resp.chosen {
        println!(
            "Frenzy would choose d={} t={} -> {} GPUs of >= {}",
            chosen.d,
            chosen.t,
            chosen.gpus,
            fmt_bytes(chosen.min_gpu_mem)
        );
    }
    Ok(())
}

/// `frenzy serve [--addr A] [--cluster C] [--steps N]`
pub fn cmd_serve(args: &Args) -> Result<()> {
    let cluster = cluster_arg(args)?;
    let addr = args.opt_or("addr", DEFAULT_ADDR);
    let steps: u64 = args.opt_parse_or("steps", 50)?;
    let cfg = CoordinatorConfig { max_real_steps: steps, ..Default::default() };
    let (handle, _join) = crate::serverless::spawn(cluster, cfg);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let local = crate::serverless::server::serve(handle, addr, stop)?;
    println!("frenzy serverless API v1 listening on http://{local}");
    println!("  POST /v1/jobs            {{\"model\":\"gpt2-350m\",\"batch\":8,\"samples\":400}}");
    println!("  GET  /v1/jobs            ?state=running&offset=0&limit=100");
    println!("  GET  /v1/jobs/<id>");
    println!("  POST /v1/jobs/<id>/cancel");
    println!("  POST /v1/predict         {{\"model\":\"gpt2-7b\",\"batch\":2}}  (dry run)");
    println!("  GET  /v1/cluster | /v1/healthz    (see API.md; unversioned aliases served)");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}
