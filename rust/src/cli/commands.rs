//! Serverless CLI subcommands — every one goes through the v1 API.
//!
//! `submit`, `status`, `cancel`, `list`, and `scale` talk to a running
//! `frenzy serve` instance over TCP via [`FrenzyClient`]. `predict` does the
//! same when `--addr` is given, and falls back to running MARP in-process
//! otherwise (so the dry-run works without a server). `serve` starts the
//! coordinator plus the thread-pool HTTP front-end. `replay` drives a
//! workload trace through the **live** engine (wall-clock coordinator +
//! timing stub) instead of the simulator — same
//! [`crate::engine::SchedulingEngine`], different clock — and with
//! `--addr` replays against a *remote* `frenzy serve` over HTTP,
//! exercising the full network path.

use super::Args;
use crate::config::cluster_by_name;
use crate::engine::EventKind;
use crate::job::JobSpec;
use crate::serverless::admission::QuotaCfg;
use crate::obs::expo;
use crate::serverless::api::{
    EventV1, EventsRequestV1, JobStatusV1, ListRequestV1, PlanV1, ReportV1, ScaleRequestV1,
    SubmitRequestV1, SubmitResultV1, VersionV1, state_from_str, MAX_BATCH_SUBMIT,
};
use crate::serverless::client::FrenzyClient;
use crate::serverless::{CoordinatorConfig, PredictReport, SchedulerKind, SubmitRequest};
use crate::util::table::{fmt_bytes, fmt_duration, Table};
use crate::workload::{generator, helios, newworkload, philly, trace};
use anyhow::{anyhow, bail, Result};

/// Default server address (matches `frenzy serve`).
pub const DEFAULT_ADDR: &str = "127.0.0.1:8315";

fn client(args: &Args) -> FrenzyClient {
    FrenzyClient::new(args.opt_or("addr", DEFAULT_ADDR))
}

/// Load a cluster: a named topology or a cluster file path.
pub fn cluster_arg(args: &Args) -> Result<crate::config::ClusterSpec> {
    let name = args.opt_or("cluster", "real");
    if let Some(c) = cluster_by_name(name) {
        return Ok(c);
    }
    crate::config::cluster_file::load_cluster(name)
}

/// Resolve `--workload` into a job trace: a named generator, a
/// `synth:<spec>` open-world generator spec (see
/// [`crate::workload::generator`] for the grammar), or a trace file path
/// (shared by `frenzy simulate` and `frenzy replay`).
pub fn load_workload(name: &str, n: usize, seed: u64) -> Result<Vec<JobSpec>> {
    Ok(match name {
        "newworkload" => newworkload::generate(n, seed),
        "philly" => philly::generate(n, seed),
        "helios" => helios::generate(n, seed),
        // Bare `synth` = every clause defaulted; `--tasks`/`--seed` still
        // apply as the jobs/seed fallbacks.
        "synth" => generator::from_spec("", n, seed).map_err(|e| anyhow!(e))?,
        other => match other.strip_prefix("synth:") {
            Some(spec) => generator::from_spec(spec, n, seed).map_err(|e| anyhow!(e))?,
            None => trace::load(other)?, // treat as a trace file
        },
    })
}

/// First positional argument parsed as a job id (or `--id`).
fn job_id_arg(args: &Args) -> Result<u64> {
    if let Some(id) = args.opt_parse::<u64>("id")? {
        return Ok(id);
    }
    let raw = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("expected a job id (positional or --id)"))?;
    raw.parse().map_err(|_| anyhow!("bad job id '{raw}'"))
}

fn status_row(t: &mut Table, st: &JobStatusV1) {
    t.row(&[
        st.job_id.to_string(),
        st.name.clone(),
        crate::serverless::api::state_to_str(st.state).to_string(),
        st.gpus.to_string(),
        st.losses.last().map(|(_, l)| format!("{l:.4}")).unwrap_or_else(|| "-".into()),
    ]);
}

/// `frenzy submit --model M --batch B --samples N [--addr A]`
pub fn cmd_submit(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let batch: u32 = args.opt_parse_or("batch", 8)?;
    let samples: u64 = args.opt_parse_or("samples", 400)?;
    let mut c = client(args);
    let id = c.submit(model, batch, samples)?;
    println!("job {id} submitted ({model}, batch {batch}, {samples} samples)");
    println!("  frenzy status {id} --addr {}", c.addr());
    Ok(())
}

/// `frenzy status <id> [--addr A]`
pub fn cmd_status(args: &Args) -> Result<()> {
    let id = job_id_arg(args)?;
    let mut c = client(args);
    match c.status(id)? {
        None => bail!("no such job {id}"),
        Some(st) => {
            let mut t = Table::new(&["job", "name", "state", "gpus", "last loss"]);
            status_row(&mut t, &st);
            println!("{}", t.render());
            Ok(())
        }
    }
}

/// `frenzy cancel <id> [--addr A]`
pub fn cmd_cancel(args: &Args) -> Result<()> {
    let id = job_id_arg(args)?;
    let mut c = client(args);
    let resp = c.cancel(id)?;
    println!(
        "job {} {}",
        resp.job_id,
        if resp.cancelled { "cancelled" } else { "not cancelled" }
    );
    Ok(())
}

/// `frenzy list [--state S] [--offset O] [--limit L] [--addr A]`
pub fn cmd_list(args: &Args) -> Result<()> {
    let state = match args.opt("state") {
        None => None,
        Some(s) => Some(state_from_str(s).ok_or_else(|| {
            anyhow!("unknown state '{s}' (queued|running|completed|rejected|cancelled)")
        })?),
    };
    let req = ListRequestV1 {
        state,
        offset: args.opt_parse_or("offset", 0usize)?,
        limit: args.opt_parse_or("limit", crate::serverless::api::DEFAULT_LIST_LIMIT)?,
    };
    let mut c = client(args);
    let page = c.list(&req)?;
    let mut t = Table::new(&["job", "name", "state", "gpus", "last loss"]).with_title(&format!(
        "jobs {}..{} of {}",
        req.offset,
        req.offset + page.jobs.len(),
        page.total
    ));
    for st in &page.jobs {
        status_row(&mut t, st);
    }
    println!("{}", t.render());
    Ok(())
}

fn plan_table(title: &str, plans: &[PlanV1]) -> Table {
    let mut t = Table::new(&[
        "rank", "d", "t", "GPUs", "min GPU mem", "predicted", "est samples/s", "efficiency",
    ])
    .with_title(title);
    for (i, p) in plans.iter().enumerate() {
        t.row(&[
            (i + 1).to_string(),
            p.d.to_string(),
            p.t.to_string(),
            p.gpus.to_string(),
            fmt_bytes(p.min_gpu_mem),
            fmt_bytes(p.predicted_bytes),
            format!("{:.2}", p.est_samples_per_sec),
            format!("{:.0}%", p.est_efficiency * 100.0),
        ]);
    }
    t
}

/// `frenzy predict --model M --batch B [--addr A | --cluster C]`
///
/// With `--addr`, queries a running server's `/v1/predict` (the cluster is
/// whatever that server schedules for); otherwise runs MARP locally against
/// `--cluster` (default "real").
pub fn cmd_predict(args: &Args) -> Result<()> {
    let model = args.require("model")?;
    let batch: u32 = args.opt_parse_or("batch", 8)?;
    let resp = if args.opt("addr").is_some() {
        client(args).predict(model, batch)?
    } else {
        let cluster = cluster_arg(args)?;
        let marp = crate::marp::Marp::with_defaults(cluster.clone());
        let m = crate::config::models::model_by_name(model)
            .ok_or_else(|| anyhow!("unknown model '{model}' (see `frenzy models`)"))?;
        let plans = marp.plans(&m, &crate::memory::TrainConfig { global_batch: batch });
        let gpu_types = crate::serverless::GpuTypeInfo::aggregate(&cluster);
        let report = PredictReport { model: model.to_string(), batch, plans, gpu_types };
        crate::serverless::api::PredictResponseV1::from_report(&report)
    };
    if !resp.feasible {
        bail!("no feasible configuration — a submit would be rejected");
    }
    println!(
        "{}",
        plan_table(&format!("MARP resource plans for {model} (B={batch})"), &resp.plans).render()
    );
    let mut t = Table::new(&["GPU type", "mem", "count", "feasible plans", "predicted peak"])
        .with_title("per-GPU-type feasibility");
    for g in &resp.per_gpu_type {
        t.row(&[
            g.gpu.clone(),
            fmt_bytes(g.mem_bytes),
            g.count.to_string(),
            g.feasible_plans.to_string(),
            g.predicted_peak_bytes.map(fmt_bytes).unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{}", t.render());
    if let Some(chosen) = &resp.chosen {
        println!(
            "Frenzy would choose d={} t={} -> {} GPUs of >= {}",
            chosen.d,
            chosen.t,
            chosen.gpus,
            fmt_bytes(chosen.min_gpu_mem)
        );
    }
    Ok(())
}

/// `frenzy scale --join --gpu <type> [--count N] [--link nvlink|pcie] [--addr A]`
/// `frenzy scale --leave <node> [--addr A]`
///
/// Elastic cluster scaling against a running server: join a node of
/// catalog GPUs, or retire a node (its jobs are preempted and requeued).
pub fn cmd_scale(args: &Args) -> Result<()> {
    let req = if let Some(node) = args.opt_parse::<usize>("leave")? {
        ScaleRequestV1::Leave { node }
    } else if args.flag("join") || args.opt("gpu").is_some() {
        let link = args.opt_or("link", "pcie");
        ScaleRequestV1::Join {
            gpu: args.require("gpu")?.to_string(),
            count: args.opt_parse_or("count", 1u32)?,
            link: crate::serverless::api::link_from_str(link)
                .ok_or_else(|| anyhow!("unknown link '{link}' (nvlink|pcie)"))?,
        }
    } else {
        bail!("expected --join --gpu <type> [--count N] [--link nvlink|pcie] or --leave <node>");
    };
    let mut c = client(args);
    let resp = c.scale(&req)?;
    // Displaced jobs are usually requeued, but one past its attempt budget
    // is rejected instead — point the operator at status, don't promise.
    let preempted = if resp.preempted.is_empty() {
        String::new()
    } else {
        format!("; preempted jobs {:?} — check `frenzy status`", resp.preempted)
    };
    println!(
        "cluster scaled ({}): node {} — {} GPUs total, {} idle{}",
        resp.op, resp.node, resp.total_gpus, resp.idle_gpus, preempted
    );
    Ok(())
}

/// Resolve `--sched` into a live [`SchedulerKind`]. Interval schedulers
/// (Sia) take their round cadence from `--round-interval` (seconds,
/// defaulting to `default_interval_s`).
pub fn scheduler_arg(args: &Args, default_interval_s: f64) -> Result<SchedulerKind> {
    Ok(match args.opt_or("sched", "has") {
        "has" | "frenzy" => SchedulerKind::Has,
        "sia" => SchedulerKind::Sia {
            round_interval_s: args.opt_parse_or("round-interval", default_interval_s)?,
        },
        "opportunistic" | "opp" => SchedulerKind::Opportunistic,
        other => bail!("unknown scheduler '{other}' (has|sia|opportunistic)"),
    })
}

/// One human-readable event-log line.
fn fmt_event(e: &EventV1) -> String {
    let detail = match &e.kind {
        EventKind::Arrival { job } => format!("job {job} arrived"),
        EventKind::Placed { job, epoch, attempts, gpus, d, t, parts, will_oom } => format!(
            "job {job} placed: {gpus} GPUs (d={d} t={t}) on {parts:?} (epoch {epoch}, attempt {attempts}{})",
            if *will_oom { ", will OOM" } else { "" }
        ),
        EventKind::Finished { job, epoch } => format!("job {job} finished (epoch {epoch})"),
        EventKind::Oomed { job, epoch, requeued } => format!(
            "job {job} OOMed (epoch {epoch}) — {}",
            if *requeued { "requeued" } else { "attempt budget exhausted" }
        ),
        EventKind::OomObserved { job, epoch, node, predicted_bytes, observed_bytes, capacity_bytes } => {
            format!(
                "job {job} observed OOM on node {node} (epoch {epoch}): {} used vs {} capacity (predicted {})",
                fmt_bytes(*observed_bytes),
                fmt_bytes(*capacity_bytes),
                fmt_bytes(*predicted_bytes)
            )
        }
        EventKind::DrainRequested { job, epoch, node, deadline_s } => format!(
            "job {job} asked to drain (epoch {epoch}, node {node} retiring, deadline {deadline_s:.3}s)"
        ),
        EventKind::Drained { job, epoch, node, steps_ckpt, state_digest } => format!(
            "job {job} drained off node {node} (epoch {epoch}): checkpointed at step {steps_ckpt} (digest {state_digest:#x})"
        ),
        EventKind::ResumedFromCkpt { job, epoch, steps_ckpt } => {
            format!("job {job} resumed from checkpoint at step {steps_ckpt} (epoch {epoch})")
        }
        EventKind::Preempted { job, node } => {
            format!("job {job} preempted (node {node} retired)")
        }
        EventKind::Rejected { job, reason } => {
            format!("job {job} rejected: {}", reason.as_str())
        }
        EventKind::Cancelled { job, was_running } => format!(
            "job {job} cancelled ({})",
            if *was_running { "was running" } else { "was queued" }
        ),
        EventKind::NodeJoined { node, gpu, gpus } => {
            format!("node {node} joined: {gpus}x {gpu}")
        }
        EventKind::NodeLeft { node, preempted } => {
            format!("node {node} left; displaced jobs {preempted:?}")
        }
        EventKind::NodeRetired { node } => {
            format!("node {node} fully retired (drain complete; safe to power off)")
        }
        EventKind::NodeCrashed { node, preempted } => format!(
            "node {node} CRASHED (no drain grace); displaced jobs {preempted:?} lose work past their last checkpoint"
        ),
        EventKind::NodeQuarantined { node, until_s } => {
            format!("node {node} quarantined until t={until_s:.3}s (excluded from placement)")
        }
        EventKind::NodeProbation { node } => {
            format!("node {node} finished probation — eligible for placement again")
        }
        EventKind::NodeSlowdown { node, factor } => {
            format!("node {node} running at {:.0}% speed (straggler)", factor * 100.0)
        }
    };
    format!("[{:>9.3}s] #{:<5} {detail}", e.time, e.seq)
}

/// Read a follower cursor file: the last event seq this follower printed,
/// written by `frenzy events --follow --cursor <path>`. Absent or
/// unparseable files mean "start from the beginning" — a follower must
/// never refuse to start over a damaged cursor.
fn read_cursor(path: &std::path::Path) -> u64 {
    std::fs::read_to_string(path).ok().and_then(|s| s.trim().parse().ok()).unwrap_or(0)
}

/// Persist the follower cursor atomically (tmp + rename) so a crash
/// mid-write can't leave a torn cursor that replays from zero.
fn write_cursor(path: &std::path::Path, seq: u64) -> Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, format!("{seq}\n"))?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// `frenzy events [--since N] [--limit L] [--follow] [--wait-ms W]
///               [--cursor PATH] [--addr A]`
///
/// Prints the cluster event log — the audit trail of arrivals, placements
/// (with the chosen plan), finishes, observed OOMs, drains, preemptions,
/// rejections, and node joins/leaves. `--follow` tails the stream,
/// preferring the server's SSE push feed (`?stream=1`, events delivered
/// as they happen over one connection) and falling back to long-poll
/// (`?wait_ms=`) when the stream cannot be opened: each fallback request
/// parks on the server until a new event lands or the wait elapses, so
/// an idle follower sends a few quiet requests per minute instead of
/// busy-polling.
///
/// `--cursor <path>` makes the follower restartable: the last printed seq
/// is persisted after every page, and a restarted `frenzy events --cursor
/// <path>` resumes from it instead of re-printing history. An explicit
/// `--since` overrides the stored cursor (and the new position is then
/// persisted as usual).
pub fn cmd_events(args: &Args) -> Result<()> {
    let mut c = client(args);
    let cursor = args.opt("cursor").map(std::path::PathBuf::from);
    let since = match args.opt_parse::<u64>("since")? {
        Some(s) => s, // explicit --since wins over the stored cursor
        None => cursor.as_deref().map(read_cursor).unwrap_or(0),
    };
    let mut req = EventsRequestV1 {
        since,
        // Clamp like the server does: a zero limit makes no progress.
        limit: args
            .opt_parse_or("limit", crate::serverless::api::DEFAULT_EVENTS_LIMIT)?
            .clamp(1, crate::serverless::api::MAX_EVENTS_LIMIT),
        wait_ms: 0,
        stream: false,
    };
    let follow = args.flag("follow");
    let follow_wait: u64 = args
        .opt_parse_or("wait-ms", 5_000u64)?
        .clamp(1, crate::serverless::api::MAX_EVENTS_WAIT_MS);
    let mut use_sse = true;
    let mut printed = 0usize;
    loop {
        let t0 = std::time::Instant::now();
        let page = c.events(&req)?;
        if page.dropped {
            eprintln!(
                "warning: events before seq {} were evicted from the ring — history has a gap",
                page.first_seq
            );
        }
        for e in &page.events {
            println!("{}", fmt_event(e));
        }
        printed += page.events.len();
        req.since = page.next_since;
        if let Some(path) = &cursor {
            write_cursor(path, req.since)?;
        }
        // Keep paging while the log has records past this page — a one-shot
        // invocation must print the whole retained history, not one page.
        // An empty page means no progress is possible; never spin on it.
        if !page.events.is_empty() && page.next_since < page.last_seq {
            continue;
        }
        if !follow {
            if printed == 0 {
                println!("(no events)");
            }
            return Ok(());
        }
        // Tail mode: long-poll from the last seen sequence number. If the
        // server answered an empty page early (its long-poll slots were
        // all taken, so it degraded to an immediate answer), pace the next
        // request ourselves instead of hammering it in a tight loop.
        if req.wait_ms > 0 && page.events.is_empty() {
            let want = std::time::Duration::from_millis(req.wait_ms);
            let elapsed = t0.elapsed();
            if elapsed < want {
                std::thread::sleep(want - elapsed);
            }
        }
        req.wait_ms = follow_wait;
        // Prefer the SSE push feed (`?stream=1`): one connection, events
        // printed as the server emits them, no polling at all. A failed
        // subscribe (older server, buffering proxy) falls back to the
        // long-poll loop for good; a cleanly ended stream goes back to
        // the top for one catch-up long-poll, then resubscribes.
        if use_sse {
            let cur = cursor.clone();
            match c.events_stream(&req, |e| {
                println!("{}", fmt_event(e));
                if let Some(path) = &cur {
                    let _ = write_cursor(path, e.seq);
                }
                true
            }) {
                Ok(seq) => {
                    req.since = req.since.max(seq);
                    if let Some(path) = &cursor {
                        write_cursor(path, req.since)?;
                    }
                }
                Err(_) => use_sse = false,
            }
        }
    }
}

/// Render a v1 report as tables (shared by `frenzy report` and the remote
/// replay summary).
fn render_report(r: &ReportV1) {
    let mut t = Table::new(&["metric", "value"])
        .with_title(&format!("run report: {} ({})", r.scheduler, r.workload));
    t.row_str(&["jobs", &r.n_jobs.to_string()]);
    t.row_str(&["completed", &r.n_completed.to_string()]);
    t.row_str(&["rejected", &r.n_rejected.to_string()]);
    t.row_str(&["cancelled", &r.n_cancelled.to_string()]);
    if r.n_throttled_backpressure > 0 || r.n_throttled_quota > 0 {
        let throttled = format!(
            "{} backpressure / {} quota (since boot)",
            r.n_throttled_backpressure, r.n_throttled_quota
        );
        t.row_str(&["throttled submits (429)", &throttled]);
    }
    t.row_str(&["avg JCT", &fmt_duration(r.avg_jct_s)]);
    t.row_str(&["p50 JCT (approx)", &fmt_duration(r.p50_jct_s)]);
    t.row_str(&["p99 JCT (approx)", &fmt_duration(r.p99_jct_s)]);
    let minmax = format!("{} / {}", fmt_duration(r.jct_min_s), fmt_duration(r.jct_max_s));
    t.row_str(&["JCT min/max", &minmax]);
    t.row_str(&["avg queue", &fmt_duration(r.avg_queue_s)]);
    t.row_str(&["makespan", &fmt_duration(r.makespan_s)]);
    t.row_str(&["OOM events", &r.n_oom_events.to_string()]);
    t.row_str(&["OOM/preempt retries", &r.total_oom_retries.to_string()]);
    t.row_str(&["graceful drains", &r.n_drains.to_string()]);
    t.row_str(&["steps executed", &r.total_steps_executed.to_string()]);
    if r.n_node_crashes > 0 || r.total_steps_lost > 0 {
        t.row_str(&["node crashes", &r.n_node_crashes.to_string()]);
        t.row_str(&["crash requeues", &r.n_crash_requeues.to_string()]);
        t.row_str(&["quarantines", &r.n_quarantines.to_string()]);
        t.row_str(&["steps lost to crashes", &r.total_steps_lost.to_string()]);
        t.row_str(&["goodput", &format!("{:.1}%", r.goodput * 100.0)]);
    }
    if r.mem_pred_samples > 0 {
        let acc = format!(
            "{:.1}% avg / {:.1}% min ({} dispatches)",
            r.mem_pred_accuracy_avg * 100.0,
            r.mem_pred_accuracy_min * 100.0,
            r.mem_pred_samples
        );
        t.row_str(&["memory prediction", &acc]);
    }
    t.row_str(&["sched overhead (wall)", &fmt_duration(r.sched_overhead_s)]);
    t.row_str(&["utilization", &format!("{:.1}%", r.avg_utilization * 100.0)]);
    println!("{}", t.render());
    if !r.tenants.is_empty() {
        let mut tt = Table::new(&[
            "tenant", "completed", "avg JCT", "avg queue", "GPU-seconds", "GPU share",
        ])
        .with_title("per-tenant fairness");
        for row in &r.tenants {
            tt.row_str(&[
                &row.tenant,
                &row.n_completed.to_string(),
                &fmt_duration(row.avg_jct_s),
                &fmt_duration(row.avg_queue_s),
                &format!("{:.1}", row.gpu_seconds),
                &format!("{:.1}%", row.gpu_share * 100.0),
            ]);
        }
        println!("{}", tt.render());
    }
    let occupied: Vec<&(f64, u64)> = r.jct_hist.iter().filter(|&&(_, c)| c > 0).collect();
    if !occupied.is_empty() {
        let mut h = Table::new(&["JCT <=", "jobs"]).with_title("JCT histogram");
        for &&(le, count) in &occupied {
            h.row_str(&[&fmt_duration(le), &count.to_string()]);
        }
        if r.jct_hist_overflow > 0 {
            h.row_str(&["(overflow)", &r.jct_hist_overflow.to_string()]);
        }
        println!("{}", h.render());
    }
}

/// `frenzy report [--addr A]` — the coordinator's streaming run report.
pub fn cmd_report(args: &Args) -> Result<()> {
    let mut c = client(args);
    let r: ReportV1 = c.report()?;
    render_report(&r);
    Ok(())
}

/// `frenzy version [--addr A]` (also `frenzy --version`) — this binary's
/// build identity; with `--addr`, the serving binary's as well (catches
/// client/server skew at a glance).
pub fn cmd_version(args: &Args) -> Result<()> {
    let v = VersionV1::current();
    println!("frenzy {} (git {})", v.version, v.git_sha);
    println!("features: {}", v.features.join(", "));
    if args.opt("addr").is_some() {
        let sv = client(args).version()?;
        println!(
            "server {}: frenzy {} (git {})",
            args.opt_or("addr", DEFAULT_ADDR),
            sv.version,
            sv.git_sha
        );
    }
    Ok(())
}

/// `frenzy metrics [--addr A] [--check]` — dump the server's raw
/// Prometheus exposition to stdout; with `--check`, run the conformance
/// validator over the live output instead of printing it (the CI scrape
/// smoke test rides on this).
pub fn cmd_metrics(args: &Args) -> Result<()> {
    let mut c = client(args);
    let text = c.metrics_text()?;
    if args.flag("check") {
        let samples = expo::parse(&text).map_err(|e| anyhow!("exposition parse: {e}"))?;
        expo::validate(&text).map_err(|e| anyhow!("exposition conformance: {e}"))?;
        println!("ok: {} samples, conformant exposition from {}", samples.len(), c.addr());
    } else {
        print!("{text}");
    }
    Ok(())
}

/// `frenzy top [--addr A] [--interval S] [--iterations N]` — live
/// dashboard over `/metrics` + `/v1/report`: jobs, scheduler round-phase
/// latency quantiles, per-route HTTP traffic, WAL health, device memory.
/// `--iterations 0` (default) refreshes until interrupted;
/// `--iterations 1` prints a single frame and exits (scriptable).
pub fn cmd_top(args: &Args) -> Result<()> {
    let interval: f64 = args.opt_parse_or("interval", 2.0)?;
    let iterations: u64 = args.opt_parse_or("iterations", 0)?;
    let mut c = client(args);
    let mut frame = 0u64;
    loop {
        let text = c.metrics_text()?;
        let samples =
            expo::parse(&text).map_err(|e| anyhow!("bad exposition from server: {e}"))?;
        // The dashboard stays up through a transient report error.
        let report = c.report().ok();
        if frame > 0 {
            // ANSI clear + home between frames only — a single-frame run
            // emits no escapes, so it composes with pipes and tests.
            print!("\x1b[2J\x1b[H");
        }
        render_top(c.addr(), &samples, report.as_ref());
        frame += 1;
        if iterations > 0 && frame >= iterations {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval.clamp(0.1, 3600.0)));
    }
}

/// One `frenzy top` frame, rendered entirely from parsed samples (plus
/// the report for the run-level JCT numbers the registry doesn't carry).
fn render_top(addr: &str, samples: &[expo::Sample], report: Option<&ReportV1>) {
    fn val(samples: &[expo::Sample], name: &str, want: &[(&str, &str)]) -> f64 {
        expo::sample_value(samples, name, want).unwrap_or(0.0)
    }
    fn fmt_q(x: Option<f64>) -> String {
        x.map(fmt_duration).unwrap_or_else(|| "-".into())
    }

    let version = samples
        .iter()
        .find(|s| s.name == "frenzy_build_info")
        .and_then(|s| s.labels.iter().find(|(k, _)| k == "version"))
        .map(|(_, v)| v.clone())
        .unwrap_or_else(|| "?".into());
    println!(
        "frenzy top — {addr} — server v{version}, up {}",
        fmt_duration(val(samples, "frenzy_process_uptime_seconds", &[]))
    );

    let queued = val(samples, "frenzy_jobs", &[("state", "queued")]);
    let running = val(samples, "frenzy_jobs", &[("state", "running")]);
    let inflight = val(samples, "frenzy_http_inflight_requests", &[]);
    let mut t = Table::new(&["metric", "value"]).with_title("load");
    t.row_str(&["jobs queued", &format!("{queued:.0}")]);
    t.row_str(&["jobs running", &format!("{running:.0}")]);
    t.row_str(&[
        "coordinator mailbox",
        &format!("{:.0}", val(samples, "frenzy_coordinator_mailbox_depth", &[])),
    ]);
    t.row_str(&["http in-flight", &format!("{inflight:.0}")]);
    let shed = format!(
        "{:.0} accept-queue 503 / {:.0} throttle 429",
        val(samples, "frenzy_http_shed_total", &[("kind", "accept_queue_503")]),
        val(samples, "frenzy_http_shed_total", &[("kind", "throttle_429")]),
    );
    t.row_str(&["load shed", &shed]);
    let adm =
        |d: &'static str| val(samples, "frenzy_admission_decisions_total", &[("decision", d)]);
    let admissions = format!(
        "{:.0} admitted / {:.0} backpressure / {:.0} quota / {:.0} infeasible",
        adm("admitted"),
        adm("throttled_backpressure"),
        adm("throttled_quota"),
        adm("rejected_infeasible"),
    );
    t.row_str(&["admission", &admissions]);
    let ooms = val(samples, "frenzy_oom_events_total", &[]);
    let requeues = val(samples, "frenzy_crash_requeues_total", &[]);
    t.row_str(&["oom events", &format!("{ooms:.0}")]);
    t.row_str(&["crash requeues", &format!("{requeues:.0}")]);
    println!("{}", t.render());

    let mut ph =
        Table::new(&["phase", "rounds", "p50", "p90", "p99"]).with_title("scheduler round phases");
    for phase in ["candidate_scan", "plan_rank", "placement"] {
        let series =
            expo::bucket_series(samples, "frenzy_sched_round_phase_seconds", &[("phase", phase)]);
        let count = series.last().map_or(0.0, |&(_, c)| c);
        ph.row_str(&[
            phase,
            &format!("{count:.0}"),
            &fmt_q(expo::quantile(&series, 0.5)),
            &fmt_q(expo::quantile(&series, 0.9)),
            &fmt_q(expo::quantile(&series, 0.99)),
        ]);
    }
    println!("{}", ph.render());

    let mut ht = Table::new(&["route", "requests", "p50", "p99"]).with_title("http routes");
    let mut any_route = false;
    for &route in crate::obs::ROUTES {
        let total: f64 = samples
            .iter()
            .filter(|s| {
                s.name == "frenzy_http_requests_total"
                    && s.labels.iter().any(|(k, v)| k == "route" && v == route)
            })
            .map(|s| s.value)
            .sum();
        if total == 0.0 {
            continue;
        }
        any_route = true;
        let series = expo::bucket_series(
            samples,
            "frenzy_http_request_duration_seconds",
            &[("route", route)],
        );
        ht.row_str(&[
            route,
            &format!("{total:.0}"),
            &fmt_q(expo::quantile(&series, 0.5)),
            &fmt_q(expo::quantile(&series, 0.99)),
        ]);
    }
    if any_route {
        println!("{}", ht.render());
    }

    if val(samples, "frenzy_wal_appends_total", &[]) > 0.0 {
        let appends = val(samples, "frenzy_wal_appends_total", &[]);
        let mut wt = Table::new(&["metric", "value"]).with_title("durability");
        wt.row_str(&["wal appends", &format!("{appends:.0}")]);
        wt.row_str(&[
            "wal bytes",
            &fmt_bytes(val(samples, "frenzy_wal_append_bytes_total", &[]) as u64),
        ]);
        wt.row_str(&["wal segments", &format!("{:.0}", val(samples, "frenzy_wal_segments", &[]))]);
        let fsync = expo::bucket_series(samples, "frenzy_wal_fsync_seconds", &[]);
        wt.row_str(&["fsync p99", &fmt_q(expo::quantile(&fsync, 0.99))]);
        wt.row_str(&["snapshots", &format!("{:.0}", val(samples, "frenzy_snapshots_total", &[]))]);
        wt.row_str(&[
            "snapshot age",
            &fmt_duration(val(samples, "frenzy_snapshot_age_seconds", &[])),
        ]);
        println!("{}", wt.render());
    }

    let used: Vec<&expo::Sample> =
        samples.iter().filter(|s| s.name == "frenzy_node_device_mem_used_bytes").collect();
    if !used.is_empty() {
        let mut nt = Table::new(&["node", "mem used", "capacity"]).with_title("device memory");
        for s in used {
            let node =
                s.labels.iter().find(|(k, _)| k == "node").map_or("?", |(_, v)| v.as_str());
            let cap = val(samples, "frenzy_node_device_mem_capacity_bytes", &[("node", node)]);
            nt.row_str(&[node, &fmt_bytes(s.value as u64), &fmt_bytes(cap as u64)]);
        }
        println!("{}", nt.render());
    }

    if let Some(r) = report {
        let mut rt = Table::new(&["metric", "value"]).with_title("run report");
        rt.row_str(&["completed", &r.n_completed.to_string()]);
        rt.row_str(&["rejected", &r.n_rejected.to_string()]);
        rt.row_str(&["avg JCT", &fmt_duration(r.avg_jct_s)]);
        rt.row_str(&["p99 JCT", &fmt_duration(r.p99_jct_s)]);
        rt.row_str(&["utilization", &format!("{:.1}%", r.avg_utilization * 100.0)]);
        if r.mem_pred_samples > 0 {
            rt.row_str(&[
                "mem prediction",
                &format!("{:.1}% avg", r.mem_pred_accuracy_avg * 100.0),
            ]);
        }
        println!("{}", rt.render());
    }
}

/// Remote half of `frenzy replay --addr`: drive the trace against a
/// running `frenzy serve` over the v1 HTTP API. The server executes with
/// whatever scheduler/cluster/executor it was started with; this side only
/// submits, polls until every submitted job goes terminal, and renders the
/// server's streaming report. The stall deadline (`--timeout`, seconds)
/// only fires when *no job makes progress* for that long — a slow server
/// that keeps completing jobs is never aborted.
fn replay_remote(
    addr: &str,
    workload: &str,
    jobs: &[JobSpec],
    speedup: f64,
    stall_timeout_s: u64,
) -> Result<()> {
    let mut c = FrenzyClient::new(addr);
    if !c.health()? {
        bail!("server at {addr} is not healthy");
    }
    println!(
        "replaying {} jobs from '{}' against {} over HTTP ({}x speedup, batched submit)",
        jobs.len(),
        workload,
        addr,
        speedup,
    );
    // Submit in arrival order, coalescing jobs whose (sped-up) inter-
    // arrival gap rounds to zero into one `jobs:batch` call — one round
    // trip and one WAL fsync per burst instead of per job. Per-job 429s
    // honor the largest Retry-After in the batch and resubmit only the
    // throttled entries; any other rejection aborts the replay.
    fn flush(c: &mut FrenzyClient, batch: &mut Vec<SubmitRequestV1>) -> Result<()> {
        while !batch.is_empty() {
            let resp = c.submit_batch(batch)?;
            let mut retry = Vec::new();
            let mut wait_ms = 0u64;
            for (req, res) in batch.iter().zip(&resp.results) {
                if let SubmitResultV1::Rejected(e) = res {
                    if e.code == 429 {
                        wait_ms = wait_ms.max(e.retry_after_ms.unwrap_or(1000));
                        retry.push(req.clone());
                    } else {
                        bail!("submit of '{}' rejected: {}: {}", req.model, e.code, e.message);
                    }
                }
            }
            *batch = retry;
            if !batch.is_empty() {
                std::thread::sleep(std::time::Duration::from_millis(wait_ms.clamp(50, 5_000)));
            }
        }
        Ok(())
    }
    let mut batch: Vec<SubmitRequestV1> = Vec::new();
    let mut last_submit = 0.0f64;
    for j in jobs {
        let gap = ((j.submit_time - last_submit) / speedup).clamp(0.0, 0.25);
        if gap > 0.0 || batch.len() >= MAX_BATCH_SUBMIT {
            flush(&mut c, &mut batch)?;
        }
        if gap > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(gap));
        }
        last_submit = j.submit_time;
        let mut req = SubmitRequestV1::new(j.model.name, j.train.global_batch, j.total_samples);
        // A generated job's tenant rides the submit body's `user` field, so
        // the server's quotas and per-tenant report see the same principal
        // the simulator would.
        req.user = j.tenant.clone();
        batch.push(req);
    }
    flush(&mut c, &mut batch)?;
    // Wait until every submitted job is terminal. Two filtered list
    // queries per cycle (not one status request per job, which would load
    // the server we are measuring with O(jobs) requests every 100 ms);
    // this assumes the replay is the server's only submitter, which is
    // the point of a replay run. The deadline resets whenever the live
    // count drops, so it bounds *stall* time, not total runtime.
    let stall = std::time::Duration::from_secs(stall_timeout_s.max(1));
    let mut deadline = std::time::Instant::now() + stall;
    let mut last_remaining = usize::MAX;
    let live_count = |c: &mut FrenzyClient, state| -> Result<usize> {
        Ok(c.list(&ListRequestV1 { state: Some(state), offset: 0, limit: 1 })?.total)
    };
    loop {
        let remaining = live_count(&mut c, crate::job::JobState::Queued)?
            + live_count(&mut c, crate::job::JobState::Running)?;
        if remaining == 0 {
            break;
        }
        if remaining < last_remaining {
            last_remaining = remaining;
            deadline = std::time::Instant::now() + stall;
        }
        if std::time::Instant::now() > deadline {
            bail!(
                "{remaining} jobs made no progress for {}s — check the server \
                 (raise --timeout for slow executors)",
                stall.as_secs()
            );
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let report = c.report()?;
    render_report(&report);
    Ok(())
}

/// `frenzy replay --workload philly --tasks 20 [--speedup 1000] [--stub-ms 20]
///               [--cluster real|sim] [--seed S] [--addr host:port]
///               [--timeout 300] [--faults <spec|seed:N>]`
///
/// `--faults` runs the replay under deterministic fault injection: the
/// plan (a comma-separated spec like `crash:0@1.5,blackout:2@3+1` or a
/// seeded `seed:42`) is compiled against the cluster and injected into
/// the live coordinator at the scripted wall-clock offsets — crashes
/// preempt abruptly with no drain grace, so the report's goodput and
/// crash counters show what the chaos cost. Only the in-process replay
/// injects; `--faults` with `--addr` is an error (the remote server owns
/// its own `--faults` flag).
///
/// Replays a workload trace through the **live** scheduling path. Without
/// `--addr` it spawns the wall-clock coordinator in-process with the
/// timing stub as executor; with `--addr` it submits the same trace to a
/// remote `frenzy serve` over the v1 HTTP API — exercising the full
/// network path (SDK framing, server routing, coordinator mailbox) — then
/// waits for every submitted job to go terminal and prints the server's
/// streaming report. In both modes jobs are submitted in arrival order
/// (inter-arrival gaps divided by `--speedup`, capped at 250 ms each).
/// Because the live coordinator and the simulator share one
/// `SchedulingEngine`, this exercises exactly the code the figures
/// simulate — on real threads, real time, and the real dispatch path.
pub fn cmd_replay(args: &Args) -> Result<()> {
    let cluster = cluster_arg(args)?;
    let n: usize = args.opt_parse_or("tasks", 20)?;
    let seed: u64 = args.opt_parse_or("seed", 11)?;
    let speedup: f64 = args.opt_parse_or("speedup", 1000.0)?;
    let stub_ms: u64 = args.opt_parse_or("stub-ms", 20)?;
    let workload = args.opt_or("workload", "newworkload");
    let jobs = load_workload(workload, n, seed)?;
    if speedup <= 0.0 {
        bail!("--speedup must be > 0");
    }
    let faults = match args.opt("faults") {
        None => None,
        Some(spec) => {
            if args.opt("addr").is_some() {
                bail!("--faults injects into the in-process coordinator; drop --addr (a remote `frenzy serve` takes its own --faults flag)");
            }
            // Seeded plans scatter events across the replay's expected wall
            // span: the sped-up submit window plus a tail for execution.
            let last_arrival = jobs.iter().map(|j| j.submit_time).fold(0.0f64, f64::max);
            let horizon = (last_arrival / speedup + 3.0).clamp(1.0, 60.0);
            Some(
                crate::faults::FaultPlan::parse(spec, cluster.nodes.len(), horizon)
                    .map_err(|e| anyhow!(e))?,
            )
        }
    };
    if let Some(addr) = args.opt("addr") {
        let stall_timeout_s: u64 = args.opt_parse_or("timeout", 300)?;
        return replay_remote(addr, workload, &jobs, speedup, stall_timeout_s);
    }

    // Interval schedulers replay with a fast default round cadence so the
    // wall-clock run finishes promptly; override with --round-interval.
    let scheduler = scheduler_arg(args, 0.2)?;
    let defaults = CoordinatorConfig::default();
    let cfg = CoordinatorConfig {
        execute_training: false,
        stub_delay_ms: stub_ms,
        scheduler,
        // Chaos replays should requeue crash-displaced jobs promptly: the
        // production 1 s backoff floor would dominate a sped-up replay.
        crash_backoff_base_ms: args.opt_parse_or("crash-backoff-ms", 100u64)?,
        crash_backoff_cap_ms: defaults.crash_backoff_cap_ms.min(2_000),
        probation_ms: 2_000,
        fault_plan: faults,
        tenant_weights: match args.opt("tenant-weights") {
            None => Vec::new(),
            Some(s) => parse_tenant_weights(s)?,
        },
        ..defaults
    };
    if let Some(p) = &cfg.fault_plan {
        println!("fault injection armed: {} scripted events ({})", p.len(), p.spec());
    }
    let (h, _join) = crate::serverless::spawn(cluster.clone(), cfg);
    println!(
        "replaying {} jobs from '{}' through the live engine on {} ({}x speedup, {} ms stub, {} scheduler)",
        jobs.len(),
        workload,
        cluster.name,
        speedup,
        stub_ms,
        args.opt_or("sched", "has"),
    );
    let mut last_submit = 0.0f64;
    for j in &jobs {
        let gap = ((j.submit_time - last_submit) / speedup).clamp(0.0, 0.25);
        if gap > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(gap));
        }
        last_submit = j.submit_time;
        // Tenant-attributed submit: the job's tenant becomes the quota
        // principal, exactly like the `user` field on the HTTP path.
        h.try_submit_as(
            SubmitRequest {
                model: j.model.name.to_string(),
                global_batch: j.train.global_batch,
                total_samples: j.total_samples,
            },
            &j.tenant,
        )?
        .map_err(|e| anyhow!(e))?;
    }
    h.drain()?;
    let report = h.report()?;
    let decisions = h.decisions()?;
    let title = format!("live replay: {} on {} ({} jobs)", workload, cluster.name, jobs.len());
    let mut t = Table::new(&["metric", "value"]).with_title(&title);
    t.row_str(&["completed", &report.n_completed.to_string()]);
    t.row_str(&["rejected", &report.n_rejected.to_string()]);
    t.row_str(&["placements", &decisions.len().to_string()]);
    t.row_str(&["avg JCT (wall)", &fmt_duration(report.avg_jct_s)]);
    t.row_str(&["avg queue (wall)", &fmt_duration(report.avg_queue_s)]);
    t.row_str(&["OOM events", &report.n_oom_events.to_string()]);
    if report.n_node_crashes > 0 || report.total_steps_lost > 0 {
        t.row_str(&["node crashes", &report.n_node_crashes.to_string()]);
        t.row_str(&["crash requeues", &report.n_crash_requeues.to_string()]);
        t.row_str(&["quarantines", &report.n_quarantines.to_string()]);
        t.row_str(&["steps lost to crashes", &report.total_steps_lost.to_string()]);
        t.row_str(&["goodput", &format!("{:.1}%", report.goodput * 100.0)]);
    }
    t.row_str(&["sched overhead (wall)", &fmt_duration(report.sched_overhead_s)]);
    t.row_str(&["utilization", &format!("{:.1}%", report.avg_utilization * 100.0)]);
    println!("{}", t.render());
    h.shutdown();
    Ok(())
}

/// Parse a `--tenant-weights` spec (`tenant=weight,...`) into the
/// engine's weighted-fair ordering table. Unlisted tenants weigh 1.0.
fn parse_tenant_weights(s: &str) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for clause in s.split(',').map(str::trim).filter(|c| !c.is_empty()) {
        let (name, w) = clause
            .split_once('=')
            .ok_or_else(|| anyhow!("bad tenant weight '{clause}' (want tenant=weight)"))?;
        let weight: f64 = w.trim().parse().map_err(|_| anyhow!("bad tenant weight '{w}'"))?;
        if !weight.is_finite() || weight <= 0.0 {
            bail!("tenant weight must be finite and > 0, got '{w}'");
        }
        out.push((name.trim().to_string(), weight));
    }
    Ok(out)
}

/// Parse a `rate[:burst]` quota spec into token-bucket parameters. The
/// burst defaults to one second of headroom at the sustained rate (never
/// below a single token, or no submit could ever pass).
fn parse_quota(s: &str) -> Result<QuotaCfg> {
    let (r, b) = match s.split_once(':') {
        Some((r, b)) => (r, Some(b)),
        None => (s, None),
    };
    let rate_per_s: f64 = r.parse().map_err(|_| anyhow!("bad quota rate '{r}'"))?;
    if !rate_per_s.is_finite() || rate_per_s < 0.0 {
        bail!("quota rate must be finite and >= 0, got '{r}'");
    }
    let burst: f64 = match b {
        Some(b) => b.parse().map_err(|_| anyhow!("bad quota burst '{b}'"))?,
        None => rate_per_s.max(1.0),
    };
    if !burst.is_finite() || burst < 1.0 {
        bail!("quota burst must be finite and >= 1, got {burst}");
    }
    Ok(QuotaCfg { rate_per_s, burst })
}

/// `frenzy serve [--addr A] [--cluster C] [--steps N]
///              [--sched has|sia|opportunistic] [--round-interval S]
///              [--drain-ms M] [--ckpt-steps K]
///              [--data-dir D] [--fsync always|every:N|interval:S]
///              [--snapshot-every E] [--max-pending N]
///              [--global-quota R[:B]] [--user-quota R[:B]]
///              [--lease-ms L] [--faults <spec|seed:N>]`
///
/// `--max-pending` caps the scheduler's pending queue (submits past it
/// get 429 + Retry-After); `--global-quota`/`--user-quota` rate-limit
/// submits per second with `B` tokens of burst (per-user quotas key on
/// the submit body's `user` field).
///
/// `--lease-ms` arms heartbeat-lease crash detection: a node that has
/// beaten `POST /v1/cluster/heartbeat` at least once and then misses the
/// lease window is declared crashed (abrupt preemption, no drain grace).
/// `--faults` arms deterministic fault injection — the plan's events fire
/// at their scripted offsets from server boot (times in seconds).
pub fn cmd_serve(args: &Args) -> Result<()> {
    let cluster = cluster_arg(args)?;
    let addr = args.opt_or("addr", DEFAULT_ADDR);
    let steps: u64 = args.opt_parse_or("steps", 50)?;
    let scheduler = scheduler_arg(args, 30.0)?;
    let defaults = CoordinatorConfig::default();
    let data_dir = args.opt("data-dir").map(std::path::PathBuf::from);
    let fsync = match args.opt("fsync") {
        None => defaults.fsync,
        Some(s) => crate::durability::FsyncPolicy::parse(s).map_err(|e| anyhow!(e))?,
    };
    let cfg = CoordinatorConfig {
        max_real_steps: steps,
        scheduler,
        drain_grace_ms: args.opt_parse_or("drain-ms", defaults.drain_grace_ms)?,
        ckpt_every_steps: args.opt_parse_or("ckpt-steps", defaults.ckpt_every_steps)?,
        data_dir,
        fsync,
        snapshot_every: args.opt_parse_or("snapshot-every", defaults.snapshot_every)?,
        max_pending: args.opt_parse_or("max-pending", defaults.max_pending)?,
        global_quota: match args.opt("global-quota") {
            None => defaults.global_quota,
            Some(s) => Some(parse_quota(s)?),
        },
        user_quota: match args.opt("user-quota") {
            None => defaults.user_quota,
            Some(s) => Some(parse_quota(s)?),
        },
        lease_timeout_ms: args.opt_parse_or("lease-ms", defaults.lease_timeout_ms)?,
        fault_plan: match args.opt("faults") {
            None => defaults.fault_plan,
            // Server fault times are seconds from boot; give seeded plans
            // an hour-long horizon to scatter over.
            Some(s) => Some(
                crate::faults::FaultPlan::parse(s, cluster.nodes.len(), 3600.0)
                    .map_err(|e| anyhow!(e))?,
            ),
        },
        tenant_weights: match args.opt("tenant-weights") {
            None => defaults.tenant_weights,
            Some(s) => parse_tenant_weights(s)?,
        },
        ..defaults
    };
    if let Some(dir) = &cfg.data_dir {
        println!("durability: WAL + snapshots in {} (fsync {fsync})", dir.display());
    }
    if cfg.lease_timeout_ms > 0 {
        println!(
            "heartbeat leases: {} ms window (nodes that beat once and go silent are crashed)",
            cfg.lease_timeout_ms
        );
    }
    if let Some(p) = &cfg.fault_plan {
        println!("fault injection armed: {} scripted events ({})", p.len(), p.spec());
    }
    let (handle, _join) = crate::serverless::spawn(cluster, cfg);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let local = crate::serverless::server::serve(handle, addr, stop)?;
    println!("frenzy serverless API v1 listening on http://{local}");
    println!("  POST /v1/jobs            {{\"model\":\"gpt2-350m\",\"batch\":8,\"samples\":400}}");
    println!("  POST /v1/jobs:batch      {{\"jobs\":[...]}}  (up to 256; one WAL fsync)");
    println!("  GET  /v1/jobs            ?state=running&offset=0&limit=100");
    println!("  GET  /v1/jobs/<id>");
    println!("  POST /v1/jobs/<id>/cancel");
    println!("  POST /v1/predict         {{\"model\":\"gpt2-7b\",\"batch\":2}}  (dry run)");
    println!("  GET  /v1/cluster/events  ?since=0&limit=500&wait_ms=5000  (audit log; long-poll)");
    println!("  GET  /v1/cluster/events  ?stream=1  (server-sent-events push feed)");
    println!("  GET  /v1/report          (streaming run report + memory-prediction accuracy)");
    println!("  GET  /v1/durability      (WAL position + snapshot freshness)");
    println!("  POST /v1/cluster/heartbeat  {{\"node\":0}}  (lease renew; see --lease-ms)");
    println!("  GET  /v1/cluster | /v1/healthz    (see API.md; unversioned aliases served)");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}
