//! Lightweight CLI argument parser (clap is unavailable offline).
//!
//! Supports: a subcommand word, `--flag`, `--key value`, `--key=value`, and
//! positional arguments. Typed accessors parse on demand and produce
//! friendly errors.
//!
//! Disambiguation rule: `--name` followed by a token that does not start
//! with `--` is parsed as an option with that value; place bare flags after
//! positionals or use `--flag` at the end (or `--key=value` forms) when
//! mixing.

pub mod commands;

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare word, if any (the subcommand).
    pub command: Option<String>,
    /// `--key value` / `--key=value` options.
    options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    flags: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I, S>(args: I) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let raw: Vec<String> = args.into_iter().map(|s| s.into()).collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err(CliError("bare '--' not supported".into()));
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(stripped.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args, CliError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError>
    where
        T::Err: fmt::Display,
    {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| CliError(format!("--{name} '{s}': {e}"))),
        }
    }

    pub fn opt_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: fmt::Display,
    {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }

    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.opt(name).ok_or_else(|| CliError(format!("missing required option --{name}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(["simulate", "--tasks", "30", "--sched=has", "trace.csv", "--verbose"])
            .unwrap();
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.opt("tasks"), Some("30"));
        assert_eq!(a.opt("sched"), Some("has"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["trace.csv"]);
    }

    #[test]
    fn typed_parsing() {
        let a = Args::parse(["x", "--n", "42", "--rate", "1.5"]).unwrap();
        assert_eq!(a.opt_parse::<u64>("n").unwrap(), Some(42));
        assert_eq!(a.opt_parse_or::<f64>("rate", 0.0).unwrap(), 1.5);
        assert_eq!(a.opt_parse_or::<u64>("missing", 7).unwrap(), 7);
    }

    #[test]
    fn typed_parse_error_mentions_option() {
        let a = Args::parse(["x", "--n", "notanum"]).unwrap();
        let err = a.opt_parse::<u64>("n").unwrap_err();
        assert!(err.0.contains("--n"));
    }

    #[test]
    fn require_missing() {
        let a = Args::parse(["x"]).unwrap();
        assert!(a.require("model").is_err());
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(["run", "--fast"]).unwrap();
        assert!(a.flag("fast"));
        assert_eq!(a.opt("fast"), None);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(["run", "--fast", "--n", "3"]).unwrap();
        assert!(a.flag("fast"));
        assert_eq!(a.opt("n"), Some("3"));
    }
}
