//! 0/1 integer-linear-program solver (branch-and-bound).
//!
//! This is the substrate for the **Sia baseline**: Sia formulates each
//! scheduling round as a goodput-maximizing assignment ILP ("which (GPU
//! type, count) config does each job get, subject to capacity"), solved with
//! a commercial solver in the original paper. We implement the same problem
//! class from scratch:
//!
//! * one *group* per job, each with candidate items (configs);
//! * at most one item chosen per group;
//! * shared resource capacities (GPUs per type);
//! * maximize total value.
//!
//! The solver is exact branch-and-bound with a greedy admissible bound.
//! Its work (`nodes_explored`) grows superlinearly with jobs × configs —
//! which is precisely the scheduling-overhead phenomenon Fig 5a reports.

/// A candidate assignment for one group (job).
#[derive(Debug, Clone, PartialEq)]
pub struct Item {
    /// Group (job) index this item belongs to.
    pub group: usize,
    /// Objective contribution if chosen.
    pub value: f64,
    /// Resource usage per dimension; must match `Problem::capacity` length.
    pub usage: Vec<u32>,
}

/// A multi-choice knapsack / assignment problem.
#[derive(Debug, Clone)]
pub struct Problem {
    pub n_groups: usize,
    pub capacity: Vec<u32>,
    pub items: Vec<Item>,
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct Solution {
    /// Chosen item index (into `Problem::items`) per group, if any.
    pub chosen: Vec<Option<usize>>,
    /// Total objective value.
    pub value: f64,
    /// Branch-and-bound nodes explored (the overhead proxy).
    pub nodes_explored: u64,
    /// True if the node limit stopped the search early.
    pub truncated: bool,
}

impl Problem {
    /// Validate well-formedness (dimensions, group indices).
    pub fn validate(&self) -> Result<(), String> {
        for (i, it) in self.items.iter().enumerate() {
            if it.group >= self.n_groups {
                return Err(format!("item {i}: group {} out of range", it.group));
            }
            if it.usage.len() != self.capacity.len() {
                return Err(format!(
                    "item {i}: usage has {} dims, capacity has {}",
                    it.usage.len(),
                    self.capacity.len()
                ));
            }
        }
        Ok(())
    }

    /// Check a full assignment against capacities.
    pub fn feasible(&self, chosen: &[Option<usize>]) -> bool {
        let mut used = vec![0u64; self.capacity.len()];
        for (g, c) in chosen.iter().enumerate() {
            if let Some(idx) = c {
                let it = &self.items[*idx];
                if it.group != g {
                    return false;
                }
                for (dim, u) in it.usage.iter().enumerate() {
                    used[dim] += *u as u64;
                }
            }
        }
        used.iter().zip(&self.capacity).all(|(u, c)| *u <= *c as u64)
    }
}

/// Exact branch-and-bound solve. `node_limit` bounds work; on hitting it the
/// best incumbent so far is returned with `truncated = true`.
pub fn solve(p: &Problem, node_limit: u64) -> Solution {
    debug_assert!(p.validate().is_ok());
    // Group the items: per group, indices sorted by value descending so the
    // bound is tight and good solutions are found early.
    let mut by_group: Vec<Vec<usize>> = vec![Vec::new(); p.n_groups];
    for (i, it) in p.items.iter().enumerate() {
        by_group[it.group].push(i);
    }
    for g in &mut by_group {
        g.sort_by(|a, b| p.items[*b].value.partial_cmp(&p.items[*a].value).unwrap());
    }
    // Order groups by their best value descending (decide valuable jobs
    // first — standard B&B ordering heuristic).
    let mut order: Vec<usize> = (0..p.n_groups).collect();
    order.sort_by(|a, b| {
        let va = by_group[*a].first().map(|i| p.items[*i].value).unwrap_or(0.0);
        let vb = by_group[*b].first().map(|i| p.items[*i].value).unwrap_or(0.0);
        vb.partial_cmp(&va).unwrap()
    });
    // Suffix bound: best possible value from groups order[k..] ignoring
    // capacity (admissible upper bound).
    let mut suffix_best = vec![0.0f64; p.n_groups + 1];
    for k in (0..p.n_groups).rev() {
        let g = order[k];
        let best = by_group[g].first().map(|i| p.items[*i].value.max(0.0)).unwrap_or(0.0);
        suffix_best[k] = suffix_best[k + 1] + best;
    }

    struct Ctx<'a> {
        p: &'a Problem,
        by_group: &'a [Vec<usize>],
        order: &'a [usize],
        suffix_best: &'a [f64],
        best_value: f64,
        best_chosen: Vec<Option<usize>>,
        nodes: u64,
        node_limit: u64,
        truncated: bool,
    }

    fn dfs(ctx: &mut Ctx, k: usize, used: &mut [u32], chosen: &mut Vec<Option<usize>>, value: f64) {
        ctx.nodes += 1;
        if ctx.nodes >= ctx.node_limit {
            ctx.truncated = true;
            return;
        }
        if k == ctx.order.len() {
            if value > ctx.best_value {
                ctx.best_value = value;
                ctx.best_chosen = chosen.clone();
            }
            return;
        }
        // Bound: even taking the best remaining items can't beat incumbent.
        if value + ctx.suffix_best[k] <= ctx.best_value {
            return;
        }
        let g = ctx.order[k];
        // Try each candidate item (ordered by value desc), then "skip".
        for &idx in &ctx.by_group[g] {
            if ctx.truncated {
                return;
            }
            let it = &ctx.p.items[idx];
            let fits = it
                .usage
                .iter()
                .zip(ctx.p.capacity.iter())
                .enumerate()
                .all(|(dim, (u, cap))| used[dim] + u <= *cap);
            if fits {
                for (dim, u) in it.usage.iter().enumerate() {
                    used[dim] += u;
                }
                chosen[g] = Some(idx);
                dfs(ctx, k + 1, used, chosen, value + it.value);
                chosen[g] = None;
                for (dim, u) in it.usage.iter().enumerate() {
                    used[dim] -= u;
                }
            }
        }
        if ctx.truncated {
            return;
        }
        // Skip this group.
        dfs(ctx, k + 1, used, chosen, value);
    }

    let mut ctx = Ctx {
        p,
        by_group: &by_group,
        order: &order,
        suffix_best: &suffix_best,
        best_value: f64::NEG_INFINITY,
        best_chosen: vec![None; p.n_groups],
        nodes: 0,
        node_limit: node_limit.max(1),
        truncated: false,
    };
    let mut used = vec![0u32; p.capacity.len()];
    let mut chosen = vec![None; p.n_groups];
    dfs(&mut ctx, 0, &mut used, &mut chosen, 0.0);

    let value = if ctx.best_value.is_finite() { ctx.best_value } else { 0.0 };
    Solution {
        chosen: ctx.best_chosen,
        value,
        nodes_explored: ctx.nodes,
        truncated: ctx.truncated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(group: usize, value: f64, usage: Vec<u32>) -> Item {
        Item { group, value, usage }
    }

    #[test]
    fn picks_best_single_group() {
        let p = Problem {
            n_groups: 1,
            capacity: vec![4],
            items: vec![item(0, 1.0, vec![1]), item(0, 3.0, vec![2]), item(0, 10.0, vec![8])],
        };
        let s = solve(&p, 1_000_000);
        // value-10 item doesn't fit; value-3 wins.
        assert_eq!(s.value, 3.0);
        assert!(p.feasible(&s.chosen));
        assert!(!s.truncated);
    }

    #[test]
    fn respects_capacity_across_groups() {
        // Two jobs each want 3 GPUs of a 4-GPU pool; only one can have 3,
        // other takes 1.
        let p = Problem {
            n_groups: 2,
            capacity: vec![4],
            items: vec![
                item(0, 5.0, vec![3]),
                item(0, 2.0, vec![1]),
                item(1, 5.0, vec![3]),
                item(1, 2.0, vec![1]),
            ],
        };
        let s = solve(&p, 1_000_000);
        assert_eq!(s.value, 7.0);
        assert!(p.feasible(&s.chosen));
        assert_eq!(s.chosen.iter().flatten().count(), 2);
    }

    #[test]
    fn multi_dimensional_capacity() {
        // dim0: A100 pool = 2, dim1: 2080Ti pool = 8.
        let p = Problem {
            n_groups: 2,
            capacity: vec![2, 8],
            items: vec![
                item(0, 10.0, vec![2, 0]),
                item(0, 6.0, vec![0, 4]),
                item(1, 9.0, vec![2, 0]),
                item(1, 5.0, vec![0, 4]),
            ],
        };
        let s = solve(&p, 1_000_000);
        // Best: group0 takes A100s (10), group1 takes 2080Tis (5) = 15.
        assert_eq!(s.value, 15.0);
        assert!(p.feasible(&s.chosen));
    }

    #[test]
    fn skip_when_nothing_fits() {
        let p = Problem {
            n_groups: 1,
            capacity: vec![1],
            items: vec![item(0, 100.0, vec![5])],
        };
        let s = solve(&p, 1_000);
        assert_eq!(s.value, 0.0);
        assert_eq!(s.chosen, vec![None]);
    }

    #[test]
    fn node_limit_truncates_but_stays_feasible() {
        // Big random-ish instance; tiny node budget.
        let mut items = Vec::new();
        for g in 0..12 {
            for c in 1..=4u32 {
                items.push(item(g, (g as f64 + 1.0) * c as f64, vec![c]));
            }
        }
        let p = Problem { n_groups: 12, capacity: vec![10], items };
        let s = solve(&p, 50);
        assert!(s.truncated);
        assert!(p.feasible(&s.chosen));
    }

    #[test]
    fn exactness_vs_bruteforce_small() {
        // Exhaustive check on a small instance.
        let p = Problem {
            n_groups: 3,
            capacity: vec![5, 3],
            items: vec![
                item(0, 4.0, vec![2, 1]),
                item(0, 3.0, vec![1, 0]),
                item(1, 5.0, vec![3, 1]),
                item(1, 2.0, vec![1, 1]),
                item(2, 6.0, vec![2, 2]),
                item(2, 1.0, vec![0, 1]),
            ],
        };
        // brute force over item-or-none per group
        let mut best = 0.0f64;
        let opts: Vec<Vec<Option<usize>>> = (0..3)
            .map(|g| {
                let mut v: Vec<Option<usize>> = p
                    .items
                    .iter()
                    .enumerate()
                    .filter(|(_, it)| it.group == g)
                    .map(|(i, _)| Some(i))
                    .collect();
                v.push(None);
                v
            })
            .collect();
        for a in &opts[0] {
            for b in &opts[1] {
                for c in &opts[2] {
                    let chosen = vec![*a, *b, *c];
                    if p.feasible(&chosen) {
                        let v: f64 =
                            chosen.iter().flatten().map(|i| p.items[*i].value).sum();
                        best = best.max(v);
                    }
                }
            }
        }
        let s = solve(&p, 1_000_000);
        assert!((s.value - best).abs() < 1e-9, "bb={} brute={}", s.value, best);
    }

    #[test]
    fn nodes_grow_with_problem_size() {
        let build = |n_groups: usize| {
            let mut items = Vec::new();
            for g in 0..n_groups {
                for c in 1..=4u32 {
                    // near-uniform values make pruning hard (worst case)
                    items.push(item(g, 1.0 + (c as f64) * 0.01 + (g as f64) * 0.001, vec![c]));
                }
            }
            Problem { n_groups, capacity: vec![(n_groups * 2) as u32], items }
        };
        let small = solve(&build(6), u64::MAX >> 1).nodes_explored;
        let large = solve(&build(12), u64::MAX >> 1).nodes_explored;
        assert!(large > 4 * small, "small={small} large={large}");
    }

    #[test]
    fn validate_catches_bad_dims() {
        let p = Problem {
            n_groups: 1,
            capacity: vec![1, 2],
            items: vec![item(0, 1.0, vec![1])],
        };
        assert!(p.validate().is_err());
        let p2 = Problem { n_groups: 1, capacity: vec![1], items: vec![item(3, 1.0, vec![1])] };
        assert!(p2.validate().is_err());
    }
}
