//! Cluster topology files: a tiny line-oriented format so users can describe
//! their own heterogeneous cluster without recompiling.
//!
//! ```text
//! # comment
//! cluster my-lab
//! inter_node_gbps 12.5
//! node A100-40G x2 pcie
//! node A800-80G x4 nvlink
//! ```

use super::{gpu_by_name, ClusterSpec, LinkKind, NodeSpec};
use anyhow::{anyhow, bail, Context, Result};

/// Parse a cluster description from text.
pub fn parse_cluster(text: &str) -> Result<ClusterSpec> {
    let mut name = String::from("custom");
    let mut inter = 12.5f64;
    let mut nodes = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let key = parts.next().unwrap();
        let ctx = || format!("cluster file line {}", lineno + 1);
        match key {
            "cluster" => {
                name = parts.next().ok_or_else(|| anyhow!("{}: missing name", ctx()))?.to_string();
            }
            "inter_node_gbps" => {
                inter = parts
                    .next()
                    .ok_or_else(|| anyhow!("{}: missing value", ctx()))?
                    .parse()
                    .with_context(ctx)?;
            }
            "node" => {
                let gpu_name = parts.next().ok_or_else(|| anyhow!("{}: missing gpu", ctx()))?;
                let count_s = parts.next().ok_or_else(|| anyhow!("{}: missing count", ctx()))?;
                let link_s = parts.next().unwrap_or("pcie");
                let gpu = gpu_by_name(gpu_name)
                    .ok_or_else(|| anyhow!("{}: unknown GPU '{gpu_name}'", ctx()))?;
                let count: u32 = count_s
                    .strip_prefix('x')
                    .unwrap_or(count_s)
                    .parse()
                    .with_context(ctx)?;
                if count == 0 {
                    bail!("{}: node must have at least one GPU", ctx());
                }
                let link = match link_s.to_ascii_lowercase().as_str() {
                    "nvlink" => LinkKind::NvLink,
                    "pcie" => LinkKind::Pcie,
                    other => bail!("{}: unknown link '{other}'", ctx()),
                };
                nodes.push(NodeSpec { gpu, count, link });
            }
            other => bail!("{}: unknown directive '{other}'", ctx()),
        }
    }
    if nodes.is_empty() {
        bail!("cluster file declares no nodes");
    }
    Ok(ClusterSpec { name, nodes, inter_node_gbps: inter })
}

/// Load a cluster description from a file path.
pub fn load_cluster(path: &str) -> Result<ClusterSpec> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading cluster file {path}"))?;
    parse_cluster(&text)
}

/// Render a ClusterSpec back to the file format (round-trip support).
pub fn render_cluster(c: &ClusterSpec) -> String {
    let mut out = format!("cluster {}\ninter_node_gbps {}\n", c.name, c.inter_node_gbps);
    for n in &c.nodes {
        let link = match n.link {
            LinkKind::NvLink => "nvlink",
            LinkKind::Pcie => "pcie",
        };
        out.push_str(&format!("node {} x{} {}\n", n.gpu.name, n.count, link));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GIB;

    #[test]
    fn parse_basic() {
        let c = parse_cluster(
            "# lab cluster\ncluster lab\ninter_node_gbps 25\nnode A100-40G x2 pcie\nnode A800-80G x4 nvlink\n",
        )
        .unwrap();
        assert_eq!(c.name, "lab");
        assert_eq!(c.inter_node_gbps, 25.0);
        assert_eq!(c.nodes.len(), 2);
        assert_eq!(c.nodes[1].count, 4);
        assert_eq!(c.nodes[1].link, LinkKind::NvLink);
        assert_eq!(c.nodes[0].gpu.mem_bytes, 40 * GIB);
    }

    #[test]
    fn roundtrip() {
        let c = crate::config::real_testbed();
        let text = render_cluster(&c);
        let back = parse_cluster(&text).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn errors() {
        assert!(parse_cluster("").is_err());
        assert!(parse_cluster("node H900 x2 pcie").is_err());
        assert!(parse_cluster("node A100-40G x0 pcie").is_err());
        assert!(parse_cluster("node A100-40G x2 warpdrive").is_err());
        assert!(parse_cluster("bogus directive").is_err());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let c = parse_cluster("\n# hi\nnode A100-40G x1 pcie # tail comment\n").unwrap();
        assert_eq!(c.nodes.len(), 1);
    }
}
