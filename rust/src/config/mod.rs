//! Static configuration: GPU catalog, LLM model zoo, cluster topologies.
//!
//! The scheduler consumes *descriptors* (memory capacity, peak compute,
//! link technology) rather than real devices, which is exactly the
//! information the paper's HAS/MARP use. Both evaluation topologies from
//! §V.A are encoded here:
//! * `real_testbed()` — 5 nodes, 3 GPU types (2×A100-40 PCIe head, 1×A100-40,
//!   4×A800-80 NVLink, 2 × 2×A100-80 PCIe).
//! * `sia_sim()` — the Sia-paper topology used with the PAI simulator
//!   (3 × 8×2080Ti, 2 × 8×A100-40, 1 × 4×RTX6000).

pub mod cluster_file;
pub mod models;

pub use models::{model_zoo, ModelConfig};

pub const GIB: u64 = 1024 * 1024 * 1024;

/// Inter-GPU link within a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// NVLink-class: high bandwidth, low latency.
    NvLink,
    /// PCIe-attached GPUs.
    Pcie,
}

impl LinkKind {
    /// Effective intra-node collective bandwidth (GB/s per GPU pair),
    /// used by the performance model.
    pub fn bandwidth_gbps(self) -> f64 {
        match self {
            LinkKind::NvLink => 300.0, // NVLink3-class aggregate
            LinkKind::Pcie => 24.0,    // PCIe 4.0 x16 effective
        }
    }
}

/// A GPU model descriptor.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Human name, e.g. "A100-40G".
    pub name: &'static str,
    /// Device memory in bytes.
    pub mem_bytes: u64,
    /// Peak dense half/bf16 throughput in TFLOPs (tensor-core class).
    pub peak_tflops: f64,
}

/// The GPU catalog covering every type in the paper's two testbeds.
pub fn gpu_catalog() -> Vec<GpuSpec> {
    vec![
        GpuSpec { name: "A100-40G", mem_bytes: 40 * GIB, peak_tflops: 312.0 },
        GpuSpec { name: "A100-80G", mem_bytes: 80 * GIB, peak_tflops: 312.0 },
        GpuSpec { name: "A800-80G", mem_bytes: 80 * GIB, peak_tflops: 312.0 },
        GpuSpec { name: "RTX2080Ti", mem_bytes: 11 * GIB, peak_tflops: 108.0 },
        GpuSpec { name: "RTX6000", mem_bytes: 24 * GIB, peak_tflops: 130.0 },
        GpuSpec { name: "RTX3090", mem_bytes: 24 * GIB, peak_tflops: 142.0 },
        GpuSpec { name: "V100-32G", mem_bytes: 32 * GIB, peak_tflops: 125.0 },
    ]
}

/// Look up a GPU by name in the catalog.
pub fn gpu_by_name(name: &str) -> Option<GpuSpec> {
    gpu_catalog().into_iter().find(|g| g.name == name)
}

/// A node: `count` identical GPUs joined by `link`.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub gpu: GpuSpec,
    pub count: u32,
    pub link: LinkKind,
}

/// A whole cluster topology.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub name: String,
    pub nodes: Vec<NodeSpec>,
    /// Cross-node network bandwidth (GB/s), e.g. 100 Gb Ethernet ≈ 12 GB/s.
    pub inter_node_gbps: f64,
}

impl ClusterSpec {
    pub fn total_gpus(&self) -> u32 {
        self.nodes.iter().map(|n| n.count).sum()
    }

    /// Distinct GPU memory sizes present, descending.
    pub fn gpu_sizes_desc(&self) -> Vec<u64> {
        let mut sizes: Vec<u64> = self.nodes.iter().map(|n| n.gpu.mem_bytes).collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        sizes.dedup();
        sizes
    }

    /// Largest GPU memory in the cluster.
    pub fn max_gpu_mem(&self) -> u64 {
        self.nodes.iter().map(|n| n.gpu.mem_bytes).max().unwrap_or(0)
    }

    /// Max GPUs on any single node (bounds sensible tensor-parallel width).
    pub fn max_gpus_per_node(&self) -> u32 {
        self.nodes.iter().map(|n| n.count).max().unwrap_or(0)
    }
}

/// §V.A real testbed: 5 nodes, 3 GPU types, 11 GPUs total.
pub fn real_testbed() -> ClusterSpec {
    let a100_40 = gpu_by_name("A100-40G").unwrap();
    let a100_80 = gpu_by_name("A100-80G").unwrap();
    let a800_80 = gpu_by_name("A800-80G").unwrap();
    ClusterSpec {
        name: "real-testbed".into(),
        nodes: vec![
            // head node: 2 x A100 40G, PCIe
            NodeSpec { gpu: a100_40.clone(), count: 2, link: LinkKind::Pcie },
            // 1 x A100 40G
            NodeSpec { gpu: a100_40, count: 1, link: LinkKind::Pcie },
            // 4 x A800 80G, NVLink
            NodeSpec { gpu: a800_80, count: 4, link: LinkKind::NvLink },
            // 2 nodes with 2 x A100 80G, PCIe
            NodeSpec { gpu: a100_80.clone(), count: 2, link: LinkKind::Pcie },
            NodeSpec { gpu: a100_80, count: 2, link: LinkKind::Pcie },
        ],
        inter_node_gbps: 12.5,
    }
}

/// §V.A simulator topology (same as Sia): 3 × 8×2080Ti, 2 × 8×A100-40,
/// 1 × 4×RTX6000 — 44 GPUs total.
pub fn sia_sim() -> ClusterSpec {
    let t2080 = gpu_by_name("RTX2080Ti").unwrap();
    let a100_40 = gpu_by_name("A100-40G").unwrap();
    let rtx6000 = gpu_by_name("RTX6000").unwrap();
    ClusterSpec {
        name: "sia-sim".into(),
        nodes: vec![
            NodeSpec { gpu: t2080.clone(), count: 8, link: LinkKind::Pcie },
            NodeSpec { gpu: t2080.clone(), count: 8, link: LinkKind::Pcie },
            NodeSpec { gpu: t2080, count: 8, link: LinkKind::Pcie },
            NodeSpec { gpu: a100_40.clone(), count: 8, link: LinkKind::NvLink },
            NodeSpec { gpu: a100_40, count: 8, link: LinkKind::NvLink },
            NodeSpec { gpu: rtx6000, count: 4, link: LinkKind::Pcie },
        ],
        inter_node_gbps: 12.5,
    }
}

/// Synthetic heterogeneous topology for scalability benchmarks
/// (`benches/bench_sched.rs` and the index property tests): `n_nodes`
/// nodes cycling through three GPU classes — 8×A800-80G NVLink,
/// 4×A100-40G PCIe, 4×RTX6000 PCIe — so three size classes (80/40/24 GB)
/// are present at every scale.
pub fn synthetic_cluster(n_nodes: usize) -> ClusterSpec {
    let a800 = gpu_by_name("A800-80G").unwrap();
    let a100_40 = gpu_by_name("A100-40G").unwrap();
    let rtx6000 = gpu_by_name("RTX6000").unwrap();
    let nodes = (0..n_nodes)
        .map(|i| match i % 3 {
            0 => NodeSpec { gpu: a800.clone(), count: 8, link: LinkKind::NvLink },
            1 => NodeSpec { gpu: a100_40.clone(), count: 4, link: LinkKind::Pcie },
            _ => NodeSpec { gpu: rtx6000.clone(), count: 4, link: LinkKind::Pcie },
        })
        .collect();
    ClusterSpec { name: format!("synthetic-{n_nodes}"), nodes, inter_node_gbps: 12.5 }
}

/// Resolve a topology by name (CLI `--cluster`).
pub fn cluster_by_name(name: &str) -> Option<ClusterSpec> {
    match name {
        "real-testbed" | "real" => Some(real_testbed()),
        "sia-sim" | "sim" => Some(sia_sim()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_covers_testbeds() {
        for n in ["A100-40G", "A100-80G", "A800-80G", "RTX2080Ti", "RTX6000"] {
            assert!(gpu_by_name(n).is_some(), "{n} missing");
        }
        assert!(gpu_by_name("H100").is_none());
    }

    #[test]
    fn real_testbed_matches_paper() {
        let c = real_testbed();
        assert_eq!(c.nodes.len(), 5);
        assert_eq!(c.total_gpus(), 11);
        // three distinct GPU *types* but two distinct memory sizes (40, 80)
        assert_eq!(c.gpu_sizes_desc(), vec![80 * GIB, 40 * GIB]);
        assert_eq!(c.max_gpus_per_node(), 4);
    }

    #[test]
    fn sia_sim_matches_sia_paper() {
        let c = sia_sim();
        assert_eq!(c.nodes.len(), 6);
        assert_eq!(c.total_gpus(), 44);
        assert_eq!(c.max_gpu_mem(), 40 * GIB);
    }

    #[test]
    fn link_bandwidths_ordered() {
        assert!(LinkKind::NvLink.bandwidth_gbps() > LinkKind::Pcie.bandwidth_gbps());
    }

    #[test]
    fn synthetic_cluster_scales_with_three_size_classes() {
        let c = synthetic_cluster(9);
        assert_eq!(c.nodes.len(), 9);
        assert_eq!(c.gpu_sizes_desc(), vec![80 * GIB, 40 * GIB, 24 * GIB]);
        assert_eq!(c.total_gpus(), 3 * (8 + 4 + 4));
        let big = synthetic_cluster(10_000);
        assert_eq!(big.nodes.len(), 10_000);
    }

    #[test]
    fn cluster_lookup() {
        assert!(cluster_by_name("real").is_some());
        assert!(cluster_by_name("sia-sim").is_some());
        assert!(cluster_by_name("nope").is_none());
    }
}
