//! LLM model zoo: the decoder-only (GPT-2 family) and encoder (BERT family)
//! configurations used by the paper's workloads (§V.A "NewWorkload" consists
//! of GPT-2 and BERT models of different sizes; §V.C validates memory
//! prediction on GPT2-350M and GPT2-7B).

/// Transformer hyper-parameters (the MARP inputs).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    /// Vocabulary size V.
    pub vocab: u64,
    /// Hidden size h.
    pub hidden: u64,
    /// Number of transformer layers l.
    pub layers: u64,
    /// Number of attention heads a.
    pub heads: u64,
    /// Sequence length s.
    pub seq_len: u64,
}

impl ModelConfig {
    /// Parameter count via the paper's profiling formula:
    /// `W = V·h + l·(12h² + 13h)`.
    pub fn param_count(&self) -> u64 {
        self.vocab * self.hidden
            + self.layers * (12 * self.hidden * self.hidden + 13 * self.hidden)
    }

    /// Approximate training FLOPs per sample (fwd+bwd ≈ 6·W per token).
    pub fn flops_per_sample(&self) -> f64 {
        6.0 * self.param_count() as f64 * self.seq_len as f64
    }
}

const GPT2_VOCAB: u64 = 50257;
const BERT_VOCAB: u64 = 30522;

/// All models available to the workload generators.
pub fn model_zoo() -> Vec<ModelConfig> {
    vec![
        // --- GPT-2 / GPT-3 style decoder models ---
        ModelConfig { name: "gpt2-125m", vocab: GPT2_VOCAB, hidden: 768, layers: 12, heads: 12, seq_len: 1024 },
        ModelConfig { name: "gpt2-350m", vocab: GPT2_VOCAB, hidden: 1024, layers: 24, heads: 16, seq_len: 1024 },
        ModelConfig { name: "gpt2-760m", vocab: GPT2_VOCAB, hidden: 1536, layers: 24, heads: 16, seq_len: 1024 },
        ModelConfig { name: "gpt2-1.3b", vocab: GPT2_VOCAB, hidden: 2048, layers: 24, heads: 16, seq_len: 1024 },
        ModelConfig { name: "gpt2-2.7b", vocab: GPT2_VOCAB, hidden: 2560, layers: 32, heads: 32, seq_len: 1024 },
        ModelConfig { name: "gpt2-7b", vocab: GPT2_VOCAB, hidden: 4096, layers: 32, heads: 32, seq_len: 1024 },
        // --- BERT family (encoder; MARP treats it with the same forms,
        // which is how the paper's NewWorkload uses it) ---
        ModelConfig { name: "bert-base", vocab: BERT_VOCAB, hidden: 768, layers: 12, heads: 12, seq_len: 512 },
        ModelConfig { name: "bert-large", vocab: BERT_VOCAB, hidden: 1024, layers: 24, heads: 16, seq_len: 512 },
        // --- tiny configs for the end-to-end CPU training example ---
        ModelConfig { name: "gpt2-tiny", vocab: 1024, hidden: 128, layers: 4, heads: 4, seq_len: 128 },
        ModelConfig { name: "gpt2-mini", vocab: 4096, hidden: 256, layers: 6, heads: 8, seq_len: 256 },
    ]
}

/// Look up a model by name.
pub fn model_by_name(name: &str) -> Option<ModelConfig> {
    model_zoo().into_iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_in_expected_ballpark() {
        // The names should describe the W formula's output within ~15%.
        let close = |name: &str, expect: f64| {
            let w = model_by_name(name).unwrap().param_count() as f64;
            let ratio = w / expect;
            assert!((0.8..1.25).contains(&ratio), "{name}: W={w:.3e} expect~{expect:.3e}");
        };
        close("gpt2-125m", 125e6);
        close("gpt2-350m", 350e6);
        close("gpt2-1.3b", 1.3e9);
        close("gpt2-7b", 6.7e9); // "7B" class == GPT-3 6.7B shape
        close("bert-base", 110e6);
        close("bert-large", 340e6);
    }

    #[test]
    fn formula_matches_manual_expansion() {
        let m = model_by_name("gpt2-350m").unwrap();
        let manual = m.vocab * m.hidden + m.layers * (12 * m.hidden * m.hidden + 13 * m.hidden);
        assert_eq!(m.param_count(), manual);
    }

    #[test]
    fn flops_scale_with_size() {
        let small = model_by_name("gpt2-125m").unwrap().flops_per_sample();
        let big = model_by_name("gpt2-7b").unwrap().flops_per_sample();
        assert!(big > 30.0 * small);
    }

    #[test]
    fn zoo_names_unique() {
        let zoo = model_zoo();
        let mut names: Vec<_> = zoo.iter().map(|m| m.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), zoo.len());
    }

    #[test]
    fn heads_divide_hidden() {
        for m in model_zoo() {
            assert_eq!(m.hidden % m.heads, 0, "{}: heads must divide hidden", m.name);
        }
    }
}
