//! Deterministic fault injection — the chaos harness.
//!
//! A [`FaultPlan`] is a time-ordered list of failure
//! [`ClusterEvent`]s compiled from either an explicit spec string or a
//! seed. Injection goes through the **normal event path** — the plan's
//! events are scheduled on the virtual clock in simulation
//! (`frenzy replay --faults <spec>`) or fed to the coordinator's mailbox
//! on the live path (`frenzy serve --faults <spec>`), so every injected
//! failure is journaled by the WAL, replayed by recovery, and visible in
//! the event log exactly like an organic one. Any trace becomes a chaos
//! experiment.
//!
//! # Spec grammar
//!
//! Either `seed:<u64>` (a pseudo-random plan over the cluster and
//! horizon, reproducible from the seed alone) or a comma-separated list
//! of explicit clauses:
//!
//! | clause | meaning |
//! |---|---|
//! | `crash:<node>@<t>` | abrupt node crash at `t` seconds |
//! | `blackout:<node>@<t>+<dur>` | heartbeats go dark at `t`; the node is declared dead when the `dur`-second silence ends (one `NodeCrash` at `t+dur`) |
//! | `straggler:<node>@<t>x<factor>+<dur>` | placements touching `node` run at `factor`× modeled throughput from `t` to `t+dur` |
//! | `ckptfail:<node>@<t>+<dur>` | checkpoint writes on `node` fail in `[t, t+dur)`; drains and crashes inside the window fall back to the last checkpoint actually written |
//!
//! Example: `crash:2@300,straggler:0@100x0.5+200,ckptfail:1@50+400`.
//!
//! Times are in seconds of sim/run time; factors are in `(0, 1)`. The
//! compiled plan is sorted by injection time with the spec's clause order
//! as a stable tie-break, so a plan is a pure function of its spec.

use crate::cluster::NodeId;
use crate::engine::ClusterEvent;
use crate::util::prng::Xoshiro256pp;

/// A compiled, time-ordered fault schedule. See the module docs for the
/// spec grammar and injection semantics.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    spec: String,
    events: Vec<(f64, ClusterEvent)>,
}

impl FaultPlan {
    /// Compile `spec` against a cluster of `n_nodes` nodes and a run
    /// horizon of `horizon_s` seconds (used to spread the seeded plan;
    /// explicit clauses may name any time). Errors name the offending
    /// clause.
    pub fn parse(spec: &str, n_nodes: usize, horizon_s: f64) -> Result<FaultPlan, String> {
        if n_nodes == 0 {
            return Err("fault plan needs a non-empty cluster".into());
        }
        let spec = spec.trim();
        if spec.is_empty() {
            return Err("empty fault spec".into());
        }
        let events = if let Some(seed) = spec.strip_prefix("seed:") {
            let seed: u64 =
                seed.parse().map_err(|_| format!("bad seed '{seed}' (want a u64)"))?;
            seeded_plan(seed, n_nodes, horizon_s)
        } else {
            let mut ev = Vec::new();
            for clause in spec.split(',') {
                parse_clause(clause.trim(), n_nodes, &mut ev)?;
            }
            ev
        };
        let mut events = events;
        // Stable: equal-time clauses keep their spec order.
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite fault times"));
        Ok(FaultPlan { spec: spec.to_string(), events })
    }

    /// The spec string this plan was compiled from.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// The compiled `(inject_at_s, event)` schedule, time-ordered.
    pub fn events(&self) -> &[(f64, ClusterEvent)] {
        &self.events
    }

    /// Consume the plan, yielding the time-ordered schedule.
    pub fn into_events(self) -> Vec<(f64, ClusterEvent)> {
        self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

fn parse_clause(
    clause: &str,
    n_nodes: usize,
    out: &mut Vec<(f64, ClusterEvent)>,
) -> Result<(), String> {
    let (kind, rest) = clause
        .split_once(':')
        .ok_or_else(|| format!("bad clause '{clause}' (want kind:node@time...)"))?;
    let (node, timing) = rest
        .split_once('@')
        .ok_or_else(|| format!("bad clause '{clause}' (missing '@time')"))?;
    let node: NodeId =
        node.parse().map_err(|_| format!("bad node '{node}' in '{clause}'"))?;
    if node >= n_nodes {
        return Err(format!("node {node} out of range (cluster has {n_nodes} nodes)"));
    }
    match kind {
        "crash" => {
            let t = parse_time(timing, clause)?;
            out.push((t, ClusterEvent::NodeCrash(node)));
        }
        "blackout" => {
            let (t, dur) = parse_time_dur(timing, clause)?;
            // The node goes dark at `t`; the failure detector can only
            // declare it dead once the silence has outlived the lease —
            // modeled as one crash when the blackout ends.
            out.push((t + dur, ClusterEvent::NodeCrash(node)));
        }
        "straggler" => {
            let (head, dur) = timing
                .split_once('+')
                .ok_or_else(|| format!("bad straggler '{clause}' (want @t x f +dur)"))?;
            let (t, factor) = head
                .split_once('x')
                .ok_or_else(|| format!("bad straggler '{clause}' (missing 'x<factor>')"))?;
            let t = parse_time(t, clause)?;
            let dur = parse_time(dur, clause)?;
            let factor: f64 =
                factor.parse().map_err(|_| format!("bad factor in '{clause}'"))?;
            if !(factor > 0.0 && factor < 1.0) {
                return Err(format!("factor must be in (0, 1) in '{clause}'"));
            }
            out.push((t, ClusterEvent::Slowdown { node, factor }));
            out.push((t + dur, ClusterEvent::Slowdown { node, factor: 1.0 }));
        }
        "ckptfail" => {
            let (t, dur) = parse_time_dur(timing, clause)?;
            out.push((t, ClusterEvent::CkptFail { node, until_s: t + dur }));
        }
        other => return Err(format!("unknown fault kind '{other}' in '{clause}'")),
    }
    Ok(())
}

fn parse_time(s: &str, clause: &str) -> Result<f64, String> {
    let t: f64 = s.trim().parse().map_err(|_| format!("bad time '{s}' in '{clause}'"))?;
    if !t.is_finite() || t < 0.0 {
        return Err(format!("time must be finite and >= 0 in '{clause}'"));
    }
    Ok(t)
}

fn parse_time_dur(timing: &str, clause: &str) -> Result<(f64, f64), String> {
    let (t, dur) = timing
        .split_once('+')
        .ok_or_else(|| format!("bad clause '{clause}' (want @<t>+<dur>)"))?;
    let t = parse_time(t, clause)?;
    let dur = parse_time(dur, clause)?;
    if dur <= 0.0 {
        return Err(format!("duration must be > 0 in '{clause}'"));
    }
    Ok((t, dur))
}

/// Pseudo-random chaos over the run: a handful of crashes (including one
/// detected via a heartbeat blackout), one or two straggler windows, and
/// a checkpoint-failure window, all inside the horizon. Purely a
/// function of `(seed, n_nodes, horizon_s)`.
fn seeded_plan(seed: u64, n_nodes: usize, horizon_s: f64) -> Vec<(f64, ClusterEvent)> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let span = if horizon_s.is_finite() && horizon_s > 0.0 { horizon_s } else { 3600.0 };
    let mut ev = Vec::new();
    let node = |rng: &mut Xoshiro256pp| rng.next_below(n_nodes as u64) as NodeId;
    let crashes = 2 + rng.next_below(3); // 2..=4 direct crashes
    for _ in 0..crashes {
        let n = node(&mut rng);
        ev.push((rng.uniform(0.05, 0.85) * span, ClusterEvent::NodeCrash(n)));
    }
    // One blackout-detected crash: dark for 2% of the span before the
    // detector fires.
    let n = node(&mut rng);
    let dark_at = rng.uniform(0.10, 0.80) * span;
    ev.push((dark_at + 0.02 * span, ClusterEvent::NodeCrash(n)));
    for _ in 0..(1 + rng.next_below(2)) {
        let n = node(&mut rng);
        let t = rng.uniform(0.05, 0.70) * span;
        let factor = rng.uniform(0.2, 0.8);
        let dur = rng.uniform(0.05, 0.20) * span;
        ev.push((t, ClusterEvent::Slowdown { node: n, factor }));
        ev.push((t + dur, ClusterEvent::Slowdown { node: n, factor: 1.0 }));
    }
    let n = node(&mut rng);
    let t = rng.uniform(0.10, 0.70) * span;
    let dur = rng.uniform(0.05, 0.15) * span;
    ev.push((t, ClusterEvent::CkptFail { node: n, until_s: t + dur }));
    ev
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_spec_compiles_in_time_order() {
        let plan = FaultPlan::parse(
            "crash:2@300, straggler:0@100x0.5+200, ckptfail:1@50+400, blackout:3@10+40",
            5,
            1000.0,
        )
        .unwrap();
        let times: Vec<f64> = plan.events().iter().map(|&(t, _)| t).collect();
        let mut sorted = times.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(times, sorted, "plan is time-ordered");
        assert_eq!(plan.len(), 5, "straggler contributes onset + clear");
        // The blackout compiles to a crash at dark-time + duration.
        assert!(plan
            .events()
            .iter()
            .any(|(t, e)| *t == 50.0 && matches!(e, ClusterEvent::NodeCrash(3))));
        // The straggler clears back to factor 1 at t + dur.
        assert!(plan.events().iter().any(|(t, e)| *t == 300.0
            && matches!(e, ClusterEvent::Slowdown { node: 0, factor } if *factor == 1.0)));
        // ckptfail carries its window end.
        assert!(plan.events().iter().any(|(t, e)| *t == 50.0
            && matches!(e, ClusterEvent::CkptFail { node: 1, until_s } if *until_s == 450.0)));
    }

    #[test]
    fn seeded_plan_is_deterministic_and_bounded() {
        let a = FaultPlan::parse("seed:42", 5, 1000.0).unwrap();
        let b = FaultPlan::parse("seed:42", 5, 1000.0).unwrap();
        let c = FaultPlan::parse("seed:43", 5, 1000.0).unwrap();
        let dump = |p: &FaultPlan| format!("{:?}", p.events());
        assert_eq!(dump(&a), dump(&b), "same seed, same plan");
        assert_ne!(dump(&a), dump(&c), "different seed, different plan");
        assert!(!a.is_empty());
        assert!(a.events().iter().all(|&(t, _)| t >= 0.0 && t <= 1020.0));
        assert!(a
            .events()
            .iter()
            .any(|(_, e)| matches!(e, ClusterEvent::NodeCrash(_))));
        // Node ids always fit the cluster given at parse time.
        for (_, e) in a.events() {
            let n = match *e {
                ClusterEvent::NodeCrash(n) => n,
                ClusterEvent::Slowdown { node, .. } => node,
                ClusterEvent::CkptFail { node, .. } => node,
                _ => 0,
            };
            assert!(n < 5);
        }
    }

    #[test]
    fn bad_specs_are_rejected_with_context() {
        for (spec, needle) in [
            ("", "empty"),
            ("crash:9@10", "out of range"),
            ("crash:0", "missing '@time'"),
            ("crash:0@-5", ">= 0"),
            ("explode:0@5", "unknown fault kind"),
            ("straggler:0@5x1.5+10", "factor must be in (0, 1)"),
            ("blackout:0@5+0", "duration must be > 0"),
            ("seed:banana", "bad seed"),
        ] {
            let err = FaultPlan::parse(spec, 5, 100.0).unwrap_err();
            assert!(err.contains(needle), "spec '{spec}': error '{err}'");
        }
        assert!(FaultPlan::parse("crash:0@1", 0, 100.0).is_err(), "empty cluster");
    }
}
