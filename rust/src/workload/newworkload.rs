//! *NewWorkload* (§V.A.b): queues of GPT-2 and BERT training tasks "with
//! different sizes and various batch sizes", used for the Fig 4 comparison
//! against Opportunistic scheduling on the real 5-node testbed.
//!
//! 30- and 60-task queues arrive as a Poisson process; each task picks a
//! model from a size-weighted mix (small models are more common, as in real
//! clusters) and a batch size from {2,4,8,16,32}; its length is drawn
//! log-normally and converted to a sample count via a reference throughput,
//! so job durations land in the tens-of-minutes range the paper's testbed
//! runs occupy.

use super::{must_model, GenCtx};
use crate::job::JobSpec;

/// Model mix: (name, weight). Mid/small models dominate; a few 2.7B whales.
const MODEL_MIX: &[(&str, f64)] = &[
    ("gpt2-125m", 0.18),
    ("gpt2-350m", 0.22),
    ("gpt2-760m", 0.16),
    ("gpt2-1.3b", 0.12),
    ("gpt2-2.7b", 0.08),
    ("bert-base", 0.14),
    ("bert-large", 0.10),
];

const BATCHES: &[u32] = &[2, 4, 8, 16, 32];

/// Mean inter-arrival time (s). 30 tasks ≈ one hour of submissions.
const MEAN_INTERARRIVAL_S: f64 = 120.0;

/// Reference throughput used to size jobs (samples/s on one A100-class GPU
/// for a mid-size model, matching the perf model): job duration target ×
/// this = total samples, so generated jobs really run for minutes-to-hours
/// on the 11-GPU testbed and the queue builds up as in the paper's runs.
const REF_SAMPLES_PER_SEC: f64 = 120.0;

/// Generate an `n`-task NewWorkload queue.
pub fn generate(n: usize, seed: u64) -> Vec<JobSpec> {
    let mut ctx = GenCtx::new(seed);
    let weights: Vec<f64> = MODEL_MIX.iter().map(|(_, w)| *w).collect();
    let mut t = 0.0f64;
    let mut jobs = Vec::with_capacity(n);
    for _ in 0..n {
        t += ctx.rng.exp(1.0 / MEAN_INTERARRIVAL_S);
        let (name, _) = MODEL_MIX[ctx.rng.weighted_index(&weights)];
        let model = must_model(name);
        let batch = *ctx.rng.choose(BATCHES);
        // Target runtime: log-normal centered ~25 min, sd ~0.7 in log space,
        // clamped to [5 min, 3 h].
        let dur_s = ctx.rng.lognormal(7.3, 0.7).clamp(300.0, 10_800.0);
        // Size-aware: bigger models process fewer samples/s; scale the
        // sample budget so runtime stays in the target band on 1–8 GPUs.
        let size_scale = (350.0e6 / model.param_count() as f64).clamp(0.02, 4.0);
        let samples = (dur_s * REF_SAMPLES_PER_SEC * size_scale).max(100.0) as u64;
        let id = ctx.id();
        jobs.push(JobSpec::new(id, model, batch, samples, t));
    }
    jobs
}

/// The two queue lengths evaluated in Fig 4.
pub fn queue_30(seed: u64) -> Vec<JobSpec> {
    generate(30, seed)
}

pub fn queue_60(seed: u64) -> Vec<JobSpec> {
    generate(60, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(queue_30(1).len(), 30);
        assert_eq!(queue_60(1).len(), 60);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(20, 7);
        let b = generate(20, 7);
        let c = generate(20, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn arrival_times_increase() {
        let jobs = generate(50, 3);
        for w in jobs.windows(2) {
            assert!(w[1].submit_time > w[0].submit_time);
        }
    }

    #[test]
    fn mixes_models_and_batches() {
        let jobs = generate(60, 5);
        let models: std::collections::HashSet<&str> =
            jobs.iter().map(|j| j.model.name).collect();
        assert!(models.len() >= 4, "expected a mixed queue, got {models:?}");
        let batches: std::collections::HashSet<u32> =
            jobs.iter().map(|j| j.train.global_batch).collect();
        assert!(batches.len() >= 3);
        assert!(jobs.iter().any(|j| j.model.name.starts_with("bert")));
    }

    #[test]
    fn sample_budgets_positive_and_bounded() {
        for j in generate(60, 11) {
            assert!(j.total_samples >= 100);
            assert!(j.total_samples < 20_000_000);
        }
    }
}
