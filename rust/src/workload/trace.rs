//! Trace (de)serialization: a CSV-lite format so generated traces can be
//! saved, inspected, and replayed byte-identically.
//!
//! ```text
//! # id,model,batch,total_samples,submit_time
//! 0,gpt2-350m,8,120000,14.2
//! ```
//!
//! Multi-tenant traces (the synthetic generator's `tenants=` profiles)
//! append a sixth `tenant` column; tenantless traces keep the historical
//! 5-field format byte-for-byte, and the parser accepts both.

use crate::config::models::model_by_name;
use crate::job::JobSpec;
use anyhow::{anyhow, Context, Result};

/// Render a trace to CSV-lite text. The `tenant` column is emitted only
/// when at least one job carries a tenant, so pre-tenancy traces (and every
/// tenantless generator) stay byte-identical with the historical format.
pub fn to_csv(jobs: &[JobSpec]) -> String {
    let tenanted = jobs.iter().any(|j| !j.tenant.is_empty());
    let mut out = if tenanted {
        String::from("# id,model,batch,total_samples,submit_time,tenant\n")
    } else {
        String::from("# id,model,batch,total_samples,submit_time\n")
    };
    for j in jobs {
        if tenanted {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                j.id, j.model.name, j.train.global_batch, j.total_samples, j.submit_time, j.tenant
            ));
        } else {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                j.id, j.model.name, j.train.global_batch, j.total_samples, j.submit_time
            ));
        }
    }
    out
}

/// Parse a trace from CSV-lite text (5-field tenantless lines or 6-field
/// tenanted lines; the two may mix — an empty sixth field is anonymous).
pub fn from_csv(text: &str) -> Result<Vec<JobSpec>> {
    let mut jobs = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let ctx = || format!("trace line {}", lineno + 1);
        let parts: Vec<&str> = line.split(',').collect();
        if parts.len() != 5 && parts.len() != 6 {
            return Err(anyhow!("{}: expected 5 or 6 fields, got {}", ctx(), parts.len()));
        }
        let id: u64 = parts[0].trim().parse().with_context(ctx)?;
        let model = model_by_name(parts[1].trim())
            .ok_or_else(|| anyhow!("{}: unknown model '{}'", ctx(), parts[1]))?;
        let batch: u32 = parts[2].trim().parse().with_context(ctx)?;
        let samples: u64 = parts[3].trim().parse().with_context(ctx)?;
        let submit: f64 = parts[4].trim().parse().with_context(ctx)?;
        let mut spec = JobSpec::new(id, model, batch, samples, submit);
        if let Some(tenant) = parts.get(5) {
            spec = spec.with_tenant(tenant.trim());
        }
        jobs.push(spec);
    }
    Ok(jobs)
}

/// Save a trace to a file.
pub fn save(path: &str, jobs: &[JobSpec]) -> Result<()> {
    crate::util::write_file(path, &to_csv(jobs))?;
    Ok(())
}

/// Load a trace from a file.
pub fn load(path: &str) -> Result<Vec<JobSpec>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    from_csv(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::newworkload;

    #[test]
    fn roundtrip() {
        let jobs = newworkload::generate(25, 3);
        let text = to_csv(&jobs);
        let back = from_csv(&text).unwrap();
        assert_eq!(back, jobs);
    }

    #[test]
    fn rejects_malformed() {
        assert!(from_csv("1,gpt2-350m,8,100").is_err()); // 4 fields
        assert!(from_csv("1,gpt2-350m,8,100,0.0,t0,extra").is_err()); // 7 fields
        assert!(from_csv("1,unknown-model,8,100,0.0").is_err());
        assert!(from_csv("x,gpt2-350m,8,100,0.0").is_err());
    }

    #[test]
    fn tenant_column_roundtrips() {
        let jobs = vec![
            JobSpec::new(0, model_by_name("gpt2-350m").unwrap(), 8, 100, 0.5).with_tenant("t1"),
            JobSpec::new(1, model_by_name("gpt2-125m").unwrap(), 4, 200, 1.5),
        ];
        let text = to_csv(&jobs);
        assert!(text.starts_with("# id,model,batch,total_samples,submit_time,tenant\n"));
        let back = from_csv(&text).unwrap();
        assert_eq!(back, jobs);
        assert_eq!(back[0].tenant, "t1");
        assert_eq!(back[1].tenant, "", "empty sixth field is anonymous");
        // Tenantless traces keep the historical 5-field format exactly.
        let plain = vec![JobSpec::new(0, model_by_name("gpt2-350m").unwrap(), 8, 100, 0.5)];
        assert!(!to_csv(&plain).contains(",tenant"));
    }

    #[test]
    fn skips_comments_and_blanks() {
        let jobs = from_csv("# header\n\n0,gpt2-350m,8,100,0.5\n").unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].train.global_batch, 8);
    }
}
