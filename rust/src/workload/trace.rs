//! Trace (de)serialization: a CSV-lite format so generated traces can be
//! saved, inspected, and replayed byte-identically.
//!
//! ```text
//! # id,model,batch,total_samples,submit_time
//! 0,gpt2-350m,8,120000,14.2
//! ```

use crate::config::models::model_by_name;
use crate::job::JobSpec;
use anyhow::{anyhow, Context, Result};

/// Render a trace to CSV-lite text.
pub fn to_csv(jobs: &[JobSpec]) -> String {
    let mut out = String::from("# id,model,batch,total_samples,submit_time\n");
    for j in jobs {
        out.push_str(&format!(
            "{},{},{},{},{}\n",
            j.id, j.model.name, j.train.global_batch, j.total_samples, j.submit_time
        ));
    }
    out
}

/// Parse a trace from CSV-lite text.
pub fn from_csv(text: &str) -> Result<Vec<JobSpec>> {
    let mut jobs = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let ctx = || format!("trace line {}", lineno + 1);
        let parts: Vec<&str> = line.split(',').collect();
        if parts.len() != 5 {
            return Err(anyhow!("{}: expected 5 fields, got {}", ctx(), parts.len()));
        }
        let id: u64 = parts[0].trim().parse().with_context(ctx)?;
        let model = model_by_name(parts[1].trim())
            .ok_or_else(|| anyhow!("{}: unknown model '{}'", ctx(), parts[1]))?;
        let batch: u32 = parts[2].trim().parse().with_context(ctx)?;
        let samples: u64 = parts[3].trim().parse().with_context(ctx)?;
        let submit: f64 = parts[4].trim().parse().with_context(ctx)?;
        jobs.push(JobSpec::new(id, model, batch, samples, submit));
    }
    Ok(jobs)
}

/// Save a trace to a file.
pub fn save(path: &str, jobs: &[JobSpec]) -> Result<()> {
    crate::util::write_file(path, &to_csv(jobs))?;
    Ok(())
}

/// Load a trace from a file.
pub fn load(path: &str) -> Result<Vec<JobSpec>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    from_csv(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::newworkload;

    #[test]
    fn roundtrip() {
        let jobs = newworkload::generate(25, 3);
        let text = to_csv(&jobs);
        let back = from_csv(&text).unwrap();
        assert_eq!(back, jobs);
    }

    #[test]
    fn rejects_malformed() {
        assert!(from_csv("1,gpt2-350m,8,100").is_err()); // 4 fields
        assert!(from_csv("1,unknown-model,8,100,0.0").is_err());
        assert!(from_csv("x,gpt2-350m,8,100,0.0").is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let jobs = from_csv("# header\n\n0,gpt2-350m,8,100,0.5\n").unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].train.global_batch, 8);
    }
}
