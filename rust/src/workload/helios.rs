//! Synthetic Helios-like trace (SenseTime, SC'21 [20]).
//!
//! Per the paper's own description: "Compared to Philly, Helios requires
//! more GPUs and has longer runtime durations." The class mix therefore
//! shifts toward multi-GPU jobs and the duration distribution stretches.

use super::{must_model, GenCtx};
use crate::job::JobSpec;

/// Demand classes shifted large relative to Philly.
const CLASSES: &[(f64, &[&str], &[u32])] = &[
    (0.40, &["gpt2-350m", "gpt2-760m", "bert-large"], &[4, 8]),
    (0.30, &["gpt2-760m", "gpt2-1.3b"], &[8, 16]),
    (0.20, &["gpt2-1.3b", "gpt2-2.7b"], &[16, 32]),
    (0.10, &["gpt2-2.7b", "gpt2-7b"], &[8, 16]),
];

const MEAN_INTERARRIVAL_S: f64 = 150.0;
const REF_SAMPLES_PER_SEC: f64 = 120.0;

/// Generate an `n`-job Helios-like trace.
pub fn generate(n: usize, seed: u64) -> Vec<JobSpec> {
    let mut ctx = GenCtx::new(seed ^ 0x4E11_05);
    let weights: Vec<f64> = CLASSES.iter().map(|c| c.0).collect();
    let mut t = 0.0f64;
    let mut jobs = Vec::with_capacity(n);
    for _ in 0..n {
        t += ctx.rng.exp(1.0 / MEAN_INTERARRIVAL_S);
        let class = &CLASSES[ctx.rng.weighted_index(&weights)];
        let model = must_model(*ctx.rng.choose(class.1));
        let batch = *ctx.rng.choose(class.2);
        // Longer durations than Philly: log-normal body shifted up.
        let dur_s = if ctx.rng.chance(0.8) {
            ctx.rng.lognormal(7.6, 1.2).clamp(300.0, 43_200.0)
        } else {
            ctx.rng.pareto(3600.0, 1.4).min(86_400.0)
        };
        let size_scale = (350.0e6 / model.param_count() as f64).clamp(0.02, 4.0);
        let samples = (dur_s * REF_SAMPLES_PER_SEC * size_scale).max(50.0) as u64;
        let id = ctx.id();
        jobs.push(JobSpec::new(id, model, batch, samples, t));
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(generate(50, 1), generate(50, 1));
    }

    #[test]
    fn bigger_than_philly() {
        let h = generate(300, 5);
        let p = crate::workload::philly::generate(300, 5);
        let mean_params = |jobs: &[JobSpec]| {
            jobs.iter().map(|j| j.model.param_count() as f64).sum::<f64>() / jobs.len() as f64
        };
        assert!(
            mean_params(&h) > 1.5 * mean_params(&p),
            "helios jobs must be larger on average"
        );
        let mean_samples_time = |jobs: &[JobSpec]| {
            // proxy for duration: samples / size_scale
            jobs.iter()
                .map(|j| j.total_samples as f64 * j.model.param_count() as f64)
                .sum::<f64>()
                / jobs.len() as f64
        };
        assert!(mean_samples_time(&h) > mean_samples_time(&p));
    }

    #[test]
    fn includes_whales() {
        let h = generate(200, 9);
        assert!(h.iter().any(|j| j.model.name == "gpt2-7b" || j.model.name == "gpt2-2.7b"));
    }
}
