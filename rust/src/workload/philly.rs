//! Synthetic Philly-like trace (Microsoft, ATC'19 [5]).
//!
//! The real trace is proprietary-adjacent (released in aggregate form), so
//! we generate a synthetic trace calibrated to the paper's published
//! distributions (DESIGN.md §6):
//!
//! * **GPU demand**: dominated by 1-GPU jobs (~70 %), with 2/4/8-GPU jobs
//!   making up most of the rest and a thin ≥16 tail. In our serverless
//!   setting demand is *implied*: we map the demand class to model size ×
//!   batch so that MARP's natural allocation lands in the same class.
//! * **Durations**: heavy-tailed; the ATC'19 characterization shows medians
//!   of minutes and a long tail of multi-hour jobs → log-normal with σ≈1.4
//!   plus a Pareto tail.
//! * **Arrivals**: Poisson (the diurnal pattern is irrelevant for the
//!   scheduler comparison; both schedulers see the identical trace).

use super::{must_model, GenCtx};
use crate::job::JobSpec;

/// Demand classes: (weight, model candidates, batch candidates).
/// Class 0 ≈ 1 GPU, class 1 ≈ 2 GPUs, class 2 ≈ 4 GPUs, class 3 ≈ 8 GPUs.
const CLASSES: &[(f64, &[&str], &[u32])] = &[
    (0.70, &["gpt2-125m", "gpt2-350m", "bert-base"], &[2, 4, 8]),
    (0.15, &["gpt2-350m", "gpt2-760m", "bert-large"], &[8, 16]),
    (0.10, &["gpt2-760m", "gpt2-1.3b"], &[16, 32]),
    (0.05, &["gpt2-1.3b", "gpt2-2.7b"], &[16, 32]),
];

/// Mean inter-arrival (s): Philly is a busy multi-tenant cluster.
const MEAN_INTERARRIVAL_S: f64 = 90.0;

const REF_SAMPLES_PER_SEC: f64 = 120.0;

/// Generate an `n`-job Philly-like trace.
pub fn generate(n: usize, seed: u64) -> Vec<JobSpec> {
    let mut ctx = GenCtx::new(seed ^ 0x9A11_7EA5);
    generate_inner(n, &mut ctx)
}

fn generate_inner(n: usize, ctx: &mut GenCtx) -> Vec<JobSpec> {
    let weights: Vec<f64> = CLASSES.iter().map(|c| c.0).collect();
    let mut t = 0.0f64;
    let mut jobs = Vec::with_capacity(n);
    for _ in 0..n {
        t += ctx.rng.exp(1.0 / MEAN_INTERARRIVAL_S);
        let class = &CLASSES[ctx.rng.weighted_index(&weights)];
        let model = must_model(*ctx.rng.choose(class.1));
        let batch = *ctx.rng.choose(class.2);
        // Heavy tail: 85 % log-normal body, 15 % Pareto tail.
        let dur_s = if ctx.rng.chance(0.85) {
            ctx.rng.lognormal(6.6, 1.4).clamp(60.0, 21_600.0)
        } else {
            ctx.rng.pareto(1800.0, 1.5).min(43_200.0)
        };
        let size_scale = (350.0e6 / model.param_count() as f64).clamp(0.02, 4.0);
        let samples = (dur_s * REF_SAMPLES_PER_SEC * size_scale).max(50.0) as u64;
        let id = ctx.id();
        jobs.push(JobSpec::new(id, model, batch, samples, t));
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sized() {
        let a = generate(100, 42);
        let b = generate(100, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
    }

    #[test]
    fn small_jobs_dominate() {
        let jobs = generate(400, 9);
        let small = jobs
            .iter()
            .filter(|j| j.model.param_count() < 400_000_000 && j.train.global_batch <= 8)
            .count();
        assert!(
            small as f64 > 0.5 * jobs.len() as f64,
            "Philly must be small-job heavy: {small}/{}",
            jobs.len()
        );
    }

    #[test]
    fn durations_heavy_tailed() {
        let jobs = generate(500, 17);
        let mut sizes: Vec<f64> = jobs.iter().map(|j| j.total_samples as f64).collect();
        sizes.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = sizes[sizes.len() / 2];
        let p99 = sizes[(sizes.len() as f64 * 0.99) as usize];
        assert!(p99 > 5.0 * p50, "p50={p50} p99={p99}");
    }
}
