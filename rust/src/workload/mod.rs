//! Workload and trace generation (§V.A.b).
//!
//! * [`newworkload`] — the paper's *NewWorkload*: GPT-2 and BERT models of
//!   different sizes and batch sizes, in 30- and 60-task queues.
//! * [`philly`] — synthetic trace calibrated to the published Philly
//!   (Microsoft ATC'19) distributions: demand heavily skewed to small jobs,
//!   heavy-tailed durations.
//! * [`helios`] — synthetic trace per the Helios (SenseTime SC'21)
//!   characterization: "requires more GPUs and has longer runtime durations"
//!   than Philly (the paper's own description).
//! * [`trace`] — CSV-lite serialization so traces can be saved/replayed.
//! * [`generator`] — the open-world synthetic generator: parameterized
//!   arrival processes (Poisson/bursty/diurnal), heavy-tailed durations,
//!   model mixes from the zoo, and per-tenant submission profiles behind
//!   the `synth:<spec>` grammar.
//!
//! All generators are seeded and deterministic.

pub mod generator;
pub mod helios;
pub mod newworkload;
pub mod philly;
pub mod trace;

use crate::config::models::{model_by_name, ModelConfig};
use crate::job::JobSpec;
use crate::util::prng::Xoshiro256pp;

/// Shared helpers for the trace generators.
pub(crate) struct GenCtx {
    pub rng: Xoshiro256pp,
    pub next_id: u64,
}

impl GenCtx {
    pub fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256pp::seed_from_u64(seed), next_id: 0 }
    }

    pub fn id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }
}

/// Resolve a model name, panicking with context (generator tables are
/// compile-time constants, so a miss is a programming error).
pub(crate) fn must_model(name: &str) -> ModelConfig {
    model_by_name(name).unwrap_or_else(|| panic!("workload references unknown model {name}"))
}

/// Quick stats over a generated trace (used by tests and `frenzy trace`).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceStats {
    pub n_jobs: usize,
    pub span_s: f64,
    pub mean_batch: f64,
    pub mean_samples: f64,
}

pub fn trace_stats(jobs: &[JobSpec]) -> TraceStats {
    let n = jobs.len().max(1);
    TraceStats {
        n_jobs: jobs.len(),
        span_s: jobs.iter().map(|j| j.submit_time).fold(0.0, f64::max),
        mean_batch: jobs.iter().map(|j| j.train.global_batch as f64).sum::<f64>() / n as f64,
        mean_samples: jobs.iter().map(|j| j.total_samples as f64).sum::<f64>() / n as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_empty() {
        let s = trace_stats(&[]);
        assert_eq!(s.n_jobs, 0);
        assert_eq!(s.span_s, 0.0);
    }
}
