//! Open-world synthetic workload generator (`--workload synth:<spec>`).
//!
//! Philly/Helios replay two *fixed* traces; this module generates
//! parameterized streams so fairness, admission, and fault changes can be
//! stressed against arbitrary open-world workloads while staying fully
//! deterministic (every draw comes from the crate PRNG seeded by the spec).
//!
//! The spec grammar follows [`crate::faults::FaultPlan`]: comma-separated
//! `key=value` clauses, every key optional.
//!
//! ```text
//! synth:seed=42,arrivals=poisson:0.5,tenants=8,mix=zoo
//! synth:seed=7,jobs=200,arrivals=bursty:0.2x10+600,dur=pareto:1800x1.5
//! synth:arrivals=diurnal:0.1+86400,tenants=4:zipf,mix=gpt2-350m
//! ```
//!
//! Clauses (the `synth:` prefix is stripped by the CLI before parsing):
//!
//! * `seed=<u64>` — PRNG seed; defaults to the CLI `--seed`.
//! * `jobs=<n>` — job count; defaults to the CLI `--tasks`.
//! * `arrivals=poisson:<rate>` — homogeneous Poisson, `rate` jobs/s.
//! * `arrivals=bursty:<rate>x<mult>+<period>` — square-wave bursts: the
//!   first 20 % of every `period` seconds runs at `rate × mult`, the rest
//!   at `rate` (Lewis–Shedler thinning, so draws stay deterministic).
//! * `arrivals=diurnal:<rate>[+<period>]` — sinusoidal day: the rate swings
//!   between 0 and `2 × rate` over `period` seconds (default 86400).
//! * `dur=mixed` — Philly calibration: 85 % log-normal body + 15 % Pareto
//!   tail (the default).
//! * `dur=lognormal:<mu>x<sigma>` — log-normal with the *underlying*
//!   normal's parameters.
//! * `dur=pareto:<scale>x<alpha>` — Pareto with scale seconds and shape.
//! * `tenants=<n>[:uniform|:zipf]` — attribute jobs to `n` tenants
//!   `t0..t{n-1}`; `zipf` skews submission weight ∝ 1/(rank+1) so a head
//!   tenant dominates (the fairness stress shape). Omitted = anonymous.
//! * `mix=zoo|small|large|<model-name>` — model mix drawn from the zoo.

use super::{must_model, GenCtx};
use crate::job::JobSpec;

/// Stream-domain tag XOR'd into the seed so `synth` draws never collide
/// with the Philly/Helios streams for the same `--seed`.
const SEED_TAG: u64 = 0x5EED_0F_0BE2;

/// Fraction of each bursty period spent at the boosted rate.
const BURST_FRAC: f64 = 0.2;

/// Reference throughput used to convert a duration target into a sample
/// count (same calibration as the Philly generator).
const REF_SAMPLES_PER_SEC: f64 = 120.0;

/// The arrival process.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrivals {
    /// Homogeneous Poisson at `rate_per_s`.
    Poisson { rate_per_s: f64 },
    /// Square-wave bursts: `rate × mult` for the first [`BURST_FRAC`] of
    /// every `period_s`, base `rate` otherwise.
    Bursty { rate_per_s: f64, mult: f64, period_s: f64 },
    /// Sinusoidal day: instantaneous rate `rate × (1 + sin(2πt/period))`.
    Diurnal { rate_per_s: f64, period_s: f64 },
}

impl Arrivals {
    /// Instantaneous rate at time `t` (jobs/s).
    fn rate_at(&self, t: f64) -> f64 {
        match *self {
            Arrivals::Poisson { rate_per_s } => rate_per_s,
            Arrivals::Bursty { rate_per_s, mult, period_s } => {
                if (t % period_s) < BURST_FRAC * period_s {
                    rate_per_s * mult
                } else {
                    rate_per_s
                }
            }
            Arrivals::Diurnal { rate_per_s, period_s } => {
                rate_per_s * (1.0 + (2.0 * std::f64::consts::PI * t / period_s).sin())
            }
        }
    }

    /// Upper bound on the instantaneous rate (the thinning envelope).
    fn max_rate(&self) -> f64 {
        match *self {
            Arrivals::Poisson { rate_per_s } => rate_per_s,
            Arrivals::Bursty { rate_per_s, mult, .. } => rate_per_s * mult.max(1.0),
            Arrivals::Diurnal { rate_per_s, .. } => 2.0 * rate_per_s,
        }
    }
}

/// The duration (→ sample count) distribution.
#[derive(Debug, Clone, PartialEq)]
pub enum Durations {
    /// Philly calibration: 85 % log-normal(6.6, 1.4) clamped to
    /// [60 s, 6 h], 15 % Pareto(1800, 1.5) capped at 12 h.
    Mixed,
    /// Log-normal with the underlying normal's (mu, sigma), clamped to
    /// [60 s, 24 h].
    Lognormal { mu: f64, sigma: f64 },
    /// Pareto(scale_s, alpha), capped at 24 h.
    Pareto { scale_s: f64, alpha: f64 },
}

/// How submissions distribute over tenants.
#[derive(Debug, Clone, PartialEq)]
pub enum Skew {
    /// Every tenant submits with equal weight.
    Uniform,
    /// Weight ∝ 1/(rank+1): tenant `t0` submits ~n/H(n) of the stream —
    /// the heavy-head shape the fairness layer must absorb.
    Zipf,
}

/// Which models jobs draw from.
#[derive(Debug, Clone, PartialEq)]
pub enum Mix {
    /// Weighted classes over the zoo, skewed small like real clusters.
    Zoo,
    /// Small models only (sub-500M) — every job fits everywhere.
    Small,
    /// Large models only (≥1.3B) — stresses the big-memory pool.
    Large,
    /// A single named model.
    Model(String),
}

/// A parsed `synth:` workload spec. Generation is a pure function of this
/// struct plus the CLI fallbacks: same spec ⇒ byte-identical trace.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthSpec {
    /// `seed=` clause; `None` falls back to the CLI `--seed`.
    pub seed: Option<u64>,
    /// `jobs=` clause; `None` falls back to the CLI `--tasks`.
    pub jobs: Option<usize>,
    pub arrivals: Arrivals,
    pub durations: Durations,
    /// Number of tenants (0 = anonymous stream).
    pub tenants: usize,
    pub skew: Skew,
    pub mix: Mix,
}

impl Default for SynthSpec {
    fn default() -> Self {
        Self {
            seed: None,
            jobs: None,
            // Philly's busy-cluster calibration: one job every 90 s.
            arrivals: Arrivals::Poisson { rate_per_s: 1.0 / 90.0 },
            durations: Durations::Mixed,
            tenants: 0,
            skew: Skew::Uniform,
            mix: Mix::Zoo,
        }
    }
}

fn parse_f64(s: &str, what: &str) -> Result<f64, String> {
    let v: f64 = s.trim().parse().map_err(|_| format!("bad {what} '{s}' (want a number)"))?;
    if !v.is_finite() || v <= 0.0 {
        return Err(format!("bad {what} '{s}' (must be finite and > 0)"));
    }
    Ok(v)
}

impl SynthSpec {
    /// Parse a spec string (everything after `synth:`). Empty = defaults.
    pub fn parse(spec: &str) -> Result<SynthSpec, String> {
        let mut out = SynthSpec::default();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (key, val) = clause
                .split_once('=')
                .ok_or_else(|| format!("bad synth clause '{clause}' (want key=value)"))?;
            match key.trim() {
                "seed" => {
                    out.seed = Some(
                        val.trim()
                            .parse()
                            .map_err(|_| format!("bad seed '{val}' (want a u64)"))?,
                    );
                }
                "jobs" => {
                    out.jobs = Some(
                        val.trim()
                            .parse::<usize>()
                            .ok()
                            .filter(|&n| n > 0)
                            .ok_or_else(|| format!("bad jobs '{val}' (want a count > 0)"))?,
                    );
                }
                "arrivals" => out.arrivals = Self::parse_arrivals(val)?,
                "dur" => out.durations = Self::parse_durations(val)?,
                "tenants" => {
                    let (n, skew) = match val.split_once(':') {
                        None => (val, Skew::Uniform),
                        Some((n, "uniform")) => (n, Skew::Uniform),
                        Some((n, "zipf")) => (n, Skew::Zipf),
                        Some((_, other)) => {
                            return Err(format!(
                                "bad tenant skew '{other}' (want uniform or zipf)"
                            ))
                        }
                    };
                    out.tenants = n
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad tenants '{val}' (want a count)"))?;
                    out.skew = skew;
                }
                "mix" => {
                    out.mix = match val.trim() {
                        "zoo" => Mix::Zoo,
                        "small" => Mix::Small,
                        "large" => Mix::Large,
                        name => {
                            if crate::config::models::model_by_name(name).is_none() {
                                return Err(format!(
                                    "bad mix '{name}' (want zoo, small, large, or a model name)"
                                ));
                            }
                            Mix::Model(name.to_string())
                        }
                    };
                }
                other => {
                    return Err(format!(
                        "unknown synth clause '{other}' \
                         (want seed, jobs, arrivals, dur, tenants, or mix)"
                    ))
                }
            }
        }
        Ok(out)
    }

    fn parse_arrivals(val: &str) -> Result<Arrivals, String> {
        let (kind, rest) = val
            .split_once(':')
            .ok_or_else(|| format!("bad arrivals '{val}' (want kind:params)"))?;
        match kind.trim() {
            "poisson" => Ok(Arrivals::Poisson { rate_per_s: parse_f64(rest, "arrival rate")? }),
            "bursty" => {
                let (rate, rest) = rest
                    .split_once('x')
                    .ok_or_else(|| format!("bad bursty '{rest}' (want rate x mult + period)"))?;
                let (mult, period) = rest
                    .split_once('+')
                    .ok_or_else(|| format!("bad bursty '{rest}' (want rate x mult + period)"))?;
                Ok(Arrivals::Bursty {
                    rate_per_s: parse_f64(rate, "arrival rate")?,
                    mult: parse_f64(mult, "burst multiplier")?,
                    period_s: parse_f64(period, "burst period")?,
                })
            }
            "diurnal" => {
                let (rate, period) = match rest.split_once('+') {
                    Some((r, p)) => (r, parse_f64(p, "diurnal period")?),
                    None => (rest, 86_400.0),
                };
                Ok(Arrivals::Diurnal {
                    rate_per_s: parse_f64(rate, "arrival rate")?,
                    period_s: period,
                })
            }
            other => Err(format!("unknown arrival process '{other}' \
                                  (want poisson, bursty, or diurnal)")),
        }
    }

    fn parse_durations(val: &str) -> Result<Durations, String> {
        if val.trim() == "mixed" {
            return Ok(Durations::Mixed);
        }
        let (kind, rest) = val
            .split_once(':')
            .ok_or_else(|| format!("bad dur '{val}' (want mixed, or kind:a x b)"))?;
        let (a, b) = rest
            .split_once('x')
            .ok_or_else(|| format!("bad dur params '{rest}' (want a x b)"))?;
        match kind.trim() {
            "lognormal" => Ok(Durations::Lognormal {
                mu: parse_f64(a, "lognormal mu")?,
                sigma: parse_f64(b, "lognormal sigma")?,
            }),
            "pareto" => Ok(Durations::Pareto {
                scale_s: parse_f64(a, "pareto scale")?,
                alpha: parse_f64(b, "pareto alpha")?,
            }),
            other => Err(format!("unknown duration kind '{other}' \
                                  (want mixed, lognormal, or pareto)")),
        }
    }

    /// Per-tenant submission weights (empty when the stream is anonymous).
    pub fn tenant_weights(&self) -> Vec<f64> {
        match self.skew {
            Skew::Uniform => vec![1.0; self.tenants],
            Skew::Zipf => (0..self.tenants).map(|i| 1.0 / (i + 1) as f64).collect(),
        }
    }
}

/// Model classes per mix: (weight, model candidates, batch candidates).
fn mix_classes(mix: &Mix) -> Vec<(f64, Vec<&'static str>, Vec<u32>)> {
    match mix {
        Mix::Zoo => vec![
            (0.55, vec!["gpt2-125m", "gpt2-350m", "bert-base"], vec![2, 4, 8]),
            (0.25, vec!["gpt2-350m", "gpt2-760m", "bert-large"], vec![8, 16]),
            (0.15, vec!["gpt2-760m", "gpt2-1.3b"], vec![16, 32]),
            (0.05, vec!["gpt2-1.3b", "gpt2-2.7b"], vec![16, 32]),
        ],
        Mix::Small => vec![(1.0, vec!["gpt2-125m", "gpt2-350m", "bert-base"], vec![2, 4, 8])],
        Mix::Large => {
            vec![(1.0, vec!["gpt2-1.3b", "gpt2-2.7b", "gpt2-7b"], vec![8, 16, 32])]
        }
        Mix::Model(name) => {
            // Validated at parse time; leak-free because zoo names are
            // 'static — resolve through the table to get the static str.
            let stat = must_model(name).name;
            vec![(1.0, vec![stat], vec![4, 8, 16, 32])]
        }
    }
}

/// Generate a trace from a parsed spec. `n_fallback`/`seed_fallback` supply
/// the CLI `--tasks`/`--seed` when the spec omits `jobs=`/`seed=`.
pub fn generate(spec: &SynthSpec, n_fallback: usize, seed_fallback: u64) -> Vec<JobSpec> {
    let n = spec.jobs.unwrap_or(n_fallback);
    let seed = spec.seed.unwrap_or(seed_fallback);
    let mut ctx = GenCtx::new(seed ^ SEED_TAG);
    let classes = mix_classes(&spec.mix);
    let class_weights: Vec<f64> = classes.iter().map(|c| c.0).collect();
    let tenant_weights = spec.tenant_weights();
    let max_rate = spec.arrivals.max_rate();

    let mut t = 0.0f64;
    let mut jobs = Vec::with_capacity(n);
    for _ in 0..n {
        // Lewis–Shedler thinning against the envelope rate: exact for the
        // nonhomogeneous processes, degenerates to plain inversion for
        // Poisson (acceptance probability 1). Draw order is fixed per
        // candidate point, so the stream is a pure function of the seed.
        loop {
            t += ctx.rng.exp(max_rate);
            if ctx.rng.chance(spec.arrivals.rate_at(t) / max_rate) {
                break;
            }
        }
        let class = &classes[ctx.rng.weighted_index(&class_weights)];
        let model = must_model(ctx.rng.choose(&class.1));
        let batch = *ctx.rng.choose(&class.2);
        let dur_s = match spec.durations {
            Durations::Mixed => {
                if ctx.rng.chance(0.85) {
                    ctx.rng.lognormal(6.6, 1.4).clamp(60.0, 21_600.0)
                } else {
                    ctx.rng.pareto(1800.0, 1.5).min(43_200.0)
                }
            }
            Durations::Lognormal { mu, sigma } => {
                ctx.rng.lognormal(mu, sigma).clamp(60.0, 86_400.0)
            }
            Durations::Pareto { scale_s, alpha } => {
                ctx.rng.pareto(scale_s, alpha).min(86_400.0)
            }
        };
        let size_scale = (350.0e6 / model.param_count() as f64).clamp(0.02, 4.0);
        let samples = (dur_s * REF_SAMPLES_PER_SEC * size_scale).max(50.0) as u64;
        let id = ctx.id();
        let mut spec_job = JobSpec::new(id, model, batch, samples, t);
        if !tenant_weights.is_empty() {
            let tenant = ctx.rng.weighted_index(&tenant_weights);
            spec_job = spec_job.with_tenant(&format!("t{tenant}"));
        }
        jobs.push(spec_job);
    }
    jobs
}

/// Parse + generate in one step — the CLI entry point for
/// `--workload synth:<spec>` (the caller strips the prefix).
pub fn from_spec(
    spec: &str,
    n_fallback: usize,
    seed_fallback: u64,
) -> Result<Vec<JobSpec>, String> {
    Ok(generate(&SynthSpec::parse(spec)?, n_fallback, seed_fallback))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_spec_is_byte_identical() {
        let spec = SynthSpec::parse("seed=42,arrivals=poisson:0.5,tenants=8,mix=zoo").unwrap();
        let a = generate(&spec, 100, 0);
        let b = generate(&spec, 100, 0);
        assert_eq!(a, b);
        assert_eq!(
            crate::workload::trace::to_csv(&a),
            crate::workload::trace::to_csv(&b)
        );
        assert_eq!(a.len(), 100);
        // Different seed, different stream.
        let c = generate(&SynthSpec { seed: Some(43), ..spec.clone() }, 100, 0);
        assert_ne!(a, c);
    }

    #[test]
    fn grammar_errors_are_contextual() {
        for (spec, needle) in [
            ("seed=abc", "bad seed"),
            ("jobs=0", "bad jobs"),
            ("arrivals=poisson", "want kind:params"),
            ("arrivals=warp:1", "unknown arrival process"),
            ("arrivals=bursty:0.5", "want rate x mult + period"),
            ("dur=weird:1x2", "unknown duration kind"),
            ("tenants=4:square", "bad tenant skew"),
            ("mix=not-a-model", "bad mix"),
            ("volume=11", "unknown synth clause"),
            ("seed", "want key=value"),
        ] {
            let err = SynthSpec::parse(spec).expect_err(spec);
            assert!(err.contains(needle), "spec '{spec}': error '{err}' lacks '{needle}'");
        }
    }

    #[test]
    fn empty_spec_uses_defaults_and_cli_fallbacks() {
        let spec = SynthSpec::parse("").unwrap();
        assert_eq!(spec, SynthSpec::default());
        let jobs = generate(&spec, 10, 7);
        assert_eq!(jobs.len(), 10);
        assert!(jobs.iter().all(|j| j.tenant.is_empty()), "default is anonymous");
        // Fallback seed feeds the stream: different --seed, different trace.
        assert_ne!(jobs, generate(&spec, 10, 8));
        // An explicit seed clause wins over the CLI fallback.
        let pinned = SynthSpec::parse("seed=3").unwrap();
        assert_eq!(generate(&pinned, 10, 7), generate(&pinned, 10, 99));
    }

    #[test]
    fn poisson_rate_within_tolerance() {
        // Mean inter-arrival of a Poisson(λ=0.5) stream is 2 s; over 4000
        // jobs the sample mean concentrates well within ±10 %.
        let spec = SynthSpec::parse("seed=11,arrivals=poisson:0.5").unwrap();
        let jobs = generate(&spec, 4000, 0);
        let span = jobs.last().unwrap().submit_time;
        let mean = span / jobs.len() as f64;
        assert!((1.8..2.2).contains(&mean), "mean inter-arrival {mean}");
    }

    #[test]
    fn bursty_concentrates_arrivals_in_the_burst_window() {
        let spec = SynthSpec::parse("seed=5,arrivals=bursty:0.05x20+1000").unwrap();
        let jobs = generate(&spec, 2000, 0);
        let in_burst = jobs
            .iter()
            .filter(|j| (j.submit_time % 1000.0) < BURST_FRAC * 1000.0)
            .count();
        // Burst windows are 20 % of time but carry 20x the rate → they
        // should hold the large majority of arrivals (expected ~83 %).
        assert!(
            in_burst as f64 > 0.6 * jobs.len() as f64,
            "only {in_burst}/{} arrivals in burst windows",
            jobs.len()
        );
    }

    #[test]
    fn diurnal_peaks_in_the_high_half_of_the_cycle() {
        let spec = SynthSpec::parse("seed=13,arrivals=diurnal:0.1+10000").unwrap();
        let jobs = generate(&spec, 3000, 0);
        // rate(t) > mean over t/period mod 1 ∈ (0, 0.5): the sine's
        // positive half-cycle should carry well over half the arrivals.
        let high = jobs
            .iter()
            .filter(|j| (j.submit_time % 10_000.0) < 5000.0)
            .count();
        assert!(
            high as f64 > 0.7 * jobs.len() as f64,
            "only {high}/{} arrivals in the peak half-cycle",
            jobs.len()
        );
    }

    #[test]
    fn pareto_tail_is_heavy() {
        let spec = SynthSpec::parse("seed=17,dur=pareto:600x1.2,mix=gpt2-350m").unwrap();
        let jobs = generate(&spec, 1000, 0);
        let mut samples: Vec<f64> = jobs.iter().map(|j| j.total_samples as f64).collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = samples[samples.len() / 2];
        let p99 = samples[(samples.len() as f64 * 0.99) as usize];
        assert!(p99 > 5.0 * p50, "p50={p50} p99={p99}");
    }

    #[test]
    fn zipf_tenants_skew_head_heavy() {
        let spec = SynthSpec::parse("seed=23,tenants=8:zipf").unwrap();
        let jobs = generate(&spec, 2000, 0);
        let count = |t: &str| jobs.iter().filter(|j| j.tenant == t).count();
        let head = count("t0");
        let tail = count("t7");
        assert!(head > 4 * tail, "zipf head {head} vs tail {tail}");
        // Uniform spreads evenly: no tenant holds more than twice its share.
        let uni = generate(&SynthSpec::parse("seed=23,tenants=8").unwrap(), 2000, 0);
        for i in 0..8 {
            let c = uni.iter().filter(|j| j.tenant == format!("t{i}")).count();
            assert!((125..500).contains(&c), "uniform tenant t{i} got {c}/2000");
        }
    }

    #[test]
    fn mix_constrains_models() {
        let small = generate(&SynthSpec::parse("seed=3,mix=small").unwrap(), 200, 0);
        assert!(small.iter().all(|j| j.model.param_count() < 500_000_000));
        let large = generate(&SynthSpec::parse("seed=3,mix=large").unwrap(), 200, 0);
        assert!(large.iter().all(|j| j.model.param_count() >= 1_000_000_000));
        let single = generate(&SynthSpec::parse("seed=3,mix=gpt2-760m").unwrap(), 50, 0);
        assert!(single.iter().all(|j| j.model.name == "gpt2-760m"));
    }

    #[test]
    fn jobs_clause_overrides_cli_tasks() {
        let spec = SynthSpec::parse("seed=1,jobs=17").unwrap();
        assert_eq!(generate(&spec, 100, 0).len(), 17);
    }
}
