//! Sia baseline (SOSP'23 [8]): heterogeneity-aware, goodput-optimized
//! scheduling via an assignment ILP solved every round.
//!
//! Faithful simplification of Sia's round structure:
//!
//! 1. For every pending job, enumerate candidate configs
//!    `(GPU type, n ∈ {1, 2, 4, …})`, valued by *normalized goodput*
//!    (throughput of the config divided by the job's best config).
//! 2. Solve `max Σ value` subject to per-type GPU capacity and one config
//!    per job — a 0/1 multi-choice ILP ([`crate::ilp`], standing in for the
//!    commercial solver Sia uses).
//! 3. Realize chosen configs on concrete nodes (most-idle-first within the
//!    type — Sia packs for goodput, not for fragmentation).
//!
//! The exhaustive re-solve is why Sia's scheduling overhead "increases
//! extremely rapidly as the number of tasks grows" (Fig 5a): the B&B node
//! count — returned as `work_units` — grows superlinearly in jobs×configs,
//! while HAS stays linear.

use super::{derive_placement, Decision, PendingJob, PendingQueue, SchedRound, Scheduler};
use crate::cluster::{Allocation, ClusterState, ClusterView};
use crate::config::ClusterSpec;
use crate::ilp;
use crate::job::JobSpec;
use crate::memory::{fits, Parallelism};
use crate::perfmodel::{PerfModel, Placement};

/// A candidate configuration for one job.
#[derive(Debug, Clone)]
struct Candidate {
    job_idx: usize,
    type_idx: usize,
    par: Parallelism,
    n: u32,
    value: f64,
}

pub struct Sia {
    pm: PerfModel,
    /// Distinct GPU types (by name) with their spec — the ILP dimensions.
    type_names: Vec<&'static str>,
    /// GPU memory size of each entry in `type_names` (parallel vector) —
    /// the bridge from type names to the capacity index's size classes.
    type_mems: Vec<u64>,
    /// True when memory size identifies the GPU type uniquely in the
    /// current topology, so per-type idle totals can be served from the
    /// index's per-class aggregates. Two types sharing a size (A100-80G
    /// vs A800-80G) force the reference scan regardless of `indexed`.
    mem_identifies_type: bool,
    /// Node-limit safeguard for the B&B solver.
    pub node_limit: u64,
    /// Cap on data-parallel width per config.
    max_gpus_per_job: u32,
    /// Sia re-solves on a fixed cadence (the Sia paper uses 30–60 s rounds;
    /// re-solving per event would be prohibitive — that's Fig 5a).
    pub round_interval: f64,
    /// Serve per-type idle totals from the capacity index (default).
    /// `false` selects the reference O(nodes) scan, kept as the
    /// differential-test oracle (`benches/bench_sched.rs`).
    pub indexed: bool,
}

/// Distinct `(name, mem)` GPU types, name-sorted, plus whether memory size
/// alone identifies the type (no two names share a size).
fn type_table(
    gpus: impl Iterator<Item = (&'static str, u64)>,
) -> (Vec<&'static str>, Vec<u64>, bool) {
    let mut pairs: Vec<(&'static str, u64)> = gpus.collect();
    pairs.sort_unstable();
    pairs.dedup();
    let names: Vec<&'static str> = pairs.iter().map(|p| p.0).collect();
    let mems: Vec<u64> = pairs.iter().map(|p| p.1).collect();
    let mut distinct = mems.clone();
    distinct.sort_unstable();
    distinct.dedup();
    let unambiguous = distinct.len() == mems.len();
    (names, mems, unambiguous)
}

impl Sia {
    pub fn new(spec: &ClusterSpec) -> Self {
        let (type_names, type_mems, mem_identifies_type) =
            type_table(spec.nodes.iter().map(|n| (n.gpu.name, n.gpu.mem_bytes)));
        Self {
            pm: PerfModel::new(spec.inter_node_gbps),
            type_names,
            type_mems,
            mem_identifies_type,
            node_limit: 20_000_000,
            max_gpus_per_job: 16,
            round_interval: 30.0,
            indexed: true,
        }
    }

    /// Tensor parallelism for this GPU type as the *user* would size it
    /// (Sia schedules "tasks with user-specified numbers of GPUs" [8] — it
    /// has no MARP): fit the model *states* `20W/t`, forgetting activations.
    /// OOM retries double the degree; after enough burns the user checks the
    /// full memory model.
    fn user_tp(&self, job: &JobSpec, mem: u64, max_t: u32, attempts: u32) -> Option<u32> {
        let static_bytes = 20.0 * job.model.param_count() as f64;
        let mut t = 1u32;
        while t <= max_t {
            if static_bytes / t as f64 <= mem as f64 {
                break;
            }
            t *= 2;
        }
        if t > max_t {
            return None;
        }
        t = (t << attempts.min(8)).min(max_t.next_power_of_two());
        if attempts >= 3 {
            while t <= max_t && !fits(&job.model, &job.train, Parallelism::new(1, t), mem) {
                t *= 2;
            }
        }
        if t <= max_t {
            Some(t)
        } else {
            None
        }
    }

    /// Enumerate configs for one job against current per-type idle counts.
    fn candidates(
        &self,
        job_idx: usize,
        job: &JobSpec,
        attempts: u32,
        snapshot: &ClusterState,
        idle_per_type: &[u32],
    ) -> Vec<Candidate> {
        let mut out = Vec::new();
        for (type_idx, &tname) in self.type_names.iter().enumerate() {
            if idle_per_type[type_idx] == 0 {
                continue;
            }
            // Representative node of this type (for mem/link/tflops).
            let node = snapshot.nodes.iter().find(|n| n.gpu.name == tname).unwrap();
            let max_node = snapshot
                .nodes
                .iter()
                .filter(|n| n.gpu.name == tname)
                .map(|n| n.total)
                .max()
                .unwrap_or(1);
            let Some(t_min) = self.user_tp(job, node.gpu.mem_bytes, max_node, attempts) else {
                continue;
            };
            let mut n = t_min;
            while n <= idle_per_type[type_idx].min(self.max_gpus_per_job) {
                let t = t_min;
                let d = n / t;
                if d >= 1 && d * t == n && d <= job.train.global_batch.max(1) {
                    let par = Parallelism::new(d, t);
                    let placement = if n <= max_node {
                        Placement::single_node(node.link)
                    } else {
                        Placement::tp_local_dp_cross(node.link)
                    };
                    let thr = self.pm.samples_per_sec(
                        &job.model,
                        &job.train,
                        par,
                        &node.gpu,
                        placement,
                    );
                    out.push(Candidate { job_idx, type_idx, par, n, value: thr });
                }
                n *= 2;
            }
        }
        // Normalize: goodput relative to the job's best config, minus a tiny
        // GPU-count penalty so ties prefer smaller allocations.
        let best = out.iter().map(|c| c.value).fold(0.0f64, f64::max);
        if best > 0.0 {
            for c in &mut out {
                c.value = c.value / best - 1e-4 * c.n as f64;
            }
        }
        out
    }

    /// Realize a chosen (type, n) config onto concrete nodes: most-idle
    /// first within the type.
    fn realize(
        &self,
        type_idx: usize,
        n: u32,
        idle: &mut [u32],
        snapshot: &ClusterState,
    ) -> Option<Vec<(usize, u32)>> {
        let tname = self.type_names[type_idx];
        let mut nodes: Vec<usize> = snapshot
            .nodes
            .iter()
            .filter(|nd| nd.gpu.name == tname && idle[nd.id] > 0)
            .map(|nd| nd.id)
            .collect();
        nodes.sort_by(|&a, &b| idle[b].cmp(&idle[a]));
        let mut parts = Vec::new();
        let mut left = n;
        for id in nodes {
            if left == 0 {
                break;
            }
            let take = idle[id].min(left);
            parts.push((id, take));
            idle[id] -= take;
            left -= take;
        }
        if left > 0 {
            // roll back
            for &(id, c) in &parts {
                idle[id] += c;
            }
            None
        } else {
            Some(parts)
        }
    }
}

impl Scheduler for Sia {
    fn name(&self) -> &'static str {
        "sia"
    }

    fn round_interval_s(&self) -> Option<f64> {
        Some(self.round_interval)
    }

    /// Elasticity: the ILP's GPU-type dimensions come from the topology.
    fn cluster_changed(&mut self, state: &ClusterState) {
        let (type_names, type_mems, mem_identifies_type) =
            type_table(state.active_nodes().map(|n| (n.gpu.name, n.gpu.mem_bytes)));
        self.type_names = type_names;
        self.type_mems = type_mems;
        self.mem_identifies_type = mem_identifies_type;
    }

    fn schedule(
        &mut self,
        pending: &PendingQueue,
        view: &ClusterView<'_>,
        _now: f64,
    ) -> SchedRound {
        let snapshot = view.state();
        let pending: Vec<&PendingJob> = pending.iter().collect();
        let mut round = SchedRound::default();
        if pending.is_empty() {
            return round;
        }
        // Per-node idle capacity with draining nodes masked out — a node in
        // graceful drain must not receive new placements, however much idle
        // capacity a (possibly stale) view still shows on it.
        let idle_mask: Vec<u32> = snapshot
            .nodes
            .iter()
            .map(|n| if view.is_draining(n.id) { 0 } else { n.idle })
            .collect();
        // Per-type idle capacity. When memory size identifies the type, the
        // totals come from the index's per-class suffix sums — O(T log S +
        // draining) instead of the reference O(T × nodes) scan. The ILP
        // re-solve itself stays superlinear by design (Fig 5a); this only
        // stops the *bookkeeping* from scaling with cluster size.
        let idle_per_type: Vec<u32> = if self.indexed && self.mem_identifies_type {
            let index = view.index();
            self.type_mems
                .iter()
                .map(|&mem| {
                    let c = index.class_for(mem);
                    if c >= index.n_classes() || index.class_size(c) != mem {
                        return 0; // no node of this type in the indexed state
                    }
                    let mut idle = index.idle_suffix(c) - index.idle_suffix(c + 1);
                    for &n in view.draining().iter() {
                        if snapshot.nodes[n].gpu.mem_bytes == mem {
                            idle = idle.saturating_sub(snapshot.nodes[n].idle);
                        }
                    }
                    idle
                })
                .collect()
        } else {
            self.type_names
                .iter()
                .map(|t| {
                    snapshot
                        .nodes
                        .iter()
                        .filter(|n| n.gpu.name == *t)
                        .map(|n| idle_mask[n.id])
                        .sum::<u32>()
                })
                .collect()
        };

        // Build the ILP.
        let mut cands: Vec<Candidate> = Vec::new();
        for (ji, job) in pending.iter().enumerate() {
            cands.extend(self.candidates(ji, &job.spec, job.attempts, snapshot, &idle_per_type));
        }
        let items: Vec<ilp::Item> = cands
            .iter()
            .map(|c| {
                let mut usage = vec![0u32; self.type_names.len()];
                usage[c.type_idx] = c.n;
                ilp::Item { group: c.job_idx, value: c.value, usage }
            })
            .collect();
        let problem =
            ilp::Problem { n_groups: pending.len(), capacity: idle_per_type, items };
        let sol = ilp::solve(&problem, self.node_limit);
        round.work_units = sol.nodes_explored;

        // Realize assignments (on the drain-masked idle capacity).
        let mut idle: Vec<u32> = idle_mask;
        for (ji, choice) in sol.chosen.iter().enumerate() {
            let Some(item_idx) = choice else { continue };
            let c = &cands[*item_idx];
            let Some(parts) = self.realize(c.type_idx, c.n, &mut idle, snapshot) else {
                continue;
            };
            let alloc = Allocation { job: pending[ji].spec.id, parts };
            let (placement, gpu) = derive_placement(&alloc, c.par, snapshot);
            let will_oom = crate::memory::exact::exact_peak_bytes(
                &pending[ji].spec.model,
                &pending[ji].spec.train,
                c.par,
            ) > gpu.mem_bytes;
            round.decisions.push(Decision {
                job: pending[ji].spec.id,
                alloc,
                par: c.par,
                placement,
                gpu,
                will_oom,
            });
        }
        round
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::model_by_name;
    use crate::config::{real_testbed, sia_sim};
    use crate::job::JobSpec;

    fn pending(id: u64, model: &str, batch: u32) -> PendingJob {
        PendingJob {
            spec: JobSpec::new(id, model_by_name(model).unwrap(), batch, 10_000, 0.0),
            attempts: 0,
        }
    }

    fn q(jobs: Vec<PendingJob>) -> PendingQueue {
        PendingQueue::from(jobs)
    }

    #[test]
    fn schedules_one_job_memory_safely() {
        let spec = sia_sim();
        let mut s = Sia::new(&spec);
        let snap = ClusterState::from_spec(&spec);
        let view = ClusterView::build(&snap);
        let round = s.schedule(&q(vec![pending(1, "gpt2-350m", 8)]), &view, 0.0);
        assert_eq!(round.decisions.len(), 1);
        // goodput-optimal for a small model: the A100 pool, which also
        // happens to be memory-safe for this job
        assert!(!round.decisions[0].will_oom);
    }

    #[test]
    fn respects_capacity_with_many_jobs() {
        let spec = real_testbed();
        let mut s = Sia::new(&spec);
        let snap = ClusterState::from_spec(&spec);
        let view = ClusterView::build(&snap);
        let jobs: Vec<PendingJob> = (0..6).map(|i| pending(i, "gpt2-350m", 8)).collect();
        let round = s.schedule(&q(jobs), &view, 0.0);
        let mut orch = crate::cluster::Orchestrator::new(&spec);
        for d in &round.decisions {
            orch.allocate(d.alloc.clone()).expect("capacity respected");
        }
        assert!(orch.check_conservation());
    }

    #[test]
    fn big_model_lands_on_big_gpus() {
        let spec = real_testbed();
        let mut s = Sia::new(&spec);
        let snap = ClusterState::from_spec(&spec);
        let view = ClusterView::build(&snap);
        let round = s.schedule(&q(vec![pending(1, "gpt2-7b", 2)]), &view, 0.0);
        assert_eq!(round.decisions.len(), 1);
        let d = &round.decisions[0];
        assert!(d.gpu.mem_bytes >= 40 * crate::config::GIB);
    }

    #[test]
    fn naive_sizing_can_oom_then_adapts_on_retry() {
        // Sia has no MARP: a 350M/b8 job sized t=1 against a 2080Ti (11 GB)
        // would OOM (measured peak ~12.8 GB). With only 2080Ti available the
        // decision must carry will_oom; after retries t grows and it fits.
        use crate::config::cluster_file::parse_cluster;
        // Only 2 GPUs exist, so data parallelism cannot rescue the naive
        // sizing (with 8 idle GPUs Sia's adaptive d=8 happens to fit).
        let spec = parse_cluster("cluster t\nnode RTX2080Ti x2 pcie\n").unwrap();
        let mut s = Sia::new(&spec);
        let snap = ClusterState::from_spec(&spec);
        let view = ClusterView::build(&snap);
        let round0 = s.schedule(&q(vec![pending(1, "gpt2-350m", 8)]), &view, 0.0);
        assert_eq!(round0.decisions.len(), 1);
        assert!(round0.decisions[0].will_oom, "naive t=1 on 11 GB must OOM");
        let retried = PendingJob {
            spec: JobSpec::new(1, model_by_name("gpt2-350m").unwrap(), 8, 10_000, 0.0),
            attempts: 3,
        };
        let round3 = s.schedule(&q(vec![retried]), &view, 100.0);
        if let Some(d) = round3.decisions.first() {
            assert!(!d.will_oom, "after retries the user sizes memory properly");
        }
    }

    #[test]
    fn ilp_never_assigns_capacity_on_draining_node() {
        // Only node 2 (4×A800) has idle GPUs. Drain-blind Sia places the
        // job there; once the node drains its capacity must vanish from the
        // ILP's per-type totals and the job stays queued.
        let spec = real_testbed();
        let mut snap = ClusterState::from_spec(&spec);
        for n in &mut snap.nodes {
            if n.id != 2 {
                n.idle = 0;
            }
        }
        let blind = ClusterView::build(&snap);
        let mut s = Sia::new(&spec);
        let round = s.schedule(&q(vec![pending(1, "gpt2-350m", 4)]), &blind, 0.0);
        assert_eq!(round.decisions.len(), 1);
        assert!(round.decisions[0].alloc.parts.iter().all(|&(n, _)| n == 2));

        let view = ClusterView::build(&snap).with_draining([2].into_iter().collect());
        let round = s.schedule(&q(vec![pending(1, "gpt2-350m", 4)]), &view, 0.0);
        assert!(round.decisions.is_empty(), "capacity on a draining node is not schedulable");
    }

    #[test]
    fn work_grows_superlinearly_with_jobs() {
        let spec = sia_sim();
        let snap = ClusterState::from_spec(&spec);
        let view = ClusterView::build(&snap);
        let run = |n: usize| {
            let mut s = Sia::new(&spec);
            let jobs: Vec<PendingJob> = (0..n as u64)
                .map(|i| {
                    let model = ["gpt2-125m", "gpt2-350m", "gpt2-760m"][i as usize % 3];
                    pending(i, model, 4 + (i % 3) as u32 * 4)
                })
                .collect();
            s.schedule(&q(jobs), &view, 0.0).work_units
        };
        let w4 = run(4);
        let w16 = run(16);
        // superlinear: 4x jobs → much more than 4x nodes
        assert!(w16 > 8 * w4, "w4={w4} w16={w16}");
    }

    /// Index-served and scan-served per-type idle totals must yield the
    /// same decisions and work units — on a topology where memory size
    /// identifies the type (sia_sim: 11/24/40 GB) *and* on one where it
    /// does not (real_testbed: A100-80G vs A800-80G both 80 GB, which
    /// forces the indexed path to fall back to the scan).
    #[test]
    fn indexed_idle_totals_match_the_reference_scan() {
        for spec in [sia_sim(), real_testbed()] {
            let snap = ClusterState::from_spec(&spec);
            // Partially used + one draining node, so the totals are
            // non-trivial in every class.
            let mut snap = snap;
            snap.nodes[0].idle = snap.nodes[0].idle.saturating_sub(1);
            let view = ClusterView::build(&snap).with_draining([1].into_iter().collect());
            let jobs: Vec<PendingJob> = (0..4)
                .map(|i| pending(i, ["gpt2-125m", "gpt2-350m"][i as usize % 2], 4))
                .collect();
            let mut indexed = Sia::new(&spec);
            let mut naive = Sia::new(&spec);
            naive.indexed = false;
            let ri = indexed.schedule(&q(jobs.clone()), &view, 0.0);
            let rn = naive.schedule(&q(jobs), &view, 0.0);
            assert_eq!(ri.work_units, rn.work_units, "{}", spec.name);
            let fp = |r: &SchedRound| -> Vec<(u64, Vec<(usize, u32)>, u32, u32)> {
                r.decisions
                    .iter()
                    .map(|d| (d.job, d.alloc.parts.clone(), d.par.d, d.par.t))
                    .collect()
            };
            assert_eq!(fp(&ri), fp(&rn), "{}", spec.name);
        }
        assert!(Sia::new(&sia_sim()).mem_identifies_type, "sia_sim must exercise the index path");
        assert!(
            !Sia::new(&real_testbed()).mem_identifies_type,
            "real_testbed must exercise the ambiguity fallback"
        );
    }

    #[test]
    fn empty_pending_is_cheap() {
        let spec = sia_sim();
        let mut s = Sia::new(&spec);
        let snap = ClusterState::from_spec(&spec);
        let view = ClusterView::build(&snap);
        let round = s.schedule(&q(vec![]), &view, 0.0);
        assert_eq!(round.work_units, 0);
        assert!(round.decisions.is_empty());
    }
}
