//! Order-preserving pending-job queue: an arrival-ordered slab plus an
//! id → slot map.
//!
//! The engine used a plain `Vec<PendingJob>`, which made every dispatch
//! removal and cancel an O(queue) `position` + `Vec::remove`. The slab
//! keeps jobs in arrival order (FCFS iteration is unchanged) while removal
//! by id is O(1): the slot is tombstoned and the vector compacted only when
//! more than half the slots are holes, so removal stays amortized O(1)
//! without ever reordering live entries.

use super::PendingJob;
use crate::job::JobId;
use std::collections::HashMap;

/// FCFS pending queue with O(1) push, O(1) removal by id, and
/// arrival-order iteration.
#[derive(Debug, Default)]
pub struct PendingQueue {
    slots: Vec<Option<PendingJob>>,
    by_id: HashMap<JobId, usize>,
}

impl PendingQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.by_id.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_id.is_empty()
    }

    pub fn contains(&self, id: JobId) -> bool {
        self.by_id.contains_key(&id)
    }

    pub fn get(&self, id: JobId) -> Option<&PendingJob> {
        self.by_id.get(&id).and_then(|&slot| self.slots[slot].as_ref())
    }

    /// Append at the back of the arrival order.
    pub fn push(&mut self, job: PendingJob) {
        debug_assert!(
            !self.by_id.contains_key(&job.spec.id),
            "duplicate pending job {}",
            job.spec.id
        );
        // Defensive in release builds: a duplicate id would otherwise leak
        // its old slot forever.
        if let Some(&slot) = self.by_id.get(&job.spec.id) {
            self.slots[slot] = None;
        }
        self.by_id.insert(job.spec.id, self.slots.len());
        self.slots.push(Some(job));
    }

    /// Remove by id in O(1) (amortized, counting deferred compaction).
    pub fn remove(&mut self, id: JobId) -> Option<PendingJob> {
        let slot = self.by_id.remove(&id)?;
        let job = self.slots[slot].take();
        debug_assert!(job.is_some(), "id map pointed at an empty slot");
        self.maybe_compact();
        job
    }

    /// Iterate live jobs in arrival order.
    pub fn iter(&self) -> impl DoubleEndedIterator<Item = &PendingJob> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }

    /// Take every job out, in arrival order.
    pub fn drain(&mut self) -> Vec<PendingJob> {
        self.by_id.clear();
        self.slots.drain(..).flatten().collect()
    }

    fn maybe_compact(&mut self) {
        if self.slots.len() >= 64 && self.by_id.len() * 2 < self.slots.len() {
            let live: Vec<PendingJob> = std::mem::take(&mut self.slots)
                .into_iter()
                .flatten()
                .collect();
            self.by_id = live
                .iter()
                .enumerate()
                .map(|(i, j)| (j.spec.id, i))
                .collect();
            self.slots = live.into_iter().map(Some).collect();
        }
    }
}

impl From<Vec<PendingJob>> for PendingQueue {
    fn from(jobs: Vec<PendingJob>) -> Self {
        let mut q = Self::new();
        for j in jobs {
            q.push(j);
        }
        q
    }
}

impl FromIterator<PendingJob> for PendingQueue {
    fn from_iter<T: IntoIterator<Item = PendingJob>>(iter: T) -> Self {
        let mut q = Self::new();
        for j in iter {
            q.push(j);
        }
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::model_by_name;
    use crate::job::JobSpec;

    fn job(id: u64) -> PendingJob {
        PendingJob {
            spec: JobSpec::new(id, model_by_name("gpt2-125m").unwrap(), 4, 100, 0.0),
            attempts: 0,
        }
    }

    #[test]
    fn fcfs_order_survives_removals() {
        let mut q = PendingQueue::new();
        for id in 0..6 {
            q.push(job(id));
        }
        assert_eq!(q.len(), 6);
        assert!(q.remove(2).is_some());
        assert!(q.remove(0).is_some());
        assert!(q.remove(99).is_none());
        let order: Vec<u64> = q.iter().map(|p| p.spec.id).collect();
        assert_eq!(order, vec![1, 3, 4, 5]);
        // Re-queued jobs go to the back, like the old Vec::push.
        q.push(job(0));
        let order: Vec<u64> = q.iter().map(|p| p.spec.id).collect();
        assert_eq!(order, vec![1, 3, 4, 5, 0]);
        assert!(q.contains(0));
        assert_eq!(q.get(3).unwrap().spec.id, 3);
    }

    #[test]
    fn compaction_preserves_order_and_lookup() {
        let mut q = PendingQueue::new();
        for id in 0..200 {
            q.push(job(id));
        }
        for id in 0..150 {
            assert!(q.remove(id).is_some(), "remove {id}");
        }
        assert_eq!(q.len(), 50);
        assert!(q.slots.len() < 200, "compaction must have fired");
        let order: Vec<u64> = q.iter().map(|p| p.spec.id).collect();
        assert_eq!(order, (150..200).collect::<Vec<u64>>());
        for id in 150..200 {
            assert_eq!(q.get(id).unwrap().spec.id, id);
        }
        assert!(q.remove(175).is_some());
        assert!(!q.contains(175));
    }

    #[test]
    fn drain_empties_in_order() {
        let mut q: PendingQueue = (0..5).map(job).collect();
        q.remove(1);
        let drained: Vec<u64> = q.drain().into_iter().map(|p| p.spec.id).collect();
        assert_eq!(drained, vec![0, 2, 3, 4]);
        assert!(q.is_empty());
        assert_eq!(q.iter().count(), 0);
    }
}
