//! Opportunistic scheduling baseline (Lyra [23]-style, §V.A.c):
//!
//! * **FCFS** — jobs are served strictly in arrival order;
//! * **fastest-first** — idle resources on the highest-compute nodes are
//!   greedily handed to the newest job, with no regard for memory size
//!   (the "prioritizes nodes with higher computational power" policy);
//! * **user-specified GPU counts** — there is no MARP; the request is what a
//!   developer would guess: pick the smallest tensor-parallel degree that
//!   fits the *largest* GPU type in the cluster, then data-parallel up to a
//!   small budget. When the greedy placement lands on *smaller* GPUs than
//!   the guess assumed, the job OOMs, is requeued, and the user "tries
//!   again" with a doubled tensor-parallel degree — the trial-and-error loop
//!   the paper's motivation describes.

use super::{derive_placement, Decision, PendingJob, PendingQueue, SchedRound, Scheduler};
use crate::cluster::{Allocation, ClusterState, ClusterView};
use crate::config::ClusterSpec;
use crate::job::JobSpec;
use crate::memory::{exact::exact_peak_bytes, fits, Parallelism};

/// GPU budget a "user" requests per job by default (the paper's NewWorkload
/// jobs are mostly small; users ask for a conservative fixed count).
const USER_GPU_BUDGET: u32 = 4;

pub struct Opportunistic {
    /// Largest GPU memory in the cluster — what users size their guess to.
    max_gpu_mem: u64,
    max_tp: u32,
    /// Use the capacity index for the nothing-idle early exit and compute
    /// the fastest-first node order once per round instead of once per job
    /// (default). `false` selects the reference per-job sort, kept as the
    /// differential-test oracle (`benches/bench_sched.rs`).
    pub indexed: bool,
}

impl Opportunistic {
    pub fn new(spec: &ClusterSpec) -> Self {
        Self {
            max_gpu_mem: spec.max_gpu_mem(),
            max_tp: spec.max_gpus_per_node().max(1),
            indexed: true,
        }
    }

    /// The user's GPU request for a job at retry `attempts`.
    ///
    /// The naive developer heuristic from the paper's motivation: size
    /// tensor parallelism so the *model states* (`20W/t`) fit the biggest
    /// GPU in the cluster — forgetting activations and that the greedy
    /// placement may land on smaller GPUs. Each OOM retry doubles `t`
    /// ("insufficient allocation may cause OOM errors during training ...
    /// extensive trial and error").
    pub fn user_request(&self, job: &JobSpec, attempts: u32) -> Option<Parallelism> {
        let static_bytes = 20.0 * job.model.param_count() as f64;
        let mut t = 1u32;
        while t <= self.max_tp {
            if static_bytes / t as f64 <= self.max_gpu_mem as f64 {
                break;
            }
            t *= 2;
        }
        if t > self.max_tp {
            return None; // hopeless even on the biggest GPU
        }
        // OOM retries double t (capped). The final fallback also checks the
        // full memory model — after enough failures even a naive user reads
        // the docs.
        t = (t << attempts.min(8)).min(self.max_tp.next_power_of_two());
        if attempts >= 3 {
            let mut t2 = t;
            while t2 <= self.max_tp
                && !fits(&job.model, &job.train, Parallelism::new(1, t2), self.max_gpu_mem)
            {
                t2 *= 2;
            }
            t = t2.min(self.max_tp.next_power_of_two());
        }
        let d = (USER_GPU_BUDGET / t).max(1).min(job.train.global_batch.max(1));
        Some(Parallelism::new(d, t))
    }
}

impl Scheduler for Opportunistic {
    fn name(&self) -> &'static str {
        "opportunistic"
    }

    /// Elasticity: users size their guesses to the biggest GPU around.
    fn cluster_changed(&mut self, state: &ClusterState) {
        let spec = state.to_spec("scaled");
        self.max_gpu_mem = spec.max_gpu_mem();
        self.max_tp = spec.max_gpus_per_node().max(1);
    }

    fn schedule(
        &mut self,
        pending: &PendingQueue,
        view: &ClusterView<'_>,
        _now: f64,
    ) -> SchedRound {
        // Memory-oblivious fastest-first is a full-scan policy by design;
        // placement reads the raw state (the capacity index orders by
        // memory class, which this baseline deliberately ignores). The
        // index still answers one question cheaply: is anything idle at
        // all? When not, every job's candidate list is empty — charge the
        // same abstract work the scans would have and skip them.
        let snapshot = view.state();
        let mut round = SchedRound::default();
        if self.indexed && view.idle_gpus_with_mem(0) == 0 {
            for job in pending.iter() {
                if self.user_request(&job.spec, job.attempts).is_some() {
                    round.work_units += 1;
                }
            }
            return round;
        }
        let mut idle: Vec<u32> = snapshot.nodes.iter().map(|n| n.idle).collect();
        // Fastest-first order over the whole topology, computed once per
        // round: the per-job candidate list is this order filtered by
        // remaining idle, so re-sorting per job (the reference path below)
        // only repeats work.
        let full_order: Option<Vec<usize>> = self.indexed.then(|| {
            let mut v: Vec<usize> = (0..snapshot.nodes.len()).collect();
            v.sort_by(|&a, &b| {
                let na = &snapshot.nodes[a];
                let nb = &snapshot.nodes[b];
                nb.gpu.peak_tflops.partial_cmp(&na.gpu.peak_tflops).unwrap().then(a.cmp(&b))
            });
            v
        });

        for job in pending.iter() {
            let Some(par) = self.user_request(&job.spec, job.attempts) else {
                continue;
            };
            let want = par.gpus();
            // Fastest-first greedy: nodes ordered by peak TFLOPs desc, ties
            // in listing order. No memory filter and no locality awareness —
            // that is the point: allocations fragment across nodes, paying
            // the cross-node communication the paper's Node(4,40) example
            // warns about, while HAS's best-fit keeps jobs on single nodes.
            // Draining nodes are excluded: even a memory-oblivious user's
            // scheduler refuses to land new work on retiring hardware.
            let order: Vec<usize> = match &full_order {
                Some(fo) => fo
                    .iter()
                    .copied()
                    .filter(|&i| idle[i] > 0 && !view.is_draining(i))
                    .collect(),
                None => {
                    let mut order: Vec<usize> = (0..snapshot.nodes.len())
                        .filter(|&i| idle[i] > 0 && !view.is_draining(i))
                        .collect();
                    order.sort_by(|&a, &b| {
                        let na = &snapshot.nodes[a];
                        let nb = &snapshot.nodes[b];
                        nb.gpu
                            .peak_tflops
                            .partial_cmp(&na.gpu.peak_tflops)
                            .unwrap()
                            .then(a.cmp(&b))
                    });
                    order
                }
            };
            round.work_units += order.len() as u64 + 1;

            let mut parts: Vec<(usize, u32)> = Vec::new();
            let mut left = want;
            for id in order {
                if left == 0 {
                    break;
                }
                let take = idle[id].min(left);
                if take > 0 {
                    parts.push((id, take));
                    left -= take;
                }
            }
            if left > 0 {
                // Not enough idle GPUs anywhere: job waits (FCFS blocks the
                // queue head only in arrival order; we still try later jobs,
                // matching Lyra's work-conserving greedy).
                continue;
            }
            for &(id, c) in &parts {
                idle[id] -= c;
            }
            let alloc = Allocation { job: job.spec.id, parts };
            let (placement, gpu) = derive_placement(&alloc, par, snapshot);
            // Ground truth: does the exact peak fit the smallest GPU used?
            let will_oom =
                exact_peak_bytes(&job.spec.model, &job.spec.train, par) > gpu.mem_bytes;
            round.decisions.push(Decision {
                job: job.spec.id,
                alloc,
                par,
                placement,
                gpu,
                will_oom,
            });
        }
        round
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::model_by_name;
    use crate::config::{real_testbed, sia_sim, GIB};
    use crate::job::JobSpec;

    fn pending(id: u64, model: &str, batch: u32) -> PendingJob {
        PendingJob {
            spec: JobSpec::new(id, model_by_name(model).unwrap(), batch, 10_000, 0.0),
            attempts: 0,
        }
    }

    fn q(jobs: Vec<PendingJob>) -> PendingQueue {
        PendingQueue::from(jobs)
    }

    #[test]
    fn user_request_small_model_is_t1() {
        let o = Opportunistic::new(&real_testbed());
        let j = pending(1, "gpt2-350m", 8);
        let par = o.user_request(&j.spec, 0).unwrap();
        assert_eq!(par.t, 1);
        assert!(par.d >= 1);
    }

    #[test]
    fn user_request_grows_t_on_retry() {
        let o = Opportunistic::new(&real_testbed());
        let j = pending(1, "gpt2-7b", 2);
        let p0 = o.user_request(&j.spec, 0).unwrap();
        let p1 = o.user_request(&j.spec, 1).unwrap();
        assert!(p1.t >= 2 * p0.t || p1.t == o.max_tp.next_power_of_two());
    }

    #[test]
    fn greedy_prefers_fastest_nodes() {
        let spec = sia_sim();
        let mut o = Opportunistic::new(&spec);
        let snap = ClusterState::from_spec(&spec);
        let view = ClusterView::build(&snap);
        let round = o.schedule(&q(vec![pending(1, "gpt2-350m", 4)]), &view, 0.0);
        assert_eq!(round.decisions.len(), 1);
        let d = &round.decisions[0];
        // A100 nodes (312 TFLOPs) must be chosen over 2080Ti/RTX6000.
        for &(node, _) in &d.alloc.parts {
            assert_eq!(snap.nodes[node].gpu.name, "A100-40G");
        }
    }

    #[test]
    fn memory_oblivious_placement_can_oom() {
        // A 7B model guessed against the 80G card, but scheduled onto 40G
        // A100s (fastest-first ties broken by idle) → OOM expected when the
        // effective allocation is 40G with t sized for 80G.
        let spec = sia_sim(); // fastest GPUs here are A100-40G only
        let mut o = Opportunistic::new(&spec);
        let snap = ClusterState::from_spec(&spec);
        let view = ClusterView::build(&snap);
        let round = o.schedule(&q(vec![pending(1, "gpt2-7b", 2)]), &view, 0.0);
        assert_eq!(round.decisions.len(), 1);
        // user sized t for 40G max (sia_sim max = 40G): t s.t. fits 40G = 4
        // ... with only 8-GPU budget d=2; placement ok. If it fit, fine; the
        // point is the decision carries a truthful will_oom flag either way.
        let d = &round.decisions[0];
        let measured =
            exact_peak_bytes(&model_by_name("gpt2-7b").unwrap(), &crate::memory::TrainConfig { global_batch: 2 }, d.par);
        assert_eq!(d.will_oom, measured > d.gpu.mem_bytes);
    }

    #[test]
    fn oom_on_real_testbed_mixed_sizes() {
        // real testbed: max mem 80G. User sizes gpt2-2.7b t guess vs 80G →
        // t=1 fits 80G. Greedy fastest-first may pull 40G cards in
        // (same TFLOPs) → exact(2.7b, t=1) ≈ 54G+ > 40G → OOM.
        let spec = real_testbed();
        let mut o = Opportunistic::new(&spec);
        let snap = ClusterState::from_spec(&spec);
        let view = ClusterView::build(&snap);
        let round = o.schedule(&q(vec![pending(1, "gpt2-2.7b", 8)]), &view, 0.0);
        assert_eq!(round.decisions.len(), 1);
        let d = &round.decisions[0];
        if d.gpu.mem_bytes <= 40 * GIB {
            assert!(d.will_oom, "2.7B at t={} on 40G must OOM", d.par.t);
        }
    }

    #[test]
    fn greedy_skips_draining_node() {
        // Only node 2 has idle GPUs. The drain-blind greedy lands there;
        // with node 2 draining, even this memory-oblivious baseline must
        // leave the job queued rather than place it on retiring hardware.
        let spec = real_testbed();
        let mut o = Opportunistic::new(&spec);
        let mut snap = ClusterState::from_spec(&spec);
        for n in &mut snap.nodes {
            if n.id != 2 {
                n.idle = 0;
            }
        }
        let blind = ClusterView::build(&snap);
        let round = o.schedule(&q(vec![pending(1, "gpt2-350m", 4)]), &blind, 0.0);
        assert_eq!(round.decisions.len(), 1);
        assert!(round.decisions[0].alloc.parts.iter().all(|&(n, _)| n == 2));

        let view = ClusterView::build(&snap).with_draining([2].into_iter().collect());
        let round = o.schedule(&q(vec![pending(1, "gpt2-350m", 4)]), &view, 0.0);
        assert!(round.decisions.is_empty());
    }

    #[test]
    fn waits_when_insufficient() {
        let spec = real_testbed();
        let mut o = Opportunistic::new(&spec);
        let mut snap = ClusterState::from_spec(&spec);
        for n in &mut snap.nodes {
            n.idle = 0;
        }
        let view = ClusterView::build(&snap);
        let round = o.schedule(&q(vec![pending(1, "gpt2-350m", 4)]), &view, 0.0);
        assert!(round.decisions.is_empty());
    }

    /// The once-per-round fastest-first order and the index-served empty
    /// early exit must not change a single decision or work unit relative
    /// to the reference per-job sort — including on a drained, partially
    /// used cluster and on a fully busy one.
    #[test]
    fn indexed_order_matches_the_reference_sort() {
        let fp = |r: &SchedRound| -> Vec<(u64, Vec<(usize, u32)>, u32, u32)> {
            r.decisions
                .iter()
                .map(|d| (d.job, d.alloc.parts.clone(), d.par.d, d.par.t))
                .collect()
        };
        for busy in [false, true] {
            for spec in [sia_sim(), real_testbed()] {
                let mut snap = ClusterState::from_spec(&spec);
                snap.nodes[0].idle = 0;
                if busy {
                    for n in &mut snap.nodes {
                        n.idle = 0;
                    }
                }
                let view =
                    ClusterView::build(&snap).with_draining([1].into_iter().collect());
                let jobs: Vec<PendingJob> = (0..5)
                    .map(|i| pending(i, ["gpt2-125m", "gpt2-350m"][i as usize % 2], 4))
                    .collect();
                let mut indexed = Opportunistic::new(&spec);
                let mut naive = Opportunistic::new(&spec);
                naive.indexed = false;
                let ri = indexed.schedule(&q(jobs.clone()), &view, 0.0);
                let rn = naive.schedule(&q(jobs), &view, 0.0);
                assert_eq!(ri.work_units, rn.work_units, "{} busy={busy}", spec.name);
                assert_eq!(fp(&ri), fp(&rn), "{} busy={busy}", spec.name);
            }
        }
    }
}
