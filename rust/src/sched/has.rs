//! HAS — Heterogeneity-Aware Scheduler (§IV.B, Algorithm 1).
//!
//! Two stages per job (Fig 3):
//!
//! 1. **Optimal plan retrieval** — walk MARP's priority-ordered plan list
//!    top-down; the first plan whose `(reqNum, reqSz)` the cluster can
//!    currently satisfy wins.
//! 2. **Heterogeneous resource scheduling** — Best-fit: among nodes whose
//!    GPU size ≥ the *fit size* (the smallest available GPU size ≥ reqSz),
//!    pick the one with the fewest idle GPUs that still covers the request
//!    (exactly-fitting nodes first). If no single node covers it, greedily
//!    take the node with the most idle GPUs, subtract, and repeat.
//!
//! The fit-size indirection is what makes HAS heterogeneity-aware: a job
//! needing 32 GB lands on 40 GB cards even when 80 GB cards are idle,
//! keeping the big cards for jobs that need them.
//!
//! **Execution strategies.** The same algorithm runs two ways:
//!
//! * `indexed == true` (default, the production hot path): Stage 1 is an
//!   O(log S) suffix-sum probe and Stage 2 an O(log n) bucket lookup
//!   against the [`CapacityOverlay`] — sub-linear in cluster size.
//! * `indexed == false`: the reference full-scan implementation
//!   ([`Has::allocate_one`]) over a cloned snapshot — kept as the
//!   differential-test oracle and the `bench_sched` baseline.
//!
//! Both strategies produce byte-identical decisions *and* identical
//! `work_units`: work units model the abstract Algorithm-1 effort (plan
//! probes + candidate-list sizes), deliberately independent of the
//! execution strategy, so simulated virtual-time trajectories do not shift
//! when the implementation gets faster. Real speed is measured in wall
//! clock by `benches/bench_sched.rs`.

use super::{derive_placement, Decision, PendingJob, PendingQueue, SchedRound, Scheduler};
use crate::cluster::{Allocation, CapacityOverlay, ClusterState, ClusterView, NodeId};
use crate::marp::{Marp, ResourcePlan};
use crate::memory::Parallelism;

/// The HAS scheduler. Owns a MARP instance (plans are recomputed per job and
/// memoized by (model, batch) key; scheduling rounds borrow from the cache —
/// no per-job plan-list clones).
pub struct Has {
    marp: Marp,
    plan_cache: std::collections::HashMap<(&'static str, u32), Vec<ResourcePlan>>,
    /// Work-unit accounting for the overhead comparison (Fig 5a): each node
    /// scan / plan check costs one unit.
    pub count_work: bool,
    /// Run Algorithm 1 against the capacity index (default). `false`
    /// selects the reference full-scan path for differential testing.
    pub indexed: bool,
}

impl Has {
    pub fn new(marp: Marp) -> Self {
        Self {
            marp,
            plan_cache: std::collections::HashMap::new(),
            count_work: true,
            indexed: true,
        }
    }

    pub fn marp(&self) -> &Marp {
        &self.marp
    }

    fn plans_for(&mut self, job: &PendingJob) -> &[ResourcePlan] {
        let key = (job.spec.model.name, job.spec.train.global_batch);
        let marp = &self.marp;
        self.plan_cache
            .entry(key)
            .or_insert_with(|| marp.plans(&job.spec.model, &job.spec.train))
    }

    /// Algorithm 1, reference implementation: full scans over a snapshot.
    /// Returns the chosen plan and allocation, or None when no plan is
    /// satisfiable right now. `work` accumulates scan steps.
    pub fn allocate_one(
        plans: &[ResourcePlan],
        snapshot: &ClusterState,
        work: &mut u64,
    ) -> Option<(ResourcePlan, Allocation)> {
        // Stage 1: first satisfiable plan (lines 1–10).
        let mut optimal: Option<&ResourcePlan> = None;
        for plan in plans {
            *work += 1;
            let ava = snapshot.idle_gpus_with_mem(plan.min_gpu_mem);
            if ava >= plan.n_gpus {
                optimal = Some(plan);
                break;
            }
        }
        let plan = optimal?;

        // Stage 2: best-fit / greedy packing (lines 11–36).
        let mut req_num = plan.n_gpus;
        let req_sz = plan.min_gpu_mem;
        let mut idle: Vec<u32> = snapshot.nodes.iter().map(|n| n.idle).collect();
        let mut parts: Vec<(usize, u32)> = Vec::new();

        while req_num > 0 {
            // fitSz = min available GPU size ≥ reqSz (line 14).
            let fit_sz = snapshot
                .nodes
                .iter()
                .filter(|n| idle[n.id] > 0 && n.gpu.mem_bytes >= req_sz)
                .map(|n| n.gpu.mem_bytes)
                .min()?; // none available → cannot happen after stage 1, but stay safe
            // NLst = nodes with gpusize ≥ fitSz, ascending idle (lines 15–16).
            let mut nlst: Vec<usize> = snapshot
                .nodes
                .iter()
                .filter(|n| idle[n.id] > 0 && n.gpu.mem_bytes >= fit_sz)
                .map(|n| n.id)
                .collect();
            nlst.sort_by_key(|&id| idle[id]);
            *work += nlst.len() as u64;

            // Best-fit: first node (fewest idle) that covers the request
            // (lines 18–26).
            if let Some(&id) = nlst.iter().find(|&&id| idle[id] >= req_num) {
                parts.push((id, req_num));
                idle[id] -= req_num;
                break;
            }
            // Greedy: node with the most idle GPUs (lines 29–33).
            let &id = nlst.last()?;
            let take = idle[id];
            parts.push((id, take));
            req_num -= take;
            idle[id] = 0;
        }
        debug_assert_eq!(parts.iter().map(|(_, c)| c).sum::<u32>(), plan.n_gpus);
        Some((plan.clone(), Allocation { job: 0, parts }))
    }

    /// Algorithm 1 against the capacity index: Stage 1 probes are suffix
    /// sums, Stage 2 best-fit/greedy are bucket range lookups. Successful
    /// placements are committed into `ov` (so later jobs in the round see
    /// reduced capacity); a packing that fails mid-way is rolled back.
    /// Decisions and `work` accounting are bit-identical to
    /// [`Has::allocate_one`].
    pub fn allocate_one_indexed(
        plans: &[ResourcePlan],
        ov: &mut CapacityOverlay<'_>,
        work: &mut u64,
    ) -> Option<(ResourcePlan, Allocation)> {
        // Stage 1: first satisfiable plan.
        let mut optimal: Option<&ResourcePlan> = None;
        for plan in plans {
            *work += 1;
            if ov.idle_with_mem(plan.min_gpu_mem) >= plan.n_gpus {
                optimal = Some(plan);
                break;
            }
        }
        let plan = optimal?;

        // Stage 2: best-fit / greedy packing.
        let mut req_num = plan.n_gpus;
        let req_sz = plan.min_gpu_mem;
        let mut parts: Vec<(NodeId, u32)> = Vec::new();
        fn rollback(ov: &mut CapacityOverlay<'_>, parts: &[(NodeId, u32)]) {
            for &(id, c) in parts {
                ov.untake(id, c);
            }
        }

        while req_num > 0 {
            let Some(fit_c) = ov.fit_class(req_sz) else {
                rollback(ov, &parts);
                return None;
            };
            // Work-unit parity: the reference path pays one unit per
            // candidate node (|NLst|) per packing iteration.
            *work += ov.avail_nodes(fit_c);

            if let Some((id, _)) = ov.best_fit(fit_c, req_num) {
                ov.take(id, req_num);
                parts.push((id, req_num));
                break;
            }
            let Some((id, idle)) = ov.most_idle(fit_c) else {
                rollback(ov, &parts);
                return None;
            };
            ov.take(id, idle);
            parts.push((id, idle));
            req_num -= idle;
        }
        debug_assert_eq!(parts.iter().map(|(_, c)| c).sum::<u32>(), plan.n_gpus);
        Some((plan.clone(), Allocation { job: 0, parts }))
    }

    /// Turn a chosen (plan, allocation) into a [`Decision`]. Shared by the
    /// indexed and naive execution paths so decision construction cannot
    /// drift between them — the differential gate depends on it.
    /// (`derive_placement` reads only static node fields, so passing the
    /// committed state here is equivalent to the round-local snapshot.)
    fn decide(
        job: crate::job::JobId,
        plan: &ResourcePlan,
        mut alloc: Allocation,
        state: &ClusterState,
    ) -> Decision {
        alloc.job = job;
        let (placement, gpu) = derive_placement(&alloc, plan.par, state);
        // Frenzy is memory-aware: the chosen plan always fits.
        let will_oom = plan.predicted_bytes > gpu.mem_bytes;
        Decision {
            job,
            alloc,
            par: Parallelism::new(plan.par.d, plan.par.t),
            placement,
            gpu,
            will_oom,
        }
    }
}

impl Scheduler for Has {
    fn name(&self) -> &'static str {
        "frenzy-has"
    }

    /// Elasticity: MARP's plan list depends on the GPU sizes present, so a
    /// NodeJoin/NodeLeave invalidates both the predictor and the memoized
    /// plans (a joined 80G node can make previously infeasible models
    /// feasible; a departed one can do the reverse).
    fn cluster_changed(&mut self, state: &ClusterState) {
        let spec = state.to_spec(self.marp.cluster().name.as_str());
        self.marp = Marp::new(spec, self.marp.config().clone());
        self.plan_cache.clear();
    }

    /// Index probe: a job is placeable iff any MARP plan's `(reqNum, reqSz)`
    /// is satisfied by the committed capacity — O(plans · log S), no
    /// allocation attempt, no snapshot.
    fn can_place(&mut self, job: &PendingJob, view: &ClusterView<'_>, _now: f64) -> bool {
        self.plans_for(job)
            .iter()
            .any(|p| view.idle_gpus_with_mem(p.min_gpu_mem) >= p.n_gpus)
    }

    fn schedule(
        &mut self,
        pending: &PendingQueue,
        view: &ClusterView<'_>,
        _now: f64,
    ) -> SchedRound {
        let mut round = SchedRound::default();
        if self.indexed {
            // Hot path: tentative placements layer into an overlay; nothing
            // cluster-sized is cloned.
            let mut ov = view.overlay();
            for job in pending.iter() {
                let mut work = 0u64;
                let placed = {
                    let plans = self.plans_for(job);
                    if plans.is_empty() {
                        // Infeasible on this cluster — admission should have
                        // rejected it; skip (the sim marks it Rejected).
                        continue;
                    }
                    Self::allocate_one_indexed(plans, &mut ov, &mut work)
                };
                if let Some((plan, alloc)) = placed {
                    round.decisions.push(Self::decide(job.spec.id, &plan, alloc, view.state()));
                }
                round.work_units += work.max(1);
            }
        } else {
            // Reference path: the pre-index implementation, full scans over
            // a cloned snapshot. Kept as the differential oracle. Draining
            // nodes are hidden by zeroing their idle counts in the clone —
            // the same capacity the indexed overlay pre-takes, so the two
            // paths keep producing byte-identical decisions and work units.
            let mut snap = view.state().clone();
            for &n in view.draining() {
                snap.nodes[n].idle = 0;
            }
            for job in pending.iter() {
                let mut work = 0u64;
                let placed = {
                    let plans = self.plans_for(job);
                    if plans.is_empty() {
                        continue;
                    }
                    Self::allocate_one(plans, &snap, &mut work)
                };
                if let Some((plan, alloc)) = placed {
                    // Track the tentative allocation in the local snapshot so
                    // later jobs in this round see reduced idle counts.
                    for &(node, count) in &alloc.parts {
                        snap.nodes[node].idle -= count;
                    }
                    round.decisions.push(Self::decide(job.spec.id, &plan, alloc, &snap));
                }
                round.work_units += work.max(1);
            }
        }
        round
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::model_by_name;
    use crate::config::{real_testbed, GIB};
    use crate::job::JobSpec;
    use crate::marp::Marp;

    fn pending(id: u64, model: &str, batch: u32) -> PendingJob {
        PendingJob {
            spec: JobSpec::new(id, model_by_name(model).unwrap(), batch, 10_000, 0.0),
            attempts: 0,
        }
    }

    fn q(jobs: Vec<PendingJob>) -> PendingQueue {
        PendingQueue::from(jobs)
    }

    fn has() -> Has {
        Has::new(Marp::with_defaults(real_testbed()))
    }

    #[test]
    fn schedules_small_job_without_oom() {
        let mut h = has();
        let snap = ClusterState::from_spec(&real_testbed());
        let view = ClusterView::build(&snap);
        let round = h.schedule(&q(vec![pending(1, "gpt2-350m", 4)]), &view, 0.0);
        assert_eq!(round.decisions.len(), 1);
        let d = &round.decisions[0];
        assert!(d.alloc.is_single_node(), "a small job must not span nodes: {:?}", d.alloc);
        assert_eq!(d.alloc.total_gpus(), d.par.gpus());
        assert!(!d.will_oom);
    }

    #[test]
    fn algorithm1_best_fit_prefers_tightest_small_gpu_node() {
        // Hand-built single plan: Job(1, 30 GiB). Fit size is 40G; among the
        // 40G nodes, the 1-GPU node (fewest idle) is the best fit — the 80G
        // nodes must be left alone even though they are idle.
        use crate::marp::ResourcePlan;
        let plan = ResourcePlan {
            par: crate::memory::Parallelism::new(1, 1),
            n_gpus: 1,
            min_gpu_mem: 30 * GIB,
            predicted_bytes: 28 * GIB,
            est_samples_per_sec: 1.0,
            est_efficiency: 1.0,
            score: 1.0,
        };
        let snap = ClusterState::from_spec(&real_testbed());
        let mut work = 0;
        let (_, alloc) =
            Has::allocate_one(std::slice::from_ref(&plan), &snap, &mut work).expect("place");
        assert_eq!(alloc.parts, vec![(1usize, 1u32)], "must pick the 1-GPU A100-40 node");
        assert!(work > 0);
        // The indexed path must agree exactly, including work units.
        let view = ClusterView::build(&snap);
        let mut ov = view.overlay();
        let mut work_idx = 0;
        let (_, alloc_idx) =
            Has::allocate_one_indexed(std::slice::from_ref(&plan), &mut ov, &mut work_idx)
                .expect("place");
        assert_eq!(alloc_idx.parts, alloc.parts);
        assert_eq!(work_idx, work);
    }

    #[test]
    fn algorithm1_paper_job_2_32_takes_40g_node() {
        // §IV.B example: Job(2, 32G) should land on the 40G node, not 80G.
        use crate::marp::ResourcePlan;
        let plan = ResourcePlan {
            par: crate::memory::Parallelism::new(2, 1),
            n_gpus: 2,
            min_gpu_mem: 32 * GIB,
            predicted_bytes: 31 * GIB,
            est_samples_per_sec: 1.0,
            est_efficiency: 1.0,
            score: 1.0,
        };
        let snap = ClusterState::from_spec(&real_testbed());
        let mut work = 0;
        let (_, alloc) =
            Has::allocate_one(std::slice::from_ref(&plan), &snap, &mut work).expect("place");
        assert_eq!(alloc.parts.len(), 1);
        let (node, count) = alloc.parts[0];
        assert_eq!(count, 2);
        assert_eq!(snap.nodes[node].gpu.mem_bytes, 40 * GIB, "best-fit → 40G node: {alloc:?}");
    }

    #[test]
    fn big_job_lands_on_80g() {
        let mut h = has();
        let snap = ClusterState::from_spec(&real_testbed());
        let view = ClusterView::build(&snap);
        let round = h.schedule(&q(vec![pending(1, "gpt2-7b", 2)]), &view, 0.0);
        assert_eq!(round.decisions.len(), 1);
        let d = &round.decisions[0];
        // 7B needs eight 40G GPUs (only 3 exist) or four 80G: the first
        // satisfiable plan uses 80G cards.
        assert!(d.gpu.mem_bytes >= 40 * GIB);
        assert_eq!(d.alloc.total_gpus(), d.par.gpus());
        assert!(!d.will_oom);
    }

    #[test]
    fn round_respects_capacity_across_jobs() {
        let mut h = has();
        let snap = ClusterState::from_spec(&real_testbed());
        let view = ClusterView::build(&snap);
        let jobs: Vec<PendingJob> = (0..8).map(|i| pending(i, "gpt2-350m", 8)).collect();
        let round = h.schedule(&q(jobs), &view, 0.0);
        // Apply all decisions to a fresh orchestrator: must never overdraw.
        let mut orch = crate::cluster::Orchestrator::new(&real_testbed());
        for d in &round.decisions {
            orch.allocate(d.alloc.clone()).expect("no overdraw");
        }
        assert!(orch.check_conservation());
    }

    #[test]
    fn queues_when_cluster_full() {
        let mut h = has();
        let mut snap = ClusterState::from_spec(&real_testbed());
        for n in &mut snap.nodes {
            n.idle = 0;
        }
        let view = ClusterView::build(&snap);
        let round = h.schedule(&q(vec![pending(1, "gpt2-350m", 4)]), &view, 0.0);
        assert!(round.decisions.is_empty());
    }

    #[test]
    fn falls_through_to_lower_priority_plan() {
        // Occupy the A800 node so only scattered GPUs remain; HAS must pick
        // a satisfiable (possibly multi-node or smaller) plan instead of the
        // top one.
        let mut h = has();
        let mut snap = ClusterState::from_spec(&real_testbed());
        snap.nodes[2].idle = 0; // 4×A800 taken
        let view = ClusterView::build(&snap);
        let round = h.schedule(&q(vec![pending(1, "gpt2-1.3b", 8)]), &view, 0.0);
        assert_eq!(round.decisions.len(), 1);
        let d = &round.decisions[0];
        assert!(!d.will_oom);
        assert!(d.alloc.total_gpus() <= 7);
    }

    #[test]
    fn multi_node_greedy_when_no_single_node_fits() {
        // Ask for more 80G GPUs than any single node has.
        let marp = Marp::with_defaults(real_testbed());
        let m = model_by_name("gpt2-1.3b").unwrap();
        let plans = marp.plans(&m, &crate::memory::TrainConfig { global_batch: 32 });
        let snap = ClusterState::from_spec(&real_testbed());
        // find a plan requiring > 4 GPUs (bigger than the largest node)
        if let Some(plan) = plans.iter().find(|p| p.n_gpus > 4) {
            let mut work = 0;
            let got = Has::allocate_one(std::slice::from_ref(plan), &snap, &mut work);
            if let Some((_, alloc)) = got {
                assert!(alloc.parts.len() > 1);
                assert_eq!(alloc.total_gpus(), plan.n_gpus);
                // The indexed path packs the exact same parts.
                let view = ClusterView::build(&snap);
                let mut ov = view.overlay();
                let mut w2 = 0;
                let (_, alloc2) =
                    Has::allocate_one_indexed(std::slice::from_ref(plan), &mut ov, &mut w2)
                        .expect("place");
                assert_eq!(alloc2.parts, alloc.parts);
                assert_eq!(w2, work);
            }
        }
    }

    #[test]
    fn indexed_and_naive_rounds_are_identical() {
        let snap = ClusterState::from_spec(&real_testbed());
        let view = ClusterView::build(&snap);
        let jobs: Vec<PendingJob> = (0..10)
            .map(|i| {
                let m = ["gpt2-125m", "gpt2-350m", "gpt2-760m", "gpt2-1.3b", "gpt2-7b"]
                    [i as usize % 5];
                pending(i, m, 2 + (i % 4) as u32 * 2)
            })
            .collect();
        let mut hi = has();
        let mut hn = has();
        hn.indexed = false;
        let ri = hi.schedule(&q(jobs.clone()), &view, 0.0);
        let rn = hn.schedule(&q(jobs), &view, 0.0);
        assert_eq!(ri.work_units, rn.work_units);
        assert_eq!(ri.decisions.len(), rn.decisions.len());
        for (a, b) in ri.decisions.iter().zip(&rn.decisions) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.alloc.parts, b.alloc.parts);
            assert_eq!(a.par, b.par);
            assert_eq!(a.will_oom, b.will_oom);
            assert_eq!(a.gpu, b.gpu);
        }
    }

    #[test]
    fn drain_aware_has_avoids_retiring_node_blind_best_fit_picks() {
        // A 4×(50 GiB) request: best-fit on the full testbed picks node 2,
        // the only single node with four 80G GPUs. When node 2 is in
        // graceful drain the same request must split across the two
        // 2×A100-80 nodes instead — and both execution strategies must
        // pack the identical parts with identical work units.
        use crate::marp::ResourcePlan;
        let plan = ResourcePlan {
            par: crate::memory::Parallelism::new(4, 1),
            n_gpus: 4,
            min_gpu_mem: 50 * GIB,
            predicted_bytes: 48 * GIB,
            est_samples_per_sec: 1.0,
            est_efficiency: 1.0,
            score: 1.0,
        };
        let snap = ClusterState::from_spec(&real_testbed());
        let mut work = 0;
        let (_, blind) =
            Has::allocate_one(std::slice::from_ref(&plan), &snap, &mut work).expect("place");
        assert_eq!(blind.parts, vec![(2usize, 4u32)], "drain-blind best-fit → node 2");

        let view = ClusterView::build(&snap).with_draining([2].into_iter().collect());
        let mut drained = snap.clone();
        for &n in view.draining() {
            drained.nodes[n].idle = 0;
        }
        let mut w_naive = 0;
        let (_, naive) = Has::allocate_one(std::slice::from_ref(&plan), &drained, &mut w_naive)
            .expect("must place around the drain");
        assert!(naive.parts.iter().all(|&(n, _)| n != 2), "landed on draining node: {naive:?}");
        assert_eq!(naive.total_gpus(), 4, "greedy spill across the A100-80 nodes");
        let mut ov = view.overlay();
        let mut w_idx = 0;
        let (_, idx) = Has::allocate_one_indexed(std::slice::from_ref(&plan), &mut ov, &mut w_idx)
            .expect("must place around the drain");
        assert_eq!(idx.parts, naive.parts);
        assert_eq!(w_idx, w_naive);
    }

    #[test]
    fn schedule_queues_rather_than_land_on_draining_node() {
        // Only node 2 has idle GPUs. A drain-blind scheduler places the job
        // there; once node 2 drains, HAS must hold the job in the queue
        // instead of landing it on retiring hardware.
        let mut snap = ClusterState::from_spec(&real_testbed());
        for n in &mut snap.nodes {
            if n.id != 2 {
                n.idle = 0;
            }
        }
        let blind = ClusterView::build(&snap);
        let round = has().schedule(&q(vec![pending(1, "gpt2-350m", 4)]), &blind, 0.0);
        assert_eq!(round.decisions.len(), 1);
        assert!(round.decisions[0].alloc.parts.iter().all(|&(n, _)| n == 2));

        for indexed in [true, false] {
            let view = ClusterView::build(&snap).with_draining([2].into_iter().collect());
            let mut h = has();
            h.indexed = indexed;
            let round = h.schedule(&q(vec![pending(1, "gpt2-350m", 4)]), &view, 0.0);
            assert!(round.decisions.is_empty(), "indexed={indexed}: must wait out the drain");
        }
    }

    #[test]
    fn can_place_probe_matches_schedule_outcome() {
        let mut h = has();
        let snap = ClusterState::from_spec(&real_testbed());
        let view = ClusterView::build(&snap);
        assert!(h.can_place(&pending(1, "gpt2-350m", 4), &view, 0.0));
        // Fully busy cluster: nothing is placeable.
        let mut busy = ClusterState::from_spec(&real_testbed());
        for n in &mut busy.nodes {
            n.idle = 0;
        }
        let busy_view = ClusterView::build(&busy);
        assert!(!h.can_place(&pending(1, "gpt2-350m", 4), &busy_view, 0.0));
    }

    #[test]
    fn work_units_scale_linearly_not_combinatorially() {
        // HAS work for n jobs should be ~n × (plans + nodes), not explode.
        let mut h = has();
        let snap = ClusterState::from_spec(&real_testbed());
        let view = ClusterView::build(&snap);
        let jobs_small: Vec<PendingJob> = (0..4).map(|i| pending(i, "gpt2-350m", 4)).collect();
        let jobs_large: Vec<PendingJob> = (0..16).map(|i| pending(i, "gpt2-350m", 4)).collect();
        let w_small = h.schedule(&q(jobs_small), &view, 0.0).work_units;
        let mut h2 = has();
        let w_large = h2.schedule(&q(jobs_large), &view, 0.0).work_units;
        assert!(w_large <= w_small * 8, "w_small={w_small} w_large={w_large}");
    }
}
