//! HAS — Heterogeneity-Aware Scheduler (§IV.B, Algorithm 1).
//!
//! Two stages per job (Fig 3):
//!
//! 1. **Optimal plan retrieval** — walk MARP's priority-ordered plan list
//!    top-down; the first plan whose `(reqNum, reqSz)` the cluster can
//!    currently satisfy wins.
//! 2. **Heterogeneous resource scheduling** — Best-fit: among nodes whose
//!    GPU size ≥ the *fit size* (the smallest available GPU size ≥ reqSz),
//!    pick the one with the fewest idle GPUs that still covers the request
//!    (exactly-fitting nodes first). If no single node covers it, greedily
//!    take the node with the most idle GPUs, subtract, and repeat.
//!
//! The fit-size indirection is what makes HAS heterogeneity-aware: a job
//! needing 32 GB lands on 40 GB cards even when 80 GB cards are idle,
//! keeping the big cards for jobs that need them.

use super::{derive_placement, Decision, PendingJob, SchedRound, Scheduler};
use crate::cluster::{Allocation, ClusterState};
use crate::marp::{Marp, ResourcePlan};
use crate::memory::Parallelism;

/// The HAS scheduler. Owns a MARP instance (plans are recomputed per job and
/// memoized by (model, batch) key).
pub struct Has {
    marp: Marp,
    plan_cache: std::collections::HashMap<(String, u32), Vec<ResourcePlan>>,
    /// Work-unit accounting for the overhead comparison (Fig 5a): each node
    /// scan / plan check costs one unit.
    pub count_work: bool,
}

impl Has {
    pub fn new(marp: Marp) -> Self {
        Self { marp, plan_cache: std::collections::HashMap::new(), count_work: true }
    }

    pub fn marp(&self) -> &Marp {
        &self.marp
    }

    fn plans_for(&mut self, job: &PendingJob) -> &[ResourcePlan] {
        let key = (job.spec.model.name.to_string(), job.spec.train.global_batch);
        let marp = &self.marp;
        self.plan_cache
            .entry(key)
            .or_insert_with(|| marp.plans(&job.spec.model, &job.spec.train))
    }

    /// Algorithm 1. Returns the chosen plan and allocation, or None when no
    /// plan is satisfiable right now. `work` accumulates scan steps.
    pub fn allocate_one(
        plans: &[ResourcePlan],
        snapshot: &ClusterState,
        work: &mut u64,
    ) -> Option<(ResourcePlan, Allocation)> {
        // Stage 1: first satisfiable plan (lines 1–10).
        let mut optimal: Option<&ResourcePlan> = None;
        for plan in plans {
            *work += 1;
            let ava = snapshot.idle_gpus_with_mem(plan.min_gpu_mem);
            if ava >= plan.n_gpus {
                optimal = Some(plan);
                break;
            }
        }
        let plan = optimal?;

        // Stage 2: best-fit / greedy packing (lines 11–36).
        let mut req_num = plan.n_gpus;
        let req_sz = plan.min_gpu_mem;
        let mut idle: Vec<u32> = snapshot.nodes.iter().map(|n| n.idle).collect();
        let mut parts: Vec<(usize, u32)> = Vec::new();

        while req_num > 0 {
            // fitSz = min available GPU size ≥ reqSz (line 14).
            let fit_sz = snapshot
                .nodes
                .iter()
                .filter(|n| idle[n.id] > 0 && n.gpu.mem_bytes >= req_sz)
                .map(|n| n.gpu.mem_bytes)
                .min()?; // none available → cannot happen after stage 1, but stay safe
            // NLst = nodes with gpusize ≥ fitSz, ascending idle (lines 15–16).
            let mut nlst: Vec<usize> = snapshot
                .nodes
                .iter()
                .filter(|n| idle[n.id] > 0 && n.gpu.mem_bytes >= fit_sz)
                .map(|n| n.id)
                .collect();
            nlst.sort_by_key(|&id| idle[id]);
            *work += nlst.len() as u64;

            // Best-fit: first node (fewest idle) that covers the request
            // (lines 18–26).
            if let Some(&id) = nlst.iter().find(|&&id| idle[id] >= req_num) {
                parts.push((id, req_num));
                idle[id] -= req_num;
                break;
            }
            // Greedy: node with the most idle GPUs (lines 29–33).
            let &id = nlst.last()?;
            let take = idle[id];
            parts.push((id, take));
            req_num -= take;
            idle[id] = 0;
        }
        debug_assert_eq!(parts.iter().map(|(_, c)| c).sum::<u32>(), plan.n_gpus);
        Some((plan.clone(), Allocation { job: 0, parts }))
    }
}

impl Scheduler for Has {
    fn name(&self) -> &'static str {
        "frenzy-has"
    }

    /// Elasticity: MARP's plan list depends on the GPU sizes present, so a
    /// NodeJoin/NodeLeave invalidates both the predictor and the memoized
    /// plans (a joined 80G node can make previously infeasible models
    /// feasible; a departed one can do the reverse).
    fn cluster_changed(&mut self, state: &ClusterState) {
        let spec = state.to_spec(self.marp.cluster().name.as_str());
        self.marp = Marp::new(spec, self.marp.config().clone());
        self.plan_cache.clear();
    }

    fn schedule(&mut self, pending: &[PendingJob], snapshot: &ClusterState, _now: f64) -> SchedRound {
        let mut round = SchedRound::default();
        let mut snap = snapshot.clone();
        for job in pending {
            let plans = self.plans_for(job).to_vec();
            if plans.is_empty() {
                // Infeasible on this cluster — admission should have
                // rejected it; skip (the sim marks it Rejected).
                continue;
            }
            let mut work = 0u64;
            if let Some((plan, mut alloc)) = Self::allocate_one(&plans, &snap, &mut work) {
                alloc.job = job.spec.id;
                // Track the tentative allocation in the local snapshot so
                // later jobs in this round see reduced idle counts.
                for &(node, count) in &alloc.parts {
                    snap.nodes[node].idle -= count;
                }
                let (placement, gpu) = derive_placement(&alloc, plan.par, &snap);
                // Frenzy is memory-aware: the chosen plan always fits.
                let will_oom = plan.predicted_bytes > gpu.mem_bytes;
                round.decisions.push(Decision {
                    job: job.spec.id,
                    alloc,
                    par: Parallelism::new(plan.par.d, plan.par.t),
                    placement,
                    gpu,
                    will_oom,
                });
            }
            round.work_units += work.max(1);
        }
        round
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::model_by_name;
    use crate::config::{real_testbed, GIB};
    use crate::job::JobSpec;
    use crate::marp::Marp;

    fn pending(id: u64, model: &str, batch: u32) -> PendingJob {
        PendingJob {
            spec: JobSpec::new(id, model_by_name(model).unwrap(), batch, 10_000, 0.0),
            attempts: 0,
        }
    }

    fn has() -> Has {
        Has::new(Marp::with_defaults(real_testbed()))
    }

    #[test]
    fn schedules_small_job_without_oom() {
        let mut h = has();
        let snap = ClusterState::from_spec(&real_testbed());
        let round = h.schedule(&[pending(1, "gpt2-350m", 4)], &snap, 0.0);
        assert_eq!(round.decisions.len(), 1);
        let d = &round.decisions[0];
        assert!(d.alloc.is_single_node(), "a small job must not span nodes: {:?}", d.alloc);
        assert_eq!(d.alloc.total_gpus(), d.par.gpus());
        assert!(!d.will_oom);
    }

    #[test]
    fn algorithm1_best_fit_prefers_tightest_small_gpu_node() {
        // Hand-built single plan: Job(1, 30 GiB). Fit size is 40G; among the
        // 40G nodes, the 1-GPU node (fewest idle) is the best fit — the 80G
        // nodes must be left alone even though they are idle.
        use crate::marp::ResourcePlan;
        let plan = ResourcePlan {
            par: crate::memory::Parallelism::new(1, 1),
            n_gpus: 1,
            min_gpu_mem: 30 * GIB,
            predicted_bytes: 28 * GIB,
            est_samples_per_sec: 1.0,
            est_efficiency: 1.0,
            score: 1.0,
        };
        let snap = ClusterState::from_spec(&real_testbed());
        let mut work = 0;
        let (_, alloc) =
            Has::allocate_one(std::slice::from_ref(&plan), &snap, &mut work).expect("place");
        assert_eq!(alloc.parts, vec![(1usize, 1u32)], "must pick the 1-GPU A100-40 node");
        assert!(work > 0);
    }

    #[test]
    fn algorithm1_paper_job_2_32_takes_40g_node() {
        // §IV.B example: Job(2, 32G) should land on the 40G node, not 80G.
        use crate::marp::ResourcePlan;
        let plan = ResourcePlan {
            par: crate::memory::Parallelism::new(2, 1),
            n_gpus: 2,
            min_gpu_mem: 32 * GIB,
            predicted_bytes: 31 * GIB,
            est_samples_per_sec: 1.0,
            est_efficiency: 1.0,
            score: 1.0,
        };
        let snap = ClusterState::from_spec(&real_testbed());
        let mut work = 0;
        let (_, alloc) =
            Has::allocate_one(std::slice::from_ref(&plan), &snap, &mut work).expect("place");
        assert_eq!(alloc.parts.len(), 1);
        let (node, count) = alloc.parts[0];
        assert_eq!(count, 2);
        assert_eq!(snap.nodes[node].gpu.mem_bytes, 40 * GIB, "best-fit → 40G node: {alloc:?}");
    }

    #[test]
    fn big_job_lands_on_80g() {
        let mut h = has();
        let snap = ClusterState::from_spec(&real_testbed());
        let round = h.schedule(&[pending(1, "gpt2-7b", 2)], &snap, 0.0);
        assert_eq!(round.decisions.len(), 1);
        let d = &round.decisions[0];
        // 7B needs eight 40G GPUs (only 3 exist) or four 80G: the first
        // satisfiable plan uses 80G cards.
        assert!(d.gpu.mem_bytes >= 40 * GIB);
        assert_eq!(d.alloc.total_gpus(), d.par.gpus());
        assert!(!d.will_oom);
    }

    #[test]
    fn round_respects_capacity_across_jobs() {
        let mut h = has();
        let snap = ClusterState::from_spec(&real_testbed());
        let jobs: Vec<PendingJob> =
            (0..8).map(|i| pending(i, "gpt2-350m", 8)).collect();
        let round = h.schedule(&jobs, &snap, 0.0);
        // Apply all decisions to a fresh orchestrator: must never overdraw.
        let mut orch = crate::cluster::Orchestrator::new(&real_testbed());
        for d in &round.decisions {
            orch.allocate(d.alloc.clone()).expect("no overdraw");
        }
        assert!(orch.check_conservation());
    }

    #[test]
    fn queues_when_cluster_full() {
        let mut h = has();
        let mut snap = ClusterState::from_spec(&real_testbed());
        for n in &mut snap.nodes {
            n.idle = 0;
        }
        let round = h.schedule(&[pending(1, "gpt2-350m", 4)], &snap, 0.0);
        assert!(round.decisions.is_empty());
    }

    #[test]
    fn falls_through_to_lower_priority_plan() {
        // Occupy the A800 node so only scattered GPUs remain; HAS must pick
        // a satisfiable (possibly multi-node or smaller) plan instead of the
        // top one.
        let mut h = has();
        let mut snap = ClusterState::from_spec(&real_testbed());
        snap.nodes[2].idle = 0; // 4×A800 taken
        let round = h.schedule(&[pending(1, "gpt2-1.3b", 8)], &snap, 0.0);
        assert_eq!(round.decisions.len(), 1);
        let d = &round.decisions[0];
        assert!(!d.will_oom);
        assert!(d.alloc.total_gpus() <= 7);
    }

    #[test]
    fn multi_node_greedy_when_no_single_node_fits() {
        // Ask for more 80G GPUs than any single node has.
        let marp = Marp::with_defaults(real_testbed());
        let m = model_by_name("gpt2-1.3b").unwrap();
        let plans = marp.plans(&m, &crate::memory::TrainConfig { global_batch: 32 });
        let snap = ClusterState::from_spec(&real_testbed());
        // find a plan requiring > 4 GPUs (bigger than the largest node)
        if let Some(plan) = plans.iter().find(|p| p.n_gpus > 4) {
            let mut work = 0;
            let got = Has::allocate_one(std::slice::from_ref(plan), &snap, &mut work);
            if let Some((_, alloc)) = got {
                assert!(alloc.parts.len() > 1);
                assert_eq!(alloc.total_gpus(), plan.n_gpus);
            }
        }
    }

    #[test]
    fn work_units_scale_linearly_not_combinatorially() {
        // HAS work for n jobs should be ~n × (plans + nodes), not explode.
        let mut h = has();
        let snap = ClusterState::from_spec(&real_testbed());
        let jobs_small: Vec<PendingJob> = (0..4).map(|i| pending(i, "gpt2-350m", 4)).collect();
        let jobs_large: Vec<PendingJob> = (0..16).map(|i| pending(i, "gpt2-350m", 4)).collect();
        let w_small = h.schedule(&jobs_small, &snap, 0.0).work_units;
        let mut h2 = has();
        let w_large = h2.schedule(&jobs_large, &snap, 0.0).work_units;
        assert!(w_large <= w_small * 8, "w_small={w_small} w_large={w_large}");
    }
}
