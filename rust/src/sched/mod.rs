//! Scheduler interface and the three policies evaluated in the paper:
//!
//! * [`has::Has`] — Frenzy's Heterogeneity-Aware Scheduler (Algorithm 1),
//! * [`sia::Sia`] — the goodput-ILP baseline (adaptive but expensive),
//! * [`opportunistic::Opportunistic`] — FCFS fastest-GPU-first (Lyra-style),
//!   memory-oblivious with OOM trial-and-error.
//!
//! Schedulers plan against an immutable [`ClusterView`] — the live
//! [`ClusterState`] plus the orchestrator's incrementally maintained
//! [`crate::cluster::CapacityIndex`] — and return [`Decision`]s; the shared
//! [`crate::engine::SchedulingEngine`] — driving both the simulator and the
//! live serverless coordinator — applies them through the
//! [`crate::cluster::Orchestrator`], which is the single authority on
//! resource state. Rounds therefore clone nothing cluster-sized: tentative
//! within-round placements live in a [`crate::cluster::CapacityOverlay`]
//! (HAS) or scheduler-local scratch (the baselines).

pub mod has;
pub mod opportunistic;
pub mod queue;
pub mod sia;

pub use queue::PendingQueue;

use crate::cluster::{Allocation, ClusterState, ClusterView};
use crate::config::GpuSpec;
use crate::job::{JobId, JobSpec};
use crate::memory::Parallelism;
use crate::perfmodel::{CommPath, Placement};

/// A job waiting for resources, with scheduling history.
#[derive(Debug, Clone)]
pub struct PendingJob {
    pub spec: JobSpec,
    /// Scheduling attempts so far (baselines' OOM retries increment this).
    pub attempts: u32,
}

/// One placement decision.
#[derive(Debug, Clone)]
pub struct Decision {
    pub job: JobId,
    pub alloc: Allocation,
    /// Parallelism the job will run with.
    pub par: Parallelism,
    /// Derived communication placement (for the throughput model).
    pub placement: Placement,
    /// Effective GPU descriptor (slowest/smallest across the allocation —
    /// stragglers gate collective training).
    pub gpu: GpuSpec,
    /// True when the scheduler knowingly or unknowingly placed the job where
    /// its peak memory exceeds a GPU — the simulator will fire an OOM.
    pub will_oom: bool,
}

/// Result of one scheduling round.
#[derive(Debug, Clone, Default)]
pub struct SchedRound {
    pub decisions: Vec<Decision>,
    /// Algorithmic work expended this round, converted to seconds by the
    /// simulator (and measured directly in the overhead benchmarks).
    pub work_units: u64,
}

/// The scheduling policy interface.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Plan allocations for `pending` (FCFS order) against `view`.
    /// Implementations must not assume they can place every job, and must
    /// not rely on mutating the view — tentative within-round state belongs
    /// in a [`crate::cluster::CapacityOverlay`] or local scratch.
    fn schedule(&mut self, pending: &PendingQueue, view: &ClusterView<'_>, now: f64)
        -> SchedRound;

    /// Cheap feasibility probe: could `job` be placed against `view`'s
    /// committed capacity? The engine uses this to reject structurally
    /// unplaceable jobs (pending on a fully idle cluster) without running a
    /// full placement round per job. The default falls back to a
    /// single-job [`Scheduler::schedule`] round; index-aware schedulers
    /// override it with an O(plans · log S) probe.
    fn can_place(&mut self, job: &PendingJob, view: &ClusterView<'_>, now: f64) -> bool {
        let single = PendingQueue::from(vec![job.clone()]);
        !self.schedule(&single, view, now).decisions.is_empty()
    }

    /// `Some(interval)` for batch schedulers that re-solve on a fixed round
    /// cadence (Sia/Pollux-style); `None` for event-driven schedulers (HAS,
    /// Opportunistic). The engine defers placements to round boundaries
    /// for interval schedulers — part of their queueing cost.
    fn round_interval_s(&self) -> Option<f64> {
        None
    }

    /// The engine calls this after the cluster topology changes (elastic
    /// `NodeJoin`/`NodeLeave`). Schedulers holding state derived from the
    /// topology — MARP plan caches, GPU-type tables, sizing heuristics —
    /// must rebuild it here, or a joined GPU type stays invisible to them.
    /// Default: no-op (for purely snapshot-driven schedulers).
    fn cluster_changed(&mut self, _state: &ClusterState) {}
}

/// Derive the communication placement and effective GPU for an allocation.
///
/// * single node → both TP and DP ride the node link;
/// * multi-node with every part a multiple of `t` → TP groups stay inside
///   nodes (the worst link among parts), DP crosses nodes;
/// * otherwise a TP group spans nodes → everything is cross-node (the
///   paper's Node(4,40)-vs-4×Node(1,40) pathology).
pub fn derive_placement(
    alloc: &Allocation,
    par: Parallelism,
    cluster: &ClusterState,
) -> (Placement, GpuSpec) {
    assert!(!alloc.parts.is_empty());
    let nodes: Vec<&crate::cluster::Node> =
        alloc.parts.iter().map(|(id, _)| &cluster.nodes[*id]).collect();
    // Effective GPU: min memory + min tflops across parts (straggler).
    let gpu = GpuSpec {
        name: nodes.iter().min_by_key(|n| n.gpu.mem_bytes).unwrap().gpu.name,
        mem_bytes: nodes.iter().map(|n| n.gpu.mem_bytes).min().unwrap(),
        peak_tflops: nodes.iter().map(|n| n.gpu.peak_tflops).fold(f64::INFINITY, f64::min),
    };
    let placement = if alloc.parts.len() == 1 {
        Placement::single_node(nodes[0].link)
    } else if alloc.parts.iter().all(|(_, c)| c % par.t == 0) {
        // TP groups intact per node; DP ring crosses nodes. Worst intra-node
        // link gates the TP collectives.
        let worst = nodes
            .iter()
            .map(|n| CommPath::from_link(n.link))
            .max_by_key(|p| match p {
                CommPath::NvLink => 0,
                CommPath::Pcie => 1,
                CommPath::CrossNode => 2,
            })
            .unwrap();
        Placement { tp_path: worst, dp_path: CommPath::CrossNode }
    } else {
        Placement::all_cross()
    };
    (placement, gpu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::real_testbed;

    #[test]
    fn single_node_placement_uses_node_link() {
        let c = ClusterState::from_spec(&real_testbed());
        // node 2 = 4×A800 NVLink
        let alloc = Allocation { job: 1, parts: vec![(2, 4)] };
        let (pl, gpu) = derive_placement(&alloc, Parallelism::new(1, 4), &c);
        assert_eq!(pl.tp_path, CommPath::NvLink);
        assert_eq!(pl.dp_path, CommPath::NvLink);
        assert_eq!(gpu.name, "A800-80G");
    }

    #[test]
    fn multi_node_tp_preserved_when_divisible() {
        let c = ClusterState::from_spec(&real_testbed());
        // nodes 3 and 4: 2×A100-80 each; t=2, d=2 → one TP group per node.
        let alloc = Allocation { job: 1, parts: vec![(3, 2), (4, 2)] };
        let (pl, _) = derive_placement(&alloc, Parallelism::new(2, 2), &c);
        assert_eq!(pl.tp_path, CommPath::Pcie);
        assert_eq!(pl.dp_path, CommPath::CrossNode);
    }

    #[test]
    fn split_tp_group_goes_cross_node() {
        let c = ClusterState::from_spec(&real_testbed());
        // t=4 but parts of 2+2: TP group spans nodes.
        let alloc = Allocation { job: 1, parts: vec![(3, 2), (4, 2)] };
        let (pl, _) = derive_placement(&alloc, Parallelism::new(1, 4), &c);
        assert_eq!(pl.tp_path, CommPath::CrossNode);
    }

    #[test]
    fn effective_gpu_is_straggler() {
        let c = ClusterState::from_spec(&real_testbed());
        // node 0 (A100-40) + node 3 (A100-80): effective mem = 40G.
        let alloc = Allocation { job: 1, parts: vec![(0, 2), (3, 2)] };
        let (_, gpu) = derive_placement(&alloc, Parallelism::new(4, 1), &c);
        assert_eq!(gpu.mem_bytes, 40 * crate::config::GIB);
    }
}
