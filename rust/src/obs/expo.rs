//! Prometheus text exposition (format version 0.0.4): the renderer that
//! serves `GET /metrics`, plus a parser/validator used by the conformance
//! tests, the CI scrape smoke (`frenzy metrics --check`), and `frenzy top`
//! (which reads its dashboard numbers back out of the scrape).
//!
//! The renderer emits every registered family with `# HELP` and `# TYPE`
//! headers, histograms in cumulative `le` form with `+Inf`/`_sum`/`_count`,
//! and label values escaped per the spec (`\\`, `\"`, `\n`).

use super::{reg, Histogram};
use std::fmt::Write as _;

/// Content-Type for the exposition format this module renders.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

fn esc_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn esc_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".into()
    } else if v == f64::INFINITY {
        "+Inf".into()
    } else if v == f64::NEG_INFINITY {
        "-Inf".into()
    } else {
        format!("{v}")
    }
}

fn head(out: &mut String, name: &str, typ: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {}", esc_help(help));
    let _ = writeln!(out, "# TYPE {name} {typ}");
}

fn sample(out: &mut String, name: &str, labels: &[(&str, &str)], value: &str) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{k}=\"{}\"", esc_label(v));
        }
        out.push('}');
    }
    let _ = writeln!(out, " {value}");
}

/// One histogram instance under `name`, carrying `labels` (may be empty).
fn histogram(out: &mut String, name: &str, labels: &[(&str, &str)], h: &Histogram) {
    let bucket_name = format!("{name}_bucket");
    let mut cum = 0u64;
    let counts = h.bucket_counts();
    for (i, &bound) in h.bounds().iter().enumerate() {
        cum += counts[i];
        let le = fmt_f64(bound);
        let mut with_le: Vec<(&str, &str)> = labels.to_vec();
        with_le.push(("le", &le));
        sample(out, &bucket_name, &with_le, &cum.to_string());
    }
    cum += counts[h.bounds().len()];
    let mut with_le: Vec<(&str, &str)> = labels.to_vec();
    with_le.push(("le", "+Inf"));
    sample(out, &bucket_name, &with_le, &cum.to_string());
    sample(out, &format!("{name}_sum"), labels, &fmt_f64(h.sum()));
    sample(out, &format!("{name}_count"), labels, &cum.to_string());
}

/// Render the full process registry as Prometheus text.
pub fn render() -> String {
    let r = reg();
    let mut out = String::with_capacity(32 * 1024);

    // --- build / process ---------------------------------------------
    head(&mut out, "frenzy_build_info", "gauge", "Build metadata; the value is always 1.");
    sample(
        &mut out,
        "frenzy_build_info",
        &[("version", super::crate_version()), ("git_sha", super::git_sha())],
        "1",
    );
    head(
        &mut out,
        "frenzy_process_uptime_seconds",
        "gauge",
        "Seconds since the telemetry registry was first touched.",
    );
    sample(&mut out, "frenzy_process_uptime_seconds", &[], &fmt_f64(r.uptime_s()));

    // --- HTTP server --------------------------------------------------
    head(
        &mut out,
        "frenzy_http_requests_total",
        "counter",
        "Requests served, by normalized route and status class.",
    );
    const CLASSES: [&str; 5] = ["1xx", "2xx", "3xx", "4xx", "5xx"];
    for rt in &r.http.routes {
        for (i, class) in CLASSES.iter().enumerate() {
            sample(
                &mut out,
                "frenzy_http_requests_total",
                &[("route", rt.route), ("code", class)],
                &rt.by_class[i].get().to_string(),
            );
        }
    }
    head(
        &mut out,
        "frenzy_http_request_duration_seconds",
        "histogram",
        "Routing + handler latency per normalized route (excludes socket writes).",
    );
    for rt in &r.http.routes {
        histogram(
            &mut out,
            "frenzy_http_request_duration_seconds",
            &[("route", rt.route)],
            &rt.latency,
        );
    }
    head(
        &mut out,
        "frenzy_http_inflight_requests",
        "gauge",
        "Requests currently inside the router.",
    );
    sample(&mut out, "frenzy_http_inflight_requests", &[], &r.http.inflight.get().to_string());
    head(
        &mut out,
        "frenzy_http_shed_total",
        "counter",
        "Load shed: accept-queue 503s (request unread) and admission 429s.",
    );
    sample(
        &mut out,
        "frenzy_http_shed_total",
        &[("kind", "accept_queue_503")],
        &r.http.shed_503.get().to_string(),
    );
    sample(
        &mut out,
        "frenzy_http_shed_total",
        &[("kind", "throttle_429")],
        &r.http.shed_429.get().to_string(),
    );
    head(
        &mut out,
        "frenzy_http_sse_connections_total",
        "counter",
        "Connections upgraded to the SSE event stream.",
    );
    sample(
        &mut out,
        "frenzy_http_sse_connections_total",
        &[],
        &r.http.sse_connections.get().to_string(),
    );

    // --- coordinator ---------------------------------------------------
    head(
        &mut out,
        "frenzy_coordinator_mailbox_depth",
        "gauge",
        "Messages sent to the coordinator mailbox and not yet received.",
    );
    sample(
        &mut out,
        "frenzy_coordinator_mailbox_depth",
        &[],
        &r.coord.mailbox_depth.get().to_string(),
    );
    head(
        &mut out,
        "frenzy_coordinator_messages_total",
        "counter",
        "Messages the coordinator loop has processed.",
    );
    sample(
        &mut out,
        "frenzy_coordinator_messages_total",
        &[],
        &r.coord.messages_total.get().to_string(),
    );
    head(
        &mut out,
        "frenzy_admission_decisions_total",
        "counter",
        "Submit admission outcomes.",
    );
    for (decision, c) in [
        ("admitted", &r.coord.admitted_total),
        ("throttled_backpressure", &r.coord.throttled_backpressure_total),
        ("throttled_quota", &r.coord.throttled_quota_total),
        ("rejected_infeasible", &r.coord.rejected_infeasible_total),
    ] {
        sample(
            &mut out,
            "frenzy_admission_decisions_total",
            &[("decision", decision)],
            &c.get().to_string(),
        );
    }

    // --- engine --------------------------------------------------------
    head(&mut out, "frenzy_jobs", "gauge", "Live jobs by state.");
    sample(&mut out, "frenzy_jobs", &[("state", "queued")], &r.engine.jobs_queued.get().to_string());
    sample(
        &mut out,
        "frenzy_jobs",
        &[("state", "running")],
        &r.engine.jobs_running.get().to_string(),
    );
    head(
        &mut out,
        "frenzy_sched_rounds_total",
        "counter",
        "Executed scheduling rounds (rounds with an empty queue are not counted).",
    );
    sample(&mut out, "frenzy_sched_rounds_total", &[], &r.engine.rounds_total.get().to_string());
    head(
        &mut out,
        "frenzy_sched_round_phase_seconds",
        "histogram",
        "Scheduler round wall time split by phase: candidate_scan (fair ordering + view), plan_rank (MARP plan + rank), placement (applying decisions).",
    );
    for (phase, h) in [
        ("candidate_scan", &r.engine.phase_candidate_scan),
        ("plan_rank", &r.engine.phase_plan_rank),
        ("placement", &r.engine.phase_placement),
    ] {
        histogram(&mut out, "frenzy_sched_round_phase_seconds", &[("phase", phase)], h);
    }
    head(
        &mut out,
        "frenzy_sched_work_units_total",
        "counter",
        "Abstract scheduler work units consumed (the unit the paper's overhead claim is measured in).",
    );
    sample(
        &mut out,
        "frenzy_sched_work_units_total",
        &[],
        &r.engine.work_units_total.get().to_string(),
    );
    head(
        &mut out,
        "frenzy_engine_events_total",
        "counter",
        "Cluster events appended to the audit log, by kind.",
    );
    for (kind, c) in &r.engine.events {
        sample(
            &mut out,
            "frenzy_engine_events_total",
            &[("kind", kind)],
            &c.get().to_string(),
        );
    }

    // --- durability ----------------------------------------------------
    head(&mut out, "frenzy_wal_appends_total", "counter", "Records appended to the WAL.");
    sample(
        &mut out,
        "frenzy_wal_appends_total",
        &[],
        &r.durability.wal_appends_total.get().to_string(),
    );
    head(
        &mut out,
        "frenzy_wal_append_bytes_total",
        "counter",
        "Framed bytes appended to the WAL.",
    );
    sample(
        &mut out,
        "frenzy_wal_append_bytes_total",
        &[],
        &r.durability.wal_append_bytes_total.get().to_string(),
    );
    head(&mut out, "frenzy_wal_fsync_seconds", "histogram", "WAL fsync (sync_data) latency.");
    histogram(&mut out, "frenzy_wal_fsync_seconds", &[], &r.durability.fsync_seconds);
    head(&mut out, "frenzy_wal_segments", "gauge", "Live WAL segment files.");
    sample(&mut out, "frenzy_wal_segments", &[], &r.durability.wal_segments.get().to_string());
    head(&mut out, "frenzy_wal_bytes", "gauge", "Total bytes across live WAL segments.");
    sample(&mut out, "frenzy_wal_bytes", &[], &r.durability.wal_bytes.get().to_string());
    head(&mut out, "frenzy_snapshots_total", "counter", "Snapshots persisted.");
    sample(
        &mut out,
        "frenzy_snapshots_total",
        &[],
        &r.durability.snapshots_total.get().to_string(),
    );
    head(
        &mut out,
        "frenzy_snapshot_age_seconds",
        "gauge",
        "Engine-time seconds since the newest snapshot (0 when durability is off).",
    );
    sample(
        &mut out,
        "frenzy_snapshot_age_seconds",
        &[],
        &fmt_f64(r.durability.snapshot_age_seconds.get()),
    );
    head(
        &mut out,
        "frenzy_snapshot_covered_seq",
        "gauge",
        "Highest WAL sequence covered by the newest snapshot.",
    );
    sample(
        &mut out,
        "frenzy_snapshot_covered_seq",
        &[],
        &r.durability.snapshot_covered_seq.get().to_string(),
    );

    // --- runtime -------------------------------------------------------
    head(
        &mut out,
        "frenzy_node_device_mem_used_bytes",
        "gauge",
        "Device-memory bytes pinned per node (the OOM ledger).",
    );
    for (node, v) in r.runtime.device_mem_used.snapshot() {
        let n = node.to_string();
        sample(&mut out, "frenzy_node_device_mem_used_bytes", &[("node", &n)], &fmt_f64(v));
    }
    head(
        &mut out,
        "frenzy_node_device_mem_capacity_bytes",
        "gauge",
        "Per-GPU device-memory capacity per node.",
    );
    for (node, v) in r.runtime.device_mem_capacity.snapshot() {
        let n = node.to_string();
        sample(&mut out, "frenzy_node_device_mem_capacity_bytes", &[("node", &n)], &fmt_f64(v));
    }
    head(&mut out, "frenzy_oom_events_total", "counter", "Out-of-memory events.");
    sample(
        &mut out,
        "frenzy_oom_events_total",
        &[],
        &r.runtime.oom_events_total.get().to_string(),
    );
    head(&mut out, "frenzy_drains_total", "counter", "Graceful drains completed.");
    sample(&mut out, "frenzy_drains_total", &[], &r.runtime.drains_total.get().to_string());
    head(
        &mut out,
        "frenzy_crash_requeues_total",
        "counter",
        "Jobs requeued after a node crash.",
    );
    sample(
        &mut out,
        "frenzy_crash_requeues_total",
        &[],
        &r.runtime.crash_requeues_total.get().to_string(),
    );
    head(&mut out, "frenzy_quarantines_total", "counter", "Nodes quarantined for flapping.");
    sample(
        &mut out,
        "frenzy_quarantines_total",
        &[],
        &r.runtime.quarantines_total.get().to_string(),
    );
    head(
        &mut out,
        "frenzy_mem_prediction_samples_total",
        "counter",
        "Predicted-vs-observed memory pairs recorded.",
    );
    sample(
        &mut out,
        "frenzy_mem_prediction_samples_total",
        &[],
        &r.runtime.mem_pred_samples_total.get().to_string(),
    );
    head(
        &mut out,
        "frenzy_mem_prediction_accuracy_avg",
        "gauge",
        "Mean memory-prediction accuracy (the paper's >92% claim).",
    );
    sample(
        &mut out,
        "frenzy_mem_prediction_accuracy_avg",
        &[],
        &fmt_f64(r.runtime.mem_pred_accuracy_avg.get()),
    );
    head(
        &mut out,
        "frenzy_mem_prediction_accuracy_min",
        "gauge",
        "Worst-case memory-prediction accuracy.",
    );
    sample(
        &mut out,
        "frenzy_mem_prediction_accuracy_min",
        &[],
        &fmt_f64(r.runtime.mem_pred_accuracy_min.get()),
    );

    out
}

// ---------------------------------------------------------------------
// Parsing + conformance checking
// ---------------------------------------------------------------------

/// One parsed sample line.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_value(s: &str) -> Result<f64, String> {
    match s {
        "+Inf" | "Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        _ => s.parse::<f64>().map_err(|_| format!("bad sample value '{s}'")),
    }
}

/// Parse one `name{labels} value` line.
fn parse_sample(line: &str) -> Result<Sample, String> {
    // Validate the value token shared by both shapes: `value` optionally
    // followed by one timestamp, nothing further.
    let read_value = |tail: &str| -> Result<f64, String> {
        let mut it = tail.split_whitespace();
        let v = it.next().ok_or_else(|| format!("no value in '{line}'"))?;
        if it.next().is_some() && it.next().is_some() {
            return Err(format!("trailing garbage in '{line}'"));
        }
        parse_value(v)
    };

    let Some(brace) = line.find('{') else {
        let mut it = line.split_whitespace();
        let name = it.next().ok_or("empty sample line")?;
        if !valid_metric_name(name) {
            return Err(format!("bad metric name '{name}'"));
        }
        let tail = line[name.len()..].trim_start();
        return Ok(Sample {
            name: name.to_string(),
            labels: Vec::new(),
            value: read_value(tail)?,
        });
    };

    let name = line[..brace].trim();
    if !valid_metric_name(name) {
        return Err(format!("bad metric name '{name}'"));
    }
    let rest = &line[brace + 1..];
    let bytes = rest.as_bytes();
    let mut labels = Vec::new();
    let mut i = 0usize;
    loop {
        if bytes.get(i) == Some(&b'}') {
            i += 1;
            break;
        }
        let eq = rest[i..]
            .find('=')
            .map(|o| i + o)
            .ok_or_else(|| format!("missing '=' in labels of '{line}'"))?;
        let lname = rest[i..eq].trim();
        if !valid_label_name(lname) {
            return Err(format!("bad label name '{lname}' in '{line}'"));
        }
        if bytes.get(eq + 1) != Some(&b'"') {
            return Err(format!("label value not quoted in '{line}'"));
        }
        // Scan for the closing quote, honoring \\ \" \n escapes.
        let mut val = String::new();
        let mut j = eq + 2;
        loop {
            match bytes.get(j) {
                None => return Err(format!("unterminated label value in '{line}'")),
                Some(b'\\') => match bytes.get(j + 1) {
                    Some(b'\\') => {
                        val.push('\\');
                        j += 2;
                    }
                    Some(b'"') => {
                        val.push('"');
                        j += 2;
                    }
                    Some(b'n') => {
                        val.push('\n');
                        j += 2;
                    }
                    _ => return Err(format!("bad escape in label value of '{line}'")),
                },
                Some(b'"') => {
                    j += 1;
                    break;
                }
                Some(_) => {
                    let c = rest[j..].chars().next().ok_or("truncated char")?;
                    val.push(c);
                    j += c.len_utf8();
                }
            }
        }
        labels.push((lname.to_string(), val));
        i = j;
        match bytes.get(i) {
            Some(b',') => i += 1,
            Some(b'}') => {}
            _ => return Err(format!("expected ',' or '}}' after label in '{line}'")),
        }
    }
    Ok(Sample { name: name.to_string(), labels, value: read_value(rest[i..].trim_start())? })
}

/// Parse every sample line (syntax check only; `# HELP`/`# TYPE`/comments
/// and blank lines are skipped).
pub fn parse(text: &str) -> Result<Vec<Sample>, String> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push(
            parse_sample(line).map_err(|e| format!("line {}: {e}", lineno + 1))?,
        );
    }
    Ok(out)
}

/// Full conformance check of an exposition document:
///
/// - every line parses (samples, `# HELP`, `# TYPE`, comments, blanks);
/// - metric and label names are well-formed;
/// - every sample's family has `# HELP` and `# TYPE` declared *before* it,
///   each exactly once;
/// - `# TYPE` is one of counter/gauge/histogram/summary/untyped;
/// - histogram families carry a `+Inf` bucket per label set, cumulative
///   bucket counts are non-decreasing in `le`, and `_count` equals the
///   `+Inf` bucket.
pub fn validate(text: &str) -> Result<(), String> {
    use std::collections::{BTreeMap, HashMap, HashSet};
    let mut helps: HashSet<String> = HashSet::new();
    let mut types: HashMap<String, String> = HashMap::new();
    // histogram name -> (labelset key -> (le -> cumulative count))
    type Buckets = BTreeMap<String, BTreeMap<u64, (f64, f64)>>;
    let mut hist_buckets: HashMap<String, Buckets> = HashMap::new();
    let mut hist_counts: HashMap<String, BTreeMap<String, f64>> = HashMap::new();
    let mut hist_sums: HashMap<String, HashSet<String>> = HashMap::new();

    let label_key = |labels: &[(String, String)]| {
        let mut ls: Vec<String> = labels
            .iter()
            .filter(|(k, _)| k != "le")
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        ls.sort();
        ls.join(",")
    };

    for (lineno, raw) in text.lines().enumerate() {
        let n = lineno + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or_default();
            if !valid_metric_name(name) {
                return Err(format!("line {n}: bad HELP metric name '{name}'"));
            }
            if !helps.insert(name.to_string()) {
                return Err(format!("line {n}: duplicate HELP for '{name}'"));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap_or_default();
            let typ = it.next().unwrap_or_default();
            if !valid_metric_name(name) {
                return Err(format!("line {n}: bad TYPE metric name '{name}'"));
            }
            if !matches!(typ, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("line {n}: bad TYPE '{typ}' for '{name}'"));
            }
            if types.insert(name.to_string(), typ.to_string()).is_some() {
                return Err(format!("line {n}: duplicate TYPE for '{name}'"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // plain comment
        }
        let s = parse_sample(line).map_err(|e| format!("line {n}: {e}"))?;
        // Resolve the family: histogram series use suffixed sample names.
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                let base = s.name.strip_suffix(suf)?;
                (types.get(base).map(String::as_str) == Some("histogram"))
                    .then(|| base.to_string())
            })
            .unwrap_or_else(|| s.name.clone());
        let Some(typ) = types.get(&family) else {
            return Err(format!(
                "line {n}: sample '{}' has no preceding # TYPE for '{family}'",
                s.name
            ));
        };
        if !helps.contains(&family) {
            return Err(format!(
                "line {n}: sample '{}' has no preceding # HELP for '{family}'",
                s.name
            ));
        }
        if typ == "counter" && s.value < 0.0 {
            return Err(format!("line {n}: counter '{}' is negative", s.name));
        }
        if typ == "histogram" {
            let key = label_key(&s.labels);
            if s.name.ends_with("_bucket") {
                let le = s
                    .labels
                    .iter()
                    .find(|(k, _)| k == "le")
                    .map(|(_, v)| v.as_str())
                    .ok_or_else(|| format!("line {n}: bucket without le label"))?;
                let le_v = parse_value(le).map_err(|e| format!("line {n}: {e}"))?;
                hist_buckets
                    .entry(family.clone())
                    .or_default()
                    .entry(key)
                    .or_default()
                    .insert(le_v.to_bits(), (le_v, s.value));
            } else if s.name.ends_with("_count") {
                hist_counts.entry(family.clone()).or_default().insert(key, s.value);
            } else if s.name.ends_with("_sum") {
                hist_sums.entry(family.clone()).or_default().insert(key);
            } else {
                return Err(format!(
                    "line {n}: bare sample '{}' under histogram family '{family}'",
                    s.name
                ));
            }
        }
    }

    // Histogram invariants per (family, label set).
    for (family, by_labels) in &hist_buckets {
        for (key, buckets) in by_labels {
            let mut series: Vec<(f64, f64)> = buckets.values().copied().collect();
            series.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("le comparable"));
            let Some(&(last_le, inf_count)) = series.last() else {
                continue;
            };
            if last_le != f64::INFINITY {
                return Err(format!("histogram '{family}'{{{key}}} missing +Inf bucket"));
            }
            let mut prev = 0.0;
            for &(le, c) in &series {
                if c + 1e-9 < prev {
                    return Err(format!(
                        "histogram '{family}'{{{key}}} buckets not cumulative at le={le}"
                    ));
                }
                prev = c;
            }
            match hist_counts.get(family).and_then(|m| m.get(key)) {
                None => {
                    return Err(format!("histogram '{family}'{{{key}}} missing _count"))
                }
                Some(&count) if (count - inf_count).abs() > 1e-9 => {
                    return Err(format!(
                        "histogram '{family}'{{{key}}} _count {count} != +Inf bucket {inf_count}"
                    ));
                }
                Some(_) => {}
            }
            if !hist_sums.get(family).is_some_and(|s| s.contains(key)) {
                return Err(format!("histogram '{family}'{{{key}}} missing _sum"));
            }
        }
    }
    Ok(())
}

/// First sample matching `name` whose labels include every `(k, v)` in
/// `want`.
pub fn sample_value(samples: &[Sample], name: &str, want: &[(&str, &str)]) -> Option<f64> {
    samples
        .iter()
        .find(|s| {
            s.name == name
                && want.iter().all(|(k, v)| {
                    s.labels.iter().any(|(lk, lv)| lk == k && lv == v)
                })
        })
        .map(|s| s.value)
}

/// Cumulative `(le, count)` series of `name_bucket` samples matching
/// `want`, sorted by `le`.
pub fn bucket_series(samples: &[Sample], name: &str, want: &[(&str, &str)]) -> Vec<(f64, f64)> {
    let bucket = format!("{name}_bucket");
    let mut out: Vec<(f64, f64)> = samples
        .iter()
        .filter(|s| {
            s.name == bucket
                && want.iter().all(|(k, v)| {
                    s.labels.iter().any(|(lk, lv)| lk == k && lv == v)
                })
        })
        .filter_map(|s| {
            let le = s.labels.iter().find(|(k, _)| k == "le")?;
            parse_value(&le.1).ok().map(|le| (le, s.value))
        })
        .collect();
    out.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("le comparable"));
    out
}

/// Approximate quantile from a cumulative bucket series (linear
/// interpolation inside the winning bucket, like PromQL's
/// `histogram_quantile`). Returns `None` on an empty histogram.
pub fn quantile(series: &[(f64, f64)], q: f64) -> Option<f64> {
    let total = series.last()?.1;
    if total <= 0.0 {
        return None;
    }
    let rank = q.clamp(0.0, 1.0) * total;
    let mut prev_le = 0.0;
    let mut prev_c = 0.0;
    for &(le, c) in series {
        if c >= rank {
            if le.is_infinite() {
                return Some(prev_le);
            }
            let span = (c - prev_c).max(1e-12);
            return Some(prev_le + (le - prev_le) * ((rank - prev_c) / span));
        }
        prev_le = le;
        prev_c = c;
    }
    Some(prev_le)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_passes_own_validator() {
        // Touch a few metrics so non-zero values render too.
        let r = reg();
        r.http.record("/v1/jobs", 202, 0.0012);
        r.http.record("/v1/jobs/<id>", 404, 0.00004);
        r.engine.phase_plan_rank.observe(0.003);
        r.runtime.device_mem_used.set_all([(0, 1e9), (1, 2e9)]);
        let text = render();
        validate(&text).expect("rendered exposition must validate");
        let samples = parse(&text).unwrap();
        assert!(
            sample_value(
                &samples,
                "frenzy_http_requests_total",
                &[("route", "/v1/jobs"), ("code", "2xx")],
            )
            .unwrap()
                >= 1.0
        );
        assert_eq!(
            sample_value(
                &samples,
                "frenzy_node_device_mem_used_bytes",
                &[("node", "1")],
            ),
            Some(2e9)
        );
        assert_eq!(sample_value(&samples, "frenzy_build_info", &[]), Some(1.0));
    }

    #[test]
    fn sample_parser_handles_labels_and_escapes() {
        let s = parse_sample(r#"m_x{a="1",b="q\"uo\\te\nnl"} 2.5"#).unwrap();
        assert_eq!(s.name, "m_x");
        assert_eq!(s.labels[0], ("a".into(), "1".into()));
        assert_eq!(s.labels[1], ("b".into(), "q\"uo\\te\nnl".into()));
        assert_eq!(s.value, 2.5);
        let s = parse_sample("plain 7").unwrap();
        assert!(s.labels.is_empty());
        assert_eq!(s.value, 7.0);
        let s = parse_sample("b{le=\"+Inf\"} 3").unwrap();
        assert_eq!(s.labels[0].1, "+Inf");
        assert!(parse_sample("1bad 2").is_err());
        assert!(parse_sample("m{a=1} 2").is_err());
        assert!(parse_sample("m{a=\"x\"").is_err());
        assert!(parse_sample("m{a=\"x\"} ").is_err());
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        // Sample without TYPE/HELP.
        assert!(validate("nometa 1\n").is_err());
        // Duplicate TYPE.
        let doc = "# HELP m h\n# TYPE m counter\n# TYPE m counter\nm 1\n";
        assert!(validate(doc).is_err());
        // Negative counter.
        let doc = "# HELP m h\n# TYPE m counter\nm -1\n";
        assert!(validate(doc).is_err());
        // Histogram without +Inf.
        let doc = "# HELP h x\n# TYPE h histogram\nh_bucket{le=\"1\"} 1\nh_sum 1\nh_count 1\n";
        assert!(validate(doc).is_err());
        // Histogram with non-cumulative buckets.
        let doc = "# HELP h x\n# TYPE h histogram\nh_bucket{le=\"1\"} 5\nh_bucket{le=\"+Inf\"} 3\nh_sum 1\nh_count 3\n";
        assert!(validate(doc).is_err());
        // Count mismatch.
        let doc = "# HELP h x\n# TYPE h histogram\nh_bucket{le=\"+Inf\"} 3\nh_sum 1\nh_count 4\n";
        assert!(validate(doc).is_err());
        // A correct histogram passes.
        let doc = "# HELP h x\n# TYPE h histogram\nh_bucket{le=\"1\"} 1\nh_bucket{le=\"+Inf\"} 3\nh_sum 1.5\nh_count 3\n";
        validate(doc).unwrap();
    }

    #[test]
    fn quantile_interpolates() {
        // 10 obs ≤ 1, 10 more ≤ 2 (cumulative 20), 0 beyond.
        let series = vec![(1.0, 10.0), (2.0, 20.0), (f64::INFINITY, 20.0)];
        let p50 = quantile(&series, 0.5).unwrap();
        assert!((p50 - 1.0).abs() < 1e-9, "{p50}");
        let p75 = quantile(&series, 0.75).unwrap();
        assert!((p75 - 1.5).abs() < 1e-9, "{p75}");
        assert!(quantile(&[], 0.5).is_none());
        // Rank falling in +Inf reports the last finite bound.
        let series = vec![(1.0, 1.0), (f64::INFINITY, 10.0)];
        assert_eq!(quantile(&series, 0.99).unwrap(), 1.0);
    }
}
