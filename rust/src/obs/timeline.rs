//! Per-job timeline derivation: replay the bounded cluster [`EventLog`]
//! into a phase breakdown (`queued` → `running` → `draining` /
//! `crash_backoff` → … → terminal) for one job, served at
//! `GET /v1/jobs/<id>/timeline`.
//!
//! This is a **pure read-side view**: derivation walks the ring the engine
//! already maintains and writes nothing back, so it cannot perturb
//! determinism. Because the ring is bounded, a long-lived job's earliest
//! records may have been evicted; the timeline then starts at the oldest
//! retained record touching the job and is flagged [`JobTimeline::partial`].

use crate::engine::events::{EventKind, EventLog};
use crate::job::JobId;
use crate::util::json::Json;

/// Phase names, in the order a job can visit them. `crash_backoff` covers
/// the whole gap from a node crash until the next placement (the engine
/// emits no event when the backoff hold releases into the queue, so the
/// hold and the re-queue wait are indistinguishable from the log).
pub const PHASES: &[&str] = &["queued", "running", "draining", "crash_backoff"];

/// One contiguous span a job spent in a phase. `end_s` is `None` while the
/// span is still open (the job is currently in this phase).
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseSpan {
    pub phase: String,
    pub start_s: f64,
    pub end_s: Option<f64>,
}

/// A log record touching the job, referenced from the timeline so a client
/// can correlate spans with `/v1/cluster/events` cursors.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineEvent {
    pub seq: u64,
    pub time_s: f64,
    pub kind: String,
}

/// The derived per-job phase breakdown.
#[derive(Debug, Clone, PartialEq)]
pub struct JobTimeline {
    pub job: JobId,
    /// True when the ring evicted the job's earliest records — spans before
    /// the oldest retained record are missing and the sums undercount.
    pub partial: bool,
    /// True once a terminal record (`finished`/`rejected`/`cancelled`, or a
    /// non-requeued `oomed`) was seen.
    pub terminal: bool,
    pub phases: Vec<PhaseSpan>,
    pub events: Vec<TimelineEvent>,
    /// Placements observed in the retained window.
    pub placements: u64,
    pub ooms: u64,
    pub drains: u64,
    pub preemptions: u64,
    pub crashes: u64,
    /// Seconds summed per phase (open spans extend to `now_s`).
    pub queue_s: f64,
    pub run_s: f64,
    pub drain_s: f64,
    pub crash_backoff_s: f64,
    /// First retained record → terminal record (or `now_s` while live).
    pub total_s: f64,
    /// Engine-clock instant the derivation used to close open spans.
    pub now_s: f64,
}

/// Does this record concern `job`? Returns the phase-transition class.
enum Touch {
    /// Direct lifecycle event with a phase transition.
    Direct,
    /// Node-scope event whose `preempted` list contains the job.
    NodeCrash,
    /// Annotation only (no phase change).
    Note,
}

fn touches(kind: &EventKind, job: JobId) -> Option<Touch> {
    match kind {
        EventKind::Arrival { job: j }
        | EventKind::Placed { job: j, .. }
        | EventKind::Finished { job: j, .. }
        | EventKind::Oomed { job: j, .. }
        | EventKind::DrainRequested { job: j, .. }
        | EventKind::Drained { job: j, .. }
        | EventKind::Preempted { job: j, .. }
        | EventKind::Rejected { job: j, .. }
        | EventKind::Cancelled { job: j, .. } => (*j == job).then_some(Touch::Direct),
        EventKind::OomObserved { job: j, .. } | EventKind::ResumedFromCkpt { job: j, .. } => {
            (*j == job).then_some(Touch::Note)
        }
        EventKind::NodeCrashed { preempted, .. } => {
            preempted.contains(&job).then_some(Touch::NodeCrash)
        }
        // Graceful leaves are followed by per-job Preempted/Drained/Rejected
        // records, which carry the phase transition; the NodeLeft itself is
        // an annotation.
        EventKind::NodeLeft { preempted, .. } => preempted.contains(&job).then_some(Touch::Note),
        _ => None,
    }
}

/// Derive the timeline for `job` from the retained event ring. `now_s` is
/// the engine clock (virtual seconds in sim, seconds since start live);
/// open spans are measured up to it. Returns `None` when no retained
/// record touches the job at all.
pub fn derive(log: &EventLog, job: JobId, now_s: f64) -> Option<JobTimeline> {
    let mut tl = JobTimeline {
        job,
        partial: false,
        terminal: false,
        phases: Vec::new(),
        events: Vec::new(),
        placements: 0,
        ooms: 0,
        drains: 0,
        preemptions: 0,
        crashes: 0,
        queue_s: 0.0,
        run_s: 0.0,
        drain_s: 0.0,
        crash_backoff_s: 0.0,
        total_s: 0.0,
        now_s,
    };
    let mut open: Option<(&'static str, f64)> = None;
    let mut first_t: Option<f64> = None;
    let mut end_t: Option<f64> = None;
    let mut saw_arrival = false;

    fn close(tl: &mut JobTimeline, open: &mut Option<(&'static str, f64)>, t: f64) {
        if let Some((phase, start)) = open.take() {
            tl.phases.push(PhaseSpan { phase: phase.into(), start_s: start, end_s: Some(t) });
        }
    }

    for rec in log.iter() {
        let Some(touch) = touches(&rec.kind, job) else { continue };
        tl.events.push(TimelineEvent {
            seq: rec.seq,
            time_s: rec.time,
            kind: rec.kind.label().into(),
        });
        first_t.get_or_insert(rec.time);
        let t = rec.time;
        match touch {
            Touch::Note => {}
            Touch::NodeCrash => {
                tl.crashes += 1;
                close(&mut tl, &mut open, t);
                open = Some(("crash_backoff", t));
            }
            Touch::Direct => match &rec.kind {
                EventKind::Arrival { .. } => {
                    saw_arrival = true;
                    close(&mut tl, &mut open, t);
                    open = Some(("queued", t));
                }
                EventKind::Placed { .. } => {
                    tl.placements += 1;
                    close(&mut tl, &mut open, t);
                    open = Some(("running", t));
                }
                EventKind::DrainRequested { .. } => {
                    tl.drains += 1;
                    close(&mut tl, &mut open, t);
                    open = Some(("draining", t));
                }
                EventKind::Drained { .. } => {
                    close(&mut tl, &mut open, t);
                    open = Some(("queued", t));
                }
                EventKind::Preempted { .. } => {
                    tl.preemptions += 1;
                    close(&mut tl, &mut open, t);
                    open = Some(("queued", t));
                }
                EventKind::Oomed { requeued, .. } => {
                    tl.ooms += 1;
                    close(&mut tl, &mut open, t);
                    if *requeued {
                        open = Some(("queued", t));
                    }
                    // A non-requeued OOM is followed by a Rejected record,
                    // which marks the terminal instant.
                }
                EventKind::Finished { .. }
                | EventKind::Rejected { .. }
                | EventKind::Cancelled { .. } => {
                    close(&mut tl, &mut open, t);
                    tl.terminal = true;
                    end_t = Some(t);
                }
                _ => unreachable!("Touch::Direct covers only the kinds above"),
            },
        }
    }

    first_t?;
    // The job predates the retained window when its first record is not an
    // arrival, or the ring has evicted records before the first one we saw.
    let first_seen = tl.events.first().map(|e| e.seq).unwrap_or(0);
    tl.partial = !saw_arrival || (log.first_seq() > 1 && first_seen == log.first_seq());
    if let Some((phase, start)) = open {
        tl.phases.push(PhaseSpan { phase: phase.into(), start_s: start, end_s: None });
    }
    let horizon = end_t.unwrap_or(now_s);
    for span in &tl.phases {
        let d = (span.end_s.unwrap_or(horizon) - span.start_s).max(0.0);
        match span.phase.as_str() {
            "queued" => tl.queue_s += d,
            "running" => tl.run_s += d,
            "draining" => tl.drain_s += d,
            "crash_backoff" => tl.crash_backoff_s += d,
            _ => {}
        }
    }
    tl.total_s = (horizon - first_t.unwrap_or(horizon)).max(0.0);
    Some(tl)
}

impl JobTimeline {
    /// Wire form served by `GET /v1/jobs/<id>/timeline`.
    pub fn to_json(&self) -> Json {
        let phases: Vec<Json> = self
            .phases
            .iter()
            .map(|p| {
                let mut j = Json::obj();
                j.set("phase", p.phase.as_str()).set("start_s", p.start_s);
                match p.end_s {
                    Some(e) => j.set("end_s", e),
                    None => j.set("end_s", Json::Null),
                };
                j
            })
            .collect();
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut j = Json::obj();
                j.set("seq", e.seq).set("time_s", e.time_s).set("kind", e.kind.as_str());
                j
            })
            .collect();
        let mut j = Json::obj();
        j.set("job", self.job)
            .set("partial", self.partial)
            .set("terminal", self.terminal)
            .set("phases", Json::Arr(phases))
            .set("events", Json::Arr(events))
            .set("placements", self.placements)
            .set("ooms", self.ooms)
            .set("drains", self.drains)
            .set("preemptions", self.preemptions)
            .set("crashes", self.crashes)
            .set("queue_s", self.queue_s)
            .set("run_s", self.run_s)
            .set("drain_s", self.drain_s)
            .set("crash_backoff_s", self.crash_backoff_s)
            .set("total_s", self.total_s)
            .set("now_s", self.now_s);
        j
    }

    /// Inverse of [`JobTimeline::to_json`] (used by the SDK and tests).
    pub fn from_json(j: &Json) -> Result<JobTimeline, String> {
        fn num(j: &Json, k: &str) -> Result<f64, String> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing field '{k}'"))
        }
        fn n_u64(j: &Json, k: &str) -> Result<u64, String> {
            j.get(k).and_then(Json::as_u64).ok_or_else(|| format!("missing field '{k}'"))
        }
        fn boolean(j: &Json, k: &str) -> Result<bool, String> {
            j.get(k).and_then(Json::as_bool).ok_or_else(|| format!("missing field '{k}'"))
        }
        let phases_j = j.get("phases").and_then(Json::as_arr).ok_or("missing field 'phases'")?;
        let mut phases = Vec::with_capacity(phases_j.len());
        for p in phases_j {
            let end = match p.get("end_s") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().ok_or("bad end_s")?),
            };
            phases.push(PhaseSpan {
                phase: p
                    .get("phase")
                    .and_then(Json::as_str)
                    .ok_or("missing field 'phase'")?
                    .to_string(),
                start_s: num(p, "start_s")?,
                end_s: end,
            });
        }
        let events_j = j.get("events").and_then(Json::as_arr).ok_or("missing field 'events'")?;
        let mut events = Vec::with_capacity(events_j.len());
        for e in events_j {
            events.push(TimelineEvent {
                seq: n_u64(e, "seq")?,
                time_s: num(e, "time_s")?,
                kind: e
                    .get("kind")
                    .and_then(Json::as_str)
                    .ok_or("missing field 'kind'")?
                    .to_string(),
            });
        }
        Ok(JobTimeline {
            job: n_u64(j, "job")?,
            partial: boolean(j, "partial")?,
            terminal: boolean(j, "terminal")?,
            phases,
            events,
            placements: n_u64(j, "placements")?,
            ooms: n_u64(j, "ooms")?,
            drains: n_u64(j, "drains")?,
            preemptions: n_u64(j, "preemptions")?,
            crashes: n_u64(j, "crashes")?,
            queue_s: num(j, "queue_s")?,
            run_s: num(j, "run_s")?,
            drain_s: num(j, "drain_s")?,
            crash_backoff_s: num(j, "crash_backoff_s")?,
            total_s: num(j, "total_s")?,
            now_s: num(j, "now_s")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn placed(job: JobId) -> EventKind {
        EventKind::Placed {
            job,
            epoch: 1,
            attempts: 1,
            gpus: 2,
            d: 2,
            t: 1,
            parts: vec![(0, 2)],
            will_oom: false,
        }
    }

    #[test]
    fn happy_path_queue_then_run() {
        let mut log = EventLog::new(64);
        log.push(1.0, EventKind::Arrival { job: 7 });
        log.push(4.0, placed(7));
        log.push(10.0, EventKind::Finished { job: 7, epoch: 1 });
        let tl = derive(&log, 7, 20.0).expect("job present");
        assert!(!tl.partial);
        assert!(tl.terminal);
        assert_eq!(tl.placements, 1);
        assert_eq!(tl.phases.len(), 2);
        assert_eq!(tl.phases[0].phase, "queued");
        assert_eq!(tl.phases[0].end_s, Some(4.0));
        assert_eq!(tl.phases[1].phase, "running");
        assert!((tl.queue_s - 3.0).abs() < 1e-9);
        assert!((tl.run_s - 6.0).abs() < 1e-9);
        // Terminal jobs measure to the terminal record, not `now`.
        assert!((tl.total_s - 9.0).abs() < 1e-9);
    }

    #[test]
    fn open_span_measures_to_now() {
        let mut log = EventLog::new(64);
        log.push(0.0, EventKind::Arrival { job: 1 });
        log.push(2.0, placed(1));
        let tl = derive(&log, 1, 12.0).unwrap();
        assert!(!tl.terminal);
        assert_eq!(tl.phases.last().unwrap().end_s, None);
        assert!((tl.run_s - 10.0).abs() < 1e-9);
        assert!((tl.total_s - 12.0).abs() < 1e-9);
    }

    #[test]
    fn drain_and_crash_gaps_are_separate_phases() {
        let mut log = EventLog::new(64);
        log.push(0.0, EventKind::Arrival { job: 3 });
        log.push(1.0, placed(3));
        log.push(5.0, EventKind::DrainRequested { job: 3, epoch: 1, node: 0, deadline_s: 7.0 });
        let drained =
            EventKind::Drained { job: 3, epoch: 1, node: 0, steps_ckpt: 10, state_digest: 1 };
        log.push(7.0, drained);
        log.push(9.0, placed(3));
        log.push(11.0, EventKind::NodeCrashed { node: 0, preempted: vec![3] });
        log.push(15.0, placed(3));
        log.push(20.0, EventKind::Finished { job: 3, epoch: 3 });
        let tl = derive(&log, 3, 99.0).unwrap();
        assert_eq!(tl.drains, 1);
        assert_eq!(tl.crashes, 1);
        assert_eq!(tl.placements, 3);
        assert!((tl.drain_s - 2.0).abs() < 1e-9, "drain 5→7");
        assert!((tl.crash_backoff_s - 4.0).abs() < 1e-9, "crash 11→15");
        assert!((tl.queue_s - (1.0 + 2.0)).abs() < 1e-9, "0→1 and 7→9");
        assert!((tl.run_s - (4.0 + 2.0 + 5.0)).abs() < 1e-9);
    }

    #[test]
    fn eviction_sets_partial() {
        let mut log = EventLog::new(2);
        log.push(0.0, EventKind::Arrival { job: 5 });
        log.push(1.0, placed(5));
        log.push(2.0, EventKind::Finished { job: 5, epoch: 1 });
        // Arrival evicted: first retained record for job 5 is the placement.
        let tl = derive(&log, 5, 10.0).unwrap();
        assert!(tl.partial);
        assert!(tl.terminal);
        assert_eq!(tl.phases[0].phase, "running");
    }

    #[test]
    fn absent_job_is_none() {
        let mut log = EventLog::new(8);
        log.push(0.0, EventKind::Arrival { job: 1 });
        assert!(derive(&log, 2, 5.0).is_none());
    }

    #[test]
    fn oom_requeue_returns_to_queue() {
        let mut log = EventLog::new(64);
        log.push(0.0, EventKind::Arrival { job: 9 });
        log.push(1.0, placed(9));
        log.push(3.0, EventKind::Oomed { job: 9, epoch: 1, requeued: true });
        log.push(6.0, placed(9));
        let tl = derive(&log, 9, 8.0).unwrap();
        assert_eq!(tl.ooms, 1);
        let kinds: Vec<&str> = tl.phases.iter().map(|p| p.phase.as_str()).collect();
        assert_eq!(kinds, vec!["queued", "running", "queued", "running"]);
        assert!((tl.queue_s - (1.0 + 3.0)).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let mut log = EventLog::new(64);
        log.push(0.5, EventKind::Arrival { job: 4 });
        log.push(2.5, placed(4));
        let tl = derive(&log, 4, 9.0).unwrap();
        let text = tl.to_json().to_string_compact();
        let back = JobTimeline::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, tl);
    }
}
