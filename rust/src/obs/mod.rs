//! Process-wide telemetry: lock-free counters, gauges, and fixed-bucket
//! histograms, rendered as Prometheus text at `GET /metrics`.
//!
//! Design constraints, in priority order:
//!
//! 1. **Determinism is untouchable.** Telemetry is write-only from the
//!    system's point of view: no scheduling decision, WAL record, snapshot,
//!    or journaled engine field ever reads a metric. Wall-clock phase
//!    timings recorded here never enter deterministic state — the engine's
//!    journaled `sched_wall_s` record (PR 6) is produced exactly as before,
//!    independent of this module. Flipping [`set_enabled`] changes nothing
//!    but whether atomics are bumped (a differential test pins this).
//! 2. **Lock-free on the hot path.** Every per-request / per-append /
//!    per-round record is a handful of relaxed atomic ops on
//!    pre-registered metrics. The only lock in the module guards the
//!    per-node gauge maps ([`DynGauges`]), written once per coordinator
//!    loop iteration and read at scrape time — never on a hot path.
//! 3. **One registry per process.** Tests that spawn several coordinators
//!    in one process share the registry; counters aggregate across them.
//!    That matches Prometheus semantics (a scrape sees the process, not a
//!    logical instance) and keeps registration allocation-free after the
//!    first use.

pub mod expo;
pub mod timeline;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{OnceLock, RwLock};

/// Global recording switch. Rendering still works when disabled — the
/// families and label sets are pre-registered — but no new values land.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Turn recording on/off process-wide (the metrics-on vs metrics-off
/// differential test flips this; operators never need to).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Monotonic counter. `store` exists for values mirrored from an
/// authoritative monotonic source (e.g. `RunAggregates` counts published
/// once per coordinator loop) — the source is monotonic, so the exposed
/// series is too.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn store(&self, v: u64) {
        if enabled() {
            self.0.store(v, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Integer gauge (can go up and down); stored as the two's-complement
/// bits of an `i64` so `add`/`sub` stay single atomic ops.
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn set(&self, v: i64) {
        if enabled() {
            self.0.store(v as u64, Ordering::Relaxed);
        }
    }

    pub fn add(&self, n: i64) {
        if enabled() {
            self.0.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    pub fn sub(&self, n: i64) {
        self.add(-n);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed) as i64
    }
}

/// Float gauge (f64 bits in an atomic; last-writer-wins set only).
#[derive(Default)]
pub struct GaugeF(AtomicU64);

impl GaugeF {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn set(&self, v: f64) {
        if enabled() {
            self.0.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram: per-bucket atomic counts plus a sum kept in
/// micro-units (for seconds histograms that is microseconds — overflow at
/// ~584k years of accumulated latency). Buckets are *non*-cumulative in
/// memory; the renderer accumulates them into Prometheus' cumulative
/// `le` form.
pub struct Histogram {
    bounds: &'static [f64],
    /// `bounds.len() + 1` slots; the last is the `+Inf` overflow bucket.
    counts: Vec<AtomicU64>,
    sum_micros: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: &'static [f64]) -> Self {
        let counts = (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect();
        Self { bounds, counts, sum_micros: AtomicU64::new(0), count: AtomicU64::new(0) }
    }

    pub fn observe(&self, v: f64) {
        if !enabled() {
            return;
        }
        let idx =
            self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add((v.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn bounds(&self) -> &'static [f64] {
        self.bounds
    }

    /// Per-bucket (non-cumulative) counts, `+Inf` last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn sum(&self) -> f64 {
        self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

/// Gauge family with one dynamic integer label (node ids): replaced
/// wholesale by the coordinator once per loop iteration, read at scrape.
/// The lock is deliberate — this is not a hot path (see module docs).
#[derive(Default)]
pub struct DynGauges {
    map: RwLock<std::collections::BTreeMap<u64, f64>>,
}

impl DynGauges {
    pub fn set_all(&self, entries: impl IntoIterator<Item = (u64, f64)>) {
        if !enabled() {
            return;
        }
        let mut m = self.map.write().expect("obs gauge map poisoned");
        m.clear();
        m.extend(entries);
    }

    pub fn snapshot(&self) -> Vec<(u64, f64)> {
        self.map
            .read()
            .expect("obs gauge map poisoned")
            .iter()
            .map(|(&k, &v)| (k, v))
            .collect()
    }
}

/// Latency bucket bounds in seconds: a 1–2.5–5 decade ladder from 1µs to
/// 2.5s (`+Inf` catches the rest). Shared by every latency histogram so
/// dashboards can compare families bucket-for-bucket.
pub const LATENCY_BOUNDS: &[f64] = &[
    1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2, 1e-1, 2.5e-1, 5e-1, 1.0, 2.5,
];

/// Normalized route labels, pre-registered so per-request recording never
/// allocates or locks. Unknown paths fall into `"other"`.
pub const ROUTES: &[&str] = &[
    "/metrics",
    "/v1/healthz",
    "/v1/cluster",
    "/v1/cluster/events",
    "/v1/cluster/scale",
    "/v1/cluster/heartbeat",
    "/v1/jobs",
    "/v1/jobs:batch",
    "/v1/jobs/<id>",
    "/v1/jobs/<id>/cancel",
    "/v1/jobs/<id>/timeline",
    "/v1/predict",
    "/v1/report",
    "/v1/durability",
    "/v1/version",
    "other",
];

/// Map a normalized request path to its pre-registered route label.
pub fn route_label(path: &str) -> &'static str {
    if let Some(rest) = path.strip_prefix("/v1/jobs/") {
        if !rest.is_empty() {
            return if rest.ends_with("/cancel") {
                "/v1/jobs/<id>/cancel"
            } else if rest.ends_with("/timeline") {
                "/v1/jobs/<id>/timeline"
            } else if !rest.contains('/') {
                "/v1/jobs/<id>"
            } else {
                "other"
            };
        }
    }
    ROUTES.iter().find(|&&r| r == path).copied().unwrap_or("other")
}

/// Per-route request metrics.
pub struct RouteMetrics {
    pub route: &'static str,
    /// Requests by status class; index 0..=4 ↔ 1xx..5xx.
    pub by_class: [Counter; 5],
    pub latency: Histogram,
}

pub struct HttpMetrics {
    pub routes: Vec<RouteMetrics>,
    pub inflight: Gauge,
    /// Load shed at the acceptor (queue full → 503, request unread).
    pub shed_503: Counter,
    /// Admission throttles answered 429 (backpressure or quota).
    pub shed_429: Counter,
    pub sse_connections: Counter,
}

impl HttpMetrics {
    fn new() -> Self {
        let routes = ROUTES
            .iter()
            .map(|&route| RouteMetrics {
                route,
                by_class: Default::default(),
                latency: Histogram::new(LATENCY_BOUNDS),
            })
            .collect();
        Self {
            routes,
            inflight: Gauge::new(),
            shed_503: Counter::new(),
            shed_429: Counter::new(),
            sse_connections: Counter::new(),
        }
    }

    pub fn route(&self, label: &str) -> &RouteMetrics {
        self.routes
            .iter()
            .find(|r| r.route == label)
            .unwrap_or_else(|| self.routes.last().expect("\"other\" route registered"))
    }

    /// Record one served request (count by status class + latency).
    pub fn record(&self, route: &'static str, status: u16, seconds: f64) {
        let r = self.route(route);
        let class = ((status / 100).clamp(1, 5) - 1) as usize;
        r.by_class[class].inc();
        r.latency.observe(seconds);
        if status == 429 {
            self.shed_429.inc();
        }
    }
}

pub struct CoordMetrics {
    /// Messages sent to the coordinator mailbox and not yet received.
    pub mailbox_depth: Gauge,
    pub messages_total: Counter,
    /// Admission outcomes; `admitted` is incremented at the decision
    /// point, the throttle/reject counts mirror the coordinator's
    /// authoritative counters once per loop.
    pub admitted_total: Counter,
    pub throttled_backpressure_total: Counter,
    pub throttled_quota_total: Counter,
    pub rejected_infeasible_total: Counter,
}

impl CoordMetrics {
    fn new() -> Self {
        Self {
            mailbox_depth: Gauge::new(),
            messages_total: Counter::new(),
            admitted_total: Counter::new(),
            throttled_backpressure_total: Counter::new(),
            throttled_quota_total: Counter::new(),
            rejected_infeasible_total: Counter::new(),
        }
    }
}

/// The scheduler-phase split (candidate-scan / plan-rank / placement) and
/// the per-event-kind audit counters. Phase timings are wall-clock
/// *observations* on both the sim and live paths; they are never written
/// into journaled state (the engine's `sched_wall_s` record is produced
/// independently, exactly as before this module existed).
pub struct EngineMetrics {
    pub rounds_total: Counter,
    pub phase_candidate_scan: Histogram,
    pub phase_plan_rank: Histogram,
    pub phase_placement: Histogram,
    pub work_units_total: Counter,
    pub jobs_queued: Gauge,
    pub jobs_running: Gauge,
    /// `(wire kind label, counter)` for every [`EventKind`] variant.
    ///
    /// [`EventKind`]: crate::engine::events::EventKind
    pub events: Vec<(&'static str, Counter)>,
}

/// Wire labels of every `EventKind` variant (the same strings the event
/// log's JSON codec emits).
pub const EVENT_KINDS: &[&str] = &[
    "arrival",
    "placed",
    "finished",
    "oomed",
    "oom_observed",
    "drain_requested",
    "drained",
    "resumed_from_ckpt",
    "preempted",
    "rejected",
    "cancelled",
    "node_joined",
    "node_left",
    "node_retired",
    "node_crash",
    "node_quarantined",
    "node_probation",
    "node_slowdown",
];

impl EngineMetrics {
    fn new() -> Self {
        Self {
            rounds_total: Counter::new(),
            phase_candidate_scan: Histogram::new(LATENCY_BOUNDS),
            phase_plan_rank: Histogram::new(LATENCY_BOUNDS),
            phase_placement: Histogram::new(LATENCY_BOUNDS),
            work_units_total: Counter::new(),
            jobs_queued: Gauge::new(),
            jobs_running: Gauge::new(),
            events: EVENT_KINDS.iter().map(|&k| (k, Counter::new())).collect(),
        }
    }

    pub fn event(&self, kind: &str) -> Option<&Counter> {
        self.events.iter().find(|(k, _)| *k == kind).map(|(_, c)| c)
    }
}

pub struct DurabilityMetrics {
    pub wal_appends_total: Counter,
    pub wal_append_bytes_total: Counter,
    /// Latency of `fsync` (`sync_data`) calls on the active WAL segment.
    pub fsync_seconds: Histogram,
    pub wal_segments: Gauge,
    pub wal_bytes: Gauge,
    pub snapshots_total: Counter,
    pub snapshot_age_seconds: GaugeF,
    pub snapshot_covered_seq: Gauge,
}

impl DurabilityMetrics {
    fn new() -> Self {
        Self {
            wal_appends_total: Counter::new(),
            wal_append_bytes_total: Counter::new(),
            fsync_seconds: Histogram::new(LATENCY_BOUNDS),
            wal_segments: Gauge::new(),
            wal_bytes: Gauge::new(),
            snapshots_total: Counter::new(),
            snapshot_age_seconds: GaugeF::new(),
            snapshot_covered_seq: Gauge::new(),
        }
    }
}

pub struct RuntimeMetrics {
    /// Device-memory bytes pinned per node (label: node id).
    pub device_mem_used: DynGauges,
    /// Per-GPU device-memory capacity per node (label: node id).
    pub device_mem_capacity: DynGauges,
    pub oom_events_total: Counter,
    pub drains_total: Counter,
    pub crash_requeues_total: Counter,
    pub quarantines_total: Counter,
    pub mem_pred_samples_total: Counter,
    pub mem_pred_accuracy_avg: GaugeF,
    pub mem_pred_accuracy_min: GaugeF,
}

impl RuntimeMetrics {
    fn new() -> Self {
        Self {
            device_mem_used: DynGauges::default(),
            device_mem_capacity: DynGauges::default(),
            oom_events_total: Counter::new(),
            drains_total: Counter::new(),
            crash_requeues_total: Counter::new(),
            quarantines_total: Counter::new(),
            mem_pred_samples_total: Counter::new(),
            mem_pred_accuracy_avg: GaugeF::new(),
            mem_pred_accuracy_min: GaugeF::new(),
        }
    }
}

/// The process-wide registry. All families and static label sets are
/// built eagerly on first access, so a scrape always renders the full
/// schema (with zero values) even before any traffic.
pub struct Registry {
    pub http: HttpMetrics,
    pub coord: CoordMetrics,
    pub engine: EngineMetrics,
    pub durability: DurabilityMetrics,
    pub runtime: RuntimeMetrics,
    start: std::time::Instant,
}

impl Registry {
    fn new() -> Self {
        Self {
            http: HttpMetrics::new(),
            coord: CoordMetrics::new(),
            engine: EngineMetrics::new(),
            durability: DurabilityMetrics::new(),
            runtime: RuntimeMetrics::new(),
            start: std::time::Instant::now(),
        }
    }

    /// Seconds since the registry was first touched (≈ process uptime).
    /// Render-time only; never feeds back into the system.
    pub fn uptime_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// The process-wide registry (created on first use).
pub fn reg() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::new)
}

/// Crate version baked in at compile time.
pub fn crate_version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Git commit the binary was built from (`build.rs` asks `git rev-parse`;
/// builds outside a checkout report `"unknown"`).
pub fn git_sha() -> &'static str {
    match option_env!("FRENZY_GIT_SHA") {
        Some(s) if !s.is_empty() => s,
        _ => "unknown",
    }
}

/// Subsystems compiled into this build, reported by `GET /v1/version`
/// (there are no cargo features — the list names the shipped
/// capabilities so fleet debugging can distinguish binary generations).
pub const FEATURES: &[&str] =
    &["durability", "sse", "faults", "tenancy", "workload-gen", "obs"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_roundtrip() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.add(3);
        g.sub(5);
        assert_eq!(g.get(), -2);
        g.set(7);
        assert_eq!(g.get(), 7);
        let f = GaugeF::new();
        f.set(0.923);
        assert!((f.get() - 0.923).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets_and_sum() {
        let h = Histogram::new(&[0.001, 0.01, 0.1]);
        h.observe(0.0005); // bucket 0
        h.observe(0.001); // le is inclusive: bucket 0
        h.observe(0.05); // bucket 2
        h.observe(10.0); // +Inf
        assert_eq!(h.bucket_counts(), vec![2, 0, 1, 1]);
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 10.0515).abs() < 1e-3, "{}", h.sum());
    }

    #[test]
    fn route_labels_normalize() {
        assert_eq!(route_label("/v1/jobs"), "/v1/jobs");
        assert_eq!(route_label("/v1/jobs/42"), "/v1/jobs/<id>");
        assert_eq!(route_label("/v1/jobs/42/cancel"), "/v1/jobs/<id>/cancel");
        assert_eq!(route_label("/v1/jobs/42/timeline"), "/v1/jobs/<id>/timeline");
        assert_eq!(route_label("/metrics"), "/metrics");
        assert_eq!(route_label("/nope"), "other");
        assert_eq!(route_label("/v1/jobs/1/2/3"), "other");
    }

    #[test]
    fn disabled_recording_is_inert_but_renderable() {
        let c = Counter::new();
        set_enabled(false);
        c.inc();
        assert_eq!(c.get(), 0);
        set_enabled(true);
        c.inc();
        assert_eq!(c.get(), 1);
    }

    #[test]
    fn dyn_gauges_replace_wholesale() {
        let d = DynGauges::default();
        d.set_all([(0, 1.0), (1, 2.0)]);
        d.set_all([(1, 3.0)]);
        assert_eq!(d.snapshot(), vec![(1, 3.0)]);
    }

    #[test]
    fn event_kind_labels_cover_every_variant() {
        use crate::engine::events::EventKind;
        // Compile-time-ish guard: every variant's label is registered.
        let samples: Vec<EventKind> = vec![
            EventKind::Arrival { job: 1 },
            EventKind::Finished { job: 1, epoch: 1 },
            EventKind::NodeRetired { node: 0 },
        ];
        for s in samples {
            assert!(
                EVENT_KINDS.contains(&s.label()),
                "unregistered event kind {}",
                s.label()
            );
        }
    }
}
