//! # Frenzy
//!
//! A memory-aware **serverless** LLM training system for heterogeneous GPU
//! clusters — a full reproduction of Chang et al. (CS.DC 2024) as a
//! three-layer rust + JAX + Pallas stack.
//!
//! Users submit *models*, not GPU requests:
//!
//! ```no_run
//! use frenzy::config::{models::model_by_name, real_testbed};
//! use frenzy::marp::Marp;
//! use frenzy::memory::TrainConfig;
//!
//! let marp = Marp::with_defaults(real_testbed());
//! let model = model_by_name("gpt2-7b").unwrap();
//! for plan in marp.plans(&model, &TrainConfig { global_batch: 2 }) {
//!     println!("{} GPUs of ≥{} bytes (d={}, t={})",
//!              plan.n_gpus, plan.min_gpu_mem, plan.par.d, plan.par.t);
//! }
//! ```
//!
//! Architecture (one-page map in `ARCHITECTURE.md`; design rationale in
//! DESIGN.md):
//! * [`memory`] / [`marp`] — the Memory-Aware Resource Predictor (§IV.A),
//! * [`sched`] — HAS (Algorithm 1) plus the Sia and Opportunistic baselines,
//! * [`cluster`] — the Resource Orchestrator (with elastic grow/shrink)
//!   and the incrementally maintained [`cluster::CapacityIndex`] that makes
//!   scheduling rounds sub-linear in cluster size,
//! * [`engine`] — the unified event-driven scheduling engine: one
//!   [`engine::ClusterEvent`] loop (arrival, finish, OOM, round ticks,
//!   node join/leave) behind a clock abstraction, shared by the simulator
//!   and the live coordinator; it folds results into streaming
//!   [`metrics::RunAggregates`] and records every event in a bounded
//!   [`engine::EventLog`] audit ring,
//! * [`durability`] — crash recovery for the live coordinator: a
//!   checksummed write-ahead log of every [`engine::ClusterEvent`],
//!   atomic snapshots, and pure-replay recovery (`frenzy serve
//!   --data-dir`),
//! * [`sim`] — discrete-event cluster simulator (the "PAI simulator"
//!   stand-in): a thin trace feeder over [`engine`] on a virtual clock,
//! * [`faults`] — deterministic chaos: a seeded [`faults::FaultPlan`]
//!   (crashes, heartbeat blackouts, stragglers, checkpoint-write
//!   failures) injected through the normal event path on either clock
//!   (`frenzy replay --faults`, `frenzy serve --faults`),
//! * [`workload`] — NewWorkload / Philly / Helios generators,
//! * [`serverless`] — the v1 control plane: coordinator (round-timer
//!   thread for interval schedulers, live OOM modeling for the baselines)
//!   plus [`serverless::api`] (typed DTOs), [`serverless::server`]
//!   (thread-pool HTTP front-end), and [`serverless::client`] (the
//!   blocking Rust SDK). Observability rides along: the event log at
//!   `GET /v1/cluster/events` and the streaming report at
//!   `GET /v1/report`. Every route is documented with request/response
//!   examples in `API.md` at the repository root,
//! * [`runtime`] — PJRT executor running the AOT-compiled JAX/Pallas
//!   training step (the request path never touches python),
//! * [`metrics`] — streaming run aggregates → [`metrics::RunReport`],
//! * [`obs`] — process-wide telemetry: lock-free counters/gauges/histograms
//!   rendered as Prometheus text at `GET /metrics`, plus per-job phase
//!   timelines (`GET /v1/jobs/<id>/timeline`) derived from the event log
//!   and the `frenzy top` live dashboard — write-only by design so
//!   telemetry can never perturb deterministic replay,
//! * [`exp`] — harnesses regenerating every figure in the paper.

pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod durability;
pub mod engine;
pub mod exp;
pub mod faults;
pub mod ilp;
pub mod job;
pub mod marp;
pub mod memory;
pub mod metrics;
pub mod obs;
pub mod perfmodel;
pub mod runtime;
pub mod sched;
pub mod serverless;
pub mod sim;
pub mod util;
pub mod workload;
