//! The unified event-driven scheduling engine (one control plane, two
//! drivers).
//!
//! Before this module existed the control loop lived twice: the discrete
//! event simulator had a private event loop (arrival/finish/OOM-requeue,
//! overhead charging) and the live serverless coordinator re-implemented
//! pending-queue management, dispatch, and release. [`SchedulingEngine`]
//! owns all of it once:
//!
//! * the [`crate::cluster::Orchestrator`] (authoritative resource state),
//! * the pending queue and per-job attempt counters,
//! * the active [`Scheduler`] policy,
//! * run metrics — folded **incrementally** into
//!   [`crate::metrics::RunAggregates`] (per-state counters, JCT histogram,
//!   queueing delay, OOM counts) so a long-running coordinator's memory
//!   stays bounded; there is no per-job outcome vector,
//! * the bounded [`events::EventLog`]: an audit ring of every event and
//!   effect (arrivals, placements with the chosen plan, finishes, OOMs,
//!   preemptions, rejections with reason, node joins/leaves), exposed live
//!   via `GET /v1/cluster/events`.
//!
//! State changes enter as one [`ClusterEvent`] enum — `Arrival`, `Finish`,
//! `Oom`, `RoundTick`, plus the elastic `NodeJoin` / `NodeLeave` (a leave
//! preempts and requeues every job allocated on that node, releasing
//! resources exactly once). The engine is driven through the
//! [`clock::Clock`] abstraction:
//!
//! * [`clock::VirtualClock`] — simulation: the engine's own Finish/Oom
//!   predictions are scheduled back into the clock's event heap and
//!   [`crate::sim::Simulator`] is a thin trace-feeding wrapper;
//! * [`clock::WallClock`] — live: the coordinator translates executor
//!   messages into events and dispatches the [`Effects::placed`] jobs to
//!   the real [`crate::runtime::executor::TrainExecutor`].
//!
//! Because both paths run this exact code, any new policy or scenario
//! (elasticity, priorities, trace replay) is written once and behaves
//! identically in simulation and in the live server — the differential
//! trace test in `tests/integration_engine.rs` asserts exactly that.

pub mod clock;
pub mod events;

pub use events::{EventKind, EventLog, EventRecord, EventsPage, RejectReason};

use crate::cluster::{ClusterState, NodeId, Orchestrator};
use crate::config::{ClusterSpec, NodeSpec};
use crate::job::{JobId, JobSpec};
use crate::metrics::RunAggregates;
use crate::perfmodel::PerfModel;
use crate::sched::{PendingJob, PendingQueue, Scheduler};
use clock::Clock;
use std::collections::{HashMap, VecDeque};

/// Everything that can happen to the cluster, in one enum — the union of
/// the simulator's old private event set and the live coordinator's
/// message handling, plus cluster elasticity.
#[derive(Debug, Clone)]
pub enum ClusterEvent {
    /// A job enters the pending queue.
    Arrival(JobSpec),
    /// A running job completed. `epoch` is the placement epoch the
    /// completion belongs to (see [`PlacedJob::epoch`]); a stale epoch —
    /// the job was preempted or cancelled and possibly re-placed since —
    /// is ignored, so resources are never released twice.
    Finish { job: JobId, epoch: u64 },
    /// A memory-oblivious placement crashed; resources are released and the
    /// job requeues with `attempts + 1` (the baselines' trial-and-error).
    Oom { job: JobId, epoch: u64 },
    /// Round boundary for interval schedulers (Sia-style).
    RoundTick,
    /// Elasticity: a node joins the cluster, its GPUs immediately idle.
    NodeJoin(NodeSpec),
    /// Elasticity: a node leaves. Every job with any GPUs on it is
    /// preempted — released exactly once and requeued with `attempts + 1`.
    NodeLeave(NodeId),
}

/// Engine tuning knobs (the scheduling-relevant subset of the old
/// `SimConfig`; the live coordinator uses `sched_work_unit_s = 0` because
/// real scheduler wall time already elapses on its clock).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Seconds before an OOM is detected and the job is requeued.
    pub oom_detect_s: f64,
    /// Seconds charged per scheduler work unit (models the paper's
    /// scheduling-overhead effect in virtual time).
    pub sched_work_unit_s: f64,
    /// Hard cap on scheduling attempts (OOM retries / preemptions) before a
    /// job is rejected.
    pub max_attempts: u32,
    /// Retention policy for terminal-job bookkeeping: per-job maps
    /// (`epochs`, `submit_times`, `first_starts`) keep entries for at most
    /// this many *terminal* jobs, oldest-terminal-first eviction. Bounds a
    /// long-running coordinator's memory; running/pending jobs are never
    /// evicted. The run's result *aggregates*
    /// ([`crate::metrics::RunAggregates`]) are O(1) and never evicted.
    pub retain_terminal: usize,
    /// Capacity of the [`EventLog`] ring (records retained; sequence
    /// numbers stay monotonic across eviction).
    pub event_log_cap: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            oom_detect_s: 45.0,
            sched_work_unit_s: 2.0e-5,
            max_attempts: 6,
            retain_terminal: 16_384,
            event_log_cap: 65_536,
        }
    }
}

/// One job the engine just placed. In virtual time the engine has already
/// scheduled the matching `Finish`/`Oom` into the clock; on a wall clock the
/// driver must dispatch the job and later feed back
/// `ClusterEvent::Finish { job, epoch }`.
#[derive(Debug, Clone)]
pub struct PlacedJob {
    pub job: JobId,
    /// Placement epoch: increments every time this job starts. Completions
    /// must echo it so results from a preempted/cancelled run are discarded.
    pub epoch: u64,
    /// Scheduling attempts including this one (1 on first placement).
    pub attempts: u32,
    pub gpus: u32,
    /// When the job starts (now + modeled scheduling overhead).
    pub start_time: f64,
    /// The placement will OOM (memory-oblivious baselines only).
    pub will_oom: bool,
    /// Throughput estimate from the performance model (0 when `will_oom`).
    pub est_samples_per_sec: f64,
    /// Estimated runtime (OOM-detection delay when `will_oom`).
    pub est_runtime_s: f64,
}

/// What one event (plus the scheduling round it triggered) did — the
/// driver's window into the engine.
#[derive(Debug, Clone, Default)]
pub struct Effects {
    /// Jobs that started running (dispatch these on a wall clock).
    pub placed: Vec<PlacedJob>,
    /// Jobs that completed (resources released, outcome recorded).
    pub finished: Vec<JobId>,
    /// Jobs rejected (attempt budget exhausted or structurally unplaceable).
    pub rejected: Vec<JobId>,
    /// Jobs preempted by a `NodeLeave` and returned to the pending queue.
    pub preempted: Vec<JobId>,
}

impl Effects {
    pub fn merge(&mut self, mut other: Effects) {
        self.placed.append(&mut other.placed);
        self.finished.append(&mut other.finished);
        self.rejected.append(&mut other.rejected);
        self.preempted.append(&mut other.preempted);
    }
}

/// One applied placement: job → sorted `(node, gpu-count)` parts.
pub type PlacementRecord = (JobId, Vec<(NodeId, u32)>);

/// Bounded tracker of terminal jobs, shared by the engine and the live
/// coordinator: ids are noted in the order they go terminal, and each note
/// returns the ids that fell past the retention cap so the caller can drop
/// its per-job bookkeeping for them (oldest-terminal-first eviction).
#[derive(Debug)]
pub struct RetentionQueue {
    order: VecDeque<JobId>,
    cap: usize,
}

impl RetentionQueue {
    pub fn new(cap: usize) -> Self {
        Self { order: VecDeque::new(), cap }
    }

    /// Record `id` as terminal; returns the evicted ids (beyond the cap).
    pub fn note(&mut self, id: JobId) -> Vec<JobId> {
        self.order.push_back(id);
        let excess = self.order.len().saturating_sub(self.cap);
        self.order.drain(..excess).collect()
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }
}

/// Cap on [`SchedulingEngine::decision_log`] entries: a long-running live
/// coordinator must not leak memory linearly in placements, so the log
/// keeps only the most recent records (the oldest half is dropped when the
/// cap is hit). Per-job bookkeeping (`epochs`, `submit_times`,
/// `first_starts`) is bounded separately by
/// [`EngineConfig::retain_terminal`].
pub const MAX_DECISION_LOG: usize = 65_536;

struct RunningJob {
    spec: JobSpec,
    first_start: f64,
    gpus: u32,
    attempts: u32,
    epoch: u64,
}

/// GPU-time utilization integrator. Integrates capacity as well as busy
/// GPU-seconds so the denominator stays correct when the cluster grows or
/// shrinks mid-run.
struct UtilIntegrator {
    last_t: f64,
    busy_gpu_seconds: f64,
    capacity_gpu_seconds: f64,
}

impl UtilIntegrator {
    fn new() -> Self {
        Self { last_t: 0.0, busy_gpu_seconds: 0.0, capacity_gpu_seconds: 0.0 }
    }

    fn advance(&mut self, now: f64, busy: u32, total: u32) {
        let dt = (now - self.last_t).max(0.0);
        self.busy_gpu_seconds += dt * busy as f64;
        self.capacity_gpu_seconds += dt * total as f64;
        self.last_t = self.last_t.max(now);
    }

    fn value(&self) -> f64 {
        if self.capacity_gpu_seconds <= 0.0 {
            0.0
        } else {
            (self.busy_gpu_seconds / self.capacity_gpu_seconds).clamp(0.0, 1.0)
        }
    }
}

/// The shared scheduling engine. See the module docs for the division of
/// labor between the engine and its drivers.
pub struct SchedulingEngine<'a> {
    orch: Orchestrator,
    sched: &'a mut dyn Scheduler,
    pm: PerfModel,
    cfg: EngineConfig,
    pending: PendingQueue,
    running: HashMap<JobId, RunningJob>,
    /// Streaming run metrics — O(1) memory regardless of job count.
    agg: RunAggregates,
    /// Bounded audit ring of everything that happened.
    events: EventLog,
    work_units: u64,
    sched_wall_s: f64,
    util: UtilIntegrator,
    submit_times: HashMap<JobId, f64>,
    first_starts: HashMap<JobId, f64>,
    epochs: HashMap<JobId, u64>,
    /// Eviction queue for [`EngineConfig::retain_terminal`].
    retention: RetentionQueue,
    /// Every applied placement, in order: (job, sorted (node, gpus) parts).
    decision_log: Vec<PlacementRecord>,
    /// Interval schedulers: time of the last executed round and whether a
    /// RoundTick is already queued in a virtual clock.
    last_round: f64,
    tick_queued: bool,
}

impl<'a> SchedulingEngine<'a> {
    pub fn new(spec: &ClusterSpec, sched: &'a mut dyn Scheduler, cfg: EngineConfig) -> Self {
        let retention = RetentionQueue::new(cfg.retain_terminal);
        let events = EventLog::new(cfg.event_log_cap);
        Self {
            orch: Orchestrator::new(spec),
            sched,
            pm: PerfModel::new(spec.inter_node_gbps),
            cfg,
            pending: PendingQueue::new(),
            running: HashMap::new(),
            agg: RunAggregates::new(),
            events,
            work_units: 0,
            sched_wall_s: 0.0,
            util: UtilIntegrator::new(),
            submit_times: HashMap::new(),
            first_starts: HashMap::new(),
            epochs: HashMap::new(),
            retention,
            decision_log: Vec::new(),
            last_round: f64::NEG_INFINITY,
            tick_queued: false,
        }
    }

    fn busy_gpus(&self) -> u32 {
        self.orch.state().total_gpus() - self.orch.state().idle_gpus()
    }

    fn advance_util(&mut self, now: f64) {
        let busy = self.busy_gpus();
        let total = self.orch.state().total_gpus();
        self.util.advance(now, busy, total);
    }

    /// Process one event. Does **not** run a scheduling round — drivers call
    /// [`Self::run_round`] after the event (or event batch) so batched
    /// same-timestamp events see one round, exactly like the old simulator.
    pub fn handle(&mut self, ev: ClusterEvent, clock: &mut dyn Clock) -> Effects {
        let now = clock.now();
        self.advance_util(now);
        let mut fx = Effects::default();
        match ev {
            ClusterEvent::Arrival(spec) => {
                self.submit_times.insert(spec.id, spec.submit_time);
                self.events.push(now, EventKind::Arrival { job: spec.id });
                self.pending.push(PendingJob { spec, attempts: 0 });
            }
            ClusterEvent::Finish { job, epoch } => {
                if self.running.get(&job).is_none_or(|r| r.epoch != epoch) {
                    return fx; // stale: preempted/cancelled since this run started
                }
                let run = self.running.remove(&job).expect("checked above");
                let _ = self.orch.release(job);
                let submit = *self.submit_times.get(&job).unwrap_or(&0.0);
                let sps = run.spec.total_samples as f64 / (now - run.first_start).max(1e-9);
                self.agg.record_completed(submit, run.first_start, now, sps, run.attempts);
                self.events.push(now, EventKind::Finished { job, epoch });
                self.note_terminal(job);
                fx.finished.push(job);
            }
            ClusterEvent::Oom { job, epoch } => {
                if self.running.get(&job).is_none_or(|r| r.epoch != epoch) {
                    return fx;
                }
                let run = self.running.remove(&job).expect("checked above");
                let _ = self.orch.release(job);
                self.agg.record_oom_event();
                let requeued = run.attempts < self.cfg.max_attempts;
                self.events.push(now, EventKind::Oomed { job, epoch, requeued });
                if requeued {
                    self.pending.push(PendingJob { spec: run.spec, attempts: run.attempts });
                } else {
                    self.reject(now, job, RejectReason::AttemptsExhausted, &mut fx);
                }
            }
            ClusterEvent::RoundTick => {
                self.tick_queued = false;
            }
            ClusterEvent::NodeJoin(node) => {
                let gpu = node.gpu.name.to_string();
                let gpus = node.count;
                let id = self.orch.grow(&node);
                self.events.push(now, EventKind::NodeJoined { node: id, gpu, gpus });
                self.sched.cluster_changed(self.orch.state());
            }
            ClusterEvent::NodeLeave(node) => {
                if let Ok(released) = self.orch.shrink(node) {
                    let displaced: Vec<JobId> = released.iter().map(|a| a.job).collect();
                    self.events
                        .push(now, EventKind::NodeLeft { node, preempted: displaced });
                    for alloc in released {
                        let Some(run) = self.running.remove(&alloc.job) else { continue };
                        if run.attempts >= self.cfg.max_attempts {
                            self.reject(now, alloc.job, RejectReason::AttemptsExhausted, &mut fx);
                        } else {
                            self.events
                                .push(now, EventKind::Preempted { job: alloc.job, node });
                            self.pending
                                .push(PendingJob { spec: run.spec, attempts: run.attempts });
                            fx.preempted.push(alloc.job);
                        }
                    }
                    self.sched.cluster_changed(self.orch.state());
                }
            }
        }
        fx
    }

    /// Record a rejection everywhere it must land: aggregates, event log,
    /// retention, and the driver-visible effects.
    fn reject(&mut self, now: f64, job: JobId, reason: RejectReason, fx: &mut Effects) {
        self.agg.record_rejected();
        self.events.push(now, EventKind::Rejected { job, reason });
        self.note_terminal(job);
        fx.rejected.push(job);
    }

    /// Run one scheduling round over the pending queue, then reject
    /// structurally unplaceable jobs. Interval schedulers (Sia-style) defer
    /// to a queued `RoundTick` on a virtual clock, or to the driver's
    /// round-timer thread on a timer-backed wall clock
    /// ([`Clock::delivers_ticks`]); on a bare wall clock — no way to receive
    /// a future tick — they round immediately instead.
    pub fn run_round(&mut self, clock: &mut dyn Clock) -> Effects {
        let mut fx = Effects::default();
        let now = clock.now();
        self.advance_util(now);
        if let Some(interval) = self.sched.round_interval_s() {
            if self.pending.is_empty() {
                return fx;
            }
            let due = self.last_round + interval;
            if now < due {
                if !self.tick_queued && clock.schedule(due, ClusterEvent::RoundTick) {
                    self.tick_queued = true;
                }
                if self.tick_queued || clock.delivers_ticks() {
                    return fx;
                }
            }
            self.last_round = now;
        }
        self.round_inner(clock, &mut fx);
        self.reject_unplaceable(clock, &mut fx);
        fx
    }

    /// The placement pass. The scheduler plans against the orchestrator's
    /// live state + capacity index through a borrowed [`ClusterView`] —
    /// no cluster snapshot is cloned per round.
    ///
    /// [`ClusterView`]: crate::cluster::ClusterView
    fn round_inner(&mut self, clock: &mut dyn Clock, fx: &mut Effects) {
        if self.pending.is_empty() {
            return;
        }
        let now = clock.now();
        let t0 = std::time::Instant::now();
        let round = {
            let view = self.orch.view();
            self.sched.schedule(&self.pending, &view, now)
        };
        self.sched_wall_s += t0.elapsed().as_secs_f64();
        self.work_units += round.work_units;
        let overhead = round.work_units as f64 * self.cfg.sched_work_unit_s;
        let start_time = now + overhead;

        for d in round.decisions {
            let Some(pj) = self.pending.remove(d.job) else {
                continue; // scheduler returned a stale decision — ignore
            };
            if self.orch.allocate(d.alloc.clone()).is_err() {
                // Scheduler overdrew (bug or stale snapshot): requeue.
                self.pending.push(pj);
                continue;
            }
            let attempts = pj.attempts + 1;
            let epoch = {
                let e = self.epochs.entry(d.job).or_insert(0);
                *e += 1;
                *e
            };
            let first_start = *self.first_starts.entry(d.job).or_insert(start_time);
            let mut parts = d.alloc.parts.clone();
            parts.sort_unstable();
            if self.decision_log.len() >= MAX_DECISION_LOG {
                self.decision_log.drain(..MAX_DECISION_LOG / 2);
            }
            self.decision_log.push((d.job, parts.clone()));
            let gpus = d.alloc.total_gpus();
            let (will_oom, thr, runtime) = if d.will_oom {
                (true, 0.0, self.cfg.oom_detect_s)
            } else {
                let thr = self.pm.samples_per_sec(
                    &pj.spec.model,
                    &pj.spec.train,
                    d.par,
                    &d.gpu,
                    d.placement,
                );
                (false, thr, pj.spec.total_samples as f64 / thr.max(1e-9))
            };
            self.events.push(
                now,
                EventKind::Placed {
                    job: d.job,
                    epoch,
                    attempts,
                    gpus,
                    d: d.par.d,
                    t: d.par.t,
                    parts,
                    will_oom: d.will_oom,
                },
            );
            self.running.insert(
                d.job,
                RunningJob { spec: pj.spec.clone(), first_start, gpus, attempts, epoch },
            );
            if will_oom {
                clock.schedule(
                    start_time + self.cfg.oom_detect_s,
                    ClusterEvent::Oom { job: d.job, epoch },
                );
            } else {
                clock.schedule(start_time + runtime, ClusterEvent::Finish { job: d.job, epoch });
            }
            fx.placed.push(PlacedJob {
                job: d.job,
                epoch,
                attempts,
                gpus,
                start_time,
                will_oom,
                est_samples_per_sec: thr,
                est_runtime_s: runtime,
            });
        }
    }

    /// If the cluster is completely idle and the scheduler still can't place
    /// a job, it never will — reject it instead of busy-looping. (A job that
    /// exceeded its attempt budget is also dropped here.) Feasibility is a
    /// single [`Scheduler::can_place`] probe per job against the capacity
    /// index — no snapshot clones and no per-job placement rounds.
    fn reject_unplaceable(&mut self, clock: &mut dyn Clock, fx: &mut Effects) {
        if !(self.running.is_empty()
            && self.orch.state().idle_gpus() == self.orch.state().total_gpus()
            && !self.pending.is_empty())
        {
            return;
        }
        let now = clock.now();
        let drained = self.pending.drain();
        let mut keep = Vec::new();
        let mut rejects: Vec<(JobId, RejectReason)> = Vec::new();
        {
            let view = self.orch.view();
            for p in drained {
                if p.attempts >= self.cfg.max_attempts {
                    rejects.push((p.spec.id, RejectReason::AttemptsExhausted));
                } else if self.sched.can_place(&p, &view, now) {
                    keep.push(p);
                } else {
                    rejects.push((p.spec.id, RejectReason::Unplaceable));
                }
            }
        }
        for (id, reason) in rejects {
            self.reject(now, id, reason, fx);
        }
        for p in keep {
            self.pending.push(p);
        }
        if !self.pending.is_empty() {
            // They are placeable on an empty cluster; place them now.
            self.round_inner(clock, fx);
        }
    }

    /// Record that `job` reached a terminal state and evict the oldest
    /// terminal jobs' bookkeeping beyond [`EngineConfig::retain_terminal`].
    fn note_terminal(&mut self, job: JobId) {
        for old in self.retention.note(job) {
            self.epochs.remove(&old);
            self.submit_times.remove(&old);
            self.first_starts.remove(&old);
        }
    }

    /// Remove a queued job (user cancel). True when it was pending.
    pub fn cancel_pending(&mut self, id: JobId, now: f64) -> bool {
        if self.pending.remove(id).is_some() {
            self.agg.record_cancelled();
            self.events.push(now, EventKind::Cancelled { job: id, was_running: false });
            self.note_terminal(id);
            true
        } else {
            false
        }
    }

    /// Cancel a running job: release its resources without recording a
    /// completion. Any in-flight `Finish`/`Oom` for the old epoch goes
    /// stale.
    pub fn cancel_running(&mut self, id: JobId, now: f64) -> bool {
        if self.running.remove(&id).is_none() {
            return false;
        }
        let _ = self.orch.release(id);
        self.agg.record_cancelled();
        self.events.push(now, EventKind::Cancelled { job: id, was_running: true });
        self.note_terminal(id);
        true
    }

    /// Drain the pending queue into rejections (end-of-run bookkeeping:
    /// whatever is still pending never got resources). Logged as
    /// [`RejectReason::RunEnded`] — these jobs may have been placeable, the
    /// run just stopped first.
    pub fn reject_remaining(&mut self, now: f64) -> Vec<JobId> {
        let ids: Vec<JobId> = self.pending.drain().into_iter().map(|p| p.spec.id).collect();
        let mut fx = Effects::default();
        for &id in &ids {
            self.reject(now, id, RejectReason::RunEnded, &mut fx);
        }
        ids
    }

    // ---- introspection -------------------------------------------------

    pub fn scheduler_name(&self) -> &'static str {
        self.sched.name()
    }

    pub fn cluster_state(&self) -> &ClusterState {
        self.orch.state()
    }

    pub fn conservation_ok(&self) -> bool {
        self.orch.check_conservation()
    }

    /// The run's streaming metrics (replaces the old unbounded per-job
    /// outcome vector).
    pub fn aggregates(&self) -> &RunAggregates {
        &self.agg
    }

    /// The bounded audit ring of everything that happened.
    pub fn event_log(&self) -> &EventLog {
        &self.events
    }

    /// Append a driver-originated record to the event log (e.g. the live
    /// coordinator's admission-control rejections, which never reach the
    /// engine's queue). Returns the assigned sequence number.
    pub fn record_event(&mut self, time: f64, kind: EventKind) -> u64 {
        self.events.push(time, kind)
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    pub fn rejected_count(&self) -> usize {
        self.agg.n_rejected
    }

    pub fn work_units(&self) -> u64 {
        self.work_units
    }

    pub fn sched_wall_s(&self) -> f64 {
        self.sched_wall_s
    }

    pub fn is_running(&self, id: JobId) -> bool {
        self.running.contains_key(&id)
    }

    pub fn is_pending(&self, id: JobId) -> bool {
        self.pending.contains(id)
    }

    /// Scheduling attempts recorded for a job so far (running or pending).
    pub fn attempts_of(&self, id: JobId) -> u32 {
        if let Some(r) = self.running.get(&id) {
            return r.attempts;
        }
        self.pending.get(id).map(|p| p.attempts).unwrap_or(0)
    }

    /// Current placement epoch of a job (0 if never placed, or if the job
    /// went terminal long enough ago that its bookkeeping was evicted under
    /// [`EngineConfig::retain_terminal`]).
    pub fn run_epoch(&self, id: JobId) -> u64 {
        self.epochs.get(&id).copied().unwrap_or(0)
    }

    /// Terminal jobs whose bookkeeping is still retained (tests).
    pub fn retained_terminal(&self) -> usize {
        self.retention.len()
    }

    /// The applied-placement log, most recent [`MAX_DECISION_LOG`] entries.
    pub fn decision_log(&self) -> &[PlacementRecord] {
        &self.decision_log
    }

    /// GPU-time utilization integral up to `now` (advances the integrator).
    pub fn utilization_to(&mut self, now: f64) -> f64 {
        self.advance_util(now);
        self.util.value()
    }
}

#[cfg(test)]
mod tests {
    use super::clock::VirtualClock;
    use super::*;
    use crate::config::models::model_by_name;
    use crate::config::{gpu_by_name, real_testbed, LinkKind};
    use crate::marp::Marp;
    use crate::sched::has::Has;

    fn job(id: u64, model: &str, batch: u32, samples: u64, t: f64) -> JobSpec {
        JobSpec::new(id, model_by_name(model).unwrap(), batch, samples, t)
    }

    /// Drain the virtual clock to completion.
    fn drive(engine: &mut SchedulingEngine, clock: &mut VirtualClock) -> Effects {
        let mut all = Effects::default();
        let mut guard = 0;
        while let Some((_, ev)) = clock.pop() {
            all.merge(engine.handle(ev, clock));
            all.merge(engine.run_round(clock));
            guard += 1;
            assert!(guard < 100_000, "event loop did not terminate");
        }
        all
    }

    #[test]
    fn arrival_place_finish_roundtrip() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();
        clock.schedule(0.0, ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)));
        let fx = drive(&mut engine, &mut clock);
        assert_eq!(fx.placed.len(), 1);
        assert_eq!(fx.finished, vec![1]);
        assert!(fx.rejected.is_empty());
        assert_eq!(engine.aggregates().n_completed, 1);
        assert!(engine.conservation_ok());
        assert_eq!(engine.cluster_state().idle_gpus(), engine.cluster_state().total_gpus());
        // The audit trail tells the whole story, in order.
        let kinds: Vec<&EventKind> = engine.event_log().iter().map(|r| &r.kind).collect();
        assert!(matches!(kinds[0], EventKind::Arrival { job: 1 }));
        assert!(matches!(kinds[1], EventKind::Placed { job: 1, epoch: 1, will_oom: false, .. }));
        assert!(matches!(kinds[2], EventKind::Finished { job: 1, epoch: 1 }));
        let seqs: Vec<u64> = engine.event_log().iter().map(|r| r.seq).collect();
        assert!(seqs.windows(2).all(|w| w[1] == w[0] + 1), "dense monotonic seqs: {seqs:?}");
    }

    #[test]
    fn stale_finish_epoch_is_ignored() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();
        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)), &mut clock);
        let fx = engine.run_round(&mut clock);
        assert_eq!(fx.placed.len(), 1);
        let epoch = fx.placed[0].epoch;
        // A completion from a previous (never-existing) epoch must not
        // release anything.
        let stale = engine.handle(ClusterEvent::Finish { job: 1, epoch: epoch + 7 }, &mut clock);
        assert!(stale.finished.is_empty());
        assert!(engine.is_running(1));
        assert!(engine.conservation_ok());
        // The real epoch completes it.
        let good = engine.handle(ClusterEvent::Finish { job: 1, epoch }, &mut clock);
        assert_eq!(good.finished, vec![1]);
        assert!(engine.conservation_ok());
    }

    #[test]
    fn node_leave_preempts_exactly_the_jobs_on_that_node() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();
        // Big job lands on 80G nodes, small job on a 40G node — disjoint.
        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-7b", 2, 1_000_000, 0.0)), &mut clock);
        engine.handle(ClusterEvent::Arrival(job(2, "gpt2-125m", 4, 1_000_000, 0.0)), &mut clock);
        let fx = engine.run_round(&mut clock);
        assert_eq!(fx.placed.len(), 2, "both jobs must start");
        let big_nodes: Vec<usize> = engine
            .decision_log()
            .iter()
            .find(|(id, _)| *id == 1)
            .unwrap()
            .1
            .iter()
            .map(|&(n, _)| n)
            .collect();
        let small_nodes: Vec<usize> = engine
            .decision_log()
            .iter()
            .find(|(id, _)| *id == 2)
            .unwrap()
            .1
            .iter()
            .map(|&(n, _)| n)
            .collect();
        assert!(big_nodes.iter().all(|n| !small_nodes.contains(n)), "disjoint placements");

        let gone = big_nodes[0];
        let fx = engine.handle(ClusterEvent::NodeLeave(gone), &mut clock);
        assert_eq!(fx.preempted, vec![1], "only the job on the retired node is preempted");
        assert!(engine.is_pending(1), "preempted job requeued");
        assert!(engine.is_running(2), "unrelated job untouched");
        assert_eq!(engine.attempts_of(1), 1, "requeued with its attempt count (next run = 2)");
        assert!(engine.conservation_ok(), "conservation after NodeLeave");

        // The remaining 80G GPUs (2×2) can host the job again.
        let fx = engine.run_round(&mut clock);
        if let Some(p) = fx.placed.iter().find(|p| p.job == 1) {
            assert_eq!(p.attempts, 2, "re-placement counts as attempt 2");
        }
        assert!(engine.conservation_ok());

        // Run everything down: preempted job must still terminate exactly
        // once, and its stale Finish from the first placement is discarded.
        drive(&mut engine, &mut clock);
        assert!(engine.conservation_ok());
        let finishes_of_1 = engine
            .event_log()
            .iter()
            .filter(|r| matches!(r.kind, EventKind::Finished { job: 1, .. }))
            .count();
        assert!(finishes_of_1 <= 1, "a preempted job completes at most once");
        // The leave is auditable: a NodeLeft naming job 1 and a matching
        // Preempted record.
        assert!(engine.event_log().iter().any(
            |r| matches!(&r.kind, EventKind::NodeLeft { preempted, .. } if preempted == &vec![1])
        ));
        assert!(engine
            .event_log()
            .iter()
            .any(|r| matches!(r.kind, EventKind::Preempted { job: 1, .. })));
        assert_eq!(engine.cluster_state().idle_gpus(), engine.cluster_state().total_gpus());
    }

    #[test]
    fn node_join_makes_infeasible_pending_job_schedulable() {
        // A cluster with only 2×40G GPUs cannot host gpt2-7b at all (MARP
        // finds no plan). Keep the cluster busy with a small job so the big
        // one is not rejected-as-unplaceable, then join an 80G node.
        let a100_40 = gpu_by_name("A100-40G").unwrap();
        let spec = ClusterSpec {
            name: "tiny".into(),
            nodes: vec![NodeSpec { gpu: a100_40, count: 2, link: LinkKind::Pcie }],
            inter_node_gbps: 12.5,
        };
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();

        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-125m", 4, 1_000_000, 0.0)), &mut clock);
        let fx = engine.run_round(&mut clock);
        assert_eq!(fx.placed.len(), 1, "blocker job runs");

        engine.handle(ClusterEvent::Arrival(job(2, "gpt2-7b", 2, 50_000, 0.0)), &mut clock);
        let fx = engine.run_round(&mut clock);
        assert!(fx.placed.is_empty(), "7b infeasible on 2×40G");
        assert!(engine.is_pending(2));

        let a800 = gpu_by_name("A800-80G").unwrap();
        let join = NodeSpec { gpu: a800, count: 4, link: LinkKind::NvLink };
        let fx = engine.handle(ClusterEvent::NodeJoin(join), &mut clock);
        assert!(fx.placed.is_empty() && fx.preempted.is_empty());
        assert_eq!(engine.cluster_state().total_gpus(), 6);
        let fx = engine.run_round(&mut clock);
        let placed: Vec<JobId> = fx.placed.iter().map(|p| p.job).collect();
        assert_eq!(placed, vec![2], "NodeJoin made the pending 7b job schedulable");
        // It landed on the joined node (id 1).
        let (_, parts) = engine.decision_log().iter().find(|(id, _)| *id == 2).unwrap();
        assert!(parts.iter().all(|&(n, _)| n == 1), "placed on the joined 80G node: {parts:?}");
        assert!(engine.conservation_ok());
    }

    #[test]
    fn terminal_retention_evicts_old_bookkeeping() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let cfg = EngineConfig { retain_terminal: 2, ..EngineConfig::default() };
        let mut engine = SchedulingEngine::new(&spec, &mut has, cfg);
        let mut clock = VirtualClock::new();
        for i in 0..5u64 {
            clock.schedule(
                i as f64 * 10_000.0,
                ClusterEvent::Arrival(job(i, "gpt2-350m", 8, 1_000, i as f64 * 10_000.0)),
            );
        }
        drive(&mut engine, &mut clock);
        assert_eq!(engine.aggregates().n_completed, 5, "aggregates are O(1) — never evicted");
        assert_eq!(engine.retained_terminal(), 2, "only the 2 newest terminal jobs tracked");
        assert_eq!(engine.run_epoch(0), 0, "evicted terminal job's epoch dropped");
        assert!(engine.run_epoch(4) >= 1, "recent terminal job retained");
        assert!(engine.conservation_ok());
    }

    #[test]
    fn conservation_holds_after_every_event_under_churn() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();
        for i in 0..8u64 {
            clock.schedule(
                i as f64 * 20.0,
                ClusterEvent::Arrival(job(i, "gpt2-350m", 8, 200_000, i as f64 * 20.0)),
            );
        }
        // Churn: retire a 40G node early, join a replacement later.
        clock.schedule(30.0, ClusterEvent::NodeLeave(0));
        let a100_40 = gpu_by_name("A100-40G").unwrap();
        let rejoin = NodeSpec { gpu: a100_40, count: 2, link: LinkKind::Pcie };
        clock.schedule(90.0, ClusterEvent::NodeJoin(rejoin));
        let mut guard = 0;
        while let Some((_, ev)) = clock.pop() {
            engine.handle(ev, &mut clock);
            assert!(engine.conservation_ok(), "conservation after every event");
            engine.run_round(&mut clock);
            assert!(engine.conservation_ok(), "conservation after every round");
            guard += 1;
            assert!(guard < 100_000);
        }
        assert_eq!(
            engine.aggregates().n_completed + engine.rejected_count(),
            8,
            "every job reaches a terminal state"
        );
        assert_eq!(engine.cluster_state().idle_gpus(), engine.cluster_state().total_gpus());
    }

    #[test]
    fn interval_scheduler_defers_on_timer_backed_wall_clock() {
        use super::clock::WallClock;
        use crate::sched::sia::Sia;
        let spec = crate::config::sia_sim();
        let mut sia = Sia::new(&spec);
        sia.round_interval = 1_000.0; // far beyond this test's wall time
        let mut engine = SchedulingEngine::new(&spec, &mut sia, EngineConfig::default());
        let mut wall = WallClock::with_round_timer();
        // First round ever is immediate (last_round = -inf).
        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)), &mut wall);
        let fx = engine.run_round(&mut wall);
        assert_eq!(fx.placed.len(), 1, "first round executes immediately");
        // A second arrival inside the interval must WAIT for the timer's
        // RoundTick instead of rounding immediately.
        engine.handle(ClusterEvent::Arrival(job(2, "gpt2-350m", 8, 10_000, 0.0)), &mut wall);
        let fx = engine.run_round(&mut wall);
        assert!(fx.placed.is_empty(), "deferred to the round timer");
        assert!(engine.is_pending(2));
        // On a bare wall clock (no timer thread) deferring would stall
        // forever, so the engine rounds immediately — the pre-timer
        // behavior.
        let mut sia2 = Sia::new(&spec);
        sia2.round_interval = 1_000.0;
        let mut engine2 = SchedulingEngine::new(&spec, &mut sia2, EngineConfig::default());
        let mut bare = WallClock::new();
        engine2.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)), &mut bare);
        engine2.run_round(&mut bare);
        engine2.handle(ClusterEvent::Arrival(job(2, "gpt2-350m", 8, 10_000, 0.0)), &mut bare);
        let fx = engine2.run_round(&mut bare);
        assert_eq!(fx.placed.len(), 1, "bare wall clock rounds immediately");
    }

    #[test]
    fn cancelled_jobs_count_in_aggregates_and_events() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();
        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)), &mut clock);
        engine.handle(ClusterEvent::Arrival(job(2, "gpt2-350m", 8, 10_000, 0.0)), &mut clock);
        let fx = engine.run_round(&mut clock);
        assert_eq!(fx.placed.len(), 2);
        assert!(engine.cancel_running(1, clock.now()));
        assert!(!engine.cancel_running(1, clock.now()), "already cancelled");
        assert_eq!(engine.aggregates().n_cancelled, 1);
        assert!(engine
            .event_log()
            .iter()
            .any(|r| matches!(r.kind, EventKind::Cancelled { job: 1, was_running: true })));
        drive(&mut engine, &mut clock);
        assert_eq!(engine.aggregates().n_completed, 1, "only job 2 completes");
        assert!(engine.conservation_ok());
    }
}
