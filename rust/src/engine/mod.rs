//! The unified event-driven scheduling engine (one control plane, two
//! drivers).
//!
//! Before this module existed the control loop lived twice: the discrete
//! event simulator had a private event loop (arrival/finish/OOM-requeue,
//! overhead charging) and the live serverless coordinator re-implemented
//! pending-queue management, dispatch, and release. [`SchedulingEngine`]
//! owns all of it once:
//!
//! * the [`crate::cluster::Orchestrator`] (authoritative resource state),
//! * the pending queue and per-job attempt counters,
//! * the active [`Scheduler`] policy,
//! * run metrics — folded **incrementally** into
//!   [`crate::metrics::RunAggregates`] (per-state counters, JCT histogram,
//!   queueing delay, OOM counts) so a long-running coordinator's memory
//!   stays bounded; there is no per-job outcome vector,
//! * the bounded [`events::EventLog`]: an audit ring of every event and
//!   effect (arrivals, placements with the chosen plan, finishes, OOMs,
//!   preemptions, rejections with reason, node joins/leaves), exposed live
//!   via `GET /v1/cluster/events`.
//!
//! State changes enter as one [`ClusterEvent`] enum — `Arrival`, `Finish`,
//! `Oom`, `RoundTick`, plus the elastic `NodeJoin` / `NodeLeave` and the
//! drain completion `Drained`. A leave either preempts instantly
//! (releasing resources exactly once) or, with
//! [`EngineConfig::drain_grace_s`] set, drains gracefully: hosted jobs
//! finish their in-flight step, checkpoint
//! ([`crate::runtime::checkpoint`]), release, and requeue with their
//! progress preserved. Dispatches charge observed peak bytes against the
//! [`crate::runtime::device::DeviceMemory`] ledger
//! ([`EngineConfig::device_memory`]), so out-of-memory is an *observed*
//! event (`oom_observed`) rather than a scripted timer, and every
//! placement contributes a predicted-vs-observed accuracy sample to the
//! run aggregates. The engine is driven through the [`clock::Clock`]
//! abstraction:
//!
//! * [`clock::VirtualClock`] — simulation: the engine's own Finish/Oom
//!   predictions are scheduled back into the clock's event heap and
//!   [`crate::sim::Simulator`] is a thin trace-feeding wrapper;
//! * [`clock::WallClock`] — live: the coordinator translates executor
//!   messages into events and dispatches the [`Effects::placed`] jobs to
//!   the real [`crate::runtime::executor::TrainExecutor`].
//!
//! Because both paths run this exact code, any new policy or scenario
//! (elasticity, priorities, trace replay) is written once and behaves
//! identically in simulation and in the live server — the differential
//! trace test in `tests/integration_engine.rs` asserts exactly that.

pub mod clock;
pub mod events;

pub use events::{EventKind, EventLog, EventRecord, EventsPage, RejectReason};

use crate::cluster::{ClusterError, ClusterState, NodeId, Orchestrator};
use crate::config::{ClusterSpec, NodeSpec};
use crate::job::{JobId, JobSpec};
use crate::memory::{exact, marp_peak_bytes, Parallelism};
use crate::metrics::RunAggregates;
use crate::perfmodel::PerfModel;
use crate::runtime::checkpoint::{self, Checkpoint, CheckpointStore};
use crate::runtime::device::DeviceMemory;
use crate::sched::{PendingJob, PendingQueue, Scheduler};
use crate::util::json::Json;
use crate::util::prng::SplitMix64;
use clock::Clock;
use std::collections::{BTreeMap, HashMap, VecDeque};

/// Everything that can happen to the cluster, in one enum — the union of
/// the simulator's old private event set and the live coordinator's
/// message handling, plus cluster elasticity.
#[derive(Debug, Clone)]
pub enum ClusterEvent {
    /// A job enters the pending queue.
    Arrival(JobSpec),
    /// A running job completed. `epoch` is the placement epoch the
    /// completion belongs to (see [`PlacedJob::epoch`]); a stale epoch —
    /// the job was preempted or cancelled and possibly re-placed since —
    /// is ignored, so resources are never released twice.
    Finish { job: JobId, epoch: u64 },
    /// A memory-oblivious placement crashed; resources are released and the
    /// job requeues with `attempts + 1` (the baselines' trial-and-error).
    Oom { job: JobId, epoch: u64 },
    /// Round boundary for interval schedulers (Sia-style).
    RoundTick,
    /// Elasticity: a node joins the cluster, its GPUs immediately idle.
    NodeJoin(NodeSpec),
    /// Elasticity: a node leaves. With graceful drain disabled
    /// (`EngineConfig::drain_grace_s == 0`) every job with any GPUs on it
    /// is preempted instantly — released exactly once and requeued with
    /// `attempts + 1`. With drain enabled the node stops accepting
    /// placements and each hosted job gets a `DrainRequested` deadline
    /// instead; its GPUs release when the matching [`Self::Drained`]
    /// arrives.
    NodeLeave(NodeId),
    /// A draining job finished its in-flight step and wrote its
    /// checkpoint: release its GPUs, reap the retiring node, and requeue
    /// the job with its progress preserved. Stale epochs (the job
    /// finished, OOMed, or was cancelled since the drain request) are
    /// ignored.
    Drained { job: JobId, epoch: u64 },
    /// User cancellation. Routing cancels through the event path (instead
    /// of the old direct [`SchedulingEngine::cancel_pending`] /
    /// [`SchedulingEngine::cancel_running`] calls) means the durability
    /// WAL captures them like every other transition, so crash recovery is
    /// *pure replay* — no side channel mutates engine state. A cancel for
    /// a job that is neither pending nor running is a no-op.
    Cancel { job: JobId },
    /// Abrupt node failure — a missed heartbeat lease, or fault injection.
    /// Unlike the operator-initiated [`Self::NodeLeave`] there is **no**
    /// drain grace: every hosted job dies mid-step, loses its work back to
    /// the last checkpoint floor, and re-enters placement after a capped
    /// exponential crash-backoff hold *without* burning an attempt (the
    /// node failed, not the job). The node's capacity stays in the cluster
    /// (idle) — a crashed node may recover, flap, or be quarantined.
    NodeCrash(NodeId),
    /// A crash-backoff hold expired: move the held job back to the pending
    /// queue. Self-scheduled on a virtual clock; delivered by the driver
    /// from an [`Effects::requeue_after`] directive on a wall clock.
    Requeue { job: JobId },
    /// A quarantined node's probation ended: it accepts placements again.
    /// Self-scheduled on a virtual clock; delivered by the driver from an
    /// [`Effects::probation_after`] directive on a wall clock.
    Probation { node: NodeId },
    /// Straggler injection: new placements touching `node` run at `factor`
    /// × modeled throughput (`factor = 1` ends the slowdown). Running jobs
    /// keep their original estimate — the degradation applies at placement
    /// time.
    Slowdown { node: NodeId, factor: f64 },
    /// Checkpoint writes on `node` fail until `until_s`: a drain or crash
    /// inside the window falls back to the last checkpoint that was
    /// actually written instead of the current floor.
    CkptFail { node: NodeId, until_s: f64 },
}

impl ClusterEvent {
    /// Serialize for the durability WAL.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match self {
            ClusterEvent::Arrival(spec) => {
                // The full spec (not just the id): replaying an Arrival must
                // reconstruct the job exactly, submit time included.
                j.set("kind", "arrival").set("spec", spec.to_json());
            }
            ClusterEvent::Finish { job, epoch } => {
                j.set("kind", "finish").set("job", *job).set("epoch", *epoch);
            }
            ClusterEvent::Oom { job, epoch } => {
                j.set("kind", "oom").set("job", *job).set("epoch", *epoch);
            }
            ClusterEvent::RoundTick => {
                j.set("kind", "round_tick");
            }
            ClusterEvent::NodeJoin(node) => {
                j.set("kind", "node_join")
                    .set("gpu", node.gpu.name)
                    .set("count", node.count)
                    .set("link", match node.link {
                        crate::config::LinkKind::NvLink => "nvlink",
                        crate::config::LinkKind::Pcie => "pcie",
                    });
            }
            ClusterEvent::NodeLeave(node) => {
                j.set("kind", "node_leave").set("node", *node);
            }
            ClusterEvent::Drained { job, epoch } => {
                j.set("kind", "drained").set("job", *job).set("epoch", *epoch);
            }
            ClusterEvent::Cancel { job } => {
                j.set("kind", "cancel").set("job", *job);
            }
            ClusterEvent::NodeCrash(node) => {
                j.set("kind", "node_crash").set("node", *node);
            }
            ClusterEvent::Requeue { job } => {
                j.set("kind", "requeue").set("job", *job);
            }
            ClusterEvent::Probation { node } => {
                j.set("kind", "probation").set("node", *node);
            }
            ClusterEvent::Slowdown { node, factor } => {
                j.set("kind", "slowdown").set("node", *node).set("factor", *factor);
            }
            ClusterEvent::CkptFail { node, until_s } => {
                j.set("kind", "ckpt_fail").set("node", *node).set("until_s", *until_s);
            }
        }
        j
    }

    /// Rebuild from [`ClusterEvent::to_json`] output.
    pub fn from_json(j: &Json) -> Result<ClusterEvent, String> {
        let kind = j.get("kind").and_then(Json::as_str).ok_or("event: missing 'kind'")?;
        let job = || j.get("job").and_then(Json::as_u64).ok_or("event: missing 'job'");
        let epoch = || j.get("epoch").and_then(Json::as_u64).ok_or("event: missing 'epoch'");
        let node = || {
            j.get("node")
                .and_then(Json::as_u64)
                .and_then(|n| usize::try_from(n).ok())
                .ok_or("event: missing 'node'")
        };
        Ok(match kind {
            "arrival" => ClusterEvent::Arrival(JobSpec::from_json(
                j.get("spec").ok_or("arrival: missing 'spec'")?,
            )?),
            "finish" => ClusterEvent::Finish { job: job()?, epoch: epoch()? },
            "oom" => ClusterEvent::Oom { job: job()?, epoch: epoch()? },
            "round_tick" => ClusterEvent::RoundTick,
            "node_join" => {
                let name =
                    j.get("gpu").and_then(Json::as_str).ok_or("node_join: missing 'gpu'")?;
                let gpu = crate::config::gpu_by_name(name)
                    .ok_or_else(|| format!("node_join: unknown gpu '{name}'"))?;
                let count = j
                    .get("count")
                    .and_then(Json::as_u64)
                    .and_then(|c| u32::try_from(c).ok())
                    .ok_or("node_join: missing 'count'")?;
                let link = match j.get("link").and_then(Json::as_str) {
                    Some("nvlink") => crate::config::LinkKind::NvLink,
                    Some("pcie") => crate::config::LinkKind::Pcie,
                    other => return Err(format!("node_join: bad link {other:?}")),
                };
                ClusterEvent::NodeJoin(NodeSpec { gpu, count, link })
            }
            "node_leave" => ClusterEvent::NodeLeave(
                j.get("node")
                    .and_then(Json::as_u64)
                    .and_then(|n| usize::try_from(n).ok())
                    .ok_or("node_leave: missing 'node'")?,
            ),
            "drained" => ClusterEvent::Drained { job: job()?, epoch: epoch()? },
            "cancel" => ClusterEvent::Cancel { job: job()? },
            "node_crash" => ClusterEvent::NodeCrash(node()?),
            "requeue" => ClusterEvent::Requeue { job: job()? },
            "probation" => ClusterEvent::Probation { node: node()? },
            "slowdown" => ClusterEvent::Slowdown {
                node: node()?,
                factor: j
                    .get("factor")
                    .and_then(Json::as_f64)
                    .ok_or("slowdown: missing 'factor'")?,
            },
            "ckpt_fail" => ClusterEvent::CkptFail {
                node: node()?,
                until_s: j
                    .get("until_s")
                    .and_then(Json::as_f64)
                    .ok_or("ckpt_fail: missing 'until_s'")?,
            },
            other => return Err(format!("event: unknown kind '{other}'")),
        })
    }
}

/// Engine tuning knobs (the scheduling-relevant subset of the old
/// `SimConfig`; the live coordinator uses `sched_work_unit_s = 0` because
/// real scheduler wall time already elapses on its clock).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Seconds before an OOM is detected and the job is requeued — the
    /// *fallback* timer, used only when [`EngineConfig::device_memory`] is
    /// off and the engine must trust the scheduler's `will_oom` flag.
    pub oom_detect_s: f64,
    /// Account device memory in bytes: every dispatch charges the job's
    /// observed per-GPU peak (the exact memory model plus
    /// [`EngineConfig::mem_jitter_frac`]) against the
    /// [`crate::runtime::device::DeviceMemory`] ledger, a failed charge is
    /// a *real* OOM (`oom_observed` in the event log, crash after
    /// [`EngineConfig::oom_observe_s`]), and every placement folds a
    /// predicted-vs-observed accuracy sample into the run aggregates.
    pub device_memory: bool,
    /// Per-dispatch activation jitter: the observed peak is the exact
    /// model's bytes times `1 + mem_jitter_frac · u` with deterministic
    /// `u ∈ [0, 1)` drawn from `(job, epoch)`. Zero (the default) keeps
    /// runs bit-reproducible with the pre-ledger behavior.
    pub mem_jitter_frac: f64,
    /// Seconds from start until a ledger-observed OOM crashes the run and
    /// is processed. Defaults to the same 45 s as the fallback detection
    /// timer so enabling the ledger changes the *cause* of an OOM (an
    /// observed over-capacity charge vs. a trusted scheduler flag), never
    /// the timing of existing runs.
    pub oom_observe_s: f64,
    /// Checkpoint cadence in training steps (0 disables checkpointing: a
    /// drained job restarts from step 0).
    pub ckpt_every_steps: u64,
    /// Seconds a drain spends writing the checkpoint.
    pub ckpt_write_s: f64,
    /// Graceful-drain budget for `NodeLeave`: hosted jobs get
    /// `min(in-flight step + ckpt_write_s, drain_grace_s)` to checkpoint
    /// and release. Zero (the default) preempts instantly — the
    /// pre-checkpoint behavior.
    pub drain_grace_s: f64,
    /// Seconds charged per scheduler work unit (models the paper's
    /// scheduling-overhead effect in virtual time).
    pub sched_work_unit_s: f64,
    /// Hard cap on scheduling attempts (OOM retries / preemptions) before a
    /// job is rejected.
    pub max_attempts: u32,
    /// First crash-backoff hold in seconds: a job displaced by
    /// [`ClusterEvent::NodeCrash`] waits `base · 2^(n-1)` (its n-th crash)
    /// before re-entering the pending queue — deterministic and
    /// clock-driven, never a spin.
    pub crash_backoff_base_s: f64,
    /// Upper bound on the crash-backoff hold.
    pub crash_backoff_cap_s: f64,
    /// A node that crashes this many times inside
    /// [`EngineConfig::quarantine_window_s`] is quarantined — excluded
    /// from placement until its probation ends. Zero disables quarantine.
    pub quarantine_crashes: u32,
    /// Sliding window (seconds) over which node crashes count toward
    /// quarantine.
    pub quarantine_window_s: f64,
    /// How long a quarantined node sits out before rejoining placement.
    pub probation_s: f64,
    /// Retention policy for terminal-job bookkeeping: per-job maps
    /// (`epochs`, `submit_times`, `first_starts`) keep entries for at most
    /// this many *terminal* jobs, oldest-terminal-first eviction. Bounds a
    /// long-running coordinator's memory; running/pending jobs are never
    /// evicted. The run's result *aggregates*
    /// ([`crate::metrics::RunAggregates`]) are O(1) and never evicted.
    pub retain_terminal: usize,
    /// Capacity of the [`EventLog`] ring (records retained; sequence
    /// numbers stay monotonic across eviction).
    pub event_log_cap: usize,
    /// Per-tenant weights for the weighted-fair pending ordering
    /// (`(tenant, weight)`; unlisted tenants weigh 1.0). The ordering layer
    /// only engages when the pending queue holds ≥ 2 distinct tenants —
    /// tenantless runs keep exact FCFS order, bit-for-bit.
    pub tenant_weights: Vec<(String, f64)>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            oom_detect_s: 45.0,
            device_memory: true,
            mem_jitter_frac: 0.0,
            oom_observe_s: 45.0,
            ckpt_every_steps: 100,
            ckpt_write_s: 5.0,
            drain_grace_s: 0.0,
            sched_work_unit_s: 2.0e-5,
            max_attempts: 6,
            crash_backoff_base_s: 1.0,
            crash_backoff_cap_s: 60.0,
            quarantine_crashes: 3,
            quarantine_window_s: 300.0,
            probation_s: 120.0,
            retain_terminal: 16_384,
            event_log_cap: 65_536,
            tenant_weights: Vec::new(),
        }
    }
}

/// One job the engine just placed. In virtual time the engine has already
/// scheduled the matching `Finish`/`Oom` into the clock; on a wall clock the
/// driver must dispatch the job and later feed back
/// `ClusterEvent::Finish { job, epoch }`.
#[derive(Debug, Clone)]
pub struct PlacedJob {
    pub job: JobId,
    /// Placement epoch: increments every time this job starts. Completions
    /// must echo it so results from a preempted/cancelled run are discarded.
    pub epoch: u64,
    /// Scheduling attempts including this one (1 on first placement).
    pub attempts: u32,
    pub gpus: u32,
    /// When the job starts (now + modeled scheduling overhead).
    pub start_time: f64,
    /// The placement will OOM. With device-memory accounting on, this is
    /// the byte ledger's verdict (observed peak > capacity); otherwise it
    /// echoes the scheduler's flag (memory-oblivious baselines only).
    pub will_oom: bool,
    /// Samples already completed before this run (resumed from checkpoint;
    /// 0 on a fresh start). Drivers subtract these from the work they
    /// dispatch.
    pub resumed_samples: u64,
    /// Throughput estimate from the performance model (0 when `will_oom`).
    pub est_samples_per_sec: f64,
    /// Estimated runtime of the *remaining* work (OOM delay when
    /// `will_oom`).
    pub est_runtime_s: f64,
}

/// A ledger-observed OOM on a wall clock: the driver must deliver
/// [`ClusterEvent::Oom`] `{job, epoch}` after `delay_s` (virtual clocks
/// self-schedule it instead, so this list stays empty in simulation).
#[derive(Debug, Clone)]
pub struct OomDirective {
    pub job: JobId,
    pub epoch: u64,
    pub delay_s: f64,
}

/// A graceful-drain deadline on a wall clock: the driver must deliver
/// [`ClusterEvent::Drained`] `{job, epoch}` after `delay_s` (virtual
/// clocks self-schedule it instead).
#[derive(Debug, Clone)]
pub struct DrainDirective {
    pub job: JobId,
    pub epoch: u64,
    pub node: NodeId,
    pub delay_s: f64,
}

/// A crash-backoff hold on a wall clock: the driver must deliver
/// [`ClusterEvent::Requeue`] `{job}` after `delay_s` (virtual clocks
/// self-schedule it instead).
#[derive(Debug, Clone)]
pub struct RequeueDirective {
    pub job: JobId,
    pub delay_s: f64,
}

/// A quarantine probation deadline on a wall clock: the driver must
/// deliver [`ClusterEvent::Probation`] `{node}` after `delay_s` (virtual
/// clocks self-schedule it instead).
#[derive(Debug, Clone)]
pub struct ProbationDirective {
    pub node: NodeId,
    pub delay_s: f64,
}

/// What one event (plus the scheduling round it triggered) did — the
/// driver's window into the engine.
#[derive(Debug, Clone, Default)]
pub struct Effects {
    /// Jobs that started running (dispatch these on a wall clock).
    pub placed: Vec<PlacedJob>,
    /// Jobs that completed (resources released, outcome recorded).
    pub finished: Vec<JobId>,
    /// Jobs rejected (attempt budget exhausted or structurally unplaceable).
    pub rejected: Vec<JobId>,
    /// Jobs preempted by a `NodeLeave` (or drained and requeued) and
    /// returned to the pending queue.
    pub preempted: Vec<JobId>,
    /// Ledger-observed OOMs the driver must feed back as
    /// [`ClusterEvent::Oom`] after each directive's delay (wall clock
    /// only).
    pub oom_observed: Vec<OomDirective>,
    /// Drain deadlines the driver must feed back as
    /// [`ClusterEvent::Drained`] after each directive's delay (wall clock
    /// only).
    pub drain_requested: Vec<DrainDirective>,
    /// Crash-backoff holds the driver must feed back as
    /// [`ClusterEvent::Requeue`] after each directive's delay (wall clock
    /// only).
    pub requeue_after: Vec<RequeueDirective>,
    /// Quarantine probations the driver must feed back as
    /// [`ClusterEvent::Probation`] after each directive's delay (wall
    /// clock only).
    pub probation_after: Vec<ProbationDirective>,
}

impl Effects {
    pub fn merge(&mut self, mut other: Effects) {
        self.placed.append(&mut other.placed);
        self.finished.append(&mut other.finished);
        self.rejected.append(&mut other.rejected);
        self.preempted.append(&mut other.preempted);
        self.oom_observed.append(&mut other.oom_observed);
        self.drain_requested.append(&mut other.drain_requested);
        self.requeue_after.append(&mut other.requeue_after);
        self.probation_after.append(&mut other.probation_after);
    }
}

/// One applied placement: job → sorted `(node, gpu-count)` parts.
pub type PlacementRecord = (JobId, Vec<(NodeId, u32)>);

/// Bounded tracker of terminal jobs, shared by the engine and the live
/// coordinator: ids are noted in the order they go terminal, and each note
/// returns the ids that fell past the retention cap so the caller can drop
/// its per-job bookkeeping for them (oldest-terminal-first eviction).
#[derive(Debug)]
pub struct RetentionQueue {
    order: VecDeque<JobId>,
    cap: usize,
}

impl RetentionQueue {
    pub fn new(cap: usize) -> Self {
        Self { order: VecDeque::new(), cap }
    }

    /// Record `id` as terminal; returns the evicted ids (beyond the cap).
    pub fn note(&mut self, id: JobId) -> Vec<JobId> {
        self.order.push_back(id);
        let excess = self.order.len().saturating_sub(self.cap);
        self.order.drain(..excess).collect()
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Terminal ids in noted order (oldest first) — serialized by the
    /// durability snapshot so eviction order survives recovery.
    pub fn ids(&self) -> impl Iterator<Item = JobId> + '_ {
        self.order.iter().copied()
    }
}

/// Cap on [`SchedulingEngine::decision_log`] entries: a long-running live
/// coordinator must not leak memory linearly in placements, so the log
/// keeps only the most recent records (the oldest half is dropped when the
/// cap is hit). Per-job bookkeeping (`epochs`, `submit_times`,
/// `first_starts`) is bounded separately by
/// [`EngineConfig::retain_terminal`].
pub const MAX_DECISION_LOG: usize = 65_536;

/// Sink for the engine's durability journal. The engine calls
/// [`Journal::event`] at the single point every [`ClusterEvent`] is applied
/// (before any state changes, so the record is on disk before its effects
/// exist anywhere else) and [`Journal::round`] after each executed
/// scheduling round. `RoundTick`s are *not* journaled — they only mark
/// round boundaries, and the [`Journal::round`] record already captures
/// each round that actually ran, with its timestamp and measured scheduler
/// wall time (which a replay cannot re-measure).
pub trait Journal {
    fn event(&mut self, time: f64, ev: &ClusterEvent);
    fn round(&mut self, time: f64, sched_wall_s: f64);
}

/// A crash-displaced job waiting out its backoff hold before re-entering
/// the pending queue (see [`ClusterEvent::NodeCrash`]).
struct HeldJob {
    spec: JobSpec,
    attempts: u32,
    /// Absolute time the hold expires (what recovery re-arms).
    release_at: f64,
}

struct RunningJob {
    spec: JobSpec,
    first_start: f64,
    gpus: u32,
    attempts: u32,
    epoch: u64,
    /// When this run (this epoch) started — drain progress is measured
    /// from here.
    start_time: f64,
    /// Modeled throughput of this run (0 for a doomed placement).
    sps: f64,
    /// Samples completed before this run (resumed from checkpoint).
    resumed_samples: u64,
    /// Set when a node retirement asked this job to drain: the triggering
    /// node and the absolute drain deadline (kept so a recovered engine
    /// can re-arm the deadline timer).
    draining: Option<(NodeId, f64)>,
    /// Absolute time of this run's predicted outcome (Finish, or Oom when
    /// [`RunningJob::will_oom`]) — what crash recovery re-arms.
    outcome_at: f64,
    /// Whether the predicted outcome is an OOM crash.
    will_oom: bool,
}

/// GPU-time utilization integrator. Integrates capacity as well as busy
/// GPU-seconds so the denominator stays correct when the cluster grows or
/// shrinks mid-run.
struct UtilIntegrator {
    last_t: f64,
    busy_gpu_seconds: f64,
    capacity_gpu_seconds: f64,
}

impl UtilIntegrator {
    fn new() -> Self {
        Self { last_t: 0.0, busy_gpu_seconds: 0.0, capacity_gpu_seconds: 0.0 }
    }

    fn advance(&mut self, now: f64, busy: u32, total: u32) {
        let dt = (now - self.last_t).max(0.0);
        self.busy_gpu_seconds += dt * busy as f64;
        self.capacity_gpu_seconds += dt * total as f64;
        self.last_t = self.last_t.max(now);
    }

    fn value(&self) -> f64 {
        if self.capacity_gpu_seconds <= 0.0 {
            0.0
        } else {
            (self.busy_gpu_seconds / self.capacity_gpu_seconds).clamp(0.0, 1.0)
        }
    }
}

/// The shared scheduling engine. See the module docs for the division of
/// labor between the engine and its drivers.
pub struct SchedulingEngine<'a> {
    orch: Orchestrator,
    sched: &'a mut dyn Scheduler,
    pm: PerfModel,
    cfg: EngineConfig,
    pending: PendingQueue,
    running: HashMap<JobId, RunningJob>,
    /// Streaming run metrics — O(1) memory regardless of job count.
    agg: RunAggregates,
    /// Bounded audit ring of everything that happened.
    events: EventLog,
    work_units: u64,
    sched_wall_s: f64,
    util: UtilIntegrator,
    submit_times: HashMap<JobId, f64>,
    first_starts: HashMap<JobId, f64>,
    epochs: HashMap<JobId, u64>,
    /// Eviction queue for [`EngineConfig::retain_terminal`].
    retention: RetentionQueue,
    /// Checkpoints of drained jobs awaiting re-placement (entries are
    /// dropped when the job goes terminal).
    ckpts: CheckpointStore,
    /// Every applied placement, in order: (job, sorted (node, gpus) parts).
    decision_log: Vec<PlacementRecord>,
    /// Crash-displaced jobs waiting out their backoff hold (released back
    /// to pending by [`ClusterEvent::Requeue`]).
    held: BTreeMap<JobId, HeldJob>,
    /// Times each job has been crash-displaced — drives the exponential
    /// backoff.
    crash_counts: BTreeMap<JobId, u32>,
    /// Recent crash timestamps per node (pruned to the quarantine window)
    /// — drives the flap detector.
    node_crash_times: BTreeMap<NodeId, Vec<f64>>,
    /// Quarantined nodes and their probation deadlines (what recovery
    /// re-arms).
    quarantine_until: BTreeMap<NodeId, f64>,
    /// Straggler state: nodes whose new placements run at `factor` ×
    /// modeled throughput.
    slow_factors: BTreeMap<NodeId, f64>,
    /// Nodes whose checkpoint writes fail until the given time.
    ckpt_fail_until: BTreeMap<NodeId, f64>,
    /// Interval schedulers: time of the last executed round and whether a
    /// RoundTick is already queued in a virtual clock.
    last_round: f64,
    tick_queued: bool,
    /// Durability sink, attached by the driver *after* any recovery replay
    /// (replay must not re-journal the records it is reading).
    journal: Option<Box<dyn Journal>>,
}

impl<'a> SchedulingEngine<'a> {
    pub fn new(spec: &ClusterSpec, sched: &'a mut dyn Scheduler, cfg: EngineConfig) -> Self {
        let retention = RetentionQueue::new(cfg.retain_terminal);
        let events = EventLog::new(cfg.event_log_cap);
        Self {
            orch: Orchestrator::new(spec),
            sched,
            pm: PerfModel::new(spec.inter_node_gbps),
            cfg,
            pending: PendingQueue::new(),
            running: HashMap::new(),
            agg: RunAggregates::new(),
            events,
            work_units: 0,
            sched_wall_s: 0.0,
            util: UtilIntegrator::new(),
            submit_times: HashMap::new(),
            first_starts: HashMap::new(),
            epochs: HashMap::new(),
            retention,
            ckpts: CheckpointStore::new(),
            decision_log: Vec::new(),
            held: BTreeMap::new(),
            crash_counts: BTreeMap::new(),
            node_crash_times: BTreeMap::new(),
            quarantine_until: BTreeMap::new(),
            slow_factors: BTreeMap::new(),
            ckpt_fail_until: BTreeMap::new(),
            last_round: f64::NEG_INFINITY,
            tick_queued: false,
            journal: None,
        }
    }

    /// Attach the durability journal. Call after recovery replay completes
    /// — replayed events must not be re-journaled.
    pub fn set_journal(&mut self, journal: Box<dyn Journal>) {
        self.journal = Some(journal);
    }

    fn busy_gpus(&self) -> u32 {
        self.orch.state().total_gpus() - self.orch.state().idle_gpus()
    }

    fn advance_util(&mut self, now: f64) {
        let busy = self.busy_gpus();
        let total = self.orch.state().total_gpus();
        self.util.advance(now, busy, total);
    }

    /// Process one event. Does **not** run a scheduling round — drivers call
    /// [`Self::run_round`] after the event (or event batch) so batched
    /// same-timestamp events see one round, exactly like the old simulator.
    pub fn handle(&mut self, ev: ClusterEvent, clock: &mut dyn Clock) -> Effects {
        let now = clock.now();
        self.advance_util(now);
        // Persist-before-effect: the WAL record hits the journal before the
        // event mutates anything, so no acknowledged transition can be lost
        // to a crash. RoundTicks are skipped — executed rounds get their own
        // `Journal::round` record (see `run_round`).
        if !matches!(ev, ClusterEvent::RoundTick) {
            if let Some(journal) = self.journal.as_mut() {
                journal.event(now, &ev);
            }
        }
        let mut fx = Effects::default();
        match ev {
            ClusterEvent::Arrival(spec) => {
                self.submit_times.insert(spec.id, spec.submit_time);
                self.events.push(now, EventKind::Arrival { job: spec.id });
                self.pending.push(PendingJob { spec, attempts: 0 });
            }
            ClusterEvent::Finish { job, epoch } => {
                if self.running.get(&job).is_none_or(|r| r.epoch != epoch) {
                    return fx; // stale: preempted/cancelled since this run started
                }
                let run = self.running.remove(&job).expect("checked above");
                let _ = self.orch.release(job);
                self.reap_retired(now);
                let batch = run.spec.train.global_batch.max(1) as u64;
                let steps_this_run =
                    run.spec.total_samples.saturating_sub(run.resumed_samples).div_ceil(batch);
                self.agg.record_run_steps(steps_this_run);
                let submit = *self.submit_times.get(&job).unwrap_or(&0.0);
                let sps = run.spec.total_samples as f64 / (now - run.first_start).max(1e-9);
                self.agg.record_completed(submit, run.first_start, now, sps, run.attempts);
                self.agg.record_tenant_completed(&run.spec.tenant, submit, run.first_start, now);
                self.charge_tenant_gpu(&run, now);
                self.events.push(now, EventKind::Finished { job, epoch });
                self.note_terminal(job);
                fx.finished.push(job);
            }
            ClusterEvent::Oom { job, epoch } => {
                if self.running.get(&job).is_none_or(|r| r.epoch != epoch) {
                    return fx;
                }
                let run = self.running.remove(&job).expect("checked above");
                self.agg.record_run_steps(Self::steps_this_run(&run, now));
                self.charge_tenant_gpu(&run, now);
                let _ = self.orch.release(job);
                self.reap_retired(now);
                self.agg.record_oom_event();
                let requeued = run.attempts < self.cfg.max_attempts;
                self.events.push(now, EventKind::Oomed { job, epoch, requeued });
                if requeued {
                    self.pending.push(PendingJob { spec: run.spec, attempts: run.attempts });
                } else {
                    self.reject(now, job, RejectReason::AttemptsExhausted, &mut fx);
                }
            }
            ClusterEvent::Drained { job, epoch } => {
                self.handle_drained(job, epoch, now, &mut fx);
            }
            ClusterEvent::Cancel { job } => {
                if !self.cancel_pending(job, now) && !self.cancel_running(job, now) {
                    self.cancel_held(job, now);
                }
            }
            ClusterEvent::NodeCrash(node) => {
                self.node_crash(node, now, clock, &mut fx);
            }
            ClusterEvent::Requeue { job } => {
                if let Some(h) = self.held.remove(&job) {
                    self.pending.push(PendingJob { spec: h.spec, attempts: h.attempts });
                }
            }
            ClusterEvent::Probation { node } => {
                if self.quarantine_until.remove(&node).is_some() {
                    self.orch.unquarantine(node);
                    self.events.push(now, EventKind::NodeProbation { node });
                    self.sched.cluster_changed(self.orch.state());
                }
            }
            ClusterEvent::Slowdown { node, factor } => {
                if factor >= 1.0 {
                    self.slow_factors.remove(&node);
                } else {
                    self.slow_factors.insert(node, factor.max(1e-3));
                }
                self.events.push(now, EventKind::NodeSlowdown { node, factor });
            }
            ClusterEvent::CkptFail { node, until_s } => {
                self.ckpt_fail_until.insert(node, until_s);
            }
            ClusterEvent::RoundTick => {
                self.tick_queued = false;
            }
            ClusterEvent::NodeJoin(node) => {
                let gpu = node.gpu.name.to_string();
                let gpus = node.count;
                let id = self.orch.grow(&node);
                self.events.push(now, EventKind::NodeJoined { node: id, gpu, gpus });
                self.sched.cluster_changed(self.orch.state());
            }
            ClusterEvent::NodeLeave(node) => {
                if self.cfg.drain_grace_s > 0.0 {
                    self.node_leave_drain(node, now, clock, &mut fx);
                } else if let Ok(released) = self.orch.shrink(node) {
                    let displaced: Vec<JobId> = released.iter().map(|a| a.job).collect();
                    self.events
                        .push(now, EventKind::NodeLeft { node, preempted: displaced });
                    for alloc in released {
                        let Some(run) = self.running.remove(&alloc.job) else { continue };
                        // The killed run's progress is real executed work —
                        // all of it re-executes (no checkpoint on this
                        // path), which is exactly what the report's
                        // `total_steps_executed` excess must show.
                        let executed = Self::steps_this_run(&run, now);
                        self.agg.record_run_steps(executed);
                        self.agg.record_steps_lost(executed);
                        self.charge_tenant_gpu(&run, now);
                        if run.attempts >= self.cfg.max_attempts {
                            self.reject(now, alloc.job, RejectReason::AttemptsExhausted, &mut fx);
                        } else {
                            self.events
                                .push(now, EventKind::Preempted { job: alloc.job, node });
                            self.pending
                                .push(PendingJob { spec: run.spec, attempts: run.attempts });
                            fx.preempted.push(alloc.job);
                        }
                    }
                    self.sched.cluster_changed(self.orch.state());
                }
            }
        }
        fx
    }

    /// Graceful `NodeLeave`: stop placements on the node, then give every
    /// hosted job a drain deadline — finish the in-flight step, write the
    /// checkpoint, release — instead of yanking its GPUs. The matching
    /// [`ClusterEvent::Drained`] is self-scheduled on a virtual clock and
    /// handed to the driver as a [`DrainDirective`] on a wall clock.
    fn node_leave_drain(
        &mut self,
        node: NodeId,
        now: f64,
        clock: &mut dyn Clock,
        fx: &mut Effects,
    ) {
        let Ok(affected) = self.orch.retire_begin(node) else { return };
        self.events.push(now, EventKind::NodeLeft { node, preempted: affected.clone() });
        if self.orch.state().nodes[node].total == 0 {
            // No resident jobs: the retirement completed in one step — emit
            // the safe-to-power-off record now, so drain-mode leaves always
            // produce one, idle or busy.
            self.events.push(now, EventKind::NodeRetired { node });
        }
        for job in affected {
            let Some(run) = self.running.get_mut(&job) else { continue };
            if run.draining.is_some() {
                continue; // already draining for another retiring node
            }
            let epoch = run.epoch;
            let step_s = if run.sps > 0.0 {
                run.spec.train.global_batch.max(1) as f64 / run.sps
            } else {
                0.0
            };
            let delay = (step_s + self.cfg.ckpt_write_s).min(self.cfg.drain_grace_s);
            let deadline = now + delay;
            run.draining = Some((node, deadline));
            self.events
                .push(now, EventKind::DrainRequested { job, epoch, node, deadline_s: deadline });
            if !clock.schedule(deadline, ClusterEvent::Drained { job, epoch }) {
                fx.drain_requested.push(DrainDirective { job, epoch, node, delay_s: delay });
            }
        }
        self.sched.cluster_changed(self.orch.state());
    }

    /// Abrupt node failure: every hosted job is killed mid-step — no drain
    /// grace, no final checkpoint write. Work falls back to the last
    /// checkpoint floor (or, while the node's checkpoint writes are
    /// failing, to the last checkpoint that actually made it out), and the
    /// job re-enters placement after a capped exponential crash-backoff
    /// hold **without** burning an attempt — the node failed, not the job.
    /// A node that crashes [`EngineConfig::quarantine_crashes`] times
    /// inside [`EngineConfig::quarantine_window_s`] is quarantined:
    /// excluded from placement until its probation ends. The node's idle
    /// capacity stays in the cluster — crash is not retirement.
    fn node_crash(&mut self, node: NodeId, now: f64, clock: &mut dyn Clock, fx: &mut Effects) {
        if self.quarantine_until.contains_key(&node) {
            return; // already fenced off — nothing left to kill
        }
        let Ok(released) = self.orch.crash_node(node) else { return };
        let displaced: Vec<JobId> = released.iter().map(|a| a.job).collect();
        self.agg.record_node_crash();
        self.events.push(now, EventKind::NodeCrashed { node, preempted: displaced });
        let ckpt_blocked = self.ckpt_fail_until.get(&node).is_some_and(|&u| now < u);
        for alloc in released {
            let Some(run) = self.running.remove(&alloc.job) else { continue };
            let job = alloc.job;
            let batch = run.spec.train.global_batch.max(1) as u64;
            let executed = Self::steps_this_run(&run, now);
            self.agg.record_run_steps(executed);
            self.charge_tenant_gpu(&run, now);
            let steps_total = run.resumed_samples / batch + executed;
            let prior = self.ckpts.get(job).map(|c| c.steps_done).unwrap_or(0);
            let floor = if ckpt_blocked {
                prior
            } else {
                checkpoint::ckpt_floor(steps_total, self.cfg.ckpt_every_steps).max(prior)
            };
            if floor > prior {
                self.ckpts.save(Checkpoint {
                    job,
                    steps_done: floor,
                    state_digest: checkpoint::state_digest(job, floor),
                });
            }
            self.agg.record_steps_lost(steps_total.saturating_sub(floor));
            self.agg.record_crash_requeue();
            let n = {
                let c = self.crash_counts.entry(job).or_insert(0);
                *c += 1;
                *c
            };
            let delay = (self.cfg.crash_backoff_base_s
                * f64::powi(2.0, n.saturating_sub(1).min(30) as i32))
            .min(self.cfg.crash_backoff_cap_s)
            .max(0.0);
            let release_at = now + delay;
            self.held.insert(job, HeldJob { spec: run.spec, attempts: run.attempts, release_at });
            fx.preempted.push(job);
            if !clock.schedule(release_at, ClusterEvent::Requeue { job }) {
                fx.requeue_after.push(RequeueDirective { job, delay_s: delay });
            }
        }
        self.reap_retired(now);
        // Flap detector: K crashes inside the window → quarantine.
        let window = self.cfg.quarantine_window_s;
        let recent = {
            let times = self.node_crash_times.entry(node).or_default();
            times.push(now);
            times.retain(|&t| now - t <= window);
            times.len() as u32
        };
        if self.cfg.quarantine_crashes > 0 && recent >= self.cfg.quarantine_crashes {
            self.node_crash_times.remove(&node);
            let until = now + self.cfg.probation_s;
            self.quarantine_until.insert(node, until);
            self.orch.quarantine(node);
            self.agg.record_quarantine();
            self.events.push(now, EventKind::NodeQuarantined { node, until_s: until });
            if !clock.schedule(until, ClusterEvent::Probation { node }) {
                fx.probation_after
                    .push(ProbationDirective { node, delay_s: self.cfg.probation_s });
            }
        }
        self.sched.cluster_changed(self.orch.state());
    }

    /// A drain deadline fired: floor the job's progress to its last
    /// checkpoint boundary, snapshot it, release the GPUs (reaping the
    /// retiring node), and requeue the job — its next placement resumes
    /// from the checkpoint instead of step 0.
    fn handle_drained(&mut self, job: JobId, epoch: u64, now: f64, fx: &mut Effects) {
        if self
            .running
            .get(&job)
            .is_none_or(|r| r.epoch != epoch || r.draining.is_none())
        {
            return; // stale: finished/OOMed/cancelled since the drain request
        }
        let run = self.running.remove(&job).expect("checked above");
        let (node, _) = run.draining.expect("checked above");
        let batch = run.spec.train.global_batch.max(1) as u64;
        let executed = Self::steps_this_run(&run, now);
        let steps_total = run.resumed_samples / batch + executed;
        let steps_ckpt = if self.ckpt_fail_until.get(&node).is_some_and(|&u| now < u) {
            // The node's checkpoint writes are failing: fall back to the
            // last checkpoint that actually made it out (possibly none).
            self.ckpts.get(job).map(|c| c.steps_done).unwrap_or(0)
        } else {
            checkpoint::ckpt_floor(steps_total, self.cfg.ckpt_every_steps)
        };
        let digest = checkpoint::state_digest(job, steps_ckpt);
        if steps_ckpt > 0 {
            self.ckpts.save(Checkpoint { job, steps_done: steps_ckpt, state_digest: digest });
        }
        self.agg.record_drained(executed);
        self.agg.record_steps_lost(steps_total.saturating_sub(steps_ckpt));
        self.charge_tenant_gpu(&run, now);
        let _ = self.orch.release(job);
        self.reap_retired(now);
        self.events
            .push(now, EventKind::Drained { job, epoch, node, steps_ckpt, state_digest: digest });
        // A drained job did nothing wrong: graceful drains never consume
        // the failure budget, so a healthy long job survives any number of
        // node retirements. (`attempts` still counts placements for the
        // retry metrics; the `max_attempts` cap applies to OOM crashes and
        // instant preemptions only.)
        self.pending.push(PendingJob { spec: run.spec, attempts: run.attempts });
        fx.preempted.push(job);
    }

    /// Whole training steps an interrupted run executed so far (modeled:
    /// elapsed × throughput, counted in cumulative step units past any
    /// resume point). Zero for doomed (`sps == 0`) placements. Feeds the
    /// report's `total_steps_executed` for drained, preempted, OOMed, and
    /// cancelled runs alike, so the excess over the nominal step total is
    /// exactly the re-execution cost of elasticity.
    /// Charge a released run's GPU-seconds against its tenant's share
    /// (no-op for anonymous jobs). Called wherever a run gives back its
    /// allocation — finish, OOM, preemption, drain, crash, cancel — so the
    /// share reflects consumption, not just successful completions.
    fn charge_tenant_gpu(&mut self, run: &RunningJob, now: f64) {
        self.agg.record_tenant_gpu_seconds(
            &run.spec.tenant,
            run.gpus as f64 * (now - run.start_time).max(0.0),
        );
    }

    fn steps_this_run(run: &RunningJob, now: f64) -> u64 {
        let batch = run.spec.train.global_batch.max(1) as u64;
        let elapsed = (now - run.start_time).max(0.0);
        let samples = ((elapsed * run.sps) as u64)
            .min(run.spec.total_samples.saturating_sub(run.resumed_samples));
        (run.resumed_samples + samples) / batch - run.resumed_samples / batch
    }

    /// Strip freed capacity off retiring nodes after a release; log a
    /// `NodeRetired` record and tell the scheduler when any node completed
    /// retirement (that record — not `NodeLeft`, which marks the *start*
    /// of the drain — is the operator's safe-to-power-off signal).
    fn reap_retired(&mut self, now: f64) {
        if self.orch.retiring_count() == 0 {
            return;
        }
        let done = self.orch.reap_retiring();
        if !done.is_empty() {
            for node in done {
                self.events.push(now, EventKind::NodeRetired { node });
            }
            self.sched.cluster_changed(self.orch.state());
        }
    }

    /// The observed per-GPU peak this dispatch will pin: the exact memory
    /// model's bytes, inflated by a deterministic per-`(job, epoch)`
    /// activation jitter of up to [`EngineConfig::mem_jitter_frac`].
    fn observed_peak_bytes(&self, spec: &JobSpec, par: Parallelism, job: JobId, epoch: u64) -> u64 {
        let exact_bytes = exact::exact_peak_bytes(&spec.model, &spec.train, par);
        if self.cfg.mem_jitter_frac <= 0.0 {
            return exact_bytes;
        }
        let mut sm = SplitMix64::new(job.wrapping_mul(0x2545F4914F6CDD1D) ^ epoch);
        let u = (sm.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        (exact_bytes as f64 * (1.0 + self.cfg.mem_jitter_frac * u)).round() as u64
    }

    /// Record a rejection everywhere it must land: aggregates, event log,
    /// retention, and the driver-visible effects.
    fn reject(&mut self, now: f64, job: JobId, reason: RejectReason, fx: &mut Effects) {
        self.agg.record_rejected();
        self.events.push(now, EventKind::Rejected { job, reason });
        self.note_terminal(job);
        fx.rejected.push(job);
    }

    /// Run one scheduling round over the pending queue, then reject
    /// structurally unplaceable jobs. Interval schedulers (Sia-style) defer
    /// to a queued `RoundTick` on a virtual clock, or to the driver's
    /// round-timer thread on a timer-backed wall clock
    /// ([`Clock::delivers_ticks`]); on a bare wall clock — no way to receive
    /// a future tick — they round immediately instead.
    pub fn run_round(&mut self, clock: &mut dyn Clock) -> Effects {
        let mut fx = Effects::default();
        let now = clock.now();
        self.advance_util(now);
        if let Some(interval) = self.sched.round_interval_s() {
            if self.pending.is_empty() {
                return fx;
            }
            let due = self.last_round + interval;
            if now < due {
                if !self.tick_queued && clock.schedule(due, ClusterEvent::RoundTick) {
                    self.tick_queued = true;
                }
                if self.tick_queued || clock.delivers_ticks() {
                    return fx;
                }
            }
            self.last_round = now;
        }
        // Journal executed rounds (a round with nothing pending mutates no
        // state and is not recorded). The measured scheduler wall time goes
        // into the record because a replay cannot re-measure it.
        let had_pending = !self.pending.is_empty();
        let wall_before = self.sched_wall_s;
        self.round_inner(clock, &mut fx);
        self.reject_unplaceable(clock, &mut fx);
        if had_pending {
            if let Some(journal) = self.journal.as_mut() {
                journal.round(now, self.sched_wall_s - wall_before);
            }
        }
        fx
    }

    /// Re-execute one journaled scheduling round during crash recovery: the
    /// round runs at the recorded time against the recovered state, and the
    /// recorded scheduler wall time is credited in place of a meaningless
    /// re-measurement. Replay is the *same* placement pass as the original
    /// (`round_inner` + `reject_unplaceable`) — recovery never mutates
    /// engine state through any other path.
    pub fn replay_round(&mut self, time: f64, sched_wall_s: f64) -> Effects {
        let mut clock = clock::ReplayClock::new();
        clock.set(time);
        let mut fx = Effects::default();
        self.advance_util(time);
        if self.sched.round_interval_s().is_some() {
            // The record's existence proves the original run passed the
            // interval gate at this time.
            self.last_round = time;
        }
        let wall_before = self.sched_wall_s;
        self.round_inner(&mut clock, &mut fx);
        self.reject_unplaceable(&mut clock, &mut fx);
        self.sched_wall_s = wall_before + sched_wall_s;
        fx
    }

    /// The placement pass. The scheduler plans against the orchestrator's
    /// live state + capacity index through a borrowed [`ClusterView`] —
    /// no cluster snapshot is cloned per round.
    ///
    /// [`ClusterView`]: crate::cluster::ClusterView
    fn round_inner(&mut self, clock: &mut dyn Clock, fx: &mut Effects) {
        if self.pending.is_empty() {
            return;
        }
        let now = clock.now();
        let t0 = std::time::Instant::now();
        // Weighted-fair tenancy layer: when ≥ 2 tenants are waiting, the
        // scheduler sees a reordered view of the queue (max-min over
        // GPU-share); otherwise it sees the queue itself, untouched.
        let fair = Self::fair_order(&self.pending, &self.running, &self.cfg.tenant_weights);
        let t1 = std::time::Instant::now();
        let round = {
            let view = self.orch.view();
            self.sched.schedule(fair.as_ref().unwrap_or(&self.pending), &view, now)
        };
        let t2 = std::time::Instant::now();
        // Journaled scheduler overhead: identical to the pre-telemetry
        // measurement (queue ordering + planning, excluding decision
        // application) — the phase histograms below are write-only
        // telemetry and never feed back into this figure.
        self.sched_wall_s += (t2 - t0).as_secs_f64();
        {
            let eng = &crate::obs::reg().engine;
            eng.rounds_total.inc();
            eng.phase_candidate_scan.observe((t1 - t0).as_secs_f64());
            eng.phase_plan_rank.observe((t2 - t1).as_secs_f64());
        }
        self.work_units += round.work_units;
        let overhead = round.work_units as f64 * self.cfg.sched_work_unit_s;
        let start_time = now + overhead;

        let t3 = std::time::Instant::now();
        for d in round.decisions {
            let Some(pj) = self.pending.remove(d.job) else {
                continue; // scheduler returned a stale decision — ignore
            };
            if self.orch.allocate(d.alloc.clone()).is_err() {
                // Scheduler overdrew (bug or stale snapshot): requeue.
                self.pending.push(pj);
                continue;
            }
            let attempts = pj.attempts + 1;
            let epoch = {
                let e = self.epochs.entry(d.job).or_insert(0);
                *e += 1;
                *e
            };
            let first_start = *self.first_starts.entry(d.job).or_insert(start_time);
            let mut parts = d.alloc.parts.clone();
            parts.sort_unstable();
            if self.decision_log.len() >= MAX_DECISION_LOG {
                self.decision_log.drain(..MAX_DECISION_LOG / 2);
            }
            self.decision_log.push((d.job, parts.clone()));
            let gpus = d.alloc.total_gpus();
            // Resume from checkpoint: samples completed before a drain
            // survive preemption and shrink this run's remaining work.
            let batch = pj.spec.train.global_batch.max(1) as u64;
            let resumed_samples = if self.cfg.ckpt_every_steps > 0 {
                self.ckpts
                    .get(d.job)
                    .map(|c| (c.steps_done * batch).min(pj.spec.total_samples))
                    .unwrap_or(0)
            } else {
                0
            };
            // Device-memory accounting: charge the observed per-GPU peak
            // against the byte ledger. A charge that does not fit is a
            // REAL OOM — the ledger decides, not the scheduler's flag —
            // and the predicted-vs-observed pair feeds the run's
            // prediction-accuracy aggregate either way.
            let mut ledger_oom = None;
            if self.cfg.device_memory {
                let predicted = marp_peak_bytes(&pj.spec.model, &pj.spec.train, d.par);
                let observed = self.observed_peak_bytes(&pj.spec, d.par, d.job, epoch);
                self.agg.record_mem_prediction(predicted, observed);
                if let Err(ClusterError::MemoryExceeded { node, observed_bytes, capacity_bytes }) =
                    self.orch.charge_memory(d.job, observed)
                {
                    ledger_oom = Some((node, predicted, observed_bytes, capacity_bytes));
                }
            }
            let (will_oom, thr, runtime) = if ledger_oom.is_some() {
                (true, 0.0, self.cfg.oom_observe_s)
            } else if !self.cfg.device_memory && d.will_oom {
                // Fallback: trust the scheduler's flag and model detection.
                (true, 0.0, self.cfg.oom_detect_s)
            } else {
                let mut thr = self.pm.samples_per_sec(
                    &pj.spec.model,
                    &pj.spec.train,
                    d.par,
                    &d.gpu,
                    d.placement,
                );
                // Straggler degradation: a synchronous data-parallel run is
                // gated by its slowest participant, so the placement runs
                // at the worst factor over the nodes it touches.
                let slow = parts
                    .iter()
                    .filter_map(|(n, _)| self.slow_factors.get(n))
                    .fold(1.0f64, |a, &b| a.min(b));
                thr *= slow;
                let remaining = pj.spec.total_samples.saturating_sub(resumed_samples);
                (false, thr, remaining as f64 / thr.max(1e-9))
            };
            self.events.push(
                now,
                EventKind::Placed {
                    job: d.job,
                    epoch,
                    attempts,
                    gpus,
                    d: d.par.d,
                    t: d.par.t,
                    parts,
                    will_oom,
                },
            );
            if let Some((node, predicted_bytes, observed_bytes, capacity_bytes)) = ledger_oom {
                self.events.push(
                    now,
                    EventKind::OomObserved {
                        job: d.job,
                        epoch,
                        node,
                        predicted_bytes,
                        observed_bytes,
                        capacity_bytes,
                    },
                );
            } else if resumed_samples > 0 {
                self.events.push(
                    now,
                    EventKind::ResumedFromCkpt {
                        job: d.job,
                        epoch,
                        steps_ckpt: resumed_samples / batch,
                    },
                );
            }
            self.running.insert(
                d.job,
                RunningJob {
                    spec: pj.spec.clone(),
                    first_start,
                    gpus,
                    attempts,
                    epoch,
                    start_time,
                    sps: thr,
                    resumed_samples,
                    draining: None,
                    outcome_at: start_time + runtime,
                    will_oom,
                },
            );
            if will_oom {
                let scheduled = clock
                    .schedule(start_time + runtime, ClusterEvent::Oom { job: d.job, epoch });
                if !scheduled && ledger_oom.is_some() {
                    // Wall clock + ledger OOM: the driver must crash the
                    // run after the observe delay. (Without the ledger the
                    // driver's own `will_oom` fallback timer applies.)
                    fx.oom_observed.push(OomDirective {
                        job: d.job,
                        epoch,
                        delay_s: (start_time - now) + runtime,
                    });
                }
            } else {
                clock.schedule(start_time + runtime, ClusterEvent::Finish { job: d.job, epoch });
            }
            fx.placed.push(PlacedJob {
                job: d.job,
                epoch,
                attempts,
                gpus,
                start_time,
                will_oom,
                resumed_samples,
                est_samples_per_sec: thr,
                est_runtime_s: runtime,
            });
        }
        crate::obs::reg().engine.phase_placement.observe(t3.elapsed().as_secs_f64());
    }

    /// Weighted max-min fair ordering over tenants. Returns a reordered
    /// copy of the pending queue, or `None` when fewer than two distinct
    /// tenants are waiting (anonymous counts as one tenant) — the common
    /// single-tenant/tenantless case pays nothing and keeps exact FCFS.
    ///
    /// The order is built by repeated deficit selection: pick the tenant
    /// with the lowest `gpu-share ÷ weight` (running GPUs now, plus one
    /// provisional unit per job already picked this round — job GPU counts
    /// are unknown before MARP runs), emit its oldest job, repeat. Ties
    /// break on lexicographic tenant name and FCFS within a tenant, so the
    /// order is a pure deterministic function of (queue, running set,
    /// weights) — WAL replay reproduces it exactly and snapshots need no
    /// new state.
    fn fair_order(
        pending: &PendingQueue,
        running: &HashMap<JobId, RunningJob>,
        weights: &[(String, f64)],
    ) -> Option<PendingQueue> {
        let mut queued: BTreeMap<&str, VecDeque<&PendingJob>> = BTreeMap::new();
        for pj in pending.iter() {
            queued.entry(pj.spec.tenant.as_str()).or_default().push_back(pj);
        }
        if queued.len() < 2 {
            return None;
        }
        let weight_of = |tenant: &str| -> f64 {
            weights
                .iter()
                .find(|(name, _)| name == tenant)
                .map(|&(_, w)| w)
                .filter(|w| w.is_finite() && *w > 0.0)
                .unwrap_or(1.0)
        };
        let mut share: BTreeMap<&str, f64> = queued.keys().map(|&t| (t, 0.0)).collect();
        for run in running.values() {
            if let Some(s) = share.get_mut(run.spec.tenant.as_str()) {
                *s += run.gpus as f64;
            }
        }
        let mut out: Vec<PendingJob> = Vec::with_capacity(pending.len());
        while !queued.is_empty() {
            // `min_by` keeps the first minimum; BTreeMap keys iterate in
            // sorted order, so ties resolve to the lexicographically
            // smallest tenant.
            let pick = *queued
                .keys()
                .min_by(|a, b| {
                    let ka = share[*a] / weight_of(a);
                    let kb = share[*b] / weight_of(b);
                    ka.partial_cmp(&kb).unwrap_or(std::cmp::Ordering::Equal)
                })
                .expect("non-empty");
            let deque = queued.get_mut(pick).expect("picked tenant has jobs");
            out.push(deque.pop_front().expect("non-empty deque").clone());
            if deque.is_empty() {
                queued.remove(pick);
            }
            *share.get_mut(pick).expect("tenant in share map") += 1.0;
        }
        Some(PendingQueue::from(out))
    }

    /// If the cluster is completely idle and the scheduler still can't place
    /// a job, it never will — reject it instead of busy-looping. (A job that
    /// exceeded its attempt budget is also dropped here.) Feasibility is a
    /// single [`Scheduler::can_place`] probe per job against the capacity
    /// index — no snapshot clones and no per-job placement rounds.
    fn reject_unplaceable(&mut self, clock: &mut dyn Clock, fx: &mut Effects) {
        if !(self.running.is_empty()
            && self.orch.state().idle_gpus() == self.orch.state().total_gpus()
            && !self.pending.is_empty())
        {
            return;
        }
        let now = clock.now();
        let drained = self.pending.drain();
        let mut keep = Vec::new();
        let mut rejects: Vec<(JobId, RejectReason)> = Vec::new();
        {
            let view = self.orch.view();
            for p in drained {
                if p.attempts >= self.cfg.max_attempts {
                    rejects.push((p.spec.id, RejectReason::AttemptsExhausted));
                } else if self.sched.can_place(&p, &view, now) {
                    keep.push(p);
                } else {
                    rejects.push((p.spec.id, RejectReason::Unplaceable));
                }
            }
        }
        for (id, reason) in rejects {
            self.reject(now, id, reason, fx);
        }
        for p in keep {
            self.pending.push(p);
        }
        if !self.pending.is_empty() {
            // They are placeable on an empty cluster; place them now.
            self.round_inner(clock, fx);
        }
    }

    /// Record that `job` reached a terminal state and evict the oldest
    /// terminal jobs' bookkeeping beyond [`EngineConfig::retain_terminal`].
    /// Terminal jobs also drop their checkpoint — the store holds entries
    /// only for jobs that may still resume.
    fn note_terminal(&mut self, job: JobId) {
        self.ckpts.remove(job);
        for old in self.retention.note(job) {
            self.epochs.remove(&old);
            self.submit_times.remove(&old);
            self.first_starts.remove(&old);
        }
    }

    /// Remove a queued job (user cancel). True when it was pending.
    pub fn cancel_pending(&mut self, id: JobId, now: f64) -> bool {
        if self.pending.remove(id).is_some() {
            self.agg.record_cancelled();
            self.events.push(now, EventKind::Cancelled { job: id, was_running: false });
            self.note_terminal(id);
            true
        } else {
            false
        }
    }

    /// Cancel a running job: release its resources without recording a
    /// completion. Any in-flight `Finish`/`Oom` for the old epoch goes
    /// stale.
    pub fn cancel_running(&mut self, id: JobId, now: f64) -> bool {
        let Some(run) = self.running.remove(&id) else {
            return false;
        };
        self.agg.record_run_steps(Self::steps_this_run(&run, now));
        self.charge_tenant_gpu(&run, now);
        let _ = self.orch.release(id);
        self.reap_retired(now);
        self.agg.record_cancelled();
        self.events.push(now, EventKind::Cancelled { job: id, was_running: true });
        self.note_terminal(id);
        true
    }

    /// Cancel a job waiting out its crash-backoff hold. True when it was
    /// held.
    pub fn cancel_held(&mut self, id: JobId, now: f64) -> bool {
        if self.held.remove(&id).is_none() {
            return false;
        }
        self.agg.record_cancelled();
        self.events.push(now, EventKind::Cancelled { job: id, was_running: false });
        self.note_terminal(id);
        true
    }

    /// Drain the pending queue into rejections (end-of-run bookkeeping:
    /// whatever is still pending never got resources). Crash-held jobs are
    /// included — their backoff hold never expired. Logged as
    /// [`RejectReason::RunEnded`] — these jobs may have been placeable, the
    /// run just stopped first.
    pub fn reject_remaining(&mut self, now: f64) -> Vec<JobId> {
        let mut ids: Vec<JobId> =
            self.pending.drain().into_iter().map(|p| p.spec.id).collect();
        ids.extend(std::mem::take(&mut self.held).into_keys());
        let mut fx = Effects::default();
        for &id in &ids {
            self.reject(now, id, RejectReason::RunEnded, &mut fx);
        }
        ids
    }

    // ---- introspection -------------------------------------------------

    pub fn scheduler_name(&self) -> &'static str {
        self.sched.name()
    }

    pub fn cluster_state(&self) -> &ClusterState {
        self.orch.state()
    }

    pub fn conservation_ok(&self) -> bool {
        self.orch.check_conservation()
    }

    /// True when `node` still has capacity and is not draining.
    pub fn node_active(&self, node: NodeId) -> bool {
        self.orch.node_active(node)
    }

    /// The device-memory byte ledger (bytes pinned per node).
    pub fn device_memory(&self) -> &DeviceMemory {
        self.orch.device_memory()
    }

    /// A drained job's saved checkpoint, if it has one.
    pub fn checkpoint_of(&self, job: JobId) -> Option<&Checkpoint> {
        self.ckpts.get(job)
    }

    /// Number of checkpoints currently stored (tests: no leaks).
    pub fn checkpoint_count(&self) -> usize {
        self.ckpts.len()
    }

    /// The run's streaming metrics (replaces the old unbounded per-job
    /// outcome vector).
    pub fn aggregates(&self) -> &RunAggregates {
        &self.agg
    }

    /// The bounded audit ring of everything that happened.
    pub fn event_log(&self) -> &EventLog {
        &self.events
    }

    /// Append a driver-originated record to the event log (e.g. the live
    /// coordinator's admission-control rejections, which never reach the
    /// engine's queue). Returns the assigned sequence number.
    pub fn record_event(&mut self, time: f64, kind: EventKind) -> u64 {
        self.events.push(time, kind)
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    pub fn rejected_count(&self) -> usize {
        self.agg.n_rejected
    }

    pub fn work_units(&self) -> u64 {
        self.work_units
    }

    pub fn sched_wall_s(&self) -> f64 {
        self.sched_wall_s
    }

    pub fn is_running(&self, id: JobId) -> bool {
        self.running.contains_key(&id)
    }

    pub fn is_pending(&self, id: JobId) -> bool {
        self.pending.contains(id)
    }

    /// True when `job` is waiting out a crash-backoff hold — displaced by
    /// a [`ClusterEvent::NodeCrash`], not yet back in the pending queue.
    pub fn is_held(&self, id: JobId) -> bool {
        self.held.contains_key(&id)
    }

    /// Jobs currently waiting out crash-backoff holds.
    pub fn held_count(&self) -> usize {
        self.held.len()
    }

    /// Nodes currently quarantined by the crash-flap detector, in id order.
    pub fn quarantined_nodes(&self) -> Vec<NodeId> {
        self.quarantine_until.keys().copied().collect()
    }

    /// Scheduling attempts recorded for a job so far (running or pending).
    pub fn attempts_of(&self, id: JobId) -> u32 {
        if let Some(r) = self.running.get(&id) {
            return r.attempts;
        }
        self.pending.get(id).map(|p| p.attempts).unwrap_or(0)
    }

    /// Current placement epoch of a job (0 if never placed, or if the job
    /// went terminal long enough ago that its bookkeeping was evicted under
    /// [`EngineConfig::retain_terminal`]).
    pub fn run_epoch(&self, id: JobId) -> u64 {
        self.epochs.get(&id).copied().unwrap_or(0)
    }

    /// Terminal jobs whose bookkeeping is still retained (tests).
    pub fn retained_terminal(&self) -> usize {
        self.retention.len()
    }

    /// The applied-placement log, most recent [`MAX_DECISION_LOG`] entries.
    pub fn decision_log(&self) -> &[PlacementRecord] {
        &self.decision_log
    }

    /// GPU-time utilization integral up to `now` (advances the integrator).
    pub fn utilization_to(&mut self, now: f64) -> f64 {
        self.advance_util(now);
        self.util.value()
    }

    // ---- durability ----------------------------------------------------

    /// Future events a recovered engine is still owed: the predicted
    /// outcome of every running job, pending drain deadlines, and the
    /// queued tick of an interval scheduler. Virtual-clock drivers push
    /// these back into the clock after a restore. A draining job re-arms
    /// *both* its drain deadline and its original outcome — whichever fires
    /// second goes stale via the epoch guard, exactly as in the original
    /// run.
    pub fn rearm_events(&self) -> Vec<(f64, ClusterEvent)> {
        let mut out: Vec<(f64, ClusterEvent)> = Vec::new();
        let mut jobs: Vec<(&JobId, &RunningJob)> = self.running.iter().collect();
        jobs.sort_by_key(|(id, _)| **id);
        for (&job, run) in jobs {
            if let Some((_, deadline)) = run.draining {
                out.push((deadline, ClusterEvent::Drained { job, epoch: run.epoch }));
            }
            let ev = if run.will_oom {
                ClusterEvent::Oom { job, epoch: run.epoch }
            } else {
                ClusterEvent::Finish { job, epoch: run.epoch }
            };
            out.push((run.outcome_at, ev));
        }
        for (&job, h) in &self.held {
            out.push((h.release_at, ClusterEvent::Requeue { job }));
        }
        for (&node, &until) in &self.quarantine_until {
            out.push((until, ClusterEvent::Probation { node }));
        }
        if self.tick_queued {
            if let Some(interval) = self.sched.round_interval_s() {
                out.push((self.last_round + interval, ClusterEvent::RoundTick));
            }
        }
        out
    }

    /// What a recovered *live* engine needs re-driven, as ordinary
    /// [`Effects`]: every running job re-dispatched (the executor that was
    /// driving it died with the old process) with its remaining-work
    /// estimate, plus OOM and drain directives carrying their remaining
    /// delays. The driver routes this through the same dispatch path as
    /// any other effects.
    pub fn rearm_effects(&self, now: f64) -> Effects {
        let mut fx = Effects::default();
        let mut jobs: Vec<(&JobId, &RunningJob)> = self.running.iter().collect();
        jobs.sort_by_key(|(id, _)| **id);
        for (&job, run) in jobs {
            let delay_s = (run.outcome_at - now).max(0.0);
            if run.will_oom {
                fx.oom_observed.push(OomDirective { job, epoch: run.epoch, delay_s });
            }
            if let Some((node, deadline)) = run.draining {
                fx.drain_requested.push(DrainDirective {
                    job,
                    epoch: run.epoch,
                    node,
                    delay_s: (deadline - now).max(0.0),
                });
            }
            fx.placed.push(PlacedJob {
                job,
                epoch: run.epoch,
                attempts: run.attempts,
                gpus: run.gpus,
                start_time: now,
                will_oom: run.will_oom,
                resumed_samples: run.resumed_samples,
                est_samples_per_sec: run.sps,
                est_runtime_s: delay_s,
            });
        }
        for (&job, h) in &self.held {
            fx.requeue_after
                .push(RequeueDirective { job, delay_s: (h.release_at - now).max(0.0) });
        }
        for (&node, &until) in &self.quarantine_until {
            fx.probation_after
                .push(ProbationDirective { node, delay_s: (until - now).max(0.0) });
        }
        fx
    }

    /// The determinism-affecting [`EngineConfig`] knobs, serialized into
    /// every snapshot so recovery can refuse to replay a WAL against a
    /// config that would make the replay diverge from the original run.
    fn config_guard_json(cfg: &EngineConfig) -> Json {
        let mut j = Json::obj();
        j.set("oom_detect_s", cfg.oom_detect_s)
            .set("device_memory", cfg.device_memory)
            .set("mem_jitter_frac", cfg.mem_jitter_frac)
            .set("oom_observe_s", cfg.oom_observe_s)
            .set("ckpt_every_steps", cfg.ckpt_every_steps)
            .set("ckpt_write_s", cfg.ckpt_write_s)
            .set("drain_grace_s", cfg.drain_grace_s)
            .set("sched_work_unit_s", cfg.sched_work_unit_s)
            .set("max_attempts", cfg.max_attempts)
            .set("crash_backoff_base_s", cfg.crash_backoff_base_s)
            .set("crash_backoff_cap_s", cfg.crash_backoff_cap_s)
            .set("quarantine_crashes", cfg.quarantine_crashes)
            .set("quarantine_window_s", cfg.quarantine_window_s)
            .set("probation_s", cfg.probation_s);
        // Fairness weights reorder placement, so a replay under different
        // weights would diverge. Emitted only when set — snapshots from
        // weightless (and pre-tenancy) configs keep their exact bytes.
        if !cfg.tenant_weights.is_empty() {
            let mut w = Json::obj();
            for (tenant, weight) in &cfg.tenant_weights {
                w.set(tenant.as_str(), *weight);
            }
            j.set("tenant_weights", w);
        }
        j
    }

    /// Serialize the engine's complete mutable state for a durable
    /// snapshot. Deterministic: identical states serialize to identical
    /// bytes (every map is emitted in sorted key order), which the
    /// crash-recovery differential tests rely on. The memory-jitter PRNG
    /// needs no cursor here — draws are stateless functions of
    /// `(job, epoch)` (see [`Self::observed_peak_bytes`]).
    pub fn snapshot_json(&self) -> Json {
        let pending: Vec<Json> = self
            .pending
            .iter()
            .map(|p| {
                let mut j = Json::obj();
                j.set("spec", p.spec.to_json()).set("attempts", p.attempts);
                j
            })
            .collect();
        let mut run_ids: Vec<JobId> = self.running.keys().copied().collect();
        run_ids.sort_unstable();
        let running: Vec<Json> = run_ids
            .into_iter()
            .map(|id| {
                let r = &self.running[&id];
                let mut j = Json::obj();
                j.set("job", id)
                    .set("spec", r.spec.to_json())
                    .set("first_start", r.first_start)
                    .set("gpus", r.gpus)
                    .set("attempts", r.attempts)
                    .set("epoch", r.epoch)
                    .set("start_time", r.start_time)
                    .set("sps", r.sps)
                    .set("resumed_samples", r.resumed_samples)
                    .set("outcome_at", r.outcome_at)
                    .set("will_oom", r.will_oom);
                if let Some((node, deadline)) = r.draining {
                    j.set("draining", Json::Arr(vec![Json::from(node), Json::from(deadline)]));
                }
                j
            })
            .collect();
        let mut util = Json::obj();
        util.set("last_t", self.util.last_t)
            .set("busy_gpu_seconds", self.util.busy_gpu_seconds)
            .set("capacity_gpu_seconds", self.util.capacity_gpu_seconds);
        let retention: Vec<Json> =
            self.retention.order.iter().map(|&id| Json::from(id)).collect();
        let decisions: Vec<Json> = self
            .decision_log
            .iter()
            .map(|(job, parts)| {
                let pj: Vec<Json> = parts
                    .iter()
                    .map(|&(n, g)| Json::Arr(vec![Json::from(n), Json::from(g)]))
                    .collect();
                Json::Arr(vec![Json::from(*job), Json::Arr(pj)])
            })
            .collect();
        let held: Vec<Json> = self
            .held
            .iter()
            .map(|(&job, h)| {
                let mut hj = Json::obj();
                hj.set("job", job)
                    .set("spec", h.spec.to_json())
                    .set("attempts", h.attempts)
                    .set("release_at", h.release_at);
                hj
            })
            .collect();
        let crash_counts: Vec<Json> = self
            .crash_counts
            .iter()
            .map(|(&job, &c)| Json::Arr(vec![Json::from(job), Json::from(c as u64)]))
            .collect();
        let crash_times: Vec<Json> = self
            .node_crash_times
            .iter()
            .map(|(&n, ts)| {
                Json::Arr(vec![
                    Json::from(n),
                    Json::Arr(ts.iter().map(|&t| Json::from(t)).collect()),
                ])
            })
            .collect();
        let mut j = Json::obj();
        j.set("config", Self::config_guard_json(&self.cfg))
            .set("orch", self.orch.to_json())
            .set("pending", Json::Arr(pending))
            .set("running", Json::Arr(running))
            .set("agg", self.agg.to_json())
            .set("events", self.events.to_json())
            .set("work_units", self.work_units)
            .set("sched_wall_s", self.sched_wall_s)
            .set("util", util)
            .set("submit_times", id_map_f64_json(&self.submit_times))
            .set("first_starts", id_map_f64_json(&self.first_starts))
            .set("epochs", id_map_u64_json(&self.epochs))
            .set("retention", Json::Arr(retention))
            .set("ckpts", self.ckpts.to_json())
            .set("decision_log", Json::Arr(decisions))
            .set("held", Json::Arr(held))
            .set("crash_counts", Json::Arr(crash_counts))
            .set("node_crash_times", Json::Arr(crash_times))
            .set("quarantine_until", node_map_f64_json(&self.quarantine_until))
            .set("slow_factors", node_map_f64_json(&self.slow_factors))
            .set("ckpt_fail_until", node_map_f64_json(&self.ckpt_fail_until))
            .set("tick_queued", self.tick_queued);
        if self.last_round != f64::NEG_INFINITY {
            // NEG_INFINITY (no round yet) has no JSON form — absence is the
            // sentinel.
            j.set("last_round", self.last_round);
        }
        j
    }

    /// Restore from [`Self::snapshot_json`] output. The engine must have
    /// been constructed with the same scheduler policy and an
    /// [`EngineConfig`] whose determinism-affecting knobs match the
    /// snapshot's — a mismatch is rejected because WAL replay on top of the
    /// restored state would silently diverge from the original run.
    pub fn restore_from_json(&mut self, j: &Json) -> Result<(), String> {
        let cfgj = j.get("config").ok_or("snapshot: missing 'config'")?;
        let mine = Self::config_guard_json(&self.cfg);
        if cfgj != &mine {
            return Err(format!(
                "snapshot engine config {} does not match running config {} — replay would \
                 diverge; restart with the original settings",
                cfgj.to_string_compact(),
                mine.to_string_compact()
            ));
        }
        self.orch = Orchestrator::from_json(j.get("orch").ok_or("snapshot: missing 'orch'")?)?;
        self.pm = PerfModel::new(self.orch.state().inter_node_gbps);
        self.pending = PendingQueue::new();
        for p in j.get("pending").and_then(Json::as_arr).ok_or("snapshot: missing 'pending'")? {
            self.pending.push(PendingJob {
                spec: JobSpec::from_json(p.get("spec").ok_or("pending: missing 'spec'")?)?,
                attempts: p
                    .get("attempts")
                    .and_then(Json::as_u64)
                    .and_then(|a| u32::try_from(a).ok())
                    .ok_or("pending: missing 'attempts'")?,
            });
        }
        self.running = HashMap::new();
        for r in j.get("running").and_then(Json::as_arr).ok_or("snapshot: missing 'running'")? {
            let f = |k: &str| {
                r.get(k).and_then(Json::as_f64).ok_or_else(|| format!("running: missing '{k}'"))
            };
            let u = |k: &str| {
                r.get(k).and_then(Json::as_u64).ok_or_else(|| format!("running: missing '{k}'"))
            };
            let draining = match r.get("draining").and_then(Json::as_arr) {
                Some([n, d]) => Some((
                    n.as_usize().ok_or("running: bad draining node")?,
                    d.as_f64().ok_or("running: bad draining deadline")?,
                )),
                Some(_) => return Err("running: bad 'draining'".into()),
                None => None,
            };
            let job = u("job")?;
            self.running.insert(
                job,
                RunningJob {
                    spec: JobSpec::from_json(r.get("spec").ok_or("running: missing 'spec'")?)?,
                    first_start: f("first_start")?,
                    gpus: u("gpus")? as u32,
                    attempts: u("attempts")? as u32,
                    epoch: u("epoch")?,
                    start_time: f("start_time")?,
                    sps: f("sps")?,
                    resumed_samples: u("resumed_samples")?,
                    draining,
                    outcome_at: f("outcome_at")?,
                    will_oom: r
                        .get("will_oom")
                        .and_then(Json::as_bool)
                        .ok_or("running: missing 'will_oom'")?,
                },
            );
        }
        self.agg = RunAggregates::from_json(j.get("agg").ok_or("snapshot: missing 'agg'")?)?;
        self.events = EventLog::from_json(
            j.get("events").ok_or("snapshot: missing 'events'")?,
            self.cfg.event_log_cap,
        )?;
        self.work_units =
            j.get("work_units").and_then(Json::as_u64).ok_or("snapshot: missing 'work_units'")?;
        self.sched_wall_s = j
            .get("sched_wall_s")
            .and_then(Json::as_f64)
            .ok_or("snapshot: missing 'sched_wall_s'")?;
        let util = j.get("util").ok_or("snapshot: missing 'util'")?;
        let uf = |k: &str| {
            util.get(k).and_then(Json::as_f64).ok_or_else(|| format!("util: missing '{k}'"))
        };
        self.util = UtilIntegrator {
            last_t: uf("last_t")?,
            busy_gpu_seconds: uf("busy_gpu_seconds")?,
            capacity_gpu_seconds: uf("capacity_gpu_seconds")?,
        };
        self.submit_times = id_map_f64_restore(j.get("submit_times"), "submit_times")?;
        self.first_starts = id_map_f64_restore(j.get("first_starts"), "first_starts")?;
        self.epochs = id_map_u64_restore(j.get("epochs"), "epochs")?;
        self.retention = RetentionQueue::new(self.cfg.retain_terminal);
        for id in
            j.get("retention").and_then(Json::as_arr).ok_or("snapshot: missing 'retention'")?
        {
            let _ = self.retention.note(id.as_u64().ok_or("retention: bad id")?);
        }
        self.ckpts = CheckpointStore::from_json(j.get("ckpts").ok_or("snapshot: missing 'ckpts'")?)?;
        self.decision_log = Vec::new();
        for d in j
            .get("decision_log")
            .and_then(Json::as_arr)
            .ok_or("snapshot: missing 'decision_log'")?
        {
            let Some([job, parts]) = d.as_arr() else {
                return Err("decision_log: bad entry".into());
            };
            let job = job.as_u64().ok_or("decision_log: bad job")?;
            let mut ps: Vec<(NodeId, u32)> = Vec::new();
            for p in parts.as_arr().ok_or("decision_log: bad parts")? {
                let Some([n, g]) = p.as_arr() else {
                    return Err("decision_log: bad part".into());
                };
                ps.push((
                    n.as_usize().ok_or("decision_log: bad node")?,
                    g.as_u64()
                        .and_then(|g| u32::try_from(g).ok())
                        .ok_or("decision_log: bad gpus")?,
                ));
            }
            self.decision_log.push((job, ps));
        }
        self.held = BTreeMap::new();
        if let Some(arr) = j.get("held").and_then(Json::as_arr) {
            for h in arr {
                let job = h.get("job").and_then(Json::as_u64).ok_or("held: missing 'job'")?;
                self.held.insert(
                    job,
                    HeldJob {
                        spec: JobSpec::from_json(h.get("spec").ok_or("held: missing 'spec'")?)?,
                        attempts: h
                            .get("attempts")
                            .and_then(Json::as_u64)
                            .and_then(|a| u32::try_from(a).ok())
                            .ok_or("held: missing 'attempts'")?,
                        release_at: h
                            .get("release_at")
                            .and_then(Json::as_f64)
                            .ok_or("held: missing 'release_at'")?,
                    },
                );
            }
        }
        self.crash_counts = BTreeMap::new();
        if let Some(arr) = j.get("crash_counts").and_then(Json::as_arr) {
            for e in arr {
                let Some([k, v]) = e.as_arr() else {
                    return Err("crash_counts: bad entry".into());
                };
                self.crash_counts.insert(
                    k.as_u64().ok_or("crash_counts: bad id")?,
                    v.as_u64()
                        .and_then(|c| u32::try_from(c).ok())
                        .ok_or("crash_counts: bad count")?,
                );
            }
        }
        self.node_crash_times = BTreeMap::new();
        if let Some(arr) = j.get("node_crash_times").and_then(Json::as_arr) {
            for e in arr {
                let Some([k, v]) = e.as_arr() else {
                    return Err("node_crash_times: bad entry".into());
                };
                let mut ts = Vec::new();
                for t in v.as_arr().ok_or("node_crash_times: bad times")? {
                    ts.push(t.as_f64().ok_or("node_crash_times: bad time")?);
                }
                self.node_crash_times
                    .insert(k.as_usize().ok_or("node_crash_times: bad node")?, ts);
            }
        }
        self.quarantine_until = node_map_f64_restore(j.get("quarantine_until"), "quarantine_until")?;
        self.slow_factors = node_map_f64_restore(j.get("slow_factors"), "slow_factors")?;
        self.ckpt_fail_until = node_map_f64_restore(j.get("ckpt_fail_until"), "ckpt_fail_until")?;
        self.last_round =
            j.get("last_round").and_then(Json::as_f64).unwrap_or(f64::NEG_INFINITY);
        self.tick_queued =
            j.get("tick_queued").and_then(Json::as_bool).ok_or("snapshot: missing 'tick_queued'")?;
        // The scheduler's own caches (MARP plan lists, ILP type dimensions)
        // are derived state: rebuild them against the restored topology.
        self.sched.cluster_changed(self.orch.state());
        Ok(())
    }
}

fn id_map_f64_json(m: &HashMap<JobId, f64>) -> Json {
    let mut keys: Vec<JobId> = m.keys().copied().collect();
    keys.sort_unstable();
    Json::Arr(
        keys.into_iter().map(|k| Json::Arr(vec![Json::from(k), Json::from(m[&k])])).collect(),
    )
}

fn id_map_u64_json(m: &HashMap<JobId, u64>) -> Json {
    let mut keys: Vec<JobId> = m.keys().copied().collect();
    keys.sort_unstable();
    Json::Arr(
        keys.into_iter().map(|k| Json::Arr(vec![Json::from(k), Json::from(m[&k])])).collect(),
    )
}

fn node_map_f64_json(m: &BTreeMap<NodeId, f64>) -> Json {
    Json::Arr(
        m.iter().map(|(&n, &v)| Json::Arr(vec![Json::from(n), Json::from(v)])).collect(),
    )
}

fn node_map_f64_restore(j: Option<&Json>, what: &str) -> Result<BTreeMap<NodeId, f64>, String> {
    let mut m = BTreeMap::new();
    let Some(arr) = j.and_then(Json::as_arr) else { return Ok(m) };
    for e in arr {
        let Some([k, v]) = e.as_arr() else {
            return Err(format!("{what}: bad entry"));
        };
        m.insert(
            k.as_usize().ok_or_else(|| format!("{what}: bad node"))?,
            v.as_f64().ok_or_else(|| format!("{what}: bad value"))?,
        );
    }
    Ok(m)
}

fn id_map_f64_restore(j: Option<&Json>, what: &str) -> Result<HashMap<JobId, f64>, String> {
    let arr = j.and_then(Json::as_arr).ok_or_else(|| format!("snapshot: missing '{what}'"))?;
    let mut m = HashMap::new();
    for e in arr {
        let Some([k, v]) = e.as_arr() else {
            return Err(format!("{what}: bad entry"));
        };
        m.insert(
            k.as_u64().ok_or_else(|| format!("{what}: bad id"))?,
            v.as_f64().ok_or_else(|| format!("{what}: bad value"))?,
        );
    }
    Ok(m)
}

fn id_map_u64_restore(j: Option<&Json>, what: &str) -> Result<HashMap<JobId, u64>, String> {
    let arr = j.and_then(Json::as_arr).ok_or_else(|| format!("snapshot: missing '{what}'"))?;
    let mut m = HashMap::new();
    for e in arr {
        let Some([k, v]) = e.as_arr() else {
            return Err(format!("{what}: bad entry"));
        };
        m.insert(
            k.as_u64().ok_or_else(|| format!("{what}: bad id"))?,
            v.as_u64().ok_or_else(|| format!("{what}: bad value"))?,
        );
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::clock::VirtualClock;
    use super::*;
    use crate::config::models::model_by_name;
    use crate::config::{gpu_by_name, real_testbed, LinkKind};
    use crate::marp::Marp;
    use crate::sched::has::Has;

    fn job(id: u64, model: &str, batch: u32, samples: u64, t: f64) -> JobSpec {
        JobSpec::new(id, model_by_name(model).unwrap(), batch, samples, t)
    }

    /// Drain the virtual clock to completion.
    fn drive(engine: &mut SchedulingEngine, clock: &mut VirtualClock) -> Effects {
        let mut all = Effects::default();
        let mut guard = 0;
        while let Some((_, ev)) = clock.pop() {
            all.merge(engine.handle(ev, clock));
            all.merge(engine.run_round(clock));
            guard += 1;
            assert!(guard < 100_000, "event loop did not terminate");
        }
        all
    }

    #[test]
    fn fair_order_passthrough_without_two_tenants() {
        let mk = |id: u64, tenant: &str| PendingJob {
            spec: job(id, "gpt2-125m", 4, 100, 0.0).with_tenant(tenant),
            attempts: 0,
        };
        let running = HashMap::new();
        // Anonymous-only and single-tenant queues stay untouched (None).
        let anon: PendingQueue = vec![mk(1, ""), mk(2, "")].into();
        assert!(SchedulingEngine::fair_order(&anon, &running, &[]).is_none());
        let single: PendingQueue = vec![mk(1, "a"), mk(2, "a")].into();
        assert!(SchedulingEngine::fair_order(&single, &running, &[]).is_none());
    }

    #[test]
    fn fair_order_interleaves_a_backlogged_tenant() {
        let mk = |id: u64, tenant: &str| PendingJob {
            spec: job(id, "gpt2-125m", 4, 100, 0.0).with_tenant(tenant),
            attempts: 0,
        };
        // 10:1 skew: heavy submitted 10 jobs before light's single job.
        let mut jobs: Vec<PendingJob> = (0..10).map(|i| mk(i, "heavy")).collect();
        jobs.push(mk(10, "light"));
        let q: PendingQueue = jobs.into();
        let fair =
            SchedulingEngine::fair_order(&q, &HashMap::new(), &[]).expect("two tenants engage");
        let order: Vec<u64> = fair.iter().map(|p| p.spec.id).collect();
        // FCFS would place light's job last (position 10); weighted max-min
        // puts it second (heavy wins the 0-0 tie lexicographically, then
        // light has the lower share).
        assert_eq!(order.len(), 11);
        assert_eq!(order[1], 10, "light tenant must not wait behind the backlog: {order:?}");
        // FCFS within a tenant is preserved.
        let heavy: Vec<u64> = order.iter().copied().filter(|&id| id != 10).collect();
        assert_eq!(heavy, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn fair_order_respects_weights_and_running_share() {
        let mk = |id: u64, tenant: &str| PendingJob {
            spec: job(id, "gpt2-125m", 4, 100, 0.0).with_tenant(tenant),
            attempts: 0,
        };
        let jobs: Vec<PendingJob> =
            (0..6).map(|i| mk(i, if i < 3 { "a" } else { "b" })).collect();
        let q: PendingQueue = jobs.into();
        // Weight 2:1 → tenant a takes two of every three slots.
        let weights = vec![("a".to_string(), 2.0)];
        let fair = SchedulingEngine::fair_order(&q, &HashMap::new(), &weights).unwrap();
        let tenants: Vec<&str> =
            fair.iter().map(|p| p.spec.tenant.as_str()).collect();
        assert_eq!(tenants, vec!["a", "b", "a", "a", "b", "a"]);
        // A tenant already holding GPUs starts with that share charged.
        let mut running = HashMap::new();
        running.insert(
            99,
            RunningJob {
                spec: job(99, "gpt2-125m", 4, 100, 0.0).with_tenant("a"),
                first_start: 0.0,
                gpus: 8,
                attempts: 1,
                epoch: 1,
                start_time: 0.0,
                sps: 1.0,
                resumed_samples: 0,
                draining: None,
                outcome_at: 100.0,
                will_oom: false,
            },
        );
        let fair = SchedulingEngine::fair_order(&q, &running, &[]).unwrap();
        assert_eq!(
            fair.iter().next().unwrap().spec.tenant,
            "b",
            "tenant with running GPUs yields the first slot"
        );
    }

    #[test]
    fn tenant_accounting_reaches_the_report() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();
        clock.schedule(
            0.0,
            ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0).with_tenant("team-a")),
        );
        clock.schedule(
            0.0,
            ClusterEvent::Arrival(job(2, "gpt2-125m", 4, 10_000, 0.0).with_tenant("team-b")),
        );
        drive(&mut engine, &mut clock);
        let tenants = engine.aggregates().tenants();
        assert_eq!(tenants.len(), 2);
        assert_eq!(tenants["team-a"].n_completed(), 1);
        assert!(tenants["team-a"].avg_jct_s() > 0.0);
        assert!(tenants["team-a"].gpu_seconds > 0.0);
        assert!(tenants["team-b"].gpu_seconds > 0.0);
        let report = crate::metrics::RunReport::from_aggregates(
            "has", "w", engine.aggregates(), 0, 0, 0.0, 0.0,
        );
        let shares: f64 = report.tenants.iter().map(|t| t.gpu_share).sum();
        assert!((shares - 1.0).abs() < 1e-9, "shares sum to 1: {shares}");
    }

    #[test]
    fn arrival_place_finish_roundtrip() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();
        clock.schedule(0.0, ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)));
        let fx = drive(&mut engine, &mut clock);
        assert_eq!(fx.placed.len(), 1);
        assert_eq!(fx.finished, vec![1]);
        assert!(fx.rejected.is_empty());
        assert_eq!(engine.aggregates().n_completed, 1);
        assert!(engine.conservation_ok());
        assert_eq!(engine.cluster_state().idle_gpus(), engine.cluster_state().total_gpus());
        // The audit trail tells the whole story, in order.
        let kinds: Vec<&EventKind> = engine.event_log().iter().map(|r| &r.kind).collect();
        assert!(matches!(kinds[0], EventKind::Arrival { job: 1 }));
        assert!(matches!(kinds[1], EventKind::Placed { job: 1, epoch: 1, will_oom: false, .. }));
        assert!(matches!(kinds[2], EventKind::Finished { job: 1, epoch: 1 }));
        let seqs: Vec<u64> = engine.event_log().iter().map(|r| r.seq).collect();
        assert!(seqs.windows(2).all(|w| w[1] == w[0] + 1), "dense monotonic seqs: {seqs:?}");
    }

    #[test]
    fn stale_finish_epoch_is_ignored() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();
        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)), &mut clock);
        let fx = engine.run_round(&mut clock);
        assert_eq!(fx.placed.len(), 1);
        let epoch = fx.placed[0].epoch;
        // A completion from a previous (never-existing) epoch must not
        // release anything.
        let stale = engine.handle(ClusterEvent::Finish { job: 1, epoch: epoch + 7 }, &mut clock);
        assert!(stale.finished.is_empty());
        assert!(engine.is_running(1));
        assert!(engine.conservation_ok());
        // The real epoch completes it.
        let good = engine.handle(ClusterEvent::Finish { job: 1, epoch }, &mut clock);
        assert_eq!(good.finished, vec![1]);
        assert!(engine.conservation_ok());
    }

    #[test]
    fn node_leave_preempts_exactly_the_jobs_on_that_node() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();
        // Big job lands on 80G nodes, small job on a 40G node — disjoint.
        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-7b", 2, 1_000_000, 0.0)), &mut clock);
        engine.handle(ClusterEvent::Arrival(job(2, "gpt2-125m", 4, 1_000_000, 0.0)), &mut clock);
        let fx = engine.run_round(&mut clock);
        assert_eq!(fx.placed.len(), 2, "both jobs must start");
        let big_nodes: Vec<usize> = engine
            .decision_log()
            .iter()
            .find(|(id, _)| *id == 1)
            .unwrap()
            .1
            .iter()
            .map(|&(n, _)| n)
            .collect();
        let small_nodes: Vec<usize> = engine
            .decision_log()
            .iter()
            .find(|(id, _)| *id == 2)
            .unwrap()
            .1
            .iter()
            .map(|&(n, _)| n)
            .collect();
        assert!(big_nodes.iter().all(|n| !small_nodes.contains(n)), "disjoint placements");

        let gone = big_nodes[0];
        let fx = engine.handle(ClusterEvent::NodeLeave(gone), &mut clock);
        assert_eq!(fx.preempted, vec![1], "only the job on the retired node is preempted");
        assert!(engine.is_pending(1), "preempted job requeued");
        assert!(engine.is_running(2), "unrelated job untouched");
        assert_eq!(engine.attempts_of(1), 1, "requeued with its attempt count (next run = 2)");
        assert!(engine.conservation_ok(), "conservation after NodeLeave");

        // The remaining 80G GPUs (2×2) can host the job again.
        let fx = engine.run_round(&mut clock);
        if let Some(p) = fx.placed.iter().find(|p| p.job == 1) {
            assert_eq!(p.attempts, 2, "re-placement counts as attempt 2");
        }
        assert!(engine.conservation_ok());

        // Run everything down: preempted job must still terminate exactly
        // once, and its stale Finish from the first placement is discarded.
        drive(&mut engine, &mut clock);
        assert!(engine.conservation_ok());
        let finishes_of_1 = engine
            .event_log()
            .iter()
            .filter(|r| matches!(r.kind, EventKind::Finished { job: 1, .. }))
            .count();
        assert!(finishes_of_1 <= 1, "a preempted job completes at most once");
        // The leave is auditable: a NodeLeft naming job 1 and a matching
        // Preempted record.
        assert!(engine.event_log().iter().any(
            |r| matches!(&r.kind, EventKind::NodeLeft { preempted, .. } if preempted == &vec![1])
        ));
        assert!(engine
            .event_log()
            .iter()
            .any(|r| matches!(r.kind, EventKind::Preempted { job: 1, .. })));
        assert_eq!(engine.cluster_state().idle_gpus(), engine.cluster_state().total_gpus());
    }

    #[test]
    fn node_join_makes_infeasible_pending_job_schedulable() {
        // A cluster with only 2×40G GPUs cannot host gpt2-7b at all (MARP
        // finds no plan). Keep the cluster busy with a small job so the big
        // one is not rejected-as-unplaceable, then join an 80G node.
        let a100_40 = gpu_by_name("A100-40G").unwrap();
        let spec = ClusterSpec {
            name: "tiny".into(),
            nodes: vec![NodeSpec { gpu: a100_40, count: 2, link: LinkKind::Pcie }],
            inter_node_gbps: 12.5,
        };
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();

        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-125m", 4, 1_000_000, 0.0)), &mut clock);
        let fx = engine.run_round(&mut clock);
        assert_eq!(fx.placed.len(), 1, "blocker job runs");

        engine.handle(ClusterEvent::Arrival(job(2, "gpt2-7b", 2, 50_000, 0.0)), &mut clock);
        let fx = engine.run_round(&mut clock);
        assert!(fx.placed.is_empty(), "7b infeasible on 2×40G");
        assert!(engine.is_pending(2));

        let a800 = gpu_by_name("A800-80G").unwrap();
        let join = NodeSpec { gpu: a800, count: 4, link: LinkKind::NvLink };
        let fx = engine.handle(ClusterEvent::NodeJoin(join), &mut clock);
        assert!(fx.placed.is_empty() && fx.preempted.is_empty());
        assert_eq!(engine.cluster_state().total_gpus(), 6);
        let fx = engine.run_round(&mut clock);
        let placed: Vec<JobId> = fx.placed.iter().map(|p| p.job).collect();
        assert_eq!(placed, vec![2], "NodeJoin made the pending 7b job schedulable");
        // It landed on the joined node (id 1).
        let (_, parts) = engine.decision_log().iter().find(|(id, _)| *id == 2).unwrap();
        assert!(parts.iter().all(|&(n, _)| n == 1), "placed on the joined 80G node: {parts:?}");
        assert!(engine.conservation_ok());
    }

    #[test]
    fn terminal_retention_evicts_old_bookkeeping() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let cfg = EngineConfig { retain_terminal: 2, ..EngineConfig::default() };
        let mut engine = SchedulingEngine::new(&spec, &mut has, cfg);
        let mut clock = VirtualClock::new();
        for i in 0..5u64 {
            clock.schedule(
                i as f64 * 10_000.0,
                ClusterEvent::Arrival(job(i, "gpt2-350m", 8, 1_000, i as f64 * 10_000.0)),
            );
        }
        drive(&mut engine, &mut clock);
        assert_eq!(engine.aggregates().n_completed, 5, "aggregates are O(1) — never evicted");
        assert_eq!(engine.retained_terminal(), 2, "only the 2 newest terminal jobs tracked");
        assert_eq!(engine.run_epoch(0), 0, "evicted terminal job's epoch dropped");
        assert!(engine.run_epoch(4) >= 1, "recent terminal job retained");
        assert!(engine.conservation_ok());
    }

    #[test]
    fn conservation_holds_after_every_event_under_churn() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();
        for i in 0..8u64 {
            clock.schedule(
                i as f64 * 20.0,
                ClusterEvent::Arrival(job(i, "gpt2-350m", 8, 200_000, i as f64 * 20.0)),
            );
        }
        // Churn: retire a 40G node early, join a replacement later.
        clock.schedule(30.0, ClusterEvent::NodeLeave(0));
        let a100_40 = gpu_by_name("A100-40G").unwrap();
        let rejoin = NodeSpec { gpu: a100_40, count: 2, link: LinkKind::Pcie };
        clock.schedule(90.0, ClusterEvent::NodeJoin(rejoin));
        let mut guard = 0;
        while let Some((_, ev)) = clock.pop() {
            engine.handle(ev, &mut clock);
            assert!(engine.conservation_ok(), "conservation after every event");
            engine.run_round(&mut clock);
            assert!(engine.conservation_ok(), "conservation after every round");
            guard += 1;
            assert!(guard < 100_000);
        }
        assert_eq!(
            engine.aggregates().n_completed + engine.rejected_count(),
            8,
            "every job reaches a terminal state"
        );
        assert_eq!(engine.cluster_state().idle_gpus(), engine.cluster_state().total_gpus());
    }

    #[test]
    fn interval_scheduler_defers_on_timer_backed_wall_clock() {
        use super::clock::WallClock;
        use crate::sched::sia::Sia;
        let spec = crate::config::sia_sim();
        let mut sia = Sia::new(&spec);
        sia.round_interval = 1_000.0; // far beyond this test's wall time
        let mut engine = SchedulingEngine::new(&spec, &mut sia, EngineConfig::default());
        let mut wall = WallClock::with_round_timer();
        // First round ever is immediate (last_round = -inf).
        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)), &mut wall);
        let fx = engine.run_round(&mut wall);
        assert_eq!(fx.placed.len(), 1, "first round executes immediately");
        // A second arrival inside the interval must WAIT for the timer's
        // RoundTick instead of rounding immediately.
        engine.handle(ClusterEvent::Arrival(job(2, "gpt2-350m", 8, 10_000, 0.0)), &mut wall);
        let fx = engine.run_round(&mut wall);
        assert!(fx.placed.is_empty(), "deferred to the round timer");
        assert!(engine.is_pending(2));
        // On a bare wall clock (no timer thread) deferring would stall
        // forever, so the engine rounds immediately — the pre-timer
        // behavior.
        let mut sia2 = Sia::new(&spec);
        sia2.round_interval = 1_000.0;
        let mut engine2 = SchedulingEngine::new(&spec, &mut sia2, EngineConfig::default());
        let mut bare = WallClock::new();
        engine2.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)), &mut bare);
        engine2.run_round(&mut bare);
        engine2.handle(ClusterEvent::Arrival(job(2, "gpt2-350m", 8, 10_000, 0.0)), &mut bare);
        let fx = engine2.run_round(&mut bare);
        assert_eq!(fx.placed.len(), 1, "bare wall clock rounds immediately");
    }

    #[test]
    fn graceful_drain_checkpoints_and_resumes() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let cfg = EngineConfig {
            drain_grace_s: 60.0,
            ckpt_every_steps: 1,
            ckpt_write_s: 1.0,
            ..EngineConfig::default()
        };
        let mut engine = SchedulingEngine::new(&spec, &mut has, cfg);
        let mut clock = VirtualClock::new();
        // A long job; retire its node mid-run.
        engine.handle(
            ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 100_000_000, 0.0)),
            &mut clock,
        );
        let fx = engine.run_round(&mut clock);
        assert_eq!(fx.placed.len(), 1);
        assert_eq!(fx.placed[0].resumed_samples, 0);
        let node = engine.decision_log()[0].1[0].0;
        clock.schedule(500.0, ClusterEvent::NodeLeave(node));
        // Let the leave + drain deadline play out.
        let mut drained_seen = false;
        let mut guard = 0;
        while let Some((_, ev)) = clock.pop() {
            let fx = engine.handle(ev, &mut clock);
            if !fx.preempted.is_empty() {
                drained_seen = true;
                // Drained on a virtual clock: no wall-clock directive.
                assert!(fx.drain_requested.is_empty());
                assert!(engine.is_pending(1), "drained job requeued");
                let ck = engine.checkpoint_of(1).expect("checkpoint saved");
                assert!(ck.steps_done >= 1, "progress survived the drain");
                assert_eq!(
                    ck.state_digest,
                    crate::runtime::checkpoint::state_digest(1, ck.steps_done)
                );
            }
            engine.run_round(&mut clock);
            assert!(engine.conservation_ok(), "conservation during drain");
            guard += 1;
            assert!(guard < 10_000);
        }
        assert!(drained_seen, "the node retirement must have drained the job");
        assert_eq!(engine.aggregates().n_completed, 1);
        assert_eq!(engine.aggregates().n_drains, 1);
        assert_eq!(engine.checkpoint_count(), 0, "terminal job dropped its checkpoint");
        assert_eq!(engine.device_memory().total_used_bytes(), 0, "no byte leak");
        // The audit trail tells the drain story in order.
        let kinds: Vec<&EventKind> = engine.event_log().iter().map(|r| &r.kind).collect();
        assert!(kinds
            .iter()
            .any(|k| matches!(k, EventKind::DrainRequested { job: 1, .. })));
        assert!(kinds
            .iter()
            .any(|k| matches!(k, EventKind::Drained { job: 1, steps_ckpt, .. } if *steps_ckpt >= 1)));
        assert!(kinds
            .iter()
            .any(|k| matches!(k, EventKind::ResumedFromCkpt { job: 1, steps_ckpt, .. } if *steps_ckpt >= 1)));
        // Resume means strictly less than double work.
        let total_steps = 100_000_000u64 / 8;
        let executed = engine.aggregates().total_steps_executed();
        assert!(
            executed >= total_steps && executed < 2 * total_steps,
            "resumed from checkpoint: executed {executed} of {total_steps} nominal"
        );
        // The retired node is gone.
        assert_eq!(engine.cluster_state().nodes[node].total, 0);
    }

    #[test]
    fn byte_ledger_observes_real_oom_without_timer() {
        use crate::sched::opportunistic::Opportunistic;
        // Opportunistic mis-sizes gpt2-2.7b on the real testbed (sized for
        // the 80G card, greedily placed on 40G): with device-memory
        // accounting the byte ledger itself must raise the OOM — no
        // `will_oom` detection timer involved.
        let spec = real_testbed();
        let mut opp = Opportunistic::new(&spec);
        let mut engine = SchedulingEngine::new(&spec, &mut opp, EngineConfig::default());
        let mut clock = VirtualClock::new();
        for i in 0..4u64 {
            clock.schedule(
                i as f64 * 10.0,
                ClusterEvent::Arrival(job(i, "gpt2-2.7b", 8, 50_000, i as f64 * 10.0)),
            );
        }
        drive(&mut engine, &mut clock);
        let agg = engine.aggregates();
        assert_eq!(agg.n_completed + engine.rejected_count(), 4);
        assert!(agg.n_oom_events > 0, "expected ledger-observed OOMs");
        // Every OOM is explained by an OomObserved record whose observed
        // bytes exceed the node's capacity.
        let observed: Vec<_> = engine
            .event_log()
            .iter()
            .filter_map(|r| match r.kind {
                EventKind::OomObserved { observed_bytes, capacity_bytes, .. } => {
                    Some((observed_bytes, capacity_bytes))
                }
                _ => None,
            })
            .collect();
        assert!(!observed.is_empty());
        assert!(observed.iter().all(|&(o, c)| o > c), "observed bytes exceed capacity");
        // Prediction accuracy was sampled on every dispatch, in the
        // paper's >92% band on average.
        assert!(agg.mem_pred_samples() > 0);
        let acc = agg.mem_pred_accuracy_avg();
        assert!((0.85..=1.0).contains(&acc), "accuracy {acc} out of band");
        assert!(engine.conservation_ok());
        assert_eq!(engine.device_memory().total_used_bytes(), 0, "all bytes released");
    }

    #[test]
    fn cancelled_jobs_count_in_aggregates_and_events() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let mut clock = VirtualClock::new();
        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)), &mut clock);
        engine.handle(ClusterEvent::Arrival(job(2, "gpt2-350m", 8, 10_000, 0.0)), &mut clock);
        let fx = engine.run_round(&mut clock);
        assert_eq!(fx.placed.len(), 2);
        assert!(engine.cancel_running(1, clock.now()));
        assert!(!engine.cancel_running(1, clock.now()), "already cancelled");
        assert_eq!(engine.aggregates().n_cancelled, 1);
        assert!(engine
            .event_log()
            .iter()
            .any(|r| matches!(r.kind, EventKind::Cancelled { job: 1, was_running: true })));
        drive(&mut engine, &mut clock);
        assert_eq!(engine.aggregates().n_completed, 1, "only job 2 completes");
        assert!(engine.conservation_ok());
    }

    // ---- failure domains -----------------------------------------------

    #[test]
    fn node_crash_holds_job_with_backoff_and_no_attempt_burn() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let cfg = EngineConfig {
            ckpt_every_steps: 1,
            crash_backoff_base_s: 7.0,
            quarantine_crashes: 0, // isolate the backoff behavior
            ..EngineConfig::default()
        };
        let mut engine = SchedulingEngine::new(&spec, &mut has, cfg);
        let mut clock = VirtualClock::new();
        engine.handle(
            ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 100_000_000, 0.0)),
            &mut clock,
        );
        let fx = engine.run_round(&mut clock);
        assert_eq!(fx.placed.len(), 1);
        let node = engine.decision_log()[0].1[0].0;
        clock.schedule(500.0, ClusterEvent::NodeCrash(node));
        let mut crash_seen = false;
        let mut requeue_time = f64::NAN;
        let mut guard = 0;
        while let Some((_, ev)) = clock.pop() {
            let is_crash = matches!(ev, ClusterEvent::NodeCrash(_));
            let is_requeue = matches!(ev, ClusterEvent::Requeue { job: 1 });
            let fx = engine.handle(ev, &mut clock);
            if is_crash {
                crash_seen = true;
                assert_eq!(fx.preempted, vec![1], "hosted job displaced");
                assert!(fx.requeue_after.is_empty(), "virtual clock self-schedules");
                assert!(engine.is_held(1), "crash-held, not immediately pending");
                assert!(!engine.is_pending(1));
                // Crash is not retirement: the node's capacity stays.
                assert!(engine.cluster_state().nodes[node].total > 0);
            }
            if is_requeue {
                requeue_time = clock.now();
                assert!(engine.is_pending(1), "hold expired → back in the queue");
            }
            engine.run_round(&mut clock);
            assert!(engine.conservation_ok(), "conservation through the crash");
            guard += 1;
            assert!(guard < 10_000);
        }
        assert!(crash_seen);
        assert!((requeue_time - 507.0).abs() < 1e-6, "released at crash + base backoff");
        let agg = engine.aggregates();
        assert_eq!(agg.n_completed, 1, "the job still finishes");
        assert_eq!(agg.n_node_crashes, 1);
        assert_eq!(agg.n_crash_requeues, 1);
        assert!(agg.steps_lost > 0, "work past the floor was lost");
        assert_eq!(engine.rejected_count(), 0, "a crash never burns the attempt budget");
        // Audit trail: the crash names the displaced job, and the job
        // resumed from its checkpoint floor rather than step 0.
        assert!(engine.event_log().iter().any(|r| matches!(
            &r.kind,
            EventKind::NodeCrashed { preempted, .. } if preempted == &vec![1]
        )));
        assert!(engine
            .event_log()
            .iter()
            .any(|r| matches!(r.kind, EventKind::ResumedFromCkpt { job: 1, steps_ckpt, .. } if steps_ckpt >= 1)));
    }

    #[test]
    fn flapping_node_is_quarantined_then_rejoins_after_probation() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let cfg = EngineConfig {
            quarantine_crashes: 2,
            quarantine_window_s: 1_000.0,
            probation_s: 200.0,
            crash_backoff_base_s: 1.0,
            ..EngineConfig::default()
        };
        let mut engine = SchedulingEngine::new(&spec, &mut has, cfg);
        let mut clock = VirtualClock::new();
        for i in 0..4u64 {
            clock.schedule(
                0.0,
                ClusterEvent::Arrival(job(i, "gpt2-350m", 8, 80_000_000, 0.0)),
            );
        }
        let flappy = 0usize;
        clock.schedule(100.0, ClusterEvent::NodeCrash(flappy));
        clock.schedule(150.0, ClusterEvent::NodeCrash(flappy));
        let mut guard = 0;
        while let Some((_, ev)) = clock.pop() {
            engine.handle(ev, &mut clock);
            engine.run_round(&mut clock);
            assert!(engine.conservation_ok());
            guard += 1;
            assert!(guard < 100_000);
        }
        let agg = engine.aggregates();
        assert_eq!(agg.n_node_crashes, 2);
        assert_eq!(agg.n_quarantines, 1, "second crash inside the window quarantines");
        let mut t_quarantine = None;
        let mut t_probation = None;
        for r in engine.event_log().iter() {
            match &r.kind {
                EventKind::NodeQuarantined { node, until_s } if *node == flappy => {
                    t_quarantine = Some(r.time);
                    assert!((until_s - (r.time + 200.0)).abs() < 1e-6);
                }
                EventKind::NodeProbation { node } if *node == flappy => {
                    t_probation = Some(r.time);
                }
                _ => {}
            }
        }
        let (tq, tp) = (t_quarantine.expect("quarantined"), t_probation.expect("probation"));
        assert!((tp - (tq + 200.0)).abs() < 1e-6, "probation ends exactly after probation_s");
        // While quarantined the node took no placements.
        for r in engine.event_log().iter() {
            if let EventKind::Placed { parts, .. } = &r.kind {
                if r.time >= tq && r.time < tp {
                    assert!(
                        parts.iter().all(|&(n, _)| n != flappy),
                        "quarantined node must be excluded from placement"
                    );
                }
            }
        }
        assert!(engine.quarantined_nodes().is_empty(), "probation lifted the quarantine");
        assert_eq!(agg.n_completed, 4, "all jobs still terminate");
        assert_eq!(engine.cluster_state().idle_gpus(), engine.cluster_state().total_gpus());
    }

    #[test]
    fn straggler_slowdown_scales_modeled_runtime_and_clears_at_one() {
        let est = |factors: &[(usize, f64)]| -> f64 {
            let spec = real_testbed();
            let mut has = Has::new(Marp::with_defaults(spec.clone()));
            let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
            let mut clock = VirtualClock::new();
            for &(node, factor) in factors {
                engine.handle(ClusterEvent::Slowdown { node, factor }, &mut clock);
            }
            engine.handle(
                ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000_000, 0.0)),
                &mut clock,
            );
            let fx = engine.run_round(&mut clock);
            assert_eq!(fx.placed.len(), 1);
            fx.placed[0].est_runtime_s
        };
        let base = est(&[]);
        let all_slow: Vec<(usize, f64)> = (0..5).map(|n| (n, 0.25)).collect();
        let slowed = est(&all_slow);
        assert!(
            (slowed / base - 4.0).abs() < 1e-6,
            "quarter throughput → 4× runtime (got {slowed} vs {base})"
        );
        // factor = 1 ends the slowdown.
        let cleared: Vec<(usize, f64)> =
            all_slow.iter().copied().chain((0..5).map(|n| (n, 1.0))).collect();
        let back = est(&cleared);
        assert!((back / base - 1.0).abs() < 1e-9, "slowdown cleared");
    }

    #[test]
    fn ckpt_fail_window_drops_drain_floor_to_last_written_checkpoint() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let cfg = EngineConfig {
            drain_grace_s: 60.0,
            ckpt_every_steps: 1,
            ckpt_write_s: 1.0,
            ..EngineConfig::default()
        };
        let mut engine = SchedulingEngine::new(&spec, &mut has, cfg);
        let mut clock = VirtualClock::new();
        engine.handle(
            ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 100_000_000, 0.0)),
            &mut clock,
        );
        engine.run_round(&mut clock);
        let node = engine.decision_log()[0].1[0].0;
        // Checkpoint writes on the node fail for the whole run, then the
        // node drains: with no prior checkpoint the drain saves nothing.
        engine.handle(ClusterEvent::CkptFail { node, until_s: 1e12 }, &mut clock);
        clock.schedule(500.0, ClusterEvent::NodeLeave(node));
        let mut guard = 0;
        let mut drained_floor = None;
        while let Some((_, ev)) = clock.pop() {
            engine.handle(ev, &mut clock);
            if drained_floor.is_none() {
                if let Some(r) = engine
                    .event_log()
                    .iter()
                    .find(|r| matches!(r.kind, EventKind::Drained { job: 1, .. }))
                {
                    if let EventKind::Drained { steps_ckpt, .. } = r.kind {
                        drained_floor = Some(steps_ckpt);
                        assert!(engine.checkpoint_of(1).is_none(), "nothing durable was written");
                    }
                }
            }
            engine.run_round(&mut clock);
            guard += 1;
            assert!(guard < 10_000);
        }
        assert_eq!(drained_floor, Some(0), "floor fell back to the last written ckpt (none)");
        assert_eq!(engine.aggregates().n_completed, 1, "job restarts from 0 and finishes");
        assert!(engine.aggregates().steps_lost > 0);
    }

    #[test]
    fn crash_state_snapshot_roundtrip_and_rearm() {
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let cfg = EngineConfig {
            quarantine_crashes: 1,
            probation_s: 300.0,
            crash_backoff_base_s: 10.0,
            ..EngineConfig::default()
        };
        let mut engine = SchedulingEngine::new(&spec, &mut has, cfg.clone());
        let mut clock = VirtualClock::new();
        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 500_000, 0.0)), &mut clock);
        engine.run_round(&mut clock);
        let node = engine.decision_log()[0].1[0].0;
        engine.handle(ClusterEvent::Slowdown { node: 4, factor: 0.5 }, &mut clock);
        engine.handle(ClusterEvent::CkptFail { node: 3, until_s: 777.0 }, &mut clock);
        let fx = engine.handle(ClusterEvent::NodeCrash(node), &mut clock);
        assert_eq!(fx.preempted, vec![1]);
        assert!(engine.is_held(1));
        assert_eq!(engine.quarantined_nodes(), vec![node], "single-crash quarantine");

        let snap = engine.snapshot_json();
        let mut has2 = Has::new(Marp::with_defaults(spec.clone()));
        let mut restored = SchedulingEngine::new(&spec, &mut has2, cfg);
        restored.restore_from_json(&snap).expect("restore");
        assert_eq!(
            restored.snapshot_json().to_string_compact(),
            snap.to_string_compact(),
            "failure-domain state survives snapshot → restore byte-for-byte"
        );
        assert!(restored.is_held(1));
        assert_eq!(restored.quarantined_nodes(), vec![node]);
        // Recovery re-arms both the backoff release and the probation end.
        let evs = restored.rearm_events();
        assert!(evs
            .iter()
            .any(|(_, e)| matches!(e, ClusterEvent::Requeue { job: 1 })));
        assert!(evs
            .iter()
            .any(|(t, e)| matches!(e, ClusterEvent::Probation { node: n } if *n == node)
                && (*t - 300.0).abs() < 1e-6));
        let fx = restored.rearm_effects(0.0);
        assert_eq!(fx.requeue_after.len(), 1);
        assert_eq!(fx.probation_after.len(), 1);
    }

    // ---- durability ----------------------------------------------------

    /// Snapshot with the one nondeterministic field (measured scheduler
    /// wall time) zeroed, so runs can be compared byte-for-byte.
    fn canonical_snapshot(engine: &SchedulingEngine) -> String {
        let mut j = engine.snapshot_json();
        j.set("sched_wall_s", 0.0);
        j.to_string_compact()
    }

    #[test]
    fn cluster_event_json_roundtrip() {
        let evs = vec![
            ClusterEvent::Arrival(job(5, "gpt2-1.3b", 4, 123, 1.5)),
            ClusterEvent::Finish { job: 1, epoch: 3 },
            ClusterEvent::Oom { job: 2, epoch: 1 },
            ClusterEvent::RoundTick,
            ClusterEvent::NodeJoin(NodeSpec {
                gpu: gpu_by_name("A100-40G").unwrap(),
                count: 2,
                link: LinkKind::Pcie,
            }),
            ClusterEvent::NodeLeave(3),
            ClusterEvent::Drained { job: 7, epoch: 2 },
            ClusterEvent::Cancel { job: 9 },
            ClusterEvent::NodeCrash(4),
            ClusterEvent::Requeue { job: 11 },
            ClusterEvent::Probation { node: 4 },
            ClusterEvent::Slowdown { node: 2, factor: 0.25 },
            ClusterEvent::CkptFail { node: 1, until_s: 99.5 },
        ];
        for ev in evs {
            let back = ClusterEvent::from_json(&ev.to_json()).expect("roundtrip");
            assert_eq!(format!("{back:?}"), format!("{ev:?}"));
        }
        let mut bogus = Json::obj();
        bogus.set("kind", "bogus");
        assert!(ClusterEvent::from_json(&bogus).is_err());
    }

    #[test]
    fn journal_sees_events_before_rounds_and_skips_ticks() {
        use std::cell::RefCell;
        use std::rc::Rc;

        struct Recorder(Rc<RefCell<Vec<String>>>);
        impl Journal for Recorder {
            fn event(&mut self, time: f64, ev: &ClusterEvent) {
                self.0
                    .borrow_mut()
                    .push(format!("ev@{time}:{}", ev.to_json().to_string_compact()));
            }
            fn round(&mut self, time: f64, _sched_wall_s: f64) {
                self.0.borrow_mut().push(format!("round@{time}"));
            }
        }

        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, EngineConfig::default());
        let log = Rc::new(RefCell::new(Vec::new()));
        engine.set_journal(Box::new(Recorder(log.clone())));
        let mut clock = VirtualClock::new();
        clock.schedule(0.0, ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)));
        drive(&mut engine, &mut clock);
        let log = log.borrow();
        assert!(
            log[0].starts_with("ev@0") && log[0].contains("\"arrival\""),
            "the arrival is journaled before anything else: {log:?}"
        );
        assert_eq!(
            log.iter().filter(|l| l.starts_with("round@")).count(),
            1,
            "only the placing round is journaled; no-op rounds are skipped: {log:?}"
        );
        assert!(log.iter().any(|l| l.contains("\"finish\"")));
        assert!(!log.iter().any(|l| l.contains("round_tick")), "ticks are never journaled");
    }

    #[test]
    fn cancel_event_is_equivalent_to_direct_cancel_calls() {
        let spec = real_testbed();
        let mut h1 = Has::new(Marp::with_defaults(spec.clone()));
        let mut h2 = Has::new(Marp::with_defaults(spec.clone()));
        let mut by_event = SchedulingEngine::new(&spec, &mut h1, EngineConfig::default());
        let mut direct = SchedulingEngine::new(&spec, &mut h2, EngineConfig::default());
        let mut c1 = VirtualClock::new();
        let mut c2 = VirtualClock::new();
        for (e, c) in [(&mut by_event, &mut c1), (&mut direct, &mut c2)] {
            e.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 10_000, 0.0)), c);
            e.handle(ClusterEvent::Arrival(job(2, "gpt2-1.3b", 4, 10_000, 0.0)), c);
            let fx = e.run_round(c);
            assert_eq!(fx.placed.len(), 2);
            // Job 3 arrives after the round: still pending when cancelled.
            e.handle(ClusterEvent::Arrival(job(3, "gpt2-350m", 8, 10_000, 1.0)), c);
        }
        by_event.handle(ClusterEvent::Cancel { job: 3 }, &mut c1);
        by_event.handle(ClusterEvent::Cancel { job: 1 }, &mut c1);
        by_event.handle(ClusterEvent::Cancel { job: 99 }, &mut c1); // unknown: no-op
        assert!(direct.cancel_pending(3, c2.now()));
        assert!(direct.cancel_running(1, c2.now()));
        assert_eq!(canonical_snapshot(&by_event), canonical_snapshot(&direct));
    }

    #[test]
    fn snapshot_restore_roundtrip_is_byte_identical() {
        let cfg = EngineConfig { drain_grace_s: 60.0, ..EngineConfig::default() };
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, cfg.clone());
        let mut clock = VirtualClock::new();
        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 500_000, 0.0)), &mut clock);
        engine.handle(ClusterEvent::Arrival(job(2, "gpt2-7b", 2, 500_000, 0.0)), &mut clock);
        engine.handle(ClusterEvent::Arrival(job(3, "gpt2-1.3b", 4, 500_000, 0.0)), &mut clock);
        engine.run_round(&mut clock);
        // Drain the node hosting job 2 so the snapshot carries a draining
        // entry, and cancel job 3 so it carries terminal bookkeeping.
        let node = engine.decision_log().iter().find(|(id, _)| *id == 2).unwrap().1[0].0;
        engine.handle(ClusterEvent::NodeLeave(node), &mut clock);
        engine.handle(ClusterEvent::Cancel { job: 3 }, &mut clock);

        let snap = engine.snapshot_json();
        let mut has2 = Has::new(Marp::with_defaults(spec.clone()));
        let mut restored = SchedulingEngine::new(&spec, &mut has2, cfg);
        restored.restore_from_json(&snap).expect("restore");
        assert_eq!(
            restored.snapshot_json().to_string_compact(),
            snap.to_string_compact(),
            "restore → snapshot reproduces the snapshot byte-for-byte"
        );

        // A determinism-affecting config mismatch is rejected, not papered
        // over.
        let other = EngineConfig { drain_grace_s: 61.0, ..EngineConfig::default() };
        let mut has3 = Has::new(Marp::with_defaults(spec.clone()));
        let mut wrong = SchedulingEngine::new(&spec, &mut has3, other);
        let err = wrong.restore_from_json(&snap).unwrap_err();
        assert!(err.contains("does not match"), "got: {err}");
    }

    #[test]
    fn recovered_engine_finishes_the_run_identically() {
        // Distinct models → distinct runtimes → no event-time ties, so the
        // uninterrupted and recovered runs see the same event order.
        let arrivals = || {
            vec![
                ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 200_000, 0.0)),
                ClusterEvent::Arrival(job(2, "gpt2-1.3b", 4, 200_000, 0.0)),
                ClusterEvent::Arrival(job(3, "gpt2-7b", 2, 200_000, 0.0)),
            ]
        };

        // Run A: uninterrupted.
        let spec = real_testbed();
        let mut ha = Has::new(Marp::with_defaults(spec.clone()));
        let mut a = SchedulingEngine::new(&spec, &mut ha, EngineConfig::default());
        let mut ca = VirtualClock::new();
        for ev in arrivals() {
            ca.schedule(0.0, ev);
        }
        drive(&mut a, &mut ca);

        // Run B: same prefix, then snapshot mid-run, restore into a fresh
        // engine, re-arm the clock from the recovered running set, finish.
        let mut hb = Has::new(Marp::with_defaults(spec.clone()));
        let mut b1 = SchedulingEngine::new(&spec, &mut hb, EngineConfig::default());
        let mut cb = VirtualClock::new();
        for ev in arrivals() {
            cb.schedule(0.0, ev);
        }
        for _ in 0..4 {
            // 3 arrivals + the first outcome: jobs still in flight after.
            let (_, ev) = cb.pop().unwrap();
            b1.handle(ev, &mut cb);
            b1.run_round(&mut cb);
        }
        assert!(b1.running_count() > 0, "crash point must leave work in flight");
        let snap = b1.snapshot_json();
        let rearm = b1.rearm_events();
        drop(b1); // the "crash"

        let mut has2 = Has::new(Marp::with_defaults(spec.clone()));
        let mut b2 = SchedulingEngine::new(&spec, &mut has2, EngineConfig::default());
        b2.restore_from_json(&snap).expect("restore");
        let mut cb2 = VirtualClock::new();
        for (t, ev) in rearm {
            cb2.schedule(t, ev);
        }
        drive(&mut b2, &mut cb2);

        assert_eq!(a.aggregates().n_completed, 3);
        assert_eq!(
            canonical_snapshot(&a),
            canonical_snapshot(&b2),
            "recovered run must converge to the uninterrupted run's exact state"
        );
    }

    #[test]
    fn rearm_effects_redispatch_running_jobs_with_remaining_delays() {
        let cfg = EngineConfig { drain_grace_s: 50.0, ..EngineConfig::default() };
        let spec = real_testbed();
        let mut has = Has::new(Marp::with_defaults(spec.clone()));
        let mut engine = SchedulingEngine::new(&spec, &mut has, cfg);
        let mut clock = VirtualClock::new();
        engine.handle(ClusterEvent::Arrival(job(1, "gpt2-350m", 8, 1_000_000, 0.0)), &mut clock);
        engine.handle(ClusterEvent::Arrival(job(2, "gpt2-7b", 2, 1_000_000, 0.0)), &mut clock);
        let fx = engine.run_round(&mut clock);
        assert_eq!(fx.placed.len(), 2);
        let node = engine.decision_log().iter().find(|(id, _)| *id == 1).unwrap().1[0].0;
        engine.handle(ClusterEvent::NodeLeave(node), &mut clock);
        assert!(engine.is_running(1), "draining keeps the job running until its deadline");

        let fx = engine.rearm_effects(10.0);
        assert_eq!(fx.placed.len(), 2, "every running job is re-dispatched");
        assert!(fx.placed.iter().all(|p| p.start_time == 10.0));
        assert!(fx.placed.iter().all(|p| p.est_runtime_s >= 0.0));
        let d = fx.drain_requested.iter().find(|d| d.job == 1).expect("drain re-armed");
        assert!(d.delay_s <= 50.0 && d.delay_s >= 0.0);

        // The virtual-clock mirror: the drained deadline plus an outcome
        // for every running job (the drained job's original outcome rides
        // along; its epoch guard makes it stale once the drain lands).
        let evs = engine.rearm_events();
        assert!(evs.iter().any(|(_, e)| matches!(e, ClusterEvent::Drained { job: 1, .. })));
        let outcomes = evs
            .iter()
            .filter(|(_, e)| {
                matches!(e, ClusterEvent::Finish { .. } | ClusterEvent::Oom { .. })
            })
            .count();
        assert_eq!(outcomes, 2);
    }
}
