//! The cluster event log: a bounded, ring-buffered audit stream of
//! everything the scheduling engine did.
//!
//! Operators of an elastic heterogeneous cluster need to answer "what
//! happened?" without replaying a trace: which nodes joined or left, which
//! jobs were preempted by a drain, what plan a placement chose, why a job
//! was rejected. Every [`crate::engine::ClusterEvent`] the engine processes
//! (and every effect it produces) is appended here as an [`EventRecord`]
//! with a **monotonically increasing sequence number** and the engine-clock
//! timestamp.
//!
//! The log is a fixed-capacity ring: old records are evicted
//! oldest-first, but sequence numbers never reset, so a client polling
//! `GET /v1/cluster/events?since=<seq>` can detect a gap (eviction outran
//! its polling) via the `dropped` flag instead of silently missing events.
//! `RoundTick`s are deliberately **not** logged — an idle live coordinator
//! ticking every few tens of milliseconds would flood the ring with noise.

use crate::cluster::NodeId;
use crate::job::JobId;
use std::collections::VecDeque;

/// Why the engine rejected a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// Admission control: MARP found no feasible plan on this cluster.
    AdmissionInfeasible,
    /// The job exhausted its scheduling-attempt budget (OOM retries or
    /// preemptions past `EngineConfig::max_attempts`).
    AttemptsExhausted,
    /// The cluster was fully idle and the scheduler still could not place
    /// the job — it never will.
    Unplaceable,
    /// The run ended (simulation time cap / final drain) while the job was
    /// still queued. Unlike `Unplaceable`, the job may have been perfectly
    /// placeable — it just never got resources before the end.
    RunEnded,
}

impl RejectReason {
    /// Wire name (used by the `/v1/cluster/events` DTOs).
    pub fn as_str(self) -> &'static str {
        match self {
            RejectReason::AdmissionInfeasible => "admission_infeasible",
            RejectReason::AttemptsExhausted => "attempts_exhausted",
            RejectReason::Unplaceable => "unplaceable",
            RejectReason::RunEnded => "run_ended",
        }
    }

    /// Inverse of [`RejectReason::as_str`].
    pub fn from_wire(s: &str) -> Option<Self> {
        match s {
            "admission_infeasible" => Some(RejectReason::AdmissionInfeasible),
            "attempts_exhausted" => Some(RejectReason::AttemptsExhausted),
            "unplaceable" => Some(RejectReason::Unplaceable),
            "run_ended" => Some(RejectReason::RunEnded),
            _ => None,
        }
    }
}

/// One thing that happened on the cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A job entered the pending queue.
    Arrival { job: JobId },
    /// A job started running under the chosen plan.
    Placed {
        job: JobId,
        /// Placement epoch (increments per start of the same job).
        epoch: u64,
        /// Scheduling attempts including this one.
        attempts: u32,
        gpus: u32,
        /// Data-parallel degree of the chosen plan.
        d: u32,
        /// Tensor-parallel degree of the chosen plan.
        t: u32,
        /// Sorted `(node, gpu-count)` parts of the allocation.
        parts: Vec<(NodeId, u32)>,
        /// The plan is memory-oblivious and will OOM (baselines only).
        will_oom: bool,
    },
    /// A running job completed; `epoch` is the run it belongs to.
    Finished { job: JobId, epoch: u64 },
    /// A running job hit an out-of-memory crash. `requeued` is false when
    /// the attempt budget was exhausted (the job was rejected instead).
    Oomed { job: JobId, epoch: u64, requeued: bool },
    /// The device-memory byte ledger observed a dispatch that does not fit:
    /// the job's observed per-GPU peak exceeds `node`'s capacity. A real
    /// OOM (an `Oomed` record follows once the crash is processed), with
    /// the predicted-vs-observed bytes that produced it.
    OomObserved {
        job: JobId,
        epoch: u64,
        node: NodeId,
        predicted_bytes: u64,
        observed_bytes: u64,
        capacity_bytes: u64,
    },
    /// A node retirement asked this job to drain gracefully: finish the
    /// in-flight step, checkpoint, then release by `deadline_s`.
    DrainRequested { job: JobId, epoch: u64, node: NodeId, deadline_s: f64 },
    /// A draining job checkpointed and released its GPUs; it resumes from
    /// `steps_ckpt` (cumulative) on its next placement. `state_digest`
    /// fingerprints the snapshot.
    Drained { job: JobId, epoch: u64, node: NodeId, steps_ckpt: u64, state_digest: u64 },
    /// A placement picked up a checkpoint: the job restarts from
    /// `steps_ckpt` instead of step 0.
    ResumedFromCkpt { job: JobId, epoch: u64, steps_ckpt: u64 },
    /// A job lost its GPUs to a node retirement and went back to the queue.
    Preempted { job: JobId, node: NodeId },
    /// A job reached the `Rejected` terminal state.
    Rejected { job: JobId, reason: RejectReason },
    /// A job was cancelled by the user.
    Cancelled { job: JobId, was_running: bool },
    /// Elasticity: a node joined the cluster.
    NodeJoined { node: NodeId, gpu: String, gpus: u32 },
    /// Elasticity: a node left; `preempted` lists every job it displaced
    /// (each also gets its own `Preempted`, `Drained`, or `Rejected`
    /// record). Under graceful drain this marks the *start* of the
    /// retirement — the node still hosts its draining jobs.
    NodeLeft { node: NodeId, preempted: Vec<JobId> },
    /// A drain-mode retirement completed — the node's capacity reached
    /// zero (immediately for an idle node, after the last resident job
    /// released otherwise) and the hardware is safe to power off. Every
    /// graceful-drain `NodeLeft` is eventually followed by one of these;
    /// instant-preemption leaves retire within their `NodeLeft` record and
    /// do not emit it.
    NodeRetired { node: NodeId },
}

/// One entry in the cluster event log.
#[derive(Debug, Clone, PartialEq)]
pub struct EventRecord {
    /// Monotonically increasing sequence number, starting at 1. Never
    /// reused, even after ring eviction.
    pub seq: u64,
    /// Engine-clock time of the event (virtual seconds in simulation,
    /// seconds since start for a live coordinator).
    pub time: f64,
    pub kind: EventKind,
}

/// A page of events returned by [`EventLog::since`].
#[derive(Debug, Clone, PartialEq)]
pub struct EventsPage {
    /// Records with `seq > since`, ascending, at most the requested limit.
    pub events: Vec<EventRecord>,
    /// True when events after `since` were already evicted from the ring —
    /// the client has a gap it can never recover from this log.
    pub dropped: bool,
    /// Oldest sequence number still retained (0 when the log is empty).
    pub first_seq: u64,
    /// Newest sequence number ever assigned (0 when nothing was logged).
    pub last_seq: u64,
}

/// Bounded ring buffer of [`EventRecord`]s with stable sequence numbers.
#[derive(Debug)]
pub struct EventLog {
    ring: VecDeque<EventRecord>,
    cap: usize,
    next_seq: u64,
}

impl EventLog {
    /// `cap` is the maximum number of retained records (at least 1).
    pub fn new(cap: usize) -> Self {
        Self { ring: VecDeque::new(), cap: cap.max(1), next_seq: 1 }
    }

    /// Append a record; evicts the oldest when full. Returns the assigned
    /// sequence number.
    pub fn push(&mut self, time: f64, kind: EventKind) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        if self.ring.len() == self.cap {
            self.ring.pop_front();
        }
        self.ring.push_back(EventRecord { seq, time, kind });
        seq
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Oldest retained sequence number (0 when empty).
    pub fn first_seq(&self) -> u64 {
        self.ring.front().map(|r| r.seq).unwrap_or(0)
    }

    /// Newest sequence number ever assigned (0 before the first push).
    pub fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    /// Records with `seq > since`, ascending, truncated to `limit`.
    /// `dropped` is set when the ring evicted records the client has not
    /// seen (i.e. `since + 1 < first_seq` while such records existed).
    pub fn since(&self, since: u64, limit: usize) -> EventsPage {
        let first = self.first_seq();
        let dropped = self.last_seq() > since && first > since + 1;
        // seq values are dense (one per push), so the start offset is
        // computable without scanning.
        let start = if first == 0 || since < first {
            0
        } else {
            (since - first + 1) as usize
        };
        let events: Vec<EventRecord> =
            self.ring.iter().skip(start).take(limit).cloned().collect();
        EventsPage { events, dropped, first_seq: first, last_seq: self.last_seq() }
    }

    /// Iterate over all retained records, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &EventRecord> {
        self.ring.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_n(log: &mut EventLog, n: u64) {
        for i in 0..n {
            log.push(i as f64, EventKind::Arrival { job: i });
        }
    }

    #[test]
    fn seq_is_monotonic_and_dense() {
        let mut log = EventLog::new(4);
        push_n(&mut log, 10);
        let seqs: Vec<u64> = log.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![7, 8, 9, 10], "ring keeps the newest, seqs never reset");
        assert_eq!(log.first_seq(), 7);
        assert_eq!(log.last_seq(), 10);
        assert_eq!(log.len(), 4);
    }

    #[test]
    fn since_before_eviction_returns_tail() {
        let mut log = EventLog::new(100);
        push_n(&mut log, 5);
        let page = log.since(2, 100);
        assert!(!page.dropped);
        assert_eq!(page.events.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![3, 4, 5]);
        assert_eq!(page.last_seq, 5);
    }

    #[test]
    fn since_across_eviction_flags_dropped() {
        let mut log = EventLog::new(3);
        push_n(&mut log, 10); // retained: 8, 9, 10
        let page = log.since(5, 100);
        assert!(page.dropped, "seqs 6..=7 were evicted unseen");
        assert_eq!(page.events.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![8, 9, 10]);
        // A client that already saw everything is not "dropped".
        let page = log.since(10, 100);
        assert!(!page.dropped);
        assert!(page.events.is_empty());
        // The boundary: since = first_seq - 1 has no gap.
        let page = log.since(7, 100);
        assert!(!page.dropped);
        assert_eq!(page.events.len(), 3);
    }

    #[test]
    fn since_respects_limit() {
        let mut log = EventLog::new(100);
        push_n(&mut log, 50);
        let page = log.since(0, 10);
        assert_eq!(page.events.len(), 10);
        assert_eq!(page.events.first().unwrap().seq, 1);
        assert_eq!(page.events.last().unwrap().seq, 10);
        // Resume from the page end.
        let page2 = log.since(page.events.last().unwrap().seq, 10);
        assert_eq!(page2.events.first().unwrap().seq, 11);
    }

    #[test]
    fn empty_log_page() {
        let log = EventLog::new(8);
        let page = log.since(0, 10);
        assert!(page.events.is_empty());
        assert!(!page.dropped);
        assert_eq!(page.first_seq, 0);
        assert_eq!(page.last_seq, 0);
    }

    #[test]
    fn reject_reason_bijection() {
        for r in [
            RejectReason::AdmissionInfeasible,
            RejectReason::AttemptsExhausted,
            RejectReason::Unplaceable,
            RejectReason::RunEnded,
        ] {
            assert_eq!(RejectReason::from_wire(r.as_str()), Some(r));
        }
        assert_eq!(RejectReason::from_wire("cosmic_rays"), None);
    }
}
