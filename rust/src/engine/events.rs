//! The cluster event log: a bounded, ring-buffered audit stream of
//! everything the scheduling engine did.
//!
//! Operators of an elastic heterogeneous cluster need to answer "what
//! happened?" without replaying a trace: which nodes joined or left, which
//! jobs were preempted by a drain, what plan a placement chose, why a job
//! was rejected. Every [`crate::engine::ClusterEvent`] the engine processes
//! (and every effect it produces) is appended here as an [`EventRecord`]
//! with a **monotonically increasing sequence number** and the engine-clock
//! timestamp.
//!
//! The log is a fixed-capacity ring: old records are evicted
//! oldest-first, but sequence numbers never reset, so a client polling
//! `GET /v1/cluster/events?since=<seq>` can detect a gap (eviction outran
//! its polling) via the `dropped` flag instead of silently missing events.
//! `RoundTick`s are deliberately **not** logged — an idle live coordinator
//! ticking every few tens of milliseconds would flood the ring with noise.

use crate::cluster::NodeId;
use crate::job::JobId;
use crate::util::json::Json;
use std::collections::VecDeque;

/// Why the engine rejected a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// Admission control: MARP found no feasible plan on this cluster.
    AdmissionInfeasible,
    /// The job exhausted its scheduling-attempt budget (OOM retries or
    /// preemptions past `EngineConfig::max_attempts`).
    AttemptsExhausted,
    /// The cluster was fully idle and the scheduler still could not place
    /// the job — it never will.
    Unplaceable,
    /// The run ended (simulation time cap / final drain) while the job was
    /// still queued. Unlike `Unplaceable`, the job may have been perfectly
    /// placeable — it just never got resources before the end.
    RunEnded,
}

impl RejectReason {
    /// Wire name (used by the `/v1/cluster/events` DTOs).
    pub fn as_str(self) -> &'static str {
        match self {
            RejectReason::AdmissionInfeasible => "admission_infeasible",
            RejectReason::AttemptsExhausted => "attempts_exhausted",
            RejectReason::Unplaceable => "unplaceable",
            RejectReason::RunEnded => "run_ended",
        }
    }

    /// Inverse of [`RejectReason::as_str`].
    pub fn from_wire(s: &str) -> Option<Self> {
        match s {
            "admission_infeasible" => Some(RejectReason::AdmissionInfeasible),
            "attempts_exhausted" => Some(RejectReason::AttemptsExhausted),
            "unplaceable" => Some(RejectReason::Unplaceable),
            "run_ended" => Some(RejectReason::RunEnded),
            _ => None,
        }
    }
}

/// One thing that happened on the cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A job entered the pending queue.
    Arrival { job: JobId },
    /// A job started running under the chosen plan.
    Placed {
        job: JobId,
        /// Placement epoch (increments per start of the same job).
        epoch: u64,
        /// Scheduling attempts including this one.
        attempts: u32,
        gpus: u32,
        /// Data-parallel degree of the chosen plan.
        d: u32,
        /// Tensor-parallel degree of the chosen plan.
        t: u32,
        /// Sorted `(node, gpu-count)` parts of the allocation.
        parts: Vec<(NodeId, u32)>,
        /// The plan is memory-oblivious and will OOM (baselines only).
        will_oom: bool,
    },
    /// A running job completed; `epoch` is the run it belongs to.
    Finished { job: JobId, epoch: u64 },
    /// A running job hit an out-of-memory crash. `requeued` is false when
    /// the attempt budget was exhausted (the job was rejected instead).
    Oomed { job: JobId, epoch: u64, requeued: bool },
    /// The device-memory byte ledger observed a dispatch that does not fit:
    /// the job's observed per-GPU peak exceeds `node`'s capacity. A real
    /// OOM (an `Oomed` record follows once the crash is processed), with
    /// the predicted-vs-observed bytes that produced it.
    OomObserved {
        job: JobId,
        epoch: u64,
        node: NodeId,
        predicted_bytes: u64,
        observed_bytes: u64,
        capacity_bytes: u64,
    },
    /// A node retirement asked this job to drain gracefully: finish the
    /// in-flight step, checkpoint, then release by `deadline_s`.
    DrainRequested { job: JobId, epoch: u64, node: NodeId, deadline_s: f64 },
    /// A draining job checkpointed and released its GPUs; it resumes from
    /// `steps_ckpt` (cumulative) on its next placement. `state_digest`
    /// fingerprints the snapshot.
    Drained { job: JobId, epoch: u64, node: NodeId, steps_ckpt: u64, state_digest: u64 },
    /// A placement picked up a checkpoint: the job restarts from
    /// `steps_ckpt` instead of step 0.
    ResumedFromCkpt { job: JobId, epoch: u64, steps_ckpt: u64 },
    /// A job lost its GPUs to a node retirement and went back to the queue.
    Preempted { job: JobId, node: NodeId },
    /// A job reached the `Rejected` terminal state.
    Rejected { job: JobId, reason: RejectReason },
    /// A job was cancelled by the user.
    Cancelled { job: JobId, was_running: bool },
    /// Elasticity: a node joined the cluster.
    NodeJoined { node: NodeId, gpu: String, gpus: u32 },
    /// Elasticity: a node left; `preempted` lists every job it displaced
    /// (each also gets its own `Preempted`, `Drained`, or `Rejected`
    /// record). Under graceful drain this marks the *start* of the
    /// retirement — the node still hosts its draining jobs.
    NodeLeft { node: NodeId, preempted: Vec<JobId> },
    /// A drain-mode retirement completed — the node's capacity reached
    /// zero (immediately for an idle node, after the last resident job
    /// released otherwise) and the hardware is safe to power off. Every
    /// graceful-drain `NodeLeft` is eventually followed by one of these;
    /// instant-preemption leaves retire within their `NodeLeft` record and
    /// do not emit it.
    NodeRetired { node: NodeId },
    /// A node crashed — missed its heartbeat lease or was killed by fault
    /// injection. Unlike a graceful `NodeLeft`, there is **no** drain
    /// grace: every hosted job loses its work back to the last checkpoint
    /// floor and requeues after a crash-backoff hold (without burning an
    /// attempt — the node failed, not the job).
    NodeCrashed { node: NodeId, preempted: Vec<JobId> },
    /// A node crossed the crash threshold (≥ K crashes inside the
    /// quarantine window) and is excluded from placement until `until_s`.
    NodeQuarantined { node: NodeId, until_s: f64 },
    /// A quarantined node finished probation and accepts placements again.
    NodeProbation { node: NodeId },
    /// A node's effective throughput changed: new placements touching it
    /// run at `factor` × modeled speed (a straggler while `factor < 1`;
    /// `factor = 1` ends the slowdown).
    NodeSlowdown { node: NodeId, factor: f64 },
}

impl EventKind {
    /// Stable wire name of this kind — the `kind` field emitted by
    /// [`EventKind::to_json`], also used as the `kind` label of the
    /// `frenzy_engine_events_total` telemetry counter.
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::Arrival { .. } => "arrival",
            EventKind::Placed { .. } => "placed",
            EventKind::Finished { .. } => "finished",
            EventKind::Oomed { .. } => "oomed",
            EventKind::OomObserved { .. } => "oom_observed",
            EventKind::DrainRequested { .. } => "drain_requested",
            EventKind::Drained { .. } => "drained",
            EventKind::ResumedFromCkpt { .. } => "resumed_from_ckpt",
            EventKind::Preempted { .. } => "preempted",
            EventKind::Rejected { .. } => "rejected",
            EventKind::Cancelled { .. } => "cancelled",
            EventKind::NodeJoined { .. } => "node_joined",
            EventKind::NodeLeft { .. } => "node_left",
            EventKind::NodeRetired { .. } => "node_retired",
            EventKind::NodeCrashed { .. } => "node_crash",
            EventKind::NodeQuarantined { .. } => "node_quarantined",
            EventKind::NodeProbation { .. } => "node_probation",
            EventKind::NodeSlowdown { .. } => "node_slowdown",
        }
    }

    /// Serialize for the durable snapshot of the event-log ring. Kind and
    /// field names follow the `/v1/cluster/events` wire DTOs.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match self {
            EventKind::Arrival { job } => {
                j.set("kind", "arrival").set("job", *job);
            }
            EventKind::Placed { job, epoch, attempts, gpus, d, t, parts, will_oom } => {
                let parts: Vec<Json> = parts
                    .iter()
                    .map(|&(n, c)| Json::from(vec![Json::from(n), Json::from(c)]))
                    .collect();
                j.set("kind", "placed")
                    .set("job", *job)
                    .set("epoch", *epoch)
                    .set("attempts", *attempts)
                    .set("gpus", *gpus)
                    .set("d", *d)
                    .set("t", *t)
                    .set("parts", Json::Arr(parts))
                    .set("will_oom", *will_oom);
            }
            EventKind::Finished { job, epoch } => {
                j.set("kind", "finished").set("job", *job).set("epoch", *epoch);
            }
            EventKind::Oomed { job, epoch, requeued } => {
                j.set("kind", "oomed")
                    .set("job", *job)
                    .set("epoch", *epoch)
                    .set("requeued", *requeued);
            }
            EventKind::OomObserved {
                job,
                epoch,
                node,
                predicted_bytes,
                observed_bytes,
                capacity_bytes,
            } => {
                j.set("kind", "oom_observed")
                    .set("job", *job)
                    .set("epoch", *epoch)
                    .set("node", *node)
                    .set("predicted_bytes", *predicted_bytes)
                    .set("observed_bytes", *observed_bytes)
                    .set("capacity_bytes", *capacity_bytes);
            }
            EventKind::DrainRequested { job, epoch, node, deadline_s } => {
                j.set("kind", "drain_requested")
                    .set("job", *job)
                    .set("epoch", *epoch)
                    .set("node", *node)
                    .set("deadline_s", *deadline_s);
            }
            EventKind::Drained { job, epoch, node, steps_ckpt, state_digest } => {
                j.set("kind", "drained")
                    .set("job", *job)
                    .set("epoch", *epoch)
                    .set("node", *node)
                    .set("steps_ckpt", *steps_ckpt)
                    .set("state_digest", *state_digest);
            }
            EventKind::ResumedFromCkpt { job, epoch, steps_ckpt } => {
                j.set("kind", "resumed_from_ckpt")
                    .set("job", *job)
                    .set("epoch", *epoch)
                    .set("steps_ckpt", *steps_ckpt);
            }
            EventKind::Preempted { job, node } => {
                j.set("kind", "preempted").set("job", *job).set("node", *node);
            }
            EventKind::Rejected { job, reason } => {
                j.set("kind", "rejected").set("job", *job).set("reason", reason.as_str());
            }
            EventKind::Cancelled { job, was_running } => {
                j.set("kind", "cancelled").set("job", *job).set("was_running", *was_running);
            }
            EventKind::NodeJoined { node, gpu, gpus } => {
                j.set("kind", "node_joined")
                    .set("node", *node)
                    .set("gpu", gpu.as_str())
                    .set("gpus", *gpus);
            }
            EventKind::NodeLeft { node, preempted } => {
                let jobs: Vec<Json> = preempted.iter().map(|&id| Json::from(id)).collect();
                j.set("kind", "node_left").set("node", *node).set("preempted", Json::Arr(jobs));
            }
            EventKind::NodeRetired { node } => {
                j.set("kind", "node_retired").set("node", *node);
            }
            EventKind::NodeCrashed { node, preempted } => {
                let jobs: Vec<Json> = preempted.iter().map(|&id| Json::from(id)).collect();
                j.set("kind", "node_crash").set("node", *node).set("preempted", Json::Arr(jobs));
            }
            EventKind::NodeQuarantined { node, until_s } => {
                j.set("kind", "node_quarantined").set("node", *node).set("until_s", *until_s);
            }
            EventKind::NodeProbation { node } => {
                j.set("kind", "node_probation").set("node", *node);
            }
            EventKind::NodeSlowdown { node, factor } => {
                j.set("kind", "node_slowdown").set("node", *node).set("factor", *factor);
            }
        }
        j
    }

    /// Inverse of [`EventKind::to_json`].
    pub fn from_json(j: &Json) -> Result<EventKind, String> {
        let kind = j.get("kind").and_then(Json::as_str).ok_or("missing field 'kind'")?;
        Ok(match kind {
            "arrival" => EventKind::Arrival { job: f_u64(j, "job")? },
            "placed" => {
                let parts_j = j.get("parts").and_then(Json::as_arr).ok_or("placed: no parts")?;
                let mut parts = Vec::with_capacity(parts_j.len());
                for p in parts_j {
                    let pair = p.as_arr().filter(|a| a.len() == 2).ok_or("placed: bad part")?;
                    let node = pair[0].as_usize().ok_or("placed: bad part node")?;
                    let count = pair[1].as_u64().ok_or("placed: bad part count")? as u32;
                    parts.push((node, count));
                }
                EventKind::Placed {
                    job: f_u64(j, "job")?,
                    epoch: f_u64(j, "epoch")?,
                    attempts: f_u32(j, "attempts")?,
                    gpus: f_u32(j, "gpus")?,
                    d: f_u32(j, "d")?,
                    t: f_u32(j, "t")?,
                    parts,
                    will_oom: f_bool(j, "will_oom")?,
                }
            }
            "finished" => {
                EventKind::Finished { job: f_u64(j, "job")?, epoch: f_u64(j, "epoch")? }
            }
            "oomed" => EventKind::Oomed {
                job: f_u64(j, "job")?,
                epoch: f_u64(j, "epoch")?,
                requeued: f_bool(j, "requeued")?,
            },
            "oom_observed" => EventKind::OomObserved {
                job: f_u64(j, "job")?,
                epoch: f_u64(j, "epoch")?,
                node: f_usize(j, "node")?,
                predicted_bytes: f_u64(j, "predicted_bytes")?,
                observed_bytes: f_u64(j, "observed_bytes")?,
                capacity_bytes: f_u64(j, "capacity_bytes")?,
            },
            "drain_requested" => EventKind::DrainRequested {
                job: f_u64(j, "job")?,
                epoch: f_u64(j, "epoch")?,
                node: f_usize(j, "node")?,
                deadline_s: f_f64(j, "deadline_s")?,
            },
            "drained" => EventKind::Drained {
                job: f_u64(j, "job")?,
                epoch: f_u64(j, "epoch")?,
                node: f_usize(j, "node")?,
                steps_ckpt: f_u64(j, "steps_ckpt")?,
                state_digest: f_u64(j, "state_digest")?,
            },
            "resumed_from_ckpt" => EventKind::ResumedFromCkpt {
                job: f_u64(j, "job")?,
                epoch: f_u64(j, "epoch")?,
                steps_ckpt: f_u64(j, "steps_ckpt")?,
            },
            "preempted" => {
                EventKind::Preempted { job: f_u64(j, "job")?, node: f_usize(j, "node")? }
            }
            "rejected" => EventKind::Rejected {
                job: f_u64(j, "job")?,
                reason: j
                    .get("reason")
                    .and_then(Json::as_str)
                    .and_then(RejectReason::from_wire)
                    .ok_or("rejected: bad reason")?,
            },
            "cancelled" => EventKind::Cancelled {
                job: f_u64(j, "job")?,
                was_running: f_bool(j, "was_running")?,
            },
            "node_joined" => EventKind::NodeJoined {
                node: f_usize(j, "node")?,
                gpu: j
                    .get("gpu")
                    .and_then(Json::as_str)
                    .ok_or("node_joined: no gpu")?
                    .to_string(),
                gpus: f_u32(j, "gpus")?,
            },
            "node_left" => {
                let jobs_j =
                    j.get("preempted").and_then(Json::as_arr).ok_or("node_left: no preempted")?;
                let preempted = jobs_j
                    .iter()
                    .map(|v| v.as_u64().ok_or("node_left: bad job id".to_string()))
                    .collect::<Result<Vec<u64>, _>>()?;
                EventKind::NodeLeft { node: f_usize(j, "node")?, preempted }
            }
            "node_retired" => EventKind::NodeRetired { node: f_usize(j, "node")? },
            "node_crash" => {
                let jobs_j = j
                    .get("preempted")
                    .and_then(Json::as_arr)
                    .ok_or("node_crash: no preempted")?;
                let preempted = jobs_j
                    .iter()
                    .map(|v| v.as_u64().ok_or("node_crash: bad job id".to_string()))
                    .collect::<Result<Vec<u64>, _>>()?;
                EventKind::NodeCrashed { node: f_usize(j, "node")?, preempted }
            }
            "node_quarantined" => EventKind::NodeQuarantined {
                node: f_usize(j, "node")?,
                until_s: f_f64(j, "until_s")?,
            },
            "node_probation" => EventKind::NodeProbation { node: f_usize(j, "node")? },
            "node_slowdown" => EventKind::NodeSlowdown {
                node: f_usize(j, "node")?,
                factor: f_f64(j, "factor")?,
            },
            other => return Err(format!("unknown event kind '{other}'")),
        })
    }
}

fn f_u64(j: &Json, k: &str) -> Result<u64, String> {
    j.get(k).and_then(Json::as_u64).ok_or_else(|| format!("missing field '{k}'"))
}

fn f_u32(j: &Json, k: &str) -> Result<u32, String> {
    f_u64(j, k).map(|v| v as u32)
}

fn f_usize(j: &Json, k: &str) -> Result<usize, String> {
    j.get(k).and_then(Json::as_usize).ok_or_else(|| format!("missing field '{k}'"))
}

fn f_f64(j: &Json, k: &str) -> Result<f64, String> {
    j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing field '{k}'"))
}

fn f_bool(j: &Json, k: &str) -> Result<bool, String> {
    j.get(k).and_then(Json::as_bool).ok_or_else(|| format!("missing field '{k}'"))
}

/// One entry in the cluster event log.
#[derive(Debug, Clone, PartialEq)]
pub struct EventRecord {
    /// Monotonically increasing sequence number, starting at 1. Never
    /// reused, even after ring eviction.
    pub seq: u64,
    /// Engine-clock time of the event (virtual seconds in simulation,
    /// seconds since start for a live coordinator).
    pub time: f64,
    pub kind: EventKind,
}

impl EventRecord {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("seq", self.seq).set("time", self.time).set("event", self.kind.to_json());
        j
    }

    pub fn from_json(j: &Json) -> Result<EventRecord, String> {
        Ok(EventRecord {
            seq: f_u64(j, "seq")?,
            time: f_f64(j, "time")?,
            kind: EventKind::from_json(j.get("event").ok_or("missing field 'event'")?)?,
        })
    }
}

/// A page of events returned by [`EventLog::since`].
#[derive(Debug, Clone, PartialEq)]
pub struct EventsPage {
    /// Records with `seq > since`, ascending, at most the requested limit.
    pub events: Vec<EventRecord>,
    /// True when events after `since` were already evicted from the ring —
    /// the client has a gap it can never recover from this log.
    pub dropped: bool,
    /// Oldest sequence number still retained (0 when the log is empty).
    pub first_seq: u64,
    /// Newest sequence number ever assigned (0 when nothing was logged).
    pub last_seq: u64,
}

/// Bounded ring buffer of [`EventRecord`]s with stable sequence numbers.
#[derive(Debug)]
pub struct EventLog {
    ring: VecDeque<EventRecord>,
    cap: usize,
    next_seq: u64,
}

impl EventLog {
    /// `cap` is the maximum number of retained records (at least 1).
    pub fn new(cap: usize) -> Self {
        Self { ring: VecDeque::new(), cap: cap.max(1), next_seq: 1 }
    }

    /// Append a record; evicts the oldest when full. Returns the assigned
    /// sequence number.
    pub fn push(&mut self, time: f64, kind: EventKind) -> u64 {
        // Single telemetry tap covering every engine effect on both the sim
        // and live paths. Write-only: never read back into engine state.
        if let Some(c) = crate::obs::reg().engine.event(kind.label()) {
            c.inc();
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        if self.ring.len() == self.cap {
            self.ring.pop_front();
        }
        self.ring.push_back(EventRecord { seq, time, kind });
        seq
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Oldest retained sequence number (0 when empty).
    pub fn first_seq(&self) -> u64 {
        self.ring.front().map(|r| r.seq).unwrap_or(0)
    }

    /// Newest sequence number ever assigned (0 before the first push).
    pub fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    /// Records with `seq > since`, ascending, truncated to `limit`.
    /// `dropped` is set when the ring evicted records the client has not
    /// seen (i.e. `since + 1 < first_seq` while such records existed).
    pub fn since(&self, since: u64, limit: usize) -> EventsPage {
        let first = self.first_seq();
        let dropped = self.last_seq() > since && first > since + 1;
        // seq values are dense (one per push), so the start offset is
        // computable without scanning.
        let start = if first == 0 || since < first {
            0
        } else {
            (since - first + 1) as usize
        };
        let events: Vec<EventRecord> =
            self.ring.iter().skip(start).take(limit).cloned().collect();
        EventsPage { events, dropped, first_seq: first, last_seq: self.last_seq() }
    }

    /// Iterate over all retained records, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &EventRecord> {
        self.ring.iter()
    }

    /// Serialize the retained ring and sequence cursor for a durable
    /// snapshot: seqs keep ascending across a coordinator restart, so an
    /// `events --follow` client can resume from its cursor.
    pub fn to_json(&self) -> Json {
        let ring: Vec<Json> = self.ring.iter().map(EventRecord::to_json).collect();
        let mut j = Json::obj();
        j.set("next_seq", self.next_seq).set("ring", Json::Arr(ring));
        j
    }

    /// Rebuild a log of capacity `cap` from [`EventLog::to_json`] output.
    /// If `cap` shrank since the snapshot, the oldest records are evicted.
    pub fn from_json(j: &Json, cap: usize) -> Result<EventLog, String> {
        let next_seq = f_u64(j, "next_seq")?;
        if next_seq == 0 {
            return Err("bad next_seq 0".into());
        }
        let ring_j = j.get("ring").and_then(Json::as_arr).ok_or("missing field 'ring'")?;
        let mut log = EventLog::new(cap);
        for r in ring_j {
            log.ring.push_back(EventRecord::from_json(r)?);
        }
        while log.ring.len() > log.cap {
            log.ring.pop_front();
        }
        log.next_seq = next_seq;
        Ok(log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_n(log: &mut EventLog, n: u64) {
        for i in 0..n {
            log.push(i as f64, EventKind::Arrival { job: i });
        }
    }

    #[test]
    fn seq_is_monotonic_and_dense() {
        let mut log = EventLog::new(4);
        push_n(&mut log, 10);
        let seqs: Vec<u64> = log.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![7, 8, 9, 10], "ring keeps the newest, seqs never reset");
        assert_eq!(log.first_seq(), 7);
        assert_eq!(log.last_seq(), 10);
        assert_eq!(log.len(), 4);
    }

    #[test]
    fn since_before_eviction_returns_tail() {
        let mut log = EventLog::new(100);
        push_n(&mut log, 5);
        let page = log.since(2, 100);
        assert!(!page.dropped);
        assert_eq!(page.events.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![3, 4, 5]);
        assert_eq!(page.last_seq, 5);
    }

    #[test]
    fn since_across_eviction_flags_dropped() {
        let mut log = EventLog::new(3);
        push_n(&mut log, 10); // retained: 8, 9, 10
        let page = log.since(5, 100);
        assert!(page.dropped, "seqs 6..=7 were evicted unseen");
        assert_eq!(page.events.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![8, 9, 10]);
        // A client that already saw everything is not "dropped".
        let page = log.since(10, 100);
        assert!(!page.dropped);
        assert!(page.events.is_empty());
        // The boundary: since = first_seq - 1 has no gap.
        let page = log.since(7, 100);
        assert!(!page.dropped);
        assert_eq!(page.events.len(), 3);
    }

    #[test]
    fn since_respects_limit() {
        let mut log = EventLog::new(100);
        push_n(&mut log, 50);
        let page = log.since(0, 10);
        assert_eq!(page.events.len(), 10);
        assert_eq!(page.events.first().unwrap().seq, 1);
        assert_eq!(page.events.last().unwrap().seq, 10);
        // Resume from the page end.
        let page2 = log.since(page.events.last().unwrap().seq, 10);
        assert_eq!(page2.events.first().unwrap().seq, 11);
    }

    #[test]
    fn empty_log_page() {
        let log = EventLog::new(8);
        let page = log.since(0, 10);
        assert!(page.events.is_empty());
        assert!(!page.dropped);
        assert_eq!(page.first_seq, 0);
        assert_eq!(page.last_seq, 0);
    }

    #[test]
    fn event_kind_json_roundtrip() {
        let kinds = vec![
            EventKind::Arrival { job: 7 },
            EventKind::Placed {
                job: 7,
                epoch: 2,
                attempts: 3,
                gpus: 4,
                d: 2,
                t: 2,
                parts: vec![(0, 2), (3, 2)],
                will_oom: false,
            },
            EventKind::Finished { job: 7, epoch: 2 },
            EventKind::Oomed { job: 7, epoch: 2, requeued: true },
            EventKind::OomObserved {
                job: 7,
                epoch: 2,
                node: 3,
                predicted_bytes: 11_000_000_000,
                observed_bytes: 12_000_000_000,
                capacity_bytes: 11_811_160_064,
            },
            EventKind::DrainRequested { job: 7, epoch: 2, node: 3, deadline_s: 12.75 },
            EventKind::Drained { job: 7, epoch: 2, node: 3, steps_ckpt: 100, state_digest: 42 },
            EventKind::ResumedFromCkpt { job: 7, epoch: 3, steps_ckpt: 100 },
            EventKind::Preempted { job: 7, node: 3 },
            EventKind::Rejected { job: 7, reason: RejectReason::Unplaceable },
            EventKind::Cancelled { job: 7, was_running: true },
            EventKind::NodeJoined { node: 5, gpu: "A100-40G".into(), gpus: 8 },
            EventKind::NodeLeft { node: 5, preempted: vec![7, 9] },
            EventKind::NodeRetired { node: 5 },
            EventKind::NodeCrashed { node: 5, preempted: vec![7, 9] },
            EventKind::NodeQuarantined { node: 5, until_s: 420.5 },
            EventKind::NodeProbation { node: 5 },
            EventKind::NodeSlowdown { node: 5, factor: 0.25 },
        ];
        for k in kinds {
            let text = k.to_json().to_string_compact();
            let back = EventKind::from_json(&crate::util::json::parse(&text).unwrap())
                .unwrap_or_else(|e| panic!("{text}: {e}"));
            assert_eq!(back, k, "{text}");
            // The telemetry label is the wire name.
            assert_eq!(k.to_json().get("kind").and_then(Json::as_str), Some(k.label()), "{text}");
        }
        assert!(EventKind::from_json(&Json::obj()).is_err());
    }

    #[test]
    fn event_log_json_roundtrip_preserves_seqs() {
        let mut log = EventLog::new(4);
        push_n(&mut log, 10); // retained: 7..=10
        let text = log.to_json().to_string_compact();
        let back = EventLog::from_json(&crate::util::json::parse(&text).unwrap(), 4).unwrap();
        assert_eq!(back.first_seq(), 7);
        assert_eq!(back.last_seq(), 10);
        assert_eq!(back.since(0, 100), log.since(0, 100));
        // Next push continues the sequence instead of restarting.
        let seq = {
            let mut b = back;
            b.push(11.0, EventKind::Arrival { job: 99 })
        };
        assert_eq!(seq, 11);
        // A shrunken cap evicts oldest-first on restore.
        let small = EventLog::from_json(&crate::util::json::parse(&text).unwrap(), 2).unwrap();
        assert_eq!(small.first_seq(), 9);
        assert_eq!(small.last_seq(), 10);
    }

    #[test]
    fn reject_reason_bijection() {
        for r in [
            RejectReason::AdmissionInfeasible,
            RejectReason::AttemptsExhausted,
            RejectReason::Unplaceable,
            RejectReason::RunEnded,
        ] {
            assert_eq!(RejectReason::from_wire(r.as_str()), Some(r));
        }
        assert_eq!(RejectReason::from_wire("cosmic_rays"), None);
    }
}
