//! Time sources driving the [`super::SchedulingEngine`].
//!
//! The engine never reads wall time or owns an event queue itself — it asks
//! a [`Clock`]:
//!
//! * [`VirtualClock`] — discrete-event time: a binary-heap of future
//!   [`ClusterEvent`]s (what used to be the simulator's private event loop).
//!   `schedule` accepts future events, so the engine's own Finish/Oom
//!   predictions drive the run.
//! * [`WallClock`] — real elapsed seconds for the live coordinator.
//!   `schedule` declines: real completions arrive from the executor as
//!   messages, so the engine reports placements to the driver instead of
//!   predicting their finish times.
//! * [`ReplayClock`] — recovery time: pinned to the timestamp of the WAL
//!   record being replayed. `schedule` declines (the WAL already holds the
//!   outcome of every prediction) and no ticks are promised, so replay is
//!   pure event application with no side timers.

use super::ClusterEvent;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

/// The engine's view of time.
pub trait Clock {
    /// Current time in seconds (virtual, or since coordinator start).
    fn now(&self) -> f64;

    /// Ask for `ev` to be delivered at absolute time `time`. Virtual clocks
    /// enqueue it and return `true`; wall clocks return `false` — delivery
    /// of future events is then the driver's job (executor callbacks).
    fn schedule(&mut self, time: f64, ev: ClusterEvent) -> bool;

    /// True when the driver guarantees periodic `RoundTick` delivery even
    /// though `schedule` declines (a wall clock backed by the coordinator's
    /// round-timer thread). Interval schedulers may then *defer* a round to
    /// the next tick instead of rounding immediately; on a bare wall clock
    /// (no timer) deferring would stall forever, so the engine rounds
    /// immediately there.
    fn delivers_ticks(&self) -> bool {
        false
    }
}

struct Entry {
    time: f64,
    seq: u64,
    ev: ClusterEvent,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earlier time first, then insertion order. `total_cmp`
        // keeps the ordering total even for a NaN timestamp — the old
        // simulator's `partial_cmp(..).unwrap()` here could panic the whole
        // event loop on one bad float (NaN sorts after every real time, so
        // a poisoned event drains last instead of aborting the run).
        other.time.total_cmp(&self.time).then(other.seq.cmp(&self.seq))
    }
}

/// Discrete-event time: a heap of pending events plus the current instant.
#[derive(Default)]
pub struct VirtualClock {
    now: f64,
    seq: u64,
    heap: BinaryHeap<Entry>,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pop the earliest event, advancing `now` to its timestamp.
    pub fn pop(&mut self) -> Option<(f64, ClusterEvent)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        Some((e.time, e.ev))
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.now
    }

    fn schedule(&mut self, time: f64, ev: ClusterEvent) -> bool {
        self.seq += 1;
        self.heap.push(Entry { time, seq: self.seq, ev });
        true
    }
}

/// Real time since construction — the live coordinator's clock. After a
/// crash-recovery the clock resumes from the recovered engine time via
/// `offset`, so engine time never runs backwards across a restart.
pub struct WallClock {
    t0: Instant,
    /// Added to the elapsed time: the engine time recovered from the WAL
    /// (0.0 for a fresh start).
    offset: f64,
    /// Set when a round-timer thread feeds `ClusterEvent::RoundTick` into
    /// the driver's mailbox (see `CoordinatorConfig::round_tick_period_s`).
    ticking: bool,
}

impl WallClock {
    pub fn new() -> Self {
        Self { t0: Instant::now(), offset: 0.0, ticking: false }
    }

    /// A wall clock whose driver runs a round-timer thread: interval
    /// schedulers defer rounds to timer ticks instead of rounding
    /// immediately, matching the virtual clock's semantics.
    pub fn with_round_timer() -> Self {
        Self { t0: Instant::now(), offset: 0.0, ticking: true }
    }

    /// A wall clock resuming at `offset` seconds — the engine time reached
    /// by WAL replay. New WAL records must carry timestamps ≥ every
    /// replayed one, which a clock restarting at zero would violate.
    /// `ticking` mirrors the fresh-start choice: true when the coordinator
    /// runs a round-timer thread (interval schedulers), false otherwise.
    pub fn resumed_at(offset: f64, ticking: bool) -> Self {
        Self { t0: Instant::now(), offset, ticking }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() + self.offset
    }

    fn schedule(&mut self, _time: f64, _ev: ClusterEvent) -> bool {
        false
    }

    fn delivers_ticks(&self) -> bool {
        self.ticking
    }
}

/// Recovery time: pinned to the WAL record under replay. The recovery loop
/// sets `t` to each record's timestamp before handing the event to the
/// engine, so replayed state transitions observe exactly the times the
/// original run observed.
#[derive(Debug, Default)]
pub struct ReplayClock {
    t: f64,
}

impl ReplayClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pin the clock to the timestamp of the record about to be replayed.
    pub fn set(&mut self, t: f64) {
        self.t = t;
    }
}

impl Clock for ReplayClock {
    fn now(&self) -> f64 {
        self.t
    }

    /// Declined: every future the engine would predict is already recorded
    /// (and will be re-armed after replay from the recovered running set).
    fn schedule(&mut self, _time: f64, _ev: ClusterEvent) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_then_insertion_order() {
        let mut c = VirtualClock::new();
        c.schedule(5.0, ClusterEvent::RoundTick);
        c.schedule(1.0, ClusterEvent::Finish { job: 1, epoch: 1 });
        c.schedule(1.0, ClusterEvent::Finish { job: 2, epoch: 1 });
        assert_eq!(c.len(), 3);
        let (t1, e1) = c.pop().unwrap();
        assert_eq!(t1, 1.0);
        assert!(matches!(e1, ClusterEvent::Finish { job: 1, .. }));
        assert_eq!(c.now(), 1.0);
        let (_, e2) = c.pop().unwrap();
        assert!(matches!(e2, ClusterEvent::Finish { job: 2, .. }), "ties break by insertion order");
        assert_eq!(c.pop().unwrap().0, 5.0);
        assert!(c.is_empty());
    }

    #[test]
    fn nan_timestamp_cannot_panic_the_heap() {
        // The old sim's Event::cmp used partial_cmp().unwrap() — one NaN
        // submit time aborted the whole run. total_cmp sorts NaN after every
        // finite time instead.
        let mut c = VirtualClock::new();
        c.schedule(f64::NAN, ClusterEvent::RoundTick);
        c.schedule(2.0, ClusterEvent::RoundTick);
        c.schedule(f64::NAN, ClusterEvent::RoundTick);
        c.schedule(1.0, ClusterEvent::RoundTick);
        assert_eq!(c.pop().unwrap().0, 1.0);
        assert_eq!(c.pop().unwrap().0, 2.0);
        assert!(c.pop().unwrap().0.is_nan());
        assert!(c.pop().unwrap().0.is_nan());
        assert!(c.pop().is_none());
    }

    #[test]
    fn wall_clock_declines_future_events_and_advances() {
        let mut w = WallClock::new();
        assert!(!w.schedule(10.0, ClusterEvent::RoundTick));
        assert!(!w.delivers_ticks());
        let a = w.now();
        let b = w.now();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn timer_backed_wall_clock_promises_ticks_but_still_declines_schedule() {
        let mut w = WallClock::with_round_timer();
        assert!(w.delivers_ticks());
        assert!(!w.schedule(10.0, ClusterEvent::RoundTick), "delivery is the timer's job");
    }

    #[test]
    fn resumed_wall_clock_never_runs_backwards() {
        let w = WallClock::resumed_at(1234.5, true);
        assert!(w.now() >= 1234.5, "recovered engine time is the floor");
        assert!(w.delivers_ticks());
        assert!(!WallClock::resumed_at(7.0, false).delivers_ticks());
    }

    #[test]
    fn replay_clock_is_pinned_and_inert() {
        let mut r = ReplayClock::new();
        assert_eq!(r.now(), 0.0);
        r.set(42.25);
        assert_eq!(r.now(), 42.25);
        assert!(!r.schedule(99.0, ClusterEvent::RoundTick), "replay predicts nothing");
        assert!(!r.delivers_ticks());
    }
}
